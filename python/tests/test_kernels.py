"""L1 correctness: Pallas kernels vs pure-jnp oracles (hypothesis sweeps).

This is the core correctness signal for the compute layer: every artifact
the Rust engine replays contains these kernels.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import ref
from compile.kernels.conv import conv2d, conv2d_bn_relu
from compile.kernels.elementwise import relu, softmax
from compile.kernels.matmul import matmul, matmul_scale_bias

KEY = jax.random.PRNGKey(0)


def rand(shape, seed=0, dtype=jnp.float32):
    return jax.random.normal(jax.random.PRNGKey(seed), shape, dtype)


# --------------------------------------------------------------------------
# matmul
# --------------------------------------------------------------------------

@settings(max_examples=25, deadline=None)
@given(
    m=st.integers(1, 130),
    k=st.integers(1, 96),
    n=st.integers(1, 130),
    seed=st.integers(0, 2**31 - 1),
)
def test_matmul_matches_ref(m, k, n, seed):
    x = rand((m, k), seed)
    w = rand((k, n), seed + 1)
    np.testing.assert_allclose(matmul(x, w), ref.matmul_ref(x, w), rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("block_m,block_n", [(8, 8), (32, 16), (256, 128), (512, 64)])
def test_matmul_block_size_sweep(block_m, block_n):
    """Block shape must never affect numerics (only the VMEM schedule)."""
    x, w = rand((100, 70), 7), rand((70, 50), 8)
    got = matmul(x, w, block_m=block_m, block_n=block_n)
    np.testing.assert_allclose(got, ref.matmul_ref(x, w), rtol=1e-4, atol=1e-4)


def test_matmul_rejects_bad_shapes():
    with pytest.raises(ValueError):
        matmul(rand((3, 4)), rand((5, 6)))
    with pytest.raises(ValueError):
        matmul(rand((3,)), rand((3, 2)))


@settings(max_examples=15, deadline=None)
@given(
    m=st.integers(1, 64),
    k=st.integers(1, 48),
    n=st.integers(1, 64),
    act=st.sampled_from(["relu", "none"]),
    seed=st.integers(0, 2**31 - 1),
)
def test_matmul_epilogue_matches_ref(m, k, n, act, seed):
    x = rand((m, k), seed)
    w = rand((k, n), seed + 1)
    scale = jnp.abs(rand((n,), seed + 2)) + 0.1
    bias = rand((n,), seed + 3)
    got = matmul_scale_bias(x, w, scale, bias, activation=act)
    want = ref.matmul_scale_bias_ref(x, w, scale, bias, activation=act)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


# --------------------------------------------------------------------------
# conv2d (im2col + Pallas matmul)
# --------------------------------------------------------------------------

@settings(max_examples=20, deadline=None)
@given(
    b=st.integers(1, 4),
    ic=st.integers(1, 8),
    oc=st.integers(1, 8),
    hw=st.integers(3, 12),
    k=st.sampled_from([1, 3, 5]),
    stride=st.sampled_from([1, 2]),
    seed=st.integers(0, 2**31 - 1),
)
def test_conv2d_matches_lax(b, ic, oc, hw, k, stride, seed):
    x = rand((b, ic, hw, hw), seed)
    w = rand((oc, ic, k, k), seed + 1)
    got = conv2d(x, w, stride=stride)
    want = ref.conv2d_ref(x, w, stride=stride)
    np.testing.assert_allclose(got, want, rtol=1e-3, atol=1e-4)


def test_conv2d_channel_mismatch_rejected():
    with pytest.raises(ValueError):
        conv2d(rand((1, 3, 8, 8)), rand((4, 5, 3, 3)))


@settings(max_examples=10, deadline=None)
@given(
    b=st.integers(1, 3),
    ic=st.integers(1, 6),
    oc=st.integers(1, 6),
    hw=st.integers(4, 10),
    seed=st.integers(0, 2**31 - 1),
)
def test_fused_conv_bn_relu_matches_ref(b, ic, oc, hw, seed):
    x = rand((b, ic, hw, hw), seed)
    w = rand((oc, ic, 3, 3), seed + 1)
    scale = jnp.abs(rand((oc,), seed + 2)) + 0.1
    bias = rand((oc,), seed + 3)
    got = conv2d_bn_relu(x, w, scale, bias)
    want = ref.conv2d_bn_relu_ref(x, w, scale, bias)
    np.testing.assert_allclose(got, want, rtol=1e-3, atol=1e-4)
    assert (np.asarray(got) >= 0).all(), "relu epilogue must clamp"


# --------------------------------------------------------------------------
# elementwise
# --------------------------------------------------------------------------

@settings(max_examples=15, deadline=None)
@given(
    dims=st.lists(st.integers(1, 9), min_size=1, max_size=4),
    seed=st.integers(0, 2**31 - 1),
)
def test_relu_matches_ref(dims, seed):
    x = rand(tuple(dims), seed)
    np.testing.assert_allclose(relu(x), ref.relu_ref(x))


def test_relu_large_unaligned():
    x = rand((7, 13, 31, 3), 99)  # numel not a multiple of the block
    np.testing.assert_allclose(relu(x), ref.relu_ref(x))


@settings(max_examples=15, deadline=None)
@given(m=st.integers(1, 80), n=st.integers(1, 64), seed=st.integers(0, 2**31 - 1))
def test_softmax_matches_ref(m, n, seed):
    x = rand((m, n), seed) * 5.0
    np.testing.assert_allclose(softmax(x), ref.softmax_ref(x), rtol=1e-5, atol=1e-6)


def test_softmax_rows_sum_to_one():
    s = np.asarray(softmax(rand((33, 17), 5)))
    np.testing.assert_allclose(s.sum(axis=-1), np.ones(33), rtol=1e-5)


def test_softmax_extreme_values_stable():
    x = jnp.array([[1e4, -1e4, 0.0]])
    s = np.asarray(softmax(x))
    assert np.isfinite(s).all()
    np.testing.assert_allclose(s[0, 0], 1.0, atol=1e-6)
