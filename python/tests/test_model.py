"""L2 correctness: model graph, shapes, and the training step."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model


@pytest.fixture(scope="module")
def params():
    return model.init_params()


@pytest.mark.parametrize("batch", model.BATCH_SIZES)
def test_forward_shape(params, batch):
    x = jax.random.normal(jax.random.PRNGKey(1), (batch, *model.IMG))
    out = model.model_apply(params, x)
    assert out.shape == (batch, model.N_CLASSES)
    assert np.isfinite(np.asarray(out)).all()


def test_forward_deterministic(params):
    x = jax.random.normal(jax.random.PRNGKey(2), (1, *model.IMG))
    a = model.model_apply(params, x)
    b = model.model_apply(params, x)
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_node_graph_is_acyclic_and_complete(params):
    seen = {"input"}
    for name, op, deps, weights in model.node_specs():
        for d in deps:
            assert d in seen, f"node {name} depends on later/unknown node {d}"
        for w in weights:
            assert w in params, f"node {name} references unknown weight {w}"
        assert op in model.OP_FNS
        seen.add(name)
    assert "fc" in seen


def test_block_concat_channels(params):
    """Mirror of rust/src/models/mini.rs: concat widths 48 and 72."""
    x = jnp.zeros((1, *model.IMG))
    vals = {"input": x}
    for name, op, deps, weights in model.node_specs():
        args = [vals[d] for d in deps] + [params[w] for w in weights]
        vals[name] = model.OP_FNS[op](*args)
    assert vals["b1_cat"].shape[1] == 48
    assert vals["b2_cat"].shape[1] == 72


def test_mlp_train_step_decreases_loss():
    mlp = model.init_mlp()
    k = jax.random.PRNGKey(3)
    x = jax.random.normal(k, (model.TRAIN_BATCH, model.MLP_DIMS[0]))
    y = jax.nn.one_hot(jnp.arange(model.TRAIN_BATCH) % model.N_CLASSES, model.N_CLASSES)
    step = jax.jit(model.train_step)
    *mlp, first = step(*mlp, x, y)
    last = first
    for _ in range(25):
        *mlp, last = step(*mlp, x, y)
    assert float(last) < 0.7 * float(first), (float(first), float(last))


def test_train_step_param_shapes_preserved():
    mlp = model.init_mlp()
    x = jnp.zeros((model.TRAIN_BATCH, model.MLP_DIMS[0]))
    y = jnp.zeros((model.TRAIN_BATCH, model.N_CLASSES))
    out = jax.jit(model.train_step)(*mlp, x, y)
    assert len(out) == len(mlp) + 1
    for p, q in zip(mlp, out[:-1]):
        assert p.shape == q.shape and p.dtype == q.dtype
    assert out[-1].shape == ()
