"""AOT pipeline: artifacts exist, HLO text parses, manifest is consistent."""

import os
import subprocess
import sys

import numpy as np
import pytest

from compile import model

ART = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")


@pytest.fixture(scope="module")
def manifest():
    path = os.path.join(ART, "manifest.tsv")
    if not os.path.exists(path):
        pytest.skip("artifacts not built (run `make artifacts`)")
    rows = [line.rstrip("\n").split("\t") for line in open(path)]
    return rows


def by_kind(rows, kind):
    return [r for r in rows if r[0] == kind]


def test_every_artifact_file_exists_and_is_hlo(manifest):
    arts = by_kind(manifest, "A")
    assert len(arts) >= 30
    for _, name, rel in arts:
        path = os.path.join(ART, rel)
        assert os.path.exists(path), name
        head = open(path).read(200)
        assert "HloModule" in head, f"{name} is not HLO text"


def test_every_weight_loads_with_declared_shape(manifest):
    for row in by_kind(manifest, "W"):
        _, name, rel, dims = row
        arr = np.load(os.path.join(ART, rel))
        assert arr.shape == tuple(int(d) for d in dims.split(",")), name
        assert arr.dtype == np.float32


def test_node_graph_consistent(manifest):
    arts = {r[1] for r in by_kind(manifest, "A")}
    weights = {r[1] for r in by_kind(manifest, "W")}
    for batch in model.BATCH_SIZES:
        rows = [r for r in by_kind(manifest, "N") if int(r[1]) == batch]
        assert len(rows) == len(model.node_specs())
        seen = {"input"}
        for _, _, node, artifact, dims, inputs in rows:
            assert artifact in arts, artifact
            for item in inputs.split(";"):
                kind, _, target = item.partition(":")
                if kind == "node":
                    assert target in seen, f"{node}: forward ref {target}"
                else:
                    assert target in weights, f"{node}: unknown weight {target}"
            seen.add(node)
        # final node output is (batch, n_classes)
        assert rows[-1][4] == f"{batch},{model.N_CLASSES}"


def test_model_artifacts_per_batch(manifest):
    ms = by_kind(manifest, "M")
    assert {int(r[1]) for r in ms} == set(model.BATCH_SIZES)


def test_train_artifact_declared(manifest):
    ts = by_kind(manifest, "T")
    assert len(ts) == 1
    _, name, n_params, batch, in_dim, n_classes = ts[0]
    assert int(n_params) == 6
    assert int(batch) == model.TRAIN_BATCH
    assert int(in_dim) == model.MLP_DIMS[0]
    assert int(n_classes) == model.N_CLASSES
