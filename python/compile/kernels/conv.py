"""L1: conv2d lowered to im2col + the Pallas tiled matmul.

The CUDA paper's hot kernels are cuDNN convolutions; the TPU-shaped rethink
(DESIGN.md §Hardware-Adaptation) turns every conv into one MXU-tiled matmul:
``patches (B·H·W × C·kh·kw) @ weights (C·kh·kw × OC)``. The im2col gather is
produced by XLA (``conv_general_dilated_patches``) and fuses into the
surrounding HLO; the FLOPs all land in the Pallas kernel.

``conv2d_bn_relu`` is the paper's operator-fusion path: the folded BN scale/
bias and the ReLU ride the matmul tile's VMEM residency (see
``matmul.matmul_scale_bias``).
"""

import functools

import jax
import jax.numpy as jnp

from .matmul import matmul, matmul_scale_bias


def _im2col(x, kh: int, kw: int, stride: int):
    """NCHW → (B·OH·OW, C·kh·kw) patch matrix, SAME padding."""
    patches = jax.lax.conv_general_dilated_patches(
        x,
        filter_shape=(kh, kw),
        window_strides=(stride, stride),
        padding="SAME",
        dimension_numbers=("NCHW", "OIHW", "NCHW"),
    )  # (B, C*kh*kw, OH, OW)
    b, ckk, oh, ow = patches.shape
    cols = patches.transpose(0, 2, 3, 1).reshape(b * oh * ow, ckk)
    return cols, (b, oh, ow)


@functools.partial(jax.jit, static_argnames=("stride",))
def conv2d(x, w, *, stride: int = 1):
    """2D convolution, NCHW input, OIHW weights, SAME padding, no bias."""
    oc, ic, kh, kw = w.shape
    if x.shape[1] != ic:
        raise ValueError(f"channel mismatch: x {x.shape} vs w {w.shape}")
    cols, (b, oh, ow) = _im2col(x, kh, kw, stride)
    wmat = w.reshape(oc, ic * kh * kw).T  # (C·kh·kw, OC)
    out = matmul(cols, wmat)  # (B·OH·OW, OC)
    return out.reshape(b, oh, ow, oc).transpose(0, 3, 1, 2)


@functools.partial(jax.jit, static_argnames=("stride", "activation"))
def conv2d_bn_relu(x, w, scale, bias, *, stride: int = 1, activation: str = "relu"):
    """Fused conv + folded-BN + activation (one Pallas kernel).

    ``scale``/``bias`` are the inference-folded BN parameters per output
    channel: ``y = act(conv(x, w) * scale + bias)``.
    """
    oc, ic, kh, kw = w.shape
    cols, (b, oh, ow) = _im2col(x, kh, kw, stride)
    wmat = w.reshape(oc, ic * kh * kw).T
    out = matmul_scale_bias(cols, wmat, scale, bias, activation=activation)
    return out.reshape(b, oh, ow, oc).transpose(0, 3, 1, 2)
