"""L1: Pallas tiled matmul — the compute hot-spot every conv in the model
lowers onto (conv = im2col + this matmul).

TPU mapping (DESIGN.md §Hardware-Adaptation): the CUDA-paper equivalent of
a threadblock-tiled SGEMM. Tiles are sized for the MXU systolic array
(multiples of 128 on the lane dimension when shapes allow) and the
HBM→VMEM schedule is expressed through ``BlockSpec`` index maps: grid cell
(i, j) stages an (bm × K) panel of ``x`` and a (K × bn) panel of ``w`` into
VMEM and writes one (bm × bn) output tile.

VMEM footprint per grid cell = 4·(bm·K + K·bn + bm·bn) bytes. For the
MiniInception shapes (K ≤ 1200, bm = 256, bn ≤ 128) that is ≤ ~1.6 MiB,
comfortably inside the ~16 MiB VMEM budget — see DESIGN.md §Perf for the
block-size sweep.

Runs with ``interpret=True``: real-TPU lowering emits a Mosaic custom call
the CPU PJRT plugin cannot execute; interpret mode lowers to plain HLO so
the artifact runs anywhere (numerics identical, verified vs ``ref.py``).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _matmul_kernel(x_ref, w_ref, o_ref):
    """One (bm, bn) output tile: full-K panel product, f32 accumulation."""
    o_ref[...] = jnp.dot(
        x_ref[...], w_ref[...], preferred_element_type=jnp.float32
    ).astype(o_ref.dtype)


def _pick_blocks(m: int, n: int, block_m: int, block_n: int):
    """Clamp block sizes to the problem and keep the grid ≥ 2 cells when the
    problem has ≥ 2 elements on some tiled axis: a single-cell pallas_call
    lowers to an HLO shape the runtime's xla_extension 0.5.1 text parser
    mis-compiles (DESIGN.md §Gotchas)."""
    bm = min(block_m, max(m, 1))
    bn = min(block_n, max(n, 1))
    grid = -(-m // bm) * -(-n // bn)
    if grid <= 1:
        if n > 1:
            bn = -(-n // 2)
        elif m > 1:
            bm = -(-m // 2)
    return bm, bn


def _pad_to(x, multiple, axis):
    size = x.shape[axis]
    rem = size % multiple
    if rem == 0:
        return x
    pad = [(0, 0)] * x.ndim
    pad[axis] = (0, multiple - rem)
    return jnp.pad(x, pad)


@functools.partial(jax.jit, static_argnames=("block_m", "block_n"))
def matmul(x, w, *, block_m: int = 256, block_n: int = 128):
    """``x @ w`` via the Pallas kernel. Shapes (M, K) × (K, N) → (M, N).

    Inputs are zero-padded up to the block grid and the result is sliced
    back, so arbitrary shapes are supported.
    """
    if x.ndim != 2 or w.ndim != 2:
        raise ValueError(f"matmul expects rank-2 operands, got {x.shape} @ {w.shape}")
    if x.shape[1] != w.shape[0]:
        raise ValueError(f"contraction mismatch: {x.shape} @ {w.shape}")
    m, k = x.shape
    _, n = w.shape
    bm, bn = _pick_blocks(m, n, block_m, block_n)
    xp = _pad_to(x, bm, 0)
    wp = _pad_to(w, bn, 1)
    mp, np_ = xp.shape[0], wp.shape[1]

    out = pl.pallas_call(
        _matmul_kernel,
        grid=(mp // bm, np_ // bn),
        in_specs=[
            pl.BlockSpec((bm, k), lambda i, j: (i, 0)),
            pl.BlockSpec((k, bn), lambda i, j: (0, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((mp, np_), x.dtype),
        interpret=True,
    )(xp, wp)
    return out[:m, :n]


def _matmul_epilogue_kernel(x_ref, w_ref, scale_ref, bias_ref, o_ref, *, activation):
    """Matmul tile with a fused per-column scale/bias (+ activation) epilogue.

    This is the fused conv+bn+relu path: the epilogue runs while the output
    tile is still resident in VMEM (registers/SMEM in the CUDA original),
    so the intermediate never touches HBM.
    """
    acc = jnp.dot(x_ref[...], w_ref[...], preferred_element_type=jnp.float32)
    acc = acc * scale_ref[...] + bias_ref[...]
    if activation == "relu":
        acc = jnp.maximum(acc, 0.0)
    o_ref[...] = acc.astype(o_ref.dtype)


@functools.partial(
    jax.jit, static_argnames=("block_m", "block_n", "activation")
)
def matmul_scale_bias(
    x, w, scale, bias, *, activation: str = "relu", block_m: int = 256, block_n: int = 128
):
    """``act((x @ w) * scale + bias)`` with the epilogue fused into the tile.

    ``scale``/``bias`` have shape (N,) — the folded inference-time
    batch-norm parameters of the following BN layer.
    """
    m, k = x.shape
    _, n = w.shape
    if scale.shape != (n,) or bias.shape != (n,):
        raise ValueError("scale/bias must be shape (N,)")
    bm, bn = _pick_blocks(m, n, block_m, block_n)
    xp = _pad_to(x, bm, 0)
    wp = _pad_to(w, bn, 1)
    sp = _pad_to(scale.reshape(1, n), bn, 1)
    bp = _pad_to(bias.reshape(1, n), bn, 1)
    mp, np_ = xp.shape[0], wp.shape[1]

    kernel = functools.partial(_matmul_epilogue_kernel, activation=activation)
    out = pl.pallas_call(
        kernel,
        grid=(mp // bm, np_ // bn),
        in_specs=[
            pl.BlockSpec((bm, k), lambda i, j: (i, 0)),
            pl.BlockSpec((k, bn), lambda i, j: (0, j)),
            pl.BlockSpec((1, bn), lambda i, j: (0, j)),
            pl.BlockSpec((1, bn), lambda i, j: (0, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((mp, np_), x.dtype),
        interpret=True,
    )(xp, wp, sp, bp)
    return out[:m, :n]
