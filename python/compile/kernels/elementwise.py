"""L1: elementwise Pallas kernels (relu / bias+relu / row softmax).

Small memory-bound kernels — on a real TPU these are VPU (vector unit)
work; the Pallas expression keeps the HBM→VMEM block schedule explicit.
Lowered with interpret=True like every kernel here (see matmul.py).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _relu_kernel(x_ref, o_ref):
    o_ref[...] = jnp.maximum(x_ref[...], 0.0)


@jax.jit
def relu(x):
    """Elementwise ReLU over an arbitrary-shape tensor (flattened blocks)."""
    flat = x.reshape(-1)
    n = flat.shape[0]
    # Keep the grid ≥ 2 cells: single-cell pallas_call lowers to an HLO
    # shape the runtime's xla_extension 0.5.1 text parser mis-compiles
    # (see DESIGN.md §Gotchas), and a 1-cell grid defeats pipelining anyway.
    block = min(65536, n.bit_length() and -(-n // 2)) if n > 1 else 1
    block = max(block, 1)
    pad = (-n) % block
    # Guard the no-op pad: jnp.pad(x, 0) lowers to a degenerate HLO
    # computation whose ROOT is a parameter, which the xla_extension 0.5.1
    # HLO-text parser mis-handles (see DESIGN.md §Gotchas).
    fp = jnp.pad(flat, (0, pad)) if pad else flat
    out = pl.pallas_call(
        _relu_kernel,
        grid=(fp.shape[0] // block,),
        in_specs=[pl.BlockSpec((block,), lambda i: (i,))],
        out_specs=pl.BlockSpec((block,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct(fp.shape, x.dtype),
        interpret=True,
    )(fp)
    return out[:n].reshape(x.shape)


def _softmax_kernel(x_ref, o_ref):
    x = x_ref[...]
    m = jnp.max(x, axis=-1, keepdims=True)
    e = jnp.exp(x - m)
    o_ref[...] = e / jnp.sum(e, axis=-1, keepdims=True)


@functools.partial(jax.jit, static_argnames=("block_rows",))
def softmax(x, *, block_rows: int = 256):
    """Numerically-stable row softmax over the last dim of a 2D tensor."""
    if x.ndim != 2:
        raise ValueError("softmax kernel expects rank 2")
    m, n = x.shape
    bm = min(block_rows, -(-m // 2) if m > 1 else 1)
    pad = (-m) % bm
    xp = jnp.pad(x, ((0, pad), (0, 0))) if pad else x
    out = pl.pallas_call(
        _softmax_kernel,
        grid=(xp.shape[0] // bm,),
        in_specs=[pl.BlockSpec((bm, n), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((bm, n), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct(xp.shape, x.dtype),
        interpret=True,
    )(xp)
    return out[:m]
