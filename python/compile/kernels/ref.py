"""Pure-jnp oracles for the Pallas kernels (the CORE correctness signal).

Every kernel in this package is checked against these references by
``python/tests/test_kernels.py`` (hypothesis shape/value sweeps with
``assert_allclose``).
"""

import jax
import jax.numpy as jnp


def matmul_ref(x, w):
    return jnp.dot(x, w, preferred_element_type=jnp.float32).astype(x.dtype)


def matmul_scale_bias_ref(x, w, scale, bias, activation="relu"):
    out = jnp.dot(x, w, preferred_element_type=jnp.float32) * scale + bias
    if activation == "relu":
        out = jnp.maximum(out, 0.0)
    return out.astype(x.dtype)


def conv2d_ref(x, w, stride=1):
    return jax.lax.conv_general_dilated(
        x,
        w,
        window_strides=(stride, stride),
        padding="SAME",
        dimension_numbers=("NCHW", "OIHW", "NCHW"),
    )


def conv2d_bn_relu_ref(x, w, scale, bias, stride=1, activation="relu"):
    out = conv2d_ref(x, w, stride) * scale[None, :, None, None] + bias[None, :, None, None]
    if activation == "relu":
        out = jnp.maximum(out, 0.0)
    return out


def relu_ref(x):
    return jnp.maximum(x, 0.0)


def softmax_ref(x):
    return jax.nn.softmax(x, axis=-1)
