"""AOT lowering: JAX → StableHLO → XlaComputation → **HLO text** artifacts.

HLO *text* (never ``.serialize()``) is the interchange format: jax ≥ 0.5
emits HloModuleProtos with 64-bit instruction ids which the runtime's
xla_extension 0.5.1 rejects (``proto.id() <= INT_MAX``); the text parser
reassigns ids and round-trips cleanly. See /opt/xla-example/README.md.

Outputs under ``--out`` (default ``../artifacts``):
  manifest.tsv          — everything the Rust runtime needs (see below)
  ops/<sig>.hlo.txt     — one artifact per distinct operator signature
  model_b<N>.hlo.txt    — whole-model forward, weights baked, per batch size
  train_step.hlo.txt    — MLP fwd+bwd+SGD step (flat params in/out)
  weights/<name>.npy    — parameter tensors (loaded as device buffers)

Manifest line grammar (tab-separated):
  A  <artifact>  <relpath>                      # compiled executable
  W  <param>     <relpath>  <dims csv>          # weight tensor
  I  <batch>     <dims csv>                    # request input dims
  N  <batch>  <node>  <artifact>  <dims csv>  <inputs ; -sep: node:X|weight:Y>
  M  <batch>  <artifact>  <weight names csv>    # whole-model executable
                                                #   (args: input, *weights)
  T  <artifact>  <n_params>  <batch>  <in_dim>  <n_classes>  # train step

Python runs ONCE at build time; the request path is pure Rust.
"""

import argparse
import os
import sys

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from . import model


def to_hlo_text(fn, *example_args, return_tuple: bool = False) -> str:
    """Lower a jittable fn at fixed shapes to HLO text.

    ``return_tuple=False`` for single-output ops: PJRT hands the tuple root
    back as ONE tuple-shaped buffer (an 8-byte index table) which cannot be
    fed to the next executable — raw array roots chain cleanly. Multi-output
    functions (train_step) keep the tuple root; PJRT untuples those into
    separate output buffers.
    """
    lowered = jax.jit(fn).lower(*example_args)
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=return_tuple
    )
    return comp.as_hlo_text()


def spec_of(x):
    return jax.ShapeDtypeStruct(x.shape, x.dtype)


def dims_csv(shape):
    return ",".join(str(d) for d in shape)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="../artifacts")
    args = ap.parse_args()
    out = args.out
    os.makedirs(os.path.join(out, "ops"), exist_ok=True)
    os.makedirs(os.path.join(out, "weights"), exist_ok=True)

    manifest = []
    params = model.init_params()

    # --- weights ---
    for name, value in sorted(params.items()):
        rel = f"weights/{name}.npy"
        np.save(os.path.join(out, rel), np.asarray(value))
        manifest.append(("W", name, rel, dims_csv(value.shape)))

    # --- per-op artifacts + node graph, per batch size ---
    artifacts = {}  # sig -> relpath

    def artifact_for(sig, fn, *ex_args):
        if sig in artifacts:
            return sig
        rel = f"ops/{sig}.hlo.txt"
        text = to_hlo_text(fn, *map(spec_of, ex_args))
        with open(os.path.join(out, rel), "w") as f:
            f.write(text)
        artifacts[sig] = rel
        manifest.append(("A", sig, rel))
        return sig

    for batch in model.BATCH_SIZES:
        x = jnp.zeros((batch, *model.IMG), jnp.float32)
        manifest.append(("I", str(batch), dims_csv(x.shape)))
        vals = {"input": x}
        for name, op, deps, weights in model.node_specs():
            fn = model.OP_FNS[op]
            arg_vals = [vals[d] for d in deps] + [params[w] for w in weights]
            result = fn(*arg_vals)
            vals[name] = result
            shapes_sig = "_".join("x".join(map(str, a.shape)) for a in arg_vals)
            sig = f"{op}_b{batch}_{shapes_sig}"
            artifact_for(sig, fn, *arg_vals)
            inputs = ";".join(
                [f"node:{d}" for d in deps] + [f"weight:{w}" for w in weights]
            )
            manifest.append(("N", str(batch), name, sig, dims_csv(result.shape), inputs))

        # Whole-model artifact. Weights are *parameters*, not baked
        # constants: `as_hlo_text()` elides large constant literals as
        # "{...}" which the runtime's HLO text parser reads back as zeros.
        pnames = sorted(params)

        def model_fn(xx, *pvals):
            return model.model_apply(dict(zip(pnames, pvals)), xx)

        msig = f"model_b{batch}"
        rel = f"{msig}.hlo.txt"
        text = to_hlo_text(model_fn, spec_of(x), *map(spec_of, (params[p] for p in pnames)))
        with open(os.path.join(out, rel), "w") as f:
            f.write(text)
        manifest.append(("A", msig, rel))
        manifest.append(("M", str(batch), msig, ",".join(pnames)))

    # --- training step artifact ---
    mlp = model.init_mlp()
    for i, p in enumerate(mlp):
        rel = f"weights/mlp_{i}.npy"
        np.save(os.path.join(out, rel), np.asarray(p))
        manifest.append(("W", f"mlp_{i}", rel, dims_csv(p.shape)))
    xb = jnp.zeros((model.TRAIN_BATCH, model.MLP_DIMS[0]), jnp.float32)
    yb = jnp.zeros((model.TRAIN_BATCH, model.N_CLASSES), jnp.float32)
    text = to_hlo_text(model.train_step, *map(spec_of, [*mlp, xb, yb]), return_tuple=True)
    with open(os.path.join(out, "train_step.hlo.txt"), "w") as f:
        f.write(text)
    manifest.append(("A", "train_step", "train_step.hlo.txt"))
    manifest.append(
        (
            "T",
            "train_step",
            str(len(mlp)),
            str(model.TRAIN_BATCH),
            str(model.MLP_DIMS[0]),
            str(model.N_CLASSES),
        )
    )

    with open(os.path.join(out, "manifest.tsv"), "w") as f:
        for row in manifest:
            f.write("\t".join(row) + "\n")
    n_art = sum(1 for r in manifest if r[0] == "A")
    print(f"wrote {n_art} artifacts + manifest to {out}", file=sys.stderr)


if __name__ == "__main__":
    main()
