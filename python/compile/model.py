"""L2: the MiniInception model in JAX, defined at *operator granularity*.

This file is the single source of truth for the real execution path: each
node below becomes one GPU task in the Rust engine, `aot.py` lowers one HLO
artifact per distinct operator signature, and `artifacts/manifest.tsv`
carries the node graph (name, artifact, dependencies, weights) that
`rust/src/runtime/manifest.rs` loads. The architecture mirrors
`rust/src/models/mini.rs` op-for-op (cross-checked in integration tests).

Convolutions and the classifier run on the L1 Pallas kernels; pools and
concats are plain jnp (they lower to trivial HLO).

Also defined here: a small MLP `train_step` (fwd + bwd + SGD in one jitted
function) lowered to `train_step.hlo.txt` — the end-to-end training driver
`examples/train_e2e.rs` runs it for a few hundred steps from Rust.
"""

import jax
import jax.numpy as jnp

from .kernels.conv import conv2d
from .kernels.elementwise import relu
from .kernels.matmul import matmul

BATCH_SIZES = (1, 8)
IMG = (3, 32, 32)
N_CLASSES = 10

# (name, out_channels, kernel, conv input channels) for the two blocks.
BLOCK1 = dict(c1=(16, 1), c3=(16, 3), c5=(8, 5), cp=(8, 1))   # in 16 -> out 48
BLOCK2 = dict(c1=(24, 1), c3=(24, 3), c5=(12, 5), cp=(12, 1))  # in 48 -> out 72


def init_params(key=None):
    """Deterministic parameter set (seed 0), He-scaled."""
    key = key if key is not None else jax.random.PRNGKey(0)
    ks = iter(jax.random.split(key, 16))

    def conv_w(oc, ic, k):
        fan_in = ic * k * k
        return jax.random.normal(next(ks), (oc, ic, k, k), jnp.float32) * (2.0 / fan_in) ** 0.5

    params = {"stem_w": conv_w(16, 3, 3)}
    for blk, spec, ic in (("b1", BLOCK1, 16), ("b2", BLOCK2, 48)):
        for name, (oc, k) in spec.items():
            params[f"{blk}_{name}_w"] = conv_w(oc, ic, k)
    params["fc_w"] = jax.random.normal(next(ks), (72, N_CLASSES), jnp.float32) * (1.0 / 72) ** 0.5
    params["fc_b"] = jnp.zeros((N_CLASSES,), jnp.float32)
    return params


# ---------------------------------------------------------------------------
# Operator functions (one artifact per distinct signature).
# ---------------------------------------------------------------------------

def op_conv(x, w):
    return conv2d(x, w, stride=1)


def op_relu(x):
    return relu(x)


def op_maxpool3(x):
    """3×3 stride-1 SAME max pool."""
    return jax.lax.reduce_window(
        x, -jnp.inf, jax.lax.max, (1, 1, 3, 3), (1, 1, 1, 1), "SAME"
    )


def op_concat(a, b, c, d):
    return jnp.concatenate([a, b, c, d], axis=1)


def op_gap(x):
    return jnp.mean(x, axis=(2, 3))


def op_linear(x, w, b):
    return matmul(x, w) + b


#: node name -> (op fn name, [input node names], [weight param names])
def node_specs():
    nodes = [
        ("stem_conv", "conv", ["input"], ["stem_w"]),
        ("stem_relu", "relu", ["stem_conv"], []),
    ]
    prev = "stem_relu"
    for blk in ("b1", "b2"):
        nodes += [
            (f"{blk}_c1", "conv", [prev], [f"{blk}_c1_w"]),
            (f"{blk}_r1", "relu", [f"{blk}_c1"], []),
            (f"{blk}_c3", "conv", [prev], [f"{blk}_c3_w"]),
            (f"{blk}_r3", "relu", [f"{blk}_c3"], []),
            (f"{blk}_c5", "conv", [prev], [f"{blk}_c5_w"]),
            (f"{blk}_r5", "relu", [f"{blk}_c5"], []),
            (f"{blk}_pool", "maxpool3", [prev], []),
            (f"{blk}_cp", "conv", [f"{blk}_pool"], [f"{blk}_cp_w"]),
            (f"{blk}_rp", "relu", [f"{blk}_cp"], []),
            (f"{blk}_cat", "concat", [f"{blk}_r1", f"{blk}_r3", f"{blk}_r5", f"{blk}_rp"], []),
        ]
        prev = f"{blk}_cat"
    nodes += [
        ("gap", "gap", [prev], []),
        ("fc", "linear", ["gap"], ["fc_w", "fc_b"]),
    ]
    return nodes


OP_FNS = {
    "conv": op_conv,
    "relu": op_relu,
    "maxpool3": op_maxpool3,
    "concat": op_concat,
    "gap": op_gap,
    "linear": op_linear,
}


def model_apply(params, x):
    """Full forward pass by interpreting the node graph (test oracle and
    the function lowered to the whole-model serving artifacts)."""
    vals = {"input": x}
    for name, op, deps, weights in node_specs():
        args = [vals[d] for d in deps] + [params[w] for w in weights]
        vals[name] = OP_FNS[op](*args)
    return vals["fc"]


# ---------------------------------------------------------------------------
# Training workload: a 3-layer MLP with an end-to-end jitted SGD step.
# ---------------------------------------------------------------------------

TRAIN_BATCH = 64
MLP_DIMS = (3 * 32 * 32, 256, 64, N_CLASSES)
LEARNING_RATE = 0.05


def init_mlp(key=None):
    key = key if key is not None else jax.random.PRNGKey(42)
    ks = jax.random.split(key, len(MLP_DIMS) - 1)
    params = []
    for k, (din, dout) in zip(ks, zip(MLP_DIMS[:-1], MLP_DIMS[1:])):
        params.append(jax.random.normal(k, (din, dout), jnp.float32) * (2.0 / din) ** 0.5)
        params.append(jnp.zeros((dout,), jnp.float32))
    return params  # [w1, b1, w2, b2, w3, b3]


def mlp_apply(params, x):
    w1, b1, w2, b2, w3, b3 = params
    h = jnp.maximum(x @ w1 + b1, 0.0)
    h = jnp.maximum(h @ w2 + b2, 0.0)
    return h @ w3 + b3


def mlp_loss(params, x, y_onehot):
    logits = mlp_apply(params, x)
    logp = jax.nn.log_softmax(logits)
    return -jnp.mean(jnp.sum(y_onehot * logp, axis=-1))


def train_step(w1, b1, w2, b2, w3, b3, x, y_onehot):
    """One SGD step; flat-argument signature so the Rust driver can bind
    each parameter to a device buffer. Returns (new params..., loss)."""
    params = [w1, b1, w2, b2, w3, b3]
    loss, grads = jax.value_and_grad(mlp_loss)(params, x, y_onehot)
    new = [p - LEARNING_RATE * g for p, g in zip(params, grads)]
    return (*new, loss)
