//! Chaos-hardened serving on the virtual substrate — no artifacts, no
//! `xla` feature needed:
//!
//!     cargo run --release --example serve_chaos
//!
//! Builds a serving runtime with a seeded [`FaultPlan`] injecting
//! engine errors/panics, replay worker deaths, and poisoning join
//! timeouts into every lane, then drives a burst of requests through
//! it. Lane supervision retries transient failures under the
//! [`RetryPolicy`] and replaces poisoned lanes; every ticket still
//! resolves exactly once, survivors carry correct outputs, and a
//! graceful [`Runtime::drain`] flushes the rest and closes the books
//! (`admitted == completed + shed + failed`).

use anyhow::Result;
use nimble::serving::{FaultPlan, Health, InferOutcome, InferRequest, RetryPolicy, Runtime};
use nimble::util::Pcg32;
use std::time::Duration;

fn main() -> Result<()> {
    // A seeded plan makes every "random" fault reproducible: same seed,
    // same faults, same schedule — chaos you can put in a regression
    // test. The probabilities are per engine call / per replay.
    let plan = FaultPlan {
        engine_error: 0.15,    // infer_batch returns Err
        engine_panic: 0.05,    // infer_batch panics (caught by the lane)
        worker_death: 0.05,    // a replay worker dies mid-replay (transient)
        join_timeout: 0.02,    // a replay times out and POISONS the lane
        ..FaultPlan::seeded(0xC4A0_5EED)
    };
    let rt = Runtime::builder()
        .model("mini_inception")
        .buckets(&[1, 4])
        .max_wait(Duration::from_millis(1))
        .fault_plan(plan)
        .retry_policy(RetryPolicy { max_retries: 2, backoff: Duration::from_micros(200) })
        .build()?;
    println!("chaos runtime up: buckets {:?}, health {:?}", rt.batch_sizes(), rt.health());

    // A burst of pre-formed batches into the storm. No deadline: each
    // ticket resolves as Output (possibly after in-lane retries or a
    // lane replacement) or Failed (retry budget exhausted) — never
    // hangs, never disappears.
    let mut rng = Pcg32::new(7);
    let len = rt.example_len();
    let mut mk = |n: usize| -> Vec<f32> {
        (0..n * len).map(|_| rng.gen_f32_range(-1.0, 1.0)).collect()
    };
    let n_jobs = 24;
    let tickets: Vec<_> = (0..n_jobs)
        .map(|i| rt.submit(InferRequest::batch(if i % 3 == 0 { 4 } else { 1 }, mk(if i % 3 == 0 { 4 } else { 1 }))))
        .collect::<Result<_>>()?;

    let (mut served, mut failed) = (0usize, 0usize);
    for t in tickets {
        match t.outcome()? {
            InferOutcome::Output(out) => {
                assert!(out.iter().all(|v| v.is_finite()));
                served += 1;
            }
            InferOutcome::Failed(e) => {
                // Every failure is traceable to an injection or the
                // lane it took down.
                assert!(
                    e.contains("injected") || e.contains("lane") || e.contains("poisoned"),
                    "unexpected failure: {e}"
                );
                failed += 1;
            }
            InferOutcome::DeadlineShed => unreachable!("no deadlines in this burst"),
        }
    }
    println!("burst resolved: {served} served, {failed} failed (of {n_jobs})");
    assert_eq!(served + failed, n_jobs, "every ticket resolves exactly once");

    // Health probe: still Healthy (or Degraded if a bucket lost its
    // lanes for good — not with these rates), then Draining once the
    // graceful drain begins.
    let handle = rt.handle();
    match rt.health() {
        Health::Healthy => println!("health: Healthy"),
        h => println!("health: {h:?}"),
    }

    // Graceful drain: reject new work, flush everything admitted, join
    // every lane, and return the final report with the chaos ledger.
    let report = rt.drain()?;
    assert_eq!(handle.health(), Health::Draining);
    assert!(handle.submit(InferRequest::new(vec![0.0; len])).is_err(), "drained = closed");
    println!("\n{}", report.render());
    assert_eq!(report.n_requests, served);
    assert_eq!(report.failed, failed);
    assert_eq!(
        report.n_requests + report.deadline_shed + report.failed,
        n_jobs,
        "accounting closes under chaos"
    );
    println!(
        "\nserve_chaos OK: {} retries absorbed, {} lanes spawned, {} retired",
        report.retries,
        report.lanes_spawned(),
        report.lanes_retired()
    );
    Ok(())
}
