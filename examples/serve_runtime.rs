//! The runtime façade end-to-end on the virtual substrate — no
//! artifacts, no `xla` feature needed:
//!
//!     cargo run --release --example serve_runtime
//!
//! Builds an elastic, deadline-first serving runtime for MiniInception
//! with one fluent builder call, then drives it three ways: plain
//! blocking requests, hinted + async tickets, and a deadline burst that
//! demonstrates admission-time shedding (`ServingReport::deadline_shed`
//! with the `admission_shed` subset — requests the scheduler proves
//! undeliverable are resolved at the door, before they occupy backlog).
//! The builder also arms the SLO controller (`.slo(target)`), which
//! force-spawns elastic lanes when the live shed rate breaches the
//! target; `.edf(false)` would restore the plain FIFO baseline.

use anyhow::Result;
use nimble::serving::{InferOutcome, InferRequest, Runtime, ScaleOptions};
use nimble::util::Pcg32;
use std::time::Duration;

fn main() -> Result<()> {
    // One builder composes what used to take three constructors and a
    // shared-pool/arena-pool wiring dance.
    let rt = Runtime::builder()
        .model("mini_inception")
        .buckets(&[1, 4, 8])
        .max_wait(Duration::from_millis(1))
        .elastic(ScaleOptions { max_lanes_per_bucket: 2, ..Default::default() })
        .shared_pool(4)
        .slo(0.25) // shed-rate target: breach it and the controller adds lanes
        .build()?;
    println!(
        "runtime up: buckets {:?}, example_len {}, output_len {}",
        rt.batch_sizes(),
        rt.example_len(),
        rt.output_len()
    );

    let mut rng = Pcg32::new(7);
    let len = rt.example_len();
    let mut mk = |n: usize| -> Vec<f32> {
        (0..n * len).map(|_| rng.gen_f32_range(-1.0, 1.0)).collect()
    };

    // 1. Blocking single examples through the dynamic batcher.
    for _ in 0..4 {
        let logits = rt.infer(InferRequest::new(mk(1)))?;
        assert!(logits.iter().all(|v| v.is_finite()));
    }
    println!("blocking requests served");

    // 2. Hinted + async: route to the bucket-8 lane, wait on tickets.
    let tickets: Vec<_> = (0..6)
        .map(|_| rt.submit(InferRequest::new(mk(1)).hint(8)))
        .collect::<Result<_>>()?;
    for t in tickets {
        t.wait()?;
    }
    println!("hinted async requests served on the bucket-8 lane");

    // 3. Deadlines: a pre-formed burst where half the requests carry an
    // already-expired deadline — the dispatcher sheds them AT ADMISSION
    // (an expired budget can never be met, so it never occupies
    // backlog); the rest complete normally.
    let tickets: Vec<_> = (0..8)
        .map(|i| {
            let req = InferRequest::batch(4, mk(4));
            let req = if i % 2 == 0 {
                req.deadline_in(Duration::ZERO) // expired at submit
            } else {
                req.deadline_in(Duration::from_secs(5))
            };
            rt.submit(req)
        })
        .collect::<Result<_>>()?;
    let (mut served, mut shed) = (0, 0);
    for t in tickets {
        match t.outcome()? {
            InferOutcome::Output(_) => served += 1,
            InferOutcome::DeadlineShed => shed += 1,
            InferOutcome::Failed(e) => anyhow::bail!("burst request failed: {e}"),
        }
    }
    println!("deadline burst: {served} served, {shed} shed");
    assert_eq!(served + shed, 8, "every ticket resolves exactly once");
    assert_eq!(shed, 4, "the expired half must shed");

    let report = rt.shutdown()?;
    println!("\n{}", report.render());
    assert_eq!(report.deadline_shed, shed);
    assert_eq!(report.admission_shed, 4, "expired-at-submit sheds resolve at the door");
    println!("\nserve_runtime OK");
    Ok(())
}
