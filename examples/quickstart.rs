//! Quickstart: build a Nimble engine from the AOT artifacts and compare
//! the paper's two execution paths on the same network and input —
//! run-time scheduling (eager) vs ahead-of-time scheduling (replay).
//!
//!     make artifacts && cargo run --release --example quickstart
//!
//! What you should see: identical logits from both paths, the Algorithm 1
//! stream assignment of the MiniInception graph, the reserved-memory
//! arena, and the measured scheduling overhead the AoT path removes.

use anyhow::Result;
use nimble::aot::TaskSchedule;
use nimble::engine::EagerEngine;
use nimble::runtime::{artifacts_dir, ArtifactRegistry, RuntimeClient};
use nimble::util::stats::fmt_secs;
use nimble::util::{Pcg32, Summary};
use std::sync::Arc;
use std::time::Instant;

fn main() -> Result<()> {
    nimble::runtime::require_artifacts()?;
    let client = RuntimeClient::cpu()?;
    println!("PJRT platform: {}", client.platform_name());
    let registry = Arc::new(ArtifactRegistry::load(client, artifacts_dir())?);
    println!("compiled {} artifacts", registry.n_executables());

    let batch = 8;
    // --- AoT scheduling (paper §4.1): one pre-run, then raw submission. ---
    let t0 = Instant::now();
    let schedule = TaskSchedule::build(&registry, batch)?;
    println!(
        "\nAoT schedule built in {} (includes the pre-run):\n  \
         {} tasks on {} streams, {} cross-stream syncs (|E'|−|M|)\n  \
         reserved arena: {} KiB (unshared would be {} KiB)",
        fmt_secs(t0.elapsed().as_secs_f64()),
        schedule.n_tasks(),
        schedule.n_streams,
        schedule.n_events,
        schedule.arena.arena_bytes / 1024,
        schedule.arena.unshared_bytes() / 1024,
    );

    let eager = EagerEngine::new(registry.clone(), batch)?;
    let mut rng = Pcg32::new(1234);
    let input: Vec<f32> =
        (0..eager.input_len()).map(|_| rng.gen_f32_range(-1.0, 1.0)).collect();

    // --- correctness: both paths agree ---
    let (out_eager, stats) = eager.infer(&input)?;
    let out_replay = schedule.replay(&registry, &input)?;
    let max_diff = out_eager
        .iter()
        .zip(&out_replay)
        .map(|(a, b)| (a - b).abs())
        .fold(0.0f32, f32::max);
    println!("\nnumerics: max |eager − replay| = {max_diff:e}");
    assert!(max_diff < 1e-5);

    // --- the paper's measurement: scheduling overhead per request ---
    let iters = 15;
    let mut eager_sched = Vec::new();
    let mut replay_sched = Vec::new();
    let mut eager_total = Vec::new();
    let mut replay_total = Vec::new();
    for _ in 0..iters {
        let t = Instant::now();
        let (_, s) = eager.infer(&input)?;
        eager_total.push(t.elapsed().as_secs_f64());
        eager_sched.push(s.sched_s);
        let t = Instant::now();
        let (_, s) = schedule.replay_with_stats(&registry, &input)?;
        replay_total.push(t.elapsed().as_secs_f64());
        replay_sched.push(s);
    }
    let es = Summary::from_samples(eager_sched);
    let rs = Summary::from_samples(replay_sched);
    let et = Summary::from_samples(eager_total);
    let rt = Summary::from_samples(replay_total);
    println!(
        "\nscheduling work per request ({} ops):\n  \
         eager (shape check + dispatch + alloc + marshal): {}\n  \
         replay (pre-scheduled submission only):           {}\n  \
         → AoT removes {:.1}× of the scheduling work",
        stats.n_ops,
        fmt_secs(es.median()),
        fmt_secs(rs.median()),
        es.median() / rs.median(),
    );
    println!(
        "end-to-end (kernel execution dominates on this 1-core CPU device):\n  \
         eager p50 {}   replay p50 {}",
        fmt_secs(et.median()),
        fmt_secs(rt.median()),
    );
    println!("\nquickstart OK");
    Ok(())
}
