//! Regenerate every table and figure of the paper's evaluation on the
//! VGPU substrate and write TSVs under `results/`. Equivalent to
//! `nimble figures all`; kept as an example so `cargo run --example
//! reproduce_figures` works without installing the CLI.

use anyhow::Result;

fn main() -> Result<()> {
    let dir = std::path::PathBuf::from("results");
    for (name, table) in nimble::figures::run("all", &dir)? {
        println!("== {name} ==\n{}", table.render());
    }
    println!("TSVs written to results/ — see EXPERIMENTS.md for paper-vs-measured notes");
    Ok(())
}
