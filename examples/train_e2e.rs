//! End-to-end training driver: run the AOT-compiled `train_step` artifact
//! (forward + backward + SGD, lowered once by python/compile/aot.py) for a
//! few hundred steps on synthetic classification data, from Rust, logging
//! the loss curve. Recorded in EXPERIMENTS.md §E2E.
//!
//!     make artifacts && cargo run --release --example train_e2e [steps]

use anyhow::Result;

fn main() -> Result<()> {
    nimble::runtime::require_artifacts()?;
    let steps: usize = std::env::args().nth(1).and_then(|s| s.parse().ok()).unwrap_or(300);
    let report = nimble::training::run_training(steps, 25)?;
    println!("{}", report.render());
    assert!(
        report.final_loss < 0.5 * report.first_loss,
        "training failed to converge: {} → {}",
        report.first_loss,
        report.final_loss
    );
    println!("train_e2e OK");
    Ok(())
}
