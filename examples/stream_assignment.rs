//! Algorithm 1 walkthrough: reproduces the paper's Figure 6 example step
//! by step, then applies the algorithm to every model-zoo network and
//! verifies the theorems mechanically.
//!
//!     cargo run --release --example stream_assignment

use nimble::graph::{minimum_equivalent_graph, Dag};
use nimble::matching::{maximum_matching, BipartiteGraph, MatchingAlgo};
use nimble::models;
use nimble::stream::verify::satisfies_max_logical_concurrency;
use nimble::stream::{assign_streams, logical_concurrency_degree, plan_syncs};
use nimble::util::table::Table;

fn main() {
    // --- Figure 6: v1→v2, v1→v3, v2→v4, v3→v4, v4→v5, v4→v6 ---
    println!("== Figure 6 walkthrough ==");
    let mut g: Dag<&str> = Dag::new();
    for name in ["v1", "v2", "v3", "v4", "v5", "v6"] {
        g.add_node(name);
    }
    for (u, v) in [(0, 1), (0, 2), (1, 3), (2, 3), (3, 4), (3, 5)] {
        g.add_edge(u, v);
    }
    let meg = minimum_equivalent_graph(&g);
    println!("step 1: MEG has {} edges (G had {})", meg.n_edges(), g.n_edges());
    let b = BipartiteGraph::from_dag_edges(g.n_nodes(), &meg.edges());
    let m = maximum_matching(&b, MatchingAlgo::FordFulkerson);
    println!("steps 2–3: maximum matching |M| = {}", m.cardinality());
    let a = assign_streams(&g, MatchingAlgo::FordFulkerson);
    println!("steps 4–5: {} streams, stream map = {:?}", a.n_streams, a.stream_of);
    let syncs = plan_syncs(&a);
    println!(
        "syncs: {} (theorem 3: |E'|−|M| = {})",
        syncs.n_syncs(),
        meg.n_edges() - m.cardinality()
    );
    assert!(satisfies_max_logical_concurrency(&g, &a.stream_of));
    assert_eq!(syncs.n_syncs(), meg.n_edges() - m.cardinality());

    // --- the model zoo ---
    println!("\n== Algorithm 1 across the model zoo ==");
    let mut t = Table::new(vec!["model", "|V|", "|E|", "|E'|", "|M|", "streams", "syncs", "Deg."]);
    for spec in models::MODELS {
        let g = models::build(spec.name, 1);
        let a = assign_streams(&g, MatchingAlgo::HopcroftKarp);
        assert!(
            satisfies_max_logical_concurrency(&g, &a.stream_of),
            "{}: theorem 2 violated",
            spec.name
        );
        let syncs = plan_syncs(&a);
        assert_eq!(syncs.n_syncs(), a.min_syncs(), "{}: theorem 3 violated", spec.name);
        t.row(vec![
            spec.name.to_string(),
            g.n_nodes().to_string(),
            g.n_edges().to_string(),
            a.meg.n_edges().to_string(),
            a.matching_size.to_string(),
            a.n_streams.to_string(),
            syncs.n_syncs().to_string(),
            logical_concurrency_degree(&g).to_string(),
        ]);
    }
    println!("{}", t.render());
    println!("all theorems verified mechanically — stream_assignment OK");
}
