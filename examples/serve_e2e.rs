//! End-to-end serving driver (the mandated E2E validation): load the real
//! MiniInception artifacts, serve Poisson-arriving requests through the
//! batched Nimble server in BOTH modes — AoT replay and the eager run-time
//! scheduling baseline — and report latency/throughput. Results are
//! recorded in EXPERIMENTS.md §E2E.
//!
//!     make artifacts && cargo run --release --example serve_e2e

use anyhow::Result;
use nimble::coordinator::{EngineConfig, ExecMode};
use nimble::serving::{InferRequest, Runtime};
use nimble::util::Pcg32;
use std::time::Duration;

fn run_mode(mode: ExecMode, n_requests: usize, rate_rps: f64) -> Result<()> {
    println!("\n=== mode: {mode:?} ({n_requests} requests, ~{rate_rps} req/s offered) ===");
    let server = Runtime::builder()
        .artifacts(EngineConfig { mode, ..Default::default() })
        .single_thread()
        .max_wait(Duration::from_millis(3))
        .build()?;
    let len = server.example_len();
    let mut rng = Pcg32::new(2718);
    let mut pending = Vec::with_capacity(n_requests);
    for _ in 0..n_requests {
        let input: Vec<f32> = (0..len).map(|_| rng.gen_f32_range(-1.0, 1.0)).collect();
        pending.push(server.submit(InferRequest::new(input))?);
        std::thread::sleep(Duration::from_secs_f64(rng.gen_exp(rate_rps)));
    }
    let mut checked = 0;
    for ticket in pending {
        let logits = ticket.wait()?;
        assert_eq!(logits.len(), 10, "classifier head width");
        assert!(logits.iter().all(|v| v.is_finite()));
        checked += 1;
    }
    let report = server.shutdown()?;
    assert_eq!(report.n_requests, checked);
    println!("{}", report.render());
    Ok(())
}

fn main() -> Result<()> {
    nimble::runtime::require_artifacts()?;
    let n: usize = std::env::args().nth(1).and_then(|s| s.parse().ok()).unwrap_or(120);
    let rate: f64 = std::env::args().nth(2).and_then(|s| s.parse().ok()).unwrap_or(50.0);
    run_mode(ExecMode::Replay, n, rate)?;
    run_mode(ExecMode::Eager, n, rate)?;
    println!("\nserve_e2e OK");
    Ok(())
}
