//! End-to-end integration over the real XLA/PJRT path: artifacts →
//! registry → eager engine vs AoT replay vs the whole-model executable.
//! All three must produce identical numerics (the paper's correctness
//! claim: Nimble "does not affect the output values of neural networks").
//!
//! Skips (with a notice) when `make artifacts` has not been run.
//! Compiled only with the `xla` feature (the PJRT runtime path).
#![cfg(feature = "xla")]

use nimble::aot::TaskSchedule;
use nimble::engine::EagerEngine;
use nimble::runtime::{artifacts_available, artifacts_dir, ArtifactRegistry, RuntimeClient};
use nimble::util::Pcg32;
use std::sync::Arc;

fn registry() -> Option<Arc<ArtifactRegistry>> {
    if !artifacts_available() {
        eprintln!("SKIP: artifacts not built (run `make artifacts`)");
        return None;
    }
    let client = RuntimeClient::cpu().expect("pjrt client");
    Some(Arc::new(ArtifactRegistry::load(client, artifacts_dir()).expect("registry")))
}

fn random_input(len: usize, seed: u64) -> Vec<f32> {
    let mut rng = Pcg32::new(seed);
    (0..len).map(|_| rng.gen_f32_range(-1.0, 1.0)).collect()
}

fn assert_close(a: &[f32], b: &[f32], tol: f32, what: &str) {
    assert_eq!(a.len(), b.len(), "{what}: length");
    for (i, (x, y)) in a.iter().zip(b).enumerate() {
        assert!(
            (x - y).abs() <= tol * (1.0 + x.abs().max(y.abs())),
            "{what}: element {i}: {x} vs {y}"
        );
    }
}

#[test]
fn registry_loads_everything() {
    let Some(reg) = registry() else { return };
    assert!(reg.n_executables() >= 30);
    assert_eq!(reg.manifest.batch_sizes(), vec![1, 8]);
}

#[test]
fn eager_vs_replay_identical_numerics() {
    let Some(reg) = registry() else { return };
    for &batch in &[1usize, 8] {
        let eager = EagerEngine::new(reg.clone(), batch).expect("eager");
        let sched = TaskSchedule::build(&reg, batch).expect("schedule");
        let input = random_input(eager.input_len(), 42 + batch as u64);
        let (out_eager, stats) = eager.infer(&input).expect("eager infer");
        let out_replay = sched.replay(&reg, &input).expect("replay");
        assert_eq!(out_eager.len(), batch * 10);
        assert_close(&out_eager, &out_replay, 1e-5, "eager vs replay");
        assert_eq!(stats.n_ops, sched.n_tasks());
    }
}

#[test]
fn replay_matches_whole_model_executable() {
    // The per-op replay must agree with the single fused whole-model HLO
    // (weights baked): cross-validates the manifest graph wiring.
    let Some(reg) = registry() else { return };
    let batch = 8usize;
    let sched = TaskSchedule::build(&reg, batch).expect("schedule");
    let (model_art, weight_names) = reg.manifest.models[&batch].clone();
    let exe = reg.executable(&model_art).expect("model exe");
    let input = random_input(sched.input_dims.iter().product(), 7);
    let out_replay = sched.replay(&reg, &input).expect("replay");

    let buf = reg.client.buffer_f32(&input, &sched.input_dims).expect("stage");
    let mut args: Vec<&xla::PjRtBuffer> = vec![&buf];
    for w in &weight_names {
        args.push(reg.weight_ref(w).expect("weight"));
    }
    let out = exe.execute_b(&args).expect("model exec");
    assert_eq!(out[0].len(), 1);
    let out_model = reg.client.to_host_f32(&out[0][0]).expect("to host");
    assert_close(&out_replay, &out_model, 1e-4, "replay vs whole-model");
}

#[test]
fn replay_is_deterministic() {
    let Some(reg) = registry() else { return };
    let sched = TaskSchedule::build(&reg, 1).expect("schedule");
    let input = random_input(sched.input_dims.iter().product(), 3);
    let a = sched.replay(&reg, &input).expect("replay 1");
    let b = sched.replay(&reg, &input).expect("replay 2");
    assert_eq!(a, b);
}

#[test]
fn schedule_structure_matches_algorithm1() {
    // MiniInception has 4-way parallel blocks: Algorithm 1 must find ≥4
    // streams and |E'|−|M| syncs; the arena must beat unshared allocation.
    let Some(reg) = registry() else { return };
    let sched = TaskSchedule::build(&reg, 8).expect("schedule");
    assert!(sched.n_streams >= 4, "streams={}", sched.n_streams);
    assert!(sched.n_events > 0);
    assert!(sched.arena.arena_bytes > 0);
    assert!(
        sched.arena.arena_bytes <= sched.arena.unshared_bytes(),
        "lifetime reuse must not lose to per-tensor allocation"
    );
    // every stream id below n_streams is actually used
    let used: std::collections::HashSet<usize> = sched.tasks.iter().map(|t| t.stream).collect();
    assert!(used.len() >= 4);
}

#[test]
fn eager_rejects_wrong_input_length() {
    let Some(reg) = registry() else { return };
    let eager = EagerEngine::new(reg, 1).expect("eager");
    assert!(eager.infer(&[0.0; 3]).is_err());
}

#[test]
fn train_step_runs_and_loss_decreases() {
    // The training E2E in short form (examples/train_e2e.rs runs the full
    // few-hundred-step version): replay the train_step artifact in a loop
    // from Rust, feeding parameter outputs back as inputs.
    let Some(reg) = registry() else { return };
    let spec = reg.manifest.train.clone().expect("train spec");
    let exe = reg.executable(&spec.artifact).expect("train exe");

    // initial parameters from the weights dir
    let mut params: Vec<xla::PjRtBuffer> = (0..spec.n_params)
        .map(|i| {
            let (rel, dims) = reg.manifest.weights[&format!("mlp_{i}")].clone();
            let arr = nimble::runtime::npy::read_npy_f32(&artifacts_dir().join(rel)).unwrap();
            assert_eq!(arr.dims, dims);
            reg.client.buffer_f32(&arr.data, &arr.dims).unwrap()
        })
        .collect();
    // synthetic classification data
    let mut rng = Pcg32::new(99);
    let x: Vec<f32> =
        (0..spec.batch * spec.in_dim).map(|_| rng.gen_f32_range(-1.0, 1.0)).collect();
    let mut y = vec![0.0f32; spec.batch * spec.n_classes];
    for r in 0..spec.batch {
        y[r * spec.n_classes + r % spec.n_classes] = 1.0;
    }
    let xb = reg.client.buffer_f32(&x, &[spec.batch, spec.in_dim]).unwrap();
    let yb = reg.client.buffer_f32(&y, &[spec.batch, spec.n_classes]).unwrap();

    let mut first_loss = None;
    let mut last_loss = 0.0f32;
    for _step in 0..30 {
        let outs = {
            let mut args: Vec<&xla::PjRtBuffer> = params.iter().collect();
            args.push(&xb);
            args.push(&yb);
            exe.execute_b(&args).expect("train step")
        };
        let outs0 = outs.into_iter().next().unwrap();
        // The train_step root is a tuple: PJRT returns one tuple-shaped
        // buffer; decompose via literal and re-stage the parameters.
        assert_eq!(outs0.len(), 1, "tuple root returns a single buffer");
        let tuple_lit = outs0[0].to_literal_sync().expect("to literal");
        let mut parts = tuple_lit.to_tuple().expect("decompose tuple");
        assert_eq!(parts.len(), spec.n_params + 1, "params + loss");
        let loss_lit = parts.pop().unwrap();
        last_loss = loss_lit.to_vec::<f32>().unwrap()[0];
        params = parts
            .into_iter()
            .map(|lit| {
                let shape = lit.array_shape().unwrap();
                let dims: Vec<usize> = shape.dims().iter().map(|&d| d as usize).collect();
                let host = lit.to_vec::<f32>().unwrap();
                reg.client.buffer_f32(&host, &dims).unwrap()
            })
            .collect();
        first_loss.get_or_insert(last_loss);
    }
    let first = first_loss.unwrap();
    assert!(last_loss < 0.7 * first, "loss did not decrease: {first} → {last_loss}");
}
