//! Integration: the full Algorithm-1 pipeline (graph → MEG → matching →
//! assignment → sync plan → launch plan) over every model-zoo graph, with
//! the paper's theorems checked on real network topologies.

use nimble::graph::{topo_order, Reachability};
use nimble::matching::MatchingAlgo;
use nimble::models;
use nimble::stream::rewrite::{rewrite, rewrite_single_stream};
use nimble::stream::sync::{plan_is_safe, plan_syncs};
use nimble::stream::verify::satisfies_max_logical_concurrency;
use nimble::stream::{assign_streams, logical_concurrency_degree};

#[test]
fn theorems_hold_on_every_zoo_model() {
    for spec in models::MODELS {
        let g = models::build(spec.name, 1);
        for algo in [MatchingAlgo::HopcroftKarp, MatchingAlgo::FordFulkerson] {
            let a = assign_streams(&g, algo);
            // Theorem 2: maximum logical concurrency.
            assert!(
                satisfies_max_logical_concurrency(&g, &a.stream_of),
                "{}: max logical concurrency violated",
                spec.name
            );
            // Theorem 3: sync count.
            let plan = plan_syncs(&a);
            assert_eq!(plan.n_syncs(), a.meg.n_edges() - a.matching_size, "{}", spec.name);
            // Operational safety of the plan.
            let order = topo_order(&g).unwrap();
            assert!(
                plan_is_safe(&g, &a.stream_of, &order, &plan),
                "{}: unsafe plan",
                spec.name
            );
        }
    }
}

#[test]
fn stream_count_bounded_by_width_and_nodes() {
    for spec in models::MODELS {
        let g = models::build(spec.name, 1);
        let a = assign_streams(&g, MatchingAlgo::HopcroftKarp);
        let width = logical_concurrency_degree(&g);
        assert!(a.n_streams >= width, "{}: streams < width", spec.name);
        assert!(a.n_streams <= g.n_nodes(), "{}", spec.name);
    }
}

#[test]
fn launch_plans_cover_every_node_once() {
    for name in ["inception_v3", "nasnet_a_mobile", "mini_inception"] {
        let g = models::build(name, 1);
        for plan in [rewrite(&g, MatchingAlgo::HopcroftKarp), rewrite_single_stream(&g)] {
            assert_eq!(plan.order.len(), g.n_nodes(), "{name}");
            let mut seen = vec![false; g.n_nodes()];
            for p in &plan.order {
                assert!(!seen[p.node], "{name}: node {} scheduled twice", p.node);
                seen[p.node] = true;
            }
        }
    }
}

#[test]
fn paper_table1_degrees_within_band() {
    // Paper Table 1 Deg. column: 6 / 7 / 11 / 12 / 15. Cell-level
    // approximations shift these slightly; widths must stay in order of
    // magnitude and Inception must stay the narrowest.
    let deg = |m: &str| logical_concurrency_degree(&models::build(m, 1));
    let inception = deg("inception_v3");
    assert_eq!(inception, 6, "paper: 6");
    for m in ["darts", "amoebanet", "nasnet_a_mobile", "nasnet_a_large"] {
        assert!(deg(m) > inception, "{m} should exceed inception_v3");
        assert!((7..=16).contains(&deg(m)), "{m} deg {}", deg(m));
    }
}

#[test]
fn fused_graphs_still_satisfy_theorems() {
    for name in ["inception_v3", "nasnet_a_mobile"] {
        let g = nimble::ops::fuse_graph(&models::build(name, 1));
        let a = assign_streams(&g, MatchingAlgo::HopcroftKarp);
        assert!(satisfies_max_logical_concurrency(&g, &a.stream_of), "{name}");
        let plan = plan_syncs(&a);
        assert_eq!(plan.n_syncs(), a.min_syncs(), "{name}");
    }
}

#[test]
fn reachability_consistent_after_rewrite() {
    // The rewrite must not change the graph itself — pure annotation.
    let g = models::build("mini_inception", 1);
    let before = Reachability::compute(&g);
    let _ = rewrite(&g, MatchingAlgo::HopcroftKarp);
    let after = Reachability::compute(&g);
    for u in 0..g.n_nodes() {
        for v in 0..g.n_nodes() {
            assert_eq!(before.reaches(u, v), after.reaches(u, v));
        }
    }
}
