//! Integration: the parallel multi-stream replay executor.
//!
//! * **Differential**: parallel replay must be bit-identical to the
//!   serial oracle on every model-zoo graph and on random DAGs — any
//!   missed synchronization surfaces as a slot mismatch.
//! * **Bounded join**: with a safe sync plan the event table can never
//!   deadlock; every wait carries a deadline, so even an injected worker
//!   failure resolves to an error within bounded time, never a hang.
//! * **Zero allocation**: the instrumented `ReplayContext` counter stays
//!   at zero across steady-state replays.
//! * **DES cross-check**: the simulator replays the *same tape*; its
//!   event ordering and the executor's measured completion stamps must
//!   both respect every record→wait edge, and the predicted multi-stream
//!   speedup on wide cells (Inception/NASNet shapes) must be ≥ 1.5×.

use nimble::aot::tape::ReplayTape;
use nimble::engine::executor::{ReplayContext, SyntheticKernel, TapeKernel};
use nimble::graph::gen::{layered_dag, random_dag};
use nimble::matching::MatchingAlgo;
use nimble::models;
use nimble::ops::{GraphBuilder, OpGraph};
use nimble::sim::{kernel_cost, simulate_tape, GpuSpec, HostProfile};
use nimble::stream::rewrite::{rewrite, rewrite_single_stream};
use nimble::util::Pcg32;
use std::time::Duration;

fn random_input(len: usize, seed: u64) -> Vec<f32> {
    let mut rng = Pcg32::new(seed);
    (0..len).map(|_| rng.gen_f32_range(-1.0, 1.0)).collect()
}

fn assert_slots_bit_identical(a: &ReplayContext, b: &ReplayContext, what: &str) {
    let n = a.tape().n_slots();
    for s in 0..n {
        let (x, y) = (a.slot(s), b.slot(s));
        assert_eq!(x.len(), y.len(), "{what}: slot {s} length");
        for (i, (p, q)) in x.iter().zip(y).enumerate() {
            assert_eq!(p.to_bits(), q.to_bits(), "{what}: slot {s} elem {i}: {p} vs {q}");
        }
    }
}

#[test]
fn parallel_replay_is_bit_identical_on_every_zoo_model() {
    for spec in models::MODELS {
        let g = models::build(spec.name, 1);
        let plan = rewrite(&g, MatchingAlgo::HopcroftKarp);
        let tape = ReplayTape::for_op_graph(&g, &plan, 256);
        let input = random_input(tape.input_slots()[0].1, 0xA11 + spec.name.len() as u64);
        let mut par = ReplayContext::new(tape.clone(), SyntheticKernel);
        let mut ser = ReplayContext::new(tape, SyntheticKernel);
        par.replay_one(&input).unwrap_or_else(|e| panic!("{}: parallel: {e}", spec.name));
        ser.replay_serial(&[&input]).unwrap_or_else(|e| panic!("{}: serial: {e}", spec.name));
        assert_slots_bit_identical(&par, &ser, spec.name);
    }
}

#[test]
fn parallel_replay_is_bit_identical_on_random_dags() {
    let mut rng = Pcg32::new(0xD1FF);
    for case in 0..30 {
        let g = if case % 2 == 0 {
            random_dag(&mut rng, 2 + (case as usize * 3) % 45, 0.12)
        } else {
            layered_dag(&mut rng, 1 + case as usize % 4, 5, 3)
        };
        let plan = rewrite(&g, MatchingAlgo::HopcroftKarp);
        let tape = ReplayTape::for_dag(&g, &plan);
        let mut par = ReplayContext::new(tape.clone(), SyntheticKernel);
        let mut ser = ReplayContext::new(tape, SyntheticKernel);
        par.replay(&[]).unwrap_or_else(|e| panic!("case {case}: parallel: {e}"));
        ser.replay_serial(&[]).unwrap_or_else(|e| panic!("case {case}: serial: {e}"));
        assert_slots_bit_identical(&par, &ser, &format!("random case {case}"));
        // replay twice: slot reuse across requests must stay correct
        par.replay(&[]).unwrap();
        assert_slots_bit_identical(&par, &ser, &format!("random case {case} (2nd replay)"));
    }
}

#[test]
fn steady_state_replay_performs_zero_heap_allocation() {
    for name in ["mini_inception", "inception_v3"] {
        let g = models::build(name, 1);
        let plan = rewrite(&g, MatchingAlgo::HopcroftKarp);
        let tape = ReplayTape::for_op_graph(&g, &plan, 256);
        let input = random_input(tape.input_slots()[0].1, 99);
        let mut ctx = ReplayContext::new(tape, SyntheticKernel);
        ctx.replay_one(&input).unwrap(); // warm-up sizes everything
        ctx.reset_alloc_events();
        for _ in 0..8 {
            ctx.replay_one(&input).unwrap();
        }
        let sched = ctx.replay_serial_with_stats(&[&input]).unwrap();
        assert!(sched >= 0.0);
        assert_eq!(
            ctx.alloc_events(),
            0,
            "{name}: steady-state replay loop must not allocate"
        );
    }
}

#[test]
fn bounded_join_no_deadlock_on_any_safe_plan() {
    // 40 random safe plans through the parallel executor with a short
    // watchdog: every replay must complete (Ok) well inside the deadline
    // — the event table cannot deadlock under a safe plan, and if it
    // ever did, the watchdog converts the hang into a bounded-time Err.
    let mut rng = Pcg32::new(0xDEAD);
    let started = std::time::Instant::now();
    for case in 0..40 {
        let g = if case % 2 == 0 {
            random_dag(&mut rng, 2 + (case as usize * 7) % 50, 0.15)
        } else {
            layered_dag(&mut rng, 1 + case as usize % 5, 6, 2)
        };
        let plan = rewrite(&g, MatchingAlgo::HopcroftKarp);
        let tape = ReplayTape::for_dag(&g, &plan);
        let mut ctx = ReplayContext::with_config(
            tape,
            SyntheticKernel,
            Vec::new(),
            Duration::from_secs(5),
        );
        ctx.replay(&[]).unwrap_or_else(|e| panic!("case {case} did not complete: {e}"));
    }
    assert!(
        started.elapsed() < Duration::from_secs(60),
        "bounded-join suite took too long: {:?}",
        started.elapsed()
    );
}

/// Kernel that panics exactly once (first execution of node 1), to prove
/// a worker failure resolves to a bounded-time `Err` — never a hang —
/// and the pool survives for the next replay.
struct PanicOnceKernel {
    fired: std::sync::atomic::AtomicBool,
}

impl TapeKernel for PanicOnceKernel {
    fn execute(&self, op: &nimble::aot::tape::TapeOp, args: &[&[f32]], out: &mut [f32]) {
        if op.node == 1 && !self.fired.swap(true, std::sync::atomic::Ordering::SeqCst) {
            panic!("injected kernel failure");
        }
        SyntheticKernel.execute(op, args, out);
    }
}

#[test]
fn worker_failure_errors_in_bounded_time_and_pool_recovers() {
    let mut g: nimble::graph::Dag<()> = nimble::graph::Dag::new();
    for _ in 0..4 {
        g.add_node(());
    }
    g.add_edge(0, 1);
    g.add_edge(0, 2);
    g.add_edge(1, 3);
    g.add_edge(2, 3);
    let plan = rewrite(&g, MatchingAlgo::HopcroftKarp);
    let tape = ReplayTape::for_dag(&g, &plan);
    let kernel = PanicOnceKernel { fired: std::sync::atomic::AtomicBool::new(false) };
    let mut ctx =
        ReplayContext::with_config(tape.clone(), kernel, Vec::new(), Duration::from_millis(300));
    let t0 = std::time::Instant::now();
    // Note: the injected panic prints a backtrace to stderr; expected.
    assert!(ctx.replay(&[]).is_err(), "failed worker must surface an error");
    assert!(t0.elapsed() < Duration::from_secs(5), "failure must resolve in bounded time");
    // The pool survives: the kernel no longer panics, replay succeeds
    // and matches the serial oracle.
    ctx.replay(&[]).expect("pool must recover after a worker panic");
    let mut ser = ReplayContext::new(tape, SyntheticKernel);
    ser.replay_serial(&[]).unwrap();
    assert_slots_bit_identical(&ctx, &ser, "post-recovery replay");
}

#[test]
fn executor_interleaving_respects_the_sync_plan_like_the_des() {
    // The same tape drives both the real executor and the simulator;
    // both must honor every record→wait edge and per-stream FIFO order.
    let g = models::build("mini_inception", 1);
    let plan = rewrite(&g, MatchingAlgo::HopcroftKarp);
    assert!(plan.n_streams > 1, "test premise: multi-stream plan");
    let tape = ReplayTape::for_op_graph(&g, &plan, 256);
    let input = random_input(tape.input_slots()[0].1, 5);
    let mut ctx = ReplayContext::new(tape.clone(), SyntheticKernel);
    ctx.set_tracing(true);
    ctx.replay_one(&input).unwrap();
    let stamps = ctx.completion_stamps();
    assert!(stamps.iter().all(|&s| s > 0), "every record must complete");

    // recorder of each event
    let mut recorder = vec![usize::MAX; tape.n_events()];
    for i in 0..tape.n_ops() {
        for &e in tape.records(tape.op(i)) {
            recorder[e as usize] = i;
        }
    }
    // (a) measured interleaving: per-stream FIFO + record-before-wait
    for s in 0..tape.n_streams() {
        let idxs = tape.stream_ops(s);
        for w in idxs.windows(2) {
            assert!(
                stamps[w[0] as usize] < stamps[w[1] as usize],
                "stream {s} FIFO violated"
            );
        }
    }
    for i in 0..tape.n_ops() {
        for &e in tape.waits(tape.op(i)) {
            let r = recorder[e as usize];
            assert!(
                stamps[r] < stamps[i],
                "event {e}: recorder stamp {} !< waiter stamp {}",
                stamps[r],
                stamps[i]
            );
        }
    }
    // (b) predicted interleaving: the DES over the same tape obeys the
    // same edges (recorder finishes before the waiter starts).
    let dev = GpuSpec::v100();
    let costs: Vec<_> = (0..g.n_nodes()).map(|v| kernel_cost(g.node(v), &dev)).collect();
    let sim = simulate_tape(&tape, &costs, HostProfile::nimble(), dev);
    let span_of = |node: usize| sim.spans.iter().find(|sp| sp.node == node).unwrap();
    for i in 0..tape.n_ops() {
        let op = tape.op(i);
        for &e in tape.waits(op) {
            let r = tape.op(recorder[e as usize]);
            assert!(
                span_of(r.node as usize).end_s <= span_of(op.node as usize).start_s + 1e-12,
                "DES violated event {e}"
            );
        }
    }
}

/// Inception-like wide cell: `branches` parallel convolutions joined by
/// a channel concat — each branch sized to occupy a fraction of the SMs
/// so true concurrency is possible (the Table 1 shape).
fn inception_cell(branches: usize) -> OpGraph {
    let mut b = GraphBuilder::new();
    let x = b.input(&[1, 32, 28, 28]);
    let outs: Vec<_> = (0..branches).map(|_| b.conv(x, 32, 3, 1)).collect();
    let _ = b.concat(&outs);
    b.finish()
}

/// NASNet-like cell: parallel conv→relu chains pairwise combined by adds
/// and concatenated (many small ops, high logical concurrency).
fn nasnet_cell(branches: usize) -> OpGraph {
    let mut b = GraphBuilder::new();
    let x = b.input(&[1, 32, 28, 28]);
    let outs: Vec<_> = (0..branches)
        .map(|_| {
            let c = b.conv(x, 32, 3, 1);
            b.relu(c)
        })
        .collect();
    let combined: Vec<_> = outs
        .chunks(2)
        .map(|pair| if pair.len() == 2 { b.add(pair[0], pair[1]) } else { pair[0] })
        .collect();
    let _ = b.concat(&combined);
    b.finish()
}

#[test]
fn des_predicts_multistream_speedup_on_wide_cells() {
    let dev = GpuSpec::v100();
    for (name, g) in [
        ("inception_cell", inception_cell(8)),
        ("nasnet_cell", nasnet_cell(10)),
    ] {
        let costs: Vec<_> = (0..g.n_nodes()).map(|v| kernel_cost(g.node(v), &dev)).collect();
        let multi = rewrite(&g, MatchingAlgo::HopcroftKarp);
        assert!(multi.n_streams >= 4, "{name}: expected a wide plan");
        let tape_multi = ReplayTape::for_op_graph(&g, &multi, 4096);
        let tape_single = ReplayTape::for_op_graph(&g, &rewrite_single_stream(&g), 4096);
        let t_multi =
            simulate_tape(&tape_multi, &costs, HostProfile::nimble(), dev.clone()).total_s;
        let t_single =
            simulate_tape(&tape_single, &costs, HostProfile::nimble(), dev.clone()).total_s;
        let speedup = t_single / t_multi;
        assert!(
            speedup >= 1.5,
            "{name}: multi-stream tape speedup {speedup:.2}x < 1.5x \
             (single {t_single:.6}s, multi {t_multi:.6}s)"
        );
        // And the executor runs the same wide tape bit-identically.
        let input = random_input(tape_multi.input_slots()[0].1, 21);
        let mut par = ReplayContext::new(tape_multi.clone(), SyntheticKernel);
        let mut ser = ReplayContext::new(tape_multi, SyntheticKernel);
        par.replay_one(&input).unwrap();
        ser.replay_serial(&[&input]).unwrap();
        assert_slots_bit_identical(&par, &ser, name);
    }
}

#[test]
fn independent_contexts_replay_concurrently() {
    // The serving path keeps one context per batch bucket; two contexts
    // replaying at the same time from different threads must not
    // interfere (separate arenas, events, pools).
    let g = models::build("mini_inception", 1);
    let plan = rewrite(&g, MatchingAlgo::HopcroftKarp);
    let tape = ReplayTape::for_op_graph(&g, &plan, 256);
    let input_a = random_input(tape.input_slots()[0].1, 1);
    let input_b = random_input(tape.input_slots()[0].1, 2);

    let mut oracle = ReplayContext::new(tape.clone(), SyntheticKernel);
    oracle.replay_serial(&[&input_a]).unwrap();
    let expect_a: Vec<f32> = oracle.output().to_vec();
    oracle.replay_serial(&[&input_b]).unwrap();
    let expect_b: Vec<f32> = oracle.output().to_vec();

    let spawn = |tape: ReplayTape, input: Vec<f32>, expect: Vec<f32>| {
        std::thread::spawn(move || {
            let mut ctx = ReplayContext::new(tape, SyntheticKernel);
            for _ in 0..10 {
                ctx.replay_one(&input).unwrap();
                assert_eq!(ctx.output(), expect.as_slice());
            }
        })
    };
    let ha = spawn(tape.clone(), input_a, expect_a);
    let hb = spawn(tape, input_b, expect_b);
    ha.join().unwrap();
    hb.join().unwrap();
}
