//! Integration: simulated end-to-end behaviour must reproduce the paper's
//! qualitative claims across the whole evaluation matrix.

use nimble::baselines::{simulate_inference, simulate_training, Baseline};
use nimble::models;
use nimble::sim::GpuSpec;

#[test]
fn nimble_wins_everywhere_except_tvm_depthwise() {
    let dev = GpuSpec::v100();
    for name in ["resnet50", "resnet101", "inception_v3", "nasnet_a_mobile", "nasnet_a_large", "efficientnet_b5"] {
        let g = models::build(name, 1);
        let nb = simulate_inference(&g, Baseline::Nimble, &dev).total_s;
        for b in [Baseline::PyTorch, Baseline::TorchScript, Baseline::Caffe2, Baseline::TensorRT] {
            let t = simulate_inference(&g, b, &dev).total_s;
            assert!(nb <= t * 1.001, "{name}: Nimble {nb} vs {} {t}", b.name());
        }
    }
}

#[test]
fn tvm_beats_nimble_only_on_depthwise_dominated_nets() {
    // The paper's single loss: MobileNetV2 (and our model extends it to the
    // equally depthwise-dominated EfficientNet-B0 — documented deviation).
    let dev = GpuSpec::v100();
    let wins = |name: &str| {
        let g = models::build(name, 1);
        let tvm = simulate_inference(&g, Baseline::Tvm, &dev).total_s;
        let nb = simulate_inference(&g, Baseline::Nimble, &dev).total_s;
        tvm < nb
    };
    assert!(wins("mobilenet_v2"), "TVM must win MobileNetV2 (paper)");
    assert!(!wins("inception_v3"));
    assert!(!wins("resnet50"));
    assert!(!wins("nasnet_a_mobile"));
}

#[test]
fn nasnet_mobile_speedup_near_paper_headline() {
    // Paper: 22.34× vs PyTorch. Substrate difference tolerated: 12×–35×.
    let dev = GpuSpec::v100();
    let g = models::build("nasnet_a_mobile", 1);
    let pt = simulate_inference(&g, Baseline::PyTorch, &dev).total_s;
    let nb = simulate_inference(&g, Baseline::Nimble, &dev).total_s;
    let speedup = pt / nb;
    assert!((12.0..35.0).contains(&speedup), "nasnet speedup {speedup}");
}

#[test]
fn multistream_speedup_ordering_matches_table1() {
    // Speedup grows with concurrency for the small-MAC NAS nets and
    // collapses for the MAC-heavy NASNet-A large (SM-bound).
    let dev = GpuSpec::v100();
    let ratio = |name: &str| {
        let g = models::build(name, 1);
        let s = simulate_inference(&g, Baseline::NimbleSingleStream, &dev).total_s;
        let m = simulate_inference(&g, Baseline::Nimble, &dev).total_s;
        s / m
    };
    let inception = ratio("inception_v3");
    let nasnet_m = ratio("nasnet_a_mobile");
    let nasnet_l = ratio("nasnet_a_large");
    assert!(nasnet_m > inception, "deg-12 net must gain more than deg-6");
    assert!(nasnet_m > nasnet_l, "MAC-heavy large must gain less than mobile");
    assert!(nasnet_l < 1.6, "large is SM-bound: {nasnet_l}");
    assert!(ratio("mobilenet_v2") <= 1.01, "chain net gains nothing");
}

#[test]
fn training_speedups_shrink_with_scale() {
    // Fig. 8: marginal on ImageNet/BERT, large on CIFAR.
    let dev = GpuSpec::v100();
    let speedup = |name: &str, batch: usize| {
        let g = models::build_train(name, batch);
        let pt = simulate_training(&g, Baseline::PyTorch, &dev).total_s;
        let nb = simulate_training(&g, Baseline::Nimble, &dev).total_s;
        pt / nb
    };
    let imagenet = speedup("resnet50", 32);
    let bert = speedup("bert_base", 32);
    let cifar = speedup("resnet50_cifar", 32);
    assert!(imagenet < 1.3, "imagenet {imagenet}");
    assert!(bert < 1.3, "bert {bert}");
    assert!(cifar > 2.0, "cifar {cifar}");
    assert!(cifar > imagenet && cifar > bert);
}

#[test]
fn fig10_speedup_decays_with_batch_size() {
    let dev = GpuSpec::v100();
    let speedup = |batch: usize| {
        let g = models::build_train("resnet50_cifar", batch);
        let pt = simulate_training(&g, Baseline::PyTorch, &dev).total_s;
        let nb = simulate_training(&g, Baseline::Nimble, &dev).total_s;
        pt / nb
    };
    let s32 = speedup(32);
    let s256 = speedup(256);
    assert!(s32 > s256, "speedup must shrink with batch: b32={s32} b256={s256}");
    assert!(s256 >= 1.0);
}

#[test]
fn devices_preserve_ordering() {
    // Fig. 9: Nimble wins across Pascal/Turing/Volta.
    for dev in GpuSpec::all() {
        let g = models::build("inception_v3", 1);
        let pt = simulate_inference(&g, Baseline::PyTorch, &dev).total_s;
        let nb = simulate_inference(&g, Baseline::Nimble, &dev).total_s;
        assert!(pt / nb > 2.0, "{}: {}", dev.name, pt / nb);
    }
}

#[test]
fn infinite_gpu_reaches_critical_path() {
    // On the idealized device with zero front-end cost and unbounded SMs,
    // Nimble's makespan approaches the critical path (Fig. 2c's bound).
    let dev = GpuSpec::infinite();
    let g = models::build("nasnet_a_mobile", 1);
    // critical path must be computed on the SAME (fused) graph the Nimble
    // run executes
    let p = nimble::baselines::prepare(&g, Baseline::Nimble, &dev, true);
    let cp = nimble::sim::metrics::critical_path_s(&p.graph, &p.costs);
    let r = nimble::baselines::run_prepared(&p, &dev);
    // makespan ≥ critical path, and within 2.5× of it (submission gaps)
    assert!(r.total_s >= cp * 0.99);
    assert!(r.total_s <= cp * 2.5, "makespan {} vs critical path {cp}", r.total_s);
}

#[test]
fn happens_before_closure_is_respected_by_the_des_schedule() {
    // The verifier's independently-built happens-before closure
    // (`aot::verify::hb`) must agree with the discrete-event simulator's
    // actual schedule: whenever the closure orders op i before op j, the
    // DES never starts j's kernel before i's completes. This cross-checks
    // the static analysis against the third implementation of the same
    // semantics (per-stream FIFO + record/wait events).
    use nimble::aot::tape::ReplayTape;
    use nimble::aot::verify::hb;
    use nimble::matching::MatchingAlgo;
    use nimble::sim::{kernel_cost, simulate_tape, HostProfile};
    use nimble::stream::rewrite::rewrite;

    let dev = GpuSpec::v100();
    for name in ["mini_inception", "resnet50_cifar", "inception_v3"] {
        let g = models::build(name, 1);
        let plan = rewrite(&g, MatchingAlgo::HopcroftKarp);
        let tape = ReplayTape::for_op_graph(&g, &plan, 4096);
        let costs: Vec<_> = (0..g.n_nodes()).map(|v| kernel_cost(g.node(v), &dev)).collect();
        let sim = simulate_tape(&tape, &costs, HostProfile::nimble(), dev.clone());

        let closure = hb::closure(&tape);
        assert!(closure.is_acyclic(), "{name}: a legal tape's closure must be acyclic");
        let mut span_of = vec![usize::MAX; g.n_nodes()];
        for (k, s) in sim.spans.iter().enumerate() {
            span_of[s.node] = k;
        }
        let mut checked = 0usize;
        for i in 0..tape.n_ops() {
            for j in 0..tape.n_ops() {
                if i == j || !closure.happens_before(i, j) {
                    continue;
                }
                let (a, b) = (span_of[tape.op(i).node as usize], span_of[tape.op(j).node as usize]);
                if a == usize::MAX || b == usize::MAX {
                    continue; // node not simulated (no span) — nothing to order
                }
                let (a, b) = (&sim.spans[a], &sim.spans[b]);
                assert!(
                    b.start_s >= a.end_s - 1e-12,
                    "{name}: op #{i} (node {}) happens-before op #{j} (node {}), yet the DES \
                     started the successor at {}s before the predecessor ended at {}s",
                    a.node,
                    b.node,
                    b.start_s,
                    a.end_s
                );
                checked += 1;
            }
        }
        assert!(checked > 0, "{name}: the closure must order at least one pair");
    }
}
