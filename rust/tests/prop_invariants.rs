//! Cross-module property tests (seeded PRNG; failing seeds are printed by
//! the runner): random graphs through the full pipeline.

use nimble::graph::gen::{layered_dag, random_dag};
use nimble::graph::{minimum_equivalent_graph, topo_order, Reachability};
use nimble::matching::MatchingAlgo;
use nimble::sim::cost::KernelCost;
use nimble::sim::{simulate, GpuSpec, HostProfile, SimConfig};
use nimble::stream::rewrite::rewrite;
use nimble::stream::sync::{plan_is_safe, plan_syncs};
use nimble::stream::verify::satisfies_max_logical_concurrency;
use nimble::stream::assign_streams;
use nimble::util::{prop, Pcg32};

fn random_graph(rng: &mut Pcg32) -> nimble::graph::Dag<()> {
    if rng.gen_bool(0.5) {
        let n = rng.gen_range_inclusive(2, 40);
        random_dag(rng, n, 0.12)
    } else {
        let blocks = rng.gen_range_inclusive(1, 5);
        layered_dag(rng, blocks, 5, 3)
    }
}

#[test]
fn prop_full_pipeline_invariants() {
    prop::check("assignment pipeline invariants", 120, |rng| {
        let g = random_graph(rng);
        let algo = if rng.gen_bool(0.5) {
            MatchingAlgo::HopcroftKarp
        } else {
            MatchingAlgo::FordFulkerson
        };
        let a = assign_streams(&g, algo);
        prop::ensure(satisfies_max_logical_concurrency(&g, &a.stream_of), || {
            format!("max concurrency violated on {} nodes", g.n_nodes())
        })?;
        let plan = plan_syncs(&a);
        prop::ensure(plan.n_syncs() == a.meg.n_edges() - a.matching_size, || {
            "theorem 3 violated".into()
        })?;
        let order = topo_order(&g).map_err(|_| "cyclic".to_string())?;
        prop::ensure(plan_is_safe(&g, &a.stream_of, &order, &plan), || "unsafe plan".into())
    });
}

#[test]
fn prop_meg_is_unique_minimal_equivalent() {
    prop::check("MEG equivalence + minimality", 80, |rng| {
        let g = random_graph(rng);
        let meg = minimum_equivalent_graph(&g);
        let r1 = Reachability::compute(&g);
        let r2 = Reachability::compute(&meg);
        for u in 0..g.n_nodes() {
            for v in 0..g.n_nodes() {
                prop::ensure(r1.reaches(u, v) == r2.reaches(u, v), || {
                    format!("reachability changed at ({u},{v})")
                })?;
            }
        }
        prop::ensure(meg.n_edges() <= g.n_edges(), || "MEG grew".into())
    });
}

#[test]
fn prop_simulated_replay_respects_every_edge() {
    // DES invariant: for every graph edge (u, v), task v starts after task
    // u ends — under any host profile, device, and stream plan.
    prop::check("DES dependency safety", 60, |rng| {
        let g = random_graph(rng);
        let plan = rewrite(&g, MatchingAlgo::HopcroftKarp);
        let mut costs = Vec::with_capacity(g.n_nodes());
        for _ in 0..g.n_nodes() {
            costs.push(KernelCost {
                duration_s: rng.gen_f64() * 1e-5 + 1e-7,
                sm_demand: rng.gen_range_inclusive(1, 90),
            });
        }
        let host = *rng.choose(&[
            HostProfile::pytorch(),
            HostProfile::nimble(),
            HostProfile::tensorrt(),
        ]);
        let dev = if rng.gen_bool(0.5) { GpuSpec::v100() } else { GpuSpec::titan_xp() };
        let r = simulate(&SimConfig { plan: &plan, costs: &costs, host, device: dev });
        let end_of = |n: usize| r.spans.iter().find(|s| s.node == n).unwrap().end_s;
        let start_of = |n: usize| r.spans.iter().find(|s| s.node == n).unwrap().start_s;
        for (u, v) in g.edges() {
            prop::ensure(start_of(v) >= end_of(u) - 1e-12, || {
                format!("edge ({u},{v}) violated: {} < {}", start_of(v), end_of(u))
            })?;
        }
        Ok(())
    });
}

#[test]
fn prop_single_stream_is_serial_and_multi_is_not_slower() {
    prop::check("multi-stream never hurts makespan", 40, |rng| {
        let g = random_graph(rng);
        let mut costs = Vec::with_capacity(g.n_nodes());
        for _ in 0..g.n_nodes() {
            costs.push(KernelCost { duration_s: rng.gen_f64() * 1e-5 + 1e-6, sm_demand: 2 });
        }
        let host = HostProfile::nimble();
        let multi = rewrite(&g, MatchingAlgo::HopcroftKarp);
        let single = nimble::stream::rewrite::rewrite_single_stream(&g);
        let rm = simulate(&SimConfig {
            plan: &multi,
            costs: &costs,
            host,
            device: GpuSpec::v100(),
        });
        let rs = simulate(&SimConfig {
            plan: &single,
            costs: &costs,
            host,
            device: GpuSpec::v100(),
        });
        // multi-stream may pay sync submission costs but with tiny kernels
        // and front-end costs it must stay within a small factor, and
        // usually wins; assert no catastrophic regression.
        prop::ensure(rm.total_s <= rs.total_s * 1.5 + 1e-5, || {
            format!("multi {} vs single {}", rm.total_s, rs.total_s)
        })
    });
}

#[test]
fn prop_fusion_preserves_macs_and_reachability_skeleton() {
    use nimble::ops::op::total_macs;
    prop::check("fusion invariants", 40, |rng| {
        // build a random small CNN-ish graph via the builder
        let mut b = nimble::ops::GraphBuilder::new();
        let x = b.input(&[1, 8, 16, 16]);
        let mut frontier = vec![x];
        for _ in 0..rng.gen_range_inclusive(2, 10) {
            let from = *rng.choose(&frontier);
            let node = match rng.gen_range(4) {
                0 => b.conv_bn_relu(from, 8, 3, 1),
                1 => b.relu(from),
                2 => b.maxpool(from, 3, 1),
                _ => {
                    let c = b.conv(from, 8, 1, 1);
                    b.bn(c)
                }
            };
            frontier.push(node);
        }
        let g = b.finish();
        let f = nimble::ops::fuse_graph(&g);
        prop::ensure(f.validate().is_ok(), || "fused graph invalid".into())?;
        prop::ensure(total_macs(&g) == total_macs(&f), || "MACs changed".into())?;
        prop::ensure(f.n_nodes() <= g.n_nodes(), || "fusion grew the graph".into())
    });
}

#[test]
fn prop_arena_plan_valid_for_schedule_shaped_lifetimes() {
    use nimble::aot::memory::{plan_arena, plan_is_valid, Lifetime};
    prop::check("arena planning on chain-structured lifetimes", 60, |rng| {
        let n = rng.gen_range_inclusive(2, 60);
        let lts: Vec<Lifetime> = (0..n)
            .map(|i| Lifetime {
                def_step: i,
                last_use_step: i + rng.gen_range_inclusive(1, 6),
                bytes: (rng.gen_range(1_000_000) + 4) as u64,
            })
            .collect();
        let plan = plan_arena(&lts);
        prop::ensure(plan_is_valid(&lts, &plan), || "overlapping live tensors".into())?;
        prop::ensure(plan.arena_bytes <= plan.unshared_bytes(), || "worse than unshared".into())
    });
}
