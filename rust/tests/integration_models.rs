//! Integration: model-zoo fidelity — MAC counts against the paper's
//! Table 1 and reference implementations, plus structural invariants.

use nimble::models;
use nimble::ops::op::{n_real_ops, total_macs};

fn gmacs(name: &str) -> f64 {
    total_macs(&models::build(name, 1)) as f64 / 1e9
}

#[test]
fn paper_table1_macs_within_35_percent() {
    for spec in models::MODELS {
        if let Some(paper) = spec.paper_gmacs {
            let got = gmacs(spec.name);
            let rel = (got - paper).abs() / paper;
            assert!(rel < 0.35, "{}: {got:.2} vs paper {paper} ({:.0}% off)", spec.name, rel * 100.0);
        }
    }
}

#[test]
fn reference_macs_for_non_table1_models() {
    // torchvision/reference counts: resnet50 4.1, resnet101 7.8,
    // mobilenet_v2 0.30, efficientnet_b0 0.39 GMACs.
    for (name, reference, tol) in [
        ("resnet50", 4.1, 0.15),
        ("resnet101", 7.8, 0.15),
        ("mobilenet_v2", 0.30, 0.25),
        ("efficientnet_b0", 0.39, 0.30),
    ] {
        let got = gmacs(name);
        let rel: f64 = (got - reference) / reference;
        assert!(rel.abs() < tol, "{name}: {got:.3} vs ref {reference}");
    }
}

#[test]
fn op_counts_reflect_architecture_class() {
    let ops = |m: &str| n_real_ops(&models::build(m, 1));
    // NAS nets have several times more operators than ResNets — the very
    // reason they are scheduling-bound.
    assert!(ops("nasnet_a_mobile") > 3 * ops("resnet50"));
    assert!(ops("nasnet_a_large") > ops("nasnet_a_mobile"));
    assert!(ops("mini_inception") < 30);
}

#[test]
fn training_graphs_are_consistent() {
    for name in ["resnet50_cifar", "mobilenet_v2_cifar", "bert_base"] {
        let fwd = models::build(name, 32);
        let train = models::build_train(name, 32);
        assert!(train.validate().is_ok(), "{name}");
        let ratio = total_macs(&train) as f64 / total_macs(&fwd) as f64;
        assert!((2.5..3.5).contains(&ratio), "{name}: train/fwd MACs {ratio}");
    }
}

#[test]
fn batch_one_and_thirty_two_shapes_consistent() {
    for name in ["resnet50", "bert_base"] {
        let g1 = models::build(name, 1);
        let g32 = models::build(name, 32);
        assert_eq!(g1.n_nodes(), g32.n_nodes(), "{name}: batch must not change topology");
        let m1 = total_macs(&g1) as f64;
        let m32 = total_macs(&g32) as f64;
        assert!((m32 / m1 - 32.0).abs() < 0.5, "{name}: MACs must scale with batch");
    }
}
