//! Cluster-layer integration: drain rerouting with zero dangling
//! tickets, dead-letter failover off a lethally-faulted replica, the
//! deterministic SLO replica scale-out, and the merged Prometheus
//! exposition.

use nimble::cluster::{Cluster, ReplicaState};
use nimble::fault::FaultPlan;
use nimble::serving::{InferOutcome, InferRequest};
use std::time::{Duration, Instant};

const TIMEOUT: Duration = Duration::from_secs(60);

fn mini_cluster(replicas: usize) -> Cluster {
    Cluster::builder()
        .model("mini_inception")
        .buckets(&[1, 4])
        .replicas(replicas)
        .route_p2c(5)
        .build()
        .expect("cluster builds")
}

/// The drain regression the ISSUE pins: a draining replica's traffic
/// reroutes to survivors and not one ticket dangles — every request
/// submitted before, during, and after the drain resolves.
#[test]
fn draining_replica_reroutes_traffic_with_zero_dangling_tickets() {
    let cluster = mini_cluster(3);
    let len = cluster.example_len();
    let input = |i: usize| vec![i as f32 / 64.0; len];

    // Phase 1: a burst admitted while all three replicas are live.
    let mut tickets = Vec::new();
    for i in 0..12 {
        tickets.push(cluster.submit(InferRequest::new(input(i))).expect("routable"));
    }
    // Drain replica 0 with that burst still in flight: its admitted
    // work must flush, not drop.
    let drained = cluster.drain_replica(0).expect("drain flushes");
    assert_eq!(
        drained.n_requests + drained.deadline_shed + drained.failed,
        drained.n_requests,
        "a faultless, deadline-less drain flushes everything as output"
    );
    assert_eq!(cluster.live_replicas(), 2);

    // Phase 2: traffic after the drain routes to the survivors.
    for i in 12..24 {
        let t = cluster.submit(InferRequest::new(input(i))).expect("still routable");
        assert_ne!(t.replica(), Some(0), "drained replica must leave the routable set");
        tickets.push(t);
    }

    // Zero dangling: every ticket resolves, all as outputs.
    for (i, mut t) in tickets.into_iter().enumerate() {
        match t.outcome_timeout(TIMEOUT).expect("ticket must resolve") {
            InferOutcome::Output(v) => assert_eq!(v.len(), cluster.output_len(), "ticket {i}"),
            other => panic!("ticket {i} resolved {other:?}, expected output"),
        }
    }
    let report = cluster.shutdown().expect("drains");
    assert_eq!(report.submitted, 24);
    assert_eq!(report.completed(), 24);
    assert_eq!(report.router_shed, 0);
    assert!(report.accounting_closes(), "{}", report.render());
    assert_eq!(report.per_replica[0].state, ReplicaState::Retired);
    assert_eq!(report.leased_arena_bytes, 0, "arena pools must balance");
}

/// A replica whose engine always errors dead-letters everything routed
/// to it; the cluster tickets fail over to the healthy replica and the
/// client sees only outputs.
#[test]
fn lethal_replica_dead_letters_fail_over_to_survivors() {
    let lethal = FaultPlan { engine_error: 1.0, ..FaultPlan::seeded(13) };
    let cluster = Cluster::builder()
        .model("mini_inception")
        .buckets(&[1])
        .replicas(2)
        .route_p2c(17)
        .replica_fault_plan(0, lethal)
        .failover(2)
        .build()
        .expect("cluster builds");
    let len = cluster.example_len();

    let tickets: Vec<_> = (0..16)
        .map(|i| cluster.submit(InferRequest::new(vec![i as f32 / 16.0; len])).unwrap())
        .collect();
    for (i, mut t) in tickets.into_iter().enumerate() {
        match t.outcome_timeout(TIMEOUT).expect("ticket must resolve") {
            InferOutcome::Output(_) => {}
            other => panic!("ticket {i} resolved {other:?} despite failover"),
        }
    }
    let report = cluster.shutdown().expect("drains");
    assert_eq!(report.completed(), 16, "every request completes via failover");
    assert!(
        report.failovers >= 1,
        "p2c over a 2-replica cluster must route something to the lethal replica"
    );
    assert_eq!(report.failed(), report.failovers as usize, "each dead letter failed over once");
    assert!(report.accounting_closes(), "{}", report.render());
}

/// Killing a replica mid-flight: its in-flight dead letters fail over,
/// nothing dangles, and the slot reports `Failed`.
#[test]
fn killed_replica_mid_flight_leaves_no_dangling_tickets() {
    let lethal = FaultPlan { engine_error: 1.0, ..FaultPlan::seeded(29) };
    let cluster = Cluster::builder()
        .model("mini_inception")
        .buckets(&[1])
        .replicas(2)
        .route_round_robin()
        .replica_fault_plan(0, lethal)
        .build()
        .expect("cluster builds");
    let len = cluster.example_len();

    let tickets: Vec<_> = (0..8)
        .map(|i| cluster.submit(InferRequest::new(vec![i as f32 / 8.0; len])).unwrap())
        .collect();
    // Kill the lethal replica while the round-robin burst is in flight.
    let _ = cluster.kill_replica(0).expect("kill resolves in-flight work");
    assert_eq!(cluster.replica_states()[0], ReplicaState::Failed);

    for (i, mut t) in tickets.into_iter().enumerate() {
        match t.outcome_timeout(TIMEOUT).expect("ticket must resolve") {
            InferOutcome::Output(_) => {}
            other => panic!("ticket {i} resolved {other:?} despite failover"),
        }
    }
    let report = cluster.shutdown().expect("drains");
    assert_eq!(report.completed(), 8);
    assert!(report.accounting_closes(), "{}", report.render());
}

/// The SLO controller couples to replica count deterministically:
/// all-expired traffic breaches two 32-outcome windows back-to-back and
/// spawns exactly one replica (the `max_replicas(2)` ceiling).
#[test]
fn slo_breach_scales_out_replicas_up_to_the_ceiling() {
    let cluster = Cluster::builder()
        .model("mini_inception")
        .buckets(&[1])
        .replicas(1)
        .max_replicas(2)
        .slo(0.5)
        .build()
        .expect("cluster builds");
    let len = cluster.example_len();
    assert_eq!(cluster.live_replicas(), 1);

    // 96 requests already expired at the door: shed rate 1.0 in every
    // window, no timing involved.
    let mut tickets = Vec::new();
    for _ in 0..96 {
        let req = InferRequest::new(vec![0.0; len]).deadline(Instant::now());
        tickets.push(cluster.submit(req).expect("door shed still yields a ticket"));
    }
    assert_eq!(
        cluster.live_replicas(),
        2,
        "two consecutive breached windows must spawn a replica"
    );
    for mut t in tickets {
        assert!(matches!(
            t.outcome_timeout(TIMEOUT).expect("resolves"),
            InferOutcome::DeadlineShed
        ));
    }
    let report = cluster.shutdown().expect("drains");
    assert_eq!(report.replicas_spawned, 1, "the ceiling caps scale-out");
    assert_eq!(report.router_shed, 96);
    assert!(report.accounting_closes(), "{}", report.render());
}

/// The merged exposition: every sample labeled with its replica, one
/// HELP/TYPE header per family across the whole cluster.
#[test]
fn cluster_exposition_merges_replica_labels_without_collisions() {
    let cluster = Cluster::builder()
        .model("mini_inception")
        .buckets(&[1])
        .replicas(3)
        .telemetry()
        .build()
        .expect("cluster builds");
    let len = cluster.example_len();
    for i in 0..6 {
        cluster.infer(InferRequest::new(vec![i as f32 / 8.0; len])).expect("serves");
    }
    let text = cluster.metrics_text().expect("telemetry attached");
    // One metadata header per family, cluster-wide.
    for name in ["nimble_requests_admitted_total", "nimble_spans_recorded_total"] {
        assert_eq!(
            text.matches(&format!("# HELP {name}")).count(),
            1,
            "duplicate HELP for {name}:\n{text}"
        );
        assert_eq!(
            text.matches(&format!("# TYPE {name}")).count(),
            1,
            "duplicate TYPE for {name}:\n{text}"
        );
    }
    // Every sample carries a replica label; no series repeats.
    let mut seen = std::collections::HashSet::new();
    for line in text.lines().filter(|l| !l.starts_with('#') && !l.is_empty()) {
        let series = line.rsplit_once(' ').map(|(s, _)| s).unwrap_or(line);
        assert!(series.contains("replica=\""), "unlabeled sample: {line}");
        assert!(seen.insert(series.to_string()), "duplicate series: {series}");
    }
    let _ = cluster.shutdown().expect("drains");
}
