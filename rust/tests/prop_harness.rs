//! Randomized differential harness for the lane scheduler, driven
//! through the [`Runtime`] façade.
//!
//! Every case draws a random operator graph (seeded generator, up to 64
//! nodes), a random bucket set (1–8 compiled batch sizes), and random
//! traffic in a shuffled arrival order, then pushes it through the
//! lane-pipelined runtime and demands **bit-identical** outputs to the
//! serial single-thread `TapeEngine` replay of the same padded batches.
//! Batch composition is pinned by submitting pre-formed batches
//! (`InferRequest::batch`), so the only thing that varies between the
//! two runs is the execution schedule — exactly the thing the lane
//! scheduler must not let leak into results.
//!
//! The deadline property additionally pins the shed accounting: with
//! `deadline = ∞` outputs stay bit-identical to the oracle; with
//! already-expired deadlines every shed is observed exactly once
//! (`completed + deadline_shed == submitted`, no ticket unresolved).
//!
//! The base seed is fixed (overridable via `NIMBLE_PROP_SEED` — CI pins
//! it), and every failure message carries the case seed that reproduces
//! it.

use nimble::coordinator::InferEngine;
use nimble::models::rand_cell::{random_cell, RANDOM_CELL_EXAMPLE_LEN};
use nimble::serving::{InferOutcome, InferRequest, LaneConfig, Runtime, TapeEngine};
use nimble::util::prop::{check_from, ensure};
use nimble::util::Pcg32;
use std::time::{Duration, Instant};

fn base_seed() -> u64 {
    std::env::var("NIMBLE_PROP_SEED")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(0x1A5E_CAFE)
}

/// Draw 1–8 distinct bucket sizes.
fn random_buckets(rng: &mut Pcg32) -> Vec<usize> {
    const CHOICES: [usize; 8] = [1, 2, 3, 4, 6, 8, 12, 16];
    let n = rng.gen_range_inclusive(1, 8);
    let mut picks = CHOICES.to_vec();
    rng.shuffle(&mut picks);
    picks.truncate(n);
    picks.sort_unstable();
    picks
}

/// Lane config with headroom so the harness never trips load shedding.
fn roomy_config(max_wait: Duration) -> LaneConfig {
    LaneConfig { max_wait, lane_cap: 12, buffers_per_lane: 14, ..Default::default() }
}

fn random_input(rng: &mut Pcg32, len: usize) -> Vec<f32> {
    (0..len).map(|_| rng.gen_f32_range(-1.0, 1.0)).collect()
}

/// Single-thread serial oracle over all buckets of a random cell.
fn oracle_engine(
    graph_seed: u64,
    n_nodes: usize,
    buckets: &[usize],
) -> Result<TapeEngine, String> {
    Runtime::builder()
        .label("rand-cell")
        .graph_fn(move |b| random_cell(&mut Pcg32::new(graph_seed), n_nodes, b))
        .buckets(buckets)
        .worker_cap(1)
        .serial_oracle()
        .build_engine()
        .map_err(|e| format!("oracle build failed: {e:#}"))
}

/// ≥100 random cases: lane-pipelined outputs are bit-identical to the
/// serial oracle across random graphs, bucket sets, and arrival orders.
#[test]
fn lane_pipeline_is_bit_identical_to_serial_replay() {
    check_from("lane-vs-serial", base_seed(), 100, |rng| {
        let n_nodes = rng.gen_range_inclusive(8, 64);
        let graph_seed = rng.next_u64();
        let buckets = random_buckets(rng);
        let build = move |b: usize| random_cell(&mut Pcg32::new(graph_seed), n_nodes, b);

        // Serial oracle: one engine, all buckets, single-thread replay.
        let mut oracle = oracle_engine(graph_seed, n_nodes, &buckets)?;
        // Lane runtime: one single-bucket engine per lane, worker-capped.
        let server = Runtime::builder()
            .label("rand-cell")
            .graph_fn(build)
            .buckets(&buckets)
            .worker_cap(2)
            .lane_config(roomy_config(Duration::from_millis(1)))
            .build()
            .map_err(|e| format!("lane server start failed: {e:#}"))?;
        ensure(server.example_len() == RANDOM_CELL_EXAMPLE_LEN, || {
            format!("example_len {} != {}", server.example_len(), RANDOM_CELL_EXAMPLE_LEN)
        })?;

        // Random traffic: padded batches over random buckets, submitted
        // in a shuffled order so lanes interleave arbitrarily.
        let n_batches = rng.gen_range_inclusive(3, 10);
        let mut jobs: Vec<(usize, Vec<f32>)> = (0..n_batches)
            .map(|_| {
                let bucket = *rng.choose(&buckets);
                let input = random_input(rng, bucket * RANDOM_CELL_EXAMPLE_LEN);
                (bucket, input)
            })
            .collect();
        rng.shuffle(&mut jobs);

        let pending: Vec<_> = jobs
            .iter()
            .map(|(bucket, input)| server.submit(InferRequest::batch(*bucket, input.clone())))
            .collect::<Result<_, _>>()
            .map_err(|e| format!("submit failed: {e:#}"))?;
        let outputs: Vec<Vec<f32>> = pending
            .into_iter()
            .map(|ticket| ticket.wait().map_err(|e| format!("{e:#}")))
            .collect::<Result<_, _>>()?;

        for (i, ((bucket, input), got)) in jobs.iter().zip(&outputs).enumerate() {
            let want = oracle
                .infer_batch(*bucket, input)
                .map_err(|e| format!("oracle replay failed: {e:#}"))?;
            ensure(got.len() == want.len(), || {
                format!("job {i}: output length {} != {}", got.len(), want.len())
            })?;
            for (j, (a, b)) in got.iter().zip(&want).enumerate() {
                ensure(a.to_bits() == b.to_bits(), || {
                    format!(
                        "job {i} (bucket {bucket}) diverged at element {j}: {a:?} vs {b:?}"
                    )
                })?;
            }
        }
        let report = server.shutdown().map_err(|e| format!("shutdown failed: {e:#}"))?;
        ensure(report.n_batches == n_batches, || {
            format!("served {} batches, submitted {n_batches}", report.n_batches)
        })?;
        Ok(())
    });
}

/// ≥100 random cases (memory-reservation satellite): for random cells
/// replayed with 1–8 worker caps, the shared-arena layout is
/// bit-identical to the per-slot-buffer layout AND to the serial oracle,
/// the packed plan respects its own happens-before conflicts, the debug
/// canaries stay intact, and the steady-state hot path still performs
/// zero allocations.
#[test]
fn arena_replay_is_bit_identical_to_per_slot_replay() {
    use nimble::aot::memory::{
        happens_before_conflicts, plan_respects_conflicts, plan_with_conflicts,
    };
    use nimble::aot::tape::ReplayTape;
    use nimble::engine::executor::{ExecOptions, ReplayContext, SyntheticKernel};
    use nimble::matching::MatchingAlgo;
    use nimble::stream::rewrite::rewrite;

    check_from("arena-vs-per-slot", base_seed() ^ 0x00AE_0A0A, 100, |rng| {
        let n_nodes = rng.gen_range_inclusive(8, 64);
        let graph_seed = rng.next_u64();
        let batch = rng.gen_range_inclusive(1, 4);
        let g = random_cell(&mut Pcg32::new(graph_seed), n_nodes, batch);
        let plan = rewrite(&g, MatchingAlgo::HopcroftKarp);
        let tape = ReplayTape::for_op_graph(&g, &plan, 4096);

        // `plan_is_valid` on the happens-before lifetimes: the packed
        // plan must respect the conflict set it was derived from.
        let conflicts = happens_before_conflicts(&tape);
        let arena_plan = plan_with_conflicts(&tape.slot_bytes(), &conflicts);
        ensure(plan_respects_conflicts(&conflicts, &arena_plan), || {
            format!("invalid hb arena plan (graph seed {graph_seed:#x})")
        })?;
        ensure(arena_plan.arena_bytes <= arena_plan.unshared_bytes(), || {
            "packed arena larger than unshared".to_string()
        })?;

        let workers = rng.gen_range_inclusive(1, 8);
        let input = random_input(rng, tape.input_slots()[0].1);
        let mut packed = ReplayContext::with_options(
            tape.clone(),
            SyntheticKernel,
            ExecOptions { max_workers: Some(workers), ..Default::default() },
        );
        let mut per_slot = ReplayContext::with_options(
            tape.clone(),
            SyntheticKernel,
            ExecOptions { max_workers: Some(workers), unshared_slots: true, ..Default::default() },
        );
        let mut serial = ReplayContext::with_options(
            tape.clone(),
            SyntheticKernel,
            ExecOptions { max_workers: Some(1), ..Default::default() },
        );
        packed.replay_one(&input).map_err(|e| format!("packed replay: {e}"))?;
        per_slot.replay_one(&input).map_err(|e| format!("per-slot replay: {e}"))?;
        serial.replay_serial(&[&input]).map_err(|e| format!("serial replay: {e}"))?;

        for (name, other) in [("per-slot", &per_slot), ("serial", &serial)] {
            let (a, b) = (packed.output(), other.output());
            ensure(a.len() == b.len(), || format!("{name}: output length mismatch"))?;
            for (i, (x, y)) in a.iter().zip(b).enumerate() {
                ensure(x.to_bits() == y.to_bits(), || {
                    format!(
                        "{name}: output diverged at {i}: {x:?} vs {y:?} \
                         (graph seed {graph_seed:#x}, {workers} workers)"
                    )
                })?;
            }
        }
        // Same layout ⇒ every slot (even retired, partially-overwritten
        // ones) is bit-identical between parallel and serial schedules.
        for s in 0..tape.n_slots() {
            let (a, b) = (packed.slot(s), serial.slot(s));
            for (x, y) in a.iter().zip(b) {
                ensure(x.to_bits() == y.to_bits(), || {
                    format!("slot {s} diverged (graph seed {graph_seed:#x})")
                })?;
            }
        }
        packed.check_canaries().map_err(|e| format!("canary: {e}"))?;

        // Steady state stays allocation-free on the packed arena.
        packed.reset_alloc_events();
        packed.replay_one(&input).map_err(|e| format!("second packed replay: {e}"))?;
        ensure(packed.alloc_events() == 0, || "packed hot path allocated".to_string())
    });
}

/// ≥100 random cases (dynamic-lane-scaling tentpole): bursty per-bucket
/// traffic with random scale-up/scale-down churn through an ELASTIC
/// lane runtime — every lane leasing replay workers from ONE shared
/// work-stealing pool and drawing its arena from ONE shared
/// [`ArenaPool`] — produces outputs bit-identical to the serial oracle.
/// The companion `lane_pipeline_is_bit_identical_to_serial_replay`
/// property pins the static-lane runtime to the same oracle, so this is
/// exactly the elastic-vs-static bit-identity the scaling work must
/// preserve. Retired lanes must hand their arenas back: the pool
/// balances to zero leased bytes after shutdown, and acquires equal
/// lanes ever spawned (one single-bucket context per lane).
#[test]
fn elastic_scaling_is_bit_identical_and_returns_arenas_to_the_pool() {
    use nimble::aot::memory::ArenaPool;
    use nimble::engine::executor::SharedWorkerPool;
    use nimble::serving::ScaleOptions;

    check_from("elastic-scaling", base_seed() ^ 0x005C_A1E5, 100, |rng| {
        let n_nodes = rng.gen_range_inclusive(8, 48);
        let graph_seed = rng.next_u64();
        let mut buckets = random_buckets(rng);
        buckets.truncate(3); // elastic churn matters more than bucket count
        let build = move |b: usize| random_cell(&mut Pcg32::new(graph_seed), n_nodes, b);

        let mut oracle = oracle_engine(graph_seed, n_nodes, &buckets)?;
        let arena_pool = ArenaPool::new();
        let workers = SharedWorkerPool::new(rng.gen_range_inclusive(1, 3));
        let idle_retire = Duration::from_micros(rng.gen_range_inclusive(200, 2000) as u64);
        let scale = ScaleOptions {
            max_lanes_per_bucket: rng.gen_range_inclusive(1, 3),
            idle_retire,
            scale_up_backlog: rng.gen_range_inclusive(1, 3),
        };
        let server = Runtime::builder()
            .label("rand-cell")
            .graph_fn(build)
            .buckets(&buckets)
            .max_wait(Duration::from_micros(200))
            .lane_cap(rng.gen_range_inclusive(4, 8))
            .buffers_per_lane(10)
            .elastic(scale)
            .shared_pool_handle(workers.clone())
            .arena_pool(arena_pool.clone())
            .build()
            .map_err(|e| format!("elastic server start failed: {e:#}"))?;

        // Bursty traffic: waves of pre-formed batches concentrated on a
        // hot bucket, with occasional quiet gaps long enough for the
        // scaling pass to retire idle lanes — so lanes churn up AND
        // down while results are checked.
        let n_waves = rng.gen_range_inclusive(2, 4);
        let hot = *rng.choose(&buckets);
        let mut total_batches = 0usize;
        for wave in 0..n_waves {
            let clump = rng.gen_range_inclusive(3, 8);
            let jobs: Vec<(usize, Vec<f32>)> = (0..clump)
                .map(|i| {
                    // ~2/3 of a wave hammers the hot bucket.
                    let bucket =
                        if i % 3 == 2 { *rng.choose(&buckets) } else { hot };
                    let input = random_input(rng, bucket * RANDOM_CELL_EXAMPLE_LEN);
                    (bucket, input)
                })
                .collect();
            total_batches += jobs.len();
            let pending: Vec<_> = jobs
                .iter()
                .map(|(bucket, input)| {
                    server.submit(InferRequest::batch(*bucket, input.clone()))
                })
                .collect::<Result<_, _>>()
                .map_err(|e| format!("submit failed: {e:#}"))?;
            for (i, ((bucket, input), ticket)) in jobs.iter().zip(pending).enumerate() {
                let got = ticket
                    .wait()
                    .map_err(|e| format!("wave {wave} job {i} failed: {e:#}"))?;
                let want = oracle
                    .infer_batch(*bucket, input)
                    .map_err(|e| format!("oracle replay failed: {e:#}"))?;
                ensure(got.len() == want.len(), || {
                    format!("wave {wave} job {i}: output length {} != {}", got.len(), want.len())
                })?;
                for (j, (a, b)) in got.iter().zip(&want).enumerate() {
                    ensure(a.to_bits() == b.to_bits(), || {
                        format!(
                            "wave {wave} job {i} (bucket {bucket}) diverged at {j}: {a:?} vs {b:?} \
                             (graph seed {graph_seed:#x})"
                        )
                    })?;
                }
            }
            // A quiet gap past the idle window (and the dispatcher's
            // scaling-pass cadence) forces scale-down churn between
            // waves on roughly half the cases.
            if wave + 1 < n_waves && rng.gen_range_inclusive(0, 1) == 1 {
                std::thread::sleep(idle_retire + Duration::from_millis(12));
            }
        }

        let report = server.shutdown().map_err(|e| format!("shutdown failed: {e:#}"))?;
        ensure(report.n_batches == total_batches, || {
            format!("served {} batches, submitted {total_batches}", report.n_batches)
        })?;
        ensure(report.lanes_spawned() >= buckets.len(), || {
            "fewer lanes spawned than buckets".to_string()
        })?;
        // Pool balance: every lane ever spawned acquired exactly one
        // arena, and every one of them is back after shutdown.
        let stats = arena_pool.stats();
        ensure(stats.leased_bytes == 0, || {
            format!("{} arena bytes still leased after shutdown", stats.leased_bytes)
        })?;
        ensure(stats.acquires == report.lanes_spawned() as u64, || {
            format!(
                "{} arena acquires for {} lanes spawned (graph seed {graph_seed:#x})",
                stats.acquires,
                report.lanes_spawned()
            )
        })?;
        Ok(())
    });
}

/// ≥100 random cases (deadline satellite): through the ELASTIC runtime,
/// requests with `deadline = ∞` stay bit-identical to the serial
/// oracle, requests whose deadline already expired at submit are shed
/// exactly, every ticket resolves (`completed + deadline_shed ==
/// submitted`), and the report's shed accounting matches what the
/// clients observed.
#[test]
fn deadline_shed_accounting_closes_and_infinite_deadlines_stay_bit_identical() {
    use nimble::serving::ScaleOptions;

    check_from("deadline-shed", base_seed() ^ 0x00DE_AD11, 100, |rng| {
        let n_nodes = rng.gen_range_inclusive(8, 48);
        let graph_seed = rng.next_u64();
        let mut buckets = random_buckets(rng);
        buckets.truncate(2);
        let build = move |b: usize| random_cell(&mut Pcg32::new(graph_seed), n_nodes, b);

        let mut oracle = oracle_engine(graph_seed, n_nodes, &buckets)?;
        let scale = ScaleOptions {
            max_lanes_per_bucket: rng.gen_range_inclusive(1, 2),
            idle_retire: Duration::from_millis(2),
            scale_up_backlog: 2,
        };
        let server = Runtime::builder()
            .label("rand-cell")
            .graph_fn(build)
            .buckets(&buckets)
            .max_wait(Duration::from_micros(200))
            .lane_cap(12)
            .buffers_per_lane(14)
            .elastic(scale)
            .shared_pool(2)
            .build()
            .map_err(|e| format!("server start failed: {e:#}"))?;

        // Mixed traffic: every job is a pre-formed batch; a random
        // subset carries a deadline that already expired at submit
        // (certain shed), the rest split between NO deadline (∞, the
        // default) and a one-minute budget (never shed) — so the
        // completing path is exercised both with and without deadline
        // plumbing.
        let n_jobs = rng.gen_range_inclusive(4, 10);
        let jobs: Vec<(usize, Vec<f32>, bool)> = (0..n_jobs)
            .map(|_| {
                let bucket = *rng.choose(&buckets);
                let input = random_input(rng, bucket * RANDOM_CELL_EXAMPLE_LEN);
                let expired = rng.gen_range_inclusive(0, 2) == 0;
                (bucket, input, expired)
            })
            .collect();
        let n_expired = jobs.iter().filter(|(_, _, e)| *e).count();

        let pending: Vec<_> = jobs
            .iter()
            .map(|(bucket, input, expired)| {
                let req = InferRequest::batch(*bucket, input.clone());
                let req = if *expired {
                    req.deadline(Instant::now())
                } else if bucket % 2 == 0 {
                    req.deadline_in(Duration::from_secs(60))
                } else {
                    req // deadline = ∞ (none)
                };
                server.submit(req)
            })
            .collect::<Result<_, _>>()
            .map_err(|e| format!("submit failed: {e:#}"))?;

        let (mut completed, mut shed) = (0usize, 0usize);
        for (i, ((bucket, input, expired), ticket)) in jobs.iter().zip(pending).enumerate() {
            // No ticket may be dropped unresolved.
            let outcome = ticket
                .outcome()
                .map_err(|e| format!("job {i}: ticket unresolved: {e:#}"))?;
            match outcome {
                InferOutcome::Output(got) => {
                    completed += 1;
                    ensure(!*expired, || {
                        format!("job {i}: expired-at-submit request was served")
                    })?;
                    let want = oracle
                        .infer_batch(*bucket, input)
                        .map_err(|e| format!("oracle replay failed: {e:#}"))?;
                    ensure(got.len() == want.len(), || {
                        format!("job {i}: output length {} != {}", got.len(), want.len())
                    })?;
                    for (j, (a, b)) in got.iter().zip(&want).enumerate() {
                        ensure(a.to_bits() == b.to_bits(), || {
                            format!(
                                "job {i} (bucket {bucket}) diverged at {j}: {a:?} vs {b:?} \
                                 (graph seed {graph_seed:#x})"
                            )
                        })?;
                    }
                }
                InferOutcome::DeadlineShed => {
                    shed += 1;
                    ensure(*expired, || {
                        format!("job {i}: a one-minute deadline was shed")
                    })?;
                }
                InferOutcome::Failed(e) => {
                    return Err(format!("job {i} failed: {e}"));
                }
            }
        }
        ensure(completed + shed == n_jobs, || {
            format!("{completed} completed + {shed} shed != {n_jobs} submitted")
        })?;
        ensure(shed == n_expired, || {
            format!("{shed} shed but {n_expired} expired at submit")
        })?;

        let report = server.shutdown().map_err(|e| format!("shutdown failed: {e:#}"))?;
        ensure(report.deadline_shed == shed, || {
            format!(
                "report counts {} sheds, clients observed {shed} (graph seed {graph_seed:#x})",
                report.deadline_shed
            )
        })?;
        ensure(report.n_requests == completed, || {
            format!("report counts {} completions, clients saw {completed}", report.n_requests)
        })?;
        ensure(report.n_requests + report.deadline_shed == n_jobs, || {
            "report-side accounting must close".to_string()
        })?;
        Ok(())
    });
}

/// ≥60 random cases (EDF / admission-shedding satellite): seeded
/// [`FaultPlan`] engine faults and retries combined with tight
/// deadlines while admission-time shedding is on (the `edf` default).
/// The invariants:
///
/// * every ticket resolves exactly once — a request is never counted in
///   both the retry-then-shed and the failed path (the partition
///   `admitted == completed + deadline_shed + failed` closes on the
///   client side AND on the report);
/// * an expired-at-submit deadline is always shed at admission
///   (`admission_shed` counts at least those), never served and never
///   failed, whatever the fault plan injects;
/// * a roomy one-minute budget is never shed — admission estimates must
///   not shed live budgets spuriously;
/// * `admission_shed` never exceeds `deadline_shed` (it is a subset);
/// * a no-op plan retries nothing.
#[test]
fn edf_admission_shedding_with_retries_keeps_accounting_closed() {
    use nimble::serving::{FaultPlan, RetryPolicy};

    check_from("edf-admission-shed", base_seed() ^ 0x00ED_F00D, 60, |rng| {
        let n_nodes = rng.gen_range_inclusive(8, 48);
        let graph_seed = rng.next_u64();
        let mut buckets = random_buckets(rng);
        buckets.truncate(2);
        let build = move |b: usize| random_cell(&mut Pcg32::new(graph_seed), n_nodes, b);

        let plan = FaultPlan {
            engine_error: if rng.gen_range_inclusive(0, 1) == 0 {
                0.0
            } else {
                rng.gen_range_inclusive(1, 25) as f64 / 100.0
            },
            ..FaultPlan::seeded(rng.next_u64())
        };
        let noop = plan.is_noop();
        let retry = RetryPolicy {
            max_retries: rng.gen_range_inclusive(0, 3) as u32,
            backoff: if rng.gen_range_inclusive(0, 1) == 0 {
                Duration::ZERO
            } else {
                Duration::from_micros(200)
            },
        };
        let server = Runtime::builder()
            .label("rand-cell")
            .graph_fn(build)
            .buckets(&buckets)
            .max_wait(Duration::from_micros(200))
            .lane_cap(12)
            .buffers_per_lane(14)
            .worker_cap(2)
            .fault_plan(plan)
            .retry_policy(retry)
            .build()
            .map_err(|e| format!("edf chaos server start failed: {e:#}"))?;

        // Pre-formed batches in four deadline flavors: expired at submit
        // (certain admission shed), none, one minute (both never shed),
        // and a tight-but-live few-ms budget whose outcome the wall
        // clock decides (any resolution is legal; accounting still must
        // close).
        let n_jobs = rng.gen_range_inclusive(4, 12);
        let jobs: Vec<(usize, Vec<f32>, u8)> = (0..n_jobs)
            .map(|_| {
                let bucket = *rng.choose(&buckets);
                let input = random_input(rng, bucket * RANDOM_CELL_EXAMPLE_LEN);
                (bucket, input, rng.gen_range_inclusive(0, 3) as u8)
            })
            .collect();
        let n_expired = jobs.iter().filter(|(_, _, k)| *k == 0).count();

        let pending: Vec<_> = jobs
            .iter()
            .map(|(bucket, input, kind)| {
                let req = InferRequest::batch(*bucket, input.clone());
                let req = match kind {
                    0 => req.deadline(Instant::now()),
                    1 => req,
                    2 => req.deadline_in(Duration::from_secs(60)),
                    _ => req.deadline_in(Duration::from_millis(3)),
                };
                server.submit(req)
            })
            .collect::<Result<_, _>>()
            .map_err(|e| format!("submit failed: {e:#}"))?;

        let (mut completed, mut shed, mut failed) = (0usize, 0usize, 0usize);
        for (i, ((_, _, kind), ticket)) in jobs.iter().zip(pending).enumerate() {
            let outcome = ticket
                .outcome_timeout(Duration::from_secs(60))
                .map_err(|e| format!("job {i}: ticket unresolved: {e:#}"))?;
            match outcome {
                InferOutcome::Output(_) => {
                    completed += 1;
                    ensure(*kind != 0, || {
                        format!("job {i}: expired-at-submit request was served")
                    })?;
                }
                InferOutcome::DeadlineShed => {
                    shed += 1;
                    ensure(*kind == 0 || *kind == 3, || {
                        format!("job {i}: a roomy budget was shed (kind {kind})")
                    })?;
                }
                InferOutcome::Failed(e) => {
                    failed += 1;
                    ensure(!noop, || format!("job {i} failed under a no-op plan: {e}"))?;
                    ensure(*kind != 0, || {
                        format!("job {i}: expired-at-submit request reached the engine: {e}")
                    })?;
                }
            }
        }
        ensure(completed + shed + failed == n_jobs, || {
            format!("{completed} completed + {shed} shed + {failed} failed != {n_jobs}")
        })?;
        ensure(shed >= n_expired, || {
            format!("{shed} shed but {n_expired} were expired at submit")
        })?;

        let report = server.shutdown().map_err(|e| format!("shutdown failed: {e:#}"))?;
        ensure(report.n_requests == completed, || {
            format!("report counts {} completions, clients saw {completed}", report.n_requests)
        })?;
        ensure(report.deadline_shed == shed, || {
            format!(
                "report counts {} sheds, clients observed {shed} (graph seed {graph_seed:#x})",
                report.deadline_shed
            )
        })?;
        ensure(report.failed == failed, || {
            format!("report counts {} failures, clients saw {failed}", report.failed)
        })?;
        ensure(report.n_requests + report.deadline_shed + report.failed == n_jobs, || {
            "report-side accounting must close with admission shedding on".to_string()
        })?;
        ensure(report.admission_shed <= report.deadline_shed, || {
            format!(
                "admission_shed {} exceeds deadline_shed {}",
                report.admission_shed, report.deadline_shed
            )
        })?;
        ensure(report.admission_shed >= n_expired, || {
            format!(
                "admission_shed {} < {n_expired} expired-at-submit requests",
                report.admission_shed
            )
        })?;
        if noop {
            ensure(report.retries == 0, || {
                format!("{} retries under a no-op plan", report.retries)
            })?;
        }
        Ok(())
    });
}

/// ≥20 random cases (builder-equivalence satellite): `Runtime::builder()`
/// with default knobs is bit-identical to the legacy
/// `TapeEngine` + `NimbleServer::start_with` constructor path on the
/// same sequential traffic (single blocking requests pin the batch
/// composition on both sides).
#[test]
fn builder_default_runtime_matches_the_legacy_single_engine_path() {
    check_from("builder-vs-legacy", base_seed() ^ 0x00B1_14DE, 20, |rng| {
        let n_nodes = rng.gen_range_inclusive(8, 40);
        let graph_seed = rng.next_u64();
        let buckets = random_buckets(rng);
        let build = move |b: usize| random_cell(&mut Pcg32::new(graph_seed), n_nodes, b);

        // The legacy constructor matrix, exactly as PR-2 clients wrote it.
        #[allow(deprecated)]
        let legacy = nimble::serving::NimbleServer::start_with(
            move || TapeEngine::from_graph_fn("rand-cell", &buckets, None, build),
            Duration::from_micros(200),
        )
        .map_err(|e| format!("legacy server start failed: {e:#}"))?;
        // The façade with default knobs (lane topology, same buckets).
        let modern = Runtime::builder()
            .label("rand-cell")
            .graph_fn(build)
            .buckets(legacy.batch_sizes())
            .max_wait(Duration::from_micros(200))
            .build()
            .map_err(|e| format!("builder runtime start failed: {e:#}"))?;
        ensure(modern.batch_sizes() == legacy.batch_sizes(), || {
            "bucket sets must agree".to_string()
        })?;

        for i in 0..4 {
            let input = random_input(rng, RANDOM_CELL_EXAMPLE_LEN);
            // One blocking request at a time pins the batch composition
            // to a single-example batch on the smallest bucket in BOTH
            // servers.
            #[allow(deprecated)]
            let want = legacy
                .infer(input.clone())
                .map_err(|e| format!("legacy infer failed: {e:#}"))?;
            let got = modern
                .infer(InferRequest::new(input))
                .map_err(|e| format!("builder infer failed: {e:#}"))?;
            ensure(got.len() == want.len(), || {
                format!("request {i}: output length {} != {}", got.len(), want.len())
            })?;
            for (j, (a, b)) in got.iter().zip(&want).enumerate() {
                ensure(a.to_bits() == b.to_bits(), || {
                    format!(
                        "request {i} diverged at element {j}: {a:?} vs {b:?} \
                         (graph seed {graph_seed:#x})"
                    )
                })?;
            }
        }
        let _ = modern.shutdown().map_err(|e| format!("shutdown failed: {e:#}"))?;
        let _ = legacy.shutdown().map_err(|e| format!("shutdown failed: {e:#}"))?;
        Ok(())
    });
}

/// The batcher path agrees with the oracle when composition is pinned to
/// single-request batches (strictly sequential blocking clients).
#[test]
fn sequential_requests_through_the_batcher_match_the_oracle() {
    check_from("lane-batcher-vs-serial", base_seed() ^ 0xD1FF, 20, |rng| {
        let n_nodes = rng.gen_range_inclusive(8, 40);
        let graph_seed = rng.next_u64();
        let buckets = random_buckets(rng);
        let build = move |b: usize| random_cell(&mut Pcg32::new(graph_seed), n_nodes, b);
        let smallest = buckets[0];

        let mut oracle = oracle_engine(graph_seed, n_nodes, &buckets)?;
        let server = Runtime::builder()
            .label("rand-cell")
            .graph_fn(build)
            .buckets(&buckets)
            .worker_cap(2)
            .lane_config(roomy_config(Duration::from_micros(200)))
            .build()
            .map_err(|e| format!("lane server start failed: {e:#}"))?;

        for i in 0..4 {
            let input = random_input(rng, RANDOM_CELL_EXAMPLE_LEN);
            // One blocking request at a time ⇒ the batcher forms a
            // single-example batch padded to the smallest bucket.
            let got = server
                .infer(InferRequest::new(input.clone()))
                .map_err(|e| format!("infer: {e:#}"))?;
            let mut padded = input;
            padded.resize(smallest * RANDOM_CELL_EXAMPLE_LEN, 0.0);
            let want = oracle
                .infer_batch(smallest, &padded)
                .map_err(|e| format!("oracle replay failed: {e:#}"))?;
            let out_len = got.len();
            ensure(want.len() >= out_len, || "oracle output too short".to_string())?;
            for (j, (a, b)) in got.iter().zip(&want[..out_len]).enumerate() {
                ensure(a.to_bits() == b.to_bits(), || {
                    format!("request {i} diverged at element {j}: {a:?} vs {b:?}")
                })?;
            }
        }
        let _ = server.shutdown().map_err(|e| format!("shutdown failed: {e:#}"))?;
        Ok(())
    });
}

/// Mixed async traffic: whatever the batch composition, every request is
/// answered exactly once with a well-formed, finite output, and the
/// per-lane stats add up.
#[test]
fn mixed_arrivals_all_served_and_lane_stats_consistent() {
    check_from("lane-mixed-arrivals", base_seed() ^ 0xA11, 15, |rng| {
        let n_nodes = rng.gen_range_inclusive(8, 40);
        let graph_seed = rng.next_u64();
        let buckets = random_buckets(rng);
        let build = move |b: usize| random_cell(&mut Pcg32::new(graph_seed), n_nodes, b);
        let server = Runtime::builder()
            .label("rand-cell")
            .graph_fn(build)
            .buckets(&buckets)
            .worker_cap(2)
            .lane_config(roomy_config(Duration::from_micros(500)))
            .build()
            .map_err(|e| format!("lane server start failed: {e:#}"))?;
        let n_requests = rng.gen_range_inclusive(5, 24);
        let pending: Vec<_> = (0..n_requests)
            .map(|_| {
                server.submit(InferRequest::new(random_input(rng, RANDOM_CELL_EXAMPLE_LEN)))
            })
            .collect::<Result<_, _>>()
            .map_err(|e| format!("submit failed: {e:#}"))?;
        for ticket in pending {
            let out = ticket.wait().map_err(|e| format!("request failed: {e:#}"))?;
            ensure(out.iter().all(|v| v.is_finite()), || "non-finite output".to_string())?;
        }
        let report = server.shutdown().map_err(|e| format!("shutdown failed: {e:#}"))?;
        ensure(report.n_requests == n_requests, || {
            format!("{} of {n_requests} requests accounted", report.n_requests)
        })?;
        ensure(report.lanes.len() == buckets.len(), || {
            format!("{} lane stats for {} buckets", report.lanes.len(), buckets.len())
        })?;
        let lane_total: usize = report.lanes.iter().map(|l| l.n_requests).sum();
        ensure(lane_total == n_requests, || {
            format!("lane stats account {lane_total} of {n_requests}")
        })?;
        ensure(report.lanes.iter().all(|l| l.alloc_events == 0), || {
            "steady-state lane dispatch allocated".to_string()
        })?;
        Ok(())
    });
}

/// ≥50 random cases (observability tentpole): the flight recorder is
/// execution-neutral and its span accounting closes.
///
/// * telemetry-off replay stays bit-identical to the serial oracle and
///   the steady-state hot path performs zero allocations — the absent
///   recorder costs one branch, never an alloc;
/// * telemetry-on replay produces the same bits too — recording spans
///   must not leak into results;
/// * after ring warmup (first replay touches each worker's ring once),
///   further replays allocate nothing: no arena events and no new
///   per-thread rings;
/// * `recorded + dropped == emitted` closes per ring AND in aggregate,
///   even on the small-capacity cases that force the drop-oldest path;
/// * the Chrome-trace export parses back with exactly `recorded` event
///   records.
#[test]
fn telemetry_is_execution_neutral_and_span_accounting_closes() {
    use nimble::aot::tape::ReplayTape;
    use nimble::engine::executor::{ExecOptions, ReplayContext, SyntheticKernel};
    use nimble::matching::MatchingAlgo;
    use nimble::stream::rewrite::rewrite;
    use nimble::telemetry::{parse_trace, Telemetry};

    check_from("telemetry-neutrality", base_seed() ^ 0x00F1_1647, 50, |rng| {
        let n_nodes = rng.gen_range_inclusive(8, 64);
        let graph_seed = rng.next_u64();
        let batch = rng.gen_range_inclusive(1, 4);
        let g = random_cell(&mut Pcg32::new(graph_seed), n_nodes, batch);
        let plan = rewrite(&g, MatchingAlgo::HopcroftKarp);
        let tape = ReplayTape::for_op_graph(&g, &plan, 4096);
        let input = random_input(rng, tape.input_slots()[0].1);

        // Small rings on many cases force drop-oldest; accounting must
        // close either way.
        let capacity = rng.gen_range_inclusive(8, 512);
        let tel = Telemetry::with_capacity(capacity);
        let labels: Vec<String> = (0..g.n_nodes()).map(|v| g.node(v).name.clone()).collect();
        tel.register_labels(&labels);

        // Telemetry-on uses the classic one-worker-per-stream pool so
        // every worker participates in every replay — that makes "one
        // warmup replay touches every ring" deterministic.
        let mut on = ReplayContext::with_options(
            tape.clone(),
            SyntheticKernel,
            ExecOptions { telemetry: Some(tel.clone()), ..Default::default() },
        );
        let workers = rng.gen_range_inclusive(1, 4);
        let mut off = ReplayContext::with_options(
            tape.clone(),
            SyntheticKernel,
            ExecOptions { max_workers: Some(workers), ..Default::default() },
        );
        let mut serial = ReplayContext::with_options(
            tape.clone(),
            SyntheticKernel,
            ExecOptions { max_workers: Some(1), ..Default::default() },
        );
        on.replay_one(&input).map_err(|e| format!("telemetry-on replay: {e}"))?;
        off.replay_one(&input).map_err(|e| format!("telemetry-off replay: {e}"))?;
        serial.replay_serial(&[&input]).map_err(|e| format!("serial replay: {e}"))?;

        for (name, ctx) in [("telemetry-on", &on), ("telemetry-off", &off)] {
            let (a, b) = (ctx.output(), serial.output());
            ensure(a.len() == b.len(), || format!("{name}: output length mismatch"))?;
            for (i, (x, y)) in a.iter().zip(b).enumerate() {
                ensure(x.to_bits() == y.to_bits(), || {
                    format!(
                        "{name}: output diverged from serial at {i}: {x:?} vs {y:?} \
                         (graph seed {graph_seed:#x})"
                    )
                })?;
            }
        }

        // Telemetry-off steady state: zero allocations.
        off.reset_alloc_events();
        off.replay_one(&input).map_err(|e| format!("telemetry-off steady replay: {e}"))?;
        ensure(off.alloc_events() == 0, || {
            "telemetry-off hot path allocated".to_string()
        })?;

        // Telemetry-on steady state: rings are warmed, so a further
        // replay adds zero arena events and zero new rings.
        let rings_before = tel.ring_allocs();
        ensure(rings_before >= 1 && rings_before <= tape.n_streams() as u64, || {
            format!(
                "{rings_before} rings allocated for {} stream workers",
                tape.n_streams()
            )
        })?;
        on.reset_alloc_events();
        on.replay_one(&input).map_err(|e| format!("telemetry-on steady replay: {e}"))?;
        ensure(on.alloc_events() == 0, || {
            "telemetry-on hot path allocated after warmup".to_string()
        })?;
        ensure(tel.ring_allocs() == rings_before, || {
            format!(
                "steady-state replay grew rings {rings_before} → {} (graph seed {graph_seed:#x})",
                tel.ring_allocs()
            )
        })?;

        // Span accounting closes per ring and in aggregate, and the
        // export round-trips with exactly the recorded events.
        let snap = tel.snapshot();
        ensure(snap.emitted > 0, || "no spans emitted".to_string())?;
        ensure(snap.recorded + snap.dropped == snap.emitted, || {
            format!(
                "aggregate accounting open: {} recorded + {} dropped != {} emitted",
                snap.recorded, snap.dropped, snap.emitted
            )
        })?;
        for (i, r) in snap.rings.iter().enumerate() {
            ensure(r.recorded + r.dropped == r.emitted, || {
                format!(
                    "ring {i} accounting open: {} recorded + {} dropped != {} emitted \
                     (capacity {capacity}, graph seed {graph_seed:#x})",
                    r.recorded, r.dropped, r.emitted
                )
            })?;
        }
        let slices =
            parse_trace(&tel.chrome_trace()).map_err(|e| format!("trace parse: {e}"))?;
        let events = slices.iter().filter(|s| s.ph == "X" || s.ph == "i").count();
        ensure(events == snap.recorded as usize, || {
            format!("trace carries {events} events for {} recorded", snap.recorded)
        })?;
        Ok(())
    });
}

/// ≥100 random cases (chaos-hardening tentpole): a random seeded
/// [`FaultPlan`] (engine errors/panics, replay worker deaths, arena
/// exhaustion, poisoning join timeouts — each often zero) under a
/// random [`RetryPolicy`] and bursty pre-formed batch traffic. The
/// invariants that must survive ANY plan:
///
/// * every ticket resolves (60 s cap turns a deadlock into a failure,
///   never a hang);
/// * survivors are bit-identical to the fault-free serial oracle —
///   retries and lane replacement must not leak into results;
/// * client-observed tallies match the report and accounting closes
///   (`n_requests + deadline_shed + failed == submitted`);
/// * a no-op plan degenerates to the fault-free system: zero failures,
///   zero retries;
/// * the shared [`ArenaPool`] balances to zero leased bytes after
///   shutdown even when lanes died and were replaced mid-run.
#[test]
fn chaos_faults_leave_survivors_bit_identical_and_accounting_closed() {
    use nimble::aot::memory::ArenaPool;
    use nimble::serving::{FaultPlan, RetryPolicy, ScaleOptions};

    check_from("chaos-faults", base_seed() ^ 0x00C4_A05, 100, |rng| {
        let n_nodes = rng.gen_range_inclusive(8, 48);
        let graph_seed = rng.next_u64();
        let mut buckets = random_buckets(rng);
        buckets.truncate(2);
        let build = move |b: usize| random_cell(&mut Pcg32::new(graph_seed), n_nodes, b);

        // Often-zero probabilities: roughly half the draws leave each
        // channel silent, so the property also pins the noop → fault-free
        // degeneracy; join timeouts (lane-fatal) stay rare to bound the
        // respawn churn per case.
        fn maybe(rng: &mut Pcg32, max_pct: usize) -> f64 {
            if rng.gen_range_inclusive(0, 1) == 0 {
                0.0
            } else {
                rng.gen_range_inclusive(1, max_pct) as f64 / 100.0
            }
        }
        let plan = FaultPlan {
            op_error: maybe(rng, 8),
            engine_error: maybe(rng, 25),
            engine_panic: maybe(rng, 10),
            worker_death: maybe(rng, 10),
            arena_exhaustion: maybe(rng, 10),
            join_timeout: if rng.gen_range_inclusive(0, 3) == 0 { 0.04 } else { 0.0 },
            ..FaultPlan::seeded(rng.next_u64())
        };
        let noop = plan.is_noop();
        let retry = RetryPolicy {
            max_retries: rng.gen_range_inclusive(0, 3) as u32,
            backoff: if rng.gen_range_inclusive(0, 1) == 0 {
                Duration::ZERO
            } else {
                Duration::from_micros(200)
            },
        };

        let mut oracle = oracle_engine(graph_seed, n_nodes, &buckets)?;
        let arena_pool = ArenaPool::new();
        let builder = Runtime::builder()
            .label("rand-cell")
            .graph_fn(build)
            .buckets(&buckets)
            .max_wait(Duration::from_micros(200))
            .lane_cap(12)
            .buffers_per_lane(14)
            .worker_cap(2)
            .arena_pool(arena_pool.clone())
            .fault_plan(plan.clone())
            .retry_policy(retry);
        let builder = if rng.gen_range_inclusive(0, 1) == 1 {
            builder.elastic(ScaleOptions {
                max_lanes_per_bucket: 2,
                idle_retire: Duration::from_millis(2),
                scale_up_backlog: 2,
            })
        } else {
            builder
        };
        let server =
            builder.build().map_err(|e| format!("chaos server start failed: {e:#}"))?;

        // One burst of pre-formed batches (pinned composition, no
        // deadlines): each must resolve as Output or Failed, nothing
        // else, and nothing may dangle.
        let n_jobs = rng.gen_range_inclusive(4, 12);
        let jobs: Vec<(usize, Vec<f32>)> = (0..n_jobs)
            .map(|_| {
                let bucket = *rng.choose(&buckets);
                let input = random_input(rng, bucket * RANDOM_CELL_EXAMPLE_LEN);
                (bucket, input)
            })
            .collect();
        let pending: Vec<_> = jobs
            .iter()
            .map(|(bucket, input)| server.submit(InferRequest::batch(*bucket, input.clone())))
            .collect::<Result<_, _>>()
            .map_err(|e| format!("submit failed: {e:#}"))?;

        let (mut completed, mut failed) = (0usize, 0usize);
        for (i, ((bucket, input), ticket)) in jobs.iter().zip(pending).enumerate() {
            let outcome = ticket
                .outcome_timeout(Duration::from_secs(60))
                .map_err(|e| format!("job {i}: ticket unresolved (deadlock?): {e:#}"))?;
            match outcome {
                InferOutcome::Output(got) => {
                    completed += 1;
                    let want = oracle
                        .infer_batch(*bucket, input)
                        .map_err(|e| format!("oracle replay failed: {e:#}"))?;
                    ensure(got.len() == want.len(), || {
                        format!("job {i}: output length {} != {}", got.len(), want.len())
                    })?;
                    for (j, (a, b)) in got.iter().zip(&want).enumerate() {
                        ensure(a.to_bits() == b.to_bits(), || {
                            format!(
                                "job {i} (bucket {bucket}) diverged at {j}: {a:?} vs {b:?} \
                                 (graph seed {graph_seed:#x})"
                            )
                        })?;
                    }
                }
                InferOutcome::Failed(e) => {
                    failed += 1;
                    ensure(!noop, || {
                        format!("job {i} failed under a no-op fault plan: {e}")
                    })?;
                    ensure(
                        e.contains("injected") || e.contains("lane") || e.contains("poisoned"),
                        || format!("job {i}: failure not traceable to an injection: {e}"),
                    )?;
                }
                InferOutcome::DeadlineShed => {
                    return Err(format!("job {i} shed without a deadline"));
                }
            }
        }
        ensure(completed + failed == n_jobs, || {
            format!("{completed} completed + {failed} failed != {n_jobs} submitted")
        })?;

        let report = server.shutdown().map_err(|e| format!("shutdown failed: {e:#}"))?;
        ensure(report.n_requests == completed, || {
            format!("report counts {} completions, clients saw {completed}", report.n_requests)
        })?;
        ensure(report.failed == failed, || {
            format!("report counts {} failures, clients saw {failed}", report.failed)
        })?;
        ensure(report.deadline_shed == 0, || {
            format!("{} sheds without deadlines", report.deadline_shed)
        })?;
        ensure(report.n_requests + report.deadline_shed + report.failed == n_jobs, || {
            "report-side accounting must close".to_string()
        })?;
        if noop {
            ensure(report.retries == 0, || {
                format!("{} retries under a no-op plan", report.retries)
            })?;
        }
        let stats = arena_pool.stats();
        ensure(stats.leased_bytes == 0, || {
            format!(
                "{} arena bytes still leased after chaos shutdown (graph seed {graph_seed:#x})",
                stats.leased_bytes
            )
        })?;
        Ok(())
    });
}

/// The static plan verifier (`aot::verify`): zero false positives on
/// seeded legal plans under both arena layouts, every oracle-certified
/// mutant killed with the diagnostic kind its class predicts (plus a
/// concrete witness for races and alias overlaps), and the
/// `dependencies_are_synchronized` shim staying equivalent to the
/// legacy operational oracle it replaced — on legal tapes and mutants
/// alike.
#[test]
fn plan_verifier_accepts_legal_plans_and_kills_every_mutant() {
    use nimble::aot::memory::{happens_before_conflicts, plan_with_conflicts, ArenaPlan};
    use nimble::aot::verify::mutate::{mutate, MutationKind, ALL_MUTATIONS};
    use nimble::aot::verify::verify_with_arena;
    use nimble::aot::{DiagKind, ReplayTape};
    use nimble::matching::MatchingAlgo;
    use nimble::stream::rewrite::rewrite;

    // `check_from` takes a `Fn` closure, so kill counters live behind a
    // `RefCell`; they only exist to prove each class actually fired.
    let kills = std::cell::RefCell::new([0usize; ALL_MUTATIONS.len()]);
    check_from("plan-verifier", base_seed() ^ 0x7E81_F1ED, 120, |rng| {
        let n_nodes = rng.gen_range_inclusive(8, 64);
        let batch = *rng.choose(&[1usize, 2, 4]);
        let g = random_cell(rng, n_nodes, batch);
        let plan = rewrite(&g, MatchingAlgo::HopcroftKarp);
        let tape = ReplayTape::for_op_graph(&g, &plan, 4096);
        let bytes = tape.slot_bytes();
        let packed = plan_with_conflicts(&bytes, &happens_before_conflicts(&tape));
        let unshared = ArenaPlan::unshared(&bytes);

        // Zero false positives: the optimizer's own output verifies
        // clean under both layouts, and the shim agrees with the oracle.
        for (label, arena) in [("packed", &packed), ("unshared", &unshared)] {
            let report = verify_with_arena(&tape, arena);
            ensure(report.is_clean(), || {
                format!("false positive on a legal plan ({label} arena):\n{}", report.render())
            })?;
        }
        ensure(
            tape.dependencies_are_synchronized() == tape.dependencies_are_synchronized_legacy(),
            || "shim disagrees with the legacy oracle on a legal tape".to_string(),
        )?;

        // Zero false negatives: every mutant the legacy oracle certifies
        // broken (or, for shrink-offset, broken by construction) must be
        // flagged with a kind from its class's expected set.
        for (class, kind) in ALL_MUTATIONS.into_iter().enumerate() {
            let Some(m) = mutate(&tape, &packed, kind, rng) else { continue };
            let report = verify_with_arena(&m.tape, &m.arena);
            ensure(!report.is_clean(), || {
                format!("false negative: {} ({}) verified clean", kind.name(), m.description)
            })?;
            let allowed: &[DiagKind] = match kind {
                MutationKind::DropSync => &[DiagKind::Race, DiagKind::UseBeforeDef],
                MutationKind::RetargetWait | MutationKind::SwapStreams => {
                    &[DiagKind::Race, DiagKind::UseBeforeDef, DiagKind::HbCycle]
                }
                MutationKind::ShrinkOffset => &[DiagKind::AliasOverlap],
            };
            ensure(allowed.iter().any(|&k| report.has(k)), || {
                format!(
                    "{} ({}) flagged, but with unexpected kinds:\n{}",
                    kind.name(),
                    m.description,
                    report.render()
                )
            })?;
            for d in &report.diagnostics {
                if matches!(d.kind, DiagKind::Race | DiagKind::AliasOverlap) {
                    ensure(d.witness.is_some(), || {
                        format!("{} diagnostic lacks a witness: {}", d.kind.name(), d.message)
                    })?;
                }
            }
            ensure(
                m.tape.dependencies_are_synchronized()
                    == m.tape.dependencies_are_synchronized_legacy(),
                || format!("shim disagrees with the legacy oracle on mutant: {}", m.description),
            )?;
            kills.borrow_mut()[class] += 1;
        }
        Ok(())
    });
    for (kind, &n) in ALL_MUTATIONS.iter().zip(kills.borrow().iter()) {
        assert!(
            n >= 10,
            "mutation class {} produced only {n} mutants over 120 cases — \
             the kill property barely exercised it",
            kind.name()
        );
    }
}

/// `builder().verify(Strict)` is a build-time gate only: it accepts the
/// optimizer's (clean) plans and serves outputs bit-identical to a
/// `verify(Off)` twin — certification adds nothing to the replay path.
#[test]
fn strict_verification_is_execution_neutral() {
    use nimble::serving::VerifyMode;
    check_from("verify-strict-neutral", base_seed() ^ 0x05_7121C7, 10, |rng| {
        let n_nodes = rng.gen_range_inclusive(8, 40);
        let graph_seed = rng.next_u64();
        let buckets = random_buckets(rng);
        let build = move |b: usize| random_cell(&mut Pcg32::new(graph_seed), n_nodes, b);
        let mk = |mode: VerifyMode| {
            Runtime::builder()
                .label("rand-cell")
                .graph_fn(build)
                .buckets(&buckets)
                .lane_config(roomy_config(Duration::from_micros(200)))
                .verify(mode)
                .build()
        };
        let strict = mk(VerifyMode::Strict)
            .map_err(|e| format!("Strict refused a legal plan (graph seed {graph_seed:#x}): {e:#}"))?;
        let off = mk(VerifyMode::Off).map_err(|e| format!("baseline build failed: {e:#}"))?;
        for i in 0..3 {
            let input = random_input(rng, RANDOM_CELL_EXAMPLE_LEN);
            let a = strict
                .infer(InferRequest::new(input.clone()))
                .map_err(|e| format!("strict infer: {e:#}"))?;
            let b = off.infer(InferRequest::new(input)).map_err(|e| format!("off infer: {e:#}"))?;
            ensure(a.len() == b.len(), || {
                format!("request {i}: output lengths differ ({} vs {})", a.len(), b.len())
            })?;
            for (j, (x, y)) in a.iter().zip(&b).enumerate() {
                ensure(x.to_bits() == y.to_bits(), || {
                    format!(
                        "request {i} diverged at element {j}: {x:?} vs {y:?} \
                         (graph seed {graph_seed:#x})"
                    )
                })?;
            }
        }
        let _ = strict.shutdown().map_err(|e| format!("shutdown failed: {e:#}"))?;
        let _ = off.shutdown().map_err(|e| format!("shutdown failed: {e:#}"))?;
        Ok(())
    });
}

/// ≥100 random cases of the cluster layer: random replica counts,
/// bursty hinted/deadline traffic, and mid-run drain/kill churn.
/// Surviving outputs stay bit-identical to the serial oracle, exactly
/// the expired requests shed (at the router's door), no ticket is left
/// unresolved, the cluster-wide accounting closes, and every replica's
/// arena pool balances to zero leased bytes after shutdown.
#[test]
fn cluster_routing_survives_churn_bit_identical_with_closed_accounting() {
    use nimble::cluster::Cluster;

    check_from("cluster-churn", base_seed() ^ 0x0C10_57E2, 100, |rng| {
        let n_nodes = rng.gen_range_inclusive(8, 48);
        let graph_seed = rng.next_u64();
        let mut buckets = random_buckets(rng);
        buckets.truncate(2);
        let replicas = rng.gen_range_inclusive(1, 4);
        let build = move |b: usize| random_cell(&mut Pcg32::new(graph_seed), n_nodes, b);

        let mut oracle = oracle_engine(graph_seed, n_nodes, &buckets)?;
        let builder = Cluster::builder()
            .label("rand-cell")
            .graph_fn(build)
            .buckets(&buckets)
            .replicas(replicas)
            .worker_cap(2)
            .lane_config(roomy_config(Duration::from_micros(200)));
        let builder = if rng.gen_range_inclusive(0, 1) == 1 {
            builder.route_p2c(rng.next_u64())
        } else {
            builder.route_round_robin()
        };
        let cluster =
            builder.build().map_err(|e| format!("cluster start failed: {e:#}"))?;

        // Bursty traffic: pre-formed batches (pinned composition), some
        // bucket-hinted, roughly a third already expired at the door.
        let n_jobs = rng.gen_range_inclusive(4, 12);
        let jobs: Vec<(usize, Vec<f32>, bool)> = (0..n_jobs)
            .map(|_| {
                let bucket = *rng.choose(&buckets);
                let input = random_input(rng, bucket * RANDOM_CELL_EXAMPLE_LEN);
                let expired = rng.gen_range_inclusive(0, 2) == 0;
                (bucket, input, expired)
            })
            .collect();
        let hinted: Vec<bool> =
            (0..n_jobs).map(|_| rng.gen_range_inclusive(0, 1) == 1).collect();
        // Mid-run churn: at a random point in the burst, drain or kill
        // one replica (only while another stays live to reroute to).
        let churn_at = rng.gen_range_inclusive(0, n_jobs);
        let churn_kill = rng.gen_range_inclusive(0, 1) == 1;
        let churn_target = rng.gen_range_inclusive(0, replicas - 1);

        let mut pending = Vec::with_capacity(n_jobs);
        for (i, (bucket, input, expired)) in jobs.iter().enumerate() {
            if i == churn_at && cluster.live_replicas() > 1 {
                let rep = if churn_kill {
                    cluster.kill_replica(churn_target)
                } else {
                    cluster.drain_replica(churn_target)
                };
                rep.map_err(|e| format!("churn on replica {churn_target} failed: {e:#}"))?;
            }
            let mut req = InferRequest::batch(*bucket, input.clone());
            if hinted[i] {
                req = req.hint(*bucket);
            }
            if *expired {
                req = req.deadline(Instant::now());
            }
            pending.push(
                cluster.submit(req).map_err(|e| format!("submit {i} failed: {e:#}"))?,
            );
        }

        let (mut completed, mut shed) = (0usize, 0usize);
        for (i, ((bucket, input, expired), mut ticket)) in
            jobs.iter().zip(pending).enumerate()
        {
            let outcome = ticket
                .outcome_timeout(Duration::from_secs(60))
                .map_err(|e| format!("job {i}: ticket unresolved (dangling?): {e:#}"))?;
            match outcome {
                InferOutcome::Output(got) => {
                    completed += 1;
                    ensure(!expired, || format!("job {i} completed past its deadline"))?;
                    let want = oracle
                        .infer_batch(*bucket, input)
                        .map_err(|e| format!("oracle replay failed: {e:#}"))?;
                    ensure(got.len() == want.len(), || {
                        format!("job {i}: output length {} != {}", got.len(), want.len())
                    })?;
                    for (j, (a, b)) in got.iter().zip(&want).enumerate() {
                        ensure(a.to_bits() == b.to_bits(), || {
                            format!(
                                "job {i} (bucket {bucket}) diverged at {j}: {a:?} vs {b:?} \
                                 (graph seed {graph_seed:#x})"
                            )
                        })?;
                    }
                }
                InferOutcome::DeadlineShed => {
                    shed += 1;
                    ensure(*expired, || format!("job {i} shed without a deadline"))?;
                }
                InferOutcome::Failed(e) => {
                    return Err(format!("job {i} failed without injected faults: {e}"));
                }
            }
        }
        let n_expired = jobs.iter().filter(|(_, _, e)| *e).count();
        ensure(completed + shed == n_jobs, || {
            format!("{completed} completed + {shed} shed != {n_jobs} submitted")
        })?;
        ensure(shed == n_expired, || {
            format!("{shed} shed != {n_expired} expired at the door")
        })?;

        let report =
            cluster.shutdown().map_err(|e| format!("cluster shutdown failed: {e:#}"))?;
        ensure(report.submitted == n_jobs as u64, || {
            format!("report counts {} submissions, clients made {n_jobs}", report.submitted)
        })?;
        ensure(report.router_shed == n_expired as u64, || {
            format!("{} door sheds != {n_expired} expired", report.router_shed)
        })?;
        ensure(report.completed() == completed, || {
            format!("report counts {} completions, clients saw {completed}", report.completed())
        })?;
        ensure(report.accounting_closes(), || {
            format!("cluster accounting must close:\n{}", report.render())
        })?;
        ensure(report.leased_arena_bytes == 0, || {
            format!(
                "{} arena bytes still leased after cluster shutdown (graph seed {graph_seed:#x})",
                report.leased_arena_bytes
            )
        })?;
        Ok(())
    });
}
