//! Integration: the serving stack — batching, correctness under
//! concurrency, error paths, per-bucket replay contexts.
//!
//! The primary tests run over the tape-backed [`TapeEngine`] (virtual
//! substrate, always available, no artifacts needed). The PJRT-backed
//! server tests live in the `xla` module at the bottom and additionally
//! skip without artifacts.

use nimble::serving::{NimbleServer, TapeEngine};
use nimble::util::Pcg32;
use std::time::Duration;

fn tape_server() -> NimbleServer {
    NimbleServer::start_with(
        || TapeEngine::new("mini_inception", &[1, 8]),
        Duration::from_millis(2),
    )
    .expect("tape server start")
}

fn inputs(n: usize, len: usize, seed: u64) -> Vec<Vec<f32>> {
    let mut rng = Pcg32::new(seed);
    (0..n).map(|_| (0..len).map(|_| rng.gen_f32_range(-1.0, 1.0)).collect()).collect()
}

#[test]
fn serves_requests_and_reports() {
    let server = tape_server();
    let len = server.example_len();
    let out_len = server.output_len();
    let mut pending = Vec::new();
    for input in inputs(20, len, 1) {
        pending.push(server.infer_async(input).unwrap());
    }
    for rx in pending {
        let logits = rx.recv().unwrap().unwrap();
        assert_eq!(logits.len(), out_len);
        assert!(logits.iter().all(|v| v.is_finite()));
    }
    let report = server.shutdown().unwrap();
    assert_eq!(report.n_requests, 20);
    assert!(report.n_batches >= 3, "20 reqs over max batch 8 → ≥3 batches");
    assert!(report.mean_batch_fill > 1.0);
}

#[test]
fn rejects_malformed_input() {
    let server = tape_server();
    let err = server.infer(vec![0.0; 5]);
    assert!(err.is_err(), "wrong-length input must be rejected");
    // server still healthy afterwards
    let ok = server.infer(vec![0.0; server.example_len()]);
    assert!(ok.is_ok());
    let _ = server.shutdown().unwrap();
}

#[test]
fn server_client_bucket_hint_is_honored_over_queue_depth() {
    // A lone request would depth-route to bucket 1; a client hint must
    // put it on the bucket-8 engine instead (satellite of the lane-aware
    // admission follow-up). The padded bucket-8 replay of the same input
    // is the oracle.
    use nimble::coordinator::InferEngine;
    let mut direct = TapeEngine::new("mini_inception", &[1, 8]).unwrap();
    let len = direct.example_len();
    let out_len = direct.output_len();
    let input = inputs(1, len, 77).pop().unwrap();
    let mut padded = input.clone();
    padded.resize(8 * len, 0.0);
    let want_hinted = direct.infer_batch(8, &padded).unwrap()[..out_len].to_vec();
    let want_plain = direct.infer_batch(1, &input).unwrap();

    let server = tape_server();
    let client = server.client();
    let hinted = client.infer_hinted(input.clone(), 8).unwrap();
    assert_eq!(hinted, want_hinted, "hint must route through the bucket-8 engine");
    let plain = client.infer(input).unwrap();
    assert_eq!(plain, want_plain, "unhinted requests keep depth routing");
    // A hint naming no compiled bucket is ignored, not an error.
    let ignored = client.infer_hinted(inputs(1, len, 78).pop().unwrap(), 5).unwrap();
    assert_eq!(ignored.len(), out_len);
    let _ = server.shutdown().unwrap();
}

#[test]
fn repeated_requests_are_deterministic() {
    let server = tape_server();
    let len = server.example_len();
    let input = inputs(1, len, 42).pop().unwrap();
    let a = server.infer(input.clone()).unwrap();
    let b = server.infer(input).unwrap();
    assert_eq!(a, b, "same input, same logits");
    let _ = server.shutdown().unwrap();
}

#[test]
fn server_responses_match_direct_engine_replay() {
    // The padded batch-bucket path must not change single-request results.
    use nimble::coordinator::InferEngine;
    let mut direct = TapeEngine::new("mini_inception", &[1, 8]).unwrap();
    let len = direct.example_len();
    let input = inputs(1, len, 9).pop().unwrap();
    let expect = direct.infer_batch(1, &input).unwrap();

    let server = tape_server();
    let got = server.infer(input).unwrap();
    assert_eq!(got, expect, "server (bucket 1) vs direct engine");
    let _ = server.shutdown().unwrap();
}

#[test]
fn padded_batch_values_match_direct_bucket_replay() {
    // Fill exactly one bucket-8 batch and check every row of the
    // server's un-padding against a direct replay of the same padded
    // batch — catches any off-by-one in row placement or slicing.
    use nimble::coordinator::InferEngine;
    let server = NimbleServer::start_with(
        || TapeEngine::new("mini_inception", &[1, 8]),
        Duration::from_millis(500), // long deadline: flush only on a full bucket
    )
    .expect("server");
    let len = server.example_len();
    let out_len = server.output_len();
    let ins = inputs(8, len, 1234);
    let pending: Vec<_> = ins.iter().map(|i| server.infer_async(i.clone()).unwrap()).collect();
    let got: Vec<Vec<f32>> =
        pending.into_iter().map(|rx| rx.recv().unwrap().unwrap()).collect();
    let report = server.shutdown().unwrap();
    assert_eq!(report.n_batches, 1, "test premise: one full bucket-8 batch");

    let mut direct = TapeEngine::new("mini_inception", &[1, 8]).unwrap();
    let padded: Vec<f32> = ins.concat();
    let expect = direct.infer_batch(8, &padded).unwrap();
    for (i, row) in got.iter().enumerate() {
        assert_eq!(
            row.as_slice(),
            &expect[i * out_len..(i + 1) * out_len],
            "row {i} mixed up by batching/un-padding"
        );
    }
}

#[test]
fn concurrent_clients_all_get_served() {
    // Many client threads firing at once through cloneable handles: every
    // request must get exactly one well-formed response (the synthetic
    // kernel is not row-separable across batch compositions, so value
    // equality across buckets is checked by the single-request tests and
    // the PJRT-mode tests instead).
    let server = tape_server();
    let len = server.example_len();
    let out_len = server.output_len();
    let handles: Vec<_> = inputs(24, len, 77)
        .into_iter()
        .map(|input| {
            let client = server.client();
            std::thread::spawn(move || {
                let got = client.infer(input).unwrap();
                assert_eq!(got.len(), out_len);
                assert!(got.iter().all(|v| v.is_finite()));
            })
        })
        .collect();
    for h in handles {
        h.join().unwrap();
    }
    let report = server.shutdown().unwrap();
    assert_eq!(report.n_requests, 24);
    assert!(report.n_batches <= 24, "concurrent requests should batch");
}

/// Shutdown must flush requests already sent, not drop them on the
/// engine channel (regression: `ServerClient` requests racing shutdown
/// used to die with "server dropped request").
#[test]
fn shutdown_flushes_in_flight_requests_single_engine() {
    // Long deadline: nothing would flush before shutdown arrives.
    let server = NimbleServer::start_with(
        || TapeEngine::new("mini_inception", &[1, 8]),
        Duration::from_millis(500),
    )
    .expect("server");
    let len = server.example_len();
    let pending: Vec<_> =
        inputs(10, len, 5).into_iter().map(|i| server.infer_async(i).unwrap()).collect();
    let report = server.shutdown().unwrap();
    assert_eq!(report.n_requests, 10, "all in-flight requests served at shutdown");
    for rx in pending {
        assert!(rx.recv().unwrap().is_ok(), "flushed request must succeed, not drop");
    }
}

#[test]
fn shutdown_flushes_in_flight_requests_lane_server() {
    use nimble::serving::{LaneConfig, LaneServer};
    let server = LaneServer::start(
        &[1, 8],
        |bucket| TapeEngine::new("mini_inception", &[bucket]),
        LaneConfig { max_wait: Duration::from_millis(500), ..Default::default() },
    )
    .expect("lane server");
    let len = server.example_len();
    let client = server.client();
    let pending: Vec<_> =
        inputs(10, len, 6).into_iter().map(|i| server.infer_async(i).unwrap()).collect();
    let report = server.shutdown().unwrap();
    assert_eq!(report.n_requests, 10, "all in-flight requests served at shutdown");
    for rx in pending {
        assert!(rx.recv().unwrap().is_ok(), "flushed request must succeed, not drop");
    }
    // Requests after shutdown fail fast with an explicit error.
    let err = client.infer(vec![0.0; len]);
    assert!(err.is_err(), "post-shutdown request must be rejected");
}

/// Deadlock/starvation regression: a fault-injected slow lane must not
/// stall the other lanes, and shutdown must still join every lane
/// thread cleanly.
#[test]
fn slow_lane_does_not_starve_other_lanes_and_shutdown_joins() {
    use nimble::coordinator::InferEngine;
    use nimble::serving::{LaneConfig, LaneServer};
    use std::time::Instant;

    /// Wraps a [`TapeEngine`] and sleeps on one bucket, simulating a
    /// stuck/overloaded engine.
    struct SlowLane {
        inner: TapeEngine,
        slow_bucket: usize,
        delay: Duration,
    }

    impl InferEngine for SlowLane {
        fn batch_sizes(&self) -> Vec<usize> {
            self.inner.batch_sizes()
        }
        fn example_len(&self) -> usize {
            self.inner.example_len()
        }
        fn output_len(&self) -> usize {
            self.inner.output_len()
        }
        fn infer_batch(&mut self, bucket: usize, input: &[f32]) -> anyhow::Result<Vec<f32>> {
            if bucket == self.slow_bucket {
                std::thread::sleep(self.delay);
            }
            self.inner.infer_batch(bucket, input)
        }
        fn stream_count(&self, bucket: usize) -> Option<usize> {
            self.inner.stream_count(bucket)
        }
    }

    const N_SLOW: usize = 3;
    const N_FAST: usize = 6;

    // Calibrate on this machine/build: one warmed direct batch-8 replay
    // bounds what a healthy fast lane needs, so the watchdog scales with
    // debug-mode and loaded-CI slowness instead of flaking.
    let t_fast = {
        let mut probe = TapeEngine::new("mini_inception", &[8]).unwrap();
        let z = vec![0.0f32; 8 * probe.example_len()];
        probe.infer_batch(8, &z).unwrap(); // warm-up
        let t0 = Instant::now();
        probe.infer_batch(8, &z).unwrap();
        t0.elapsed()
    };
    // Watchdog: generous for the fast lane (per-batch time × batches,
    // plus fixed headroom)…
    let watchdog = t_fast * (N_FAST as u32 + 2) + Duration::from_millis(500);
    // …while each slow-lane batch alone eats a full watchdog, so a
    // regression to single-engine-thread serialization (fast waits for
    // N_SLOW × delay) overshoots it 3× and fails loudly.
    let delay = watchdog;

    let server = LaneServer::start(
        &[1, 8],
        move |bucket| {
            Ok(SlowLane {
                inner: TapeEngine::new("mini_inception", &[bucket])?,
                slow_bucket: 1,
                delay,
            })
        },
        LaneConfig { max_wait: Duration::from_millis(1), ..Default::default() },
    )
    .expect("lane server");
    let len = server.example_len();
    let out_len = server.output_len();

    // Jam the slow lane first (its queue keeps it busy for 3 × delay)...
    let slow: Vec<_> = (0..N_SLOW)
        .map(|i| server.submit_batch(1, inputs(1, len, 100 + i as u64).concat()).unwrap())
        .collect();
    // ...then drive the fast lane and demand it drains under the watchdog.
    let t0 = Instant::now();
    let fast: Vec<_> = (0..N_FAST)
        .map(|i| server.submit_batch(8, inputs(8, len, 200 + i as u64).concat()).unwrap())
        .collect();
    for (i, rx) in fast.into_iter().enumerate() {
        let remaining = watchdog.saturating_sub(t0.elapsed());
        let out = rx
            .recv_timeout(remaining)
            .unwrap_or_else(|_| panic!("fast batch {i} starved behind the slow lane"))
            .expect("fast batch failed");
        assert_eq!(out.len(), 8 * out_len);
    }
    assert!(
        t0.elapsed() < watchdog,
        "fast lane took {:?} (watchdog {:?}), starved behind the slow lane",
        t0.elapsed(),
        watchdog
    );

    // The slow jobs still complete, and shutdown joins every lane.
    for rx in slow {
        assert!(rx.recv().unwrap().is_ok());
    }
    let report = server.shutdown().expect("shutdown joins all lanes");
    assert_eq!(report.lane(1).unwrap().n_batches, N_SLOW);
    assert_eq!(report.lane(8).unwrap().n_batches, N_FAST);
    // Sanity: the fast-lane outputs came from the real engine.
    let mut direct = TapeEngine::new("mini_inception", &[8]).unwrap();
    let batch = inputs(8, len, 200).concat();
    assert_eq!(direct.infer_batch(8, &batch).unwrap().len(), 8 * out_len);
}

/// PJRT-backed serving tests (feature `xla`; skip without artifacts).
#[cfg(feature = "xla")]
mod xla {
    use super::inputs;
    use nimble::coordinator::{EngineConfig, ExecMode};
    use nimble::serving::{NimbleServer, ServerConfig};
    use std::time::Duration;

    fn server(mode: ExecMode) -> Option<NimbleServer> {
        if !nimble::runtime::artifacts_available() {
            eprintln!("SKIP: artifacts not built");
            return None;
        }
        Some(
            NimbleServer::start(ServerConfig {
                engine: EngineConfig { mode, ..Default::default() },
                max_wait: Duration::from_millis(2),
            })
            .expect("server start"),
        )
    }

    #[test]
    fn serves_requests_and_reports_real_engine() {
        let Some(server) = server(ExecMode::Replay) else { return };
        let len = server.example_len();
        let mut pending = Vec::new();
        for input in inputs(20, len, 1) {
            pending.push(server.infer_async(input).unwrap());
        }
        for rx in pending {
            let logits = rx.recv().unwrap().unwrap();
            assert_eq!(logits.len(), server.output_len());
        }
        let report = server.shutdown().unwrap();
        assert_eq!(report.n_requests, 20);
        assert!(report.n_batches >= 3, "20 reqs over max batch 8 → ≥3 batches");
        assert!(report.mean_batch_fill > 1.0);
    }

    #[test]
    fn replay_and_eager_servers_agree() {
        let Some(replay) = server(ExecMode::Replay) else { return };
        let len = replay.example_len();
        let ins = inputs(4, len, 7);
        let out_replay: Vec<Vec<f32>> =
            ins.iter().map(|i| replay.infer(i.clone()).unwrap()).collect();
        let _ = replay.shutdown().unwrap();
        let Some(eager) = server(ExecMode::Eager) else { return };
        for (input, expected) in ins.into_iter().zip(out_replay) {
            let got = eager.infer(input).unwrap();
            for (a, b) in got.iter().zip(&expected) {
                assert!((a - b).abs() < 1e-4, "{a} vs {b}");
            }
        }
        let _ = eager.shutdown().unwrap();
    }
}
