//! Integration: the serving stack through the [`Runtime`] façade —
//! batching, correctness under concurrency, error paths, per-bucket
//! replay contexts, hint/deadline routing parity across topologies.
//!
//! The primary tests run over the tape-backed engines (virtual
//! substrate, always available, no artifacts needed). The PJRT-backed
//! server tests live in the `xla` module at the bottom and additionally
//! skip without artifacts.

use nimble::serving::{InferRequest, Runtime, TapeEngineOptions};
use nimble::util::Pcg32;
use std::time::Duration;

/// Single-engine-thread runtime (the PR-1 baseline topology).
fn tape_server() -> Runtime {
    Runtime::builder()
        .model("mini_inception")
        .buckets(&[1, 8])
        .single_thread()
        .max_wait(Duration::from_millis(2))
        .build()
        .expect("tape server start")
}

fn direct_engine(buckets: &[usize]) -> nimble::serving::TapeEngine {
    Runtime::builder()
        .model("mini_inception")
        .buckets(buckets)
        .build_engine()
        .expect("direct engine")
}

fn inputs(n: usize, len: usize, seed: u64) -> Vec<Vec<f32>> {
    let mut rng = Pcg32::new(seed);
    (0..n).map(|_| (0..len).map(|_| rng.gen_f32_range(-1.0, 1.0)).collect()).collect()
}

#[test]
fn serves_requests_and_reports() {
    let server = tape_server();
    let len = server.example_len();
    let out_len = server.output_len();
    let mut pending = Vec::new();
    for input in inputs(20, len, 1) {
        pending.push(server.submit(InferRequest::new(input)).unwrap());
    }
    for ticket in pending {
        let logits = ticket.wait().unwrap();
        assert_eq!(logits.len(), out_len);
        assert!(logits.iter().all(|v| v.is_finite()));
    }
    let report = server.shutdown().unwrap();
    assert_eq!(report.n_requests, 20);
    assert!(report.n_batches >= 3, "20 reqs over max batch 8 → ≥3 batches");
    assert!(report.mean_batch_fill > 1.0);
    assert_eq!(report.deadline_shed, 0, "no deadlines were set");
}

#[test]
fn rejects_malformed_input() {
    let server = tape_server();
    let err = server.infer(InferRequest::new(vec![0.0; 5]));
    assert!(err.is_err(), "wrong-length input must be rejected");
    // server still healthy afterwards
    let ok = server.infer(InferRequest::new(vec![0.0; server.example_len()]));
    assert!(ok.is_ok());
    let _ = server.shutdown().unwrap();
}

/// The client-parity regression (the old matrix had no
/// `ServerClient::infer_hinted_async`): hinted + async submission must
/// work — and route — identically through BOTH topologies' handles,
/// with the padded bucket-8 replay of the same input as the oracle.
#[test]
fn hinted_async_routing_is_identical_through_both_topologies() {
    use nimble::coordinator::InferEngine;
    let mut direct = direct_engine(&[1, 8]);
    let len = direct.example_len();
    let out_len = direct.output_len();
    let input = inputs(1, len, 77).pop().unwrap();
    let mut padded = input.clone();
    padded.resize(8 * len, 0.0);
    let want_hinted = direct.infer_batch(8, &padded).unwrap()[..out_len].to_vec();
    let want_plain = direct.infer_batch(1, &input).unwrap();

    let single = tape_server();
    let lanes = Runtime::builder()
        .model("mini_inception")
        .buckets(&[1, 8])
        .max_wait(Duration::from_millis(2))
        .build()
        .expect("lane runtime");
    for (name, server) in [("single", &single), ("lanes", &lanes)] {
        let handle = server.handle();
        // Async + hinted: the exact combination ServerClient could not
        // express before the façade.
        let ticket = handle.submit(InferRequest::new(input.clone()).hint(8)).unwrap();
        assert_eq!(
            ticket.wait().unwrap(),
            want_hinted,
            "{name}: hint must route through the bucket-8 engine"
        );
        let plain = handle.infer(InferRequest::new(input.clone())).unwrap();
        assert_eq!(plain, want_plain, "{name}: unhinted requests keep depth routing");
        // Unknown hints are rejected identically on both topologies.
        let bad = handle.submit(InferRequest::new(input.clone()).hint(5));
        assert!(bad.is_err(), "{name}: hints must name a compiled bucket");
    }
    let report = lanes.shutdown().unwrap();
    assert_eq!(report.lane(8).unwrap().n_requests, 1, "hinted request must land on lane 8");
    let _ = single.shutdown().unwrap();
}

/// The deprecated shims keep their historical semantics: a legacy
/// `infer_hinted` with an unknown bucket is ignored (depth-routed), not
/// an error, and the once-missing `ServerClient::infer_hinted_async`
/// now exists (closing the parity gap on the legacy surface too).
#[test]
#[allow(deprecated)]
fn legacy_shims_still_serve_with_their_old_semantics() {
    let legacy = nimble::serving::NimbleServer::start_with(
        || {
            nimble::serving::TapeEngine::from_graph_fn_opts(
                "mini_inception",
                &[1, 8],
                TapeEngineOptions::default(),
                |b| nimble::models::build("mini_inception", b),
            )
        },
        Duration::from_millis(2),
    )
    .expect("legacy server");
    let len = legacy.example_len();
    let out_len = legacy.output_len();
    let input = inputs(1, len, 78).pop().unwrap();
    let ignored = legacy.client().infer_hinted(input.clone(), 5).unwrap();
    assert_eq!(ignored.len(), out_len, "legacy unknown hints are ignored, not errors");
    let rx = legacy.client().infer_hinted_async(input.clone(), 8).unwrap();
    assert_eq!(rx.recv().unwrap().unwrap().len(), out_len);
    assert_eq!(legacy.infer(input).unwrap().len(), out_len);
    let _ = legacy.shutdown().unwrap();
}

#[test]
fn repeated_requests_are_deterministic() {
    let server = tape_server();
    let len = server.example_len();
    let input = inputs(1, len, 42).pop().unwrap();
    let a = server.infer(InferRequest::new(input.clone())).unwrap();
    let b = server.infer(InferRequest::new(input)).unwrap();
    assert_eq!(a, b, "same input, same logits");
    let _ = server.shutdown().unwrap();
}

#[test]
fn server_responses_match_direct_engine_replay() {
    // The padded batch-bucket path must not change single-request results.
    use nimble::coordinator::InferEngine;
    let mut direct = direct_engine(&[1, 8]);
    let len = direct.example_len();
    let input = inputs(1, len, 9).pop().unwrap();
    let expect = direct.infer_batch(1, &input).unwrap();

    let server = tape_server();
    let got = server.infer(InferRequest::new(input)).unwrap();
    assert_eq!(got, expect, "server (bucket 1) vs direct engine");
    let _ = server.shutdown().unwrap();
}

#[test]
fn padded_batch_values_match_direct_bucket_replay() {
    // Fill exactly one bucket-8 batch and check every row of the
    // server's un-padding against a direct replay of the same padded
    // batch — catches any off-by-one in row placement or slicing.
    use nimble::coordinator::InferEngine;
    let server = Runtime::builder()
        .model("mini_inception")
        .buckets(&[1, 8])
        .single_thread()
        .max_wait(Duration::from_millis(500)) // flush only on a full bucket
        .build()
        .expect("server");
    let len = server.example_len();
    let out_len = server.output_len();
    let ins = inputs(8, len, 1234);
    let pending: Vec<_> =
        ins.iter().map(|i| server.submit(InferRequest::new(i.clone())).unwrap()).collect();
    let got: Vec<Vec<f32>> = pending.into_iter().map(|t| t.wait().unwrap()).collect();
    let report = server.shutdown().unwrap();
    assert_eq!(report.n_batches, 1, "test premise: one full bucket-8 batch");

    let mut direct = direct_engine(&[1, 8]);
    let padded: Vec<f32> = ins.concat();
    let expect = direct.infer_batch(8, &padded).unwrap();
    for (i, row) in got.iter().enumerate() {
        assert_eq!(
            row.as_slice(),
            &expect[i * out_len..(i + 1) * out_len],
            "row {i} mixed up by batching/un-padding"
        );
    }
}

#[test]
fn concurrent_clients_all_get_served() {
    // Many client threads firing at once through cloneable handles: every
    // request must get exactly one well-formed response (the synthetic
    // kernel is not row-separable across batch compositions, so value
    // equality across buckets is checked by the single-request tests and
    // the PJRT-mode tests instead).
    let server = tape_server();
    let len = server.example_len();
    let out_len = server.output_len();
    let handles: Vec<_> = inputs(24, len, 77)
        .into_iter()
        .map(|input| {
            let handle = server.handle();
            std::thread::spawn(move || {
                let got = handle.infer(InferRequest::new(input)).unwrap();
                assert_eq!(got.len(), out_len);
                assert!(got.iter().all(|v| v.is_finite()));
            })
        })
        .collect();
    for h in handles {
        h.join().unwrap();
    }
    let report = server.shutdown().unwrap();
    assert_eq!(report.n_requests, 24);
    assert!(report.n_batches <= 24, "concurrent requests should batch");
}

/// Shutdown must flush requests already sent, not drop them on the
/// engine channel (regression: `ServerClient` requests racing shutdown
/// used to die with "server dropped request").
#[test]
fn shutdown_flushes_in_flight_requests_single_engine() {
    // Long deadline: nothing would flush before shutdown arrives.
    let server = Runtime::builder()
        .model("mini_inception")
        .buckets(&[1, 8])
        .single_thread()
        .max_wait(Duration::from_millis(500))
        .build()
        .expect("server");
    let len = server.example_len();
    let pending: Vec<_> = inputs(10, len, 5)
        .into_iter()
        .map(|i| server.submit(InferRequest::new(i)).unwrap())
        .collect();
    let report = server.shutdown().unwrap();
    assert_eq!(report.n_requests, 10, "all in-flight requests served at shutdown");
    for ticket in pending {
        assert!(ticket.wait().is_ok(), "flushed request must succeed, not drop");
    }
}

#[test]
fn shutdown_flushes_in_flight_requests_lane_server() {
    let server = Runtime::builder()
        .model("mini_inception")
        .buckets(&[1, 8])
        .max_wait(Duration::from_millis(500))
        .build()
        .expect("lane server");
    let len = server.example_len();
    let handle = server.handle();
    let pending: Vec<_> = inputs(10, len, 6)
        .into_iter()
        .map(|i| server.submit(InferRequest::new(i)).unwrap())
        .collect();
    let report = server.shutdown().unwrap();
    assert_eq!(report.n_requests, 10, "all in-flight requests served at shutdown");
    for ticket in pending {
        assert!(ticket.wait().is_ok(), "flushed request must succeed, not drop");
    }
    // Requests after shutdown fail fast with an explicit error.
    let err = handle.infer(InferRequest::new(vec![0.0; len]));
    assert!(err.is_err(), "post-shutdown request must be rejected");
}

/// Deadlock/starvation regression: a fault-injected slow lane must not
/// stall the other lanes, and shutdown must still join every lane
/// thread cleanly.
#[test]
fn slow_lane_does_not_starve_other_lanes_and_shutdown_joins() {
    use nimble::coordinator::InferEngine;
    use nimble::serving::TapeEngine;
    use std::time::Instant;

    /// Wraps a [`TapeEngine`] and sleeps on one bucket, simulating a
    /// stuck/overloaded engine.
    struct SlowLane {
        inner: TapeEngine,
        slow_bucket: usize,
        delay: Duration,
    }

    impl InferEngine for SlowLane {
        fn batch_sizes(&self) -> Vec<usize> {
            self.inner.batch_sizes()
        }
        fn example_len(&self) -> usize {
            self.inner.example_len()
        }
        fn output_len(&self) -> usize {
            self.inner.output_len()
        }
        fn infer_batch(&mut self, bucket: usize, input: &[f32]) -> anyhow::Result<Vec<f32>> {
            if bucket == self.slow_bucket {
                std::thread::sleep(self.delay);
            }
            self.inner.infer_batch(bucket, input)
        }
        fn stream_count(&self, bucket: usize) -> Option<usize> {
            self.inner.stream_count(bucket)
        }
    }

    const N_SLOW: usize = 3;
    const N_FAST: usize = 6;

    // Calibrate on this machine/build: one warmed direct batch-8 replay
    // bounds what a healthy fast lane needs, so the watchdog scales with
    // debug-mode and loaded-CI slowness instead of flaking.
    let t_fast = {
        let mut probe = direct_engine(&[8]);
        let z = vec![0.0f32; 8 * probe.example_len()];
        probe.infer_batch(8, &z).unwrap(); // warm-up
        let t0 = Instant::now();
        probe.infer_batch(8, &z).unwrap();
        t0.elapsed()
    };
    // Watchdog: generous for the fast lane (per-batch time × batches,
    // plus fixed headroom)…
    let watchdog = t_fast * (N_FAST as u32 + 2) + Duration::from_millis(500);
    // …while each slow-lane batch alone eats a full watchdog, so a
    // regression to single-engine-thread serialization (fast waits for
    // N_SLOW × delay) overshoots it 3× and fails loudly.
    let delay = watchdog;

    let server = Runtime::builder()
        .buckets(&[1, 8])
        .max_wait(Duration::from_millis(1))
        .build_with_factory(move |bucket| {
            Ok(SlowLane {
                inner: Runtime::builder()
                    .model("mini_inception")
                    .buckets(&[bucket])
                    .build_engine()?,
                slow_bucket: 1,
                delay,
            })
        })
        .expect("lane server");
    let len = server.example_len();
    let out_len = server.output_len();

    // Jam the slow lane first (its queue keeps it busy for 3 × delay)...
    let slow: Vec<_> = (0..N_SLOW)
        .map(|i| {
            server
                .submit(InferRequest::batch(1, inputs(1, len, 100 + i as u64).concat()))
                .unwrap()
        })
        .collect();
    // ...then drive the fast lane and demand it drains under the watchdog.
    let t0 = Instant::now();
    let fast: Vec<_> = (0..N_FAST)
        .map(|i| {
            server
                .submit(InferRequest::batch(8, inputs(8, len, 200 + i as u64).concat()))
                .unwrap()
        })
        .collect();
    for (i, ticket) in fast.into_iter().enumerate() {
        let remaining = watchdog.saturating_sub(t0.elapsed());
        let out = ticket
            .wait_timeout(remaining)
            .unwrap_or_else(|_| panic!("fast batch {i} starved behind the slow lane"));
        assert_eq!(out.len(), 8 * out_len);
    }
    assert!(
        t0.elapsed() < watchdog,
        "fast lane took {:?} (watchdog {:?}), starved behind the slow lane",
        t0.elapsed(),
        watchdog
    );

    // The slow jobs still complete, and shutdown joins every lane.
    for ticket in slow {
        assert!(ticket.wait().is_ok());
    }
    let report = server.shutdown().expect("shutdown joins all lanes");
    assert_eq!(report.lane(1).unwrap().n_batches, N_SLOW);
    assert_eq!(report.lane(8).unwrap().n_batches, N_FAST);
    // Sanity: the fast-lane outputs came from the real engine.
    let mut direct = direct_engine(&[8]);
    let batch = inputs(8, len, 200).concat();
    assert_eq!(direct.infer_batch(8, &batch).unwrap().len(), 8 * out_len);
}

/// PJRT-backed serving tests (feature `xla`; skip without artifacts).
#[cfg(feature = "xla")]
mod xla {
    use super::inputs;
    use nimble::coordinator::{EngineConfig, ExecMode};
    use nimble::serving::{InferRequest, Runtime};
    use std::time::Duration;

    fn server(mode: ExecMode) -> Option<Runtime> {
        if !nimble::runtime::artifacts_available() {
            eprintln!("SKIP: artifacts not built");
            return None;
        }
        Some(
            Runtime::builder()
                .artifacts(EngineConfig { mode, ..Default::default() })
                .single_thread()
                .max_wait(Duration::from_millis(2))
                .build()
                .expect("server start"),
        )
    }

    #[test]
    fn serves_requests_and_reports_real_engine() {
        let Some(server) = server(ExecMode::Replay) else { return };
        let len = server.example_len();
        let mut pending = Vec::new();
        for input in inputs(20, len, 1) {
            pending.push(server.submit(InferRequest::new(input)).unwrap());
        }
        for ticket in pending {
            let logits = ticket.wait().unwrap();
            assert_eq!(logits.len(), server.output_len());
        }
        let report = server.shutdown().unwrap();
        assert_eq!(report.n_requests, 20);
        assert!(report.n_batches >= 3, "20 reqs over max batch 8 → ≥3 batches");
        assert!(report.mean_batch_fill > 1.0);
    }

    #[test]
    fn replay_and_eager_servers_agree() {
        let Some(replay) = server(ExecMode::Replay) else { return };
        let len = replay.example_len();
        let ins = inputs(4, len, 7);
        let out_replay: Vec<Vec<f32>> =
            ins.iter().map(|i| replay.infer(InferRequest::new(i.clone())).unwrap()).collect();
        let _ = replay.shutdown().unwrap();
        let Some(eager) = server(ExecMode::Eager) else { return };
        for (input, expected) in ins.into_iter().zip(out_replay) {
            let got = eager.infer(InferRequest::new(input)).unwrap();
            for (a, b) in got.iter().zip(&expected) {
                assert!((a - b).abs() < 1e-4, "{a} vs {b}");
            }
        }
        let _ = eager.shutdown().unwrap();
    }
}
