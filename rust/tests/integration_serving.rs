//! Integration: the serving stack — batching, correctness under
//! concurrency, error paths, per-bucket replay contexts.
//!
//! The primary tests run over the tape-backed [`TapeEngine`] (virtual
//! substrate, always available, no artifacts needed). The PJRT-backed
//! server tests live in the `xla` module at the bottom and additionally
//! skip without artifacts.

use nimble::serving::{NimbleServer, TapeEngine};
use nimble::util::Pcg32;
use std::time::Duration;

fn tape_server() -> NimbleServer {
    NimbleServer::start_with(
        || TapeEngine::new("mini_inception", &[1, 8]),
        Duration::from_millis(2),
    )
    .expect("tape server start")
}

fn inputs(n: usize, len: usize, seed: u64) -> Vec<Vec<f32>> {
    let mut rng = Pcg32::new(seed);
    (0..n).map(|_| (0..len).map(|_| rng.gen_f32_range(-1.0, 1.0)).collect()).collect()
}

#[test]
fn serves_requests_and_reports() {
    let server = tape_server();
    let len = server.example_len();
    let out_len = server.output_len();
    let mut pending = Vec::new();
    for input in inputs(20, len, 1) {
        pending.push(server.infer_async(input).unwrap());
    }
    for rx in pending {
        let logits = rx.recv().unwrap().unwrap();
        assert_eq!(logits.len(), out_len);
        assert!(logits.iter().all(|v| v.is_finite()));
    }
    let report = server.shutdown().unwrap();
    assert_eq!(report.n_requests, 20);
    assert!(report.n_batches >= 3, "20 reqs over max batch 8 → ≥3 batches");
    assert!(report.mean_batch_fill > 1.0);
}

#[test]
fn rejects_malformed_input() {
    let server = tape_server();
    let err = server.infer(vec![0.0; 5]);
    assert!(err.is_err(), "wrong-length input must be rejected");
    // server still healthy afterwards
    let ok = server.infer(vec![0.0; server.example_len()]);
    assert!(ok.is_ok());
    let _ = server.shutdown().unwrap();
}

#[test]
fn repeated_requests_are_deterministic() {
    let server = tape_server();
    let len = server.example_len();
    let input = inputs(1, len, 42).pop().unwrap();
    let a = server.infer(input.clone()).unwrap();
    let b = server.infer(input).unwrap();
    assert_eq!(a, b, "same input, same logits");
    let _ = server.shutdown().unwrap();
}

#[test]
fn server_responses_match_direct_engine_replay() {
    // The padded batch-bucket path must not change single-request results.
    use nimble::coordinator::InferEngine;
    let mut direct = TapeEngine::new("mini_inception", &[1, 8]).unwrap();
    let len = direct.example_len();
    let input = inputs(1, len, 9).pop().unwrap();
    let expect = direct.infer_batch(1, &input).unwrap();

    let server = tape_server();
    let got = server.infer(input).unwrap();
    assert_eq!(got, expect, "server (bucket 1) vs direct engine");
    let _ = server.shutdown().unwrap();
}

#[test]
fn padded_batch_values_match_direct_bucket_replay() {
    // Fill exactly one bucket-8 batch and check every row of the
    // server's un-padding against a direct replay of the same padded
    // batch — catches any off-by-one in row placement or slicing.
    use nimble::coordinator::InferEngine;
    let server = NimbleServer::start_with(
        || TapeEngine::new("mini_inception", &[1, 8]),
        Duration::from_millis(500), // long deadline: flush only on a full bucket
    )
    .expect("server");
    let len = server.example_len();
    let out_len = server.output_len();
    let ins = inputs(8, len, 1234);
    let pending: Vec<_> = ins.iter().map(|i| server.infer_async(i.clone()).unwrap()).collect();
    let got: Vec<Vec<f32>> =
        pending.into_iter().map(|rx| rx.recv().unwrap().unwrap()).collect();
    let report = server.shutdown().unwrap();
    assert_eq!(report.n_batches, 1, "test premise: one full bucket-8 batch");

    let mut direct = TapeEngine::new("mini_inception", &[1, 8]).unwrap();
    let padded: Vec<f32> = ins.concat();
    let expect = direct.infer_batch(8, &padded).unwrap();
    for (i, row) in got.iter().enumerate() {
        assert_eq!(
            row.as_slice(),
            &expect[i * out_len..(i + 1) * out_len],
            "row {i} mixed up by batching/un-padding"
        );
    }
}

#[test]
fn concurrent_clients_all_get_served() {
    // Many client threads firing at once through cloneable handles: every
    // request must get exactly one well-formed response (the synthetic
    // kernel is not row-separable across batch compositions, so value
    // equality across buckets is checked by the single-request tests and
    // the PJRT-mode tests instead).
    let server = tape_server();
    let len = server.example_len();
    let out_len = server.output_len();
    let handles: Vec<_> = inputs(24, len, 77)
        .into_iter()
        .map(|input| {
            let client = server.client();
            std::thread::spawn(move || {
                let got = client.infer(input).unwrap();
                assert_eq!(got.len(), out_len);
                assert!(got.iter().all(|v| v.is_finite()));
            })
        })
        .collect();
    for h in handles {
        h.join().unwrap();
    }
    let report = server.shutdown().unwrap();
    assert_eq!(report.n_requests, 24);
    assert!(report.n_batches <= 24, "concurrent requests should batch");
}

/// PJRT-backed serving tests (feature `xla`; skip without artifacts).
#[cfg(feature = "xla")]
mod xla {
    use super::inputs;
    use nimble::coordinator::{EngineConfig, ExecMode};
    use nimble::serving::{NimbleServer, ServerConfig};
    use std::time::Duration;

    fn server(mode: ExecMode) -> Option<NimbleServer> {
        if !nimble::runtime::artifacts_available() {
            eprintln!("SKIP: artifacts not built");
            return None;
        }
        Some(
            NimbleServer::start(ServerConfig {
                engine: EngineConfig { mode, ..Default::default() },
                max_wait: Duration::from_millis(2),
            })
            .expect("server start"),
        )
    }

    #[test]
    fn serves_requests_and_reports_real_engine() {
        let Some(server) = server(ExecMode::Replay) else { return };
        let len = server.example_len();
        let mut pending = Vec::new();
        for input in inputs(20, len, 1) {
            pending.push(server.infer_async(input).unwrap());
        }
        for rx in pending {
            let logits = rx.recv().unwrap().unwrap();
            assert_eq!(logits.len(), server.output_len());
        }
        let report = server.shutdown().unwrap();
        assert_eq!(report.n_requests, 20);
        assert!(report.n_batches >= 3, "20 reqs over max batch 8 → ≥3 batches");
        assert!(report.mean_batch_fill > 1.0);
    }

    #[test]
    fn replay_and_eager_servers_agree() {
        let Some(replay) = server(ExecMode::Replay) else { return };
        let len = replay.example_len();
        let ins = inputs(4, len, 7);
        let out_replay: Vec<Vec<f32>> =
            ins.iter().map(|i| replay.infer(i.clone()).unwrap()).collect();
        let _ = replay.shutdown().unwrap();
        let Some(eager) = server(ExecMode::Eager) else { return };
        for (input, expected) in ins.into_iter().zip(out_replay) {
            let got = eager.infer(input).unwrap();
            for (a, b) in got.iter().zip(&expected) {
                assert!((a - b).abs() < 1e-4, "{a} vs {b}");
            }
        }
        let _ = eager.shutdown().unwrap();
    }
}
