//! Integration: the serving stack over the real engine — batching,
//! correctness under concurrency, mode equivalence, error paths.
//! Skips without artifacts.

use nimble::coordinator::{EngineConfig, ExecMode};
use nimble::serving::{NimbleServer, ServerConfig};
use nimble::util::Pcg32;
use std::time::Duration;

fn server(mode: ExecMode) -> Option<NimbleServer> {
    if !nimble::runtime::artifacts_available() {
        eprintln!("SKIP: artifacts not built");
        return None;
    }
    Some(
        NimbleServer::start(ServerConfig {
            engine: EngineConfig { mode, ..Default::default() },
            max_wait: Duration::from_millis(2),
        })
        .expect("server start"),
    )
}

fn inputs(n: usize, len: usize, seed: u64) -> Vec<Vec<f32>> {
    let mut rng = Pcg32::new(seed);
    (0..n).map(|_| (0..len).map(|_| rng.gen_f32_range(-1.0, 1.0)).collect()).collect()
}

#[test]
fn serves_requests_and_reports() {
    let Some(server) = server(ExecMode::Replay) else { return };
    let len = server.example_len();
    let mut pending = Vec::new();
    for input in inputs(20, len, 1) {
        pending.push(server.infer_async(input).unwrap());
    }
    for rx in pending {
        let logits = rx.recv().unwrap().unwrap();
        assert_eq!(logits.len(), 10);
    }
    let report = server.shutdown().unwrap();
    assert_eq!(report.n_requests, 20);
    assert!(report.n_batches >= 3, "20 reqs over max batch 8 → ≥3 batches");
    assert!(report.mean_batch_fill > 1.0);
}

#[test]
fn replay_and_eager_servers_agree() {
    let Some(replay) = server(ExecMode::Replay) else { return };
    let len = replay.example_len();
    let ins = inputs(4, len, 7);
    let out_replay: Vec<Vec<f32>> =
        ins.iter().map(|i| replay.infer(i.clone()).unwrap()).collect();
    let _ = replay.shutdown().unwrap();
    let Some(eager) = server(ExecMode::Eager) else { return };
    for (input, expected) in ins.into_iter().zip(out_replay) {
        let got = eager.infer(input).unwrap();
        for (a, b) in got.iter().zip(&expected) {
            assert!((a - b).abs() < 1e-4, "{a} vs {b}");
        }
    }
    let _ = eager.shutdown().unwrap();
}

#[test]
fn rejects_malformed_input() {
    let Some(server) = server(ExecMode::Replay) else { return };
    let err = server.infer(vec![0.0; 5]);
    assert!(err.is_err(), "wrong-length input must be rejected");
    // server still healthy afterwards
    let ok = server.infer(vec![0.0; server.example_len()]);
    assert!(ok.is_ok());
    let _ = server.shutdown().unwrap();
}

#[test]
fn batching_pads_and_unpads_correctly() {
    // A single request goes through the batch-1 engine (or padded bucket);
    // its logits must match a direct single inference.
    let Some(server) = server(ExecMode::Replay) else { return };
    let len = server.example_len();
    let input = inputs(1, len, 42).pop().unwrap();
    let a = server.infer(input.clone()).unwrap();
    let b = server.infer(input).unwrap();
    assert_eq!(a, b, "same input, same logits");
    let _ = server.shutdown().unwrap();
}
