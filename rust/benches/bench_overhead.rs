//! The paper's central measurement: per-request scheduling overhead.
//!
//! Section 1 (always available) runs on the synthetic tape substrate:
//! the *pre-tape* replay bookkeeping (fresh per-task argument vectors +
//! per-slot occupancy checks, exactly what `replay_with_stats` pays) vs
//! the zero-allocation tape path, serial-vs-parallel wall times, and the
//! DES-predicted single-vs-multi-stream speedup over the same tapes.
//! Results are also written to `BENCH_replay.json` (format documented in
//! `rust/README.md`).
//!
//! Section 2 (feature `xla`, skips without artifacts) repeats the
//! Fig. 2b methodology over real XLA/PJRT executables: eager run-time
//! scheduling vs AoT replay vs the prepared (tape-style) replay.

mod common;
use common::{bench, section};
use nimble::aot::tape::ReplayTape;
use nimble::engine::executor::{ReplayContext, SyntheticKernel};
use nimble::matching::MatchingAlgo;
use nimble::models;
use nimble::sim::{kernel_cost, simulate_tape, GpuSpec, HostProfile};
use nimble::stream::rewrite::{rewrite, rewrite_single_stream};
use nimble::util::stats::fmt_secs;
use nimble::util::{Pcg32, Summary};

fn main() {
    tape_substrate_section();
    telemetry_overhead_section();
    #[cfg(feature = "xla")]
    xla_real::real_substrate_section();
    #[cfg(not(feature = "xla"))]
    println!("\n(real-XLA section skipped: built without `--features xla`)");
}

fn tape_substrate_section() {
    section("tape replay: submission bookkeeping + parallel execution (synthetic substrate)");
    let iters = 12;
    let dev = GpuSpec::v100();
    let mut entries: Vec<String> = Vec::new();
    for name in ["mini_inception", "inception_v3", "nasnet_a_mobile"] {
        let g = models::build(name, 1);
        let plan = rewrite(&g, MatchingAlgo::HopcroftKarp);
        let tape = ReplayTape::for_op_graph(&g, &plan, 512);
        let n_tasks = tape.n_tasks() as f64;
        let input: Vec<f32> = {
            let mut rng = Pcg32::new(11);
            (0..tape.input_slots()[0].1).map(|_| rng.gen_f32_range(-1.0, 1.0)).collect()
        };
        let mut ctx = ReplayContext::new(tape.clone(), SyntheticKernel);
        ctx.replay_one(&input).expect("warm-up");
        ctx.reset_alloc_events();

        let mut baseline_sched = Vec::with_capacity(iters);
        let mut tape_sched = Vec::with_capacity(iters);
        for _ in 0..iters {
            baseline_sched
                .push(ctx.replay_serial_alloc_baseline(&[&input]).expect("baseline replay"));
            tape_sched.push(ctx.replay_serial_with_stats(&[&input]).expect("tape replay"));
        }
        let bs = Summary::from_samples(baseline_sched);
        let ts = Summary::from_samples(tape_sched);
        let sp = bench(&format!("{name}: parallel replay wall"), 2, iters, || {
            ctx.replay_one(&input).unwrap()
        });
        let ss = bench(&format!("{name}: serial replay wall"), 2, iters, || {
            ctx.replay_serial(&[&input]).unwrap()
        });
        let alloc_events = ctx.alloc_events();

        let costs: Vec<_> = (0..g.n_nodes()).map(|v| kernel_cost(g.node(v), &dev)).collect();
        let single = ReplayTape::for_op_graph(&g, &rewrite_single_stream(&g), 512);
        let sim_multi = simulate_tape(&tape, &costs, HostProfile::nimble(), dev.clone()).total_s;
        let sim_single =
            simulate_tape(&single, &costs, HostProfile::nimble(), dev.clone()).total_s;

        println!(
            "{name}: bookkeeping/task  pre-tape {}  tape {}  ({:.2}x less)   \
             steady-state alloc events: {alloc_events}",
            fmt_secs(bs.median() / n_tasks),
            fmt_secs(ts.median() / n_tasks),
            bs.median() / ts.median().max(1e-12),
        );
        println!(
            "{name}: DES prediction (V100, nimble host)  single {}  multi {}  speedup {:.2}x",
            fmt_secs(sim_single),
            fmt_secs(sim_multi),
            sim_single / sim_multi,
        );
        entries.push(format!(
            "  {{\"model\": \"{name}\", \"batch\": 1, \"n_tasks\": {}, \"n_streams\": {}, \
             \"n_events\": {}, \
             \"baseline_sched_s\": {:.9}, \"tape_sched_s\": {:.9}, \
             \"parallel_wall_s\": {:.9}, \"serial_wall_s\": {:.9}, \
             \"alloc_events_steady\": {alloc_events}, \
             \"sim_single_stream_s\": {sim_single:.9}, \"sim_multi_stream_s\": {sim_multi:.9}, \
             \"sim_speedup\": {:.4}}}",
            tape.n_tasks(),
            tape.n_streams(),
            tape.n_events(),
            bs.median(),
            ts.median(),
            sp.median(),
            ss.median(),
            sim_single / sim_multi,
        ));
    }
    let json = format!("[\n{}\n]\n", entries.join(",\n"));
    match std::fs::write("BENCH_replay.json", &json) {
        Ok(()) => println!("\nwrote BENCH_replay.json ({} models)", entries.len()),
        Err(e) => println!("\ncould not write BENCH_replay.json: {e}"),
    }
}

/// Flight-recorder overhead gate: the same tape replayed with the
/// recorder off and on. Recording enabled must cost ≤5% on the
/// min-of-iterations wall time (the ISSUE-8 acceptance bound); results
/// land in `BENCH_overhead.json` for the CI observability job.
fn telemetry_overhead_section() {
    use nimble::engine::executor::ExecOptions;
    use nimble::telemetry::Telemetry;

    section("flight recorder overhead (telemetry on vs off, min-of-iterations)");
    let iters = 40;
    let name = "mini_inception";
    let g = models::build(name, 1);
    let plan = rewrite(&g, MatchingAlgo::HopcroftKarp);
    let tape = ReplayTape::for_op_graph(&g, &plan, 512);
    let input: Vec<f32> = {
        let mut rng = Pcg32::new(11);
        (0..tape.input_slots()[0].1).map(|_| rng.gen_f32_range(-1.0, 1.0)).collect()
    };

    let mut off =
        ReplayContext::with_options(tape.clone(), SyntheticKernel, ExecOptions::default());
    let tel = Telemetry::with_capacity(1 << 14);
    let labels: Vec<String> = (0..g.n_nodes()).map(|v| g.node(v).name.clone()).collect();
    tel.register_labels(&labels);
    let mut on = ReplayContext::with_options(
        tape.clone(),
        SyntheticKernel,
        ExecOptions { telemetry: Some(tel.clone()), ..Default::default() },
    );

    let s_off = bench(&format!("{name}: replay, telemetry off"), 3, iters, || {
        off.replay_one(&input).unwrap()
    });
    let s_on = bench(&format!("{name}: replay, telemetry on"), 3, iters, || {
        on.replay_one(&input).unwrap()
    });
    // Min-of-iterations: the noise-floor comparison — every sample
    // above the min is scheduler jitter, not recorder cost.
    let ratio = s_on.min() / s_off.min().max(1e-12);
    let snap = tel.snapshot();
    println!(
        "overhead: on/off min ratio {ratio:.4}  ({} spans recorded, {} dropped, {} rings)",
        snap.recorded,
        snap.dropped,
        tel.ring_allocs(),
    );
    let json = format!(
        "[\n  {{\"model\": \"{name}\", \"iters\": {iters}, \
         \"telemetry_off_min_s\": {:.9}, \"telemetry_on_min_s\": {:.9}, \
         \"overhead_ratio\": {ratio:.4}, \"spans_recorded\": {}, \"spans_dropped\": {}, \
         \"ring_allocs\": {}}}\n]\n",
        s_off.min(),
        s_on.min(),
        snap.recorded,
        snap.dropped,
        tel.ring_allocs(),
    );
    match std::fs::write("BENCH_overhead.json", &json) {
        Ok(()) => println!("wrote BENCH_overhead.json"),
        Err(e) => println!("could not write BENCH_overhead.json: {e}"),
    }
    assert!(
        ratio <= 1.05,
        "telemetry-on replay exceeded the 5% overhead budget: on/off min ratio {ratio:.4}"
    );
}

/// Real-substrate section (Fig. 2b methodology over PJRT executables).
#[cfg(feature = "xla")]
mod xla_real {
    use super::*;
    use nimble::aot::TaskSchedule;
    use nimble::engine::EagerEngine;
    use nimble::runtime::{artifacts_available, artifacts_dir, ArtifactRegistry, RuntimeClient};
    use std::sync::Arc;

    pub fn real_substrate_section() {
        if !artifacts_available() {
            println!("\nSKIP real-XLA section: run `make artifacts` first");
            return;
        }
        let client = RuntimeClient::cpu().expect("client");
        let reg = Arc::new(ArtifactRegistry::load(client, artifacts_dir()).expect("registry"));

        for batch in [1usize, 8] {
            section(&format!("MiniInception batch={batch} (real XLA executables)"));
            let eager = EagerEngine::new(reg.clone(), batch).expect("eager");
            let sched = TaskSchedule::build(&reg, batch).expect("schedule");
            let mut prep = sched.prepare_replay();
            let mut rng = Pcg32::new(5);
            let input: Vec<f32> =
                (0..eager.input_len()).map(|_| rng.gen_f32_range(-1.0, 1.0)).collect();

            let iters = 12;
            let mut e_sched = Vec::new();
            let mut r_sched = Vec::new();
            let mut p_sched = Vec::new();
            bench("eager end-to-end", 2, iters, || {
                let (_, s) = eager.infer(&input).unwrap();
                e_sched.push(s.sched_s);
            });
            bench("replay end-to-end", 2, iters, || {
                let (_, s) = sched.replay_with_stats(&reg, &input).unwrap();
                r_sched.push(s);
            });
            bench("prepared (tape) replay end-to-end", 2, iters, || {
                let (_, s) = sched.replay_prepared(&reg, &mut prep, &input).unwrap();
                p_sched.push(s);
            });
            let es = Summary::from_samples(e_sched);
            let rs = Summary::from_samples(r_sched);
            let ps = Summary::from_samples(p_sched);
            let n = sched.n_tasks() as f64;
            println!(
                "scheduling work only: eager {}/req ({}/op)  replay {}/req ({}/op)  \
                 prepared {}/req ({}/op)  -> {:.1}x removed vs eager, {:.2}x vs replay",
                fmt_secs(es.median()),
                fmt_secs(es.median() / n),
                fmt_secs(rs.median()),
                fmt_secs(rs.median() / n),
                fmt_secs(ps.median()),
                fmt_secs(ps.median() / n),
                es.median() / ps.median(),
                rs.median() / ps.median(),
            );
        }
    }
}
