//! The paper's central measurement on the REAL substrate: per-request
//! scheduling overhead, eager run-time scheduling vs AoT replay, over the
//! actual XLA/PJRT executables (Fig. 2b methodology: identical kernels,
//! only the scheduling differs). Skips if artifacts are missing.

mod common;
use common::{bench, section};
use nimble::aot::TaskSchedule;
use nimble::engine::EagerEngine;
use nimble::runtime::{artifacts_available, artifacts_dir, ArtifactRegistry, RuntimeClient};
use nimble::util::stats::fmt_secs;
use nimble::util::{Pcg32, Summary};
use std::sync::Arc;

fn main() {
    if !artifacts_available() {
        println!("SKIP bench_overhead: run `make artifacts` first");
        return;
    }
    let client = RuntimeClient::cpu().expect("client");
    let reg = Arc::new(ArtifactRegistry::load(client, artifacts_dir()).expect("registry"));

    for batch in [1usize, 8] {
        section(&format!("MiniInception batch={batch} (real XLA executables)"));
        let eager = EagerEngine::new(reg.clone(), batch).expect("eager");
        let sched = TaskSchedule::build(&reg, batch).expect("schedule");
        let mut rng = Pcg32::new(5);
        let input: Vec<f32> =
            (0..eager.input_len()).map(|_| rng.gen_f32_range(-1.0, 1.0)).collect();

        let iters = 12;
        let mut e_sched = Vec::new();
        let mut r_sched = Vec::new();
        bench("eager end-to-end", 2, iters, || {
            let (_, s) = eager.infer(&input).unwrap();
            e_sched.push(s.sched_s);
        });
        bench("replay end-to-end", 2, iters, || {
            let (_, s) = sched.replay_with_stats(&reg, &input).unwrap();
            r_sched.push(s);
        });
        let es = Summary::from_samples(e_sched);
        let rs = Summary::from_samples(r_sched);
        let n = sched.n_tasks() as f64;
        println!(
            "scheduling work only: eager {}/req ({}/op)  replay {}/req ({}/op)  -> {:.1}x removed",
            fmt_secs(es.median()),
            fmt_secs(es.median() / n),
            fmt_secs(rs.median()),
            fmt_secs(rs.median() / n),
            es.median() / rs.median()
        );
    }
}
