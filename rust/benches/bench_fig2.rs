//! Fig. 2 regeneration benchmark: times the three motivation experiments
//! (GPU-active ratio, scheduling-minimized comparison, critical-path
//! analysis) and prints their tables.

mod common;
use common::{bench, section};

fn main() {
    section("Fig. 2a (GPU active-time ratios)");
    bench("fig2a", 1, 5, nimble::figures::fig2a);
    println!("{}", nimble::figures::fig2a().render());
    section("Fig. 2b (scheduling-minimized)");
    bench("fig2b", 1, 5, nimble::figures::fig2b);
    println!("{}", nimble::figures::fig2b().render());
    section("Fig. 2c (critical path)");
    bench("fig2c", 1, 5, nimble::figures::fig2c);
    println!("{}", nimble::figures::fig2c().render());
}
