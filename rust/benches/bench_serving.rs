//! Serving-path benchmark: lane-scheduler throughput against the
//! single-engine-thread baseline on a 4-bucket mixed workload, the
//! elastic-scaling burst trace, a deadline-shedding sweep, the EDF /
//! SLO-controller cross-check, and the classic offered-load sweep —
//! all driven through the `Runtime` façade.
//!
//! The headline measurement replays the *same* 64 pre-formed padded
//! batches (round-robin over buckets 1/2/4/8 of a chain-shaped model, so
//! each bucket's tape is single-stream and a lone engine thread cannot
//! hide any latency) two ways:
//!
//! * **serial** — one engine executing the batches back-to-back, exactly
//!   what the PR-1 `NimbleServer` engine thread does, and
//! * **lanes** — `InferRequest::batch` submissions through one lane per
//!   bucket, so the four buckets overlap end-to-end.
//!
//! It also runs the multi-lane DES over the same four tapes for the
//! predicted overlap speedup, and writes everything to
//! `BENCH_serving.json` (format documented in `rust/README.md`) — the
//! CI artifact comparing DES-predicted vs measured overlap and
//! DES-predicted vs measured deadline shedding.

mod common;
use common::section;
use nimble::coordinator::InferEngine;
use nimble::ops::{GraphBuilder, OpGraph};
use nimble::serving::{InferOutcome, InferRequest, Runtime, TapeEngine};
use nimble::sim::{kernel_cost, simulate_lanes, GpuSpec, HostProfile, KernelCost, LaneLoad};
use nimble::util::Pcg32;
use std::time::{Duration, Instant};

/// A deep conv chain: every tape is single-stream, so one engine thread
/// leaves the other cores idle and lane overlap is pure win.
fn chain_graph(batch: usize, depth: usize) -> OpGraph {
    let mut b = GraphBuilder::new();
    let mut x = b.input(&[batch, 16, 16, 16]);
    for _ in 0..depth {
        x = b.conv_bn_relu(x, 16, 3, 1);
    }
    let pooled = b.gap(x);
    let _logits = b.linear(pooled, 10);
    b.finish()
}

const BUCKETS: [usize; 4] = [1, 2, 4, 8];
const DEPTH: usize = 12;
const N_BATCHES: usize = 64;

fn chain_engine(buckets: &[usize]) -> TapeEngine {
    Runtime::builder()
        .label("chain")
        .graph_fn(|b| chain_graph(b, DEPTH))
        .buckets(buckets)
        .build_engine()
        .expect("chain engine")
}

fn padded_batches(example_len: usize) -> Vec<(usize, Vec<f32>)> {
    let mut rng = Pcg32::new(4242);
    (0..N_BATCHES)
        .map(|i| {
            let bucket = BUCKETS[i % BUCKETS.len()];
            let input: Vec<f32> =
                (0..bucket * example_len).map(|_| rng.gen_f32_range(-1.0, 1.0)).collect();
            (bucket, input)
        })
        .collect()
}

fn lane_vs_serial() -> String {
    section("lane scheduler vs single engine thread (4-bucket mixed chain workload)");

    // --- Serial baseline: one engine, batches back-to-back. ---
    let mut serial_engine = chain_engine(&BUCKETS);
    let example_len = serial_engine.example_len();
    let batches = padded_batches(example_len);
    // Warm up every context once.
    for &bucket in &BUCKETS {
        let z = vec![0.0f32; bucket * example_len];
        serial_engine.infer_batch(bucket, &z).unwrap();
    }
    let t0 = Instant::now();
    for (bucket, input) in &batches {
        std::hint::black_box(serial_engine.infer_batch(*bucket, input).unwrap());
    }
    let serial_wall_s = t0.elapsed().as_secs_f64();

    // --- Lane run: same batches through one lane per bucket. ---
    // Caps derive from the workload so the one-shot burst below can
    // never trip load-shedding, whatever N_BATCHES/BUCKETS become.
    let per_lane_cap = N_BATCHES / BUCKETS.len() + 2;
    let server = Runtime::builder()
        .label("chain")
        .graph_fn(|b| chain_graph(b, DEPTH))
        .buckets(&BUCKETS)
        .max_wait(Duration::from_millis(1))
        .lane_cap(per_lane_cap)
        .buffers_per_lane(per_lane_cap + 2)
        .build()
        .expect("lane server");
    // Warm up each lane once.
    for &bucket in &BUCKETS {
        let z = vec![0.0f32; bucket * example_len];
        server.submit(InferRequest::batch(bucket, z)).unwrap().wait().unwrap();
    }
    let t0 = Instant::now();
    let pending: Vec<_> = batches
        .iter()
        .map(|(bucket, input)| {
            server.submit(InferRequest::batch(*bucket, input.clone())).unwrap()
        })
        .collect();
    for ticket in pending {
        ticket.wait().unwrap();
    }
    let lane_wall_s = t0.elapsed().as_secs_f64();
    let report = server.shutdown().expect("report");
    let measured_speedup = serial_wall_s / lane_wall_s;

    // --- DES prediction over the same four tapes. ---
    // Models ONE round of the workload: the four buckets' tapes arriving
    // together and overlapping on a shared device. The measured run is 16
    // such rounds pipelined FIFO per lane, so the per-round overlap is
    // the steady-state prediction; it is labelled `_round_` in the JSON
    // because simulate_lanes does not model same-lane batch pipelining.
    use nimble::aot::tape::ReplayTape;
    use nimble::matching::MatchingAlgo;
    use nimble::stream::rewrite::rewrite;
    let dev = GpuSpec::v100();
    let graphs: Vec<OpGraph> = BUCKETS.iter().map(|&b| chain_graph(b, DEPTH)).collect();
    let costs: Vec<Vec<KernelCost>> = graphs
        .iter()
        .map(|g| (0..g.n_nodes()).map(|v| kernel_cost(g.node(v), &dev)).collect())
        .collect();
    let tapes: Vec<ReplayTape> = graphs
        .iter()
        .map(|g| ReplayTape::for_op_graph(g, &rewrite(g, MatchingAlgo::HopcroftKarp), 4096))
        .collect();
    let lanes: Vec<LaneLoad> = tapes
        .iter()
        .zip(&costs)
        .map(|(tape, costs)| LaneLoad { tape, costs, arrival_s: 0.0 })
        .collect();
    let des = simulate_lanes(&lanes, HostProfile::nimble(), dev);
    let des_round_speedup = des.overlap_speedup();

    let target = 1.5f64;
    println!(
        "serial={serial_wall_s:.4}s  lanes={lane_wall_s:.4}s  measured speedup={measured_speedup:.2}x  \
         DES per-round={des_round_speedup:.2}x  target>={target}x  [{}]",
        if measured_speedup >= target { "PASS" } else { "FAIL" }
    );
    println!("{}", report.render());

    // Structured stats straight off the report — the JSON consumers
    // read the same keys LaneStat::to_json() guarantees.
    let lane_json: Vec<String> =
        report.lanes.iter().map(|l| format!("    {}", l.to_json())).collect();
    let buckets_json =
        BUCKETS.iter().map(|b| b.to_string()).collect::<Vec<_>>().join(", ");
    format!(
        "{{\n  \"workload\": \"4-bucket-mixed-chain\",\n  \"buckets\": [{buckets_json}],\n  \
         \"n_batches\": {N_BATCHES},\n  \"chain_depth\": {DEPTH},\n  \
         \"serial_wall_s\": {serial_wall_s:.6},\n  \"lane_wall_s\": {lane_wall_s:.6},\n  \
         \"measured_speedup\": {measured_speedup:.4},\n  \
         \"des_predicted_round_speedup\": {des_round_speedup:.4},\n  \
         \"target_speedup\": {target},\n  \"pass\": {},\n  \"lanes\": [\n{}\n  ]\n}}",
        measured_speedup >= target,
        lane_json.join(",\n")
    )
}

/// Bursty-trace scaling benchmark: the same waves of hot-bucket batches
/// through (a) the static one-lane-per-bucket scheduler and (b) the
/// elastic scheduler (shared work-stealing worker pool + shared arena
/// pool, up to `MAX_LANES` lanes on the hot bucket), plus the
/// `simulate_scaling` DES prediction over the identical arrival trace.
/// The elastic run must match static throughput or better while keeping
/// worker threads capped at the shared pool size and retiring its extra
/// lanes between bursts.
fn elastic_vs_static() -> String {
    use nimble::aot::memory::ArenaPool;
    use nimble::aot::tape::ReplayTape;
    use nimble::engine::executor::SharedWorkerPool;
    use nimble::matching::MatchingAlgo;
    use nimble::serving::ScaleOptions;
    use nimble::sim::{simulate_scaling, ScaleSimPolicy, ScalingTrace};
    use nimble::stream::rewrite::rewrite;

    section("elastic vs static lanes (bursty hot-bucket chain workload)");

    const HOT: usize = 8;
    const COLD: usize = 1;
    const WAVES: usize = 4;
    const HOT_PER_WAVE: usize = 12;
    const COLD_PER_WAVE: usize = 2;
    const MAX_LANES: usize = 3;
    const WORKERS: usize = 4;
    let idle_retire = Duration::from_millis(10);
    let gap = Duration::from_millis(25);
    let buckets = [COLD, HOT];

    let run = |elastic: bool| -> (f64, nimble::serving::ServingReport) {
        let scale = if elastic {
            ScaleOptions {
                max_lanes_per_bucket: MAX_LANES,
                idle_retire,
                scale_up_backlog: 2,
            }
        } else {
            ScaleOptions::default() // max_lanes_per_bucket = 1: static
        };
        let server = Runtime::builder()
            .label("chain")
            .graph_fn(|b| chain_graph(b, DEPTH))
            .buckets(&buckets)
            .max_wait(Duration::from_millis(1))
            .lane_cap(HOT_PER_WAVE + 2)
            .buffers_per_lane(4)
            .elastic(scale)
            .shared_pool_handle(SharedWorkerPool::new(WORKERS))
            .arena_pool(ArenaPool::new())
            .build()
            .expect("scaling bench server");
        let example_len = server.example_len();
        let mut rng = Pcg32::new(7171);
        let mut mk = |bucket: usize| -> Vec<f32> {
            (0..bucket * example_len).map(|_| rng.gen_f32_range(-1.0, 1.0)).collect()
        };
        // Warm up both buckets once (outside the timed region).
        for &b in &buckets {
            let z = vec![0.0; b * example_len];
            server.submit(InferRequest::batch(b, z)).unwrap().wait().unwrap();
        }
        let t0 = Instant::now();
        for wave in 0..WAVES {
            let mut pending = Vec::new();
            for _ in 0..HOT_PER_WAVE {
                pending.push(server.submit(InferRequest::batch(HOT, mk(HOT))).unwrap());
            }
            for _ in 0..COLD_PER_WAVE {
                pending.push(server.submit(InferRequest::batch(COLD, mk(COLD))).unwrap());
            }
            for ticket in pending {
                ticket.wait().unwrap();
            }
            if wave + 1 < WAVES {
                std::thread::sleep(gap);
            }
        }
        let wall_s = t0.elapsed().as_secs_f64();
        (wall_s, server.shutdown().expect("scaling report"))
    };

    let (static_wall_s, static_report) = run(false);
    let (elastic_wall_s, elastic_report) = run(true);
    let measured_speedup = static_wall_s / elastic_wall_s;

    // --- DES prediction over the identical arrival trace. ---
    let dev = GpuSpec::v100();
    let graphs: Vec<OpGraph> = buckets.iter().map(|&b| chain_graph(b, DEPTH)).collect();
    let costs: Vec<Vec<KernelCost>> = graphs
        .iter()
        .map(|g| (0..g.n_nodes()).map(|v| kernel_cost(g.node(v), &dev)).collect())
        .collect();
    let tapes: Vec<ReplayTape> = graphs
        .iter()
        .map(|g| ReplayTape::for_op_graph(g, &rewrite(g, MatchingAlgo::HopcroftKarp), 4096))
        .collect();
    let gap_s = gap.as_secs_f64();
    let mut hot_arrivals = Vec::new();
    let mut cold_arrivals = Vec::new();
    for wave in 0..WAVES {
        let t = wave as f64 * gap_s;
        hot_arrivals.extend(std::iter::repeat(t).take(HOT_PER_WAVE));
        cold_arrivals.extend(std::iter::repeat(t).take(COLD_PER_WAVE));
    }
    let des = simulate_scaling(
        &[
            ScalingTrace { tape: &tapes[0], costs: &costs[0], arrivals_s: &cold_arrivals },
            ScalingTrace { tape: &tapes[1], costs: &costs[1], arrivals_s: &hot_arrivals },
        ],
        HostProfile::nimble(),
        dev,
        &ScaleSimPolicy {
            max_lanes_per_bucket: MAX_LANES,
            idle_retire_s: idle_retire.as_secs_f64(),
            scale_up_backlog: 2,
        },
    );

    let pass = measured_speedup >= 1.0;
    println!(
        "static={static_wall_s:.4}s  elastic={elastic_wall_s:.4}s  speedup={measured_speedup:.2}x  \
         lanes spawned={} retired={}  steals={}  workers={WORKERS}  \
         DES speedup={:.2}x peak-lanes={}  [{}]",
        elastic_report.lanes_spawned(),
        elastic_report.lanes_retired(),
        elastic_report.steals(),
        des.scaling_speedup(),
        des.per_bucket.iter().map(|b| b.peak_lanes).max().unwrap_or(1),
        if pass { "PASS" } else { "FAIL" }
    );
    println!("{}", elastic_report.render());

    format!(
        "{{\n  \"workload\": \"bursty-elastic-chain\",\n  \"buckets\": [{COLD}, {HOT}],\n  \
         \"waves\": {WAVES},\n  \"hot_per_wave\": {HOT_PER_WAVE},\n  \
         \"cold_per_wave\": {COLD_PER_WAVE},\n  \"gap_s\": {gap_s},\n  \
         \"worker_pool_size\": {WORKERS},\n  \"max_lanes_per_bucket\": {MAX_LANES},\n  \
         \"static_wall_s\": {static_wall_s:.6},\n  \"elastic_wall_s\": {elastic_wall_s:.6},\n  \
         \"measured_speedup\": {measured_speedup:.4},\n  \
         \"static_lanes_spawned\": {},\n  \"elastic_lanes_spawned\": {},\n  \
         \"elastic_lanes_retired\": {},\n  \"elastic_steals\": {},\n  \
         \"des_predicted_speedup\": {:.4},\n  \"des_predicted_peak_lanes\": {},\n  \
         \"des_lanes_spawned\": {},\n  \"des_lanes_retired\": {},\n  \"pass\": {pass}\n}}",
        static_report.lanes_spawned(),
        elastic_report.lanes_spawned(),
        elastic_report.lanes_retired(),
        elastic_report.steals(),
        des.scaling_speedup(),
        des.per_bucket.iter().map(|b| b.peak_lanes).max().unwrap_or(1),
        des.lanes_spawned(),
        des.lanes_retired(),
    )
}

/// Deadline-shedding sweep: a burst of same-bucket batches under a
/// per-request deadline budget of `k ×` the measured per-batch service
/// time, swept over `k`. Measured shed counts come from the live lane
/// scheduler (`ServingReport::deadline_shed`), predicted counts from
/// the deadline-aware DES (`simulate_lanes_deadline`) over the same
/// arrival pattern in *its* service-time units — with batch `j` of a
/// simultaneous burst starting at `j × service`, both sides should shed
/// the tail `j ≥ k`.
fn deadline_sweep() -> String {
    use nimble::aot::tape::ReplayTape;
    use nimble::matching::MatchingAlgo;
    use nimble::sim::{simulate_lanes_deadline, LaneTraffic};
    use nimble::stream::rewrite::rewrite;

    section("deadline shedding vs budget (single-bucket chain burst, measured vs DES)");

    const BUCKET: usize = 4;
    const BURST: usize = 8;
    let budgets: [f64; 4] = [0.5, 1.5, 3.5, f64::INFINITY];

    // Measured per-batch service time: warmed direct replays.
    let mut probe = chain_engine(&[BUCKET]);
    let example_len = probe.example_len();
    let zeros = vec![0.0f32; BUCKET * example_len];
    probe.infer_batch(BUCKET, &zeros).unwrap(); // warm-up
    let mut samples: Vec<f64> = (0..5)
        .map(|_| {
            let t0 = Instant::now();
            probe.infer_batch(BUCKET, &zeros).unwrap();
            t0.elapsed().as_secs_f64()
        })
        .collect();
    samples.sort_by(f64::total_cmp);
    let service_s = samples[samples.len() / 2];

    // DES service time for the same tape.
    let dev = GpuSpec::v100();
    let g = chain_graph(BUCKET, DEPTH);
    let costs: Vec<KernelCost> =
        (0..g.n_nodes()).map(|v| kernel_cost(g.node(v), &dev)).collect();
    let tape = ReplayTape::for_op_graph(&g, &rewrite(&g, MatchingAlgo::HopcroftKarp), 4096);

    let mut rows = Vec::new();
    let mut shed_curve = Vec::new();
    for &budget_x in &budgets {
        // --- Measured: one lane, BURST simultaneous batches. ---
        let server = Runtime::builder()
            .label("chain")
            .graph_fn(|b| chain_graph(b, DEPTH))
            .buckets(&[BUCKET])
            .max_wait(Duration::from_millis(1))
            .lane_cap(BURST + 2)
            .buffers_per_lane(BURST + 2)
            .build()
            .expect("deadline sweep server");
        server.submit(InferRequest::batch(BUCKET, zeros.clone())).unwrap().wait().unwrap();
        let mut rng = Pcg32::new(99);
        let pending: Vec<_> = (0..BURST)
            .map(|_| {
                let input: Vec<f32> = (0..BUCKET * example_len)
                    .map(|_| rng.gen_f32_range(-1.0, 1.0))
                    .collect();
                let req = InferRequest::batch(BUCKET, input);
                let req = if budget_x.is_finite() {
                    req.deadline_in(Duration::from_secs_f64(budget_x * service_s))
                } else {
                    req
                };
                server.submit(req).unwrap()
            })
            .collect();
        let (mut measured_completed, mut measured_shed) = (0usize, 0usize);
        for ticket in pending {
            match ticket.outcome().unwrap() {
                InferOutcome::Output(_) => measured_completed += 1,
                InferOutcome::DeadlineShed => measured_shed += 1,
                InferOutcome::Failed(e) => panic!("sweep batch failed: {e}"),
            }
        }
        let report = server.shutdown().expect("sweep report");
        // Consume the report through `ServingReport::to_json()` instead
        // of reading render() strings: the structured path is what CI
        // parses, so the assertion exercises it end-to-end.
        let doc = nimble::util::json::parse_json(&report.to_json()).expect("report json");
        let json_shed = doc
            .get("deadline_shed")
            .and_then(nimble::util::json::JsonValue::as_u64)
            .expect("deadline_shed field") as usize;
        assert_eq!(json_shed, measured_shed, "report must match client outcomes");

        // --- DES over the same burst in its own service units. ---
        let des_service =
            nimble::sim::simulate_tape(&tape, &costs, HostProfile::nimble(), dev.clone())
                .total_s;
        let deadline = if budget_x.is_finite() {
            budget_x * des_service
        } else {
            f64::INFINITY
        };
        let batches: Vec<(f64, f64)> = (0..BURST).map(|_| (0.0, deadline)).collect();
        let des = simulate_lanes_deadline(
            &[LaneTraffic { tape: &tape, costs: &costs, batches: &batches }],
            HostProfile::nimble(),
            dev.clone(),
        );

        let label =
            if budget_x.is_finite() { format!("{budget_x:.1}") } else { "inf".to_string() };
        println!(
            "budget={label}x service: measured completed={measured_completed} \
             shed={measured_shed}  DES completed={} shed={}",
            des.completed(),
            des.shed()
        );
        shed_curve.push(measured_shed);
        assert_eq!(
            measured_completed + measured_shed,
            BURST,
            "accounting must close at every budget"
        );
        let budget_json = if budget_x.is_finite() {
            format!("{budget_x}")
        } else {
            "null".to_string()
        };
        rows.push(format!(
            "    {{\"budget_x\": {budget_json}, \"measured_completed\": {measured_completed}, \
             \"measured_shed\": {measured_shed}, \"des_completed\": {}, \"des_shed\": {}}}",
            des.completed(),
            des.shed()
        ));
    }

    // Pass: an infinite budget sheds nothing, and shedding is monotone
    // non-increasing in the budget (timing noise may move a marginal
    // batch by one, never break monotonicity across the 1x steps).
    let pass = *shed_curve.last().unwrap() == 0
        && shed_curve.windows(2).all(|w| w[1] <= w[0]);
    println!("deadline sweep [{}]", if pass { "PASS" } else { "FAIL" });

    format!(
        "{{\n  \"workload\": \"deadline-sweep-chain\",\n  \"bucket\": {BUCKET},\n  \
         \"burst\": {BURST},\n  \"chain_depth\": {DEPTH},\n  \
         \"measured_service_s\": {service_s:.6},\n  \"pass\": {pass},\n  \
         \"sweep\": [\n{}\n  ]\n}}",
        rows.join(",\n")
    )
}

/// Chaos cross-check: sequential pre-formed batches through ONE lane
/// whose engine is wrapped in a seeded `ChaosEngine` (engine errors +
/// panics, bounded in-lane retries, no deadlines), against the
/// fault-aware DES (`simulate_faults`) rolling the *identical* derived
/// fault schedule. Sequential blocking submission pins the engine-call
/// order to the arrival order and there is no warm-up request, so the
/// live `ChaosEngine` call counter and the simulated one advance in
/// lockstep — completed/failed/retried must match **exactly**, not
/// statistically.
fn chaos_check() -> String {
    use nimble::aot::tape::ReplayTape;
    use nimble::matching::MatchingAlgo;
    use nimble::serving::{FaultPlan, RetryPolicy};
    use nimble::sim::{simulate_faults, FaultTraffic};
    use nimble::stream::rewrite::rewrite;

    section("chaos faults: measured vs DES (single-bucket chain, seeded fault schedule)");

    const BUCKET: usize = 2;
    const N_JOBS: usize = 48;
    const SEED: u64 = 0xC4A0_5EED;
    const MAX_RETRIES: u32 = 2;
    let plan = FaultPlan {
        engine_error: 0.15,
        engine_panic: 0.05,
        ..FaultPlan::seeded(SEED)
    };

    // --- Measured: one chaos lane, strictly sequential traffic. ---
    let server = Runtime::builder()
        .label("chain")
        .graph_fn(|b| chain_graph(b, DEPTH))
        .buckets(&[BUCKET])
        .max_wait(Duration::from_millis(1))
        .lane_cap(4)
        .buffers_per_lane(4)
        .fault_plan(plan.clone())
        .retry_policy(RetryPolicy { max_retries: MAX_RETRIES, backoff: Duration::ZERO })
        .build()
        .expect("chaos bench server");
    let example_len = server.example_len();
    let mut rng = Pcg32::new(515);
    let (mut measured_completed, mut measured_failed) = (0usize, 0usize);
    for i in 0..N_JOBS {
        let input: Vec<f32> =
            (0..BUCKET * example_len).map(|_| rng.gen_f32_range(-1.0, 1.0)).collect();
        let outcome = server
            .submit(InferRequest::batch(BUCKET, input))
            .unwrap()
            .outcome()
            .unwrap();
        match outcome {
            InferOutcome::Output(_) => measured_completed += 1,
            InferOutcome::Failed(e) => {
                assert!(e.contains("injected"), "job {i}: non-injected failure: {e}");
                measured_failed += 1;
            }
            InferOutcome::DeadlineShed => panic!("job {i} shed without a deadline"),
        }
    }
    let report = server.shutdown().expect("chaos report");
    let measured_retries = report.retries;
    assert_eq!(report.n_requests, measured_completed, "report/client completion mismatch");
    assert_eq!(report.failed, measured_failed, "report/client failure mismatch");

    // --- DES: the identical derived fault schedule over the same tape. ---
    let dev = GpuSpec::v100();
    let g = chain_graph(BUCKET, DEPTH);
    let costs: Vec<KernelCost> =
        (0..g.n_nodes()).map(|v| kernel_cost(g.node(v), &dev)).collect();
    let tape = ReplayTape::for_op_graph(&g, &rewrite(&g, MatchingAlgo::HopcroftKarp), 4096);
    let batches: Vec<(f64, f64)> = (0..N_JOBS).map(|_| (0.0, f64::INFINITY)).collect();
    let des = simulate_faults(
        &[FaultTraffic {
            tape: &tape,
            costs: &costs,
            batches: &batches,
            // The builder hands each lane engine plan.derive(bucket).
            plan: plan.derive(BUCKET as u64),
            max_retries: MAX_RETRIES,
            backoff_s: 0.0,
        }],
        HostProfile::nimble(),
        dev,
    );

    let pass = measured_completed == des.completed()
        && measured_failed == des.failed()
        && measured_retries == des.retried();
    println!(
        "measured completed={measured_completed} failed={measured_failed} \
         retries={measured_retries}  DES completed={} failed={} retried={}  [{}]",
        des.completed(),
        des.failed(),
        des.retried(),
        if pass { "PASS" } else { "FAIL" }
    );
    println!("{}", report.render());

    format!(
        "{{\n  \"workload\": \"chaos-chain\",\n  \"bucket\": {BUCKET},\n  \
         \"n_batches\": {N_JOBS},\n  \"chain_depth\": {DEPTH},\n  \"seed\": {SEED},\n  \
         \"engine_error\": 0.15,\n  \"engine_panic\": 0.05,\n  \
         \"max_retries\": {MAX_RETRIES},\n  \
         \"measured_completed\": {measured_completed},\n  \
         \"measured_failed\": {measured_failed},\n  \
         \"measured_retries\": {measured_retries},\n  \
         \"des_completed\": {},\n  \"des_failed\": {},\n  \"des_retried\": {},\n  \
         \"pass\": {pass}\n}}",
        des.completed(),
        des.failed(),
        des.retried(),
    )
}

/// Deadline-first scheduling cross-check, three sub-runs:
///
/// * **(a) FIFO vs EDF** — six deadline-less requests submitted ahead
///   of three tight (`3.5×` service) budgets through ONE single-buffer
///   bucket-1 lane. Arrival order dooms the tight requests under FIFO
///   (they queue behind ~6 service times); EDF forms their batches
///   first, so every one starts inside its budget. The budget also
///   clears the warm admission estimate (at most `2×` service with one
///   buffer in flight), so the comparison isolates *ordering*, not
///   admission shedding. EDF must shed strictly fewer.
/// * **(b) live vs `simulate_edf`, exact** — a seeded chaos-free run of
///   degenerate budgets (expired at the door vs infinite) submitted
///   sequentially-blocking through one static lane. Both sides resolve
///   every job deterministically (expired → admission shed even with a
///   cold estimate, infinite → complete), so completed / shed /
///   admission-shed must match **exactly**, not statistically.
/// * **(c) SLO controller** — the same bursty tight-deadline waves with
///   and without `.slo(target)`, with the pressure-gated scale-up
///   disabled (`scale_up_backlog` unreachable) so any spawned lane is
///   the controller's doing. The controller run must spawn lanes and
///   shed fewer requests than the static run.
fn edf_slo() -> String {
    use nimble::aot::memory::ArenaPool;
    use nimble::aot::tape::ReplayTape;
    use nimble::engine::executor::SharedWorkerPool;
    use nimble::matching::MatchingAlgo;
    use nimble::serving::ScaleOptions;
    use nimble::sim::{simulate_edf, simulate_tape, EdfSimPolicy, EdfTraffic};
    use nimble::stream::rewrite::rewrite;

    section("EDF + SLO: FIFO vs EDF sheds, live vs simulate_edf (exact), SLO controller");

    let dev = GpuSpec::v100();
    let host = HostProfile::nimble();
    let tape_for = |bucket: usize| {
        let g = chain_graph(bucket, DEPTH);
        let costs: Vec<KernelCost> =
            (0..g.n_nodes()).map(|v| kernel_cost(g.node(v), &dev)).collect();
        let tape =
            ReplayTape::for_op_graph(&g, &rewrite(&g, MatchingAlgo::HopcroftKarp), 4096);
        (tape, costs)
    };
    let measured_service = |bucket: usize| -> f64 {
        let mut probe = chain_engine(&[bucket]);
        let zeros = vec![0.0f32; bucket * probe.example_len()];
        probe.infer_batch(bucket, &zeros).unwrap(); // warm-up
        let mut samples: Vec<f64> = (0..5)
            .map(|_| {
                let t0 = Instant::now();
                probe.infer_batch(bucket, &zeros).unwrap();
                t0.elapsed().as_secs_f64()
            })
            .collect();
        samples.sort_by(f64::total_cmp);
        samples[samples.len() / 2]
    };

    // --- (a) FIFO vs EDF ordering. ---
    const N_INF: usize = 6;
    const N_TIGHT: usize = 3;
    let budget_x = 3.5f64;
    let service_1 = measured_service(1);
    let ordering_run = |edf: bool| -> (usize, usize, nimble::serving::ServingReport) {
        let server = Runtime::builder()
            .label("chain")
            .graph_fn(|b| chain_graph(b, DEPTH))
            .buckets(&[1])
            .max_wait(Duration::from_millis(1))
            .lane_cap(2)
            .buffers_per_lane(1)
            .edf(edf)
            .build()
            .expect("edf ordering server");
        let len = server.example_len();
        // Warm the context AND the admission EWMA outside the burst.
        server.submit(InferRequest::new(vec![0.0; len])).unwrap().wait().unwrap();
        let mut rng = Pcg32::new(808);
        let budget = Duration::from_secs_f64(budget_x * service_1);
        let mut pending = Vec::new();
        for i in 0..N_INF + N_TIGHT {
            let input: Vec<f32> = (0..len).map(|_| rng.gen_f32_range(-1.0, 1.0)).collect();
            let req = InferRequest::new(input);
            let req = if i < N_INF { req } else { req.deadline_in(budget) };
            pending.push(server.submit(req).unwrap());
        }
        let (mut completed, mut shed) = (0usize, 0usize);
        for ticket in pending {
            match ticket.outcome().unwrap() {
                InferOutcome::Output(_) => completed += 1,
                InferOutcome::DeadlineShed => shed += 1,
                InferOutcome::Failed(e) => panic!("edf ordering request failed: {e}"),
            }
        }
        assert_eq!(completed + shed, N_INF + N_TIGHT, "ordering accounting must close");
        let report = server.shutdown().expect("edf ordering report");
        assert_eq!(report.deadline_shed, shed, "report must match client outcomes");
        (completed, shed, report)
    };
    let (fifo_completed, fifo_shed, fifo_report) = ordering_run(false);
    let (edf_completed, edf_shed, edf_report) = ordering_run(true);
    assert_eq!(fifo_report.admission_shed, 0, "edf(false) must never shed at admission");

    // DES prediction over the same arrival pattern in its service units.
    let (tape_1, costs_1) = tape_for(1);
    let des_service_1 = simulate_tape(&tape_1, &costs_1, host, dev.clone()).total_s;
    let mut batches_a: Vec<(f64, f64)> = vec![(0.0, f64::INFINITY); N_INF];
    batches_a.extend(std::iter::repeat((0.0, budget_x * des_service_1)).take(N_TIGHT));
    let traffic_a = [EdfTraffic { tape: &tape_1, costs: &costs_1, batches: &batches_a }];
    let des_fifo = simulate_edf(
        &traffic_a,
        host,
        dev.clone(),
        &EdfSimPolicy { edf: false, slo: None, max_lanes_per_bucket: 1 },
    );
    let des_edf = simulate_edf(
        &traffic_a,
        host,
        dev.clone(),
        &EdfSimPolicy { edf: true, slo: None, max_lanes_per_bucket: 1 },
    );
    let pass_a = edf_shed < fifo_shed;
    println!(
        "ordering: FIFO completed={fifo_completed} shed={fifo_shed}  \
         EDF completed={edf_completed} shed={edf_shed} (adm={})  \
         DES FIFO shed={} EDF shed={}  [{}]",
        edf_report.admission_shed,
        des_fifo.shed(),
        des_edf.shed(),
        if pass_a { "PASS" } else { "FAIL" }
    );

    // --- (b) live vs simulate_edf, exact accounting. ---
    const EXACT_BUCKET: usize = 2;
    const EXACT_JOBS: usize = 12;
    let mut rng = Pcg32::new(0xEDF0);
    let expired: Vec<bool> =
        (0..EXACT_JOBS).map(|_| rng.gen_range_inclusive(0, 2) == 0).collect();
    let n_expired = expired.iter().filter(|e| **e).count();
    let server = Runtime::builder()
        .label("chain")
        .graph_fn(|b| chain_graph(b, DEPTH))
        .buckets(&[EXACT_BUCKET])
        .max_wait(Duration::from_millis(1))
        .lane_cap(4)
        .buffers_per_lane(4)
        .build()
        .expect("edf exact server");
    let len = server.example_len();
    let (mut exact_completed, mut exact_shed) = (0usize, 0usize);
    for (i, is_expired) in expired.iter().enumerate() {
        let input: Vec<f32> =
            (0..EXACT_BUCKET * len).map(|_| rng.gen_f32_range(-1.0, 1.0)).collect();
        let req = InferRequest::batch(EXACT_BUCKET, input);
        let req = if *is_expired { req.deadline(Instant::now()) } else { req };
        match server.submit(req).unwrap().outcome().unwrap() {
            InferOutcome::Output(_) => exact_completed += 1,
            InferOutcome::DeadlineShed => exact_shed += 1,
            InferOutcome::Failed(e) => panic!("exact-run job {i} failed: {e}"),
        }
    }
    let exact_report = server.shutdown().expect("edf exact report");
    let (tape_2, costs_2) = tape_for(EXACT_BUCKET);
    let batches_b: Vec<(f64, f64)> =
        expired.iter().map(|e| (0.0, if *e { 0.0 } else { f64::INFINITY })).collect();
    let des_exact = simulate_edf(
        &[EdfTraffic { tape: &tape_2, costs: &costs_2, batches: &batches_b }],
        host,
        dev.clone(),
        &EdfSimPolicy { edf: true, slo: None, max_lanes_per_bucket: 1 },
    );
    let pass_b = exact_completed == des_exact.completed()
        && exact_shed == des_exact.shed()
        && exact_report.admission_shed == des_exact.admission_shed()
        && exact_shed == n_expired;
    println!(
        "exact: measured completed={exact_completed} shed={exact_shed} (adm={})  \
         DES completed={} shed={} (adm={})  [{}]",
        exact_report.admission_shed,
        des_exact.completed(),
        des_exact.shed(),
        des_exact.admission_shed(),
        if pass_b { "PASS" } else { "FAIL" }
    );

    // --- (c) SLO controller on the bursty tight-deadline waves. ---
    const SLO_BUCKET: usize = 4;
    const WAVES: usize = 3;
    const PER_WAVE: usize = 8;
    const MAX_LANES: usize = 3;
    let slo_target = 0.05f64;
    let gap = Duration::from_millis(30);
    let service_4 = measured_service(SLO_BUCKET);
    let slo_run = |slo: Option<f64>| -> (usize, usize, nimble::serving::ServingReport) {
        let builder = Runtime::builder()
            .label("chain")
            .graph_fn(|b| chain_graph(b, DEPTH))
            .buckets(&[SLO_BUCKET])
            .max_wait(Duration::from_millis(1))
            .lane_cap(PER_WAVE + 2)
            .buffers_per_lane(PER_WAVE + 2)
            .elastic(ScaleOptions {
                max_lanes_per_bucket: MAX_LANES,
                idle_retire: Duration::from_millis(200),
                // Unreachable: only the SLO controller may spawn.
                scale_up_backlog: 64,
            })
            .shared_pool_handle(SharedWorkerPool::new(4))
            .arena_pool(ArenaPool::new());
        let builder = match slo {
            Some(t) => builder.slo(t),
            None => builder,
        };
        let server = builder.build().expect("slo bench server");
        let len = server.example_len();
        let zeros = vec![0.0f32; SLO_BUCKET * len];
        server.submit(InferRequest::batch(SLO_BUCKET, zeros)).unwrap().wait().unwrap();
        let mut rng = Pcg32::new(4545);
        let budget = Duration::from_secs_f64(budget_x * service_4);
        let (mut completed, mut shed) = (0usize, 0usize);
        for wave in 0..WAVES {
            let pending: Vec<_> = (0..PER_WAVE)
                .map(|_| {
                    let input: Vec<f32> = (0..SLO_BUCKET * len)
                        .map(|_| rng.gen_f32_range(-1.0, 1.0))
                        .collect();
                    server
                        .submit(InferRequest::batch(SLO_BUCKET, input).deadline_in(budget))
                        .unwrap()
                })
                .collect();
            for ticket in pending {
                match ticket.outcome().unwrap() {
                    InferOutcome::Output(_) => completed += 1,
                    InferOutcome::DeadlineShed => shed += 1,
                    InferOutcome::Failed(e) => panic!("slo bench batch failed: {e}"),
                }
            }
            if wave + 1 < WAVES {
                std::thread::sleep(gap);
            }
        }
        assert_eq!(completed + shed, WAVES * PER_WAVE, "slo accounting must close");
        (completed, shed, server.shutdown().expect("slo report"))
    };
    let (off_completed, off_shed, off_report) = slo_run(None);
    let (on_completed, on_shed, on_report) = slo_run(Some(slo_target));
    assert_eq!(off_report.lanes_spawned(), 0, "pressure gate must stay closed");

    // DES prediction of the same wave structure in its service units.
    let (tape_4, costs_4) = tape_for(SLO_BUCKET);
    let des_service_4 = simulate_tape(&tape_4, &costs_4, host, dev.clone()).total_s;
    let mut batches_c: Vec<(f64, f64)> = Vec::new();
    for wave in 0..WAVES {
        let t = wave as f64 * 3.0 * des_service_4;
        batches_c
            .extend(std::iter::repeat((t, t + budget_x * des_service_4)).take(PER_WAVE));
    }
    let traffic_c = [EdfTraffic { tape: &tape_4, costs: &costs_4, batches: &batches_c }];
    let des_off = simulate_edf(
        &traffic_c,
        host,
        dev.clone(),
        &EdfSimPolicy { edf: true, slo: None, max_lanes_per_bucket: MAX_LANES },
    );
    let des_on = simulate_edf(
        &traffic_c,
        host,
        dev,
        &EdfSimPolicy { edf: true, slo: Some(slo_target), max_lanes_per_bucket: MAX_LANES },
    );
    let pass_c = on_report.lanes_spawned() >= 1 && on_shed < off_shed;
    println!(
        "slo: off completed={off_completed} shed={off_shed} spawned={}  \
         on completed={on_completed} shed={on_shed} (adm={}) spawned={}  \
         DES off shed={} on shed={} lanes-live={}  [{}]",
        off_report.lanes_spawned(),
        on_report.admission_shed,
        on_report.lanes_spawned(),
        des_off.shed(),
        des_on.shed(),
        des_on.lanes_spawned(),
        if pass_c { "PASS" } else { "FAIL" }
    );

    let pass = pass_a && pass_b && pass_c;
    println!("edf-slo [{}]", if pass { "PASS" } else { "FAIL" });

    format!(
        "{{\n  \"workload\": \"edf-slo-chain\",\n  \"chain_depth\": {DEPTH},\n  \
         \"budget_x\": {budget_x},\n  \
         \"ordering\": {{\"bucket\": 1, \"n_inf\": {N_INF}, \"n_tight\": {N_TIGHT}, \
         \"fifo_completed\": {fifo_completed}, \"fifo_shed\": {fifo_shed}, \
         \"edf_completed\": {edf_completed}, \"edf_shed\": {edf_shed}, \
         \"edf_admission_shed\": {}, \"des_fifo_shed\": {}, \"des_edf_shed\": {}, \
         \"pass\": {pass_a}}},\n  \
         \"sim_exact\": {{\"bucket\": {EXACT_BUCKET}, \"n_jobs\": {EXACT_JOBS}, \
         \"n_expired\": {n_expired}, \"measured_completed\": {exact_completed}, \
         \"measured_shed\": {exact_shed}, \"measured_admission_shed\": {}, \
         \"des_completed\": {}, \"des_shed\": {}, \"des_admission_shed\": {}, \
         \"pass\": {pass_b}}},\n  \
         \"slo\": {{\"bucket\": {SLO_BUCKET}, \"waves\": {WAVES}, \
         \"per_wave\": {PER_WAVE}, \"target_shed_rate\": {slo_target}, \
         \"max_lanes_per_bucket\": {MAX_LANES}, \
         \"off_completed\": {off_completed}, \"off_shed\": {off_shed}, \
         \"on_completed\": {on_completed}, \"on_shed\": {on_shed}, \
         \"on_admission_shed\": {}, \"on_lanes_spawned\": {}, \
         \"des_off_shed\": {}, \"des_on_shed\": {}, \"des_on_lanes_live\": {}, \
         \"pass\": {pass_c}}},\n  \"pass\": {pass}\n}}",
        edf_report.admission_shed,
        des_fifo.shed(),
        des_edf.shed(),
        exact_report.admission_shed,
        des_exact.completed(),
        des_exact.shed(),
        des_exact.admission_shed(),
        on_report.admission_shed,
        on_report.lanes_spawned(),
        des_off.shed(),
        des_on.shed(),
        des_on.lanes_spawned(),
    )
}

fn sweep(label: &str, start: impl Fn() -> Runtime) {
    for rate in [5.0f64, 20.0] {
        let server = start();
        let len = server.example_len();
        let mut rng = Pcg32::new(9);
        let n = 24;
        let mut pending = Vec::new();
        for _ in 0..n {
            let input: Vec<f32> = (0..len).map(|_| rng.gen_f32_range(-1.0, 1.0)).collect();
            pending.push(server.submit(InferRequest::new(input)).unwrap());
            std::thread::sleep(Duration::from_secs_f64(rng.gen_exp(rate)));
        }
        for ticket in pending {
            ticket.wait().unwrap();
        }
        let report = server.shutdown().expect("report");
        println!("{label} @ ~{rate} req/s:\n{}", report.render());
    }
}

fn lane_sweep() {
    section("serving load sweep (lane scheduler, MiniInception, per-bucket lanes)");
    sweep("lane-server", || {
        Runtime::builder()
            .model("mini_inception")
            .buckets(&[1, 8])
            .max_wait(Duration::from_millis(3))
            .build()
            .expect("lane server")
    });
}

fn main() {
    let lane_entry = lane_vs_serial();
    let scaling_entry = elastic_vs_static();
    let deadline_entry = deadline_sweep();
    let chaos_entry = chaos_check();
    let edf_entry = edf_slo();
    let json = format!(
        "[\n{lane_entry},\n{scaling_entry},\n{deadline_entry},\n{chaos_entry},\n{edf_entry}\n]\n"
    );
    match std::fs::write("BENCH_serving.json", &json) {
        Ok(()) => println!("\nwrote BENCH_serving.json"),
        Err(e) => println!("\ncould not write BENCH_serving.json: {e}"),
    }

    section("serving load sweep (tape replay engine, MiniInception, per-bucket contexts)");
    sweep("tape-engine", || {
        Runtime::builder()
            .model("mini_inception")
            .buckets(&[1, 8])
            .single_thread()
            .max_wait(Duration::from_millis(3))
            .build()
            .expect("tape server")
    });

    lane_sweep();

    #[cfg(feature = "xla")]
    {
        use nimble::coordinator::EngineConfig;
        if nimble::runtime::artifacts_available() {
            section("serving load sweep (real PJRT replay engine, MiniInception)");
            sweep("pjrt-engine", || {
                Runtime::builder()
                    .artifacts(EngineConfig::default())
                    .single_thread()
                    .max_wait(Duration::from_millis(3))
                    .build()
                    .expect("server")
            });
        } else {
            println!("\nSKIP real-engine sweep: run `make artifacts` first");
        }
    }
    #[cfg(not(feature = "xla"))]
    println!("\n(real-engine sweep skipped: built without `--features xla`)");
}
