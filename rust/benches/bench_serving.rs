//! Serving-path benchmark over the REAL engine: offered-load sweep through
//! the batched server (replay mode), reporting p50/p99 latency and
//! throughput. Skips without artifacts.

mod common;
use common::section;
use nimble::coordinator::EngineConfig;
use nimble::serving::{NimbleServer, ServerConfig};
use nimble::util::Pcg32;
use std::time::Duration;

fn main() {
    if !nimble::runtime::artifacts_available() {
        println!("SKIP bench_serving: run `make artifacts` first");
        return;
    }
    section("serving load sweep (replay engine, MiniInception)");
    for rate in [5.0f64, 20.0] {
        let server = NimbleServer::start(ServerConfig {
            engine: EngineConfig::default(),
            max_wait: Duration::from_millis(3),
        })
        .expect("server");
        let len = server.example_len();
        let mut rng = Pcg32::new(9);
        let n = 24;
        let mut pending = Vec::new();
        for _ in 0..n {
            let input: Vec<f32> = (0..len).map(|_| rng.gen_f32_range(-1.0, 1.0)).collect();
            pending.push(server.infer_async(input).unwrap());
            std::thread::sleep(Duration::from_secs_f64(rng.gen_exp(rate)));
        }
        for rx in pending {
            rx.recv().unwrap().unwrap();
        }
        let report = server.shutdown().expect("report");
        println!("offered ~{rate} req/s:\n{}", report.render());
    }
}
