//! Serving-path benchmark: offered-load sweep through the batched
//! server, reporting p50/p99 latency and throughput.
//!
//! Always runs over the tape-backed engine (independent per-bucket
//! replay contexts on the synthetic substrate); with the `xla` feature
//! and artifacts present it also sweeps the real PJRT engine.

mod common;
use common::section;
use nimble::serving::{NimbleServer, TapeEngine};
use nimble::util::Pcg32;
use std::time::Duration;

fn sweep(label: &str, start: impl Fn() -> NimbleServer) {
    for rate in [5.0f64, 20.0] {
        let server = start();
        let len = server.example_len();
        let mut rng = Pcg32::new(9);
        let n = 24;
        let mut pending = Vec::new();
        for _ in 0..n {
            let input: Vec<f32> = (0..len).map(|_| rng.gen_f32_range(-1.0, 1.0)).collect();
            pending.push(server.infer_async(input).unwrap());
            std::thread::sleep(Duration::from_secs_f64(rng.gen_exp(rate)));
        }
        for rx in pending {
            rx.recv().unwrap().unwrap();
        }
        let report = server.shutdown().expect("report");
        println!("{label} @ ~{rate} req/s:\n{}", report.render());
    }
}

fn main() {
    section("serving load sweep (tape replay engine, MiniInception, per-bucket contexts)");
    sweep("tape-engine", || {
        NimbleServer::start_with(
            || TapeEngine::new("mini_inception", &[1, 8]),
            Duration::from_millis(3),
        )
        .expect("tape server")
    });

    #[cfg(feature = "xla")]
    {
        use nimble::coordinator::EngineConfig;
        use nimble::serving::ServerConfig;
        if nimble::runtime::artifacts_available() {
            section("serving load sweep (real PJRT replay engine, MiniInception)");
            sweep("pjrt-engine", || {
                NimbleServer::start(ServerConfig {
                    engine: EngineConfig::default(),
                    max_wait: Duration::from_millis(3),
                })
                .expect("server")
            });
        } else {
            println!("\nSKIP real-engine sweep: run `make artifacts` first");
        }
    }
    #[cfg(not(feature = "xla"))]
    println!("\n(real-engine sweep skipped: built without `--features xla`)");
}
