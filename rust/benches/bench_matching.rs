//! Hopcroft–Karp vs Ford–Fulkerson on the Step-2 bipartite graphs — the
//! ablation behind choosing HK as the production default while keeping the
//! paper's FF implementation.

mod common;
use common::{bench, section};
use nimble::graph::minimum_equivalent_graph;
use nimble::matching::{maximum_matching, BipartiteGraph, MatchingAlgo};
use nimble::models;

fn main() {
    section("maximum matching: Hopcroft–Karp vs Ford–Fulkerson");
    for name in ["inception_v3", "nasnet_a_mobile", "nasnet_a_large"] {
        let g = models::build(name, 1);
        let meg = minimum_equivalent_graph(&g);
        let b = BipartiteGraph::from_dag_edges(g.n_nodes(), &meg.edges());
        let hk = bench(&format!("hopcroft_karp {name} (|E'|={})", meg.n_edges()), 2, 20, || {
            maximum_matching(&b, MatchingAlgo::HopcroftKarp)
        });
        let ff = bench(&format!("ford_fulkerson {name}"), 2, 20, || {
            maximum_matching(&b, MatchingAlgo::FordFulkerson)
        });
        println!("  -> FF takes {:.2}x of HK time", ff.median() / hk.median());
        assert_eq!(
            maximum_matching(&b, MatchingAlgo::HopcroftKarp).cardinality(),
            maximum_matching(&b, MatchingAlgo::FordFulkerson).cardinality()
        );
    }
}
