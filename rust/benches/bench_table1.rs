//! Table 1 regeneration benchmark: multi- vs single-stream Nimble across
//! the five parallelizable architectures.

mod common;
use common::{bench, section};

fn main() {
    section("Table 1 (multi-stream impact)");
    bench("table1 sweep", 0, 3, nimble::figures::table1);
    println!("{}", nimble::figures::table1().render());
}
