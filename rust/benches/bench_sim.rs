//! Discrete-event simulator throughput: simulated tasks per second on the
//! figure-regeneration hot path (target in DESIGN.md §Perf: ≥ ~1M tasks/s
//! so `nimble figures all` stays interactive).

mod common;
use common::{bench, section};
use nimble::baselines::{prepare, run_prepared, Baseline};
use nimble::models;
use nimble::sim::GpuSpec;

fn main() {
    section("DES throughput (end-to-end simulate per model)");
    let dev = GpuSpec::v100();
    for (name, b) in [
        ("resnet50", Baseline::PyTorch),
        ("nasnet_a_mobile", Baseline::PyTorch),
        ("nasnet_a_mobile", Baseline::Nimble),
        ("nasnet_a_large", Baseline::Nimble),
    ] {
        let g = models::build(name, 1);
        let p = prepare(&g, b, &dev, true);
        let n_tasks = p.plan.order.len();
        let s = bench(&format!("simulate {name} / {}", b.name()), 2, 15, || {
            run_prepared(&p, &dev)
        });
        println!("  -> {:.2}M simulated tasks/s", n_tasks as f64 / s.median() / 1e6);
    }

    section("training-graph simulation");
    let g = models::build_train("resnet50_cifar", 32);
    let p = prepare(&g, Baseline::Nimble, &dev, false);
    bench("simulate resnet50_cifar train b32 / Nimble", 1, 10, || run_prepared(&p, &dev));
}
