//! Cluster-layer benchmark: data-parallel replica groups behind the
//! deadline-aware router, judged against `sim::simulate_cluster` with
//! the same measured-vs-predicted discipline as the serving bench.
//!
//! Three entries, written to `BENCH_cluster.json`:
//!
//! 1. **sim_exact** — a seeded closed-loop 2-replica p2c run whose
//!    completed / shed / per-replica-admitted counts the cluster DES
//!    must reproduce *bit-for-bit* (door sheds consume no router draw,
//!    closed-loop pressure is identically zero, so routing reduces to
//!    the shared seeded draw protocol). Asserted, not just reported —
//!    this is the ISSUE's acceptance gate.
//! 2. **scale** — 1 vs 2 vs 4 replicas under the same open-loop
//!    deadline workload: measured throughput/shed next to the DES
//!    prediction for the same arrival schedule in its service units.
//! 3. **router** — power-of-two-choices vs round-robin with replica 0
//!    skewed slow by a deterministic per-op delay fault: p2c's
//!    pressure signal routes around the slow replica, round-robin
//!    blindly feeds it half the traffic.

mod common;
use common::section;
use nimble::aot::tape::ReplayTape;
use nimble::cluster::Cluster;
use nimble::fault::FaultPlan;
use nimble::matching::MatchingAlgo;
use nimble::ops::{GraphBuilder, OpGraph};
use nimble::serving::{InferOutcome, InferRequest};
use nimble::sim::{
    kernel_cost, simulate_cluster, simulate_tape, ClusterSimPolicy, ClusterTraffic, GpuSpec,
    HostProfile, KernelCost,
};
use nimble::stream::rewrite::rewrite;
use nimble::util::Pcg32;
use std::time::{Duration, Instant};

/// Same deep conv chain as the serving bench: single-stream tapes, so
/// per-replica service time is stable and the DES service unit is
/// meaningful.
fn chain_graph(batch: usize, depth: usize) -> OpGraph {
    let mut b = GraphBuilder::new();
    let mut x = b.input(&[batch, 16, 16, 16]);
    for _ in 0..depth {
        x = b.conv_bn_relu(x, 16, 3, 1);
    }
    let pooled = b.gap(x);
    let _logits = b.linear(pooled, 10);
    b.finish()
}

const DEPTH: usize = 12;

fn tape_and_costs() -> (ReplayTape, Vec<KernelCost>) {
    let g = chain_graph(1, DEPTH);
    let dev = GpuSpec::v100();
    let costs: Vec<KernelCost> = (0..g.n_nodes()).map(|v| kernel_cost(g.node(v), &dev)).collect();
    let tape = ReplayTape::for_op_graph(&g, &rewrite(&g, MatchingAlgo::HopcroftKarp), 4096);
    (tape, costs)
}

fn chain_cluster(replicas: usize) -> nimble::cluster::ClusterBuilder {
    Cluster::builder()
        .label("chain")
        .graph_fn(|b| chain_graph(b, DEPTH))
        .buckets(&[1])
        .replicas(replicas)
        .max_wait(Duration::from_millis(1))
}

/// (1) Closed-loop exact match: live cluster vs `simulate_cluster`,
/// same seed, bit-identical counts.
fn sim_exact() -> String {
    section("cluster DES exact match (closed loop, 2 replicas, seeded p2c)");
    const N: usize = 24;
    const SEED: u64 = 0xC10C;

    // Seeded expiry mask: roughly a third of the requests arrive
    // already expired and must shed at the door, consuming no draw.
    let mut rng = Pcg32::new(0xC1A0);
    let expired: Vec<bool> = (0..N).map(|_| rng.gen_range_inclusive(0, 2) == 0).collect();
    let n_expired = expired.iter().filter(|e| **e).count();

    let cluster = chain_cluster(2).route_p2c(SEED).build().expect("exact cluster");
    let len = cluster.example_len();
    let (mut completed, mut shed) = (0usize, 0usize);
    for (i, is_expired) in expired.iter().enumerate() {
        let input: Vec<f32> = (0..len).map(|_| rng.gen_f32_range(-1.0, 1.0)).collect();
        let req = InferRequest::new(input);
        let req = if *is_expired { req.deadline(Instant::now()) } else { req };
        // Closed loop: wait for each outcome before the next submit, so
        // every routing decision sees identically-zero pressure.
        match cluster.submit(req).unwrap().outcome().unwrap() {
            InferOutcome::Output(_) => completed += 1,
            InferOutcome::DeadlineShed => shed += 1,
            InferOutcome::Failed(e) => panic!("exact-run request {i} failed: {e}"),
        }
    }
    let admitted: Vec<u64> = cluster.admitted_per_replica();
    let report = cluster.shutdown().expect("exact report");
    assert!(report.accounting_closes(), "cluster accounting must close:\n{}", report.render());

    let (tape, costs) = tape_and_costs();
    let requests: Vec<(f64, f64)> =
        expired.iter().map(|e| (0.0, if *e { 0.0 } else { f64::INFINITY })).collect();
    let des = simulate_cluster(
        &ClusterTraffic { tape: &tape, costs: &costs, requests: &requests },
        HostProfile::nimble(),
        GpuSpec::v100(),
        &ClusterSimPolicy {
            replicas: 2,
            lanes_per_replica: 1,
            p2c: true,
            seed: SEED,
            closed_loop: true,
        },
    );
    let des_admitted: Vec<u64> =
        des.admitted_per_replica().iter().map(|&a| a as u64).collect();

    // The acceptance gate: measured and simulated runs agree exactly.
    assert_eq!(completed, des.completed(), "completed must match the DES exactly");
    assert_eq!(shed, des.shed(), "shed must match the DES exactly");
    assert_eq!(shed, n_expired, "exactly the expired requests shed");
    assert_eq!(admitted, des_admitted, "per-replica routing must match the DES exactly");
    let pass = true;
    println!(
        "exact: measured completed={completed} shed={shed} admitted={admitted:?}  \
         DES completed={} shed={} admitted={des_admitted:?}  [PASS]",
        des.completed(),
        des.shed(),
    );

    format!(
        "{{\n  \"workload\": \"cluster-exact-chain\",\n  \"chain_depth\": {DEPTH}, \
         \"replicas\": 2, \"router_seed\": {SEED}, \"n_requests\": {N}, \
         \"n_expired\": {n_expired},\n  \
         \"measured\": {{\"completed\": {completed}, \"shed\": {shed}, \
         \"admitted_per_replica\": {admitted:?}}},\n  \
         \"des\": {{\"completed\": {}, \"shed\": {}, \
         \"admitted_per_replica\": {des_admitted:?}}},\n  \"pass\": {pass}\n}}",
        des.completed(),
        des.shed(),
    )
}

/// (2) Replica scaling: the same open-loop deadline workload against
/// 1, 2, and 4 replicas, measured vs predicted.
fn scale() -> String {
    section("replica scaling (open loop, deadline traffic, 1 vs 2 vs 4 replicas)");
    const N: usize = 32;
    // Arrivals at 0.6× the service time saturate one replica; deadlines
    // at 3× the service time give survivors room.
    const ARRIVE_X: f64 = 0.6;
    const BUDGET_X: f64 = 3.0;

    // Measured service time of one warm replica, the live time unit.
    let service_s = {
        let cluster = chain_cluster(1).build().expect("probe cluster");
        let len = cluster.example_len();
        let zeros = vec![0.0f32; len];
        cluster.infer(InferRequest::new(zeros.clone())).expect("warm");
        let t0 = Instant::now();
        for _ in 0..4 {
            cluster.infer(InferRequest::new(zeros.clone())).expect("probe");
        }
        let s = t0.elapsed().as_secs_f64() / 4.0;
        let _ = cluster.shutdown().expect("probe report");
        s
    };

    let (tape, costs) = tape_and_costs();
    let des_service_s =
        simulate_tape(&tape, &costs, HostProfile::nimble(), GpuSpec::v100()).total_s;

    let mut entries = Vec::new();
    let mut measured_shed = Vec::new();
    let mut des_shed = Vec::new();
    for &replicas in &[1usize, 2, 4] {
        let cluster = chain_cluster(replicas).route_p2c(7).build().expect("scale cluster");
        let len = cluster.example_len();
        // Warm every replica's lane path before the timed phase.
        for _ in 0..2 * replicas {
            cluster.infer(InferRequest::new(vec![0.0; len])).expect("warmup");
        }
        let mut rng = Pcg32::new(0x5CA1);
        let budget = Duration::from_secs_f64(BUDGET_X * service_s);
        let gap = Duration::from_secs_f64(ARRIVE_X * service_s);
        let t0 = Instant::now();
        let mut pending = Vec::with_capacity(N);
        for _ in 0..N {
            let input: Vec<f32> = (0..len).map(|_| rng.gen_f32_range(-1.0, 1.0)).collect();
            pending.push(cluster.submit(InferRequest::new(input).deadline_in(budget)).unwrap());
            std::thread::sleep(gap);
        }
        let (mut completed, mut shed) = (0usize, 0usize);
        for t in pending {
            match t.outcome().unwrap() {
                InferOutcome::Output(_) => completed += 1,
                InferOutcome::DeadlineShed => shed += 1,
                InferOutcome::Failed(e) => panic!("scale request failed: {e}"),
            }
        }
        let wall_s = t0.elapsed().as_secs_f64();
        let report = cluster.shutdown().expect("scale report");
        assert!(report.accounting_closes(), "accounting must close:\n{}", report.render());

        // DES prediction of the same schedule in its own service units.
        let requests: Vec<(f64, f64)> = (0..N)
            .map(|i| {
                let at = i as f64 * ARRIVE_X * des_service_s;
                (at, at + BUDGET_X * des_service_s)
            })
            .collect();
        let des = simulate_cluster(
            &ClusterTraffic { tape: &tape, costs: &costs, requests: &requests },
            HostProfile::nimble(),
            GpuSpec::v100(),
            &ClusterSimPolicy {
                replicas,
                lanes_per_replica: 1,
                p2c: true,
                seed: 7,
                closed_loop: false,
            },
        );
        println!(
            "{replicas} replica(s): measured completed={completed} shed={shed} \
             ({:.1} req/s)  DES completed={} shed={}",
            completed as f64 / wall_s,
            des.completed(),
            des.shed(),
        );
        measured_shed.push(shed);
        des_shed.push(des.shed());
        entries.push(format!(
            "{{\"replicas\": {replicas}, \"measured_completed\": {completed}, \
             \"measured_shed\": {shed}, \"measured_rps\": {:.2}, \
             \"des_completed\": {}, \"des_shed\": {}}}",
            completed as f64 / wall_s,
            des.completed(),
            des.shed(),
        ));
    }
    // Scaling out must not increase shedding, measured and predicted.
    let pass = measured_shed[2] <= measured_shed[0] && des_shed[2] <= des_shed[0];
    println!("scale [{}]", if pass { "PASS" } else { "FAIL" });
    format!(
        "{{\n  \"workload\": \"cluster-scale-chain\",\n  \"chain_depth\": {DEPTH}, \
         \"n_requests\": {N}, \"arrive_x\": {ARRIVE_X}, \"budget_x\": {BUDGET_X},\n  \
         \"runs\": [{}],\n  \"pass\": {pass}\n}}",
        entries.join(", ")
    )
}

/// (3) p2c vs round-robin with a deterministically slow replica 0:
/// pressure-aware routing sheds less than blind rotation.
fn router_delta() -> String {
    section("router policy delta (p2c vs round-robin, replica 0 skewed slow)");
    const N: usize = 16;
    // Every op on replica 0 stalls 4 ms: a DEPTH-op chain batch takes
    // tens of ms there vs sub-ms on replica 1.
    let slow = FaultPlan { op_delay: 1.0, delay: Duration::from_millis(4), ..FaultPlan::default() };
    let budget = Duration::from_millis(250);

    let run = |p2c: bool| -> (usize, usize, f64) {
        let builder = chain_cluster(2).replica_fault_plan(0, slow.clone());
        let builder = if p2c { builder.route_p2c(11) } else { builder.route_round_robin() };
        let cluster = builder.build().expect("router cluster");
        let len = cluster.example_len();
        // Warm the fast replica only (one closed-loop request may land
        // on either; warm both to be fair).
        for _ in 0..2 {
            cluster.infer(InferRequest::new(vec![0.0; len])).expect("warmup");
        }
        let mut rng = Pcg32::new(0xDE17A);
        let t0 = Instant::now();
        let pending: Vec<_> = (0..N)
            .map(|_| {
                let input: Vec<f32> = (0..len).map(|_| rng.gen_f32_range(-1.0, 1.0)).collect();
                cluster.submit(InferRequest::new(input).deadline_in(budget)).unwrap()
            })
            .collect();
        let (mut completed, mut shed) = (0usize, 0usize);
        for t in pending {
            match t.outcome().unwrap() {
                InferOutcome::Output(_) => completed += 1,
                InferOutcome::DeadlineShed => shed += 1,
                InferOutcome::Failed(e) => panic!("router-delta request failed: {e}"),
            }
        }
        let wall_s = t0.elapsed().as_secs_f64();
        let report = cluster.shutdown().expect("router report");
        assert!(report.accounting_closes(), "accounting must close:\n{}", report.render());
        (completed, shed, wall_s)
    };

    let (rr_completed, rr_shed, rr_wall) = run(false);
    let (p2c_completed, p2c_shed, p2c_wall) = run(true);
    // Round-robin feeds the slow replica half the burst and must miss
    // deadlines there; p2c routes around it once pressure diverges.
    let pass = p2c_shed <= rr_shed;
    println!(
        "router: RR completed={rr_completed} shed={rr_shed} ({rr_wall:.3}s)  \
         p2c completed={p2c_completed} shed={p2c_shed} ({p2c_wall:.3}s)  [{}]",
        if pass { "PASS" } else { "FAIL" }
    );
    format!(
        "{{\n  \"workload\": \"cluster-router-delta\",\n  \"chain_depth\": {DEPTH}, \
         \"n_requests\": {N}, \"slow_replica_op_delay_ms\": 4, \"budget_ms\": 250,\n  \
         \"round_robin\": {{\"completed\": {rr_completed}, \"shed\": {rr_shed}, \
         \"wall_s\": {rr_wall:.4}}},\n  \
         \"p2c\": {{\"completed\": {p2c_completed}, \"shed\": {p2c_shed}, \
         \"wall_s\": {p2c_wall:.4}}},\n  \"pass\": {pass}\n}}"
    )
}

fn main() {
    let exact_entry = sim_exact();
    let scale_entry = scale();
    let router_entry = router_delta();
    let json = format!("[\n{exact_entry},\n{scale_entry},\n{router_entry}\n]\n");
    match std::fs::write("BENCH_cluster.json", &json) {
        Ok(()) => println!("\nwrote BENCH_cluster.json"),
        Err(e) => println!("\ncould not write BENCH_cluster.json: {e}"),
    }
}
