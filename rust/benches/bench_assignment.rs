//! Algorithm 1 end-to-end cost (MEG + matching + partition) across graph
//! sizes and real model graphs. The paper's App. A bounds this at O(V³);
//! it runs once per engine build, but must stay interactive for the
//! biggest NAS graphs.

mod common;
use common::{bench, section};
use nimble::graph::gen::{layered_dag, random_dag};
use nimble::matching::MatchingAlgo;
use nimble::models;
use nimble::stream::assign_streams;
use nimble::util::Pcg32;

fn main() {
    section("Algorithm 1 on synthetic DAGs");
    for &n in &[50usize, 200, 800] {
        let g = random_dag(&mut Pcg32::new(1), n, 0.02);
        bench(&format!("assign_streams random n={n}"), 2, 10, || {
            assign_streams(&g, MatchingAlgo::HopcroftKarp)
        });
    }
    let g = layered_dag(&mut Pcg32::new(2), 20, 8, 3);
    bench(&format!("assign_streams layered n={}", g.n_nodes()), 2, 10, || {
        assign_streams(&g, MatchingAlgo::HopcroftKarp)
    });

    section("Algorithm 1 on model-zoo graphs (engine-build cost)");
    for name in ["resnet50", "inception_v3", "nasnet_a_mobile", "nasnet_a_large"] {
        let g = models::build(name, 1);
        bench(&format!("assign_streams {name} (|V|={})", g.n_nodes()), 1, 5, || {
            assign_streams(&g, MatchingAlgo::HopcroftKarp)
        });
    }
}
