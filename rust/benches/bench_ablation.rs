//! Ablations of the design choices DESIGN.md calls out:
//!  1. Synchronization planning: Algorithm 1's minimum plan (|E'|−|M|) vs
//!     a naive plan that syncs every cross-stream edge of G — the paper's
//!     argument for minimizing syncs ("synchronizations hamper the fast
//!     launching of tasks").
//!  2. Operator fusion on/off under the Nimble host profile.
//!  3. Multi-stream vs single-stream (Table 1's core ablation) on the
//!     extension models (MixNet / ResNeSt).

mod common;
use common::section;
use nimble::baselines::{baseline_costs, simulate_inference, Baseline};
use nimble::matching::MatchingAlgo;
use nimble::models;
use nimble::sim::{simulate, GpuSpec, HostProfile, SimConfig};
use nimble::stream::assign_streams;
use nimble::stream::rewrite::rewrite_with;
use nimble::stream::sync::SyncPlan;

fn main() {
    let dev = GpuSpec::v100();

    section("ablation 1: minimum sync plan vs naive all-cross-edge syncs");
    for name in ["inception_v3", "nasnet_a_mobile", "amoebanet"] {
        let g = models::build(name, 1);
        let a = assign_streams(&g, MatchingAlgo::HopcroftKarp);
        let costs = baseline_costs(&g, Baseline::Nimble, &dev);
        // minimum plan (Algorithm 1 / Theorem 3)
        let min_plan = rewrite_with(&g, &a);
        // naive plan: one sync per cross-stream edge of the FULL graph
        let mut syncs = Vec::new();
        for (u, v) in g.edges() {
            if a.stream_of[u] != a.stream_of[v] {
                let event = syncs.len();
                syncs.push(nimble::stream::sync::Sync { src: u, dst: v, event });
            }
        }
        let naive_syncs = SyncPlan::new(syncs, g.n_nodes());
        let naive_plan = {
            // same streams/order, more events
            let mut p = min_plan.clone();
            for node_plan in &mut p.order {
                node_plan.wait_events = naive_syncs.waits_before(node_plan.node).to_vec();
                node_plan.record_events = naive_syncs.records_after(node_plan.node).to_vec();
            }
            p.n_events = naive_syncs.n_syncs();
            p
        };
        let host = HostProfile::nimble();
        let t_min = simulate(&SimConfig { plan: &min_plan, costs: &costs, host, device: dev.clone() }).total_s;
        let t_naive = simulate(&SimConfig { plan: &naive_plan, costs: &costs, host, device: dev.clone() }).total_s;
        println!(
            "{name:<18} syncs {:>4} -> {:>4} (min)   latency {:.3} ms -> {:.3} ms ({:+.1}%)",
            naive_plan.n_events,
            min_plan.n_events,
            t_naive * 1e3,
            t_min * 1e3,
            (t_min / t_naive - 1.0) * 100.0
        );
        assert!(min_plan.n_events <= naive_plan.n_events);
    }

    section("ablation 2: operator fusion on/off (Nimble host, single device)");
    for name in ["resnet50", "efficientnet_b0"] {
        let g = models::build(name, 1);
        let fused = simulate_inference(&g, Baseline::Nimble, &dev).total_s;
        // single-stream nimble without fusion ≈ AoT-only
        let p = nimble::baselines::prepare(&g, Baseline::Nimble, &dev, false);
        let unfused = nimble::baselines::run_prepared(&p, &dev).total_s;
        println!(
            "{name:<18} unfused {:.3} ms -> fused {:.3} ms ({:.2}x)",
            unfused * 1e3,
            fused * 1e3,
            unfused / fused
        );
    }

    section("ablation 3: multi-stream on the extension models (MixNet/ResNeSt)");
    for name in ["mixnet_s", "resnest50"] {
        let g = models::build(name, 1);
        let single = simulate_inference(&g, Baseline::NimbleSingleStream, &dev).total_s;
        let multi = simulate_inference(&g, Baseline::Nimble, &dev).total_s;
        println!(
            "{name:<18} single {:.3} ms -> multi {:.3} ms ({:.2}x)",
            single * 1e3,
            multi * 1e3,
            single / multi
        );
    }
}
