//! Fig. 8 + Fig. 10 regeneration benchmark: training-step speedups at
//! batch 32 and across batch sizes.

mod common;
use common::{bench, section};

fn main() {
    section("Fig. 8 (training speedups, batch 32)");
    bench("fig8 sweep", 0, 2, nimble::figures::fig8);
    println!("{}", nimble::figures::fig8().render());
    section("Fig. 10 (batch-size sweep)");
    bench("fig10 sweep", 0, 2, nimble::figures::fig10);
    println!("{}", nimble::figures::fig10().render());
}
