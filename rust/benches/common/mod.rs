//! Shared micro-bench harness (criterion is unavailable offline): warmup +
//! timed iterations with mean/p50/p99 reporting.

use nimble::util::stats::{fmt_secs, Summary};
use std::time::Instant;

/// Time `iters` runs of `f` after `warmup` runs; print and return stats.
#[allow(dead_code)]
pub fn bench<T>(name: &str, warmup: usize, iters: usize, mut f: impl FnMut() -> T) -> Summary {
    for _ in 0..warmup {
        std::hint::black_box(f());
    }
    let mut samples = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t0 = Instant::now();
        std::hint::black_box(f());
        samples.push(t0.elapsed().as_secs_f64());
    }
    let s = Summary::from_samples(samples);
    println!(
        "{name:<48} mean={:>12} p50={:>12} p99={:>12} (n={iters})",
        fmt_secs(s.mean()),
        fmt_secs(s.median()),
        fmt_secs(s.percentile(99.0)),
    );
    s
}

/// Section header.
pub fn section(title: &str) {
    println!("\n### {title}");
}
