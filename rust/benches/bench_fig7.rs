//! Fig. 7 regeneration benchmark: the full 8-network × 6-system inference
//! sweep on the simulated V100, printing the speedup table.

mod common;
use common::{bench, section};

fn main() {
    section("Fig. 7 (inference speedups vs PyTorch, batch 1, V100)");
    bench("fig7 full sweep", 0, 3, nimble::figures::fig7);
    println!("{}", nimble::figures::fig7().render());
}
