//! Reserved-memory benchmark: the stream-aware arena against per-slot
//! allocation, cross-checked against the DES.
//!
//! For each model (batch 1, multi-stream rewrite) this measures:
//!
//! * `unshared_bytes` — per-slot-buffer footprint (no lifetime sharing),
//! * `arena_bytes` — the packed happens-before arena the executor
//!   actually reserves (`ReplayContext::reserved_bytes`),
//! * `serial_arena_bytes` — the serial-interval plan, the lower bound a
//!   single-thread replay could pack to (unsound for the parallel
//!   executor; reported for the serial-vs-stream-aware gap),
//! * `des_peak_bytes` — the DES-predicted peak concurrently-reserved
//!   bytes over the simulated schedule, and
//! * `measured_peak_bytes` — the executor's traced high-water mark over
//!   a real parallel replay, and
//! * `runtime_lane_reserved_bytes` — the same reservation surfaced
//!   through the serving façade (`Runtime::builder()` lane report),
//!   which must equal `arena_bytes` exactly.
//!
//! On the single-stream rewrite, the DES prediction and the serial
//! executor's measured peak must agree **exactly** (same order, same
//! accounting); on the multi-stream tape both peaks must sit inside the
//! reservation. Results go to `BENCH_memory.json` (format documented in
//! `rust/README.md`) — the CI artifact for the memory plan.

mod common;
use common::section;
use nimble::aot::memory::{interval_conflicts, plan_with_conflicts, serial_lifetimes};
use nimble::aot::tape::ReplayTape;
use nimble::engine::executor::{ReplayContext, SyntheticKernel};
use nimble::matching::MatchingAlgo;
use nimble::models;
use nimble::serving::Runtime;
use nimble::sim::{kernel_cost, peak_reserved_bytes, simulate_tape, GpuSpec, HostProfile};
use nimble::stream::rewrite::{rewrite, rewrite_single_stream};

const MODELS: [&str; 4] = ["mini_inception", "inception_v3", "nasnet_a_mobile", "mixnet_s"];

struct Row {
    model: &'static str,
    n_tasks: usize,
    n_streams: usize,
    unshared_bytes: u64,
    arena_bytes: u64,
    serial_arena_bytes: u64,
    des_peak_bytes: u64,
    measured_peak_bytes: u64,
    single_stream_peak_match: bool,
    /// The same reservation surfaced through the serving façade
    /// (`Runtime` lane report) — must equal `arena_bytes` exactly.
    runtime_lane_reserved_bytes: u64,
    pass: bool,
}

fn measure(model: &'static str) -> Row {
    let dev = GpuSpec::v100();
    let g = models::build(model, 1);
    let costs: Vec<_> = (0..g.n_nodes()).map(|v| kernel_cost(g.node(v), &dev)).collect();

    // --- Multi-stream tape: packed arena, DES peak, measured peak. ---
    let plan = rewrite(&g, MatchingAlgo::HopcroftKarp);
    let tape = ReplayTape::for_op_graph(&g, &plan, 4096);
    let mut ctx = ReplayContext::new(tape.clone(), SyntheticKernel);
    let arena_bytes = ctx.reserved_bytes();
    let unshared_bytes = ctx.unshared_bytes();
    let serial_arena_bytes =
        plan_with_conflicts(&tape.slot_bytes(), &interval_conflicts(&serial_lifetimes(&tape)))
            .arena_bytes;

    let sim = simulate_tape(&tape, &costs, HostProfile::nimble(), dev.clone());
    let des_peak_bytes = peak_reserved_bytes(&tape, &sim.spans, &ctx.arena_plan().rounded_sizes);

    let input = vec![0.5f32; tape.input_slots()[0].1];
    ctx.set_tracing(true);
    ctx.replay_one(&input).expect("parallel replay");
    let measured_peak_bytes = ctx.peak_live_bytes();
    ctx.check_canaries().expect("canaries intact");

    // --- Single-stream cross-check: prediction == measurement. ---
    let tape_s = ReplayTape::for_op_graph(&g, &rewrite_single_stream(&g), 4096);
    let mut ctx_s = ReplayContext::new(tape_s.clone(), SyntheticKernel);
    let sim_s = simulate_tape(&tape_s, &costs, HostProfile::nimble(), dev);
    let predicted_s =
        peak_reserved_bytes(&tape_s, &sim_s.spans, &ctx_s.arena_plan().rounded_sizes);
    let input_s = vec![0.5f32; tape_s.input_slots()[0].1];
    ctx_s.set_tracing(true);
    ctx_s.replay_serial(&[&input_s]).expect("serial replay");
    let single_stream_peak_match = predicted_s == ctx_s.peak_live_bytes();

    // --- Façade cross-check: the serving runtime's per-lane report
    // must surface the exact same packed reservation. ---
    let server = Runtime::builder()
        .model(model)
        .buckets(&[1])
        .build()
        .expect("façade runtime for the memory cross-check");
    let runtime_report = server.shutdown().expect("runtime report");
    let runtime_lane_reserved_bytes =
        runtime_report.lane(1).and_then(|l| l.reserved_bytes).unwrap_or(0);

    let pass = (plan.n_streams == 1 || arena_bytes < unshared_bytes)
        && des_peak_bytes <= arena_bytes
        && measured_peak_bytes <= arena_bytes
        && single_stream_peak_match
        && runtime_lane_reserved_bytes == arena_bytes;
    Row {
        model,
        n_tasks: tape.n_tasks(),
        n_streams: plan.n_streams,
        unshared_bytes,
        arena_bytes,
        serial_arena_bytes,
        des_peak_bytes,
        measured_peak_bytes,
        single_stream_peak_match,
        runtime_lane_reserved_bytes,
        pass,
    }
}

fn main() {
    section("reserved-memory arena vs unshared vs DES-predicted peak (batch 1)");
    println!(
        "{:<18} {:>7} {:>8} {:>12} {:>12} {:>12} {:>12} {:>12}  {}",
        "model",
        "tasks",
        "streams",
        "unshared",
        "arena",
        "serial",
        "des-peak",
        "measured",
        "pass"
    );
    let rows: Vec<Row> = MODELS.iter().map(|&m| measure(m)).collect();
    for r in &rows {
        println!(
            "{:<18} {:>7} {:>8} {:>12} {:>12} {:>12} {:>12} {:>12}  {}",
            r.model,
            r.n_tasks,
            r.n_streams,
            r.unshared_bytes,
            r.arena_bytes,
            r.serial_arena_bytes,
            r.des_peak_bytes,
            r.measured_peak_bytes,
            if r.pass { "PASS" } else { "FAIL" }
        );
    }

    let entries: Vec<String> = rows
        .iter()
        .map(|r| {
            format!(
                "  {{\"model\": \"{}\", \"n_tasks\": {}, \"n_streams\": {}, \
                 \"unshared_bytes\": {}, \"arena_bytes\": {}, \"serial_arena_bytes\": {}, \
                 \"des_peak_bytes\": {}, \"measured_peak_bytes\": {}, \
                 \"single_stream_peak_match\": {}, \"runtime_lane_reserved_bytes\": {}, \
                 \"pass\": {}}}",
                r.model,
                r.n_tasks,
                r.n_streams,
                r.unshared_bytes,
                r.arena_bytes,
                r.serial_arena_bytes,
                r.des_peak_bytes,
                r.measured_peak_bytes,
                r.single_stream_peak_match,
                r.runtime_lane_reserved_bytes,
                r.pass
            )
        })
        .collect();
    let json = format!("[\n{}\n]\n", entries.join(",\n"));
    match std::fs::write("BENCH_memory.json", &json) {
        Ok(()) => println!("\nwrote BENCH_memory.json"),
        Err(e) => println!("\ncould not write BENCH_memory.json: {e}"),
    }
    assert!(rows.iter().all(|r| r.pass), "memory-plan acceptance failed (see table)");
}
