//! Minimal offline stand-in for the `anyhow` crate.
//!
//! The workspace builds without crates.io access, so this vendored crate
//! provides exactly the `anyhow` surface the codebase uses: an [`Error`]
//! type holding a chain of context strings, the [`Context`] extension
//! trait for `Result` and `Option`, the [`anyhow!`], [`bail!`] and
//! [`ensure!`] macros, and the `Result<T>` alias. Formatting matches
//! `anyhow`'s conventions: `{}` prints the outermost context, `{:#}`
//! prints the whole chain separated by `": "`, and `{:?}` prints the
//! outermost message followed by a `Caused by:` list.

use std::fmt::{self, Debug, Display};

/// Error type: a root cause plus the contexts attached on the way up.
/// Stored innermost-first; displayed outermost-first.
pub struct Error {
    chain: Vec<String>,
}

impl Error {
    /// Create an error from a displayable message.
    pub fn msg<M: Display>(message: M) -> Error {
        Error { chain: vec![message.to_string()] }
    }

    /// Attach an outer context message.
    pub fn context<C: Display>(mut self, context: C) -> Error {
        self.chain.push(context.to_string());
        self
    }

    /// The innermost (root-cause) message.
    pub fn root_cause(&self) -> &str {
        &self.chain[0]
    }

    /// Iterate the chain outermost-first.
    pub fn chain(&self) -> impl Iterator<Item = &str> {
        self.chain.iter().rev().map(String::as_str)
    }
}

impl Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if f.alternate() {
            for (i, part) in self.chain.iter().rev().enumerate() {
                if i > 0 {
                    f.write_str(": ")?;
                }
                f.write_str(part)?;
            }
            Ok(())
        } else {
            f.write_str(self.chain.last().expect("non-empty chain"))
        }
    }
}

impl Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(self.chain.last().expect("non-empty chain"))?;
        if self.chain.len() > 1 {
            f.write_str("\n\nCaused by:")?;
            for part in self.chain.iter().rev().skip(1) {
                write!(f, "\n    {part}")?;
            }
        }
        Ok(())
    }
}

impl<E: std::error::Error + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Error {
        let mut chain = Vec::new();
        let mut source: Option<&(dyn std::error::Error + 'static)> = e.source();
        while let Some(s) = source {
            chain.push(s.to_string());
            source = s.source();
        }
        chain.reverse();
        chain.push(e.to_string());
        Error { chain }
    }
}

/// `anyhow`-style result alias.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Extension trait adding `.context(...)` / `.with_context(|| ...)`.
pub trait Context<T> {
    fn context<C: Display>(self, context: C) -> Result<T>;
    fn with_context<C: Display, F: FnOnce() -> C>(self, f: F) -> Result<T>;
}

impl<T, E: Into<Error>> Context<T> for std::result::Result<T, E> {
    fn context<C: Display>(self, context: C) -> Result<T> {
        self.map_err(|e| {
            let err: Error = e.into();
            err.context(context)
        })
    }

    fn with_context<C: Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.map_err(|e| {
            let err: Error = e.into();
            err.context(f())
        })
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: Display>(self, context: C) -> Result<T> {
        self.ok_or_else(|| Error::msg(context))
    }

    fn with_context<C: Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Construct an [`Error`] from a format string or displayable value.
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(format!($msg))
    };
    ($fmt:expr, $($arg:tt)*) => {
        $crate::Error::msg(format!($fmt, $($arg)*))
    };
    ($msg:expr $(,)?) => {
        $crate::Error::msg($msg)
    };
}

/// Return early with an error built like [`anyhow!`].
#[macro_export]
macro_rules! bail {
    ($($t:tt)*) => {
        return Err($crate::anyhow!($($t)*))
    };
}

/// Return early with an error unless the condition holds.
#[macro_export]
macro_rules! ensure {
    ($cond:expr $(,)?) => {
        if !($cond) {
            return Err($crate::Error::msg(concat!("condition failed: ", stringify!($cond))));
        }
    };
    ($cond:expr, $($t:tt)+) => {
        if !($cond) {
            return Err($crate::anyhow!($($t)+));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::{Context, Error, Result};

    fn fails() -> Result<()> {
        Err(Error::msg("root"))
    }

    #[test]
    fn context_chains_and_formats() {
        let err = fails().context("outer").unwrap_err();
        assert_eq!(format!("{err}"), "outer");
        assert_eq!(format!("{err:#}"), "outer: root");
        assert!(format!("{err:?}").contains("Caused by:"));
    }

    #[test]
    fn option_context() {
        let v: Option<u32> = None;
        let err = v.with_context(|| format!("missing {}", 7)).unwrap_err();
        assert_eq!(format!("{err}"), "missing 7");
    }

    #[test]
    fn std_error_conversion() {
        let io: std::io::Error = std::io::Error::new(std::io::ErrorKind::Other, "disk");
        let err: Error = io.into();
        assert_eq!(format!("{err}"), "disk");
    }

    #[test]
    fn macros() {
        fn inner(flag: bool) -> Result<u32> {
            crate::ensure!(flag, "flag was {flag}");
            Ok(1)
        }
        assert_eq!(inner(true).unwrap(), 1);
        assert_eq!(format!("{:#}", inner(false).unwrap_err()), "flag was false");
        let e = crate::anyhow!("x = {}", 3);
        assert_eq!(format!("{e}"), "x = 3");
    }
}
