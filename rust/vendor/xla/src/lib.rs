//! Stub of the `xla` (PJRT) API surface used by the nimble runtime.
//!
//! The container this workspace builds in has no PJRT plugin and no
//! crates.io access, so this crate keeps the `--features xla` code paths
//! *type-checked* without providing a real backend: every entry point
//! ([`PjRtClient::cpu`], [`HloModuleProto::from_text_file`]) returns a
//! clear "stub backend" error, and because no value of [`PjRtClient`] /
//! [`PjRtBuffer`] / [`PjRtLoadedExecutable`] can ever be constructed, the
//! remaining methods are statically unreachable. Swapping in the real
//! `xla` crate (same module paths, same signatures) enables the PJRT
//! path with no source changes.

use std::fmt;

/// Error type matching the shape of the real crate's error.
#[derive(Debug, Clone)]
pub struct Error(String);

impl Error {
    fn stub(what: &str) -> Error {
        Error(format!(
            "{what}: built against the stub `xla` crate (no PJRT backend in this environment); \
             vendor the real xla/PJRT crate to enable the real runtime"
        ))
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.0)
    }
}

impl std::error::Error for Error {}

pub type Result<T> = std::result::Result<T, Error>;

/// PJRT client handle. Unconstructible in the stub.
pub struct PjRtClient(());

impl PjRtClient {
    /// The real crate creates the CPU PJRT client here; the stub reports
    /// that no backend is available.
    pub fn cpu() -> Result<PjRtClient> {
        Err(Error::stub("PjRtClient::cpu"))
    }

    pub fn platform_name(&self) -> String {
        unreachable!("stub PjRtClient cannot be constructed")
    }

    pub fn compile(&self, _computation: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        unreachable!("stub PjRtClient cannot be constructed")
    }

    pub fn buffer_from_host_buffer(
        &self,
        _data: &[f32],
        _dims: &[usize],
        _device: Option<usize>,
    ) -> Result<PjRtBuffer> {
        unreachable!("stub PjRtClient cannot be constructed")
    }
}

/// Parsed HLO module. Unconstructible in the stub.
pub struct HloModuleProto(());

impl HloModuleProto {
    pub fn from_text_file(_path: &str) -> Result<HloModuleProto> {
        Err(Error::stub("HloModuleProto::from_text_file"))
    }
}

/// XLA computation wrapper.
pub struct XlaComputation(());

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        unreachable!("stub HloModuleProto cannot be constructed")
    }
}

/// Compiled executable handle. Unconstructible in the stub.
pub struct PjRtLoadedExecutable(());

impl PjRtLoadedExecutable {
    pub fn execute_b(&self, _args: &[&PjRtBuffer]) -> Result<Vec<Vec<PjRtBuffer>>> {
        unreachable!("stub PjRtLoadedExecutable cannot be constructed")
    }
}

/// Device buffer handle. Unconstructible in the stub.
pub struct PjRtBuffer(());

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        unreachable!("stub PjRtBuffer cannot be constructed")
    }
}

/// Host literal. Unconstructible in the stub.
pub struct Literal(());

impl Literal {
    pub fn to_vec<T>(&self) -> Result<Vec<T>> {
        unreachable!("stub Literal cannot be constructed")
    }

    pub fn to_tuple(self) -> Result<Vec<Literal>> {
        unreachable!("stub Literal cannot be constructed")
    }

    pub fn array_shape(&self) -> Result<ArrayShape> {
        unreachable!("stub Literal cannot be constructed")
    }
}

/// Array shape (dims as i64, matching the real crate).
pub struct ArrayShape(Vec<i64>);

impl ArrayShape {
    pub fn dims(&self) -> &[i64] {
        &self.0
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn entry_points_report_stub() {
        let err = PjRtClient::cpu().err().expect("stub must error");
        assert!(err.to_string().contains("stub"));
        let err = HloModuleProto::from_text_file("x.hlo.txt").err().expect("stub must error");
        assert!(err.to_string().contains("stub"));
    }
}
