//! Baseline systems (Fig. 7 / Fig. 8 comparators) as composition of:
//! host profile × graph transform (fusion) × kernel quality × stream plan.
//!
//! | system      | host overhead | fusion | kernels            | streams |
//! |-------------|---------------|--------|--------------------|---------|
//! | PyTorch     | eager, high   | none   | cuDNN/native       | 1       |
//! | TorchScript | C++ runtime   | none   | cuDNN/native       | 1       |
//! | Caffe2      | graph runtime | none   | cuDNN              | 1       |
//! | TensorFlow  | graph runtime | none   | cuDNN              | 1       |
//! | TensorRT    | engine        | yes    | autotuned (~0.9×)  | 1       |
//! | TVM         | compiled      | yes    | tuned: dense ~0.95×, depthwise ~0.5× | 1 |
//! | Nimble (1s) | AoT replay    | yes    | selected (~0.9×)   | 1       |
//! | Nimble      | AoT replay    | yes    | selected (~0.9×)   | Algorithm 1 |
//!
//! The TVM row encodes the paper's MobileNetV2 observation: two days of
//! auto-tuning finds dramatically faster *depthwise* kernels than cuDNN
//! (the only network where TVM beats Nimble), while dense convs are near
//! cuDNN parity. Nimble's 0.9× models its cuDNN-vs-native kernel
//! selection; TensorRT's 0.9× its kernel autotuner. Scheduling behaviour —
//! the paper's actual subject — is exact: per-op host overheads, fusion
//! changing task counts, and Algorithm 1 stream plans.

use crate::matching::MatchingAlgo;
use crate::ops::{fuse_graph, OpGraph, OpKind};
use crate::sim::cost::{kernel_cost, KernelCost};
use crate::sim::{simulate, GpuSpec, HostProfile, SimConfig, SimResult};
use crate::stream::rewrite::{rewrite, rewrite_single_stream};
use crate::stream::LaunchPlan;

/// The systems compared in the paper's evaluation.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Baseline {
    PyTorch,
    TorchScript,
    Caffe2,
    TensorFlow,
    TensorRT,
    Tvm,
    /// Nimble restricted to one stream (Table 1's baseline).
    NimbleSingleStream,
    /// Full Nimble: AoT scheduling + Algorithm 1 multi-stream.
    Nimble,
    /// The hand-written "scheduling-minimized" program of Fig. 2b.
    SchedMinimized,
}

impl Baseline {
    pub fn name(self) -> &'static str {
        match self {
            Baseline::PyTorch => "PyTorch",
            Baseline::TorchScript => "TorchScript",
            Baseline::Caffe2 => "Caffe2",
            Baseline::TensorFlow => "TensorFlow",
            Baseline::TensorRT => "TensorRT",
            Baseline::Tvm => "TVM",
            Baseline::NimbleSingleStream => "Nimble(1-stream)",
            Baseline::Nimble => "Nimble",
            Baseline::SchedMinimized => "SchedMinimized",
        }
    }

    /// The Fig. 7 inference line-up.
    pub fn inference_systems() -> Vec<Baseline> {
        vec![
            Baseline::PyTorch,
            Baseline::TorchScript,
            Baseline::Caffe2,
            Baseline::TensorRT,
            Baseline::Tvm,
            Baseline::Nimble,
        ]
    }

    /// The Fig. 8 training line-up.
    pub fn training_systems() -> Vec<Baseline> {
        vec![Baseline::PyTorch, Baseline::TorchScript, Baseline::Nimble]
    }

    pub fn host(self) -> HostProfile {
        match self {
            Baseline::PyTorch => HostProfile::pytorch(),
            Baseline::TorchScript => HostProfile::torchscript(),
            Baseline::Caffe2 => HostProfile::caffe2(),
            Baseline::TensorFlow => HostProfile::tensorflow(),
            Baseline::TensorRT => HostProfile::tensorrt(),
            Baseline::Tvm => HostProfile::tvm(),
            Baseline::NimbleSingleStream | Baseline::Nimble => HostProfile::nimble(),
            Baseline::SchedMinimized => HostProfile::sched_minimized(),
        }
    }

    /// Does the system run an operator-fusion pass?
    pub fn fuses(self) -> bool {
        matches!(
            self,
            Baseline::TensorRT | Baseline::Tvm | Baseline::Nimble | Baseline::NimbleSingleStream
        )
    }

    /// Kernel-duration multipliers (dense matmul-like, depthwise conv).
    pub fn kernel_scales(self) -> (f64, f64) {
        match self {
            Baseline::TensorRT => (0.90, 0.90),
            Baseline::Tvm => (0.95, 0.50),
            Baseline::Nimble | Baseline::NimbleSingleStream => (0.90, 0.90),
            _ => (1.0, 1.0),
        }
    }

    pub fn multi_stream(self) -> bool {
        matches!(self, Baseline::Nimble)
    }
}

/// Per-node kernel costs for a graph under a baseline's kernel quality.
pub fn baseline_costs(g: &OpGraph, b: Baseline, dev: &GpuSpec) -> Vec<KernelCost> {
    let (dense, dw) = b.kernel_scales();
    (0..g.n_nodes())
        .map(|v| {
            let op = g.node(v);
            let mut c = kernel_cost(op, dev);
            let scale = match &op.kind {
                OpKind::Conv2d { groups, .. } if *groups > 1 => dw,
                k if k.is_matmul_like() => dense,
                OpKind::Fused { parts } => {
                    if parts
                        .iter()
                        .any(|p| matches!(p, OpKind::Conv2d { groups, .. } if *groups > 1))
                    {
                        dw
                    } else if parts.iter().any(|p| p.is_matmul_like()) {
                        dense
                    } else {
                        1.0
                    }
                }
                _ => 1.0,
            };
            if scale != 1.0 {
                let var = (c.duration_s - dev.kernel_fixed_s).max(0.0);
                c.duration_s = var * scale + dev.kernel_fixed_s;
            }
            // TVM's code-generated kernels skip cuDNN's heuristic dispatch
            // and launch leaner — a small fixed-cost edge that decides the
            // paper's one Nimble loss (MobileNetV2).
            if b == Baseline::Tvm && c.duration_s > 0.0 {
                c.duration_s -= 0.35 * dev.kernel_fixed_s;
            }
            c
        })
        .collect()
}

/// A fully prepared run: transformed graph + plan + costs.
pub struct PreparedRun {
    pub graph: OpGraph,
    pub plan: LaunchPlan,
    pub costs: Vec<KernelCost>,
    pub baseline: Baseline,
}

/// Prepare a model graph for a baseline. `allow_fusion=false` for training
/// graphs (frameworks don't fuse through autograd; BN stays separate).
pub fn prepare(g: &OpGraph, b: Baseline, dev: &GpuSpec, allow_fusion: bool) -> PreparedRun {
    let graph = if b.fuses() && allow_fusion { fuse_graph(g) } else { g.clone() };
    let plan = if b.multi_stream() {
        rewrite(&graph, MatchingAlgo::HopcroftKarp)
    } else {
        rewrite_single_stream(&graph)
    };
    let costs = baseline_costs(&graph, b, dev);
    PreparedRun { graph, plan, costs, baseline: b }
}

/// Simulate a prepared run.
pub fn run_prepared(p: &PreparedRun, dev: &GpuSpec) -> SimResult {
    simulate(&SimConfig {
        plan: &p.plan,
        costs: &p.costs,
        host: p.baseline.host(),
        device: dev.clone(),
    })
}

/// One-shot: simulate an *inference* run of a model graph under a baseline.
pub fn simulate_inference(g: &OpGraph, b: Baseline, dev: &GpuSpec) -> SimResult {
    run_prepared(&prepare(g, b, dev, true), dev)
}

/// One-shot: simulate a *training step* (graph must already be the
/// fwd+bwd+opt graph; fusion disabled).
pub fn simulate_training(g_train: &OpGraph, b: Baseline, dev: &GpuSpec) -> SimResult {
    run_prepared(&prepare(g_train, b, dev, false), dev)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models;

    #[test]
    fn nimble_beats_pytorch_on_small_kernel_nets() {
        let g = models::build("mini_inception", 1);
        let dev = GpuSpec::v100();
        let pt = simulate_inference(&g, Baseline::PyTorch, &dev).total_s;
        let nb = simulate_inference(&g, Baseline::Nimble, &dev).total_s;
        assert!(pt / nb > 3.0, "pytorch {pt} vs nimble {nb}");
    }

    #[test]
    fn multi_stream_helps_branchy_graphs() {
        let g = models::build("mini_inception", 1);
        let dev = GpuSpec::v100();
        let single = simulate_inference(&g, Baseline::NimbleSingleStream, &dev).total_s;
        let multi = simulate_inference(&g, Baseline::Nimble, &dev).total_s;
        assert!(multi < single, "multi {multi} should beat single {single}");
    }

    #[test]
    fn fusion_reduces_task_count() {
        let g = models::build("resnet50", 1);
        let dev = GpuSpec::v100();
        let trt = prepare(&g, Baseline::TensorRT, &dev, true);
        let pt = prepare(&g, Baseline::PyTorch, &dev, true);
        assert!(trt.graph.n_nodes() < pt.graph.n_nodes() / 2);
    }

    #[test]
    fn tvm_wins_on_depthwise_heavy_mobilenet() {
        // The paper's one loss: TVM's tuned depthwise kernels.
        let g = models::build("mobilenet_v2", 1);
        let dev = GpuSpec::v100();
        let tvm = simulate_inference(&g, Baseline::Tvm, &dev).total_s;
        let nb = simulate_inference(&g, Baseline::Nimble, &dev).total_s;
        assert!(tvm < nb, "tvm {tvm} vs nimble {nb}");
    }

    #[test]
    fn nimble_beats_tensorrt() {
        let g = models::build("inception_v3", 1);
        let dev = GpuSpec::v100();
        let trt = simulate_inference(&g, Baseline::TensorRT, &dev).total_s;
        let nb = simulate_inference(&g, Baseline::Nimble, &dev).total_s;
        assert!(nb < trt, "nimble {nb} vs tensorrt {trt}");
    }

    #[test]
    fn training_fusion_disabled() {
        let g = models::build_train("mini_inception", 8);
        let dev = GpuSpec::v100();
        let p = prepare(&g, Baseline::Nimble, &dev, false);
        assert_eq!(p.graph.n_nodes(), g.n_nodes());
    }

    #[test]
    fn all_systems_produce_consistent_results() {
        let g = models::build("mini_inception", 1);
        let dev = GpuSpec::v100();
        for b in Baseline::inference_systems() {
            let r = simulate_inference(&g, b, &dev);
            assert!(r.total_s > 0.0, "{}", b.name());
            assert!(r.gpu_active_s <= r.total_s + 1e-12, "{}", b.name());
        }
    }
}
