//! The one runtime façade: [`Runtime::builder()`] + [`InferRequest`].
//!
//! Nimble's pitch is that every scheduling decision is made ahead of
//! time, so the run-time surface should be one cheap, uniform submit
//! path. Before this module the public API was a matrix of constructors
//! (`TapeEngine::{new, with_worker_cap, from_graph_fn, from_graph_fn_opts}`,
//! `LaneServer::{start, start_pooled_tape, start_elastic_tape}`,
//! `NimbleServer::{start, start_with}`) and per-client method variants
//! (`infer` / `infer_hinted` / `infer_async` / `infer_hinted_async`).
//! All of those are now thin `#[deprecated]` shims; the supported
//! surface is:
//!
//! ```no_run
//! use nimble::serving::{InferRequest, Runtime, ScaleOptions};
//! # fn main() -> anyhow::Result<()> {
//! let rt = Runtime::builder()
//!     .model("mini_inception")
//!     .buckets(&[1, 4, 16])
//!     .elastic(ScaleOptions { max_lanes_per_bucket: 3, ..Default::default() })
//!     .shared_pool(8)
//!     .build()?;
//!
//! // Blocking:
//! let out = rt.infer(InferRequest::new(vec![0.0; rt.example_len()]))?;
//!
//! // Async, with routing + deadline composed on the request:
//! let req = InferRequest::new(vec![0.0; rt.example_len()])
//!     .hint(16)
//!     .deadline_in(std::time::Duration::from_millis(20));
//! let ticket = rt.submit(req)?;
//! let outcome = ticket.outcome()?; // Output(..) | DeadlineShed | Failed(..)
//! # let _ = (out, outcome);
//! # Ok(()) }
//! ```
//!
//! Exactly two submit paths exist — blocking [`Runtime::infer`] and
//! waitable [`Runtime::submit`] returning a [`Ticket`] — and every knob
//! that used to force a new constructor (worker caps, arena pools, the
//! shared work-stealing pool, elastic scaling) composes on
//! [`RuntimeBuilder`]. **Deadlines** are the capability the old matrix
//! could not express — and on the lane topology they are a first-class
//! scheduling input, not just a filter: the batcher forms batches
//! earliest-deadline-first (FIFO among equal or absent deadlines), the
//! dispatcher sheds budgets it estimates unmeetable at *admission*
//! (before they occupy backlog), and a request whose deadline expires
//! while it waits (batcher queue, lane stage, or lane queue) is shed
//! the moment it comes due. Every shed is surfaced as
//! [`InferOutcome::DeadlineShed`] to the caller and counted in
//! `ServingReport::deadline_shed` / `LaneStat::deadline_shed`
//! (admission sheds also in `admission_shed`). An optional
//! [`slo`](RuntimeBuilder::slo) target drives lane scaling from the
//! live shed rate. The DES predicts shed counts offline
//! ([`crate::sim::simulate_lanes_deadline`], [`crate::sim::simulate_edf`]).

use anyhow::{Context, Result};
use std::sync::mpsc;
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use super::lanes::{HealthState, LaneClient, LaneConfig, LaneServer, ScaleOptions};
use super::metrics::ServingReport;
use super::server::{NimbleServer, ServerClient};
use super::sim_engine::{TapeEngine, TapeEngineOptions};
use crate::aot::memory::ArenaPool;
use crate::aot::verify::VerifyMode;
use crate::coordinator::InferEngine;
use crate::engine::executor::SharedWorkerPool;
use crate::fault::{ChaosEngine, FaultPlan, RetryPolicy};
use crate::models;
use crate::ops::OpGraph;
use crate::telemetry::Telemetry;

/// The exact reply string of a deadline-shed request — a reserved
/// sentinel on the legacy `Result<_, String>` reply channel. A reply
/// equal to this whole string classifies as
/// [`InferOutcome::DeadlineShed`]; every other error is
/// [`InferOutcome::Failed`] (engines must not return this exact
/// message as a genuine error).
pub const DEADLINE_SHED: &str = "deadline shed: expired before execution";

/// The reply a shed request receives (always equals [`DEADLINE_SHED`]).
pub(crate) fn shed_error() -> String {
    DEADLINE_SHED.to_string()
}

/// Internal request token carried through the batcher and the lane
/// queues: the per-request reply channel plus the request's deadline.
pub(crate) struct ReqToken {
    pub reply: mpsc::Sender<Result<Vec<f32>, String>>,
    pub deadline: Option<Instant>,
    /// Flight-recorder trace id correlating this request's lifecycle
    /// events (admit → stage → pop/shed → reply). 0 when telemetry is
    /// off or the request predates the recorder (single-engine server).
    pub trace: u64,
}

impl ReqToken {
    /// The shed rule, shared by the lane threads, the single-engine
    /// thread, and the DES: expired once `now` reaches the deadline.
    pub fn expired(&self, now: Instant) -> bool {
        self.deadline.is_some_and(|d| now >= d)
    }

    /// Resolve this token as shed (the receiver may already be gone).
    pub fn shed(&self) {
        let _ = self.reply.send(Err(shed_error()));
    }
}

/// Per-request options ([`InferRequest::opts`]).
#[derive(Debug, Clone, Default)]
pub struct RequestOptions {
    /// Route the request's batch to this compiled bucket instead of
    /// deriving the bucket from queue depth (sequence-length-aware
    /// clients pick their own lane). Must name a compiled bucket.
    pub bucket_hint: Option<usize>,
    /// Shed the request (resolving its [`Ticket`] with
    /// [`InferOutcome::DeadlineShed`]) if it is still waiting —
    /// batcher queue, lane stage, or lane queue — at this instant.
    /// Execution already started is never interrupted.
    pub deadline: Option<Instant>,
}

/// One inference request: the input plus composable [`RequestOptions`].
/// Built with [`new`](Self::new) (one example, runs through the dynamic
/// batcher) or [`batch`](Self::batch) (a pre-formed padded batch routed
/// straight to its bucket's lane).
#[derive(Debug, Clone)]
pub struct InferRequest {
    /// Flattened input: one example ([`new`](Self::new)) or
    /// `bucket * example_len` values ([`batch`](Self::batch)).
    pub input: Vec<f32>,
    pub opts: RequestOptions,
    /// `Some(bucket)` for a pre-formed padded batch.
    batch: Option<usize>,
}

impl InferRequest {
    /// One example through the dynamic batcher.
    pub fn new(input: Vec<f32>) -> InferRequest {
        InferRequest { input, opts: RequestOptions::default(), batch: None }
    }

    /// A pre-formed padded batch (`bucket * example_len` values) routed
    /// straight to `bucket`'s lane; the reply carries the full padded
    /// output. Requires the lane topology (the builder default).
    pub fn batch(bucket: usize, input: Vec<f32>) -> InferRequest {
        InferRequest { input, opts: RequestOptions::default(), batch: Some(bucket) }
    }

    /// Replace the whole option set.
    pub fn with_options(mut self, opts: RequestOptions) -> Self {
        self.opts = opts;
        self
    }

    /// Route to this compiled bucket ([`RequestOptions::bucket_hint`]).
    pub fn hint(mut self, bucket: usize) -> Self {
        self.opts.bucket_hint = Some(bucket);
        self
    }

    /// Absolute deadline ([`RequestOptions::deadline`]).
    pub fn deadline(mut self, at: Instant) -> Self {
        self.opts.deadline = Some(at);
        self
    }

    /// Deadline `budget` from now.
    pub fn deadline_in(self, budget: Duration) -> Self {
        self.deadline(Instant::now() + budget)
    }

    /// The pre-formed batch bucket, if this is a batch request.
    pub fn bucket(&self) -> Option<usize> {
        self.batch
    }
}

impl From<Vec<f32>> for InferRequest {
    fn from(input: Vec<f32>) -> InferRequest {
        InferRequest::new(input)
    }
}

/// How a submitted request resolved.
#[derive(Debug, Clone, PartialEq)]
pub enum InferOutcome {
    /// The flattened output (one example's logits, or the full padded
    /// batch output for [`InferRequest::batch`]).
    Output(Vec<f32>),
    /// The deadline expired while the request waited; the engine never
    /// ran it.
    DeadlineShed,
    /// The engine (or the server) failed the request.
    Failed(String),
}

impl InferOutcome {
    pub fn is_shed(&self) -> bool {
        matches!(self, InferOutcome::DeadlineShed)
    }

    /// The output, if the request completed.
    pub fn output(self) -> Option<Vec<f32>> {
        match self {
            InferOutcome::Output(v) => Some(v),
            _ => None,
        }
    }
}

/// Liveness probe ([`Runtime::health`] / [`RuntimeHandle::health`]).
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum Health {
    /// Serving normally.
    Healthy,
    /// One or more buckets lost their lanes for good (the replacement
    /// rebuild failed too) and fail fast; the rest serve normally.
    Degraded { buckets: Vec<usize> },
    /// [`Runtime::drain`] / shutdown began: admission rejects new work
    /// while everything already admitted flushes.
    Draining,
}

/// Marker error for request-shape validation failures at submit time
/// (bad input/batch length, unknown bucket, contradictory hint).
/// Every replica built from the same spec rejects the request
/// identically, so routers propagate these instead of retrying on
/// another replica — test with [`is_validation_error`] rather than
/// matching the message text.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ValidationError(pub String);

impl std::fmt::Display for ValidationError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(&self.0)
    }
}

impl std::error::Error for ValidationError {}

/// Whether any error in `e`'s chain is a [`ValidationError`] — a
/// permanent, replica-independent rejection.
pub fn is_validation_error(e: &anyhow::Error) -> bool {
    e.chain().any(|c| c.downcast_ref::<ValidationError>().is_some())
}

fn classify(reply: Result<Vec<f32>, String>) -> InferOutcome {
    match reply {
        Ok(v) => InferOutcome::Output(v),
        // Exact-equality on the reserved sentinel: only ReqToken::shed
        // produces this whole string, so a genuine engine error cannot
        // masquerade as a shed by sharing a prefix.
        Err(e) if e == DEADLINE_SHED => InferOutcome::DeadlineShed,
        Err(e) => InferOutcome::Failed(e),
    }
}

/// Waitable handle to a submitted request ([`Runtime::submit`]) — the
/// typed replacement for the raw `mpsc::Receiver` the deprecated
/// `infer_async` variants exposed. Every submitted ticket resolves
/// exactly once: output, deadline-shed, or failure.
pub struct Ticket {
    rx: mpsc::Receiver<Result<Vec<f32>, String>>,
}

impl Ticket {
    pub(crate) fn new(rx: mpsc::Receiver<Result<Vec<f32>, String>>) -> Ticket {
        Ticket { rx }
    }

    /// Block for the outcome. A dropped reply channel (the server was
    /// torn down before resolving the request) classifies as
    /// [`InferOutcome::Failed`], not an `Err`: every submitted ticket
    /// resolves exactly once no matter how the server dies.
    pub fn outcome(self) -> Result<InferOutcome> {
        match self.rx.recv() {
            Ok(reply) => Ok(classify(reply)),
            Err(_) => Ok(InferOutcome::Failed("server dropped request".to_string())),
        }
    }

    /// Like [`outcome`](Self::outcome) with a wait bound; `Err` only on
    /// timeout (a dropped reply channel still resolves as `Failed`).
    pub fn outcome_timeout(self, timeout: Duration) -> Result<InferOutcome> {
        self.poll_timeout(timeout)
            .ok_or_else(|| anyhow::anyhow!("timed out waiting for the request outcome"))
    }

    /// Poll for the outcome with a wait bound without consuming the
    /// ticket: `None` means the bound elapsed and the ticket may be
    /// polled again; `Some` is the one-shot resolution (a dropped
    /// reply channel classifies as `Failed`, as in
    /// [`outcome`](Self::outcome)). Polling again after `Some` yields
    /// `Failed` — the channel resolves exactly once.
    pub fn poll_timeout(&self, timeout: Duration) -> Option<InferOutcome> {
        match self.rx.recv_timeout(timeout) {
            Ok(reply) => Some(classify(reply)),
            Err(mpsc::RecvTimeoutError::Timeout) => None,
            Err(mpsc::RecvTimeoutError::Disconnected) => {
                Some(InferOutcome::Failed("server dropped request".to_string()))
            }
        }
    }

    /// Block for the output; shed and failed requests become errors
    /// (shed errors carry the [`DEADLINE_SHED`] marker).
    pub fn wait(self) -> Result<Vec<f32>> {
        match self.outcome()? {
            InferOutcome::Output(v) => Ok(v),
            InferOutcome::DeadlineShed => Err(anyhow::anyhow!(shed_error())),
            InferOutcome::Failed(e) => Err(anyhow::anyhow!(e)),
        }
    }

    /// Like [`wait`](Self::wait) with a wait bound.
    pub fn wait_timeout(self, timeout: Duration) -> Result<Vec<f32>> {
        match self.outcome_timeout(timeout)? {
            InferOutcome::Output(v) => Ok(v),
            InferOutcome::DeadlineShed => Err(anyhow::anyhow!(shed_error())),
            InferOutcome::Failed(e) => Err(anyhow::anyhow!(e)),
        }
    }

    /// Block until *any* of `tickets` resolves; the winner is removed
    /// from the vec and returned with the index it occupied. `None` iff
    /// the vec is empty. Resolution is a cooperative poll (reply
    /// channels have no native multiplexer), so ties break toward the
    /// lowest index — deterministic for tests.
    pub fn select(tickets: &mut Vec<Ticket>) -> Option<(usize, InferOutcome)> {
        if tickets.is_empty() {
            return None;
        }
        loop {
            for i in 0..tickets.len() {
                match tickets[i].rx.try_recv() {
                    Ok(reply) => {
                        tickets.remove(i);
                        return Some((i, classify(reply)));
                    }
                    Err(mpsc::TryRecvError::Disconnected) => {
                        tickets.remove(i);
                        return Some((
                            i,
                            InferOutcome::Failed("server dropped request".to_string()),
                        ));
                    }
                    Err(mpsc::TryRecvError::Empty) => {}
                }
            }
            std::thread::sleep(SELECT_POLL);
        }
    }

    /// Resolve every ticket, preserving submission order. Outcomes are
    /// collected with [`outcome`](Self::outcome) semantics: a dropped
    /// reply channel is `Failed`, never a panic or an `Err`, so the
    /// result always has exactly `tickets.len()` entries.
    pub fn join_all(tickets: Vec<Ticket>) -> Vec<InferOutcome> {
        tickets
            .into_iter()
            .map(|t| {
                t.outcome()
                    .unwrap_or_else(|e| InferOutcome::Failed(e.to_string()))
            })
            .collect()
    }

    /// Adapt the ticket to a [`std::future::Future`] resolving to its
    /// [`InferOutcome`]. The repo is executor-agnostic (no async
    /// runtime dependency), so the adapter parks a small named thread
    /// on the reply channel and wakes the registered waker on
    /// resolution — correct under any executor, sized for request
    /// counts (one thread per in-flight future), not for million-task
    /// fan-out. `Ticket` also implements [`std::future::IntoFuture`],
    /// so `rt.submit(req)?.await` works directly in async contexts.
    pub fn into_future(self) -> TicketFuture {
        let shared = Arc::new(Mutex::new(TicketFutureState {
            outcome: None,
            waker: None,
        }));
        let inner = Arc::clone(&shared);
        let rx = self.rx;
        std::thread::Builder::new()
            .name("nimble-ticket-future".to_string())
            .spawn(move || {
                let outcome = match rx.recv() {
                    Ok(reply) => classify(reply),
                    Err(_) => InferOutcome::Failed("server dropped request".to_string()),
                };
                let mut st = inner.lock().unwrap_or_else(|e| e.into_inner());
                st.outcome = Some(outcome);
                if let Some(w) = st.waker.take() {
                    w.wake();
                }
            })
            .expect("spawn ticket-future waiter thread");
        TicketFuture { shared }
    }
}

/// Poll cadence for [`Ticket::select`] between sweeps over the pending
/// reply channels.
const SELECT_POLL: Duration = Duration::from_micros(50);

struct TicketFutureState {
    outcome: Option<InferOutcome>,
    waker: Option<std::task::Waker>,
}

/// [`Future`](std::future::Future) adapter over a [`Ticket`]
/// ([`Ticket::into_future`] / `ticket.await`); resolves to the
/// ticket's [`InferOutcome`] exactly once.
pub struct TicketFuture {
    shared: Arc<Mutex<TicketFutureState>>,
}

impl std::future::Future for TicketFuture {
    type Output = InferOutcome;

    fn poll(
        self: std::pin::Pin<&mut Self>,
        cx: &mut std::task::Context<'_>,
    ) -> std::task::Poll<InferOutcome> {
        let mut st = self.shared.lock().unwrap_or_else(|e| e.into_inner());
        match st.outcome.take() {
            Some(out) => std::task::Poll::Ready(out),
            None => {
                st.waker = Some(cx.waker().clone());
                std::task::Poll::Pending
            }
        }
    }
}

impl std::future::IntoFuture for Ticket {
    type Output = InferOutcome;
    type IntoFuture = TicketFuture;

    fn into_future(self) -> TicketFuture {
        Ticket::into_future(self)
    }
}

/// What the engines execute: a zoo model / arbitrary graph builder on
/// the tape substrate, or the PJRT artifact registry (`xla` feature).
enum Source {
    Graph {
        label: String,
        build: Arc<dyn Fn(usize) -> OpGraph + Send + Sync>,
    },
    #[cfg(feature = "xla")]
    Artifacts(crate::coordinator::EngineConfig),
}

/// How the shared work-stealing pool is provided.
enum PoolSpec {
    Size(usize),
    Handle(SharedWorkerPool),
}

/// Fluent, typed composition of everything the old constructor matrix
/// spread over nine entry points. See the [module docs](self) for the
/// shape; every method is optional except a source
/// ([`model`](Self::model) / [`graph_fn`](Self::graph_fn) /
/// `artifacts`).
pub struct RuntimeBuilder {
    label: String,
    source: Option<Source>,
    buckets: Vec<usize>,
    lane: LaneConfig,
    worker_cap: Option<usize>,
    unshared_slots: bool,
    arena_pool: Option<ArenaPool>,
    shared_pool: Option<PoolSpec>,
    single_thread: bool,
    serial: bool,
    fault: Option<FaultPlan>,
    verify: VerifyMode,
}

impl Default for RuntimeBuilder {
    fn default() -> Self {
        RuntimeBuilder {
            label: "runtime".to_string(),
            source: None,
            buckets: vec![1, 8],
            lane: LaneConfig::default(),
            worker_cap: None,
            unshared_slots: false,
            arena_pool: None,
            shared_pool: None,
            single_thread: false,
            serial: false,
            fault: None,
            verify: VerifyMode::default(),
        }
    }
}

impl RuntimeBuilder {
    /// Serve a model-zoo network on the tape substrate.
    pub fn model(mut self, name: &str) -> Self {
        let owned = name.to_string();
        self.label = name.to_string();
        self.source = Some(Source::Graph {
            label: name.to_string(),
            build: Arc::new(move |b| models::build(&owned, b)),
        });
        self
    }

    /// Serve an arbitrary per-bucket operator-graph builder (the
    /// differential harness feeds seeded random cells through this).
    pub fn graph_fn(
        mut self,
        build: impl Fn(usize) -> OpGraph + Send + Sync + 'static,
    ) -> Self {
        self.source =
            Some(Source::Graph { label: self.label.clone(), build: Arc::new(build) });
        self
    }

    /// Serve the PJRT artifact registry (the paper's real-runtime path).
    #[cfg(feature = "xla")]
    pub fn artifacts(mut self, config: crate::coordinator::EngineConfig) -> Self {
        self.source = Some(Source::Artifacts(config));
        self
    }

    /// Label used in error messages (defaults to the model name).
    pub fn label(mut self, label: &str) -> Self {
        self.label = label.to_string();
        if let Some(Source::Graph { label: l, .. }) = &mut self.source {
            *l = label.to_string();
        }
        self
    }

    /// Compiled batch-size buckets (deduplicated, sorted). Default
    /// `[1, 8]`.
    pub fn buckets(mut self, buckets: &[usize]) -> Self {
        self.buckets = buckets.to_vec();
        self
    }

    /// Max time the oldest request may wait before a partial batch
    /// flushes ([`LaneConfig::max_wait`]).
    pub fn max_wait(mut self, max_wait: Duration) -> Self {
        self.lane.max_wait = max_wait;
        self
    }

    /// Replace the whole lane configuration (admission/lane caps,
    /// buffer pools, backlog valve, scaling) in one call.
    pub fn lane_config(mut self, config: LaneConfig) -> Self {
        self.lane = config;
        self
    }

    /// Per-lane job-queue capacity ([`LaneConfig::lane_cap`]).
    pub fn lane_cap(mut self, cap: usize) -> Self {
        self.lane.lane_cap = cap;
        self
    }

    /// Pooled padded-input buffers per lane
    /// ([`LaneConfig::buffers_per_lane`]).
    pub fn buffers_per_lane(mut self, n: usize) -> Self {
        self.lane.buffers_per_lane = n;
        self
    }

    /// Admission-queue capacity ([`LaneConfig::admission_cap`]).
    pub fn admission_cap(mut self, cap: usize) -> Self {
        self.lane.admission_cap = cap;
        self
    }

    /// Batcher-backlog valve ([`LaneConfig::backlog_cap`]).
    pub fn backlog_cap(mut self, cap: usize) -> Self {
        self.lane.backlog_cap = cap;
        self
    }

    /// Elastic lane scaling ([`LaneConfig::scale`]; default static).
    pub fn elastic(mut self, scale: ScaleOptions) -> Self {
        self.lane.scale = scale;
        self
    }

    /// Earliest-deadline-first scheduling ([`LaneConfig::edf`]; default
    /// **on**). When on, the batcher orders deadline-carrying requests
    /// ahead of deadline-less ones (earliest first, FIFO among equal or
    /// absent deadlines), the dispatcher sheds doomed budgets at
    /// admission from its per-bucket queue-delay estimate, and expired
    /// batcher/staged work is shed the moment it comes due.
    /// `edf(false)` restores the strict-FIFO, pop-time-shed-only
    /// discipline (the PR-5 behavior) — useful as a bench baseline.
    /// Deadline-free workloads behave identically either way.
    pub fn edf(mut self, on: bool) -> Self {
        self.lane.edf = on;
        self
    }

    /// SLO target shed rate ([`LaneConfig::slo`]): a periodic control
    /// pass in the dispatcher compares the live shed rate (feedback)
    /// and a queueing-estimate prediction over staged deadlines
    /// (feed-forward) against `target_shed_rate` in `[0, 1]`, and
    /// force-spawns lanes — up to
    /// [`ScaleOptions::max_lanes_per_bucket`] — while either exceeds
    /// it. Compose with [`elastic`](Self::elastic) to raise that
    /// ceiling; requires the lane topology.
    pub fn slo(mut self, target_shed_rate: f64) -> Self {
        self.lane.slo = Some(target_shed_rate);
        self
    }

    /// Per-context worker cap (the executor's capped work-sharing
    /// pool). Ignored when a shared pool is set.
    pub fn worker_cap(mut self, cap: usize) -> Self {
        self.worker_cap = Some(cap);
        self
    }

    /// Per-slot-buffer layout instead of the packed stream-aware arena
    /// (the differential harness's baseline engine).
    pub fn unshared_slots(mut self) -> Self {
        self.unshared_slots = true;
        self
    }

    /// Draw every replay context's arena from this shared pool, so
    /// rebuilt/respawned lanes recycle their reservations.
    pub fn arena_pool(mut self, pool: ArenaPool) -> Self {
        self.arena_pool = Some(pool);
        self
    }

    /// Lease replay workers from ONE process-wide work-stealing pool of
    /// `n_workers` threads instead of spawning per-context workers —
    /// however many lanes scale up, total replay threads stay capped.
    pub fn shared_pool(mut self, n_workers: usize) -> Self {
        self.shared_pool = Some(PoolSpec::Size(n_workers));
        self
    }

    /// Like [`shared_pool`](Self::shared_pool) with a caller-owned pool
    /// (share one pool across several runtimes, or keep a handle for
    /// stats).
    pub fn shared_pool_handle(mut self, pool: SharedWorkerPool) -> Self {
        self.shared_pool = Some(PoolSpec::Handle(pool));
        self
    }

    /// Single-engine-thread topology (the measured PR-1 baseline)
    /// instead of per-bucket lanes. Pre-formed batch requests require
    /// the lane topology; of the lane knobs only
    /// [`max_wait`](Self::max_wait) applies here, and combining with
    /// [`elastic`](Self::elastic) is rejected at build.
    pub fn single_thread(mut self) -> Self {
        self.single_thread = true;
        self
    }

    /// Serial-oracle engines: replay on the submitting thread in merged
    /// submission order (the differential oracle the parallel paths are
    /// checked against bit-for-bit).
    pub fn serial_oracle(mut self) -> Self {
        self.serial = true;
        self
    }

    /// Seeded, deterministic chaos: each lane's engine is wrapped in
    /// [`ChaosEngine`] with a per-bucket derivation of `plan`, and its
    /// replay executor injects `plan`'s replay-level faults (worker
    /// deaths, arena exhaustion, poisoning join timeouts). Lane
    /// supervision retries or replaces per
    /// [`retry_policy`](Self::retry_policy); the DES predicts the
    /// resulting counts ([`crate::sim::simulate_faults`]). Requires the
    /// lane topology (the builder default).
    pub fn fault_plan(mut self, plan: FaultPlan) -> Self {
        self.fault = Some(plan);
        self
    }

    /// Bounded, deadline-aware retry for transient lane failures
    /// ([`LaneConfig::retry`]): a failed job is re-run up to
    /// `max_retries` times (after `backoff`) as long as some of its
    /// requests can still meet their deadlines, then resolved as
    /// [`InferOutcome::Failed`].
    pub fn retry_policy(mut self, retry: RetryPolicy) -> Self {
        self.lane.retry = retry;
        self
    }

    /// Attach a flight recorder ([`Telemetry`]): replay-op spans,
    /// request-lifecycle events (admit → stage → pop/shed → reply) and
    /// lane/pool events are recorded into its lock-free rings, and its
    /// Prometheus metrics are bumped. Off by default — without this
    /// call the runtime records nothing and pays nothing. The same
    /// recorder is readable live through the handle
    /// ([`RuntimeHandle::trace_json`] / [`RuntimeHandle::metrics_text`])
    /// or directly via the `Telemetry` clone the caller keeps.
    pub fn telemetry(mut self, telemetry: Telemetry) -> Self {
        self.lane.telemetry = Some(telemetry);
        self
    }

    /// Static plan verification policy ([`crate::aot::verify`]): every
    /// bucket's compiled tape and arena layout are certified at build
    /// time — happens-before races, orphan waits, wait/record cycles,
    /// arena aliasing, well-formedness. `Strict` makes any diagnostic a
    /// build error (with the rendered report in the message), `Warn`
    /// prints it to stderr and builds anyway, `Off` skips the pass.
    /// Default: `Warn` in debug builds, `Off` in release. Verification
    /// is build-time only — the replay hot path is identical under
    /// every mode. Applies to the tape engines; the PJRT artifact path
    /// has no replay tape to certify and ignores it.
    pub fn verify(mut self, mode: VerifyMode) -> Self {
        self.verify = mode;
        self
    }

    fn engine_opts(&self) -> Result<TapeEngineOptions> {
        let shared_pool = match &self.shared_pool {
            None => None,
            Some(PoolSpec::Handle(p)) => Some(p.clone()),
            Some(PoolSpec::Size(n)) => {
                anyhow::ensure!(*n >= 1, "shared_pool needs at least one worker");
                Some(SharedWorkerPool::new(*n))
            }
        };
        Ok(TapeEngineOptions {
            worker_cap: self.worker_cap,
            unshared_slots: self.unshared_slots,
            arena_pool: self.arena_pool.clone(),
            shared_pool,
            fault: None,
            telemetry: self.lane.telemetry.clone(),
            verify: self.verify,
        })
    }

    /// Build the runtime: per-bucket serving lanes by default, the
    /// single-engine-thread topology under
    /// [`single_thread`](Self::single_thread).
    ///
    /// Incompatible knob combinations are rejected, not silently
    /// dropped: elastic scaling requires the lane topology, and the
    /// tape-engine knobs (worker caps, pools, serial oracle) do not
    /// apply to the PJRT artifact engines.
    pub fn build(self) -> Result<Runtime> {
        anyhow::ensure!(
            !(self.single_thread && self.lane.scale.max_lanes_per_bucket != 1),
            "elastic scaling needs the lane topology: drop single_thread() or elastic()"
        );
        anyhow::ensure!(
            !(self.single_thread && self.fault.is_some()),
            "fault_plan() needs the lane topology (supervision and retry live in the \
             lanes): drop single_thread() or fault_plan()"
        );
        anyhow::ensure!(
            !(self.single_thread && self.lane.slo.is_some()),
            "slo() needs the lane topology (the controller scales lanes): drop \
             single_thread() or slo()"
        );
        if let Some(target) = self.lane.slo {
            anyhow::ensure!(
                (0.0..=1.0).contains(&target),
                "slo() target shed rate must be in [0, 1], got {target}"
            );
        }
        #[cfg(feature = "xla")]
        if matches!(&self.source, Some(Source::Artifacts(_))) {
            anyhow::ensure!(
                self.worker_cap.is_none()
                    && !self.unshared_slots
                    && self.arena_pool.is_none()
                    && self.shared_pool.is_none()
                    && !self.serial
                    && self.fault.is_none(),
                "worker_cap/unshared_slots/arena_pool/shared_pool/serial_oracle/fault_plan \
                 are tape-engine knobs; the PJRT artifact engines do not take them"
            );
        }
        let opts = self.engine_opts()?;
        let source = self
            .source
            .context("RuntimeBuilder needs a source: model(), graph_fn(), or artifacts()")?;
        let serial = self.serial;
        let telemetry = self.lane.telemetry.clone();
        match source {
            Source::Graph { label, build } => {
                if self.single_thread {
                    let buckets = self.buckets.clone();
                    let factory = move || {
                        let e =
                            TapeEngine::build_opts(&label, &buckets, opts, |b| (*build)(b))?;
                        Ok(if serial { e.serial() } else { e })
                    };
                    NimbleServer::spawn(factory, self.lane.max_wait)
                        .map(|s| Runtime::from_single(s, telemetry))
                } else if let Some(plan) = self.fault.clone() {
                    // Chaos topology: the executor gets a per-bucket
                    // derivation of the plan for replay-level faults,
                    // and the engine is wrapped in ChaosEngine for
                    // call-level errors/panics. Both derivations are
                    // pure functions of (plan.seed, bucket), so a
                    // respawned lane replays the same fault schedule.
                    let factory = move |bucket: usize| {
                        let mut opts = opts.clone();
                        opts.fault =
                            Some(plan.derive(bucket as u64 ^ FaultPlan::REPLAY_SALT));
                        let e = TapeEngine::build_opts(&label, &[bucket], opts, |b| {
                            (*build)(b)
                        })?;
                        let e = if serial { e.serial() } else { e };
                        Ok(ChaosEngine::new(e, plan.derive(bucket as u64)))
                    };
                    LaneServer::start_inner(&self.buckets, factory, self.lane)
                        .map(|s| Runtime::from_lanes(s, telemetry))
                } else {
                    let factory = move |bucket: usize| {
                        let e = TapeEngine::build_opts(
                            &label,
                            &[bucket],
                            opts.clone(),
                            |b| (*build)(b),
                        )?;
                        Ok(if serial { e.serial() } else { e })
                    };
                    LaneServer::start_inner(&self.buckets, factory, self.lane)
                        .map(|s| Runtime::from_lanes(s, telemetry))
                }
            }
            #[cfg(feature = "xla")]
            Source::Artifacts(config) => {
                use crate::coordinator::NimbleEngine;
                if self.single_thread {
                    NimbleServer::spawn(move || NimbleEngine::build(config), self.lane.max_wait)
                        .map(|s| Runtime::from_single(s, telemetry))
                } else {
                    let factory =
                        move |bucket: usize| NimbleEngine::build_for(config.clone(), &[bucket]);
                    LaneServer::start_inner(&self.buckets, factory, self.lane)
                        .map(|s| Runtime::from_lanes(s, telemetry))
                }
            }
        }
    }

    /// Build a bare [`TapeEngine`] (all buckets in one engine, no
    /// server) with this builder's engine knobs — the direct-replay /
    /// differential-oracle path (compose with
    /// [`serial_oracle`](Self::serial_oracle)).
    pub fn build_engine(self) -> Result<TapeEngine> {
        anyhow::ensure!(
            self.fault.is_none(),
            "fault_plan() applies to served lanes; wrap the bare engine in \
             nimble::fault::ChaosEngine instead"
        );
        let opts = self.engine_opts()?;
        let source = self
            .source
            .context("RuntimeBuilder needs a source: model() or graph_fn()")?;
        match source {
            Source::Graph { label, build } => {
                let e = TapeEngine::build_opts(&label, &self.buckets, opts, |b| (*build)(b))?;
                Ok(if self.serial { e.serial() } else { e })
            }
            #[cfg(feature = "xla")]
            Source::Artifacts(_) => anyhow::bail!(
                "build_engine() is tape-backed; the PJRT artifact path serves via build()"
            ),
        }
    }

    /// Build serving lanes over a custom engine factory (fault
    /// injection, engine wrappers): the factory runs once per lane *on
    /// that lane's thread* and must return an engine serving at least
    /// that bucket. Engine knobs ([`worker_cap`](Self::worker_cap),
    /// pools, …) are the factory's business here; lane and scaling
    /// knobs still apply.
    pub fn build_with_factory<E, F>(self, factory: F) -> Result<Runtime>
    where
        E: InferEngine + 'static,
        F: Fn(usize) -> Result<E> + Send + Sync + 'static,
    {
        anyhow::ensure!(
            !self.single_thread,
            "build_with_factory uses the lane topology (per-bucket factories)"
        );
        anyhow::ensure!(
            self.fault.is_none(),
            "build_with_factory owns engine construction; wrap its engines in \
             nimble::fault::ChaosEngine instead of fault_plan()"
        );
        if let Some(target) = self.lane.slo {
            anyhow::ensure!(
                (0.0..=1.0).contains(&target),
                "slo() target shed rate must be in [0, 1], got {target}"
            );
        }
        let telemetry = self.lane.telemetry.clone();
        LaneServer::start_inner(&self.buckets, factory, self.lane)
            .map(|s| Runtime::from_lanes(s, telemetry))
    }
}

enum ServerInner {
    /// The single topology has no supervisor, so the runtime owns its
    /// health flag directly (only `Healthy`/`Draining` apply).
    Single(NimbleServer, Arc<HealthState>),
    Lanes(LaneServer),
}

/// One handle over the whole serving stack — subsumes the deprecated
/// `NimbleServer` / `LaneServer` pair. Built by [`Runtime::builder`];
/// submit with [`infer`](Self::infer) / [`submit`](Self::submit), clone
/// [`handle`](Self::handle)s for client threads, stop with
/// [`shutdown`](Self::shutdown).
pub struct Runtime {
    inner: ServerInner,
    /// Built once so the hot `infer`/`submit` path never re-clones the
    /// client (its batch-size vector in particular).
    handle: RuntimeHandle,
}

impl Runtime {
    fn from_single(server: NimbleServer, telemetry: Option<Telemetry>) -> Runtime {
        let health = HealthState::new();
        let handle = RuntimeHandle {
            inner: HandleInner::Single(server.client(), Arc::clone(&health)),
            telemetry,
            replica: None,
        };
        Runtime { inner: ServerInner::Single(server, health), handle }
    }

    fn from_lanes(server: LaneServer, telemetry: Option<Telemetry>) -> Runtime {
        let handle = RuntimeHandle {
            inner: HandleInner::Lanes(server.client()),
            telemetry,
            replica: None,
        };
        Runtime { inner: ServerInner::Lanes(server), handle }
    }

    pub fn builder() -> RuntimeBuilder {
        RuntimeBuilder::default()
    }

    /// Flattened input length of one example.
    pub fn example_len(&self) -> usize {
        match &self.inner {
            ServerInner::Single(s, _) => s.example_len(),
            ServerInner::Lanes(s) => s.example_len(),
        }
    }

    /// Flattened output length of one example.
    pub fn output_len(&self) -> usize {
        match &self.inner {
            ServerInner::Single(s, _) => s.output_len(),
            ServerInner::Lanes(s) => s.output_len(),
        }
    }

    /// Compiled batch buckets, ascending.
    pub fn batch_sizes(&self) -> &[usize] {
        match &self.inner {
            ServerInner::Single(s, _) => s.batch_sizes(),
            ServerInner::Lanes(s) => s.batch_sizes(),
        }
    }

    /// Liveness probe: `Healthy`, `Degraded { buckets }` (a bucket lost
    /// its lanes for good and fails fast), or `Draining` once
    /// [`drain`](Self::drain)/[`shutdown`](Self::shutdown) began. Also
    /// available on every [`RuntimeHandle`].
    pub fn health(&self) -> Health {
        match &self.inner {
            ServerInner::Single(_, h) => h.snapshot(),
            ServerInner::Lanes(s) => s.health(),
        }
    }

    /// A cloneable, `Send` request handle for client threads.
    pub fn handle(&self) -> RuntimeHandle {
        self.handle.clone()
    }

    /// The attached flight recorder, if any ([`RuntimeHandle::telemetry`]).
    pub fn telemetry(&self) -> Option<&Telemetry> {
        self.handle.telemetry()
    }

    /// Chrome-trace JSON so far ([`RuntimeHandle::trace_json`]).
    pub fn trace_json(&self) -> Option<String> {
        self.handle.trace_json()
    }

    /// Prometheus metrics text ([`RuntimeHandle::metrics_text`]).
    pub fn metrics_text(&self) -> Option<String> {
        self.handle.metrics_text()
    }

    /// Blocking inference: submit and wait for the output.
    pub fn infer(&self, req: InferRequest) -> Result<Vec<f32>> {
        self.handle.infer(req)
    }

    /// Submit a request; returns a waitable [`Ticket`].
    pub fn submit(&self, req: InferRequest) -> Result<Ticket> {
        self.handle.submit(req)
    }

    /// Stop the runtime: flush everything already admitted, join every
    /// engine/lane thread, and collect the serving report.
    pub fn shutdown(self) -> Result<ServingReport> {
        match self.inner {
            ServerInner::Single(s, health) => {
                health.set_draining();
                s.shutdown()
            }
            ServerInner::Lanes(s) => s.shutdown(),
        }
    }

    /// Gracefully drain the runtime. Admission flips to reject-new
    /// first (retained handles see submit errors and
    /// [`Health::Draining`]), then everything already admitted —
    /// staged partial batches, queued lane jobs, retry backlog — is
    /// flushed or resolved, every lane/engine thread is joined, and the
    /// final [`ServingReport`] is returned. After a drain every ticket
    /// ever issued has resolved: output, deadline-shed, or failed.
    ///
    /// `drain` and [`shutdown`](Self::shutdown) are the same operation;
    /// this is the serving-facing name.
    pub fn drain(self) -> Result<ServingReport> {
        self.shutdown()
    }
}

#[derive(Clone)]
enum HandleInner {
    Single(ServerClient, Arc<HealthState>),
    Lanes(LaneClient),
}

/// Cloneable, `Send` request handle to a [`Runtime`] — one per client
/// thread. Dropping handles does not stop the runtime.
#[derive(Clone)]
pub struct RuntimeHandle {
    inner: HandleInner,
    /// The flight recorder attached at build
    /// ([`RuntimeBuilder::telemetry`]), if any.
    telemetry: Option<Telemetry>,
    /// Replica index stamped on every Prometheus sample
    /// ([`with_replica_label`](Self::with_replica_label)); `None` keeps
    /// the single-runtime exposition unchanged.
    replica: Option<u32>,
}

impl RuntimeHandle {
    pub fn example_len(&self) -> usize {
        match &self.inner {
            HandleInner::Single(c, _) => c.example_len(),
            HandleInner::Lanes(c) => c.example_len(),
        }
    }

    pub fn output_len(&self) -> usize {
        match &self.inner {
            HandleInner::Single(c, _) => c.output_len(),
            HandleInner::Lanes(c) => c.output_len(),
        }
    }

    /// Compiled batch buckets, ascending.
    pub fn batch_sizes(&self) -> &[usize] {
        match &self.inner {
            HandleInner::Single(c, _) => c.batch_sizes(),
            HandleInner::Lanes(c) => c.batch_sizes(),
        }
    }

    /// Current [`Health`] of the runtime this handle points at (valid
    /// even after the runtime was drained: it reports `Draining`).
    pub fn health(&self) -> Health {
        match &self.inner {
            HandleInner::Single(_, h) => h.snapshot(),
            HandleInner::Lanes(c) => c.health(),
        }
    }

    /// The attached flight recorder, if any
    /// ([`RuntimeBuilder::telemetry`]).
    pub fn telemetry(&self) -> Option<&Telemetry> {
        self.telemetry.as_ref()
    }

    /// Chrome-trace JSON of everything recorded so far (replay-op
    /// slices + lifecycle instants; drains the rings). Same slice
    /// schema as the DES export ([`crate::sim::trace::to_chrome_trace`])
    /// so measured and predicted timelines overlay and diff
    /// ([`crate::telemetry::diff_traces`]). `None` without telemetry.
    pub fn trace_json(&self) -> Option<String> {
        self.telemetry.as_ref().map(Telemetry::chrome_trace)
    }

    /// Prometheus text exposition of the runtime's metrics (counters,
    /// the live-lanes gauge, latency/op-span histograms). `None`
    /// without telemetry. With a
    /// [`with_replica_label`](Self::with_replica_label) index set,
    /// every sample carries a `replica="<n>"` label so expositions
    /// from multiple runtimes in one process merge without series
    /// collisions ([`crate::cluster::Cluster::metrics_text`]).
    pub fn metrics_text(&self) -> Option<String> {
        let t = self.telemetry.as_ref()?;
        Some(match self.replica {
            Some(n) => t.metrics_text_labeled(&format!("replica=\"{n}\"")),
            None => t.metrics_text(),
        })
    }

    /// Stamp a replica index onto this handle: every Prometheus sample
    /// from [`metrics_text`](Self::metrics_text) gains a
    /// `replica="<n>"` label. Used by the cluster layer; harmless (and
    /// available) on standalone runtimes running several to a process.
    pub fn with_replica_label(mut self, replica: u32) -> RuntimeHandle {
        self.replica = Some(replica);
        self
    }

    /// Requests admitted but not yet pulled by the dispatcher — one of
    /// the router's pressure inputs. Always `0` on the single-thread
    /// topology (admission is synchronous there).
    pub fn queue_depth(&self) -> usize {
        match &self.inner {
            HandleInner::Single(..) => 0,
            HandleInner::Lanes(c) => c.queue_depth(),
        }
    }

    /// Blocking inference: submit and wait for the output (shed and
    /// failed requests become errors).
    pub fn infer(&self, req: InferRequest) -> Result<Vec<f32>> {
        self.submit(req)?.wait()
    }

    /// Submit a request; returns a waitable [`Ticket`]. Validates the
    /// input length and any bucket hint against the compiled buckets —
    /// identically on both topologies.
    pub fn submit(&self, req: InferRequest) -> Result<Ticket> {
        let InferRequest { input, opts, batch } = req;
        let invalid = |msg: String| anyhow::Error::new(ValidationError(msg));
        if let Some(hint) = opts.bucket_hint {
            if !self.batch_sizes().contains(&hint) {
                return Err(invalid(format!("no compiled bucket {hint} to hint")));
            }
        }
        if let Some(bucket) = batch {
            if !self.batch_sizes().contains(&bucket) {
                return Err(invalid(format!("no compiled bucket {bucket}")));
            }
            if input.len() != bucket * self.example_len() {
                return Err(invalid(format!(
                    "bad batch length {} != {}",
                    input.len(),
                    bucket * self.example_len()
                )));
            }
            if let Some(hint) = opts.bucket_hint {
                if hint != bucket {
                    return Err(invalid(format!(
                        "bucket hint {hint} contradicts the pre-formed batch bucket {bucket}"
                    )));
                }
            }
            match &self.inner {
                HandleInner::Lanes(c) => {
                    c.submit_batch_raw(bucket, input, opts.deadline).map(Ticket::new)
                }
                HandleInner::Single(..) => anyhow::bail!(
                    "pre-formed batch requests need the lane topology \
                     (the builder default; this runtime is single_thread)"
                ),
            }
        } else {
            if input.len() != self.example_len() {
                return Err(invalid(format!(
                    "bad input length {} != {}",
                    input.len(),
                    self.example_len()
                )));
            }
            match &self.inner {
                HandleInner::Single(c, _) => {
                    c.submit_raw(input, opts.bucket_hint, opts.deadline).map(Ticket::new)
                }
                HandleInner::Lanes(c) => {
                    c.submit_raw(input, opts.bucket_hint, opts.deadline).map(Ticket::new)
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Pcg32;

    fn inputs(n: usize, len: usize, seed: u64) -> Vec<Vec<f32>> {
        let mut rng = Pcg32::new(seed);
        (0..n).map(|_| (0..len).map(|_| rng.gen_f32_range(-1.0, 1.0)).collect()).collect()
    }

    #[test]
    fn builder_serves_on_both_topologies_bit_identically() {
        let lanes = Runtime::builder().model("mini_inception").build().unwrap();
        let single =
            Runtime::builder().model("mini_inception").single_thread().build().unwrap();
        assert_eq!(lanes.batch_sizes(), &[1, 8], "default buckets");
        assert_eq!(lanes.batch_sizes(), single.batch_sizes());
        let len = lanes.example_len();
        assert_eq!(len, single.example_len());
        for input in inputs(3, len, 11) {
            let a = lanes.infer(InferRequest::new(input.clone())).unwrap();
            let b = single.infer(InferRequest::new(input)).unwrap();
            assert_eq!(a, b, "topology must not leak into results");
        }
        let _ = lanes.shutdown().unwrap();
        let _ = single.shutdown().unwrap();
    }

    #[test]
    fn batch_requests_route_to_their_bucket_and_match_the_engine() {
        let rt = Runtime::builder().model("mini_inception").buckets(&[1, 4]).build().unwrap();
        let len = rt.example_len();
        let batch: Vec<f32> = inputs(4, len, 21).concat();
        let got = rt.submit(InferRequest::batch(4, batch.clone())).unwrap().wait().unwrap();
        let mut direct = Runtime::builder()
            .model("mini_inception")
            .buckets(&[4])
            .build_engine()
            .unwrap();
        assert_eq!(got, direct.infer_batch(4, &batch).unwrap());
        // Validation: unknown bucket, bad length, contradictory hint.
        assert!(rt.submit(InferRequest::batch(3, vec![0.0; 3 * len])).is_err());
        assert!(rt.submit(InferRequest::batch(4, vec![0.0; len])).is_err());
        assert!(rt.submit(InferRequest::batch(4, batch.clone()).hint(1)).is_err());
        let report = rt.shutdown().unwrap();
        assert_eq!(report.n_batches, 1);
    }

    #[test]
    fn batch_requests_require_the_lane_topology() {
        let rt = Runtime::builder()
            .model("mini_inception")
            .buckets(&[1, 4])
            .single_thread()
            .build()
            .unwrap();
        let err = rt.submit(InferRequest::batch(4, vec![0.0; 4 * rt.example_len()]));
        assert!(err.is_err());
        let _ = rt.shutdown().unwrap();
    }

    #[test]
    fn hints_are_validated_identically_on_both_topologies() {
        for single in [false, true] {
            let b = Runtime::builder().model("mini_inception").buckets(&[1, 8]);
            let rt = if single { b.single_thread() } else { b }.build().unwrap();
            let len = rt.example_len();
            let ok = rt.infer(InferRequest::new(vec![0.1; len]).hint(8));
            assert!(ok.is_ok(), "valid hint must serve (single={single})");
            let bad = rt.submit(InferRequest::new(vec![0.1; len]).hint(3));
            assert!(bad.is_err(), "unknown hint must be rejected (single={single})");
            let short = rt.submit(InferRequest::new(vec![0.1; len - 1]));
            assert!(short.is_err(), "bad length must be rejected (single={single})");
            let _ = rt.shutdown().unwrap();
        }
    }

    #[test]
    fn expired_deadlines_shed_and_are_accounted() {
        for single in [false, true] {
            let b = Runtime::builder()
                .model("mini_inception")
                .buckets(&[1])
                .max_wait(Duration::from_micros(200));
            let rt = if single { b.single_thread() } else { b }.build().unwrap();
            let len = rt.example_len();
            // Already expired at submit: the engine must never run it.
            let shed = rt
                .submit(InferRequest::new(vec![0.2; len]).deadline(Instant::now()))
                .unwrap();
            assert_eq!(shed.outcome().unwrap(), InferOutcome::DeadlineShed);
            // A roomy deadline completes normally.
            let ok = rt
                .submit(InferRequest::new(vec![0.2; len]).deadline_in(Duration::from_secs(60)))
                .unwrap();
            assert!(matches!(ok.outcome().unwrap(), InferOutcome::Output(_)));
            let report = rt.shutdown().unwrap();
            assert_eq!(report.deadline_shed, 1, "single={single}");
            assert_eq!(report.n_requests, 1, "completed excludes shed (single={single})");
        }
    }

    #[test]
    fn wait_surfaces_shed_as_a_marked_error() {
        let (tx, rx) = mpsc::channel();
        tx.send(Err(shed_error())).unwrap();
        let err = Ticket::new(rx).wait().unwrap_err();
        assert!(format!("{err:#}").starts_with(DEADLINE_SHED));
        let (tx, rx) = mpsc::channel();
        tx.send(Err("engine exploded".to_string())).unwrap();
        assert_eq!(
            Ticket::new(rx).outcome().unwrap(),
            InferOutcome::Failed("engine exploded".to_string())
        );
    }

    #[test]
    fn builder_requires_a_source() {
        assert!(Runtime::builder().build().is_err());
        assert!(Runtime::builder().buckets(&[1]).build_engine().is_err());
    }

    #[test]
    fn dropped_reply_channels_resolve_tickets_as_failed() {
        let failed = InferOutcome::Failed("server dropped request".to_string());
        let (tx, rx) = mpsc::channel::<Result<Vec<f32>, String>>();
        drop(tx);
        assert_eq!(Ticket::new(rx).outcome().unwrap(), failed);
        let (tx, rx) = mpsc::channel::<Result<Vec<f32>, String>>();
        drop(tx);
        assert_eq!(
            Ticket::new(rx).outcome_timeout(Duration::from_millis(50)).unwrap(),
            failed
        );
        // A still-pending (not dropped) channel times out as an error,
        // distinct from resolution.
        let (_tx, rx) = mpsc::channel::<Result<Vec<f32>, String>>();
        assert!(Ticket::new(rx).outcome_timeout(Duration::from_millis(10)).is_err());
    }

    #[test]
    fn drain_flushes_admitted_work_then_rejects_new_submissions() {
        let rt = Runtime::builder()
            .model("mini_inception")
            .buckets(&[1, 4])
            .max_wait(Duration::from_micros(200))
            .build()
            .unwrap();
        assert_eq!(rt.health(), Health::Healthy);
        let len = rt.example_len();
        let tickets: Vec<Ticket> = inputs(6, len, 31)
            .into_iter()
            .map(|i| rt.submit(InferRequest::new(i)).unwrap())
            .collect();
        let handle = rt.handle();
        let report = rt.drain().unwrap();
        // Everything admitted before the drain was served, not dropped.
        for t in tickets {
            assert!(matches!(t.outcome().unwrap(), InferOutcome::Output(_)));
        }
        assert_eq!(report.n_requests, 6);
        assert_eq!(report.failed, 0);
        assert_eq!(report.deadline_shed, 0);
        // The drained runtime rejects new work and reports Draining on
        // retained handles.
        assert_eq!(handle.health(), Health::Draining);
        assert!(handle.submit(InferRequest::new(vec![0.0; len])).is_err());
    }

    #[test]
    fn single_topology_drain_reports_draining_via_the_handle() {
        let rt = Runtime::builder()
            .model("mini_inception")
            .buckets(&[1])
            .single_thread()
            .build()
            .unwrap();
        let handle = rt.handle();
        assert_eq!(handle.health(), Health::Healthy);
        let _ = rt.drain().unwrap();
        assert_eq!(handle.health(), Health::Draining);
    }

    #[test]
    fn slo_knob_is_validated_and_requires_the_lane_topology() {
        let err = Runtime::builder()
            .model("mini_inception")
            .single_thread()
            .slo(0.05)
            .build();
        assert!(err.is_err(), "slo() needs the lane controller");
        let err = Runtime::builder().model("mini_inception").slo(1.5).build();
        assert!(err.is_err(), "target shed rate outside [0, 1]");
        let rt = Runtime::builder()
            .model("mini_inception")
            .buckets(&[1])
            .slo(0.05)
            .build()
            .unwrap();
        let len = rt.example_len();
        let out = rt.infer(InferRequest::new(vec![0.1; len])).unwrap();
        assert_eq!(out.len(), rt.output_len());
        let _ = rt.shutdown().unwrap();
    }

    #[test]
    fn edf_off_restores_fifo_and_still_sheds_at_pop() {
        let rt = Runtime::builder()
            .model("mini_inception")
            .buckets(&[1])
            .max_wait(Duration::from_micros(200))
            .edf(false)
            .build()
            .unwrap();
        let len = rt.example_len();
        let shed = rt
            .submit(InferRequest::new(vec![0.2; len]).deadline(Instant::now()))
            .unwrap();
        assert_eq!(shed.outcome().unwrap(), InferOutcome::DeadlineShed);
        let ok = rt
            .submit(InferRequest::new(vec![0.2; len]).deadline_in(Duration::from_secs(60)))
            .unwrap();
        assert!(matches!(ok.outcome().unwrap(), InferOutcome::Output(_)));
        let report = rt.shutdown().unwrap();
        assert_eq!(report.deadline_shed, 1);
        assert_eq!(report.admission_shed, 0, "no admission estimate under edf(false)");
        assert_eq!(report.n_requests, 1);
    }

    #[test]
    fn fault_plan_is_rejected_off_the_lane_topology() {
        let err = Runtime::builder()
            .model("mini_inception")
            .single_thread()
            .fault_plan(FaultPlan::seeded(7))
            .build();
        assert!(err.is_err());
        let err = Runtime::builder()
            .model("mini_inception")
            .fault_plan(FaultPlan::seeded(7))
            .build_engine();
        assert!(err.is_err());
    }

    #[test]
    fn chaos_engine_faults_surface_as_failed_and_are_counted() {
        // Every engine call errors and no retries are allowed, so the
        // one request must fail with the injected marker and be counted
        // in the report without inflating n_requests.
        let plan = FaultPlan { engine_error: 1.0, ..FaultPlan::seeded(3) };
        let rt = Runtime::builder()
            .model("mini_inception")
            .buckets(&[1])
            .max_wait(Duration::from_micros(200))
            .fault_plan(plan)
            .retry_policy(RetryPolicy { max_retries: 0, backoff: Duration::ZERO })
            .build()
            .unwrap();
        let len = rt.example_len();
        let out =
            rt.submit(InferRequest::new(vec![0.3; len])).unwrap().outcome().unwrap();
        match out {
            InferOutcome::Failed(msg) => {
                assert!(msg.contains(crate::fault::INJECTED), "got: {msg}")
            }
            other => panic!("expected Failed, got {other:?}"),
        }
        let report = rt.shutdown().unwrap();
        assert_eq!(report.failed, 1);
        assert_eq!(report.n_requests, 0);
    }

    #[test]
    fn select_returns_the_first_resolved_ticket_and_removes_it() {
        let (tx0, rx0) = mpsc::channel::<Result<Vec<f32>, String>>();
        let (tx1, rx1) = mpsc::channel::<Result<Vec<f32>, String>>();
        let mut tickets = vec![Ticket::new(rx0), Ticket::new(rx1)];
        tx1.send(Ok(vec![2.0])).unwrap();
        let (idx, out) = Ticket::select(&mut tickets).unwrap();
        assert_eq!(idx, 1);
        assert_eq!(out, InferOutcome::Output(vec![2.0]));
        assert_eq!(tickets.len(), 1);
        // The remaining ticket still resolves; a dropped sender counts
        // as Failed, same as outcome().
        drop(tx0);
        let (idx, out) = Ticket::select(&mut tickets).unwrap();
        assert_eq!(idx, 0);
        assert_eq!(out, InferOutcome::Failed("server dropped request".to_string()));
        assert!(Ticket::select(&mut tickets).is_none());
    }

    #[test]
    fn join_all_preserves_submission_order_across_outcome_kinds() {
        let (tx0, rx0) = mpsc::channel();
        let (tx1, rx1) = mpsc::channel();
        let (tx2, rx2) = mpsc::channel::<Result<Vec<f32>, String>>();
        tx0.send(Ok(vec![1.0])).unwrap();
        tx1.send(Err(shed_error())).unwrap();
        drop(tx2);
        let outs =
            Ticket::join_all(vec![Ticket::new(rx0), Ticket::new(rx1), Ticket::new(rx2)]);
        assert_eq!(
            outs,
            vec![
                InferOutcome::Output(vec![1.0]),
                InferOutcome::DeadlineShed,
                InferOutcome::Failed("server dropped request".to_string()),
            ]
        );
    }

    /// Minimal executor for [`TicketFuture`]: park the test thread,
    /// unpark on wake. Exercises the real waker path (the resolver
    /// thread must wake a *registered* waker, not rely on polling).
    fn block_on<F: std::future::Future>(fut: F) -> F::Output {
        struct ThreadWaker(std::thread::Thread);
        impl std::task::Wake for ThreadWaker {
            fn wake(self: Arc<Self>) {
                self.0.unpark();
            }
        }
        let waker = std::task::Waker::from(Arc::new(ThreadWaker(std::thread::current())));
        let mut cx = std::task::Context::from_waker(&waker);
        let mut fut = std::pin::pin!(fut);
        loop {
            match fut.as_mut().poll(&mut cx) {
                std::task::Poll::Ready(out) => return out,
                std::task::Poll::Pending => std::thread::park(),
            }
        }
    }

    #[test]
    fn ticket_future_resolves_through_a_registered_waker() {
        let (tx, rx) = mpsc::channel();
        let fut = Ticket::new(rx).into_future();
        // Resolve only after the future is in flight, from another
        // thread, so Ready must come via wake(), not the first poll.
        let sender = std::thread::spawn(move || {
            std::thread::sleep(Duration::from_millis(20));
            tx.send(Ok(vec![4.0])).unwrap();
        });
        assert_eq!(block_on(fut), InferOutcome::Output(vec![4.0]));
        sender.join().unwrap();
        // IntoFuture sugar + dropped-sender path.
        let (tx, rx) = mpsc::channel::<Result<Vec<f32>, String>>();
        drop(tx);
        let out = block_on(std::future::IntoFuture::into_future(Ticket::new(rx)));
        assert_eq!(out, InferOutcome::Failed("server dropped request".to_string()));
    }

    #[test]
    fn replica_label_stamps_every_metrics_sample() {
        let rt = Runtime::builder()
            .model("mini_inception")
            .buckets(&[1])
            .telemetry(Telemetry::new())
            .build()
            .unwrap();
        let len = rt.example_len();
        let _ = rt.infer(InferRequest::new(vec![0.1; len])).unwrap();
        let handle = rt.handle().with_replica_label(3);
        let text = handle.metrics_text().unwrap();
        assert!(
            text.contains("nimble_requests_admitted_total{replica=\"3\"} "),
            "bare sample must gain the replica label:\n{text}"
        );
        for line in text.lines() {
            if line.starts_with('#') || line.is_empty() {
                continue;
            }
            assert!(
                line.contains("replica=\"3\""),
                "unlabeled sample in labeled exposition: {line}"
            );
        }
        // The plain handle is unchanged.
        assert!(!rt.handle().metrics_text().unwrap().contains("replica=\""));
        let _ = rt.shutdown().unwrap();
    }
}
