//! The one runtime façade: [`Runtime::builder()`] + [`InferRequest`].
//!
//! Nimble's pitch is that every scheduling decision is made ahead of
//! time, so the run-time surface should be one cheap, uniform submit
//! path. Before this module the public API was a matrix of constructors
//! (`TapeEngine::{new, with_worker_cap, from_graph_fn, from_graph_fn_opts}`,
//! `LaneServer::{start, start_pooled_tape, start_elastic_tape}`,
//! `NimbleServer::{start, start_with}`) and per-client method variants
//! (`infer` / `infer_hinted` / `infer_async` / `infer_hinted_async`).
//! All of those are now thin `#[deprecated]` shims; the supported
//! surface is:
//!
//! ```no_run
//! use nimble::serving::{InferRequest, Runtime, ScaleOptions};
//! # fn main() -> anyhow::Result<()> {
//! let rt = Runtime::builder()
//!     .model("mini_inception")
//!     .buckets(&[1, 4, 16])
//!     .elastic(ScaleOptions { max_lanes_per_bucket: 3, ..Default::default() })
//!     .shared_pool(8)
//!     .build()?;
//!
//! // Blocking:
//! let out = rt.infer(InferRequest::new(vec![0.0; rt.example_len()]))?;
//!
//! // Async, with routing + deadline composed on the request:
//! let req = InferRequest::new(vec![0.0; rt.example_len()])
//!     .hint(16)
//!     .deadline_in(std::time::Duration::from_millis(20));
//! let ticket = rt.submit(req)?;
//! let outcome = ticket.outcome()?; // Output(..) | DeadlineShed | Failed(..)
//! # let _ = (out, outcome);
//! # Ok(()) }
//! ```
//!
//! Exactly two submit paths exist — blocking [`Runtime::infer`] and
//! waitable [`Runtime::submit`] returning a [`Ticket`] — and every knob
//! that used to force a new constructor (worker caps, arena pools, the
//! shared work-stealing pool, elastic scaling) composes on
//! [`RuntimeBuilder`]. **Deadlines** are the capability the old matrix
//! could not express: a request whose deadline expires while it waits
//! (batcher queue, lane stage, or lane queue) is *shed* before the
//! engine runs it, surfaced as [`InferOutcome::DeadlineShed`] to the
//! caller and counted in `ServingReport::deadline_shed` /
//! `LaneStat::deadline_shed`. The DES predicts shed counts offline
//! ([`crate::sim::simulate_lanes_deadline`]).

use anyhow::{Context, Result};
use std::sync::mpsc;
use std::sync::Arc;
use std::time::{Duration, Instant};

use super::lanes::{LaneClient, LaneConfig, LaneServer, ScaleOptions};
use super::metrics::ServingReport;
use super::server::{NimbleServer, ServerClient};
use super::sim_engine::{TapeEngine, TapeEngineOptions};
use crate::aot::memory::ArenaPool;
use crate::coordinator::InferEngine;
use crate::engine::executor::SharedWorkerPool;
use crate::models;
use crate::ops::OpGraph;

/// The exact reply string of a deadline-shed request — a reserved
/// sentinel on the legacy `Result<_, String>` reply channel. A reply
/// equal to this whole string classifies as
/// [`InferOutcome::DeadlineShed`]; every other error is
/// [`InferOutcome::Failed`] (engines must not return this exact
/// message as a genuine error).
pub const DEADLINE_SHED: &str = "deadline shed: expired before execution";

/// The reply a shed request receives (always equals [`DEADLINE_SHED`]).
pub(crate) fn shed_error() -> String {
    DEADLINE_SHED.to_string()
}

/// Internal request token carried through the batcher and the lane
/// queues: the per-request reply channel plus the request's deadline.
pub(crate) struct ReqToken {
    pub reply: mpsc::Sender<Result<Vec<f32>, String>>,
    pub deadline: Option<Instant>,
}

impl ReqToken {
    /// The shed rule, shared by the lane threads, the single-engine
    /// thread, and the DES: expired once `now` reaches the deadline.
    pub fn expired(&self, now: Instant) -> bool {
        self.deadline.is_some_and(|d| now >= d)
    }

    /// Resolve this token as shed (the receiver may already be gone).
    pub fn shed(&self) {
        let _ = self.reply.send(Err(shed_error()));
    }
}

/// Per-request options ([`InferRequest::opts`]).
#[derive(Debug, Clone, Default)]
pub struct RequestOptions {
    /// Route the request's batch to this compiled bucket instead of
    /// deriving the bucket from queue depth (sequence-length-aware
    /// clients pick their own lane). Must name a compiled bucket.
    pub bucket_hint: Option<usize>,
    /// Shed the request (resolving its [`Ticket`] with
    /// [`InferOutcome::DeadlineShed`]) if it is still waiting —
    /// batcher queue, lane stage, or lane queue — at this instant.
    /// Execution already started is never interrupted.
    pub deadline: Option<Instant>,
}

/// One inference request: the input plus composable [`RequestOptions`].
/// Built with [`new`](Self::new) (one example, runs through the dynamic
/// batcher) or [`batch`](Self::batch) (a pre-formed padded batch routed
/// straight to its bucket's lane).
#[derive(Debug, Clone)]
pub struct InferRequest {
    /// Flattened input: one example ([`new`](Self::new)) or
    /// `bucket * example_len` values ([`batch`](Self::batch)).
    pub input: Vec<f32>,
    pub opts: RequestOptions,
    /// `Some(bucket)` for a pre-formed padded batch.
    batch: Option<usize>,
}

impl InferRequest {
    /// One example through the dynamic batcher.
    pub fn new(input: Vec<f32>) -> InferRequest {
        InferRequest { input, opts: RequestOptions::default(), batch: None }
    }

    /// A pre-formed padded batch (`bucket * example_len` values) routed
    /// straight to `bucket`'s lane; the reply carries the full padded
    /// output. Requires the lane topology (the builder default).
    pub fn batch(bucket: usize, input: Vec<f32>) -> InferRequest {
        InferRequest { input, opts: RequestOptions::default(), batch: Some(bucket) }
    }

    /// Replace the whole option set.
    pub fn with_options(mut self, opts: RequestOptions) -> Self {
        self.opts = opts;
        self
    }

    /// Route to this compiled bucket ([`RequestOptions::bucket_hint`]).
    pub fn hint(mut self, bucket: usize) -> Self {
        self.opts.bucket_hint = Some(bucket);
        self
    }

    /// Absolute deadline ([`RequestOptions::deadline`]).
    pub fn deadline(mut self, at: Instant) -> Self {
        self.opts.deadline = Some(at);
        self
    }

    /// Deadline `budget` from now.
    pub fn deadline_in(self, budget: Duration) -> Self {
        self.deadline(Instant::now() + budget)
    }

    /// The pre-formed batch bucket, if this is a batch request.
    pub fn bucket(&self) -> Option<usize> {
        self.batch
    }
}

impl From<Vec<f32>> for InferRequest {
    fn from(input: Vec<f32>) -> InferRequest {
        InferRequest::new(input)
    }
}

/// How a submitted request resolved.
#[derive(Debug, Clone, PartialEq)]
pub enum InferOutcome {
    /// The flattened output (one example's logits, or the full padded
    /// batch output for [`InferRequest::batch`]).
    Output(Vec<f32>),
    /// The deadline expired while the request waited; the engine never
    /// ran it.
    DeadlineShed,
    /// The engine (or the server) failed the request.
    Failed(String),
}

impl InferOutcome {
    pub fn is_shed(&self) -> bool {
        matches!(self, InferOutcome::DeadlineShed)
    }

    /// The output, if the request completed.
    pub fn output(self) -> Option<Vec<f32>> {
        match self {
            InferOutcome::Output(v) => Some(v),
            _ => None,
        }
    }
}

fn classify(reply: Result<Vec<f32>, String>) -> InferOutcome {
    match reply {
        Ok(v) => InferOutcome::Output(v),
        // Exact-equality on the reserved sentinel: only ReqToken::shed
        // produces this whole string, so a genuine engine error cannot
        // masquerade as a shed by sharing a prefix.
        Err(e) if e == DEADLINE_SHED => InferOutcome::DeadlineShed,
        Err(e) => InferOutcome::Failed(e),
    }
}

/// Waitable handle to a submitted request ([`Runtime::submit`]) — the
/// typed replacement for the raw `mpsc::Receiver` the deprecated
/// `infer_async` variants exposed. Every submitted ticket resolves
/// exactly once: output, deadline-shed, or failure.
pub struct Ticket {
    rx: mpsc::Receiver<Result<Vec<f32>, String>>,
}

impl Ticket {
    pub(crate) fn new(rx: mpsc::Receiver<Result<Vec<f32>, String>>) -> Ticket {
        Ticket { rx }
    }

    /// Block for the outcome. `Err` only if the server dropped the
    /// reply channel (it never does for an admitted request).
    pub fn outcome(self) -> Result<InferOutcome> {
        let reply = self.rx.recv().context("server dropped request")?;
        Ok(classify(reply))
    }

    /// Like [`outcome`](Self::outcome) with a wait bound; `Err` on
    /// timeout (distinct from the server dropping the reply channel).
    pub fn outcome_timeout(self, timeout: Duration) -> Result<InferOutcome> {
        let reply = self.rx.recv_timeout(timeout).map_err(|e| match e {
            mpsc::RecvTimeoutError::Timeout => {
                anyhow::anyhow!("timed out waiting for the request outcome")
            }
            mpsc::RecvTimeoutError::Disconnected => {
                anyhow::anyhow!("server dropped request")
            }
        })?;
        Ok(classify(reply))
    }

    /// Block for the output; shed and failed requests become errors
    /// (shed errors carry the [`DEADLINE_SHED`] marker).
    pub fn wait(self) -> Result<Vec<f32>> {
        match self.outcome()? {
            InferOutcome::Output(v) => Ok(v),
            InferOutcome::DeadlineShed => Err(anyhow::anyhow!(shed_error())),
            InferOutcome::Failed(e) => Err(anyhow::anyhow!(e)),
        }
    }

    /// Like [`wait`](Self::wait) with a wait bound.
    pub fn wait_timeout(self, timeout: Duration) -> Result<Vec<f32>> {
        match self.outcome_timeout(timeout)? {
            InferOutcome::Output(v) => Ok(v),
            InferOutcome::DeadlineShed => Err(anyhow::anyhow!(shed_error())),
            InferOutcome::Failed(e) => Err(anyhow::anyhow!(e)),
        }
    }
}

/// What the engines execute: a zoo model / arbitrary graph builder on
/// the tape substrate, or the PJRT artifact registry (`xla` feature).
enum Source {
    Graph {
        label: String,
        build: Arc<dyn Fn(usize) -> OpGraph + Send + Sync>,
    },
    #[cfg(feature = "xla")]
    Artifacts(crate::coordinator::EngineConfig),
}

/// How the shared work-stealing pool is provided.
enum PoolSpec {
    Size(usize),
    Handle(SharedWorkerPool),
}

/// Fluent, typed composition of everything the old constructor matrix
/// spread over nine entry points. See the [module docs](self) for the
/// shape; every method is optional except a source
/// ([`model`](Self::model) / [`graph_fn`](Self::graph_fn) /
/// `artifacts`).
pub struct RuntimeBuilder {
    label: String,
    source: Option<Source>,
    buckets: Vec<usize>,
    lane: LaneConfig,
    worker_cap: Option<usize>,
    unshared_slots: bool,
    arena_pool: Option<ArenaPool>,
    shared_pool: Option<PoolSpec>,
    single_thread: bool,
    serial: bool,
}

impl Default for RuntimeBuilder {
    fn default() -> Self {
        RuntimeBuilder {
            label: "runtime".to_string(),
            source: None,
            buckets: vec![1, 8],
            lane: LaneConfig::default(),
            worker_cap: None,
            unshared_slots: false,
            arena_pool: None,
            shared_pool: None,
            single_thread: false,
            serial: false,
        }
    }
}

impl RuntimeBuilder {
    /// Serve a model-zoo network on the tape substrate.
    pub fn model(mut self, name: &str) -> Self {
        let owned = name.to_string();
        self.label = name.to_string();
        self.source = Some(Source::Graph {
            label: name.to_string(),
            build: Arc::new(move |b| models::build(&owned, b)),
        });
        self
    }

    /// Serve an arbitrary per-bucket operator-graph builder (the
    /// differential harness feeds seeded random cells through this).
    pub fn graph_fn(
        mut self,
        build: impl Fn(usize) -> OpGraph + Send + Sync + 'static,
    ) -> Self {
        self.source =
            Some(Source::Graph { label: self.label.clone(), build: Arc::new(build) });
        self
    }

    /// Serve the PJRT artifact registry (the paper's real-runtime path).
    #[cfg(feature = "xla")]
    pub fn artifacts(mut self, config: crate::coordinator::EngineConfig) -> Self {
        self.source = Some(Source::Artifacts(config));
        self
    }

    /// Label used in error messages (defaults to the model name).
    pub fn label(mut self, label: &str) -> Self {
        self.label = label.to_string();
        if let Some(Source::Graph { label: l, .. }) = &mut self.source {
            *l = label.to_string();
        }
        self
    }

    /// Compiled batch-size buckets (deduplicated, sorted). Default
    /// `[1, 8]`.
    pub fn buckets(mut self, buckets: &[usize]) -> Self {
        self.buckets = buckets.to_vec();
        self
    }

    /// Max time the oldest request may wait before a partial batch
    /// flushes ([`LaneConfig::max_wait`]).
    pub fn max_wait(mut self, max_wait: Duration) -> Self {
        self.lane.max_wait = max_wait;
        self
    }

    /// Replace the whole lane configuration (admission/lane caps,
    /// buffer pools, backlog valve, scaling) in one call.
    pub fn lane_config(mut self, config: LaneConfig) -> Self {
        self.lane = config;
        self
    }

    /// Per-lane job-queue capacity ([`LaneConfig::lane_cap`]).
    pub fn lane_cap(mut self, cap: usize) -> Self {
        self.lane.lane_cap = cap;
        self
    }

    /// Pooled padded-input buffers per lane
    /// ([`LaneConfig::buffers_per_lane`]).
    pub fn buffers_per_lane(mut self, n: usize) -> Self {
        self.lane.buffers_per_lane = n;
        self
    }

    /// Admission-queue capacity ([`LaneConfig::admission_cap`]).
    pub fn admission_cap(mut self, cap: usize) -> Self {
        self.lane.admission_cap = cap;
        self
    }

    /// Batcher-backlog valve ([`LaneConfig::backlog_cap`]).
    pub fn backlog_cap(mut self, cap: usize) -> Self {
        self.lane.backlog_cap = cap;
        self
    }

    /// Elastic lane scaling ([`LaneConfig::scale`]; default static).
    pub fn elastic(mut self, scale: ScaleOptions) -> Self {
        self.lane.scale = scale;
        self
    }

    /// Per-context worker cap (the executor's capped work-sharing
    /// pool). Ignored when a shared pool is set.
    pub fn worker_cap(mut self, cap: usize) -> Self {
        self.worker_cap = Some(cap);
        self
    }

    /// Per-slot-buffer layout instead of the packed stream-aware arena
    /// (the differential harness's baseline engine).
    pub fn unshared_slots(mut self) -> Self {
        self.unshared_slots = true;
        self
    }

    /// Draw every replay context's arena from this shared pool, so
    /// rebuilt/respawned lanes recycle their reservations.
    pub fn arena_pool(mut self, pool: ArenaPool) -> Self {
        self.arena_pool = Some(pool);
        self
    }

    /// Lease replay workers from ONE process-wide work-stealing pool of
    /// `n_workers` threads instead of spawning per-context workers —
    /// however many lanes scale up, total replay threads stay capped.
    pub fn shared_pool(mut self, n_workers: usize) -> Self {
        self.shared_pool = Some(PoolSpec::Size(n_workers));
        self
    }

    /// Like [`shared_pool`](Self::shared_pool) with a caller-owned pool
    /// (share one pool across several runtimes, or keep a handle for
    /// stats).
    pub fn shared_pool_handle(mut self, pool: SharedWorkerPool) -> Self {
        self.shared_pool = Some(PoolSpec::Handle(pool));
        self
    }

    /// Single-engine-thread topology (the measured PR-1 baseline)
    /// instead of per-bucket lanes. Pre-formed batch requests require
    /// the lane topology; of the lane knobs only
    /// [`max_wait`](Self::max_wait) applies here, and combining with
    /// [`elastic`](Self::elastic) is rejected at build.
    pub fn single_thread(mut self) -> Self {
        self.single_thread = true;
        self
    }

    /// Serial-oracle engines: replay on the submitting thread in merged
    /// submission order (the differential oracle the parallel paths are
    /// checked against bit-for-bit).
    pub fn serial_oracle(mut self) -> Self {
        self.serial = true;
        self
    }

    fn engine_opts(&self) -> Result<TapeEngineOptions> {
        let shared_pool = match &self.shared_pool {
            None => None,
            Some(PoolSpec::Handle(p)) => Some(p.clone()),
            Some(PoolSpec::Size(n)) => {
                anyhow::ensure!(*n >= 1, "shared_pool needs at least one worker");
                Some(SharedWorkerPool::new(*n))
            }
        };
        Ok(TapeEngineOptions {
            worker_cap: self.worker_cap,
            unshared_slots: self.unshared_slots,
            arena_pool: self.arena_pool.clone(),
            shared_pool,
        })
    }

    /// Build the runtime: per-bucket serving lanes by default, the
    /// single-engine-thread topology under
    /// [`single_thread`](Self::single_thread).
    ///
    /// Incompatible knob combinations are rejected, not silently
    /// dropped: elastic scaling requires the lane topology, and the
    /// tape-engine knobs (worker caps, pools, serial oracle) do not
    /// apply to the PJRT artifact engines.
    pub fn build(self) -> Result<Runtime> {
        anyhow::ensure!(
            !(self.single_thread && self.lane.scale.max_lanes_per_bucket != 1),
            "elastic scaling needs the lane topology: drop single_thread() or elastic()"
        );
        #[cfg(feature = "xla")]
        if matches!(&self.source, Some(Source::Artifacts(_))) {
            anyhow::ensure!(
                self.worker_cap.is_none()
                    && !self.unshared_slots
                    && self.arena_pool.is_none()
                    && self.shared_pool.is_none()
                    && !self.serial,
                "worker_cap/unshared_slots/arena_pool/shared_pool/serial_oracle are \
                 tape-engine knobs; the PJRT artifact engines do not take them"
            );
        }
        let opts = self.engine_opts()?;
        let source = self
            .source
            .context("RuntimeBuilder needs a source: model(), graph_fn(), or artifacts()")?;
        let serial = self.serial;
        match source {
            Source::Graph { label, build } => {
                if self.single_thread {
                    let buckets = self.buckets.clone();
                    let factory = move || {
                        let e =
                            TapeEngine::build_opts(&label, &buckets, opts, |b| (*build)(b))?;
                        Ok(if serial { e.serial() } else { e })
                    };
                    NimbleServer::spawn(factory, self.lane.max_wait)
                        .map(Runtime::from_single)
                } else {
                    let factory = move |bucket: usize| {
                        let e = TapeEngine::build_opts(
                            &label,
                            &[bucket],
                            opts.clone(),
                            |b| (*build)(b),
                        )?;
                        Ok(if serial { e.serial() } else { e })
                    };
                    LaneServer::start_inner(&self.buckets, factory, self.lane)
                        .map(Runtime::from_lanes)
                }
            }
            #[cfg(feature = "xla")]
            Source::Artifacts(config) => {
                use crate::coordinator::NimbleEngine;
                if self.single_thread {
                    NimbleServer::spawn(move || NimbleEngine::build(config), self.lane.max_wait)
                        .map(Runtime::from_single)
                } else {
                    let factory =
                        move |bucket: usize| NimbleEngine::build_for(config.clone(), &[bucket]);
                    LaneServer::start_inner(&self.buckets, factory, self.lane)
                        .map(Runtime::from_lanes)
                }
            }
        }
    }

    /// Build a bare [`TapeEngine`] (all buckets in one engine, no
    /// server) with this builder's engine knobs — the direct-replay /
    /// differential-oracle path (compose with
    /// [`serial_oracle`](Self::serial_oracle)).
    pub fn build_engine(self) -> Result<TapeEngine> {
        let opts = self.engine_opts()?;
        let source = self
            .source
            .context("RuntimeBuilder needs a source: model() or graph_fn()")?;
        match source {
            Source::Graph { label, build } => {
                let e = TapeEngine::build_opts(&label, &self.buckets, opts, |b| (*build)(b))?;
                Ok(if self.serial { e.serial() } else { e })
            }
            #[cfg(feature = "xla")]
            Source::Artifacts(_) => anyhow::bail!(
                "build_engine() is tape-backed; the PJRT artifact path serves via build()"
            ),
        }
    }

    /// Build serving lanes over a custom engine factory (fault
    /// injection, engine wrappers): the factory runs once per lane *on
    /// that lane's thread* and must return an engine serving at least
    /// that bucket. Engine knobs ([`worker_cap`](Self::worker_cap),
    /// pools, …) are the factory's business here; lane and scaling
    /// knobs still apply.
    pub fn build_with_factory<E, F>(self, factory: F) -> Result<Runtime>
    where
        E: InferEngine + 'static,
        F: Fn(usize) -> Result<E> + Send + Sync + 'static,
    {
        anyhow::ensure!(
            !self.single_thread,
            "build_with_factory uses the lane topology (per-bucket factories)"
        );
        LaneServer::start_inner(&self.buckets, factory, self.lane)
            .map(Runtime::from_lanes)
    }
}

enum ServerInner {
    Single(NimbleServer),
    Lanes(LaneServer),
}

/// One handle over the whole serving stack — subsumes the deprecated
/// `NimbleServer` / `LaneServer` pair. Built by [`Runtime::builder`];
/// submit with [`infer`](Self::infer) / [`submit`](Self::submit), clone
/// [`handle`](Self::handle)s for client threads, stop with
/// [`shutdown`](Self::shutdown).
pub struct Runtime {
    inner: ServerInner,
    /// Built once so the hot `infer`/`submit` path never re-clones the
    /// client (its batch-size vector in particular).
    handle: RuntimeHandle,
}

impl Runtime {
    fn from_single(server: NimbleServer) -> Runtime {
        let handle = RuntimeHandle { inner: HandleInner::Single(server.client()) };
        Runtime { inner: ServerInner::Single(server), handle }
    }

    fn from_lanes(server: LaneServer) -> Runtime {
        let handle = RuntimeHandle { inner: HandleInner::Lanes(server.client()) };
        Runtime { inner: ServerInner::Lanes(server), handle }
    }

    pub fn builder() -> RuntimeBuilder {
        RuntimeBuilder::default()
    }

    /// Flattened input length of one example.
    pub fn example_len(&self) -> usize {
        match &self.inner {
            ServerInner::Single(s) => s.example_len(),
            ServerInner::Lanes(s) => s.example_len(),
        }
    }

    /// Flattened output length of one example.
    pub fn output_len(&self) -> usize {
        match &self.inner {
            ServerInner::Single(s) => s.output_len(),
            ServerInner::Lanes(s) => s.output_len(),
        }
    }

    /// Compiled batch buckets, ascending.
    pub fn batch_sizes(&self) -> &[usize] {
        match &self.inner {
            ServerInner::Single(s) => s.batch_sizes(),
            ServerInner::Lanes(s) => s.batch_sizes(),
        }
    }

    /// A cloneable, `Send` request handle for client threads.
    pub fn handle(&self) -> RuntimeHandle {
        self.handle.clone()
    }

    /// Blocking inference: submit and wait for the output.
    pub fn infer(&self, req: InferRequest) -> Result<Vec<f32>> {
        self.handle.infer(req)
    }

    /// Submit a request; returns a waitable [`Ticket`].
    pub fn submit(&self, req: InferRequest) -> Result<Ticket> {
        self.handle.submit(req)
    }

    /// Stop the runtime: flush everything already admitted, join every
    /// engine/lane thread, and collect the serving report.
    pub fn shutdown(self) -> Result<ServingReport> {
        match self.inner {
            ServerInner::Single(s) => s.shutdown(),
            ServerInner::Lanes(s) => s.shutdown(),
        }
    }
}

#[derive(Clone)]
enum HandleInner {
    Single(ServerClient),
    Lanes(LaneClient),
}

/// Cloneable, `Send` request handle to a [`Runtime`] — one per client
/// thread. Dropping handles does not stop the runtime.
#[derive(Clone)]
pub struct RuntimeHandle {
    inner: HandleInner,
}

impl RuntimeHandle {
    pub fn example_len(&self) -> usize {
        match &self.inner {
            HandleInner::Single(c) => c.example_len(),
            HandleInner::Lanes(c) => c.example_len(),
        }
    }

    pub fn output_len(&self) -> usize {
        match &self.inner {
            HandleInner::Single(c) => c.output_len(),
            HandleInner::Lanes(c) => c.output_len(),
        }
    }

    /// Compiled batch buckets, ascending.
    pub fn batch_sizes(&self) -> &[usize] {
        match &self.inner {
            HandleInner::Single(c) => c.batch_sizes(),
            HandleInner::Lanes(c) => c.batch_sizes(),
        }
    }

    /// Blocking inference: submit and wait for the output (shed and
    /// failed requests become errors).
    pub fn infer(&self, req: InferRequest) -> Result<Vec<f32>> {
        self.submit(req)?.wait()
    }

    /// Submit a request; returns a waitable [`Ticket`]. Validates the
    /// input length and any bucket hint against the compiled buckets —
    /// identically on both topologies.
    pub fn submit(&self, req: InferRequest) -> Result<Ticket> {
        let InferRequest { input, opts, batch } = req;
        if let Some(hint) = opts.bucket_hint {
            anyhow::ensure!(
                self.batch_sizes().contains(&hint),
                "no compiled bucket {hint} to hint"
            );
        }
        if let Some(bucket) = batch {
            anyhow::ensure!(
                self.batch_sizes().contains(&bucket),
                "no compiled bucket {bucket}"
            );
            anyhow::ensure!(
                input.len() == bucket * self.example_len(),
                "bad batch length {} != {}",
                input.len(),
                bucket * self.example_len()
            );
            if let Some(hint) = opts.bucket_hint {
                anyhow::ensure!(
                    hint == bucket,
                    "bucket hint {hint} contradicts the pre-formed batch bucket {bucket}"
                );
            }
            match &self.inner {
                HandleInner::Lanes(c) => {
                    c.submit_batch_raw(bucket, input, opts.deadline).map(Ticket::new)
                }
                HandleInner::Single(_) => anyhow::bail!(
                    "pre-formed batch requests need the lane topology \
                     (the builder default; this runtime is single_thread)"
                ),
            }
        } else {
            anyhow::ensure!(
                input.len() == self.example_len(),
                "bad input length {} != {}",
                input.len(),
                self.example_len()
            );
            match &self.inner {
                HandleInner::Single(c) => {
                    c.submit_raw(input, opts.bucket_hint, opts.deadline).map(Ticket::new)
                }
                HandleInner::Lanes(c) => {
                    c.submit_raw(input, opts.bucket_hint, opts.deadline).map(Ticket::new)
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Pcg32;

    fn inputs(n: usize, len: usize, seed: u64) -> Vec<Vec<f32>> {
        let mut rng = Pcg32::new(seed);
        (0..n).map(|_| (0..len).map(|_| rng.gen_f32_range(-1.0, 1.0)).collect()).collect()
    }

    #[test]
    fn builder_serves_on_both_topologies_bit_identically() {
        let lanes = Runtime::builder().model("mini_inception").build().unwrap();
        let single =
            Runtime::builder().model("mini_inception").single_thread().build().unwrap();
        assert_eq!(lanes.batch_sizes(), &[1, 8], "default buckets");
        assert_eq!(lanes.batch_sizes(), single.batch_sizes());
        let len = lanes.example_len();
        assert_eq!(len, single.example_len());
        for input in inputs(3, len, 11) {
            let a = lanes.infer(InferRequest::new(input.clone())).unwrap();
            let b = single.infer(InferRequest::new(input)).unwrap();
            assert_eq!(a, b, "topology must not leak into results");
        }
        let _ = lanes.shutdown().unwrap();
        let _ = single.shutdown().unwrap();
    }

    #[test]
    fn batch_requests_route_to_their_bucket_and_match_the_engine() {
        let rt = Runtime::builder().model("mini_inception").buckets(&[1, 4]).build().unwrap();
        let len = rt.example_len();
        let batch: Vec<f32> = inputs(4, len, 21).concat();
        let got = rt.submit(InferRequest::batch(4, batch.clone())).unwrap().wait().unwrap();
        let mut direct = Runtime::builder()
            .model("mini_inception")
            .buckets(&[4])
            .build_engine()
            .unwrap();
        assert_eq!(got, direct.infer_batch(4, &batch).unwrap());
        // Validation: unknown bucket, bad length, contradictory hint.
        assert!(rt.submit(InferRequest::batch(3, vec![0.0; 3 * len])).is_err());
        assert!(rt.submit(InferRequest::batch(4, vec![0.0; len])).is_err());
        assert!(rt.submit(InferRequest::batch(4, batch.clone()).hint(1)).is_err());
        let report = rt.shutdown().unwrap();
        assert_eq!(report.n_batches, 1);
    }

    #[test]
    fn batch_requests_require_the_lane_topology() {
        let rt = Runtime::builder()
            .model("mini_inception")
            .buckets(&[1, 4])
            .single_thread()
            .build()
            .unwrap();
        let err = rt.submit(InferRequest::batch(4, vec![0.0; 4 * rt.example_len()]));
        assert!(err.is_err());
        let _ = rt.shutdown().unwrap();
    }

    #[test]
    fn hints_are_validated_identically_on_both_topologies() {
        for single in [false, true] {
            let b = Runtime::builder().model("mini_inception").buckets(&[1, 8]);
            let rt = if single { b.single_thread() } else { b }.build().unwrap();
            let len = rt.example_len();
            let ok = rt.infer(InferRequest::new(vec![0.1; len]).hint(8));
            assert!(ok.is_ok(), "valid hint must serve (single={single})");
            let bad = rt.submit(InferRequest::new(vec![0.1; len]).hint(3));
            assert!(bad.is_err(), "unknown hint must be rejected (single={single})");
            let short = rt.submit(InferRequest::new(vec![0.1; len - 1]));
            assert!(short.is_err(), "bad length must be rejected (single={single})");
            let _ = rt.shutdown().unwrap();
        }
    }

    #[test]
    fn expired_deadlines_shed_and_are_accounted() {
        for single in [false, true] {
            let b = Runtime::builder()
                .model("mini_inception")
                .buckets(&[1])
                .max_wait(Duration::from_micros(200));
            let rt = if single { b.single_thread() } else { b }.build().unwrap();
            let len = rt.example_len();
            // Already expired at submit: the engine must never run it.
            let shed = rt
                .submit(InferRequest::new(vec![0.2; len]).deadline(Instant::now()))
                .unwrap();
            assert_eq!(shed.outcome().unwrap(), InferOutcome::DeadlineShed);
            // A roomy deadline completes normally.
            let ok = rt
                .submit(InferRequest::new(vec![0.2; len]).deadline_in(Duration::from_secs(60)))
                .unwrap();
            assert!(matches!(ok.outcome().unwrap(), InferOutcome::Output(_)));
            let report = rt.shutdown().unwrap();
            assert_eq!(report.deadline_shed, 1, "single={single}");
            assert_eq!(report.n_requests, 1, "completed excludes shed (single={single})");
        }
    }

    #[test]
    fn wait_surfaces_shed_as_a_marked_error() {
        let (tx, rx) = mpsc::channel();
        tx.send(Err(shed_error())).unwrap();
        let err = Ticket::new(rx).wait().unwrap_err();
        assert!(format!("{err:#}").starts_with(DEADLINE_SHED));
        let (tx, rx) = mpsc::channel();
        tx.send(Err("engine exploded".to_string())).unwrap();
        assert_eq!(
            Ticket::new(rx).outcome().unwrap(),
            InferOutcome::Failed("engine exploded".to_string())
        );
    }

    #[test]
    fn builder_requires_a_source() {
        assert!(Runtime::builder().build().is_err());
        assert!(Runtime::builder().buckets(&[1]).build_engine().is_err());
    }
}
