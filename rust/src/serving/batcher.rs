//! Dynamic batcher: group pending requests up to the largest compiled
//! batch size, or flush early when the oldest request has waited past the
//! deadline. Static shapes ⇒ partial batches are padded with zeros and the
//! padding outputs dropped (one compiled engine per batch size bucket).
//!
//! Queued requests are kept in **EDF order** (earliest deadline first):
//! a request with a deadline is inserted ahead of every queued request
//! with a later deadline and ahead of all deadline-less requests;
//! requests with equal deadlines — and all deadline-less requests —
//! stay in FIFO arrival order. A workload that never sets deadlines
//! therefore sees exactly the old FIFO batcher, bit for bit.

use std::time::{Duration, Instant};

/// Batching policy.
#[derive(Debug, Clone)]
pub struct BatchPolicy {
    /// Compiled batch sizes, ascending (from the manifest).
    pub batch_sizes: Vec<usize>,
    /// Max time the oldest request may wait before a partial batch flushes.
    pub max_wait: Duration,
}

impl BatchPolicy {
    pub fn max_batch(&self) -> usize {
        *self.batch_sizes.last().expect("at least one batch size")
    }

    /// Smallest compiled batch size that fits `n` requests.
    pub fn bucket_for(&self, n: usize) -> usize {
        debug_assert!(n > 0);
        *self
            .batch_sizes
            .iter()
            .find(|&&b| b >= n)
            .unwrap_or_else(|| self.batch_sizes.last().expect("non-empty"))
    }
}

/// A queued request: opaque id + one example's input, plus an optional
/// bucket hint (validated against the policy at push) and an optional
/// deadline (drives the EDF queue order).
#[derive(Debug, Clone)]
pub struct Pending<T> {
    pub token: T,
    pub input: Vec<f32>,
    pub hint: Option<usize>,
    pub deadline: Option<Instant>,
    pub enqueued: Instant,
}

/// Accumulates pending requests and decides when to form a batch.
#[derive(Debug)]
pub struct Batcher<T> {
    policy: BatchPolicy,
    queue: Vec<Pending<T>>,
}

/// A formed batch ready for execution.
#[derive(Debug)]
pub struct FormedBatch<T> {
    /// Compiled batch size (≥ len of tokens; rest is padding).
    pub bucket: usize,
    /// Flattened, zero-padded input of `bucket` examples.
    pub input: Vec<f32>,
    /// Tokens of the real examples, in input order.
    pub tokens: Vec<(T, Instant)>,
}

/// A formed batch whose padded input was written into a caller-owned
/// buffer ([`Batcher::form_with`]) — the server's allocation-reusing path.
#[derive(Debug)]
pub struct FormedTokens<T> {
    /// Compiled batch size (≥ len of tokens; rest is padding).
    pub bucket: usize,
    /// Tokens of the real examples, in input order.
    pub tokens: Vec<(T, Instant)>,
}

impl<T> Batcher<T> {
    pub fn new(policy: BatchPolicy) -> Self {
        Batcher { policy, queue: Vec::new() }
    }

    pub fn push(&mut self, token: T, input: Vec<f32>) {
        self.push_request(token, input, None, None);
    }

    /// Queue a request with an optional bucket hint. A hint naming a
    /// compiled bucket routes the request's batch to that bucket
    /// (sequence-length-style routing the client decides) **instead of**
    /// deriving the bucket from queue depth; hints naming no compiled
    /// bucket are ignored.
    pub fn push_hinted(&mut self, token: T, input: Vec<f32>, hint: Option<usize>) {
        self.push_request(token, input, hint, None);
    }

    /// Queue a request with an optional bucket hint and an optional
    /// deadline. The deadline decides the queue position (EDF): the
    /// request slots ahead of every queued request with a strictly later
    /// deadline and ahead of all deadline-less requests, behind requests
    /// with an equal or earlier deadline (FIFO among equals). A
    /// deadline-less request appends at the back exactly like the old
    /// FIFO batcher.
    pub fn push_request(
        &mut self,
        token: T,
        input: Vec<f32>,
        hint: Option<usize>,
        deadline: Option<Instant>,
    ) {
        let hint = hint.filter(|h| self.policy.batch_sizes.contains(h));
        let at = match deadline {
            None => self.queue.len(),
            Some(d) => self
                .queue
                .iter()
                .position(|p| match p.deadline {
                    None => true,
                    Some(pd) => pd > d,
                })
                .unwrap_or(self.queue.len()),
        };
        self.queue
            .insert(at, Pending { token, input, hint, deadline, enqueued: Instant::now() });
    }

    pub fn pending(&self) -> usize {
        self.queue.len()
    }

    /// The batch the next [`form_with`](Self::form_with) will produce,
    /// as `(request count, bucket)` — `None` on an empty queue. The
    /// oldest request decides: a hinted head groups the maximal run of
    /// identically-hinted requests behind it (capped at the hinted
    /// bucket, which is honored verbatim); an unhinted head takes the
    /// maximal unhinted run, bucketed by size as before. Dispatchers
    /// route by this plan *before* forming, so a saturated lane leaves
    /// the queue untouched.
    pub fn plan_next(&self) -> Option<(usize, usize)> {
        let head = self.queue.first()?;
        Some(match head.hint {
            Some(b) => {
                let run = self.queue.iter().take_while(|p| p.hint == Some(b)).count();
                (run.min(b), b)
            }
            None => {
                let run = self.queue.iter().take_while(|p| p.hint.is_none()).count();
                let take = run.min(self.policy.max_batch());
                (take, self.policy.bucket_for(take))
            }
        })
    }

    /// Should a batch be formed now? A full batch (the planned run fills
    /// its bucket) flushes immediately; otherwise the oldest request's
    /// deadline governs.
    pub fn ready(&self, now: Instant) -> bool {
        let Some(head) = self.queue.first() else {
            return false;
        };
        let full = match head.hint {
            Some(b) => self.queue.iter().take_while(|p| p.hint == Some(b)).count() >= b,
            // Count only the unhinted prefix `plan_next` will actually
            // take — hinted requests queued behind the head must not
            // trigger a premature, underfilled flush.
            None => {
                self.queue.iter().take_while(|p| p.hint.is_none()).count()
                    >= self.policy.max_batch()
            }
        };
        full || now.duration_since(head.enqueued) >= self.policy.max_wait
    }

    /// When the dispatcher must next look at this queue: the head's
    /// flush point (`enqueued + max_wait`) folded with the earliest
    /// request deadline still queued. The queue is EDF-ordered, so the
    /// earliest deadline (if any request carries one) is the head's —
    /// deadline-less requests always sort behind deadline-carrying ones.
    pub fn next_deadline(&self) -> Option<Instant> {
        let head = self.queue.first()?;
        let flush = head.enqueued + self.policy.max_wait;
        Some(match head.deadline {
            Some(d) => flush.min(d),
            None => flush,
        })
    }

    /// The earliest request deadline still queued, if any.
    pub fn earliest_request_deadline(&self) -> Option<Instant> {
        self.queue.first().and_then(|p| p.deadline)
    }

    /// Remove every queued request whose deadline has passed
    /// (`now >= deadline`) and hand the tokens back so the caller can
    /// resolve them as shed — before they occupy a formed batch. The
    /// EDF order means expired requests form a prefix of the queue.
    pub fn shed_expired(&mut self, now: Instant) -> Vec<T> {
        let keep = self
            .queue
            .iter()
            .position(|p| match p.deadline {
                Some(d) => now < d,
                None => true,
            })
            .unwrap_or(self.queue.len());
        self.queue.drain(..keep).map(|p| p.token).collect()
    }

    /// Form the next batch (call when `ready`). `example_len` is the per-
    /// example input length; padding examples are zero.
    pub fn form(&mut self, example_len: usize) -> Option<FormedBatch<T>> {
        let mut input = Vec::new();
        let ft = self.form_with(example_len, &mut input)?;
        Some(FormedBatch { bucket: ft.bucket, input, tokens: ft.tokens })
    }

    /// Like [`form`](Self::form), but writes the zero-padded batch input
    /// into a caller-owned buffer so the server reuses one allocation
    /// across batches.
    pub fn form_with(
        &mut self,
        example_len: usize,
        input: &mut Vec<f32>,
    ) -> Option<FormedTokens<T>> {
        let (take, bucket) = self.plan_next()?;
        input.clear();
        input.resize(bucket * example_len, 0.0);
        let mut tokens = Vec::with_capacity(take);
        for (i, p) in self.queue.drain(..take).enumerate() {
            assert_eq!(p.input.len(), example_len, "inconsistent example length");
            input[i * example_len..(i + 1) * example_len].copy_from_slice(&p.input);
            tokens.push((p.token, p.enqueued));
        }
        Some(FormedTokens { bucket, tokens })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn policy() -> BatchPolicy {
        BatchPolicy { batch_sizes: vec![1, 8], max_wait: Duration::from_millis(5) }
    }

    #[test]
    fn bucket_selection() {
        let p = policy();
        assert_eq!(p.bucket_for(1), 1);
        assert_eq!(p.bucket_for(2), 8);
        assert_eq!(p.bucket_for(8), 8);
        assert_eq!(p.bucket_for(20), 8, "clamps to max");
    }

    #[test]
    fn full_batch_is_ready_immediately() {
        let mut b = Batcher::new(policy());
        for i in 0..8 {
            b.push(i, vec![i as f32; 4]);
        }
        assert!(b.ready(Instant::now()));
        let fb = b.form(4).unwrap();
        assert_eq!(fb.bucket, 8);
        assert_eq!(fb.tokens.len(), 8);
        assert_eq!(fb.input[0], 0.0);
        assert_eq!(fb.input[4], 1.0);
        assert_eq!(b.pending(), 0);
    }

    #[test]
    fn partial_batch_waits_for_deadline() {
        let mut b = Batcher::new(policy());
        b.push(0, vec![1.0; 4]);
        assert!(!b.ready(Instant::now()));
        let later = Instant::now() + Duration::from_millis(10);
        assert!(b.ready(later));
        let fb = b.form(4).unwrap();
        assert_eq!(fb.bucket, 1);
        assert_eq!(fb.tokens.len(), 1);
    }

    #[test]
    fn partial_batch_pads_with_zeros() {
        let mut b = Batcher::new(policy());
        b.push(0, vec![1.0; 4]);
        b.push(1, vec![2.0; 4]);
        let fb = b.form(4).unwrap();
        assert_eq!(fb.bucket, 8);
        assert_eq!(fb.input.len(), 32);
        assert_eq!(&fb.input[..4], &[1.0; 4]);
        assert_eq!(&fb.input[4..8], &[2.0; 4]);
        assert!(fb.input[8..].iter().all(|&v| v == 0.0));
    }

    #[test]
    fn overflow_leaves_remainder_queued() {
        let mut b = Batcher::new(policy());
        for i in 0..11 {
            b.push(i, vec![0.0; 4]);
        }
        let fb = b.form(4).unwrap();
        assert_eq!(fb.tokens.len(), 8);
        assert_eq!(b.pending(), 3);
    }

    #[test]
    fn empty_form_returns_none() {
        let mut b: Batcher<u32> = Batcher::new(policy());
        assert!(b.form(4).is_none());
        assert!(b.form_with(4, &mut Vec::new()).is_none());
        assert!(b.next_deadline().is_none());
    }

    #[test]
    fn hinted_head_routes_to_its_bucket_over_queue_depth() {
        let mut b = Batcher::new(policy()); // buckets [1, 8]
        b.push_hinted(0, vec![1.0; 4], Some(8));
        // queue-depth routing would pick bucket 1 for a lone request;
        // the hint must win
        assert_eq!(b.plan_next(), Some((1, 8)));
        let fb = b.form(4).unwrap();
        assert_eq!((fb.bucket, fb.tokens.len()), (8, 1));
        assert_eq!(fb.input.len(), 32);
    }

    #[test]
    fn hinted_full_batch_is_ready_immediately_and_caps_its_run() {
        let mut b = Batcher::new(policy());
        b.push_hinted(0, vec![1.0; 4], Some(1));
        assert!(b.ready(Instant::now()), "a full hinted batch flushes immediately");
        b.push_hinted(1, vec![2.0; 4], Some(1));
        // head hint 1 caps the run at one request per batch
        assert_eq!(b.plan_next(), Some((1, 1)));
        let fb = b.form(4).unwrap();
        assert_eq!((fb.bucket, fb.tokens.len()), (1, 1));
        assert_eq!(b.pending(), 1);
    }

    #[test]
    fn mixed_hints_form_in_arrival_runs() {
        let mut b = Batcher::new(policy());
        b.push(0, vec![0.0; 4]);
        b.push(1, vec![1.0; 4]);
        b.push_hinted(2, vec![2.0; 4], Some(8));
        // the unhinted prefix forms first, depth-routed as before
        assert_eq!(b.plan_next(), Some((2, 8)));
        let fb = b.form(4).unwrap();
        assert_eq!((fb.bucket, fb.tokens.len()), (8, 2));
        // then the hinted run
        assert_eq!(b.plan_next(), Some((1, 8)));
    }

    #[test]
    fn hinted_tail_does_not_trigger_a_premature_unhinted_flush() {
        // 1 unhinted head + 7 hinted requests: the queue is 8 deep but
        // the plannable unhinted run is 1, so only the deadline (not the
        // depth) may flush the head.
        let mut b = Batcher::new(policy());
        b.push(0, vec![0.0; 4]);
        for i in 1..8 {
            b.push_hinted(i, vec![i as f32; 4], Some(8));
        }
        assert_eq!(b.pending(), 8);
        assert!(!b.ready(Instant::now()), "underfilled batch must wait for its deadline");
        assert!(b.ready(Instant::now() + Duration::from_millis(10)));
        assert_eq!(b.plan_next(), Some((1, 1)));
    }

    #[test]
    fn unknown_hints_are_ignored() {
        let mut b = Batcher::new(policy());
        b.push_hinted(0, vec![0.0; 4], Some(3)); // 3 is not a compiled bucket
        assert_eq!(b.plan_next(), Some((1, 1)), "depth routing applies");
    }

    #[test]
    fn edf_orders_tight_deadlines_first_and_deadline_less_last() {
        let mut b = Batcher::new(policy());
        let now = Instant::now();
        b.push_request(0, vec![0.0; 4], None, None); // no deadline
        b.push_request(1, vec![1.0; 4], None, Some(now + Duration::from_millis(50)));
        b.push_request(2, vec![2.0; 4], None, Some(now + Duration::from_millis(10)));
        b.push_request(3, vec![3.0; 4], None, Some(now + Duration::from_millis(50)));
        b.push_request(4, vec![4.0; 4], None, None);
        // EDF: 10ms first, then the two 50ms in arrival order (FIFO among
        // equals), then the deadline-less in arrival order.
        let order: Vec<u32> = b.queue.iter().map(|p| p.token).collect();
        assert_eq!(order, vec![2, 1, 3, 0, 4]);
    }

    #[test]
    fn deadline_free_pushes_stay_in_fifo_order() {
        let mut b = Batcher::new(policy());
        for i in 0..6u32 {
            b.push_hinted(i, vec![i as f32; 4], if i % 2 == 0 { Some(8) } else { None });
        }
        let order: Vec<u32> = b.queue.iter().map(|p| p.token).collect();
        assert_eq!(order, vec![0, 1, 2, 3, 4, 5], "no deadline ⇒ identical to FIFO");
    }

    #[test]
    fn next_deadline_folds_in_the_earliest_request_deadline() {
        let mut b = Batcher::new(policy()); // max_wait = 5ms
        let now = Instant::now();
        b.push(0, vec![0.0; 4]);
        // flush point only: ~now + 5ms
        let nd = b.next_deadline().unwrap();
        assert!(nd >= now + Duration::from_millis(4));
        // a 1ms-deadline request jumps the queue and pulls the wakeup in
        b.push_request(1, vec![1.0; 4], None, Some(now + Duration::from_millis(1)));
        let nd = b.next_deadline().unwrap();
        assert!(nd <= now + Duration::from_millis(1));
        assert_eq!(b.earliest_request_deadline(), Some(now + Duration::from_millis(1)));
    }

    #[test]
    fn shed_expired_removes_exactly_the_expired_prefix() {
        let mut b = Batcher::new(policy());
        let now = Instant::now();
        b.push_request(0, vec![0.0; 4], None, None);
        b.push_request(1, vec![1.0; 4], None, Some(now - Duration::from_millis(1)));
        b.push_request(2, vec![2.0; 4], None, Some(now + Duration::from_secs(60)));
        let shed = b.shed_expired(now);
        assert_eq!(shed, vec![1]);
        assert_eq!(b.pending(), 2);
        let order: Vec<u32> = b.queue.iter().map(|p| p.token).collect();
        assert_eq!(order, vec![2, 0]);
        assert!(b.shed_expired(now).is_empty(), "idempotent once drained");
    }

    #[test]
    fn form_with_reuses_buffer_and_repads() {
        let mut b = Batcher::new(policy());
        let mut buf = Vec::new();
        b.push(0, vec![1.0; 4]);
        b.push(1, vec![2.0; 4]);
        let ft = b.form_with(4, &mut buf).unwrap();
        assert_eq!(ft.bucket, 8);
        assert_eq!(buf.len(), 32);
        assert_eq!(&buf[..4], &[1.0; 4]);
        assert!(buf[8..].iter().all(|&v| v == 0.0));
        let cap = buf.capacity();
        // refill: stale values must not leak, capacity must be reused
        b.push(2, vec![3.0; 4]);
        let ft = b.form_with(4, &mut buf).unwrap();
        assert_eq!(ft.bucket, 1);
        assert_eq!(buf, vec![3.0; 4]);
        assert_eq!(buf.capacity(), cap, "no reallocation on a smaller batch");
    }
}
