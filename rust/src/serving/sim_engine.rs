//! Tape-backed serving engine for the virtual-GPU substrate.
//!
//! [`TapeEngine`] is the non-PJRT implementation of
//! [`InferEngine`](crate::coordinator::InferEngine): per compiled batch
//! bucket it builds the model's operator graph, runs Algorithm 1 + the
//! graph rewriter, compiles the launch plan into a
//! [`ReplayTape`](crate::aot::tape::ReplayTape), and keeps an
//! **independent [`ReplayContext`]** (its own slot arena, event table
//! and worker pool). Buckets therefore replay concurrently and a hot
//! bucket never contends with a cold one — and the steady-state request
//! loop performs zero per-task heap allocation.
//!
//! Build through
//! [`Runtime::builder().build_engine()`](crate::serving::RuntimeBuilder::build_engine)
//! — `graph_fn` feeds arbitrary builders (the randomized differential
//! harness uses seeded random cells), `worker_cap` caps each context's
//! pool via the executor's work-sharing mode (many lanes × many streams
//! must not exceed the physical cores by much), and
//! [`serial`](TapeEngine::serial) (or the builder's `serial_oracle()`)
//! switches `infer_batch` to the single-thread serial replay — the
//! differential oracle the lane pipeline is checked against
//! bit-for-bit. The old `TapeEngine::{new, with_worker_cap,
//! from_graph_fn, from_graph_fn_opts}` constructors are deprecated
//! shims over the same internals.

use anyhow::{Context, Result};
use std::collections::HashMap;

use crate::aot::memory::ArenaPool;
use crate::aot::tape::ReplayTape;
use crate::coordinator::InferEngine;
use crate::engine::executor::{ExecOptions, ReplayContext, SharedWorkerPool, SyntheticKernel};
use crate::matching::MatchingAlgo;
use crate::models;
use crate::ops::OpGraph;
use crate::stream::rewrite::rewrite;

/// Intermediate-activation clamp for the synthetic substrate (input and
/// output slots keep their true lengths).
const MAX_TASK_ELEMS: usize = 4096;

/// Build-time knobs for [`TapeEngine`] (see
/// [`from_graph_fn_opts`](TapeEngine::from_graph_fn_opts)).
#[derive(Default, Clone)]
pub struct TapeEngineOptions {
    /// Per-context worker cap ([`ExecOptions::max_workers`]).
    pub worker_cap: Option<usize>,
    /// Per-slot-buffer layout instead of the packed stream-aware arena
    /// (the differential harness's baseline engine).
    pub unshared_slots: bool,
    /// Draw every context's arena from this shared pool (serving lanes
    /// pass one pool so rebuilt lanes recycle their reservations).
    pub arena_pool: Option<ArenaPool>,
    /// Lease workers from this process-wide work-stealing pool instead
    /// of spawning per-context threads ([`ExecOptions::shared_pool`]) —
    /// the elastic lane scheduler backs every lane with one pool so
    /// lanes × streams never exceed the pool's worker count. Takes
    /// precedence over `worker_cap`.
    pub shared_pool: Option<SharedWorkerPool>,
    /// Seeded replay-level fault injection for every context
    /// ([`ExecOptions::fault`]); `Runtime::builder().fault_plan(..)`
    /// derives one independent stream per bucket before building.
    pub fault: Option<crate::fault::FaultPlan>,
    /// Flight recorder shared by every context
    /// ([`ExecOptions::telemetry`]); build also registers each graph's
    /// node names as span labels for trace export and calibration.
    pub telemetry: Option<crate::telemetry::Telemetry>,
    /// Static plan verification policy: every bucket's compiled tape
    /// and arena layout run through [`crate::aot::verify`] at build
    /// time. `Strict` refuses to build on any diagnostic, `Warn` prints
    /// the report to stderr, `Off` skips the pass; the default is
    /// `Warn` in debug builds and `Off` in release. Build-time only —
    /// the replay hot path never sees the verifier.
    pub verify: crate::aot::verify::VerifyMode,
}

/// One independent replay context per compiled batch bucket.
pub struct TapeEngine {
    batch_sizes: Vec<usize>,
    example_len: usize,
    output_len: usize,
    contexts: HashMap<usize, ReplayContext>,
    /// Serial-oracle mode: replay on the calling thread in merged
    /// submission order instead of releasing the worker pool.
    serial: bool,
    /// Contexts lease from a shared work-stealing pool (steal counts
    /// are meaningful).
    shared_pool: bool,
}

impl TapeEngine {
    /// Build contexts for the zoo model `model` at each batch bucket.
    #[deprecated(note = "use Runtime::builder().model(..).buckets(..).build_engine()")]
    pub fn new(model: &str, batch_sizes: &[usize]) -> Result<TapeEngine> {
        let name = model.to_string();
        Self::build_opts(model, batch_sizes, TapeEngineOptions::default(), move |b| {
            models::build(&name, b)
        })
    }

    /// Like [`new`](Self::new), with a per-context worker cap
    /// ([`ExecOptions::max_workers`]).
    #[deprecated(note = "use Runtime::builder().model(..).worker_cap(..).build_engine()")]
    pub fn with_worker_cap(
        model: &str,
        batch_sizes: &[usize],
        worker_cap: Option<usize>,
    ) -> Result<TapeEngine> {
        let name = model.to_string();
        let opts = TapeEngineOptions { worker_cap, ..Default::default() };
        Self::build_opts(model, batch_sizes, opts, move |b| models::build(&name, b))
    }

    /// Build contexts from an arbitrary per-bucket graph builder. The
    /// graph must have exactly one `Input` node; `name` labels errors.
    #[deprecated(note = "use Runtime::builder().graph_fn(..).build_engine()")]
    pub fn from_graph_fn(
        name: &str,
        batch_sizes: &[usize],
        worker_cap: Option<usize>,
        build: impl Fn(usize) -> OpGraph,
    ) -> Result<TapeEngine> {
        let opts = TapeEngineOptions { worker_cap, ..Default::default() };
        Self::build_opts(name, batch_sizes, opts, build)
    }

    /// Like [`from_graph_fn`](Self::from_graph_fn) with full build-time
    /// options: worker cap, per-slot (unshared) arena layout, and a
    /// shared [`ArenaPool`] to draw the contexts' arenas from.
    #[deprecated(
        note = "use Runtime::builder().graph_fn(..) with worker_cap()/unshared_slots()/\
                arena_pool()/shared_pool() and build_engine()"
    )]
    pub fn from_graph_fn_opts(
        name: &str,
        batch_sizes: &[usize],
        opts: TapeEngineOptions,
        build: impl Fn(usize) -> OpGraph,
    ) -> Result<TapeEngine> {
        Self::build_opts(name, batch_sizes, opts, build)
    }

    /// The one constructor behind the deprecated public matrix and
    /// [`RuntimeBuilder::build_engine`](crate::serving::RuntimeBuilder):
    /// contexts from a per-bucket graph builder with full build-time
    /// options.
    pub(crate) fn build_opts(
        name: &str,
        batch_sizes: &[usize],
        opts: TapeEngineOptions,
        build: impl Fn(usize) -> OpGraph,
    ) -> Result<TapeEngine> {
        anyhow::ensure!(!batch_sizes.is_empty(), "need at least one batch size");
        let mut sizes: Vec<usize> = batch_sizes.to_vec();
        sizes.sort_unstable();
        sizes.dedup();
        let mut contexts = HashMap::new();
        let mut example_len = 0usize;
        let mut output_len = 0usize;
        for &batch in &sizes {
            let g = build(batch);
            if let Some(tel) = &opts.telemetry {
                // Node names label replay-op spans in trace export and
                // key the calibration profile (cold path: build only).
                let labels: Vec<&str> =
                    (0..g.n_nodes()).map(|v| g.node(v).name.as_str()).collect();
                tel.register_labels(&labels);
            }
            let plan = rewrite(&g, MatchingAlgo::HopcroftKarp);
            let tape = ReplayTape::for_op_graph(&g, &plan, MAX_TASK_ELEMS);
            anyhow::ensure!(
                tape.input_slots().len() == 1,
                "{name}: expected exactly one input, got {}",
                tape.input_slots().len()
            );
            let in_len = tape.input_slots()[0].1;
            let out_len = g.node(tape.output_slot()).out_shape.numel();
            anyhow::ensure!(
                in_len % batch == 0 && out_len % batch == 0,
                "{name}: lengths not divisible by batch {batch}"
            );
            anyhow::ensure!(
                out_len <= MAX_TASK_ELEMS,
                "{name}: output larger than the substrate clamp"
            );
            if opts.verify != crate::aot::verify::VerifyMode::Off {
                // Certify the same artifact pair the context is about
                // to execute: the compiled tape plus the arena layout
                // its executor will resolve slot views from. Recomputing
                // the layout here duplicates a little build-time work so
                // the verifier stays a pure observer of the build path.
                use crate::aot::memory::{happens_before_conflicts, plan_with_conflicts, ArenaPlan};
                let bytes = tape.slot_bytes();
                let arena = if opts.unshared_slots {
                    ArenaPlan::unshared(&bytes)
                } else {
                    plan_with_conflicts(&bytes, &happens_before_conflicts(&tape))
                };
                let report = crate::aot::verify::verify_with_arena(&tape, &arena);
                if !report.is_clean() {
                    match opts.verify {
                        crate::aot::verify::VerifyMode::Strict => anyhow::bail!(
                            "{name} (bucket {batch}): static plan verification failed\n{}",
                            report.render()
                        ),
                        _ => eprintln!(
                            "warning: {name} (bucket {batch}): plan verifier found \
                             diagnostics (building anyway under VerifyMode::Warn)\n{}",
                            report.render()
                        ),
                    }
                }
            }
            let (per_in, per_out) = (in_len / batch, out_len / batch);
            if example_len == 0 {
                example_len = per_in;
                output_len = per_out;
            } else {
                anyhow::ensure!(
                    example_len == per_in && output_len == per_out,
                    "{name}: inconsistent per-example shapes across batches"
                );
            }
            contexts.insert(
                batch,
                ReplayContext::with_options(
                    tape,
                    SyntheticKernel,
                    ExecOptions {
                        max_workers: opts.worker_cap,
                        unshared_slots: opts.unshared_slots,
                        arena_pool: opts.arena_pool.clone(),
                        shared_pool: opts.shared_pool.clone(),
                        fault: opts.fault.clone(),
                        telemetry: opts.telemetry.clone(),
                        ..Default::default()
                    },
                ),
            );
        }
        Ok(TapeEngine {
            batch_sizes: sizes,
            example_len,
            output_len,
            contexts,
            serial: false,
            shared_pool: opts.shared_pool.is_some(),
        })
    }

    /// Switch to serial-oracle mode: `infer_batch` replays on the
    /// calling thread in merged submission order. The parallel and lane
    /// paths are asserted bit-identical to this in the randomized
    /// differential harness (`tests/prop_harness.rs`).
    pub fn serial(mut self) -> Self {
        self.serial = true;
        self
    }

    /// Direct access to a bucket's context (tests, benches).
    pub fn context_mut(&mut self, batch: usize) -> Option<&mut ReplayContext> {
        self.contexts.get_mut(&batch)
    }
}

impl InferEngine for TapeEngine {
    fn batch_sizes(&self) -> Vec<usize> {
        self.batch_sizes.clone()
    }

    fn example_len(&self) -> usize {
        self.example_len
    }

    fn output_len(&self) -> usize {
        self.output_len
    }

    fn infer_batch(&mut self, bucket: usize, input: &[f32]) -> Result<Vec<f32>> {
        let serial = self.serial;
        let ctx = self
            .contexts
            .get_mut(&bucket)
            .with_context(|| format!("no replay context for batch {bucket}"))?;
        if serial {
            ctx.replay_serial(&[input]).map_err(anyhow::Error::msg)?;
        } else {
            ctx.replay_one(input).map_err(anyhow::Error::msg)?;
        }
        Ok(ctx.output().to_vec())
    }

    fn stream_count(&self, bucket: usize) -> Option<usize> {
        self.contexts.get(&bucket).map(|c| c.n_streams())
    }

    fn reserved_bytes(&self, bucket: usize) -> Option<u64> {
        self.contexts.get(&bucket).map(|c| c.reserved_bytes())
    }

    fn steals(&self) -> Option<u64> {
        if !self.shared_pool {
            return None;
        }
        Some(self.contexts.values().map(|c| c.steal_count()).sum())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Pcg32;

    fn inputs(n: usize, len: usize, seed: u64) -> Vec<Vec<f32>> {
        let mut rng = Pcg32::new(seed);
        (0..n).map(|_| (0..len).map(|_| rng.gen_f32_range(-1.0, 1.0)).collect()).collect()
    }

    fn mini(batch_sizes: &[usize], opts: TapeEngineOptions) -> TapeEngine {
        TapeEngine::build_opts("mini_inception", batch_sizes, opts, |b| {
            models::build("mini_inception", b)
        })
        .expect("mini_inception engine")
    }

    #[test]
    fn engine_reports_consistent_shapes() {
        let e = mini(&[1, 8], TapeEngineOptions::default());
        assert_eq!(e.batch_sizes(), vec![1, 8]);
        assert!(e.example_len() > 0);
        assert!(e.output_len() > 0);
        assert!(e.stream_count(1).unwrap_or(0) >= 1);
        assert!(e.stream_count(4).is_none());
    }

    #[test]
    fn deprecated_constructors_still_build_the_same_engine() {
        #[allow(deprecated)]
        let legacy = TapeEngine::new("mini_inception", &[1, 8]).unwrap();
        let modern = mini(&[1, 8], TapeEngineOptions::default());
        assert_eq!(legacy.batch_sizes(), modern.batch_sizes());
        assert_eq!(legacy.example_len(), modern.example_len());
        assert_eq!(legacy.output_len(), modern.output_len());
    }

    #[test]
    fn batch_one_and_padded_batch_agree_on_shared_prefix() {
        let mut e = mini(&[1, 8], TapeEngineOptions::default());
        let len = e.example_len();
        let x = inputs(1, len, 5).pop().unwrap();
        let out1 = e.infer_batch(1, &x).unwrap();
        assert_eq!(out1.len(), e.output_len());
        // replays are deterministic per bucket
        let out1b = e.infer_batch(1, &x).unwrap();
        assert_eq!(out1, out1b);
    }

    #[test]
    fn engine_reports_reserved_bytes_and_unshared_layout_matches() {
        let mut packed = mini(&[1], TapeEngineOptions::default());
        let mut unshared =
            mini(&[1], TapeEngineOptions { unshared_slots: true, ..Default::default() });
        let packed_bytes = packed.reserved_bytes(1).unwrap();
        let unshared_bytes = unshared.reserved_bytes(1).unwrap();
        assert!(packed_bytes < unshared_bytes, "{packed_bytes} !< {unshared_bytes}");
        assert!(packed.reserved_bytes(4).is_none());
        let x = inputs(1, packed.example_len(), 31).pop().unwrap();
        assert_eq!(
            packed.infer_batch(1, &x).unwrap(),
            unshared.infer_batch(1, &x).unwrap(),
            "arena layout must not leak into results"
        );
    }

    #[test]
    fn pooled_engines_recycle_arenas_across_builds() {
        let pool = crate::aot::memory::ArenaPool::new();
        let opts =
            TapeEngineOptions { arena_pool: Some(pool.clone()), ..Default::default() };
        let e1 = mini(&[1, 2], opts.clone());
        let first = pool.stats();
        assert_eq!(first.acquires, 2, "one arena per bucket context");
        drop(e1);
        assert_eq!(pool.stats().leased_bytes, 0, "arenas return on engine drop");
        let _e2 = mini(&[1, 2], opts);
        let second = pool.stats();
        assert_eq!(second.acquires, 4);
        assert!(second.hits >= 1, "rebuilt buckets must recycle size classes");
        assert_eq!(second.high_water_bytes, first.high_water_bytes, "the pool did not grow");
    }

    #[test]
    fn unknown_bucket_errors() {
        let mut e = mini(&[1], TapeEngineOptions::default());
        assert!(e.infer_batch(4, &[0.0; 16]).is_err());
    }

    #[test]
    fn serial_oracle_and_capped_engine_match_parallel_bitwise() {
        let mut par = mini(&[1, 2], TapeEngineOptions::default());
        let mut ser = mini(&[1, 2], TapeEngineOptions::default()).serial();
        let mut capped =
            mini(&[1, 2], TapeEngineOptions { worker_cap: Some(1), ..Default::default() });
        let len = par.example_len();
        for (i, x) in inputs(3, len, 77).into_iter().enumerate() {
            let a = par.infer_batch(1, &x).unwrap();
            let b = ser.infer_batch(1, &x).unwrap();
            let c = capped.infer_batch(1, &x).unwrap();
            assert_eq!(a, b, "case {i}: parallel vs serial oracle");
            assert_eq!(a, c, "case {i}: parallel vs capped pool");
        }
    }
}
