//! The lane scheduler: pipelined multi-bucket serving.
//!
//! The single-engine-thread server ([`super::server`]) funnels every
//! batch through one thread, so a bucket-1 straggler serializes behind a
//! bucket-8 replay even though their replay contexts are completely
//! independent. [`LaneServer`] turns each compiled batch bucket into an
//! independent **lane**:
//!
//! ```text
//!   clients ──► bounded MPMC admission queue ──► dispatcher thread
//!                                                 │  (batcher + routing)
//!                             ┌───────────────────┼──────────────────┐
//!                             ▼                   ▼                  ▼
//!                     lane[bucket=1]       lane[bucket=4]     lane[bucket=8]
//!                     own InferEngine      own InferEngine    own InferEngine
//! ```
//!
//! * **Admission** is a bounded MPMC queue ([`super::queue::Bounded`]):
//!   when the system is saturated, clients block at the door instead of
//!   queueing unbounded work.
//! * The **dispatcher** runs the dynamic batcher and routes each formed
//!   batch to its bucket's lane. It never blocks on a lane: a batch that
//!   cannot be enqueued is *staged* (per lane, bounded), and when a
//!   lane's stage and buffer pool are exhausted the requests simply wait
//!   in the batcher — so one slow lane never stalls the others
//!   (head-of-line blocking begins only once the global backlog cap is
//!   reached and admission pauses). Padded batch inputs come from a
//!   per-lane pool of reused buffers sized at startup; steady-state
//!   dispatch performs no buffer allocation (instrumented by
//!   [`LaneStat::alloc_events`]).
//! * Each **lane thread** builds its own [`InferEngine`] *on the lane
//!   thread* (PJRT state is not `Send`) restricted to its bucket, and
//!   drains its job queue FIFO — same-bucket batches pipeline in order,
//!   different buckets overlap end-to-end.
//! * Lanes are **elastic** ([`ScaleOptions`]): the dispatcher tracks
//!   per-bucket admission pressure (staged + queued batches, hinted
//!   arrivals) and spawns extra lanes for a saturated bucket up to
//!   `max_lanes_per_bucket`, retiring lanes idle past `idle_retire`
//!   (the seed lane per bucket never retires). Elastic deployments back
//!   every lane with one shared
//!   [`SharedWorkerPool`](crate::engine::executor::SharedWorkerPool)
//!   and one [`ArenaPool`](crate::aot::memory::ArenaPool)
//!   ([`LaneServer::start_elastic_tape`]), so scale-ups re-draw retired
//!   reservations instead of growing the heap and total replay threads
//!   stay capped however many lanes are live. Batches on replica lanes
//!   of one bucket run deterministic engine copies, so outputs stay
//!   bit-identical to the static single-lane scheduler (asserted by the
//!   scaling property in `tests/prop_harness.rs`).
//!
//! * Requests carry optional **deadlines**
//!   ([`RequestOptions::deadline`](crate::serving::RequestOptions)),
//!   and deadlines are the scheduling discipline, not an afterthought
//!   ([`LaneConfig::edf`], on by default). The batcher forms batches
//!   **earliest-deadline-first** (deadline-less requests rank last,
//!   FIFO among equals — a deadline-free workload is bit-identical to
//!   strict FIFO), the dispatcher sheds a request at **admission**
//!   when its per-bucket EWMA queue-delay estimate says the budget
//!   cannot be met ([`ServingReport::admission_shed`]), and deadlines
//!   that expire in the batcher queue or a lane stage are shed by the
//!   dispatcher the moment they come due. A deadline that expires
//!   inside a lane's job queue still sheds at lane-pop time, before
//!   the engine runs it. Shed requests resolve their tickets as
//!   [`InferOutcome::DeadlineShed`](crate::serving::InferOutcome) and
//!   count into [`LaneStat::deadline_shed`]; execution already started
//!   is never interrupted, and surviving rows of a partially-shed batch
//!   stay bit-identical to the oracle. An optional **SLO controller**
//!   ([`LaneConfig::slo`]) holds the live shed rate under a target by
//!   force-spawning lanes for the breaching bucket. The DES predicts
//!   shed counts offline ([`crate::sim::simulate_lanes_deadline`] for
//!   pop-time FIFO, [`crate::sim::simulate_edf`] for the full
//!   admission-estimate + EDF + controller discipline).
//!
//! * Lanes are **supervised**: transient engine failures (errors,
//!   panics, short outputs) are retried in-lane under a bounded
//!   deadline-aware [`RetryPolicy`]; a lane whose replay context is
//!   *poisoned* (fatal — nothing it runs can succeed again) hands its
//!   work to a dead-letter queue and retires, and the dispatcher
//!   rebuilds a replacement lane and re-admits the orphaned jobs.
//!   Requests that exhaust their retry budget resolve as
//!   [`InferOutcome::Failed`](crate::serving::InferOutcome) and count
//!   into [`LaneStat::failed`] — no ticket ever dangles. A bucket whose
//!   rebuild also fails is marked broken and fails fast
//!   ([`Health::Degraded`]).
//!
//! Shutdown closes the admission queue first and then drains everything
//! already admitted: a request whose `push` succeeded is always
//! answered (served, deadline-shed, or failed); later requests fail
//! fast with "server stopped". The randomized differential harness
//! (`tests/prop_harness.rs`) asserts lane-pipelined outputs are
//! bit-identical to the serial-replay oracle.
//!
//! Construct through [`Runtime::builder()`](crate::serving::Runtime) —
//! the `LaneServer::start*` constructors and the `infer*` /
//! `submit_batch` method variants are deprecated shims over the same
//! internals.

use anyhow::{Context, Result};
use std::collections::{HashMap, VecDeque};
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{mpsc, Arc, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use super::batcher::{BatchPolicy, Batcher};
use super::metrics::{LaneStat, ServingReport};
use super::queue::{Bounded, PopResult, PushError};
use super::runtime::{Health, ReqToken};
use crate::coordinator::InferEngine;
use crate::engine::executor::panic_message;
use crate::fault::RetryPolicy;
use crate::telemetry::{EventKind, Telemetry};
use crate::util::stats::Summary;

/// How often the dispatcher runs the scaling pass (reap + retire) while
/// elastic lanes exist. Static deployments (`max_lanes_per_bucket` = 1,
/// nothing retiring) never pay this wakeup.
const SCALE_POLL: Duration = Duration::from_millis(5);

/// Smoothing factor of the per-bucket EWMA batch-service-time estimate
/// (updated at scale-pass cadence from lane completion counters) that
/// drives admission-time shedding and the SLO controller.
const EWMA_ALPHA: f64 = 0.3;

/// Elastic scaling policy ([`LaneConfig::scale`]).
///
/// The dispatcher tracks per-bucket admission pressure — staged + queued
/// batches across the bucket's lanes, plus hinted-bucket arrivals since
/// the last scaling pass — and spawns an extra lane for a bucket whose
/// least-loaded lane is saturated while that pressure is at
/// `scale_up_backlog` or more. A lane with no in-flight work at all —
/// nothing staged, queued, or executing — for `idle_retire` is retired
/// and its engine dropped, returning its arena to the shared
/// [`ArenaPool`](crate::aot::memory::ArenaPool); the bucket's seed lane
/// never retires, so every compiled bucket always has a live engine.
#[derive(Debug, Clone)]
pub struct ScaleOptions {
    /// Max lanes (thread + engine) per batch bucket. 1 = static lanes,
    /// exactly the pre-elastic scheduler.
    pub max_lanes_per_bucket: usize,
    /// Retire an elastic lane once it has been idle this long.
    pub idle_retire: Duration,
    /// Minimum per-bucket pressure (staged + queued batches + hinted
    /// arrivals since the last pass) before a saturated bucket spawns
    /// another lane.
    pub scale_up_backlog: usize,
}

impl Default for ScaleOptions {
    fn default() -> Self {
        ScaleOptions {
            max_lanes_per_bucket: 1,
            idle_retire: Duration::from_millis(50),
            scale_up_backlog: 2,
        }
    }
}

/// Lane-scheduler configuration.
#[derive(Debug, Clone)]
pub struct LaneConfig {
    /// Max time the oldest request may wait before a partial batch flushes.
    pub max_wait: Duration,
    /// Admission-queue capacity; producers block when it is full.
    pub admission_cap: usize,
    /// Per-lane job-queue capacity (batches in flight behind the engine).
    pub lane_cap: usize,
    /// Reused padded-input buffers pooled per lane. Also bounds how many
    /// batcher-formed batches a lane can hold overall (queue + stage).
    pub buffers_per_lane: usize,
    /// The dispatcher pauses admission once this many requests wait in
    /// the batcher — the global backpressure valve.
    pub backlog_cap: usize,
    /// Elastic lane scaling (defaults to static single-lane buckets).
    pub scale: ScaleOptions,
    /// Bounded retry of transiently-failed batches (engine errors and
    /// panics). Retries never extend past a request's deadline.
    pub retry: RetryPolicy,
    /// Deadline-first scheduling (default). The batcher orders staged
    /// requests earliest-deadline-first (deadline-less requests rank
    /// last, FIFO among equals), the dispatcher sheds a request at
    /// *admission* when the per-bucket queue-delay estimate says its
    /// budget cannot be met, and deadlines that expire in the batcher
    /// or a lane stage shed there instead of waiting for a lane pop.
    /// `false` restores the pre-EDF discipline — strict FIFO formation
    /// with pop-time-only shedding — kept as the bench baseline.
    pub edf: bool,
    /// SLO target shed rate (fraction of admitted requests, e.g. 0.05).
    /// When set, a periodic control pass compares the live shed rate —
    /// and the predicted rate over the current backlog, the same
    /// FIFO-server law [`crate::sim::simulate_lanes_deadline`] uses —
    /// against the target and force-spawns a lane for the breaching
    /// bucket, bypassing `scale_up_backlog` but never
    /// `max_lanes_per_bucket`. `None` disables the controller.
    pub slo: Option<f64>,
    /// Flight recorder ([`crate::telemetry::Telemetry`]). When set, the
    /// dispatcher and lanes record request-lifecycle events (admit →
    /// stage → pop / shed → retry → reply) and lane/pool events into its
    /// rings and bump its metrics. `None` (default): no recording, no
    /// overhead.
    pub telemetry: Option<Telemetry>,
}

impl Default for LaneConfig {
    fn default() -> Self {
        LaneConfig {
            max_wait: Duration::from_millis(2),
            admission_cap: 256,
            lane_cap: 4,
            buffers_per_lane: 6,
            backlog_cap: 256,
            scale: ScaleOptions::default(),
            retry: RetryPolicy::default(),
            edf: true,
            slo: None,
            telemetry: None,
        }
    }
}

type Reply = mpsc::Sender<Result<Vec<f32>, String>>;

enum Admit {
    /// One example through the dynamic batcher. `hint` optionally names
    /// the bucket (and so the lane) the request's batch must route to —
    /// honored over queue-depth routing when it names a compiled bucket.
    /// `deadline` sheds the request if it still waits when it expires.
    Infer { input: Vec<f32>, hint: Option<usize>, deadline: Option<Instant>, reply: Reply },
    /// A pre-formed padded batch straight to `bucket`'s lane (benches,
    /// the differential harness, upstream batch-aware clients). Replies
    /// with the full padded output.
    Batch { bucket: usize, input: Vec<f32>, deadline: Option<Instant>, reply: Reply },
}

/// One batch handed to a lane.
struct LaneJob {
    /// Padded batch input (pooled; returned to the lane's pool after use).
    input: Vec<f32>,
    /// Per-request reply tokens in row order (batcher path).
    tokens: Vec<(ReqToken, Instant)>,
    /// Whole-batch reply token (pre-formed-batch path).
    batch: Option<ReqToken>,
    /// When the dispatcher routed the job (queue-wait accounting).
    routed: Instant,
    /// Engine executions this job has survived — carried across lanes
    /// when a dead lane's work is re-admitted, so the retry budget
    /// ([`RetryPolicy::max_retries`]) is global per job, not per lane.
    attempts: u32,
    /// Row-resolution mask (parallel to `tokens`): a row already shed or
    /// answered must not be resolved twice when the job is retried.
    /// Empty until the first lane pop normalizes it.
    done: Vec<bool>,
}

/// Jobs orphaned by a dead lane, waiting for the dispatcher to retry
/// them on a replacement lane or resolve them as failed.
type DeadLetter = Arc<Mutex<Vec<(usize, LaneJob, String)>>>;

/// Shared liveness flags between the dispatcher and the server/client
/// handles (surfaced as [`Health`] via `Runtime::health()`).
pub(crate) struct HealthState {
    draining: AtomicBool,
    degraded: Mutex<Vec<usize>>,
}

impl HealthState {
    pub(crate) fn new() -> Arc<HealthState> {
        Arc::new(HealthState { draining: AtomicBool::new(false), degraded: Mutex::new(Vec::new()) })
    }

    pub(crate) fn set_draining(&self) {
        self.draining.store(true, Ordering::SeqCst);
    }

    fn set_degraded(&self, buckets: Vec<usize>) {
        *self.degraded.lock().unwrap() = buckets;
    }

    pub(crate) fn snapshot(&self) -> Health {
        if self.draining.load(Ordering::SeqCst) {
            return Health::Draining;
        }
        let degraded = self.degraded.lock().unwrap();
        if degraded.is_empty() {
            Health::Healthy
        } else {
            Health::Degraded { buckets: degraded.clone() }
        }
    }
}

/// Dispatcher-side view of one lane instance.
struct Lane {
    bucket: usize,
    jobs: Bounded<LaneJob>,
    free: Bounded<Vec<f32>>,
    /// Formed jobs waiting for queue space (the dispatcher never blocks
    /// on a lane).
    staged: VecDeque<LaneJob>,
    /// Padded-buffer would-allocate events (buffer growth during form).
    alloc_events: u64,
    join: Option<JoinHandle<(LaneStat, Vec<f64>, usize)>>,
    /// Jobs the dispatcher has routed to this lane (staged or pushed).
    routed_jobs: u64,
    /// Jobs the lane thread has finished, published after each batch —
    /// `routed_jobs - done_jobs` is the true in-flight count, including
    /// the batch the engine is executing right now (a queue-only view
    /// would let the scaling pass retire a lane mid-batch).
    done_jobs: Arc<AtomicU64>,
    /// `done_jobs` value last observed by the scaling pass.
    seen_done: u64,
    /// Cumulative nanoseconds the lane engine spent inside
    /// `infer_batch`, published after each attempt — with `done_jobs`
    /// this yields the per-bucket service-time EWMA behind
    /// admission-time shedding and the SLO controller.
    busy_ns: Arc<AtomicU64>,
    /// `busy_ns` value last observed by the scaling pass.
    seen_busy_ns: u64,
    /// Requests this lane thread has deadline-shed so far, published
    /// live (the folded [`LaneStat`] only lands at join) — the SLO
    /// controller's feedback signal.
    shed_live: Arc<AtomicU64>,
    /// Last routing or observed completion (idle-retire clock).
    last_active: Instant,
    /// Elastic lanes may retire; the per-bucket seed lane never does.
    elastic: bool,
}

impl Lane {
    /// Batches routed to this lane and not yet completed: staged +
    /// queued + the one the engine is executing. The routing and
    /// pressure load metric, and the scaling pass's busy test.
    fn in_flight(&self) -> usize {
        self.routed_jobs.saturating_sub(self.done_jobs.load(Ordering::Relaxed)) as usize
    }

    /// Route one job to this lane (both the batcher-formed and the
    /// pre-formed-batch path go through here so the in-flight and
    /// idleness accounting cannot drift).
    fn stage(&mut self, job: LaneJob) {
        self.routed_jobs += 1;
        self.last_active = Instant::now();
        self.staged.push_back(job);
    }
}

/// All lanes — live, and draining toward retirement — of one batch
/// bucket, plus the folded stats of lanes already gone.
struct LaneGroup {
    bucket: usize,
    /// Live lanes; `lanes[0]` is the seed lane and never retires.
    lanes: Vec<Lane>,
    /// Retired/dead lanes whose job queues are closed; joined (and their
    /// stats folded) once their threads finish draining.
    retiring: Vec<Lane>,
    /// Lanes ever spawned for this bucket (seed included).
    spawned: usize,
    /// Elastic lanes retired before shutdown.
    retired: usize,
    /// Hinted arrivals for this bucket since the last scaling pass (one
    /// of the admission-pressure inputs).
    hinted_since_scale: usize,
    /// Folded runtime counters of joined lanes.
    stat: LaneStat,
    latencies: Vec<f64>,
    fill_sum: usize,
    /// Padded buffers recovered from retired lanes, re-seeded into the
    /// next spawned lane so scale-up re-uses warm allocations.
    spare_buffers: Vec<Vec<f32>>,
    /// Set when the bucket's last lane died AND rebuilding a replacement
    /// failed: the bucket fails fast from then on (and the server
    /// reports `Health::Degraded`) instead of rebuilding forever.
    broken: Option<String>,
}

impl LaneGroup {
    fn new(bucket: usize, seed: Lane) -> LaneGroup {
        LaneGroup {
            bucket,
            lanes: vec![seed],
            retiring: Vec::new(),
            spawned: 1,
            retired: 0,
            hinted_since_scale: 0,
            stat: LaneStat::empty(bucket),
            latencies: Vec::new(),
            fill_sum: 0,
            spare_buffers: Vec::new(),
            broken: None,
        }
    }

    /// Index of the least-loaded live lane (ties go to the seed end, so
    /// low traffic concentrates on the seed and elastic lanes go idle).
    fn pick_lane(&self) -> usize {
        let mut best = 0;
        let mut best_load = usize::MAX;
        for (i, lane) in self.lanes.iter().enumerate() {
            let load = lane.in_flight();
            if load < best_load {
                best_load = load;
                best = i;
            }
        }
        best
    }

    /// Per-bucket admission pressure: batches in flight across live
    /// lanes plus hinted arrivals since the last scaling pass.
    fn pressure(&self) -> usize {
        self.lanes.iter().map(Lane::in_flight).sum::<usize>() + self.hinted_since_scale
    }

    /// Join a finished lane thread and fold its counters in. Anything
    /// the lane thread never answered — staged jobs, or queue leftovers
    /// of a thread that died early — is resolved as failed here, so no
    /// ticket ever dangles past the fold.
    fn fold_joined(&mut self, mut lane: Lane) {
        // Recover pooled padded buffers for the next spawn.
        while let Some(buf) = lane.free.try_pop() {
            self.spare_buffers.push(buf);
        }
        self.stat.alloc_events += lane.alloc_events;
        if let Some(handle) = lane.join.take() {
            if let Ok((stat, latencies, fill)) = handle.join() {
                self.stat.absorb(&stat);
                self.latencies.extend(latencies);
                self.fill_sum += fill;
            }
        }
        lane.jobs.close();
        let msg = format!("lane {} shut down before serving this job", self.bucket);
        for job in lane.staged.drain(..) {
            self.stat.failed += fail_job(job, &msg);
        }
        while let Some(job) = lane.jobs.try_pop() {
            self.stat.failed += fail_job(job, &msg);
        }
    }
}

/// Resolve every still-unresolved request of a job as failed; returns
/// how many were failed (a pre-formed batch counts as one request,
/// matching `n_requests` accounting).
fn fail_job(job: LaneJob, msg: &str) -> usize {
    let LaneJob { tokens, batch, done, .. } = job;
    fail_requests(tokens, batch, &done, msg)
}

/// [`fail_job`] over a job's already-destructured parts.
fn fail_requests(
    tokens: Vec<(ReqToken, Instant)>,
    batch: Option<ReqToken>,
    done: &[bool],
    msg: &str,
) -> usize {
    let mut failed = 0;
    if let Some(tok) = batch {
        let _ = tok.reply.send(Err(msg.to_string()));
        failed += 1;
    }
    for (i, (tok, _)) in tokens.into_iter().enumerate() {
        if done.get(i).copied().unwrap_or(false) {
            continue;
        }
        let _ = tok.reply.send(Err(msg.to_string()));
        failed += 1;
    }
    failed
}

/// True when at least one unresolved request of the job could still be
/// served by an execution happening at `at` (requests without deadlines
/// always qualify) — the deadline-aware retry gate: a retry no request
/// could benefit from is skipped and the job resolves immediately.
fn retry_viable(job: &LaneJob, at: Instant) -> bool {
    if let Some(tok) = &job.batch {
        return !tok.expired(at);
    }
    job.tokens
        .iter()
        .zip(&job.done)
        .any(|((tok, _), done)| !done && !tok.expired(at))
}

/// Push staged jobs into the lane queue until it fills (non-blocking).
fn flush_staged(lane: &mut Lane) {
    while let Some(job) = lane.staged.pop_front() {
        match lane.jobs.try_push(job) {
            Ok(()) => {}
            Err(PushError::Full(job)) => {
                lane.staged.push_front(job);
                break;
            }
            // The lane died (its engine build failed closed the queue):
            // keep the job staged — the scaling pass re-routes a dead
            // lane's stage to the group's surviving lanes rather than
            // failing requests the seed lane could serve.
            Err(PushError::Closed(job)) => {
                lane.staged.push_front(job);
                break;
            }
        }
    }
}

/// Earliest deadline among unresolved requests staged at any lane —
/// jobs the batcher no longer sees. Folded into the dispatcher's wait
/// deadline so a deadline whose only copy sits in a staged batch still
/// wakes the dispatcher on time; it would otherwise shed only at the
/// next unrelated wakeup, later than the `now >= deadline` rule
/// promises.
fn staged_min_deadline(groups: &[LaneGroup]) -> Option<Instant> {
    let mut min: Option<Instant> = None;
    let mut fold = |d: Option<Instant>| {
        if let Some(d) = d {
            min = Some(min.map_or(d, |m| m.min(d)));
        }
    };
    for group in groups {
        for lane in &group.lanes {
            for job in &lane.staged {
                if let Some(tok) = &job.batch {
                    fold(tok.deadline);
                }
                for (i, (tok, _)) in job.tokens.iter().enumerate() {
                    if !job.done.get(i).copied().unwrap_or(false) {
                        fold(tok.deadline);
                    }
                }
            }
        }
    }
    min
}

/// Dispatcher-side shed pass: resolve every request whose deadline has
/// already expired while it waits where the lane pop cannot see it —
/// the batcher queue (EDF order keeps expired entries a contiguous
/// prefix) and the per-lane stages. Shed staged rows are marked done in
/// place and the job stays staged, so the routed/done accounting is
/// untouched and the lane pop recycles an all-shed job without running
/// the engine. Batcher sheds (no definite bucket) land in `misc_shed`;
/// staged sheds in the owning bucket's stat.
fn shed_expired_work(
    groups: &mut [LaneGroup],
    batcher: &mut Batcher<ReqToken>,
    now: Instant,
    misc_shed: &mut usize,
    telemetry: Option<&Telemetry>,
) {
    for tok in batcher.shed_expired(now) {
        tok.shed();
        if let Some(tel) = telemetry {
            // No definite bucket yet: the batcher queue is bucket-less.
            tel.event(EventKind::ShedStaged, 0, 0, tok.trace);
        }
        *misc_shed += 1;
    }
    for group in groups.iter_mut() {
        let bucket = group.bucket as u32;
        let mut shed = 0usize;
        for lane in &mut group.lanes {
            for job in &mut lane.staged {
                if let Some(tok) = &job.batch {
                    if tok.expired(now) {
                        tok.shed();
                        if let Some(tel) = telemetry {
                            tel.event(EventKind::ShedStaged, bucket, 0, tok.trace);
                        }
                        shed += 1;
                        job.batch = None;
                    }
                }
                if job.done.len() != job.tokens.len() {
                    job.done = vec![false; job.tokens.len()];
                }
                for ((tok, _), done) in job.tokens.iter().zip(job.done.iter_mut()) {
                    if !*done && tok.expired(now) {
                        tok.shed();
                        if let Some(tel) = telemetry {
                            tel.event(EventKind::ShedStaged, bucket, 0, tok.trace);
                        }
                        shed += 1;
                        *done = true;
                    }
                }
            }
        }
        group.stat.deadline_shed += shed;
    }
}

/// Estimated queue delay (seconds) a request admitted *now* would see
/// before its batch starts on one of `group`'s lanes: the EWMA batch
/// service time scaled by the per-lane backlog it queues behind, plus
/// its own slot. 0 while the estimate is unknown (no completed batch
/// yet), so a cold server never sheds a live budget.
fn admission_estimate_s(group: &LaneGroup, ewma_s: f64) -> f64 {
    if ewma_s <= 0.0 {
        return 0.0;
    }
    let lanes = group.lanes.len().max(1);
    let backlog: usize = group.lanes.iter().map(Lane::in_flight).sum();
    ewma_s * (backlog as f64 / lanes as f64 + 1.0)
}

/// The admission-time shed test: true when the request's budget already
/// cannot be met — it is expired at the door (`now >= deadline`,
/// deterministic regardless of the estimate), or the queue-delay
/// estimate reaches past its deadline. Hinted and pre-formed-batch
/// requests are judged against their bucket; an unhinted request
/// against the most optimistic bucket (it sheds only when every bucket
/// is doomed). Deadline-less requests never shed here.
fn admission_doomed(
    deadline: Option<Instant>,
    hint_gi: Option<usize>,
    groups: &[LaneGroup],
    ewma: &[f64],
    now: Instant,
) -> bool {
    let Some(d) = deadline else { return false };
    if now >= d {
        return true;
    }
    let est = match hint_gi {
        Some(gi) => admission_estimate_s(&groups[gi], ewma[gi]),
        None => groups
            .iter()
            .zip(ewma)
            .map(|(g, &e)| admission_estimate_s(g, e))
            .fold(f64::INFINITY, f64::min),
    };
    if !est.is_finite() {
        return false;
    }
    now + Duration::from_secs_f64(est) >= d
}

/// Index of the bucket with the lowest queue-delay estimate — where an
/// unhinted admission-shed is attributed (the bucket that came closest
/// to serving it).
fn best_group(groups: &[LaneGroup], ewma: &[f64]) -> usize {
    let mut best = 0;
    let mut best_est = f64::INFINITY;
    for (gi, (group, &e)) in groups.iter().zip(ewma).enumerate() {
        let est = admission_estimate_s(group, e);
        if est < best_est {
            best_est = est;
            best = gi;
        }
    }
    best
}

/// Live deadline-shed total across the server: per-bucket folded stats
/// (admission + staged sheds, and lanes already joined) plus the
/// running counters of lane threads still alive. Monotone — a lane's
/// counter is absorbed into its group's stat exactly when the lane is
/// folded away. The SLO controller's feedback signal.
fn live_shed(groups: &[LaneGroup]) -> u64 {
    let mut total = 0u64;
    for group in groups {
        total += group.stat.deadline_shed as u64;
        for lane in group.lanes.iter().chain(&group.retiring) {
            total += lane.shed_live.load(Ordering::Relaxed);
        }
    }
    total
}

/// Shed-rate totals at the SLO controller's last control pass
/// ([`LaneConfig::slo`]); deltas against them give the per-window rate.
struct SloWindow {
    admitted: u64,
    shed: u64,
}

/// The per-lane worker: builds the engine on this thread, reports its
/// shape, then drains the job queue FIFO until it closes. Transient
/// engine failures (errors, panics, short outputs) are retried in-lane
/// under the [`RetryPolicy`]; a *fatal* failure — a poisoned replay
/// context, which can serve nothing further — dead-letters the current
/// job plus everything queued and retires the thread, leaving the
/// dispatcher's supervision pass to spawn a replacement. Returns
/// `(stats, per-request latencies, real-example fill sum)`.
#[allow(clippy::too_many_arguments)]
fn lane_thread<E, F>(
    factory: Arc<F>,
    bucket: usize,
    jobs: Bounded<LaneJob>,
    free: Bounded<Vec<f32>>,
    done_jobs: Arc<AtomicU64>,
    busy_ns: Arc<AtomicU64>,
    shed_live: Arc<AtomicU64>,
    wake: Bounded<Admit>,
    ready: mpsc::Sender<Result<(usize, usize), String>>,
    retry: RetryPolicy,
    dead_letter: DeadLetter,
    telemetry: Option<Telemetry>,
) -> (LaneStat, Vec<f64>, usize)
where
    E: InferEngine + 'static,
    F: Fn(usize) -> Result<E> + Send + Sync + 'static,
{
    let mut stat = LaneStat::empty(bucket);
    let mut latencies: Vec<f64> = Vec::new();
    let mut fill_sum = 0usize;
    // Flight-recorder hook: one event into this thread's ring (no-op
    // when telemetry is off). Lifecycle invariant: LaneSpawn here,
    // LaneRetire on every exit path, so the live-lanes gauge closes.
    let tev = |kind: EventKind, op: u32, trace: u64| {
        if let Some(tel) = &telemetry {
            tel.event(kind, bucket as u32, op, trace);
        }
    };
    tev(EventKind::LaneSpawn, 0, 0);
    // A lane that cannot build its engine must not strand work: close
    // the queue itself (elastic spawns have no startup handshake) and
    // answer whatever the dispatcher already routed.
    let die = |stat: &mut LaneStat, msg: String| {
        let _ = ready.send(Err(msg.clone()));
        jobs.close();
        while let Some(job) = jobs.try_pop() {
            stat.failed += fail_job(job, &msg);
        }
    };
    let mut engine = match factory(bucket) {
        Ok(e) => e,
        Err(err) => {
            die(&mut stat, format!("lane {bucket}: {err:#}"));
            tev(EventKind::LaneRetire, 0, 0);
            return (stat, latencies, fill_sum);
        }
    };
    if !engine.batch_sizes().contains(&bucket) {
        die(&mut stat, format!("lane {bucket}: engine does not serve this bucket"));
        tev(EventKind::LaneRetire, 0, 0);
        return (stat, latencies, fill_sum);
    }
    let output_len = engine.output_len();
    stat.n_streams = engine.stream_count(bucket);
    stat.reserved_bytes = engine.reserved_bytes(bucket);
    let _ = ready.send(Ok((engine.example_len(), output_len)));

    let mut wait_sum = 0.0f64;
    while let Some(mut job) = jobs.pop() {
        // The pop freed a job-queue slot: kick the dispatcher so staged
        // work flushes into it on the event instead of a poll tick.
        wake.kick();
        tev(EventKind::Kick, 0, 0);
        let rows = job.tokens.len().max(usize::from(job.batch.is_some()));
        tev(
            EventKind::Pop,
            rows as u32,
            job.batch.as_ref().map_or(0, |tok| tok.trace),
        );
        let started = Instant::now();
        // Deadline shedding happens HERE, at pop time: a request whose
        // deadline expired while it was staged or queued is resolved as
        // shed and never reaches the engine. Shed rows stay in the
        // padded input (surviving rows keep their positions); a job
        // with nothing live left skips the engine entirely.
        if let Some(tok) = &job.batch {
            if tok.expired(started) {
                tok.shed();
                tev(EventKind::ShedPop, 0, tok.trace);
                stat.deadline_shed += 1;
                shed_live.fetch_add(1, Ordering::Relaxed);
                let _ = free.try_push(job.input);
                done_jobs.fetch_add(1, Ordering::Relaxed);
                wake.kick();
                tev(EventKind::Kick, 0, 0);
                continue;
            }
        }
        if job.done.len() != job.tokens.len() {
            job.done = vec![false; job.tokens.len()];
        }
        for ((tok, _), done) in job.tokens.iter().zip(job.done.iter_mut()) {
            if !*done && tok.expired(started) {
                tok.shed();
                tev(EventKind::ShedPop, 0, tok.trace);
                stat.deadline_shed += 1;
                shed_live.fetch_add(1, Ordering::Relaxed);
                *done = true;
            }
        }
        if job.batch.is_none() && job.done.iter().all(|d| *d) {
            let _ = free.try_push(job.input);
            done_jobs.fetch_add(1, Ordering::Relaxed);
            wake.kick();
            tev(EventKind::Kick, 0, 0);
            continue;
        }
        wait_sum += started.duration_since(job.routed).as_secs_f64();
        stat.n_batches += 1;
        // Execute with bounded in-lane retry. An engine panic must not
        // kill the lane: it is caught and treated like any transient
        // engine error. A *poisoned* replay context is fatal — nothing
        // this engine runs can ever succeed again — so the lane hands
        // all its work to the dead-letter queue and retires itself.
        let result = loop {
            let t0 = Instant::now();
            let attempt = catch_unwind(AssertUnwindSafe(|| engine.infer_batch(bucket, &job.input)))
                .unwrap_or_else(|p| {
                    Err(anyhow::anyhow!("lane {bucket} engine panicked: {}", panic_message(p)))
                });
            let spent = t0.elapsed();
            stat.busy_s += spent.as_secs_f64();
            busy_ns.fetch_add(spent.as_nanos() as u64, Ordering::Relaxed);
            job.attempts += 1;
            // A short output would panic the row slicing below (outside
            // the per-job panic guard) and kill the lane; demote it to a
            // retryable per-job error instead.
            let attempt = attempt.and_then(|out| {
                let needed = job.tokens.len() * output_len;
                anyhow::ensure!(
                    out.len() >= needed,
                    "lane {bucket}: engine returned {} values, need {needed}",
                    out.len()
                );
                Ok(out)
            });
            let err = match attempt {
                Ok(out) => break Ok(out),
                Err(err) => err,
            };
            let msg = format!("{err:#}");
            if msg.contains("poisoned") {
                jobs.close();
                {
                    let mut dl = dead_letter.lock().unwrap();
                    let queued_msg = format!("lane {bucket} died: {msg}");
                    dl.push((bucket, job, msg));
                    while let Some(q) = jobs.try_pop() {
                        dl.push((bucket, q, queued_msg.clone()));
                    }
                }
                stat.mean_queue_wait_s =
                    if stat.n_batches == 0 { 0.0 } else { wait_sum / stat.n_batches as f64 };
                stat.steals = engine.steals().unwrap_or(0);
                // Wake the dispatcher so the supervision pass notices
                // the dead-lettered work before its next timed tick.
                wake.kick();
                tev(EventKind::Kick, 0, 0);
                tev(EventKind::LaneRetire, 0, 0);
                return (stat, latencies, fill_sum);
            }
            if job.attempts > retry.max_retries
                || !retry_viable(&job, Instant::now() + retry.backoff)
            {
                break Err(msg);
            }
            stat.retries += 1;
            tev(EventKind::Retry, job.attempts, job.batch.as_ref().map_or(0, |t| t.trace));
            if !retry.backoff.is_zero() {
                std::thread::sleep(retry.backoff);
            }
            // Shed whatever expired during the failed attempt or the
            // backoff; a job with nothing live left is already resolved.
            let now = Instant::now();
            if let Some(tok) = &job.batch {
                if tok.expired(now) {
                    tok.shed();
                    tev(EventKind::ShedPop, 0, tok.trace);
                    stat.deadline_shed += 1;
                    shed_live.fetch_add(1, Ordering::Relaxed);
                    job.batch = None;
                    break Ok(Vec::new());
                }
            } else {
                for ((tok, _), done) in job.tokens.iter().zip(job.done.iter_mut()) {
                    if !*done && tok.expired(now) {
                        tok.shed();
                        tev(EventKind::ShedPop, 0, tok.trace);
                        stat.deadline_shed += 1;
                        shed_live.fetch_add(1, Ordering::Relaxed);
                        *done = true;
                    }
                }
                if job.done.iter().all(|d| *d) {
                    break Ok(Vec::new());
                }
            }
        };
        let finished = Instant::now();
        let LaneJob { input, tokens, batch, routed, done, .. } = job;
        match result {
            Ok(out) => {
                if let Some(tok) = batch {
                    // A pre-formed batch counts as one request of
                    // `bucket` padded rows.
                    stat.n_requests += 1;
                    fill_sum += bucket;
                    latencies.push(finished.duration_since(routed).as_secs_f64());
                    if let Some(tel) = &telemetry {
                        tel.reply_span(bucket as u32, tok.trace, routed, finished);
                    }
                    let _ = tok.reply.send(Ok(out));
                } else {
                    for (i, ((tok, enqueued), was_done)) in
                        tokens.into_iter().zip(done).enumerate()
                    {
                        if was_done {
                            continue;
                        }
                        stat.n_requests += 1;
                        fill_sum += 1;
                        latencies.push(finished.duration_since(enqueued).as_secs_f64());
                        if let Some(tel) = &telemetry {
                            tel.reply_span(bucket as u32, tok.trace, enqueued, finished);
                        }
                        let row = out[i * output_len..(i + 1) * output_len].to_vec();
                        let _ = tok.reply.send(Ok(row));
                    }
                }
            }
            Err(msg) => {
                stat.failed +=
                    fail_requests(tokens, batch, &done, &msg);
            }
        }
        // Recycle the padded buffer (dropped if the pool is full),
        // publish the completion (the scaling pass's in-flight clock),
        // and kick the dispatcher: a buffer and a job slot just freed,
        // which is exactly the event a stalled formation pass waits on.
        let _ = free.try_push(input);
        done_jobs.fetch_add(1, Ordering::Relaxed);
        wake.kick();
        tev(EventKind::Kick, 0, 0);
    }
    stat.mean_queue_wait_s =
        if stat.n_batches == 0 { 0.0 } else { wait_sum / stat.n_batches as f64 };
    stat.steals = engine.steals().unwrap_or(0);
    tev(EventKind::LaneRetire, 0, 0);
    (stat, latencies, fill_sum)
}

/// A lane thread's startup handshake: `(example_len, output_len)` on a
/// successful engine build, the build error otherwise.
type ReadySignal = mpsc::Receiver<Result<(usize, usize), String>>;

/// Spawn one lane instance (thread + queues). The engine is built on the
/// lane thread; seed lanes block on the returned readiness channel at
/// server start. Elastic spawns drop the channel: a failed elastic build
/// closes the lane's queue (jobs already queued are answered with the
/// build error) and the scaling pass re-routes its staged work to the
/// group's survivors.
fn spawn_lane<E, F>(
    factory: &Arc<F>,
    bucket: usize,
    config: &LaneConfig,
    elastic: bool,
    dead_letter: &DeadLetter,
    wake: &Bounded<Admit>,
) -> Result<(Lane, ReadySignal)>
where
    E: InferEngine + 'static,
    F: Fn(usize) -> Result<E> + Send + Sync + 'static,
{
    let jobs: Bounded<LaneJob> = Bounded::new(config.lane_cap);
    let free: Bounded<Vec<f32>> = Bounded::new(config.buffers_per_lane);
    let done_jobs = Arc::new(AtomicU64::new(0));
    let busy_ns = Arc::new(AtomicU64::new(0));
    let shed_live = Arc::new(AtomicU64::new(0));
    let (ready_tx, ready_rx) = mpsc::channel();
    let join = {
        let factory = Arc::clone(factory);
        let jobs = jobs.clone();
        let free = free.clone();
        let done_jobs = Arc::clone(&done_jobs);
        let busy_ns = Arc::clone(&busy_ns);
        let shed_live = Arc::clone(&shed_live);
        let wake = wake.clone();
        let retry = config.retry.clone();
        let dead_letter = Arc::clone(dead_letter);
        let telemetry = config.telemetry.clone();
        std::thread::Builder::new()
            .name(format!("nimble-lane-{bucket}"))
            .spawn(move || {
                lane_thread(
                    factory,
                    bucket,
                    jobs,
                    free,
                    done_jobs,
                    busy_ns,
                    shed_live,
                    wake,
                    ready_tx,
                    retry,
                    dead_letter,
                    telemetry,
                )
            })
            .context("spawning lane thread")?
    };
    Ok((
        Lane {
            bucket,
            jobs,
            free,
            staged: VecDeque::new(),
            alloc_events: 0,
            join: Some(join),
            routed_jobs: 0,
            done_jobs,
            seen_done: 0,
            busy_ns,
            seen_busy_ns: 0,
            shed_live,
            last_active: Instant::now(),
            elastic,
        },
        ready_rx,
    ))
}

/// Spawn an elastic lane for a saturated group if the scaling policy
/// allows; returns the new lane's index. The lane's padded-buffer pool
/// is seeded from the group's spare buffers (recovered from retired
/// lanes) so repeat scale-ups re-use warm allocations. `force` (the
/// SLO controller's spawn) bypasses the `scale_up_backlog` pressure
/// gate but never `max_lanes_per_bucket`.
#[allow(clippy::too_many_arguments)]
fn maybe_spawn<E, F>(
    group: &mut LaneGroup,
    config: &LaneConfig,
    example_len: usize,
    factory: &Arc<F>,
    dead_letter: &DeadLetter,
    wake: &Bounded<Admit>,
    force: bool,
) -> Option<usize>
where
    E: InferEngine + 'static,
    F: Fn(usize) -> Result<E> + Send + Sync + 'static,
{
    if group.lanes.len() >= config.scale.max_lanes_per_bucket
        || (!force && group.pressure() < config.scale.scale_up_backlog)
    {
        return None;
    }
    let Ok((lane, _ready)) = spawn_lane(factory, group.bucket, config, true, dead_letter, wake)
    else {
        return None;
    };
    for _ in 0..config.buffers_per_lane {
        let buf = group
            .spare_buffers
            .pop()
            .unwrap_or_else(|| Vec::with_capacity(group.bucket * example_len));
        let _ = lane.free.try_push(buf);
    }
    group.spawned += 1;
    group.lanes.push(lane);
    Some(group.lanes.len() - 1)
}

/// Route a pre-formed batch to its bucket's least-loaded lane, spawning
/// an elastic lane when that lane is saturated and the scaling policy
/// allows, and shedding load only once the group cannot grow.
#[allow(clippy::too_many_arguments)]
fn route_batch<E, F>(
    group: &mut LaneGroup,
    stage_cap: usize,
    input: Vec<f32>,
    deadline: Option<Instant>,
    reply: Reply,
    trace: u64,
    config: &LaneConfig,
    example_len: usize,
    factory: &Arc<F>,
    dead_letter: &DeadLetter,
    wake: &Bounded<Admit>,
) where
    E: InferEngine + 'static,
    F: Fn(usize) -> Result<E> + Send + Sync + 'static,
{
    if group.lanes.is_empty() {
        let msg = group
            .broken
            .clone()
            .unwrap_or_else(|| format!("lane {} unavailable", group.bucket));
        let _ = reply.send(Err(msg));
        group.stat.failed += 1;
        return;
    }
    let mut li = group.pick_lane();
    if group.lanes[li].staged.len() >= stage_cap {
        match maybe_spawn(group, config, example_len, factory, dead_letter, wake, false) {
            Some(fresh) => li = fresh,
            None => {
                let _ = reply.send(Err(format!(
                    "lane {} overloaded: {} batches staged",
                    group.bucket,
                    group.lanes[li].staged.len()
                )));
                group.stat.failed += 1;
                return;
            }
        }
    }
    if let Some(tel) = &config.telemetry {
        tel.event(EventKind::Stage, group.bucket as u32, 0, trace);
    }
    let lane = &mut group.lanes[li];
    lane.stage(LaneJob {
        input,
        tokens: Vec::new(),
        batch: Some(ReqToken { reply, deadline, trace }),
        routed: Instant::now(),
        attempts: 0,
        done: Vec::new(),
    });
    flush_staged(lane);
}

/// Handle one admitted `Infer`/`Batch` message. `stage_cap` bounds the
/// per-lane stage for pre-formed batches; the shutdown drain passes
/// `usize::MAX` so nothing already admitted is ever load-shed.
/// `misc_failed` counts requests rejected here without reaching a lane
/// (malformed lengths, unknown buckets) so the report's accounting
/// still closes. Under EDF ([`LaneConfig::edf`]) a deadline the
/// per-bucket queue-delay estimate already rules out is shed HERE, at
/// admission, before the request occupies backlog ([`admission_doomed`]);
/// `admitted` counts well-formed arrivals (the SLO controller's rate
/// denominator).
#[allow(clippy::too_many_arguments)]
fn admit_one<E, F>(
    msg: Admit,
    groups: &mut [LaneGroup],
    group_index: &HashMap<usize, usize>,
    batcher: &mut Batcher<ReqToken>,
    example_len: usize,
    stage_cap: usize,
    config: &LaneConfig,
    factory: &Arc<F>,
    dead_letter: &DeadLetter,
    wake: &Bounded<Admit>,
    ewma: &[f64],
    misc_failed: &mut usize,
    admitted: &mut u64,
) where
    E: InferEngine + 'static,
    F: Fn(usize) -> Result<E> + Send + Sync + 'static,
{
    match msg {
        Admit::Infer { input, hint, deadline, reply } => {
            if input.len() != example_len {
                let _ =
                    reply.send(Err(format!("bad input length {} != {example_len}", input.len())));
                *misc_failed += 1;
            } else {
                *admitted += 1;
                let trace = config.telemetry.as_ref().map_or(0, Telemetry::next_trace_id);
                let hint_gi = hint.and_then(|h| group_index.get(&h)).copied();
                if let Some(tel) = &config.telemetry {
                    tel.event(EventKind::Admit, hint.unwrap_or(0) as u32, 0, trace);
                }
                if config.edf
                    && admission_doomed(deadline, hint_gi, groups, ewma, Instant::now())
                {
                    let gi = hint_gi.unwrap_or_else(|| best_group(groups, ewma));
                    if let Some(tel) = &config.telemetry {
                        tel.event(
                            EventKind::ShedAdmission,
                            groups[gi].bucket as u32,
                            0,
                            trace,
                        );
                    }
                    ReqToken { reply, deadline, trace }.shed();
                    groups[gi].stat.deadline_shed += 1;
                    groups[gi].stat.admission_shed += 1;
                } else {
                    // Hinted arrivals feed the bucket's admission pressure.
                    if let Some(gi) = hint_gi {
                        groups[gi].hinted_since_scale += 1;
                    }
                    if let Some(tel) = &config.telemetry {
                        tel.event(EventKind::Stage, hint.unwrap_or(0) as u32, 0, trace);
                    }
                    if config.edf {
                        batcher.push_request(
                            ReqToken { reply, deadline, trace },
                            input,
                            hint,
                            deadline,
                        );
                    } else {
                        batcher.push_hinted(ReqToken { reply, deadline, trace }, input, hint);
                    }
                }
            }
        }
        Admit::Batch { bucket, input, deadline, reply } => match group_index.get(&bucket) {
            Some(&gi) if input.len() == bucket * example_len => {
                *admitted += 1;
                let trace = config.telemetry.as_ref().map_or(0, Telemetry::next_trace_id);
                if let Some(tel) = &config.telemetry {
                    tel.event(EventKind::Admit, bucket as u32, 0, trace);
                }
                if config.edf
                    && admission_doomed(deadline, Some(gi), groups, ewma, Instant::now())
                {
                    if let Some(tel) = &config.telemetry {
                        tel.event(EventKind::ShedAdmission, bucket as u32, 0, trace);
                    }
                    ReqToken { reply, deadline, trace }.shed();
                    groups[gi].stat.deadline_shed += 1;
                    groups[gi].stat.admission_shed += 1;
                    return;
                }
                route_batch(
                    &mut groups[gi],
                    stage_cap,
                    input,
                    deadline,
                    reply,
                    trace,
                    config,
                    example_len,
                    factory,
                    dead_letter,
                    wake,
                );
            }
            Some(_) => {
                let _ = reply.send(Err(format!(
                    "bad batch length {} != {}",
                    input.len(),
                    bucket * example_len
                )));
                *misc_failed += 1;
            }
            None => {
                let _ = reply.send(Err(format!("no lane for bucket {bucket}")));
                *misc_failed += 1;
            }
        },
    }
}

/// The periodic scaling + supervision pass: reap finished retiring
/// lanes, detect dead lanes (engine build failed, fatal poisoned
/// context, or a thread that died without cleanup), rebuild a
/// replacement when a bucket loses its last lane, and retire elastic
/// lanes idle past the quiescence window. Spawning for load is
/// event-driven (at routing time, where saturation is observed), not
/// part of this pass.
fn scale_groups<E, F>(
    groups: &mut [LaneGroup],
    config: &LaneConfig,
    example_len: usize,
    factory: &Arc<F>,
    dead_letter: &DeadLetter,
    wake: &Bounded<Admit>,
    ewma: &mut [f64],
) where
    E: InferEngine + 'static,
    F: Fn(usize) -> Result<E> + Send + Sync + 'static,
{
    for (group, bucket_ewma) in groups.iter_mut().zip(ewma.iter_mut()) {
        // Reap retiring lanes whose threads finished draining.
        let mut i = 0;
        while i < group.retiring.len() {
            let finished =
                group.retiring[i].join.as_ref().map_or(true, |handle| handle.is_finished());
            if finished {
                let lane = group.retiring.swap_remove(i);
                group.fold_joined(lane);
            } else {
                i += 1;
            }
        }
        // Advance each live lane's idleness clock past any completions
        // since the last pass (completion times themselves are not
        // published; observing them at pass cadence only delays retire
        // by at most one SCALE_POLL, never hastens it), and fold the
        // window's mean batch service time into the bucket's EWMA —
        // the queue-delay estimate behind admission-time shedding and
        // the SLO controller. Jobs resolved without running the engine
        // (all rows shed) dilute the mean; the estimator tolerates
        // that: it only ever under-estimates, never sheds spuriously.
        for lane in &mut group.lanes {
            let done = lane.done_jobs.load(Ordering::Relaxed);
            if done != lane.seen_done {
                let busy = lane.busy_ns.load(Ordering::Relaxed);
                let jobs = done - lane.seen_done;
                let busy_delta = busy.saturating_sub(lane.seen_busy_ns);
                lane.seen_done = done;
                lane.seen_busy_ns = busy;
                lane.last_active = Instant::now();
                if busy_delta > 0 {
                    let sample = busy_delta as f64 / 1e9 / jobs as f64;
                    *bucket_ewma = if *bucket_ewma <= 0.0 {
                        sample
                    } else {
                        EWMA_ALPHA * sample + (1.0 - EWMA_ALPHA) * *bucket_ewma
                    };
                }
            }
        }
        // Dead-lane detection, seed included: a dead lane either closed
        // its own queue (failed engine build, fatal poisoned context —
        // its queued jobs are already failed or dead-lettered) or its
        // thread died without cleanup (salvage the queue here). Its
        // staged jobs re-route to a surviving lane below.
        let mut rerouted: Vec<LaneJob> = Vec::new();
        let mut i = 0;
        while i < group.lanes.len() {
            let dead = group.lanes[i].jobs.is_closed()
                || group.lanes[i].join.as_ref().map_or(true, |handle| handle.is_finished());
            if dead {
                let mut lane = group.lanes.remove(i);
                group.retired += 1;
                if !lane.jobs.is_closed() {
                    lane.jobs.close();
                }
                {
                    let mut dl = dead_letter.lock().unwrap();
                    while let Some(job) = lane.jobs.try_pop() {
                        dl.push((
                            group.bucket,
                            job,
                            format!("lane {} died before serving this job", group.bucket),
                        ));
                    }
                }
                rerouted.extend(lane.staged.drain(..));
                group.retiring.push(lane);
            } else {
                i += 1;
            }
        }
        // A bucket that lost its last lane gets ONE replacement build
        // per failure (blocking on the readiness handshake keeps this
        // deterministic); if the rebuild itself fails the bucket is
        // marked broken and fails fast instead of rebuilding forever.
        if group.lanes.is_empty() && group.broken.is_none() {
            match spawn_lane(factory, group.bucket, config, false, dead_letter, wake) {
                Ok((lane, ready_rx)) => match ready_rx.recv() {
                    Ok(Ok(_shape)) => {
                        for _ in 0..config.buffers_per_lane {
                            let buf = group
                                .spare_buffers
                                .pop()
                                .unwrap_or_else(|| Vec::with_capacity(group.bucket * example_len));
                            let _ = lane.free.try_push(buf);
                        }
                        group.spawned += 1;
                        group.lanes.push(lane);
                    }
                    Ok(Err(e)) => {
                        group.broken = Some(format!("lane {} rebuild failed: {e}", group.bucket));
                        group.retiring.push(lane);
                    }
                    Err(_) => {
                        group.broken =
                            Some(format!("lane {} died during rebuild", group.bucket));
                        group.retiring.push(lane);
                    }
                },
                Err(e) => {
                    group.broken = Some(format!("lane {} rebuild failed: {e:#}", group.bucket));
                }
            }
        }
        if let Some(survivor) = group.lanes.first_mut() {
            for job in rerouted {
                survivor.stage(job);
            }
            flush_staged(survivor);
        } else if !rerouted.is_empty() {
            let msg = group
                .broken
                .clone()
                .unwrap_or_else(|| format!("lane {} unavailable", group.bucket));
            let mut dl = dead_letter.lock().unwrap();
            for job in rerouted {
                dl.push((group.bucket, job, msg.clone()));
            }
        }
        // Retire elastic lanes idle past the window (seed lane exempt).
        // `in_flight` covers staged, queued, AND the batch the engine is
        // executing, so a busy lane is never retired mid-batch.
        let mut i = 1;
        while i < group.lanes.len() {
            let lane = &group.lanes[i];
            let idle = lane.elastic
                && lane.in_flight() == 0
                && lane.last_active.elapsed() >= config.scale.idle_retire;
            if idle {
                let lane = group.lanes.remove(i);
                lane.jobs.close();
                group.retired += 1;
                group.retiring.push(lane);
            } else {
                i += 1;
            }
        }
        group.hinted_since_scale = 0;
    }
}

/// The SLO control pass ([`LaneConfig::slo`]), run at scale-pass
/// cadence: hold the live shed rate under the
/// `Runtime::builder().slo(target)` goal by growing lanes ahead of
/// demand. **Feedback** is the measured shed rate over the last control
/// window (live lane counters + dispatcher-side sheds over admitted
/// arrivals). **Feed-forward** is the DES's FIFO-server shed law
/// ([`crate::sim::simulate_lanes_deadline`]: a request sheds iff its
/// start time reaches its deadline) applied to the live backlog through
/// the EWMA queue-delay estimate — staged requests whose estimated
/// start already breaches their deadline count as predicted sheds
/// before they happen. Either rate crossing the target force-spawns a
/// lane for the breaching bucket (bypassing `scale_up_backlog`, never
/// `max_lanes_per_bucket`); scale-down stays with the idle-retire rule.
#[allow(clippy::too_many_arguments)]
fn slo_pass<E, F>(
    groups: &mut [LaneGroup],
    config: &LaneConfig,
    example_len: usize,
    factory: &Arc<F>,
    dead_letter: &DeadLetter,
    wake: &Bounded<Admit>,
    ewma: &[f64],
    window: &mut SloWindow,
    admitted: u64,
    misc_shed: usize,
    target: f64,
    now: Instant,
) where
    E: InferEngine + 'static,
    F: Fn(usize) -> Result<E> + Send + Sync + 'static,
{
    let shed_now = live_shed(groups) + misc_shed as u64;
    let window_admitted = admitted.saturating_sub(window.admitted);
    let window_shed = shed_now.saturating_sub(window.shed);
    window.admitted = admitted;
    window.shed = shed_now;
    let feedback = if window_admitted == 0 {
        0.0
    } else {
        window_shed as f64 / window_admitted as f64
    };
    for (gi, group) in groups.iter_mut().enumerate() {
        let est = admission_estimate_s(group, ewma[gi]);
        let horizon = now + Duration::from_secs_f64(est);
        let mut at_risk = 0usize;
        let mut with_deadline = 0usize;
        for lane in &group.lanes {
            for job in &lane.staged {
                if let Some(tok) = &job.batch {
                    if let Some(d) = tok.deadline {
                        with_deadline += 1;
                        if horizon >= d {
                            at_risk += 1;
                        }
                    }
                }
                for (i, (tok, _)) in job.tokens.iter().enumerate() {
                    if job.done.get(i).copied().unwrap_or(false) {
                        continue;
                    }
                    if let Some(d) = tok.deadline {
                        with_deadline += 1;
                        if horizon >= d {
                            at_risk += 1;
                        }
                    }
                }
            }
        }
        let feedforward =
            if with_deadline == 0 { 0.0 } else { at_risk as f64 / with_deadline as f64 };
        if feedback > target || feedforward > target {
            let _ = maybe_spawn(group, config, example_len, factory, dead_letter, wake, true);
        }
    }
}

#[allow(clippy::too_many_arguments)]
fn dispatcher_thread<E, F>(
    admission: Bounded<Admit>,
    mut groups: Vec<LaneGroup>,
    policy: BatchPolicy,
    example_len: usize,
    config: LaneConfig,
    factory: Arc<F>,
    dead_letter: DeadLetter,
    health: Arc<HealthState>,
    wakeups: Arc<AtomicU64>,
    report_tx: mpsc::Sender<ServingReport>,
) where
    E: InferEngine + 'static,
    F: Fn(usize) -> Result<E> + Send + Sync + 'static,
{
    let group_index: HashMap<usize, usize> =
        groups.iter().enumerate().map(|(i, g)| (g.bucket, i)).collect();
    let mut batcher: Batcher<ReqToken> = Batcher::new(policy);
    let started = Instant::now();
    // Admission closed (by shutdown/drain or the server handle dropping).
    let mut closed = false;
    // Last form pass hit a saturated lane: its (already-passed) flush
    // deadline is not actionable until a lane event, so the wait must
    // not spin on it — the lane-free kick is the wakeup instead.
    let mut stalled = false;
    let mut last_scale = Instant::now();
    // Requests rejected before reaching any lane (malformed inputs,
    // unknown buckets) — folded into the report so accounting closes.
    let mut misc_failed = 0usize;
    // Requests deadline-shed out of the batcher queue (expired while
    // waiting, no definite bucket to attribute them to).
    let mut misc_shed = 0usize;
    // Per-bucket EWMA batch service time (seconds), indexed like
    // `groups` — the queue-delay estimate behind admission-time
    // shedding and the SLO controller's feed-forward term.
    let mut ewma: Vec<f64> = vec![0.0; groups.len()];
    // Well-formed requests admitted (the SLO rate denominator).
    let mut admitted = 0u64;
    // Admission-queue kick counter last observed: lane threads kick
    // when a job slot or pooled buffer frees, so the dispatcher wakes
    // on the event the old poll clamps were waiting for. Sampled before
    // any wait so a kick delivered while the dispatcher works is seen
    // on the next wait, never lost.
    let mut seen_kicks = admission.kicks();
    // SLO control-pass window totals.
    let mut slo_window = SloWindow { admitted: 0, shed: 0 };
    // Dead-lettered jobs waiting out their retry backoff: (due, bucket, job).
    let mut retry_backlog: Vec<(Instant, usize, LaneJob)> = Vec::new();

    'outer: loop {
        wakeups.fetch_add(1, Ordering::Relaxed);
        // Resolve deadlines that expired where the lane pop cannot see
        // them (batcher queue + staged jobs) before forming batches.
        if config.edf {
            shed_expired_work(
                &mut groups,
                &mut batcher,
                Instant::now(),
                &mut misc_shed,
                config.telemetry.as_ref(),
            );
        }
        for group in &mut groups {
            for lane in &mut group.lanes {
                flush_staged(lane);
            }
        }
        // The scaling pass runs at SCALE_POLL cadence, not per message:
        // hinted-arrival pressure accumulates across a whole window
        // (resetting it every admitted message would erase the signal
        // before it could ever reach scale_up_backlog).
        if last_scale.elapsed() >= SCALE_POLL {
            scale_groups(
                &mut groups,
                &config,
                example_len,
                &factory,
                &dead_letter,
                &admission,
                &mut ewma,
            );
            if let Some(target) = config.slo {
                slo_pass(
                    &mut groups,
                    &config,
                    example_len,
                    &factory,
                    &dead_letter,
                    &admission,
                    &ewma,
                    &mut slo_window,
                    admitted,
                    misc_shed,
                    target,
                    Instant::now(),
                );
            }
            health.set_degraded(
                groups.iter().filter(|g| g.broken.is_some()).map(|g| g.bucket).collect(),
            );
            last_scale = Instant::now();
        }

        // --- Supervision: re-admit dead-lettered jobs and due retries. ---
        let dead: Vec<(usize, LaneJob, String)> =
            std::mem::take(&mut *dead_letter.lock().unwrap());
        for (bucket, job, msg) in dead {
            let group = &mut groups[group_index[&bucket]];
            if job.attempts > config.retry.max_retries || group.broken.is_some() {
                group.stat.failed += fail_job(job, &msg);
            } else {
                if job.attempts > 0 {
                    group.stat.retries += 1;
                }
                retry_backlog.push((Instant::now() + config.retry.backoff, bucket, job));
            }
        }
        if !retry_backlog.is_empty() {
            let now = Instant::now();
            let mut i = 0;
            while i < retry_backlog.len() {
                if retry_backlog[i].0 > now {
                    i += 1;
                    continue;
                }
                let gi = group_index[&retry_backlog[i].1];
                if groups[gi].lanes.is_empty() {
                    if let Some(msg) = groups[gi].broken.clone() {
                        let (_, _, job) = retry_backlog.swap_remove(i);
                        groups[gi].stat.failed += fail_job(job, &msg);
                    } else {
                        // Replacement lane still rebuilding: keep waiting.
                        i += 1;
                    }
                    continue;
                }
                let (_, _, job) = retry_backlog.swap_remove(i);
                let group = &mut groups[gi];
                let li = group.pick_lane();
                // Deliberately bypasses the stage cap: re-admitted work
                // was already accounted once and must not be load-shed.
                group.lanes[li].stage(job);
                flush_staged(&mut group.lanes[li]);
            }
        }

        // --- Wait for the next admission event. ---
        // ONE timestamp for the whole wait computation: every deadline
        // below derives from this read, so the bounds cannot drift
        // apart across re-reads of the clock.
        let now = Instant::now();
        // Elastic activity (scaled-up groups or draining retirees) needs
        // periodic scaling passes; static deployments never poll for it.
        let elastic_active =
            groups.iter().any(|g| g.lanes.len() > 1 || !g.retiring.is_empty());
        // While anything is in flight, a lane could die and dead-letter
        // its work with no admission event to wake us — bound the wait
        // so the supervision pass always runs soon after. A fully idle
        // server still blocks indefinitely. Saturated lanes no longer
        // poll: lane threads kick the admission queue when a job slot
        // or pooled buffer frees, which is exactly the event the old
        // `stalled` / `any_staged` poll clamps were spinning for.
        let supervision = !retry_backlog.is_empty()
            || groups.iter().any(|g| {
                g.broken.is_some()
                    || !g.retiring.is_empty()
                    || g.lanes.iter().any(|l| l.in_flight() > 0)
            });
        if !closed && admission.is_closed() {
            // The server handle closed the door (shutdown, drain, or
            // drop): flush everything that got in before it shut — a
            // request whose push succeeded is never dropped, and never
            // load-shed (uncapped stage), since no new work can arrive
            // to justify backpressure.
            closed = true;
            while let Some(m) = admission.try_pop() {
                admit_one(
                    m,
                    &mut groups,
                    &group_index,
                    &mut batcher,
                    example_len,
                    usize::MAX,
                    &config,
                    &factory,
                    &dead_letter,
                    &admission,
                    &ewma,
                    &mut misc_failed,
                    &mut admitted,
                );
            }
        }
        let msg = if closed || batcher.pending() >= config.backlog_cap {
            // Draining (nothing left to pop), or backpressure (the
            // batcher is at its cap and admission must pause): progress
            // now depends only on lane events, so park on the kick
            // counter instead of sleep-polling, bounded by the
            // supervision cadence.
            seen_kicks = admission.wait_kick(now + SCALE_POLL, seen_kicks);
            None
        } else {
            let mut deadline = batcher.next_deadline();
            if stalled {
                // Formation is blocked on lane capacity, so an
                // already-due flush deadline is not actionable —
                // waiting on it would spin. Keep only deadlines still
                // in the future (request-deadline sheds); the wakeup
                // that unblocks formation is the lane-free kick.
                deadline = deadline.filter(|d| *d > now);
            }
            // A deadline whose only copy sits in a staged batch must
            // wake the dispatcher too, so the shed pass resolves it on
            // time (pop-time-only mode keeps the PR-5 semantics: staged
            // deadlines resolve when the lane reaches them).
            if config.edf {
                if let Some(d) = staged_min_deadline(&groups) {
                    deadline = Some(deadline.map_or(d, |b| b.min(d)));
                }
            }
            if elastic_active || supervision {
                let scale_at = now + SCALE_POLL;
                deadline = Some(deadline.map_or(scale_at, |d| d.min(scale_at)));
            }
            match deadline {
                None => admission.pop().or_else(|| {
                    closed = true;
                    None
                }),
                Some(d) => {
                    let (res, kicks) = admission.pop_kicked(d, seen_kicks);
                    seen_kicks = kicks;
                    match res {
                        PopResult::Item(m) => Some(m),
                        PopResult::TimedOut => None,
                        PopResult::Closed => {
                            closed = true;
                            None
                        }
                    }
                }
            }
        };
        if let Some(m) = msg {
            admit_one(
                m,
                &mut groups,
                &group_index,
                &mut batcher,
                example_len,
                config.lane_cap,
                &config,
                &factory,
                &dead_letter,
                &admission,
                &ewma,
                &mut misc_failed,
                &mut admitted,
            );
        }

        // --- Form ready batches and route them (never blocking). ---
        let shutting = closed;
        stalled = false;
        loop {
            let now = Instant::now();
            if !((shutting && batcher.pending() > 0) || batcher.ready(now)) {
                break;
            }
            // The batcher plans the bucket (honoring client hints over
            // queue-depth routing); routing happens before forming so a
            // saturated lane leaves the queue untouched.
            let Some((_, bucket)) = batcher.plan_next() else { break };
            let gi = group_index[&bucket];
            let group = &mut groups[gi];
            if group.lanes.is_empty() {
                // The bucket is broken (its last lane died and the
                // rebuild failed): resolve its requests instead of
                // leaving them in the batcher forever. A bucket still
                // rebuilding counts as stalled — its flush deadline is
                // not actionable until the scaling pass restores a lane.
                let Some(msg) = group.broken.clone() else {
                    stalled = true;
                    break;
                };
                let mut buf = Vec::new();
                let Some(formed) = batcher.form_with(example_len, &mut buf) else { break };
                for (tok, _) in formed.tokens {
                    let _ = tok.reply.send(Err(msg.clone()));
                    group.stat.failed += 1;
                }
                continue;
            }
            let mut li = group.pick_lane();
            if group.lanes[li].staged.len() >= config.lane_cap
                || group.lanes[li].free.is_empty()
            {
                // Saturated (stage full, or every pooled buffer in
                // flight): grow the group if the policy allows,
                // otherwise the requests wait in the batcher.
                match maybe_spawn(group, &config, example_len, &factory, &dead_letter, &admission, false)
                {
                    Some(fresh) => li = fresh,
                    None => {
                        stalled = true;
                        break;
                    }
                }
            }
            let lane = &mut group.lanes[li];
            let Some(mut buf) = lane.free.try_pop() else {
                stalled = true;
                break; // no pooled buffer: lane is at its in-flight bound
            };
            let cap_before = buf.capacity();
            let Some(formed) = batcher.form_with(example_len, &mut buf) else {
                let _ = lane.free.try_push(buf);
                break;
            };
            debug_assert_eq!(formed.bucket, bucket, "bucket drifted between plan and form");
            if buf.capacity() != cap_before {
                lane.alloc_events += 1;
            }
            lane.stage(LaneJob {
                input: buf,
                tokens: formed.tokens,
                batch: None,
                routed: Instant::now(),
                attempts: 0,
                done: Vec::new(),
            });
            flush_staged(lane);
        }

        if shutting
            && batcher.pending() == 0
            && groups.iter().all(|g| g.lanes.iter().all(|l| l.staged.is_empty()))
            && retry_backlog.is_empty()
            && dead_letter.lock().unwrap().is_empty()
        {
            break 'outer;
        }
    }

    // --- Drain lanes and aggregate the per-bucket report. ---
    for group in &groups {
        for lane in group.lanes.iter().chain(&group.retiring) {
            lane.jobs.close();
        }
    }
    for group in &mut groups {
        let lanes: Vec<Lane> =
            group.lanes.drain(..).chain(group.retiring.drain(..)).collect();
        for lane in lanes {
            group.fold_joined(lane);
        }
    }
    // A lane that died while we were exiting may have dead-lettered its
    // work after the last supervision pass; every lane thread is joined
    // now, so whatever is here is final — resolve it as failed.
    for (bucket, job, msg) in dead_letter.lock().unwrap().drain(..) {
        groups[group_index[&bucket]].stat.failed += fail_job(job, &msg);
    }
    for (_, _, job) in retry_backlog.drain(..) {
        misc_failed += fail_job(job, "server shut down before the retry could run");
    }
    let mut lane_stats = Vec::with_capacity(groups.len());
    let mut all_latencies: Vec<f64> = Vec::new();
    let (mut n_requests, mut n_batches, mut fill_sum) = (0usize, 0usize, 0usize);
    for mut group in groups {
        let mut stat = group.stat;
        stat.lanes_spawned = group.spawned;
        stat.lanes_retired = group.retired;
        n_requests += stat.n_requests;
        n_batches += stat.n_batches;
        fill_sum += group.fill_sum;
        all_latencies.append(&mut group.latencies);
        lane_stats.push(stat);
    }
    let report = ServingReport {
        n_requests,
        n_batches,
        wall_time: started.elapsed(),
        latency: if all_latencies.is_empty() {
            Summary::from_samples(vec![0.0])
        } else {
            Summary::from_samples(all_latencies)
        },
        mean_batch_fill: if n_batches == 0 { 0.0 } else { fill_sum as f64 / n_batches as f64 },
        deadline_shed: lane_stats.iter().map(|l| l.deadline_shed).sum::<usize>() + misc_shed,
        admission_shed: lane_stats.iter().map(|l| l.admission_shed).sum(),
        failed: lane_stats.iter().map(|l| l.failed).sum::<usize>() + misc_failed,
        retries: lane_stats.iter().map(|l| l.retries).sum(),
        lanes: lane_stats,
    };
    let _ = report_tx.send(report);
}

/// Cloneable, `Send` request handle to a [`LaneServer`].
#[derive(Clone)]
pub struct LaneClient {
    admission: Bounded<Admit>,
    example_len: usize,
    output_len: usize,
    batch_sizes: Vec<usize>,
    health: Arc<HealthState>,
}

impl LaneClient {
    pub fn example_len(&self) -> usize {
        self.example_len
    }

    /// Liveness probe: `Draining` once shutdown began, `Degraded` while
    /// any bucket is failing fast after losing its lanes for good.
    pub fn health(&self) -> Health {
        self.health.snapshot()
    }

    pub fn output_len(&self) -> usize {
        self.output_len
    }

    pub fn batch_sizes(&self) -> &[usize] {
        &self.batch_sizes
    }

    /// Requests sitting in the bounded admission queue right now —
    /// admitted but not yet pulled by the dispatcher. A cheap,
    /// lock-light pressure signal for the cluster router
    /// ([`crate::cluster`]); momentarily stale by design.
    pub fn queue_depth(&self) -> usize {
        self.admission.len()
    }

    /// The one single-example submit path: enqueue
    /// `(input, hint, deadline)` and hand back the raw reply channel.
    /// [`RuntimeHandle`](crate::serving::RuntimeHandle) wraps this (and
    /// validates); the deprecated `infer*` variants are shims over it.
    pub(crate) fn submit_raw(
        &self,
        input: Vec<f32>,
        hint: Option<usize>,
        deadline: Option<Instant>,
    ) -> Result<mpsc::Receiver<Result<Vec<f32>, String>>> {
        anyhow::ensure!(
            input.len() == self.example_len,
            "bad input length {} != {}",
            input.len(),
            self.example_len
        );
        let (reply, rx) = mpsc::channel();
        self.admission
            .push(Admit::Infer { input, hint, deadline, reply })
            .map_err(|_| anyhow::anyhow!("server stopped"))?;
        Ok(rx)
    }

    /// The one pre-formed-batch submit path: route a padded batch
    /// straight to `bucket`'s lane; the reply carries the full padded
    /// output (`bucket * output_len` values). May reply with an
    /// explicit overload error when the lane is saturated (load shed).
    pub(crate) fn submit_batch_raw(
        &self,
        bucket: usize,
        input: Vec<f32>,
        deadline: Option<Instant>,
    ) -> Result<mpsc::Receiver<Result<Vec<f32>, String>>> {
        anyhow::ensure!(self.batch_sizes.contains(&bucket), "no lane for bucket {bucket}");
        anyhow::ensure!(
            input.len() == bucket * self.example_len,
            "bad batch length {} != {}",
            input.len(),
            bucket * self.example_len
        );
        let (reply, rx) = mpsc::channel();
        self.admission
            .push(Admit::Batch { bucket, input, deadline, reply })
            .map_err(|_| anyhow::anyhow!("server stopped"))?;
        Ok(rx)
    }

    /// Blocking inference of one example. Blocks at admission when the
    /// server is saturated (bounded queue).
    #[deprecated(note = "build a Runtime and call infer(InferRequest) — see rust/README.md")]
    pub fn infer(&self, input: Vec<f32>) -> Result<Vec<f32>> {
        let rx = self.submit_raw(input, None, None)?;
        rx.recv().context("server dropped request")?.map_err(anyhow::Error::msg)
    }

    /// Fire an async request; returns the reply channel.
    #[deprecated(note = "use Runtime::submit(InferRequest) -> Ticket")]
    pub fn infer_async(&self, input: Vec<f32>) -> Result<mpsc::Receiver<Result<Vec<f32>, String>>> {
        self.submit_raw(input, None, None)
    }

    /// Blocking inference with a bucket hint: the dispatcher routes the
    /// request's batch to `bucket`'s lane (honored over queue-depth
    /// routing) — sequence-length-aware clients pick their own lane.
    #[deprecated(note = "use Runtime::infer(InferRequest::new(..).hint(bucket))")]
    pub fn infer_hinted(&self, input: Vec<f32>, bucket: usize) -> Result<Vec<f32>> {
        anyhow::ensure!(self.batch_sizes.contains(&bucket), "no lane for bucket {bucket}");
        let rx = self.submit_raw(input, Some(bucket), None)?;
        rx.recv().context("server dropped request")?.map_err(anyhow::Error::msg)
    }

    /// Async variant of [`infer_hinted`](Self::infer_hinted). The hint
    /// must name a compiled bucket.
    #[deprecated(note = "use Runtime::submit(InferRequest::new(..).hint(bucket)) -> Ticket")]
    pub fn infer_hinted_async(
        &self,
        input: Vec<f32>,
        bucket: usize,
    ) -> Result<mpsc::Receiver<Result<Vec<f32>, String>>> {
        anyhow::ensure!(self.batch_sizes.contains(&bucket), "no lane for bucket {bucket}");
        self.submit_raw(input, Some(bucket), None)
    }

    /// Submit a pre-formed padded batch straight to `bucket`'s lane.
    #[deprecated(note = "use Runtime::submit(InferRequest::batch(bucket, input)) -> Ticket")]
    pub fn submit_batch(
        &self,
        bucket: usize,
        input: Vec<f32>,
    ) -> Result<mpsc::Receiver<Result<Vec<f32>, String>>> {
        self.submit_batch_raw(bucket, input, None)
    }
}

/// Handle to a running lane-scheduled server.
pub struct LaneServer {
    admission: Bounded<Admit>,
    dispatcher: Option<JoinHandle<()>>,
    example_len: usize,
    output_len: usize,
    batch_sizes: Vec<usize>,
    health: Arc<HealthState>,
    wakeups: Arc<AtomicU64>,
    report_rx: mpsc::Receiver<ServingReport>,
}

impl LaneServer {
    /// Start one lane per bucket in `batch_sizes`. The factory runs once
    /// per lane *on that lane's thread* (non-`Send` engines work) and
    /// must return an engine serving at least that bucket; the call
    /// blocks until every lane finished building. The public spellings
    /// are `Runtime::builder().build()` / `build_with_factory()`.
    pub(crate) fn start_inner<E, F>(
        batch_sizes: &[usize],
        factory: F,
        config: LaneConfig,
    ) -> Result<LaneServer>
    where
        E: InferEngine + 'static,
        F: Fn(usize) -> Result<E> + Send + Sync + 'static,
    {
        anyhow::ensure!(!batch_sizes.is_empty(), "need at least one batch bucket");
        anyhow::ensure!(config.lane_cap >= 1, "lane_cap must be >= 1");
        anyhow::ensure!(config.buffers_per_lane >= 1, "buffers_per_lane must be >= 1");
        anyhow::ensure!(
            config.scale.max_lanes_per_bucket >= 1,
            "max_lanes_per_bucket must be >= 1"
        );
        let mut sizes: Vec<usize> = batch_sizes.to_vec();
        sizes.sort_unstable();
        sizes.dedup();
        let factory = Arc::new(factory);
        let admission: Bounded<Admit> = Bounded::new(config.admission_cap);
        let dead_letter: DeadLetter = Arc::new(Mutex::new(Vec::new()));
        let health = HealthState::new();

        let mut lanes: Vec<Lane> = Vec::with_capacity(sizes.len());
        let mut readies = Vec::with_capacity(sizes.len());
        for &bucket in &sizes {
            let (lane, ready_rx) =
                spawn_lane(&factory, bucket, &config, false, &dead_letter, &admission)?;
            lanes.push(lane);
            readies.push(ready_rx);
        }

        // Collect readiness from every lane; all shapes must agree.
        let mut example_len = 0usize;
        let mut output_len = 0usize;
        let mut startup_err: Option<String> = None;
        for (lane, ready_rx) in lanes.iter().zip(&readies) {
            match ready_rx.recv() {
                Ok(Ok((el, ol))) => {
                    if example_len == 0 {
                        example_len = el;
                        output_len = ol;
                    } else if example_len != el || output_len != ol {
                        startup_err.get_or_insert(format!(
                            "lane {}: per-example shapes disagree with other lanes",
                            lane.bucket
                        ));
                    }
                }
                Ok(Err(e)) => {
                    startup_err.get_or_insert(e);
                }
                Err(_) => {
                    startup_err
                        .get_or_insert(format!("lane {} died during build", lane.bucket));
                }
            }
        }
        if let Some(e) = startup_err {
            for lane in &lanes {
                lane.jobs.close();
            }
            for lane in &mut lanes {
                if let Some(h) = lane.join.take() {
                    let _ = h.join();
                }
            }
            anyhow::bail!("lane startup failed: {e}");
        }

        // Pre-size the padded-buffer pools so steady-state dispatch never
        // allocates (asserted via LaneStat::alloc_events).
        for lane in &lanes {
            for _ in 0..config.buffers_per_lane {
                let _ = lane.free.try_push(Vec::with_capacity(lane.bucket * example_len));
            }
        }
        let groups: Vec<LaneGroup> =
            lanes.into_iter().map(|lane| LaneGroup::new(lane.bucket, lane)).collect();

        let policy = BatchPolicy { batch_sizes: sizes.clone(), max_wait: config.max_wait };
        let (report_tx, report_rx) = mpsc::channel();
        let wakeups = Arc::new(AtomicU64::new(0));
        let dispatcher = {
            let admission = admission.clone();
            let health = Arc::clone(&health);
            let wakeups = Arc::clone(&wakeups);
            std::thread::Builder::new()
                .name("nimble-dispatch".into())
                .spawn(move || {
                    dispatcher_thread(
                        admission,
                        groups,
                        policy,
                        example_len,
                        config,
                        factory,
                        dead_letter,
                        health,
                        wakeups,
                        report_tx,
                    )
                })
                .context("spawning dispatcher thread")?
        };
        Ok(LaneServer {
            admission,
            dispatcher: Some(dispatcher),
            example_len,
            output_len,
            batch_sizes: sizes,
            health,
            wakeups,
            report_rx,
        })
    }

    /// Start one lane per bucket over a custom engine factory.
    #[deprecated(
        note = "use Runtime::builder().build() or build_with_factory() — see rust/README.md"
    )]
    pub fn start<E, F>(batch_sizes: &[usize], factory: F, config: LaneConfig) -> Result<LaneServer>
    where
        E: InferEngine + 'static,
        F: Fn(usize) -> Result<E> + Send + Sync + 'static,
    {
        Self::start_inner(batch_sizes, factory, config)
    }

    /// Start one [`TapeEngine`](super::TapeEngine) lane per bucket, all
    /// lanes drawing their per-bucket slot arenas from the given shared
    /// [`ArenaPool`](crate::aot::memory::ArenaPool) — a restarted or
    /// rebuilt lane server re-draws the same bucket-sized reservations
    /// instead of growing the heap. The caller keeps a clone of the pool
    /// for stats; per-lane reserved footprints surface in
    /// [`LaneStat::reserved_bytes`].
    #[deprecated(note = "use Runtime::builder().graph_fn(..).arena_pool(pool).build()")]
    pub fn start_pooled_tape<G>(
        batch_sizes: &[usize],
        worker_cap: Option<usize>,
        pool: crate::aot::memory::ArenaPool,
        config: LaneConfig,
        build: G,
    ) -> Result<LaneServer>
    where
        G: Fn(usize) -> crate::ops::OpGraph + Send + Sync + Clone + 'static,
    {
        use super::sim_engine::{TapeEngine, TapeEngineOptions};
        let factory = move |bucket: usize| {
            let opts = TapeEngineOptions {
                worker_cap,
                arena_pool: Some(pool.clone()),
                ..Default::default()
            };
            TapeEngine::build_opts("pooled-lane", &[bucket], opts, build.clone())
        };
        Self::start_inner(batch_sizes, factory, config)
    }

    /// Start an **elastic** tape-engine server: every lane (seed and
    /// scale-up alike) draws its arena from the shared
    /// [`ArenaPool`](crate::aot::memory::ArenaPool) — so spawning a lane
    /// for a bucket the pool has served before is allocation-free on the
    /// warm path — and leases its replay workers from the ONE
    /// process-wide work-stealing pool, so however many lanes the
    /// scaling policy ([`LaneConfig::scale`]) spins up, total replay
    /// worker threads never exceed `workers.n_workers()`. Cross-lane
    /// steals surface in [`LaneStat::steals`], scaling decisions in
    /// [`LaneStat::lanes_spawned`] / [`LaneStat::lanes_retired`].
    #[deprecated(
        note = "use Runtime::builder().graph_fn(..).elastic(scale)\
                .shared_pool_handle(workers).arena_pool(pool).build()"
    )]
    pub fn start_elastic_tape<G>(
        batch_sizes: &[usize],
        workers: crate::engine::executor::SharedWorkerPool,
        pool: crate::aot::memory::ArenaPool,
        config: LaneConfig,
        build: G,
    ) -> Result<LaneServer>
    where
        G: Fn(usize) -> crate::ops::OpGraph + Send + Sync + Clone + 'static,
    {
        use super::sim_engine::{TapeEngine, TapeEngineOptions};
        let factory = move |bucket: usize| {
            let opts = TapeEngineOptions {
                arena_pool: Some(pool.clone()),
                shared_pool: Some(workers.clone()),
                ..Default::default()
            };
            TapeEngine::build_opts("elastic-lane", &[bucket], opts, build.clone())
        };
        Self::start_inner(batch_sizes, factory, config)
    }

    pub fn example_len(&self) -> usize {
        self.example_len
    }

    pub fn output_len(&self) -> usize {
        self.output_len
    }

    pub fn batch_sizes(&self) -> &[usize] {
        &self.batch_sizes
    }

    /// A cloneable request handle for client threads.
    pub fn client(&self) -> LaneClient {
        LaneClient {
            admission: self.admission.clone(),
            example_len: self.example_len,
            output_len: self.output_len,
            batch_sizes: self.batch_sizes.clone(),
            health: Arc::clone(&self.health),
        }
    }

    /// Liveness probe: `Draining` once shutdown began, `Degraded` while
    /// any bucket is failing fast after losing its lanes for good.
    pub fn health(&self) -> Health {
        self.health.snapshot()
    }

    /// Dispatcher loop iterations since start — a diagnostics counter.
    /// The dispatcher parks between events (admission messages, lane
    /// kicks, due deadlines, supervision ticks), so this grows with the
    /// event count, not with wall time: a saturated lane no longer
    /// degenerates into a poll loop (pinned by the bounded-wakeup
    /// regression test).
    pub fn dispatcher_wakeups(&self) -> u64 {
        self.wakeups.load(Ordering::Relaxed)
    }

    /// Blocking inference of one example.
    #[deprecated(note = "build a Runtime and call infer(InferRequest) — see rust/README.md")]
    pub fn infer(&self, input: Vec<f32>) -> Result<Vec<f32>> {
        let rx = self.client().submit_raw(input, None, None)?;
        rx.recv().context("server dropped request")?.map_err(anyhow::Error::msg)
    }

    /// Blocking inference with a bucket hint
    /// ([`LaneClient::infer_hinted`]).
    #[deprecated(note = "use Runtime::infer(InferRequest::new(..).hint(bucket))")]
    pub fn infer_hinted(&self, input: Vec<f32>, bucket: usize) -> Result<Vec<f32>> {
        self.client().infer_hinted(input, bucket)
    }

    /// Fire an async request; returns the reply channel.
    #[deprecated(note = "use Runtime::submit(InferRequest) -> Ticket")]
    pub fn infer_async(&self, input: Vec<f32>) -> Result<mpsc::Receiver<Result<Vec<f32>, String>>> {
        self.client().submit_raw(input, None, None)
    }

    /// Submit a pre-formed padded batch (see [`LaneClient::submit_batch`]).
    #[deprecated(note = "use Runtime::submit(InferRequest::batch(bucket, input)) -> Ticket")]
    pub fn submit_batch(
        &self,
        bucket: usize,
        input: Vec<f32>,
    ) -> Result<mpsc::Receiver<Result<Vec<f32>, String>>> {
        self.client().submit_batch_raw(bucket, input, None)
    }

    /// Stop the server: close admission (new submits fail fast with
    /// "server stopped"), flush everything already admitted, join every
    /// lane, and collect the per-lane serving report. This IS the
    /// graceful drain — `Runtime::drain()` and `Runtime::shutdown()`
    /// both land here.
    pub fn shutdown(mut self) -> Result<ServingReport> {
        self.health.set_draining();
        self.admission.close();
        let report = self.report_rx.recv().context("no report from dispatcher")?;
        if let Some(j) = self.dispatcher.take() {
            let _ = j.join();
        }
        Ok(report)
    }
}

impl Drop for LaneServer {
    fn drop(&mut self) {
        // Dropping without shutdown still drains admitted work and joins
        // every lane thread (the dispatcher sees the closed queue).
        self.health.set_draining();
        self.admission.close();
        if let Some(j) = self.dispatcher.take() {
            let _ = j.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::serving::{InferRequest, Runtime, TapeEngine};
    use crate::util::Pcg32;

    fn lane_server(max_wait: Duration) -> Runtime {
        Runtime::builder()
            .model("mini_inception")
            .buckets(&[1, 8])
            .max_wait(max_wait)
            .build()
            .expect("lane server start")
    }

    fn direct_engine(buckets: &[usize]) -> TapeEngine {
        Runtime::builder()
            .model("mini_inception")
            .buckets(buckets)
            .build_engine()
            .expect("direct engine")
    }

    fn inputs(n: usize, len: usize, seed: u64) -> Vec<Vec<f32>> {
        let mut rng = Pcg32::new(seed);
        (0..n).map(|_| (0..len).map(|_| rng.gen_f32_range(-1.0, 1.0)).collect()).collect()
    }

    /// Deterministic-shape engine with a configurable service time —
    /// saturates a lane for a controlled window.
    struct SlowEngine {
        buckets: Vec<usize>,
        delay: Duration,
    }

    impl InferEngine for SlowEngine {
        fn batch_sizes(&self) -> Vec<usize> {
            self.buckets.clone()
        }
        fn example_len(&self) -> usize {
            4
        }
        fn output_len(&self) -> usize {
            2
        }
        fn infer_batch(&mut self, bucket: usize, input: &[f32]) -> Result<Vec<f32>> {
            std::thread::sleep(self.delay);
            Ok(vec![input.iter().sum::<f32>(); bucket * 2])
        }
    }

    fn slow_server(delay: Duration, config: LaneConfig) -> LaneServer {
        LaneServer::start_inner(
            &[1],
            move |_bucket| Ok(SlowEngine { buckets: vec![1], delay }),
            config,
        )
        .expect("slow lane server")
    }

    #[test]
    fn dispatcher_wakeups_stay_bounded_while_a_lane_is_saturated() {
        // The busy-wait regression: the old wait loop clamped to a
        // 500us poll tick whenever a lane was saturated, so a 300ms
        // saturation window cost 600+ dispatcher wakeups. Lane threads
        // now kick the dispatcher on job-slot/buffer frees, so wakeups
        // scale with events (admissions + completions + 5ms supervision
        // ticks), not with wall time.
        let server = slow_server(
            Duration::from_millis(10),
            LaneConfig {
                max_wait: Duration::from_micros(100),
                lane_cap: 1,
                buffers_per_lane: 2,
                ..LaneConfig::default()
            },
        );
        let client = server.client();
        let pending: Vec<_> = (0..30)
            .map(|_| client.submit_raw(vec![0.25; 4], None, None).unwrap())
            .collect();
        for rx in pending {
            rx.recv().unwrap().unwrap();
        }
        let wakeups = server.dispatcher_wakeups();
        let report = server.shutdown().unwrap();
        assert_eq!(report.n_requests, 30);
        assert_eq!(report.failed, 0);
        // Event budget: ~30 admissions + 2 kicks per job + one 5ms
        // supervision tick per job's 10ms service + slack. The old
        // poll loop burned ~600 wakeups on this trace (and grows with
        // wall time); the bound holds even on a slow machine because
        // supervision ticks amortize 5ms each.
        assert!(
            wakeups < 450,
            "dispatcher woke {wakeups} times for 30 requests — poll loop is back?"
        );
    }

    #[test]
    fn staged_only_deadline_sheds_on_time() {
        // The staged-deadline regression: a deadline whose only copy
        // sits in a STAGED job (lane saturated, batcher empty) used to
        // be invisible to the wait loop — it shed only when the lane
        // eventually popped the job. The dispatcher now folds staged
        // deadlines into its wait and sheds them the moment they come
        // due.
        let server = slow_server(
            Duration::from_millis(100),
            LaneConfig {
                max_wait: Duration::from_micros(100),
                lane_cap: 1,
                buffers_per_lane: 3,
                ..LaneConfig::default()
            },
        );
        let client = server.client();
        // R1 occupies the engine (~100ms); R2 fills the lane queue
        // (lane_cap 1); R3 then stages with the only live deadline.
        let r1 = client.submit_raw(vec![0.5; 4], None, None).unwrap();
        std::thread::sleep(Duration::from_millis(25));
        let r2 = client.submit_raw(vec![0.5; 4], None, None).unwrap();
        std::thread::sleep(Duration::from_millis(10));
        let t0 = Instant::now();
        let r3 = client
            .submit_raw(vec![0.5; 4], None, Some(t0 + Duration::from_millis(40)))
            .unwrap();
        let res = r3.recv().unwrap();
        let waited = t0.elapsed();
        assert_eq!(res.unwrap_err(), crate::serving::DEADLINE_SHED);
        // Well before R1 finishes (100ms) — the old code shed this
        // only at lane pop, ~200ms in.
        assert!(
            waited < Duration::from_millis(90),
            "staged deadline shed {waited:?} after submit; must resolve at ~40ms"
        );
        r1.recv().unwrap().unwrap();
        r2.recv().unwrap().unwrap();
        let report = server.shutdown().unwrap();
        assert_eq!(report.n_requests, 2);
        assert_eq!(report.deadline_shed, 1);
        assert_eq!(report.failed, 0);
    }

    #[test]
    fn doomed_budgets_shed_at_admission_once_the_estimate_warms() {
        let server = slow_server(
            Duration::from_millis(20),
            LaneConfig {
                max_wait: Duration::from_micros(100),
                lane_cap: 1,
                buffers_per_lane: 2,
                ..LaneConfig::default()
            },
        );
        let client = server.client();
        // A request expired at the door sheds at admission even on a
        // cold server (deterministic, estimate-independent).
        let dead = client.submit_raw(vec![0.1; 4], None, Some(Instant::now())).unwrap();
        assert_eq!(dead.recv().unwrap().unwrap_err(), crate::serving::DEADLINE_SHED);
        // Warm the per-bucket service estimate (~20ms per batch).
        for _ in 0..3 {
            client.submit_raw(vec![0.1; 4], None, None).unwrap().recv().unwrap().unwrap();
        }
        // Saturate the lane, then submit a budget far below one service
        // time: the EWMA estimate rules it out at admission — the reply
        // arrives while the lane is still busy with the long work.
        let long: Vec<_> = (0..2)
            .map(|_| client.submit_raw(vec![0.1; 4], None, None).unwrap())
            .collect();
        let t0 = Instant::now();
        let tight = client
            .submit_raw(vec![0.1; 4], None, Some(t0 + Duration::from_millis(5)))
            .unwrap();
        let res = tight.recv().unwrap();
        let waited = t0.elapsed();
        assert_eq!(res.unwrap_err(), crate::serving::DEADLINE_SHED);
        assert!(
            waited < Duration::from_millis(15),
            "admission shed replied {waited:?} after submit; must not wait for the lane"
        );
        for rx in long {
            rx.recv().unwrap().unwrap();
        }
        let report = server.shutdown().unwrap();
        assert_eq!(report.n_requests, 5);
        assert_eq!(report.deadline_shed, 2);
        assert!(
            report.admission_shed >= 1,
            "at least the expired-at-door request sheds at admission"
        );
        assert_eq!(report.failed, 0);
    }

    #[test]
    fn serves_requests_and_reports_lane_stats() {
        let server = lane_server(Duration::from_millis(2));
        let len = server.example_len();
        let out_len = server.output_len();
        let mut pending = Vec::new();
        for input in inputs(20, len, 1) {
            pending.push(server.submit(InferRequest::new(input)).unwrap());
        }
        for ticket in pending {
            let logits = ticket.wait().unwrap();
            assert_eq!(logits.len(), out_len);
            assert!(logits.iter().all(|v| v.is_finite()));
        }
        let report = server.shutdown().unwrap();
        assert_eq!(report.n_requests, 20);
        assert_eq!(report.lanes.len(), 2, "one stat per bucket");
        let total: usize = report.lanes.iter().map(|l| l.n_requests).sum();
        assert_eq!(total, 20);
        assert!(report.lanes.iter().all(|l| l.alloc_events == 0), "pooled buffers must not grow");
    }

    #[test]
    fn single_requests_match_the_direct_engine() {
        let mut direct = direct_engine(&[1, 8]);
        let server = lane_server(Duration::from_millis(1));
        let input = inputs(1, server.example_len(), 9).pop().unwrap();
        let expect = direct.infer_batch(1, &input).unwrap();
        let got = server.infer(InferRequest::new(input)).unwrap();
        assert_eq!(got, expect);
        let _ = server.shutdown().unwrap();
    }

    #[test]
    fn submit_batch_replies_with_full_padded_output() {
        let server = lane_server(Duration::from_millis(1));
        let len = server.example_len();
        let out_len = server.output_len();
        let batch: Vec<f32> = inputs(8, len, 33).concat();
        let got = server.submit(InferRequest::batch(8, batch.clone())).unwrap().wait().unwrap();
        assert_eq!(got.len(), 8 * out_len);
        let mut direct = direct_engine(&[8]);
        assert_eq!(got, direct.infer_batch(8, &batch).unwrap());
        let _ = server.shutdown().unwrap();
    }

    #[test]
    fn bucket_hint_overrides_queue_depth_routing() {
        let server = lane_server(Duration::from_millis(1));
        let len = server.example_len();
        let out_len = server.output_len();
        let input = inputs(1, len, 55).pop().unwrap();
        // A lone request depth-routes to bucket 1; the hint forces lane 8.
        let got = server.infer(InferRequest::new(input.clone()).hint(8)).unwrap();
        assert_eq!(got.len(), out_len);
        let mut direct = direct_engine(&[8]);
        let mut padded = input;
        padded.resize(8 * len, 0.0);
        let want = direct.infer_batch(8, &padded).unwrap();
        assert_eq!(got.as_slice(), &want[..out_len]);
        // hints naming no lane are rejected client-side
        assert!(server.submit(InferRequest::new(vec![0.0; len]).hint(3)).is_err());
        let report = server.shutdown().unwrap();
        assert_eq!(report.lane(8).unwrap().n_requests, 1, "hinted request must land on lane 8");
        assert_eq!(report.lane(1).unwrap().n_requests, 0);
    }

    #[test]
    fn rejects_malformed_inputs_client_side() {
        let server = lane_server(Duration::from_millis(1));
        assert!(server.infer(InferRequest::new(vec![0.0; 3])).is_err());
        assert!(server.submit(InferRequest::batch(3, vec![0.0; 3])).is_err(), "unknown bucket");
        assert!(
            server.submit(InferRequest::batch(8, vec![0.0; 5])).is_err(),
            "bad batch length"
        );
        // server still healthy afterwards
        assert!(server.infer(InferRequest::new(vec![0.0; server.example_len()])).is_ok());
        let _ = server.shutdown().unwrap();
    }

    #[test]
    fn pooled_lanes_report_reserved_bytes_and_recycle_arenas() {
        let pool = crate::aot::memory::ArenaPool::new();
        let start = || {
            Runtime::builder()
                .model("mini_inception")
                .buckets(&[1, 8])
                .worker_cap(2)
                .arena_pool(pool.clone())
                .build()
                .expect("pooled lane server")
        };
        let server = start();
        let _ = server.infer(InferRequest::new(vec![0.1; server.example_len()])).unwrap();
        let report = server.shutdown().unwrap();
        assert!(
            report.lanes.iter().all(|l| l.reserved_bytes.unwrap_or(0) > 0),
            "every lane must report its packed arena footprint"
        );
        assert!(report.render().contains("arena="));
        let first = pool.stats();
        assert_eq!(first.acquires, 2, "one arena per single-bucket lane engine");
        assert_eq!(first.leased_bytes, 0, "shutdown returns the arenas to the pool");

        // A restarted server re-draws the same bucket-sized classes.
        drop(start());
        let second = pool.stats();
        assert_eq!(second.acquires, 4);
        assert!(second.hits >= 2, "restart must recycle, got {} hits", second.hits);
        assert_eq!(second.high_water_bytes, first.high_water_bytes, "the pool did not grow");
    }

    #[test]
    fn drop_without_shutdown_joins_cleanly() {
        let server = lane_server(Duration::from_millis(1));
        let _ = server.infer(InferRequest::new(vec![0.1; server.example_len()])).unwrap();
        drop(server); // must not hang or leak lane threads
    }

    #[test]
    fn elastic_lanes_spawn_and_retire_without_spurious_deadlocks() {
        // The scale-down regression test: bursty traffic forces a
        // scale-up, an idle window retires the elastic lane (its engine
        // drops, returning workers to the shared pool and its arena to
        // the arena pool), and traffic AFTER the retirement must still
        // be served — no request may fail with a spurious
        // "parked with nothing runnable" deadlock report.
        let arena_pool = crate::aot::memory::ArenaPool::new();
        let workers = crate::engine::executor::SharedWorkerPool::new(2);
        let server = Runtime::builder()
            .model("mini_inception")
            .buckets(&[1, 4])
            .max_wait(Duration::from_micros(200))
            .lane_cap(2)
            .buffers_per_lane(3)
            .elastic(ScaleOptions {
                max_lanes_per_bucket: 3,
                idle_retire: Duration::from_millis(5),
                scale_up_backlog: 1,
            })
            .shared_pool_handle(workers.clone())
            .arena_pool(arena_pool.clone())
            .build()
            .expect("elastic lane server");
        let len = server.example_len();
        let batch: Vec<f32> = inputs(4, len, 71).concat();

        // Burst: more in-flight batches than one lane can hold.
        let pending: Vec<_> = (0..12)
            .map(|_| server.submit(InferRequest::batch(4, batch.clone())).unwrap())
            .collect();
        for ticket in pending {
            ticket.wait().unwrap();
        }
        // Idle long enough for the scaling pass to retire extras.
        std::thread::sleep(Duration::from_millis(60));
        // Traffic resumes against the shrunken group.
        let pending: Vec<_> = (0..4)
            .map(|_| server.submit(InferRequest::batch(4, batch.clone())).unwrap())
            .collect();
        for ticket in pending {
            ticket.wait().unwrap();
        }

        let report = server.shutdown().unwrap();
        let lane4 = report.lane(4).unwrap();
        assert_eq!(lane4.n_batches, 16, "every batch served exactly once");
        assert!(lane4.lanes_spawned >= 2, "the burst must trigger a scale-up");
        assert!(lane4.lanes_retired >= 1, "the idle window must retire a lane");
        assert!(
            lane4.lanes_spawned <= 3 && report.lane(1).unwrap().lanes_spawned == 1,
            "scaling stays within policy bounds"
        );
        // Retired lanes' arenas are back in the pool, none leaked (the
        // warm-path recycling across bursts is pinned by the scaling
        // property in tests/prop_harness.rs).
        assert_eq!(arena_pool.stats().leased_bytes, 0, "all arenas returned after shutdown");
    }

    #[test]
    fn elastic_output_matches_the_direct_engine_bitwise() {
        let arena_pool = crate::aot::memory::ArenaPool::new();
        let workers = crate::engine::executor::SharedWorkerPool::new(2);
        let server = Runtime::builder()
            .model("mini_inception")
            .buckets(&[2])
            .max_wait(Duration::from_micros(200))
            .lane_cap(4)
            .elastic(ScaleOptions {
                max_lanes_per_bucket: 2,
                idle_retire: Duration::from_millis(4),
                scale_up_backlog: 1,
            })
            .shared_pool_handle(workers)
            .arena_pool(arena_pool)
            .build()
            .expect("elastic lane server");
        let len = server.example_len();
        let batch: Vec<f32> = inputs(2, len, 72).concat();
        let mut direct = direct_engine(&[2]);
        let want = direct.infer_batch(2, &batch).unwrap();
        // Concurrent duplicates may land on different replica lanes; all
        // must agree with the direct engine bit-for-bit.
        let pending: Vec<_> = (0..10)
            .map(|_| server.submit(InferRequest::batch(2, batch.clone())).unwrap())
            .collect();
        for ticket in pending {
            assert_eq!(ticket.wait().unwrap(), want);
        }
        let _ = server.shutdown().unwrap();
    }

    #[test]
    fn factory_failure_tears_down_cleanly() {
        let r = Runtime::builder().buckets(&[1, 2]).build_with_factory(|bucket| {
            if bucket == 2 {
                anyhow::bail!("injected build failure");
            }
            Runtime::builder().model("mini_inception").buckets(&[bucket]).build_engine()
        });
        assert!(r.is_err());
        assert!(format!("{:#}", r.err().unwrap()).contains("injected build failure"));
    }

    #[test]
    fn poisoned_lane_is_replaced_and_later_requests_succeed() {
        use crate::fault::{FaultPlan, ReplayFault, RetryPolicy};
        // The regression this pins: before lane supervision, a replay
        // context poisoned by one timed-out join failed every later
        // request on that lane forever. Now the lane dead-letters its
        // work and retires, the dispatcher rebuilds a replacement, and
        // the wedged request is retried there.
        //
        // Deterministic seed search: the runtime derives the bucket-1
        // replay fault stream as plan.derive(1 ^ REPLAY_SALT); pick a
        // seed whose stream wedges exactly at replay 2 and nowhere else
        // among the first 40, so the replacement lane (a fresh injector,
        // replay indices restarting at 0) never wedges again within this
        // test's four requests.
        let plan_for = |seed: u64| FaultPlan { join_timeout: 0.08, ..FaultPlan::seeded(seed) };
        let seed = (0..20_000u64)
            .find(|&s| {
                let replays = plan_for(s).derive(1u64 ^ FaultPlan::REPLAY_SALT);
                replays.replay_fault(2) == Some(ReplayFault::JoinTimeout)
                    && (0..40).filter(|&j| j != 2).all(|j| replays.replay_fault(j).is_none())
            })
            .expect("a seed that wedges only replay 2");

        let server = Runtime::builder()
            .model("mini_inception")
            .buckets(&[1])
            .max_wait(Duration::from_micros(200))
            .fault_plan(plan_for(seed))
            .retry_policy(RetryPolicy { max_retries: 2, backoff: Duration::ZERO })
            .build()
            .expect("chaos lane server");
        let len = server.example_len();
        let mut direct = direct_engine(&[1]);
        // Sequential blocking submits pin the replay order: requests 0-1
        // succeed on the seed lane, request 2 poisons it (retried on the
        // replacement), request 3 lands on the replacement directly.
        for input in inputs(4, len, 77) {
            let want = direct.infer_batch(1, &input).unwrap();
            let got = server.infer(InferRequest::new(input)).unwrap();
            assert_eq!(got, want, "recovered outputs stay bit-identical to the oracle");
        }
        assert!(matches!(server.health(), crate::serving::Health::Healthy));
        let report = server.shutdown().unwrap();
        assert_eq!(report.n_requests, 4, "every request must be served");
        assert_eq!(report.failed, 0, "the wedged request is retried, not failed");
        assert!(report.retries >= 1, "recovery must count at least one retry");
        let lane1 = report.lane(1).unwrap();
        assert!(lane1.lanes_spawned >= 2, "a replacement lane must have been built");
        assert!(lane1.lanes_retired >= 1, "the poisoned lane must have been retired");
    }
}
