//! Bounded MPMC queue: the admission and lane-dispatch channel of the
//! lane scheduler.
//!
//! `std::sync::mpsc` channels are unbounded, so a burst of clients could
//! queue arbitrarily much work in front of a busy engine. [`Bounded`] is
//! a small Mutex+Condvar MPMC queue with a hard capacity: producers
//! block (or fail fast with [`PushError::Full`] via
//! [`try_push`](Bounded::try_push)) when the queue is full, which is how
//! backpressure propagates from a slow lane all the way back to the
//! clients. Closing the queue wakes everyone: blocked producers fail
//! with [`PushError::Closed`], consumers drain the remaining items and
//! then observe the close — nothing enqueued before the close is ever
//! dropped (the shutdown-flush guarantee of the lane server).

use std::collections::VecDeque;
use std::sync::{Arc, Condvar, Mutex};
use std::time::Instant;

/// Why a push did not enqueue. The rejected value is handed back so the
/// caller can reply to it (e.g. with an explicit shutdown error).
#[derive(Debug)]
pub enum PushError<T> {
    /// Queue at capacity (returned by [`Bounded::try_push`] only).
    Full(T),
    /// Queue closed; no further items are accepted.
    Closed(T),
}

/// Outcome of a deadline-bounded pop.
#[derive(Debug)]
pub enum PopResult<T> {
    Item(T),
    /// Deadline passed with the queue still empty (and open).
    TimedOut,
    /// Queue closed and fully drained.
    Closed,
}

struct State<T> {
    buf: VecDeque<T>,
    closed: bool,
    /// Monotone event counter bumped by [`Bounded::kick`]: lets a
    /// producer-side event (a lane freeing a job slot or retiring a
    /// batch) wake a consumer parked in
    /// [`Bounded::pop_kicked`] without enqueuing anything.
    kicks: u64,
}

struct Shared<T> {
    state: Mutex<State<T>>,
    cap: usize,
    /// Signalled when an item is pushed or the queue closes.
    not_empty: Condvar,
    /// Signalled when an item is popped or the queue closes.
    not_full: Condvar,
}

/// A cloneable handle to one bounded MPMC queue; every clone is both a
/// producer and a consumer.
pub struct Bounded<T> {
    shared: Arc<Shared<T>>,
}

impl<T> Clone for Bounded<T> {
    fn clone(&self) -> Self {
        Bounded { shared: Arc::clone(&self.shared) }
    }
}

impl<T> Bounded<T> {
    /// A queue holding at most `cap` items (`cap` ≥ 1).
    pub fn new(cap: usize) -> Bounded<T> {
        assert!(cap >= 1, "bounded queue needs capacity >= 1");
        Bounded {
            shared: Arc::new(Shared {
                state: Mutex::new(State {
                    buf: VecDeque::with_capacity(cap),
                    closed: false,
                    kicks: 0,
                }),
                cap,
                not_empty: Condvar::new(),
                not_full: Condvar::new(),
            }),
        }
    }

    pub fn capacity(&self) -> usize {
        self.shared.cap
    }

    pub fn len(&self) -> usize {
        self.shared.state.lock().unwrap().buf.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Block until there is space, then enqueue. Fails only when closed.
    pub fn push(&self, item: T) -> Result<(), PushError<T>> {
        let mut st = self.shared.state.lock().unwrap();
        loop {
            if st.closed {
                return Err(PushError::Closed(item));
            }
            if st.buf.len() < self.shared.cap {
                st.buf.push_back(item);
                drop(st);
                self.shared.not_empty.notify_one();
                return Ok(());
            }
            st = self.shared.not_full.wait(st).unwrap();
        }
    }

    /// Enqueue without blocking.
    pub fn try_push(&self, item: T) -> Result<(), PushError<T>> {
        let mut st = self.shared.state.lock().unwrap();
        if st.closed {
            return Err(PushError::Closed(item));
        }
        if st.buf.len() >= self.shared.cap {
            return Err(PushError::Full(item));
        }
        st.buf.push_back(item);
        drop(st);
        self.shared.not_empty.notify_one();
        Ok(())
    }

    /// Block until an item is available; `None` once closed *and* drained.
    pub fn pop(&self) -> Option<T> {
        let mut st = self.shared.state.lock().unwrap();
        loop {
            if let Some(item) = st.buf.pop_front() {
                drop(st);
                self.shared.not_full.notify_one();
                return Some(item);
            }
            if st.closed {
                return None;
            }
            st = self.shared.not_empty.wait(st).unwrap();
        }
    }

    /// Dequeue without blocking (even on a closed queue, drains leftovers).
    pub fn try_pop(&self) -> Option<T> {
        let mut st = self.shared.state.lock().unwrap();
        let item = st.buf.pop_front();
        if item.is_some() {
            drop(st);
            self.shared.not_full.notify_one();
        }
        item
    }

    /// Block until an item arrives, the queue closes, or `deadline` passes.
    pub fn pop_deadline(&self, deadline: Instant) -> PopResult<T> {
        let mut st = self.shared.state.lock().unwrap();
        loop {
            if let Some(item) = st.buf.pop_front() {
                drop(st);
                self.shared.not_full.notify_one();
                return PopResult::Item(item);
            }
            if st.closed {
                return PopResult::Closed;
            }
            let now = Instant::now();
            if now >= deadline {
                return PopResult::TimedOut;
            }
            let (guard, _timeout) =
                self.shared.not_empty.wait_timeout(st, deadline - now).unwrap();
            st = guard;
        }
    }

    /// The current kick counter. Sample it before doing other work, then
    /// pass the sample to [`pop_kicked`](Self::pop_kicked): any kick that
    /// lands in between returns immediately instead of being lost.
    pub fn kicks(&self) -> u64 {
        self.shared.state.lock().unwrap().kicks
    }

    /// Wake a consumer parked in [`pop_kicked`](Self::pop_kicked) (or
    /// make its next call return immediately) without enqueuing an item.
    /// Lane threads kick the admission queue when a job slot frees or a
    /// batch retires, so the dispatcher wakes on the event instead of
    /// polling for it.
    pub fn kick(&self) {
        let mut st = self.shared.state.lock().unwrap();
        st.kicks = st.kicks.wrapping_add(1);
        drop(st);
        self.shared.not_empty.notify_all();
    }

    /// Like [`pop_deadline`](Self::pop_deadline), but also returns (as
    /// `TimedOut`) when the kick counter moves past `seen` — including
    /// kicks delivered *before* the call, so a wakeup can never be lost.
    /// Returns the outcome plus the kick counter to pass to the next
    /// call. `Instant::now()` is read at most once per wakeup.
    pub fn pop_kicked(&self, deadline: Instant, seen: u64) -> (PopResult<T>, u64) {
        let mut st = self.shared.state.lock().unwrap();
        loop {
            let kicks = st.kicks;
            if let Some(item) = st.buf.pop_front() {
                drop(st);
                self.shared.not_full.notify_one();
                return (PopResult::Item(item), kicks);
            }
            if st.closed {
                return (PopResult::Closed, kicks);
            }
            if kicks != seen {
                return (PopResult::TimedOut, kicks);
            }
            let now = Instant::now();
            if now >= deadline {
                return (PopResult::TimedOut, kicks);
            }
            let (guard, _timeout) =
                self.shared.not_empty.wait_timeout(st, deadline - now).unwrap();
            st = guard;
        }
    }

    /// Park until the kick counter moves past `seen` or `deadline`
    /// passes, *without* popping — and regardless of whether the queue
    /// is closed (the dispatcher's drain keeps waiting on lane events
    /// after admission closes). The backpressure/drain wait: the
    /// dispatcher must not consume messages while the backlog is at its
    /// cap, but still needs lane-event wakeups. Returns the current
    /// kick counter to pass to the next call.
    pub fn wait_kick(&self, deadline: Instant, seen: u64) -> u64 {
        let mut st = self.shared.state.lock().unwrap();
        loop {
            if st.kicks != seen {
                return st.kicks;
            }
            let now = Instant::now();
            if now >= deadline {
                return st.kicks;
            }
            let (guard, _timeout) =
                self.shared.not_empty.wait_timeout(st, deadline - now).unwrap();
            st = guard;
        }
    }

    /// Close the queue: producers fail from now on, consumers drain what
    /// is left. Idempotent.
    pub fn close(&self) {
        let mut st = self.shared.state.lock().unwrap();
        st.closed = true;
        drop(st);
        self.shared.not_empty.notify_all();
        self.shared.not_full.notify_all();
    }

    pub fn is_closed(&self) -> bool {
        self.shared.state.lock().unwrap().closed
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::time::Duration;

    #[test]
    fn fifo_order_and_capacity() {
        let q: Bounded<u32> = Bounded::new(2);
        q.push(1).unwrap();
        q.push(2).unwrap();
        assert!(matches!(q.try_push(3), Err(PushError::Full(3))));
        assert_eq!(q.pop(), Some(1));
        q.push(3).unwrap();
        assert_eq!(q.pop(), Some(2));
        assert_eq!(q.pop(), Some(3));
        assert!(q.try_pop().is_none());
    }

    #[test]
    fn close_drains_then_reports_closed() {
        let q: Bounded<u32> = Bounded::new(4);
        q.push(7).unwrap();
        q.close();
        assert!(matches!(q.push(8), Err(PushError::Closed(8))));
        assert_eq!(q.pop(), Some(7), "items enqueued before close survive");
        assert_eq!(q.pop(), None);
        assert!(matches!(q.pop_deadline(Instant::now()), PopResult::Closed));
    }

    #[test]
    fn pop_deadline_times_out_when_empty() {
        let q: Bounded<u32> = Bounded::new(1);
        let t0 = Instant::now();
        let r = q.pop_deadline(t0 + Duration::from_millis(20));
        assert!(matches!(r, PopResult::TimedOut));
        assert!(t0.elapsed() >= Duration::from_millis(20));
    }

    #[test]
    fn kick_wakes_a_parked_consumer_and_is_never_lost() {
        let q: Bounded<u32> = Bounded::new(1);
        // A kick delivered before the wait is observed on entry, not lost.
        let seen = q.kicks();
        q.kick();
        let t0 = Instant::now();
        let (r, seen) = q.pop_kicked(t0 + Duration::from_secs(5), seen);
        assert!(matches!(r, PopResult::TimedOut));
        assert!(t0.elapsed() < Duration::from_secs(1), "pre-delivered kick returns at once");
        // A kick delivered mid-wait wakes the consumer.
        let q2 = q.clone();
        let kicker = std::thread::spawn(move || {
            std::thread::sleep(Duration::from_millis(20));
            q2.kick();
        });
        let t0 = Instant::now();
        let (r, _seen) = q.pop_kicked(t0 + Duration::from_secs(5), seen);
        assert!(matches!(r, PopResult::TimedOut));
        assert!(t0.elapsed() < Duration::from_secs(1));
        kicker.join().unwrap();
    }

    #[test]
    fn pop_kicked_still_delivers_items_and_close() {
        let q: Bounded<u32> = Bounded::new(2);
        let seen = q.kicks();
        q.push(9).unwrap();
        let (r, seen) = q.pop_kicked(Instant::now() + Duration::from_millis(50), seen);
        assert!(matches!(r, PopResult::Item(9)));
        q.close();
        let (r, _seen) = q.pop_kicked(Instant::now() + Duration::from_millis(50), seen);
        assert!(matches!(r, PopResult::Closed));
    }

    #[test]
    fn wait_kick_wakes_without_popping_and_survives_close() {
        let q: Bounded<u32> = Bounded::new(2);
        q.push(5).unwrap();
        // A kick wakes the waiter without consuming the queued item.
        let seen = q.kicks();
        let q2 = q.clone();
        let kicker = std::thread::spawn(move || {
            std::thread::sleep(Duration::from_millis(20));
            q2.kick();
        });
        let t0 = Instant::now();
        let seen = q.wait_kick(t0 + Duration::from_secs(5), seen);
        assert!(t0.elapsed() < Duration::from_secs(1), "kick must wake the waiter");
        assert_eq!(q.pop(), Some(5), "wait_kick must not consume items");
        kicker.join().unwrap();
        // On a closed quiescent queue it times out instead of spinning.
        q.close();
        let t0 = Instant::now();
        let _ = q.wait_kick(t0 + Duration::from_millis(30), seen);
        assert!(t0.elapsed() >= Duration::from_millis(25), "no early return on closed");
    }

    #[test]
    fn blocked_producer_resumes_after_pop() {
        let q: Bounded<u32> = Bounded::new(1);
        q.push(1).unwrap();
        let q2 = q.clone();
        let producer = std::thread::spawn(move || q2.push(2));
        std::thread::sleep(Duration::from_millis(10));
        assert_eq!(q.pop(), Some(1));
        producer.join().unwrap().unwrap();
        assert_eq!(q.pop(), Some(2));
    }

    #[test]
    fn blocked_consumer_wakes_on_close() {
        let q: Bounded<u32> = Bounded::new(1);
        let q2 = q.clone();
        let consumer = std::thread::spawn(move || q2.pop());
        std::thread::sleep(Duration::from_millis(10));
        q.close();
        assert_eq!(consumer.join().unwrap(), None);
    }

    #[test]
    fn mpmc_all_items_delivered_exactly_once() {
        let q: Bounded<u64> = Bounded::new(8);
        let n_producers = 4;
        let per_producer = 50u64;
        let consumers: Vec<_> = (0..3)
            .map(|_| {
                let q = q.clone();
                std::thread::spawn(move || {
                    let mut got = Vec::new();
                    while let Some(v) = q.pop() {
                        got.push(v);
                    }
                    got
                })
            })
            .collect();
        let producers: Vec<_> = (0..n_producers)
            .map(|p| {
                let q = q.clone();
                std::thread::spawn(move || {
                    for i in 0..per_producer {
                        q.push(p as u64 * per_producer + i).unwrap();
                    }
                })
            })
            .collect();
        for p in producers {
            p.join().unwrap();
        }
        q.close();
        let mut all: Vec<u64> =
            consumers.into_iter().flat_map(|c| c.join().unwrap()).collect();
        all.sort_unstable();
        let expect: Vec<u64> = (0..n_producers as u64 * per_producer).collect();
        assert_eq!(all, expect);
    }
}
