//! Bounded MPMC queue: the admission and lane-dispatch channel of the
//! lane scheduler.
//!
//! `std::sync::mpsc` channels are unbounded, so a burst of clients could
//! queue arbitrarily much work in front of a busy engine. [`Bounded`] is
//! a small Mutex+Condvar MPMC queue with a hard capacity: producers
//! block (or fail fast with [`PushError::Full`] via
//! [`try_push`](Bounded::try_push)) when the queue is full, which is how
//! backpressure propagates from a slow lane all the way back to the
//! clients. Closing the queue wakes everyone: blocked producers fail
//! with [`PushError::Closed`], consumers drain the remaining items and
//! then observe the close — nothing enqueued before the close is ever
//! dropped (the shutdown-flush guarantee of the lane server).

use std::collections::VecDeque;
use std::sync::{Arc, Condvar, Mutex};
use std::time::Instant;

/// Why a push did not enqueue. The rejected value is handed back so the
/// caller can reply to it (e.g. with an explicit shutdown error).
#[derive(Debug)]
pub enum PushError<T> {
    /// Queue at capacity (returned by [`Bounded::try_push`] only).
    Full(T),
    /// Queue closed; no further items are accepted.
    Closed(T),
}

/// Outcome of a deadline-bounded pop.
#[derive(Debug)]
pub enum PopResult<T> {
    Item(T),
    /// Deadline passed with the queue still empty (and open).
    TimedOut,
    /// Queue closed and fully drained.
    Closed,
}

struct State<T> {
    buf: VecDeque<T>,
    closed: bool,
}

struct Shared<T> {
    state: Mutex<State<T>>,
    cap: usize,
    /// Signalled when an item is pushed or the queue closes.
    not_empty: Condvar,
    /// Signalled when an item is popped or the queue closes.
    not_full: Condvar,
}

/// A cloneable handle to one bounded MPMC queue; every clone is both a
/// producer and a consumer.
pub struct Bounded<T> {
    shared: Arc<Shared<T>>,
}

impl<T> Clone for Bounded<T> {
    fn clone(&self) -> Self {
        Bounded { shared: Arc::clone(&self.shared) }
    }
}

impl<T> Bounded<T> {
    /// A queue holding at most `cap` items (`cap` ≥ 1).
    pub fn new(cap: usize) -> Bounded<T> {
        assert!(cap >= 1, "bounded queue needs capacity >= 1");
        Bounded {
            shared: Arc::new(Shared {
                state: Mutex::new(State { buf: VecDeque::with_capacity(cap), closed: false }),
                cap,
                not_empty: Condvar::new(),
                not_full: Condvar::new(),
            }),
        }
    }

    pub fn capacity(&self) -> usize {
        self.shared.cap
    }

    pub fn len(&self) -> usize {
        self.shared.state.lock().unwrap().buf.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Block until there is space, then enqueue. Fails only when closed.
    pub fn push(&self, item: T) -> Result<(), PushError<T>> {
        let mut st = self.shared.state.lock().unwrap();
        loop {
            if st.closed {
                return Err(PushError::Closed(item));
            }
            if st.buf.len() < self.shared.cap {
                st.buf.push_back(item);
                drop(st);
                self.shared.not_empty.notify_one();
                return Ok(());
            }
            st = self.shared.not_full.wait(st).unwrap();
        }
    }

    /// Enqueue without blocking.
    pub fn try_push(&self, item: T) -> Result<(), PushError<T>> {
        let mut st = self.shared.state.lock().unwrap();
        if st.closed {
            return Err(PushError::Closed(item));
        }
        if st.buf.len() >= self.shared.cap {
            return Err(PushError::Full(item));
        }
        st.buf.push_back(item);
        drop(st);
        self.shared.not_empty.notify_one();
        Ok(())
    }

    /// Block until an item is available; `None` once closed *and* drained.
    pub fn pop(&self) -> Option<T> {
        let mut st = self.shared.state.lock().unwrap();
        loop {
            if let Some(item) = st.buf.pop_front() {
                drop(st);
                self.shared.not_full.notify_one();
                return Some(item);
            }
            if st.closed {
                return None;
            }
            st = self.shared.not_empty.wait(st).unwrap();
        }
    }

    /// Dequeue without blocking (even on a closed queue, drains leftovers).
    pub fn try_pop(&self) -> Option<T> {
        let mut st = self.shared.state.lock().unwrap();
        let item = st.buf.pop_front();
        if item.is_some() {
            drop(st);
            self.shared.not_full.notify_one();
        }
        item
    }

    /// Block until an item arrives, the queue closes, or `deadline` passes.
    pub fn pop_deadline(&self, deadline: Instant) -> PopResult<T> {
        let mut st = self.shared.state.lock().unwrap();
        loop {
            if let Some(item) = st.buf.pop_front() {
                drop(st);
                self.shared.not_full.notify_one();
                return PopResult::Item(item);
            }
            if st.closed {
                return PopResult::Closed;
            }
            let now = Instant::now();
            if now >= deadline {
                return PopResult::TimedOut;
            }
            let (guard, _timeout) =
                self.shared.not_empty.wait_timeout(st, deadline - now).unwrap();
            st = guard;
        }
    }

    /// Close the queue: producers fail from now on, consumers drain what
    /// is left. Idempotent.
    pub fn close(&self) {
        let mut st = self.shared.state.lock().unwrap();
        st.closed = true;
        drop(st);
        self.shared.not_empty.notify_all();
        self.shared.not_full.notify_all();
    }

    pub fn is_closed(&self) -> bool {
        self.shared.state.lock().unwrap().closed
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::time::Duration;

    #[test]
    fn fifo_order_and_capacity() {
        let q: Bounded<u32> = Bounded::new(2);
        q.push(1).unwrap();
        q.push(2).unwrap();
        assert!(matches!(q.try_push(3), Err(PushError::Full(3))));
        assert_eq!(q.pop(), Some(1));
        q.push(3).unwrap();
        assert_eq!(q.pop(), Some(2));
        assert_eq!(q.pop(), Some(3));
        assert!(q.try_pop().is_none());
    }

    #[test]
    fn close_drains_then_reports_closed() {
        let q: Bounded<u32> = Bounded::new(4);
        q.push(7).unwrap();
        q.close();
        assert!(matches!(q.push(8), Err(PushError::Closed(8))));
        assert_eq!(q.pop(), Some(7), "items enqueued before close survive");
        assert_eq!(q.pop(), None);
        assert!(matches!(q.pop_deadline(Instant::now()), PopResult::Closed));
    }

    #[test]
    fn pop_deadline_times_out_when_empty() {
        let q: Bounded<u32> = Bounded::new(1);
        let t0 = Instant::now();
        let r = q.pop_deadline(t0 + Duration::from_millis(20));
        assert!(matches!(r, PopResult::TimedOut));
        assert!(t0.elapsed() >= Duration::from_millis(20));
    }

    #[test]
    fn blocked_producer_resumes_after_pop() {
        let q: Bounded<u32> = Bounded::new(1);
        q.push(1).unwrap();
        let q2 = q.clone();
        let producer = std::thread::spawn(move || q2.push(2));
        std::thread::sleep(Duration::from_millis(10));
        assert_eq!(q.pop(), Some(1));
        producer.join().unwrap().unwrap();
        assert_eq!(q.pop(), Some(2));
    }

    #[test]
    fn blocked_consumer_wakes_on_close() {
        let q: Bounded<u32> = Bounded::new(1);
        let q2 = q.clone();
        let consumer = std::thread::spawn(move || q2.pop());
        std::thread::sleep(Duration::from_millis(10));
        q.close();
        assert_eq!(consumer.join().unwrap(), None);
    }

    #[test]
    fn mpmc_all_items_delivered_exactly_once() {
        let q: Bounded<u64> = Bounded::new(8);
        let n_producers = 4;
        let per_producer = 50u64;
        let consumers: Vec<_> = (0..3)
            .map(|_| {
                let q = q.clone();
                std::thread::spawn(move || {
                    let mut got = Vec::new();
                    while let Some(v) = q.pop() {
                        got.push(v);
                    }
                    got
                })
            })
            .collect();
        let producers: Vec<_> = (0..n_producers)
            .map(|p| {
                let q = q.clone();
                std::thread::spawn(move || {
                    for i in 0..per_producer {
                        q.push(p as u64 * per_producer + i).unwrap();
                    }
                })
            })
            .collect();
        for p in producers {
            p.join().unwrap();
        }
        q.close();
        let mut all: Vec<u64> =
            consumers.into_iter().flat_map(|c| c.join().unwrap()).collect();
        all.sort_unstable();
        let expect: Vec<u64> = (0..n_producers as u64 * per_producer).collect();
        assert_eq!(all, expect);
    }
}
