//! The serving loop: a dedicated engine thread (PJRT state is not `Send`)
//! consuming a request channel through the dynamic batcher. This is the
//! **single-engine-thread baseline**; the lane scheduler
//! ([`super::lanes::LaneServer`]) overlaps batch buckets end-to-end and
//! is what the serving bench compares against. Shutdown flushes the
//! request channel before the engine stops: a request sent before
//! `shutdown` was called is served, never dropped.
//!
//! Wire-up:
//!   client threads → mpsc<Request> → [server thread: batcher → engine
//!   (any [`InferEngine`]) → per-request responses] → mpsc<Response> per
//!   client.
//!
//! The server is engine-agnostic: [`NimbleServer::start_with`] takes a
//! factory that builds the engine *on the engine thread* (so non-`Send`
//! engines like the PJRT one work), and the engine keeps its own
//! reusable per-bucket replay contexts ([`PreparedReplay`] on the PJRT
//! side, [`ReplayContext`] in the tape engine). The batcher writes each
//! padded batch into one reused buffer (`form_with`), so the steady-state
//! serving loop allocates only for response marshalling.
//!
//! [`PreparedReplay`]: crate::aot::tape
//! [`ReplayContext`]: crate::engine::executor::ReplayContext

use anyhow::{Context, Result};
use std::sync::mpsc;
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use super::batcher::{BatchPolicy, Batcher};
use super::metrics::ServingReport;
use crate::coordinator::{EngineConfig, ExecMode, InferEngine};
use crate::util::stats::Summary;

/// Server configuration (PJRT-backed engine).
#[derive(Debug, Clone)]
pub struct ServerConfig {
    pub engine: EngineConfig,
    pub max_wait: Duration,
}

impl Default for ServerConfig {
    fn default() -> Self {
        ServerConfig { engine: EngineConfig::default(), max_wait: Duration::from_millis(2) }
    }
}

enum Msg {
    Infer {
        input: Vec<f32>,
        /// Optional bucket hint the batcher honors over queue-depth
        /// routing (ignored unless it names a compiled bucket).
        hint: Option<usize>,
        reply: mpsc::Sender<Result<Vec<f32>, String>>,
    },
    Shutdown { reply: mpsc::Sender<ServingReport> },
}

/// Handle to a running server.
pub struct NimbleServer {
    tx: mpsc::Sender<Msg>,
    join: Option<JoinHandle<()>>,
    example_len: usize,
    output_len: usize,
}

/// Cloneable, `Send` request handle: one per client thread
/// ([`NimbleServer::client`]). Dropping clients does not stop the server.
#[derive(Clone)]
pub struct ServerClient {
    tx: mpsc::Sender<Msg>,
    example_len: usize,
    output_len: usize,
}

impl ServerClient {
    pub fn example_len(&self) -> usize {
        self.example_len
    }

    pub fn output_len(&self) -> usize {
        self.output_len
    }

    /// Blocking inference of one example.
    pub fn infer(&self, input: Vec<f32>) -> Result<Vec<f32>> {
        let (reply, rx) = mpsc::channel();
        self.tx
            .send(Msg::Infer { input, hint: None, reply })
            .map_err(|_| anyhow::anyhow!("server stopped"))?;
        rx.recv().context("server dropped request")?.map_err(anyhow::Error::msg)
    }

    /// Blocking inference carrying a bucket hint: the batcher routes the
    /// request's batch to `bucket` (if compiled) instead of deriving the
    /// bucket from queue depth — sequence-length-aware clients pick
    /// their own lane.
    pub fn infer_hinted(&self, input: Vec<f32>, bucket: usize) -> Result<Vec<f32>> {
        let (reply, rx) = mpsc::channel();
        self.tx
            .send(Msg::Infer { input, hint: Some(bucket), reply })
            .map_err(|_| anyhow::anyhow!("server stopped"))?;
        rx.recv().context("server dropped request")?.map_err(anyhow::Error::msg)
    }

    /// Fire an async request; returns the reply channel.
    pub fn infer_async(&self, input: Vec<f32>) -> Result<mpsc::Receiver<Result<Vec<f32>, String>>> {
        let (reply, rx) = mpsc::channel();
        self.tx
            .send(Msg::Infer { input, hint: None, reply })
            .map_err(|_| anyhow::anyhow!("server stopped"))?;
        Ok(rx)
    }
}

impl NimbleServer {
    /// Start a server over any [`InferEngine`]; the factory runs on the
    /// engine thread and the call blocks until the engine finished its
    /// build (so the first request is already schedule-replayed).
    pub fn start_with<E, F>(factory: F, max_wait: Duration) -> Result<NimbleServer>
    where
        E: InferEngine + 'static,
        F: FnOnce() -> Result<E> + Send + 'static,
    {
        let (tx, rx) = mpsc::channel::<Msg>();
        let (ready_tx, ready_rx) = mpsc::channel::<Result<(usize, usize), String>>();
        let join = std::thread::Builder::new()
            .name("nimble-engine".into())
            .spawn(move || engine_thread(factory, max_wait, rx, ready_tx))
            .context("spawning engine thread")?;
        let (example_len, output_len) = ready_rx
            .recv()
            .context("engine thread died during build")?
            .map_err(anyhow::Error::msg)?;
        Ok(NimbleServer { tx, join: Some(join), example_len, output_len })
    }

    /// Start the PJRT-backed server (the paper's real-runtime path).
    #[cfg(feature = "xla")]
    pub fn start(config: ServerConfig) -> Result<NimbleServer> {
        let engine_config = config.engine.clone();
        Self::start_with(
            move || crate::coordinator::NimbleEngine::build(engine_config),
            config.max_wait,
        )
    }

    pub fn example_len(&self) -> usize {
        self.example_len
    }

    /// Flattened output length of one example.
    pub fn output_len(&self) -> usize {
        self.output_len
    }

    /// A cloneable request handle for client threads.
    pub fn client(&self) -> ServerClient {
        ServerClient {
            tx: self.tx.clone(),
            example_len: self.example_len,
            output_len: self.output_len,
        }
    }

    /// Blocking inference of one example.
    pub fn infer(&self, input: Vec<f32>) -> Result<Vec<f32>> {
        self.client().infer(input)
    }

    /// Blocking inference with a bucket hint
    /// ([`ServerClient::infer_hinted`]).
    pub fn infer_hinted(&self, input: Vec<f32>, bucket: usize) -> Result<Vec<f32>> {
        self.client().infer_hinted(input, bucket)
    }

    /// Fire an async request; returns the reply channel.
    pub fn infer_async(&self, input: Vec<f32>) -> Result<mpsc::Receiver<Result<Vec<f32>, String>>> {
        self.client().infer_async(input)
    }

    /// Stop the server and collect the serving report.
    pub fn shutdown(mut self) -> Result<ServingReport> {
        let (reply, rx) = mpsc::channel();
        self.tx.send(Msg::Shutdown { reply }).ok();
        let report = rx.recv().context("no report from engine thread")?;
        if let Some(j) = self.join.take() {
            let _ = j.join();
        }
        Ok(report)
    }
}

fn engine_thread<E: InferEngine>(
    factory: impl FnOnce() -> Result<E>,
    max_wait: Duration,
    rx: mpsc::Receiver<Msg>,
    ready: mpsc::Sender<Result<(usize, usize), String>>,
) {
    let mut engine = match factory() {
        Ok(e) => e,
        Err(err) => {
            let _ = ready.send(Err(format!("{err:#}")));
            return;
        }
    };
    let batch_sizes = engine.batch_sizes();
    let example_len = engine.example_len();
    let output_len = engine.output_len();
    let _ = ready.send(Ok((example_len, output_len)));

    let policy = BatchPolicy { batch_sizes, max_wait };
    let mut batcher: Batcher<mpsc::Sender<Result<Vec<f32>, String>>> = Batcher::new(policy);
    // Reused padded-batch input buffer (`Batcher::form_with`).
    let mut batch_input: Vec<f32> = Vec::new();
    let started = Instant::now();
    let mut latencies: Vec<f64> = Vec::new();
    let mut n_requests = 0usize;
    let mut n_batches = 0usize;
    let mut fill_sum = 0usize;
    let mut shutdown_reply: Option<mpsc::Sender<ServingReport>> = None;

    'outer: loop {
        // Wait for work (bounded by the oldest request's flush deadline).
        let msg = match batcher.next_deadline() {
            None => rx.recv().ok(),
            Some(deadline) => {
                let now = Instant::now();
                if deadline <= now {
                    None
                } else {
                    match rx.recv_timeout(deadline - now) {
                        Ok(m) => Some(m),
                        Err(mpsc::RecvTimeoutError::Timeout) => None,
                        Err(mpsc::RecvTimeoutError::Disconnected) => break 'outer,
                    }
                }
            }
        };
        match msg {
            Some(Msg::Infer { input, hint, reply }) => {
                if input.len() != example_len {
                    let _ = reply
                        .send(Err(format!("bad input length {} != {example_len}", input.len())));
                } else {
                    batcher.push_hinted(reply, input, hint);
                }
            }
            Some(Msg::Shutdown { reply }) => {
                shutdown_reply = Some(reply);
                // Flush the channel: requests already sent when shutdown
                // was requested must be served, not dropped with the
                // receiver. (Anything sent after this drain fails at the
                // sender once the channel disconnects below.)
                while let Ok(m) = rx.try_recv() {
                    match m {
                        Msg::Infer { input, hint, reply } => {
                            if input.len() != example_len {
                                let _ = reply.send(Err(format!(
                                    "bad input length {} != {example_len}",
                                    input.len()
                                )));
                            } else {
                                batcher.push_hinted(reply, input, hint);
                            }
                        }
                        Msg::Shutdown { .. } => {}
                    }
                }
            }
            None if batcher.pending() == 0 && shutdown_reply.is_none() => break 'outer,
            None => {}
        }

        // Flush ready batches (always flush everything on shutdown).
        while (shutdown_reply.is_some() && batcher.pending() > 0)
            || batcher.ready(Instant::now())
        {
            let Some(fb) = batcher.form_with(example_len, &mut batch_input) else { break };
            n_batches += 1;
            fill_sum += fb.tokens.len();
            match engine.infer_batch(fb.bucket, &batch_input) {
                Ok(out) => {
                    let done = Instant::now();
                    for (i, (reply, enq)) in fb.tokens.into_iter().enumerate() {
                        latencies.push(done.duration_since(enq).as_secs_f64());
                        n_requests += 1;
                        let slice = out[i * output_len..(i + 1) * output_len].to_vec();
                        let _ = reply.send(Ok(slice));
                    }
                }
                Err(err) => {
                    for (reply, _) in fb.tokens {
                        let _ = reply.send(Err(format!("{err:#}")));
                    }
                }
            }
        }

        if shutdown_reply.is_some() && batcher.pending() == 0 {
            break 'outer;
        }
    }

    let report = ServingReport {
        n_requests,
        n_batches,
        wall_time: started.elapsed(),
        latency: if latencies.is_empty() {
            Summary::from_samples(vec![0.0])
        } else {
            Summary::from_samples(latencies)
        },
        mean_batch_fill: if n_batches == 0 { 0.0 } else { fill_sum as f64 / n_batches as f64 },
        lanes: Vec::new(),
    };
    if let Some(reply) = shutdown_reply {
        let _ = reply.send(report);
    }
}

/// Convenience: describe which mode a server runs in (for reports).
pub fn mode_name(mode: ExecMode) -> &'static str {
    match mode {
        ExecMode::Replay => "nimble-replay",
        ExecMode::Eager => "eager-baseline",
    }
}
