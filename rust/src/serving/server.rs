//! The serving loop: a dedicated engine thread (PJRT state is not `Send`)
//! consuming a request channel through the dynamic batcher. This is the
//! **single-engine-thread baseline**; the lane scheduler
//! ([`super::lanes::LaneServer`]) overlaps batch buckets end-to-end and
//! is what the serving bench compares against. Shutdown flushes the
//! request channel before the engine stops: a request sent before
//! `shutdown` was called is served, never dropped. Requests whose
//! [`deadline`](crate::serving::RequestOptions::deadline) expires while
//! they wait in the batcher are shed before the engine runs them
//! (`ServingReport::deadline_shed`).
//!
//! Construct through [`Runtime::builder()`](crate::serving::Runtime)
//! with [`single_thread()`](crate::serving::RuntimeBuilder::single_thread);
//! the old `NimbleServer::{start, start_with}` constructors and the
//! `infer*` method variants are deprecated shims over the same
//! internals.
//!
//! Wire-up:
//!   client threads → mpsc<Request> → [server thread: batcher → engine
//!   (any [`InferEngine`]) → per-request responses] → mpsc<Response> per
//!   client.
//!
//! The server is engine-agnostic: the factory runs *on the engine
//! thread* (so non-`Send` engines like the PJRT one work), and the
//! engine keeps its own reusable per-bucket replay contexts
//! ([`PreparedReplay`] on the PJRT side, [`ReplayContext`] in the tape
//! engine). The batcher writes each padded batch into one reused buffer
//! (`form_with`), so the steady-state serving loop allocates only for
//! response marshalling.
//!
//! [`PreparedReplay`]: crate::aot::tape
//! [`ReplayContext`]: crate::engine::executor::ReplayContext

use anyhow::{Context, Result};
use std::sync::mpsc;
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use super::batcher::{BatchPolicy, Batcher};
use super::metrics::ServingReport;
use super::runtime::ReqToken;
use crate::coordinator::{EngineConfig, ExecMode, InferEngine};
use crate::util::stats::Summary;

/// Server configuration (PJRT-backed engine).
#[derive(Debug, Clone)]
pub struct ServerConfig {
    pub engine: EngineConfig,
    pub max_wait: Duration,
}

impl Default for ServerConfig {
    fn default() -> Self {
        ServerConfig { engine: EngineConfig::default(), max_wait: Duration::from_millis(2) }
    }
}

enum Msg {
    Infer {
        input: Vec<f32>,
        /// Optional bucket hint the batcher honors over queue-depth
        /// routing (ignored unless it names a compiled bucket).
        hint: Option<usize>,
        /// Shed the request if it still waits in the batcher at this
        /// instant.
        deadline: Option<Instant>,
        reply: mpsc::Sender<Result<Vec<f32>, String>>,
    },
    Shutdown { reply: mpsc::Sender<ServingReport> },
}

/// Handle to a running server.
pub struct NimbleServer {
    tx: mpsc::Sender<Msg>,
    join: Option<JoinHandle<()>>,
    example_len: usize,
    output_len: usize,
    batch_sizes: Vec<usize>,
}

/// Cloneable, `Send` request handle: one per client thread
/// ([`NimbleServer::client`]). Dropping clients does not stop the server.
#[derive(Clone)]
pub struct ServerClient {
    tx: mpsc::Sender<Msg>,
    example_len: usize,
    output_len: usize,
    batch_sizes: Vec<usize>,
}

impl ServerClient {
    pub fn example_len(&self) -> usize {
        self.example_len
    }

    pub fn output_len(&self) -> usize {
        self.output_len
    }

    /// Compiled batch buckets of the engine, ascending.
    pub fn batch_sizes(&self) -> &[usize] {
        &self.batch_sizes
    }

    /// The one submit path: enqueue `(input, hint, deadline)` and hand
    /// back the raw reply channel. [`RuntimeHandle`] wraps this (and
    /// validates) — the deprecated `infer*` variants are shims over it.
    ///
    /// [`RuntimeHandle`]: crate::serving::RuntimeHandle
    pub(crate) fn submit_raw(
        &self,
        input: Vec<f32>,
        hint: Option<usize>,
        deadline: Option<Instant>,
    ) -> Result<mpsc::Receiver<Result<Vec<f32>, String>>> {
        let (reply, rx) = mpsc::channel();
        self.tx
            .send(Msg::Infer { input, hint, deadline, reply })
            .map_err(|_| anyhow::anyhow!("server stopped"))?;
        Ok(rx)
    }

    /// Blocking inference of one example.
    #[deprecated(note = "build a Runtime and call infer(InferRequest) — see rust/README.md")]
    pub fn infer(&self, input: Vec<f32>) -> Result<Vec<f32>> {
        let rx = self.submit_raw(input, None, None)?;
        rx.recv().context("server dropped request")?.map_err(anyhow::Error::msg)
    }

    /// Blocking inference carrying a bucket hint: the batcher routes the
    /// request's batch to `bucket` (if compiled) instead of deriving the
    /// bucket from queue depth — sequence-length-aware clients pick
    /// their own lane.
    #[deprecated(note = "use Runtime::infer(InferRequest::new(..).hint(bucket))")]
    pub fn infer_hinted(&self, input: Vec<f32>, bucket: usize) -> Result<Vec<f32>> {
        let rx = self.submit_raw(input, Some(bucket), None)?;
        rx.recv().context("server dropped request")?.map_err(anyhow::Error::msg)
    }

    /// Fire an async request; returns the reply channel.
    #[deprecated(note = "use Runtime::submit(InferRequest) -> Ticket")]
    pub fn infer_async(&self, input: Vec<f32>) -> Result<mpsc::Receiver<Result<Vec<f32>, String>>> {
        self.submit_raw(input, None, None)
    }

    /// Async variant of [`infer_hinted`](Self::infer_hinted) — closes
    /// the historical parity gap with `LaneClient::infer_hinted_async`.
    #[deprecated(note = "use Runtime::submit(InferRequest::new(..).hint(bucket)) -> Ticket")]
    pub fn infer_hinted_async(
        &self,
        input: Vec<f32>,
        bucket: usize,
    ) -> Result<mpsc::Receiver<Result<Vec<f32>, String>>> {
        self.submit_raw(input, Some(bucket), None)
    }
}

impl NimbleServer {
    /// Start a server over any [`InferEngine`]; the factory runs on the
    /// engine thread and the call blocks until the engine finished its
    /// build (so the first request is already schedule-replayed). The
    /// non-deprecated spelling is
    /// `Runtime::builder().single_thread().build()`.
    pub(crate) fn spawn<E, F>(factory: F, max_wait: Duration) -> Result<NimbleServer>
    where
        E: InferEngine + 'static,
        F: FnOnce() -> Result<E> + Send + 'static,
    {
        let (tx, rx) = mpsc::channel::<Msg>();
        type Ready = Result<(usize, usize, Vec<usize>), String>;
        let (ready_tx, ready_rx) = mpsc::channel::<Ready>();
        let join = std::thread::Builder::new()
            .name("nimble-engine".into())
            .spawn(move || engine_thread(factory, max_wait, rx, ready_tx))
            .context("spawning engine thread")?;
        let (example_len, output_len, batch_sizes) = ready_rx
            .recv()
            .context("engine thread died during build")?
            .map_err(anyhow::Error::msg)?;
        Ok(NimbleServer { tx, join: Some(join), example_len, output_len, batch_sizes })
    }

    /// Start a server over any [`InferEngine`] built by `factory` on
    /// the engine thread.
    #[deprecated(note = "use Runtime::builder().single_thread().build() — see rust/README.md")]
    pub fn start_with<E, F>(factory: F, max_wait: Duration) -> Result<NimbleServer>
    where
        E: InferEngine + 'static,
        F: FnOnce() -> Result<E> + Send + 'static,
    {
        Self::spawn(factory, max_wait)
    }

    /// Start the PJRT-backed server (the paper's real-runtime path).
    #[cfg(feature = "xla")]
    #[deprecated(
        note = "use Runtime::builder().artifacts(config.engine).single_thread().build()"
    )]
    pub fn start(config: ServerConfig) -> Result<NimbleServer> {
        let engine_config = config.engine.clone();
        Self::spawn(
            move || crate::coordinator::NimbleEngine::build(engine_config),
            config.max_wait,
        )
    }

    pub fn example_len(&self) -> usize {
        self.example_len
    }

    /// Flattened output length of one example.
    pub fn output_len(&self) -> usize {
        self.output_len
    }

    /// Compiled batch buckets of the engine, ascending.
    pub fn batch_sizes(&self) -> &[usize] {
        &self.batch_sizes
    }

    /// A cloneable request handle for client threads.
    pub fn client(&self) -> ServerClient {
        ServerClient {
            tx: self.tx.clone(),
            example_len: self.example_len,
            output_len: self.output_len,
            batch_sizes: self.batch_sizes.clone(),
        }
    }

    /// Blocking inference of one example.
    #[deprecated(note = "build a Runtime and call infer(InferRequest) — see rust/README.md")]
    pub fn infer(&self, input: Vec<f32>) -> Result<Vec<f32>> {
        let rx = self.client().submit_raw(input, None, None)?;
        rx.recv().context("server dropped request")?.map_err(anyhow::Error::msg)
    }

    /// Blocking inference with a bucket hint.
    #[deprecated(note = "use Runtime::infer(InferRequest::new(..).hint(bucket))")]
    pub fn infer_hinted(&self, input: Vec<f32>, bucket: usize) -> Result<Vec<f32>> {
        let rx = self.client().submit_raw(input, Some(bucket), None)?;
        rx.recv().context("server dropped request")?.map_err(anyhow::Error::msg)
    }

    /// Fire an async request; returns the reply channel.
    #[deprecated(note = "use Runtime::submit(InferRequest) -> Ticket")]
    pub fn infer_async(&self, input: Vec<f32>) -> Result<mpsc::Receiver<Result<Vec<f32>, String>>> {
        self.client().submit_raw(input, None, None)
    }

    /// Stop the server and collect the serving report.
    pub fn shutdown(mut self) -> Result<ServingReport> {
        let (reply, rx) = mpsc::channel();
        self.tx.send(Msg::Shutdown { reply }).ok();
        let report = rx.recv().context("no report from engine thread")?;
        if let Some(j) = self.join.take() {
            let _ = j.join();
        }
        Ok(report)
    }
}

fn engine_thread<E: InferEngine>(
    factory: impl FnOnce() -> Result<E>,
    max_wait: Duration,
    rx: mpsc::Receiver<Msg>,
    ready: mpsc::Sender<Result<(usize, usize, Vec<usize>), String>>,
) {
    let mut engine = match factory() {
        Ok(e) => e,
        Err(err) => {
            let _ = ready.send(Err(format!("{err:#}")));
            return;
        }
    };
    let batch_sizes = engine.batch_sizes();
    let example_len = engine.example_len();
    let output_len = engine.output_len();
    let _ = ready.send(Ok((example_len, output_len, batch_sizes.clone())));

    let policy = BatchPolicy { batch_sizes, max_wait };
    let mut batcher: Batcher<ReqToken> = Batcher::new(policy);
    // Reused padded-batch input buffer (`Batcher::form_with`).
    let mut batch_input: Vec<f32> = Vec::new();
    let started = Instant::now();
    let mut latencies: Vec<f64> = Vec::new();
    let mut n_requests = 0usize;
    let mut n_batches = 0usize;
    let mut fill_sum = 0usize;
    let mut deadline_shed = 0usize;
    let mut failed = 0usize;
    let mut shutdown_reply: Option<mpsc::Sender<ServingReport>> = None;

    let admit = |batcher: &mut Batcher<ReqToken>,
                 failed: &mut usize,
                 input: Vec<f32>,
                 hint: Option<usize>,
                 deadline: Option<Instant>,
                 reply: mpsc::Sender<Result<Vec<f32>, String>>| {
        if input.len() != example_len {
            let _ =
                reply.send(Err(format!("bad input length {} != {example_len}", input.len())));
            *failed += 1;
        } else {
            batcher.push_hinted(ReqToken { reply, deadline, trace: 0 }, input, hint);
        }
    };

    'outer: loop {
        // Wait for work (bounded by the oldest request's flush deadline).
        let msg = match batcher.next_deadline() {
            None => rx.recv().ok(),
            Some(deadline) => {
                let now = Instant::now();
                if deadline <= now {
                    None
                } else {
                    match rx.recv_timeout(deadline - now) {
                        Ok(m) => Some(m),
                        Err(mpsc::RecvTimeoutError::Timeout) => None,
                        Err(mpsc::RecvTimeoutError::Disconnected) => break 'outer,
                    }
                }
            }
        };
        match msg {
            Some(Msg::Infer { input, hint, deadline, reply }) => {
                admit(&mut batcher, &mut failed, input, hint, deadline, reply);
            }
            Some(Msg::Shutdown { reply }) => {
                shutdown_reply = Some(reply);
                // Flush the channel: requests already sent when shutdown
                // was requested must be served, not dropped with the
                // receiver. (Anything sent after this drain fails at the
                // sender once the channel disconnects below.)
                while let Ok(m) = rx.try_recv() {
                    match m {
                        Msg::Infer { input, hint, deadline, reply } => {
                            admit(&mut batcher, &mut failed, input, hint, deadline, reply);
                        }
                        Msg::Shutdown { .. } => {}
                    }
                }
            }
            None if batcher.pending() == 0 && shutdown_reply.is_none() => break 'outer,
            None => {}
        }

        // Flush ready batches (always flush everything on shutdown).
        while (shutdown_reply.is_some() && batcher.pending() > 0)
            || batcher.ready(Instant::now())
        {
            let Some(fb) = batcher.form_with(example_len, &mut batch_input) else { break };
            // Shed whatever expired while it waited in the batcher —
            // shed rows stay in the padded input (zero-risk: surviving
            // rows keep their positions), but an all-shed batch skips
            // the engine entirely.
            let now = Instant::now();
            let shed: Vec<bool> = fb.tokens.iter().map(|(tok, _)| tok.expired(now)).collect();
            let n_live = shed.iter().filter(|s| !**s).count();
            for ((tok, _), is_shed) in fb.tokens.iter().zip(&shed) {
                if *is_shed {
                    tok.shed();
                    deadline_shed += 1;
                }
            }
            if n_live == 0 {
                continue;
            }
            n_batches += 1;
            fill_sum += n_live;
            match engine.infer_batch(fb.bucket, &batch_input) {
                Ok(out) => {
                    let done = Instant::now();
                    for (i, ((tok, enq), is_shed)) in
                        fb.tokens.into_iter().zip(shed).enumerate()
                    {
                        if is_shed {
                            continue;
                        }
                        latencies.push(done.duration_since(enq).as_secs_f64());
                        n_requests += 1;
                        let slice = out[i * output_len..(i + 1) * output_len].to_vec();
                        let _ = tok.reply.send(Ok(slice));
                    }
                }
                Err(err) => {
                    for ((tok, _), is_shed) in fb.tokens.into_iter().zip(shed) {
                        if !is_shed {
                            let _ = tok.reply.send(Err(format!("{err:#}")));
                            failed += 1;
                        }
                    }
                }
            }
        }

        if shutdown_reply.is_some() && batcher.pending() == 0 {
            break 'outer;
        }
    }

    let report = ServingReport {
        n_requests,
        n_batches,
        wall_time: started.elapsed(),
        latency: if latencies.is_empty() {
            Summary::from_samples(vec![0.0])
        } else {
            Summary::from_samples(latencies)
        },
        mean_batch_fill: if n_batches == 0 { 0.0 } else { fill_sum as f64 / n_batches as f64 },
        deadline_shed,
        admission_shed: 0,
        failed,
        retries: 0,
        lanes: Vec::new(),
    };
    if let Some(reply) = shutdown_reply {
        let _ = reply.send(report);
    }
}

/// Convenience: describe which mode a server runs in (for reports).
pub fn mode_name(mode: ExecMode) -> &'static str {
    match mode {
        ExecMode::Replay => "nimble-replay",
        ExecMode::Eager => "eager-baseline",
    }
}
