//! Batched serving front-end — the "serving paper" L3 shape: request
//! queue → dynamic batcher → inference engine(s) → latency/throughput
//! metrics.
//!
//! Two servers share the batcher and the [`InferEngine`](crate::coordinator::InferEngine)
//! contract:
//!
//! * [`server::NimbleServer`] — the single-engine-thread baseline: one
//!   dedicated thread owns the engine (PJRT state is not `Send`) and
//!   executes batches sequentially.
//! * [`lanes::LaneServer`] — the lane scheduler: a bounded MPMC
//!   admission queue feeds a dispatcher that routes each formed batch to
//!   its bucket's **lane**, a dedicated thread with its own engine.
//!   Same-bucket batches pipeline FIFO; different buckets overlap
//!   end-to-end. Backpressure flows lane → buffer pool → batcher →
//!   admission queue → clients.
//!
//! Static shapes (the paper's core assumption) mean the batcher pads
//! each group to the nearest compiled batch size, TensorRT-profile
//! style, writing into reused batch buffers. Each batch bucket replays
//! on its own reusable context: [`sim_engine::TapeEngine`] on the
//! virtual substrate (always available), the PJRT `NimbleEngine` with
//! the `xla` feature (per-lane instances via
//! `NimbleEngine::build_for`).

pub mod batcher;
pub mod lanes;
pub mod metrics;
pub mod queue;
pub mod server;
pub mod sim_engine;

pub use batcher::{BatchPolicy, Batcher};
pub use lanes::{LaneClient, LaneConfig, LaneServer, ScaleOptions};
pub use metrics::{LaneStat, ServingReport};
pub use queue::Bounded;
pub use server::{NimbleServer, ServerClient, ServerConfig};
pub use sim_engine::{TapeEngine, TapeEngineOptions};
