//! Batched serving front-end — the "serving paper" L3 shape: request
//! queue → dynamic batcher → inference engine → latency/throughput
//! metrics.
//!
//! The server is generic over [`InferEngine`](crate::coordinator::InferEngine)
//! and runs the engine on a dedicated thread (PJRT state is not `Send`),
//! communicating over channels. Static shapes (the paper's core
//! assumption) mean the batcher pads each group to the nearest compiled
//! batch size, TensorRT-profile style, writing into one reused batch
//! buffer. Each batch bucket replays on its own reusable context:
//! [`sim_engine::TapeEngine`] on the virtual substrate (always
//! available), the PJRT `NimbleEngine` with the `xla` feature.

pub mod batcher;
pub mod metrics;
pub mod server;
pub mod sim_engine;

pub use batcher::{BatchPolicy, Batcher};
pub use metrics::ServingReport;
pub use server::{NimbleServer, ServerClient, ServerConfig};
pub use sim_engine::TapeEngine;
