//! Batched serving front-end — the "serving paper" L3 shape: request
//! queue → dynamic batcher → inference engine(s) → latency/throughput
//! metrics — behind ONE runtime façade.
//!
//! The public surface is [`runtime`]: compose everything on
//! [`Runtime::builder()`] (model / graph-fn / PJRT artifacts, batch
//! buckets, worker caps, arena + shared worker pools, elastic scaling,
//! topology) and submit through exactly two methods — blocking
//! [`Runtime::infer`] and waitable [`Runtime::submit`] — both taking an
//! [`InferRequest`] whose [`RequestOptions`] carry bucket hints and
//! **deadlines**. Deadlines are the scheduling discipline, not just a
//! filter: requests whose budget the per-bucket queue-delay estimate
//! already rules out are shed *at admission* (broken out in
//! [`ServingReport::admission_shed`]), batches form
//! earliest-deadline-first with deadline-less traffic ranked last
//! (FIFO ties — deadline-free workloads are bit-identical to the
//! `builder().edf(false)` FIFO baseline), expired-while-waiting
//! requests are shed before execution wherever they sit, and
//! `builder().slo(target)` closes the loop with a shed-rate controller
//! that force-spawns elastic lanes. Sheds surface as
//! [`InferOutcome::DeadlineShed`] and count in
//! [`ServingReport::deadline_shed`]; [`crate::sim::simulate_edf`]
//! predicts the whole discipline offline.
//!
//! Two server topologies sit behind the façade, sharing the batcher and
//! the [`InferEngine`](crate::coordinator::InferEngine) contract:
//!
//! * [`server::NimbleServer`] — the single-engine-thread baseline
//!   (`builder().single_thread()`): one dedicated thread owns the
//!   engine (PJRT state is not `Send`) and executes batches
//!   sequentially.
//! * [`lanes::LaneServer`] — the lane scheduler (the default): a
//!   bounded MPMC admission queue feeds a dispatcher that routes each
//!   formed batch to its bucket's **lane**, a dedicated thread with its
//!   own engine. Same-bucket batches pipeline FIFO; different buckets
//!   overlap end-to-end; saturated buckets scale elastically
//!   ([`ScaleOptions`]). Backpressure flows lane → buffer pool →
//!   batcher → admission queue → clients.
//!
//! Static shapes (the paper's core assumption) mean the batcher pads
//! each group to the nearest compiled batch size, TensorRT-profile
//! style, writing into reused batch buffers. Each batch bucket replays
//! on its own reusable context: [`sim_engine::TapeEngine`] on the
//! virtual substrate (always available), the PJRT `NimbleEngine` with
//! the `xla` feature (per-lane instances via `NimbleEngine::build_for`).
//!
//! Failure semantics: every admitted request resolves exactly once —
//! output, [`InferOutcome::DeadlineShed`], or [`InferOutcome::Failed`].
//! Lane supervision retries transient engine failures under a bounded
//! [`RetryPolicy`], replaces lanes whose contexts were poisoned, and
//! [`Runtime::drain`] flushes everything before the final report.
//! Seeded chaos ([`FaultPlan`] via `builder().fault_plan(..)`) makes
//! all of it deterministic and testable; [`Runtime::health`] /
//! [`RuntimeHandle::health`] expose the [`Health`] probe.
//!
//! The pre-façade constructors (`TapeEngine::new` …,
//! `LaneServer::start*`, `NimbleServer::start*`) and per-client method
//! variants (`infer`/`infer_hinted`/`infer_async`/`infer_hinted_async`/
//! `submit_batch`) are `#[deprecated]` shims over the same internals —
//! see the migration table in `rust/README.md`.

pub mod batcher;
pub mod lanes;
pub mod metrics;
pub mod queue;
pub mod runtime;
pub mod server;
pub mod sim_engine;

pub use batcher::{BatchPolicy, Batcher};
pub use lanes::{LaneClient, LaneConfig, LaneServer, ScaleOptions};
pub use metrics::{LaneStat, ServingReport};
pub use queue::Bounded;
pub use crate::aot::verify::VerifyMode;
pub use crate::fault::{ChaosEngine, FaultPlan, RetryPolicy};
pub use crate::telemetry::Telemetry;
pub use runtime::{
    is_validation_error, Health, InferOutcome, InferRequest, RequestOptions, Runtime,
    RuntimeBuilder, RuntimeHandle, Ticket, TicketFuture, ValidationError, DEADLINE_SHED,
};
pub use server::{NimbleServer, ServerClient, ServerConfig};
pub use sim_engine::{TapeEngine, TapeEngineOptions};
