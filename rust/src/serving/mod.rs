//! Batched serving front-end — the "serving paper" L3 shape: request
//! queue → dynamic batcher → Nimble engine → latency/throughput metrics.
//!
//! The engine owns PJRT state, which is not `Send`; the server therefore
//! runs the engine on a dedicated thread and communicates over channels.
//! Static shapes (the paper's core assumption) mean the batcher pads each
//! group to the nearest compiled batch size, TensorRT-profile style.

pub mod batcher;
pub mod metrics;
pub mod server;

pub use batcher::{BatchPolicy, Batcher};
pub use metrics::ServingReport;
pub use server::{NimbleServer, ServerConfig};
