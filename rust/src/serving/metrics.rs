//! Serving metrics: per-request latency distribution + throughput, and
//! per-lane breakdowns for the lane scheduler.

use crate::util::stats::{fmt_secs, Summary};
use std::time::Duration;

/// Per-lane counters reported by the lane scheduler: one entry per batch
/// bucket, filled by that bucket's lane thread at shutdown.
#[derive(Debug, Clone)]
pub struct LaneStat {
    /// Compiled batch size this lane serves.
    pub bucket: usize,
    /// Stream count of the lane engine's replay context, when the engine
    /// exposes it ([`InferEngine::stream_count`](crate::coordinator::InferEngine::stream_count)).
    pub n_streams: Option<usize>,
    /// Packed arena reservation of the lane engine's replay context,
    /// when the engine exposes it
    /// ([`InferEngine::reserved_bytes`](crate::coordinator::InferEngine::reserved_bytes)).
    pub reserved_bytes: Option<u64>,
    pub n_batches: usize,
    /// Real (unpadded) examples served by this lane.
    pub n_requests: usize,
    /// Seconds the lane engine spent inside `infer_batch`.
    pub busy_s: f64,
    /// Mean seconds a formed batch waited in this lane's queue.
    pub mean_queue_wait_s: f64,
    /// Padded-buffer would-allocate events on this lane's dispatch path
    /// (0 in steady state: buffers are pooled and reused).
    pub alloc_events: u64,
}

impl LaneStat {
    pub fn render(&self) -> String {
        format!(
            "lane[bucket={}]: batches={} requests={} busy={} qwait={}{}{}{}",
            self.bucket,
            self.n_batches,
            self.n_requests,
            fmt_secs(self.busy_s),
            fmt_secs(self.mean_queue_wait_s),
            match self.n_streams {
                Some(s) => format!(" streams={s}"),
                None => String::new(),
            },
            match self.reserved_bytes {
                Some(b) => format!(" arena={b}B"),
                None => String::new(),
            },
            if self.alloc_events > 0 {
                format!(" ALLOC_EVENTS={}", self.alloc_events)
            } else {
                String::new()
            },
        )
    }
}

/// Aggregated report for a serving run.
#[derive(Debug, Clone)]
pub struct ServingReport {
    pub n_requests: usize,
    pub n_batches: usize,
    pub wall_time: Duration,
    pub latency: Summary,
    /// Mean real (unpadded) examples per formed batch.
    pub mean_batch_fill: f64,
    /// Per-bucket lane breakdown (empty for the single-engine-thread
    /// server, one entry per bucket for the lane scheduler).
    pub lanes: Vec<LaneStat>,
}

impl ServingReport {
    pub fn throughput_rps(&self) -> f64 {
        self.n_requests as f64 / self.wall_time.as_secs_f64()
    }

    /// Lane stat for one bucket, if this run was lane-scheduled.
    pub fn lane(&self, bucket: usize) -> Option<&LaneStat> {
        self.lanes.iter().find(|l| l.bucket == bucket)
    }

    pub fn render(&self) -> String {
        let mut out = format!(
            "requests={}  batches={}  fill={:.2}  wall={}  thpt={:.1} req/s\n\
             latency: p50={} p90={} p99={} max={}",
            self.n_requests,
            self.n_batches,
            self.mean_batch_fill,
            fmt_secs(self.wall_time.as_secs_f64()),
            self.throughput_rps(),
            fmt_secs(self.latency.percentile(50.0)),
            fmt_secs(self.latency.percentile(90.0)),
            fmt_secs(self.latency.percentile(99.0)),
            fmt_secs(self.latency.max()),
        );
        for lane in &self.lanes {
            out.push('\n');
            out.push_str(&lane.render());
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn report_renders_and_computes_throughput() {
        let r = ServingReport {
            n_requests: 100,
            n_batches: 20,
            wall_time: Duration::from_secs(2),
            latency: Summary::from_samples(vec![0.01; 100]),
            mean_batch_fill: 5.0,
            lanes: Vec::new(),
        };
        assert!((r.throughput_rps() - 50.0).abs() < 1e-9);
        let s = r.render();
        assert!(s.contains("requests=100"));
        assert!(s.contains("p99"));
    }

    #[test]
    fn lane_stats_render_and_lookup() {
        let r = ServingReport {
            n_requests: 10,
            n_batches: 4,
            wall_time: Duration::from_secs(1),
            latency: Summary::from_samples(vec![0.01; 10]),
            mean_batch_fill: 2.5,
            lanes: vec![
                LaneStat {
                    bucket: 1,
                    n_streams: Some(2),
                    reserved_bytes: Some(1536),
                    n_batches: 2,
                    n_requests: 2,
                    busy_s: 0.1,
                    mean_queue_wait_s: 0.001,
                    alloc_events: 0,
                },
                LaneStat {
                    bucket: 8,
                    n_streams: None,
                    reserved_bytes: None,
                    n_batches: 2,
                    n_requests: 8,
                    busy_s: 0.2,
                    mean_queue_wait_s: 0.002,
                    alloc_events: 0,
                },
            ],
        };
        assert_eq!(r.lane(8).unwrap().n_requests, 8);
        assert!(r.lane(4).is_none());
        let s = r.render();
        assert!(s.contains("lane[bucket=1]"));
        assert!(s.contains("streams=2"));
        assert!(s.contains("arena=1536B"));
    }
}
