//! Serving metrics: per-request latency distribution + throughput, and
//! per-lane breakdowns for the lane scheduler.

use crate::util::stats::{fmt_secs, Summary};
use std::fmt::Write as _;
use std::time::Duration;

/// Per-bucket counters reported by the lane scheduler, filled by that
/// bucket's lane thread(s) at shutdown. Under elastic scaling a bucket
/// may be served by several lanes over its lifetime; the scheduler
/// folds them into ONE stat per bucket ([`absorb`](Self::absorb)) and
/// records the scaling decisions in `lanes_spawned` / `lanes_retired`.
#[derive(Debug, Clone)]
pub struct LaneStat {
    /// Compiled batch size this lane serves.
    pub bucket: usize,
    /// Stream count of the lane engine's replay context, when the engine
    /// exposes it ([`InferEngine::stream_count`](crate::coordinator::InferEngine::stream_count)).
    pub n_streams: Option<usize>,
    /// Packed arena reservation of the lane engine's replay context,
    /// when the engine exposes it
    /// ([`InferEngine::reserved_bytes`](crate::coordinator::InferEngine::reserved_bytes)).
    pub reserved_bytes: Option<u64>,
    pub n_batches: usize,
    /// Real (unpadded) examples served by this lane.
    pub n_requests: usize,
    /// Seconds the lane engine spent inside `infer_batch`.
    pub busy_s: f64,
    /// Mean seconds a formed batch waited in this lane's queue.
    pub mean_queue_wait_s: f64,
    /// Padded-buffer would-allocate events on this lane's dispatch path
    /// (0 in steady state: buffers are pooled and reused).
    pub alloc_events: u64,
    /// Requests shed because their deadline
    /// ([`RequestOptions::deadline`](crate::serving::RequestOptions))
    /// expired while they waited (staged or queued) — resolved as
    /// [`InferOutcome::DeadlineShed`](crate::serving::InferOutcome),
    /// never executed. `n_requests` counts completions only; requests
    /// that fail outright (overload load-shed, engine errors after the
    /// retry budget, lane death) are counted in
    /// [`failed`](Self::failed), closing the invariant
    /// `admitted == n_requests + deadline_shed + failed`.
    pub deadline_shed: usize,
    /// Subset of [`deadline_shed`](Self::deadline_shed) resolved at
    /// **admission**: the dispatcher's per-bucket queue-delay estimate
    /// ruled the budget unmeetable (or the deadline had already passed
    /// at the door), so the request was shed before it occupied any
    /// backlog. The remainder shed later, at the dispatcher's expiry
    /// sweep or at lane pop.
    pub admission_shed: usize,
    /// Requests resolved as [`InferOutcome::Failed`](crate::serving::InferOutcome):
    /// overload load-shed replies, engine errors that exhausted the
    /// [`RetryPolicy`](crate::fault::RetryPolicy), and jobs orphaned by
    /// a dead lane that could not be recovered.
    pub failed: usize,
    /// Batch re-executions after a transient engine failure (each
    /// counts one extra `infer_batch` attempt beyond the first).
    pub retries: usize,
    /// Lanes ever spawned for this bucket (the seed lane counts, so ≥ 1
    /// on a live report; elastic scale-ups add to it).
    pub lanes_spawned: usize,
    /// Elastic lanes retired before shutdown (idle past
    /// `ScaleOptions::idle_retire`).
    pub lanes_retired: usize,
    /// Cross-context worker steals this bucket's engines received from
    /// the shared work-stealing pool
    /// ([`SharedWorkerPool`](crate::engine::executor::SharedWorkerPool));
    /// 0 without one.
    pub steals: u64,
}

impl LaneStat {
    /// A zeroed stat for `bucket` — the fold identity for
    /// [`absorb`](Self::absorb).
    pub fn empty(bucket: usize) -> LaneStat {
        LaneStat {
            bucket,
            n_streams: None,
            reserved_bytes: None,
            n_batches: 0,
            n_requests: 0,
            busy_s: 0.0,
            mean_queue_wait_s: 0.0,
            alloc_events: 0,
            deadline_shed: 0,
            admission_shed: 0,
            failed: 0,
            retries: 0,
            lanes_spawned: 0,
            lanes_retired: 0,
            steals: 0,
        }
    }

    /// Fold another lane instance's runtime counters into this
    /// per-bucket aggregate (queue wait re-weighted by batch count).
    /// `lanes_spawned` / `lanes_retired` are scheduler-level decisions,
    /// not per-instance counters, so the scheduler sets them directly.
    pub fn absorb(&mut self, other: &LaneStat) {
        debug_assert_eq!(self.bucket, other.bucket, "absorb folds within one bucket");
        let total = self.n_batches + other.n_batches;
        if total > 0 {
            self.mean_queue_wait_s = (self.mean_queue_wait_s * self.n_batches as f64
                + other.mean_queue_wait_s * other.n_batches as f64)
                / total as f64;
        }
        self.n_batches = total;
        self.n_requests += other.n_requests;
        self.busy_s += other.busy_s;
        self.alloc_events += other.alloc_events;
        self.deadline_shed += other.deadline_shed;
        self.admission_shed += other.admission_shed;
        self.failed += other.failed;
        self.retries += other.retries;
        self.steals += other.steals;
        if self.n_streams.is_none() {
            self.n_streams = other.n_streams;
        }
        if self.reserved_bytes.is_none() {
            self.reserved_bytes = other.reserved_bytes;
        }
    }

    /// One JSON object with every counter — the machine-readable
    /// counterpart of [`render`](Self::render) (benches and the
    /// `BENCH_*.json` artifacts consume this instead of scraping the
    /// human text).
    pub fn to_json(&self) -> String {
        let mut o = String::from("{");
        let _ = write!(o, "\"bucket\": {}", self.bucket);
        match self.n_streams {
            Some(s) => drop(write!(o, ", \"n_streams\": {s}")),
            None => o.push_str(", \"n_streams\": null"),
        }
        match self.reserved_bytes {
            Some(b) => drop(write!(o, ", \"reserved_bytes\": {b}")),
            None => o.push_str(", \"reserved_bytes\": null"),
        }
        let _ = write!(
            o,
            ", \"n_batches\": {}, \"n_requests\": {}, \"busy_s\": {:e}, \
             \"mean_queue_wait_s\": {:e}, \"alloc_events\": {}, \"deadline_shed\": {}, \
             \"admission_shed\": {}, \"failed\": {}, \"retries\": {}, \
             \"lanes_spawned\": {}, \"lanes_retired\": {}, \"steals\": {}}}",
            self.n_batches,
            self.n_requests,
            self.busy_s,
            self.mean_queue_wait_s,
            self.alloc_events,
            self.deadline_shed,
            self.admission_shed,
            self.failed,
            self.retries,
            self.lanes_spawned,
            self.lanes_retired,
            self.steals,
        );
        o
    }

    pub fn render(&self) -> String {
        format!(
            "lane[bucket={}]: batches={} requests={} busy={} qwait={}{}{}{}{}{}{}{}{}",
            self.bucket,
            self.n_batches,
            self.n_requests,
            fmt_secs(self.busy_s),
            fmt_secs(self.mean_queue_wait_s),
            match self.n_streams {
                Some(s) => format!(" streams={s}"),
                None => String::new(),
            },
            match self.reserved_bytes {
                Some(b) => format!(" arena={b}B"),
                None => String::new(),
            },
            if self.lanes_spawned > 1 || self.lanes_retired > 0 {
                format!(" lanes={}/{} retired={}", self.lanes_spawned - self.lanes_retired,
                    self.lanes_spawned, self.lanes_retired)
            } else {
                String::new()
            },
            if self.deadline_shed > 0 {
                if self.admission_shed > 0 {
                    format!(" shed={} (adm={})", self.deadline_shed, self.admission_shed)
                } else {
                    format!(" shed={}", self.deadline_shed)
                }
            } else {
                String::new()
            },
            if self.failed > 0 { format!(" failed={}", self.failed) } else { String::new() },
            if self.retries > 0 { format!(" retries={}", self.retries) } else { String::new() },
            if self.steals > 0 { format!(" steals={}", self.steals) } else { String::new() },
            if self.alloc_events > 0 {
                format!(" ALLOC_EVENTS={}", self.alloc_events)
            } else {
                String::new()
            },
        )
    }
}

/// Aggregated report for a serving run.
#[derive(Debug, Clone)]
pub struct ServingReport {
    /// Requests completed. Deadline-shed requests are counted
    /// separately in [`deadline_shed`](Self::deadline_shed) and
    /// requests resolved as errors in [`failed`](Self::failed), so
    /// every admitted request lands in exactly one of the three
    /// counts: `admitted == n_requests + deadline_shed + failed`.
    pub n_requests: usize,
    pub n_batches: usize,
    pub wall_time: Duration,
    pub latency: Summary,
    /// Mean real (unpadded) examples per formed batch.
    pub mean_batch_fill: f64,
    /// Requests shed because their deadline expired while they waited
    /// (sum over lanes for the lane scheduler).
    pub deadline_shed: usize,
    /// Subset of [`deadline_shed`](Self::deadline_shed) resolved at
    /// admission by the dispatcher's queue-delay estimate (sum over
    /// lanes; always 0 for the single-engine-thread server, which has
    /// no admission estimate).
    pub admission_shed: usize,
    /// Requests resolved as `Failed` (sum over lanes): overload
    /// load-shed, engine errors past the retry budget, lane death.
    pub failed: usize,
    /// Batch re-executions after transient engine failures (sum over
    /// lanes).
    pub retries: usize,
    /// Per-bucket lane breakdown (empty for the single-engine-thread
    /// server, one entry per bucket for the lane scheduler).
    pub lanes: Vec<LaneStat>,
}

impl ServingReport {
    /// The fold identity for [`absorb`](Self::absorb): an all-zero
    /// report with the same single-`0.0` sentinel latency an idle lane
    /// run produces.
    pub fn empty() -> ServingReport {
        ServingReport {
            n_requests: 0,
            n_batches: 0,
            wall_time: Duration::ZERO,
            latency: Summary::from_samples(vec![0.0]),
            mean_batch_fill: 0.0,
            deadline_shed: 0,
            admission_shed: 0,
            failed: 0,
            retries: 0,
            lanes: Vec::new(),
        }
    }

    /// Fold another runtime's report into this one — how the cluster
    /// layer aggregates its per-replica reports. Counters sum; batch
    /// fill re-weights by batch count; wall time takes the max
    /// (replicas run concurrently, not back-to-back); latency
    /// summaries merge losslessly from their raw samples (reports that
    /// completed nothing contribute none, so the idle sentinel sample
    /// never skews percentiles); per-bucket lane stats fold with
    /// [`LaneStat::absorb`] plus the scheduler-level spawn/retire
    /// decisions, which `absorb` leaves to the scheduler — across
    /// replicas those ARE per-instance counts and must sum.
    pub fn absorb(&mut self, other: &ServingReport) {
        let batches = self.n_batches + other.n_batches;
        if batches > 0 {
            self.mean_batch_fill = (self.mean_batch_fill * self.n_batches as f64
                + other.mean_batch_fill * other.n_batches as f64)
                / batches as f64;
        }
        self.n_batches = batches;
        let mut samples: Vec<f64> = Vec::new();
        if self.n_requests > 0 {
            samples.extend_from_slice(self.latency.samples());
        }
        if other.n_requests > 0 {
            samples.extend_from_slice(other.latency.samples());
        }
        if samples.is_empty() {
            samples.push(0.0);
        }
        self.latency = Summary::from_samples(samples);
        self.n_requests += other.n_requests;
        self.wall_time = self.wall_time.max(other.wall_time);
        self.deadline_shed += other.deadline_shed;
        self.admission_shed += other.admission_shed;
        self.failed += other.failed;
        self.retries += other.retries;
        for lane in &other.lanes {
            match self.lanes.iter_mut().find(|l| l.bucket == lane.bucket) {
                Some(agg) => {
                    agg.absorb(lane);
                    agg.lanes_spawned += lane.lanes_spawned;
                    agg.lanes_retired += lane.lanes_retired;
                }
                None => self.lanes.push(lane.clone()),
            }
        }
        self.lanes.sort_by_key(|l| l.bucket);
    }

    pub fn throughput_rps(&self) -> f64 {
        self.n_requests as f64 / self.wall_time.as_secs_f64()
    }

    /// Lane stat for one bucket, if this run was lane-scheduled.
    pub fn lane(&self, bucket: usize) -> Option<&LaneStat> {
        self.lanes.iter().find(|l| l.bucket == bucket)
    }

    /// Total lanes ever spawned across buckets (elastic scale-ups
    /// included; 0 for the single-engine-thread server).
    pub fn lanes_spawned(&self) -> usize {
        self.lanes.iter().map(|l| l.lanes_spawned).sum()
    }

    /// Total elastic lanes retired before shutdown.
    pub fn lanes_retired(&self) -> usize {
        self.lanes.iter().map(|l| l.lanes_retired).sum()
    }

    /// Total cross-context worker steals across buckets.
    pub fn steals(&self) -> u64 {
        self.lanes.iter().map(|l| l.steals).sum()
    }

    /// The whole report as one JSON document (latency percentiles,
    /// aggregate counters, and the per-bucket [`LaneStat::to_json`]
    /// breakdown) — parseable by [`crate::util::json::parse_json`], so
    /// benches assert on fields instead of scraping
    /// [`render`](Self::render) text.
    pub fn to_json(&self) -> String {
        let mut o = String::from("{\n");
        // A zero-wall-time report (degenerate, but constructible) must
        // not emit `inf`/`NaN` — not valid JSON.
        let rps = self.throughput_rps();
        let rps = if rps.is_finite() { rps } else { 0.0 };
        let _ = write!(
            o,
            "  \"n_requests\": {}, \"n_batches\": {}, \"wall_s\": {:e}, \
             \"throughput_rps\": {:e}, \"mean_batch_fill\": {:e},\n  \
             \"deadline_shed\": {}, \"admission_shed\": {}, \"failed\": {}, \
             \"retries\": {},\n  \"latency\": {{\"p50_s\": {:e}, \"p90_s\": {:e}, \
             \"p99_s\": {:e}, \"max_s\": {:e}, \"mean_s\": {:e}}},\n  \"lanes\": [",
            self.n_requests,
            self.n_batches,
            self.wall_time.as_secs_f64(),
            rps,
            self.mean_batch_fill,
            self.deadline_shed,
            self.admission_shed,
            self.failed,
            self.retries,
            self.latency.percentile(50.0),
            self.latency.percentile(90.0),
            self.latency.percentile(99.0),
            self.latency.max(),
            self.latency.mean(),
        );
        for (i, lane) in self.lanes.iter().enumerate() {
            if i > 0 {
                o.push_str(", ");
            }
            o.push_str(&lane.to_json());
        }
        o.push_str("]\n}\n");
        o
    }

    pub fn render(&self) -> String {
        let mut out = format!(
            "requests={}  batches={}  fill={:.2}{}  wall={}  thpt={:.1} req/s\n\
             latency: p50={} p90={} p99={} max={}",
            self.n_requests,
            self.n_batches,
            self.mean_batch_fill,
            {
                let mut extra = String::new();
                if self.deadline_shed > 0 {
                    extra.push_str(&format!("  shed={}", self.deadline_shed));
                    if self.admission_shed > 0 {
                        extra.push_str(&format!(" (adm={})", self.admission_shed));
                    }
                }
                if self.failed > 0 {
                    extra.push_str(&format!("  failed={}", self.failed));
                }
                if self.retries > 0 {
                    extra.push_str(&format!("  retries={}", self.retries));
                }
                extra
            },
            fmt_secs(self.wall_time.as_secs_f64()),
            self.throughput_rps(),
            fmt_secs(self.latency.percentile(50.0)),
            fmt_secs(self.latency.percentile(90.0)),
            fmt_secs(self.latency.percentile(99.0)),
            fmt_secs(self.latency.max()),
        );
        for lane in &self.lanes {
            out.push('\n');
            out.push_str(&lane.render());
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn report_renders_and_computes_throughput() {
        let r = ServingReport {
            n_requests: 100,
            n_batches: 20,
            wall_time: Duration::from_secs(2),
            latency: Summary::from_samples(vec![0.01; 100]),
            mean_batch_fill: 5.0,
            deadline_shed: 0,
            admission_shed: 0,
            failed: 0,
            retries: 0,
            lanes: Vec::new(),
        };
        assert!((r.throughput_rps() - 50.0).abs() < 1e-9);
        let s = r.render();
        assert!(s.contains("requests=100"));
        assert!(s.contains("p99"));
        assert!(!s.contains("shed="), "no shed counter rendered when nothing shed");
        assert!(!s.contains("failed="), "no failure counter rendered when nothing failed");
    }

    #[test]
    fn lane_stats_render_and_lookup() {
        let r = ServingReport {
            n_requests: 10,
            n_batches: 4,
            wall_time: Duration::from_secs(1),
            latency: Summary::from_samples(vec![0.01; 10]),
            mean_batch_fill: 2.5,
            deadline_shed: 3,
            admission_shed: 1,
            failed: 2,
            retries: 1,
            lanes: vec![
                LaneStat {
                    n_streams: Some(2),
                    reserved_bytes: Some(1536),
                    n_batches: 2,
                    n_requests: 2,
                    busy_s: 0.1,
                    mean_queue_wait_s: 0.001,
                    lanes_spawned: 1,
                    ..LaneStat::empty(1)
                },
                LaneStat {
                    n_batches: 2,
                    n_requests: 8,
                    busy_s: 0.2,
                    mean_queue_wait_s: 0.002,
                    lanes_spawned: 3,
                    lanes_retired: 2,
                    deadline_shed: 3,
                    failed: 2,
                    retries: 1,
                    steals: 5,
                    ..LaneStat::empty(8)
                },
            ],
        };
        assert_eq!(r.lane(8).unwrap().n_requests, 8);
        assert!(r.lane(4).is_none());
        assert_eq!((r.lanes_spawned(), r.lanes_retired(), r.steals()), (4, 2, 5));
        let s = r.render();
        assert!(s.contains("lane[bucket=1]"));
        assert!(s.contains("streams=2"));
        assert!(s.contains("arena=1536B"));
        assert!(s.contains("lanes=1/3 retired=2"), "scaling decisions must render: {s}");
        assert!(s.contains("shed=3"), "deadline sheds must render: {s}");
        assert!(s.contains("(adm=1)"), "admission-shed subset must render: {s}");
        assert!(s.contains("failed=2"), "failures must render: {s}");
        assert!(s.contains("retries=1"), "retries must render: {s}");
        assert!(s.contains("steals=5"));
    }

    #[test]
    fn report_json_parses_and_carries_every_counter() {
        let r = ServingReport {
            n_requests: 10,
            n_batches: 4,
            wall_time: Duration::from_secs(1),
            latency: Summary::from_samples(vec![0.01; 10]),
            mean_batch_fill: 2.5,
            deadline_shed: 3,
            admission_shed: 1,
            failed: 2,
            retries: 1,
            lanes: vec![
                LaneStat { n_streams: Some(2), n_requests: 2, ..LaneStat::empty(1) },
                LaneStat { steals: 5, n_requests: 8, ..LaneStat::empty(8) },
            ],
        };
        let doc = crate::util::json::parse_json(&r.to_json())
            .expect("report JSON must parse");
        assert_eq!(doc.get("n_requests").and_then(|v| v.as_u64()), Some(10));
        assert_eq!(doc.get("deadline_shed").and_then(|v| v.as_u64()), Some(3));
        assert_eq!(doc.get("admission_shed").and_then(|v| v.as_u64()), Some(1));
        let p50 = doc
            .get("latency")
            .and_then(|l| l.get("p50_s"))
            .and_then(|v| v.as_f64())
            .unwrap();
        assert!((p50 - 0.01).abs() < 1e-12);
        let lanes = doc.get("lanes").and_then(|l| l.as_array()).unwrap();
        assert_eq!(lanes.len(), 2);
        assert_eq!(lanes[0].get("n_streams").and_then(|v| v.as_u64()), Some(2));
        assert!(lanes[1].get("n_streams").is_some_and(|v| v.as_u64().is_none()),
            "absent shape serializes as null");
        assert_eq!(lanes[1].get("steals").and_then(|v| v.as_u64()), Some(5));
    }

    #[test]
    fn report_absorb_sums_counters_merges_latency_and_folds_lanes() {
        let mut agg = ServingReport::empty();
        agg.absorb(&ServingReport {
            n_requests: 2,
            n_batches: 2,
            wall_time: Duration::from_secs(3),
            latency: Summary::from_samples(vec![0.010, 0.030]),
            mean_batch_fill: 1.0,
            deadline_shed: 1,
            admission_shed: 1,
            failed: 0,
            retries: 2,
            lanes: vec![LaneStat {
                n_batches: 2,
                n_requests: 2,
                lanes_spawned: 2,
                lanes_retired: 1,
                ..LaneStat::empty(1)
            }],
        });
        // An idle replica (sentinel latency) must not skew percentiles.
        agg.absorb(&ServingReport::empty());
        agg.absorb(&ServingReport {
            n_requests: 2,
            n_batches: 1,
            wall_time: Duration::from_secs(2),
            latency: Summary::from_samples(vec![0.020, 0.040]),
            mean_batch_fill: 2.0,
            deadline_shed: 0,
            admission_shed: 0,
            failed: 3,
            retries: 0,
            lanes: vec![
                LaneStat { n_batches: 1, n_requests: 2, lanes_spawned: 1, ..LaneStat::empty(1) },
                LaneStat { lanes_spawned: 1, ..LaneStat::empty(8) },
            ],
        });
        assert_eq!(agg.n_requests, 4);
        assert_eq!(agg.n_batches, 3);
        assert_eq!(agg.wall_time, Duration::from_secs(3), "concurrent replicas: max");
        assert_eq!((agg.deadline_shed, agg.admission_shed, agg.failed, agg.retries), (1, 1, 3, 2));
        assert!((agg.mean_batch_fill - 4.0 / 3.0).abs() < 1e-12, "batch-weighted fill");
        assert_eq!(agg.latency.len(), 4, "samples merged, sentinel skipped");
        assert!((agg.latency.max() - 0.040).abs() < 1e-12);
        assert_eq!(agg.lanes.len(), 2, "per-bucket fold across replicas");
        let b1 = agg.lane(1).unwrap();
        assert_eq!((b1.n_requests, b1.lanes_spawned, b1.lanes_retired), (4, 3, 1));
        assert_eq!(agg.lane(8).unwrap().lanes_spawned, 1);
    }

    #[test]
    fn absorb_folds_runtime_counters_and_reweights_queue_wait() {
        let mut agg = LaneStat::empty(4);
        agg.absorb(&LaneStat {
            n_batches: 3,
            n_requests: 9,
            busy_s: 0.3,
            mean_queue_wait_s: 0.010,
            n_streams: Some(2),
            reserved_bytes: Some(4096),
            steals: 2,
            ..LaneStat::empty(4)
        });
        agg.absorb(&LaneStat {
            n_batches: 1,
            n_requests: 2,
            busy_s: 0.1,
            mean_queue_wait_s: 0.002,
            alloc_events: 1,
            deadline_shed: 2,
            failed: 3,
            retries: 2,
            steals: 1,
            ..LaneStat::empty(4)
        });
        assert_eq!(agg.n_batches, 4);
        assert_eq!(agg.n_requests, 11);
        assert!((agg.busy_s - 0.4).abs() < 1e-12);
        assert!((agg.mean_queue_wait_s - 0.008).abs() < 1e-12, "batch-weighted mean");
        assert_eq!(agg.alloc_events, 1);
        assert_eq!(agg.deadline_shed, 2);
        assert_eq!(agg.failed, 3);
        assert_eq!(agg.retries, 2);
        assert_eq!(agg.steals, 3);
        assert_eq!(agg.n_streams, Some(2), "first known shape wins");
        assert_eq!(agg.reserved_bytes, Some(4096));
    }
}
