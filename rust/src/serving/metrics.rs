//! Serving metrics: per-request latency distribution + throughput.

use crate::util::stats::{fmt_secs, Summary};
use std::time::Duration;

/// Aggregated report for a serving run.
#[derive(Debug, Clone)]
pub struct ServingReport {
    pub n_requests: usize,
    pub n_batches: usize,
    pub wall_time: Duration,
    pub latency: Summary,
    /// Mean real (unpadded) examples per formed batch.
    pub mean_batch_fill: f64,
}

impl ServingReport {
    pub fn throughput_rps(&self) -> f64 {
        self.n_requests as f64 / self.wall_time.as_secs_f64()
    }

    pub fn render(&self) -> String {
        format!(
            "requests={}  batches={}  fill={:.2}  wall={}  thpt={:.1} req/s\n\
             latency: p50={} p90={} p99={} max={}",
            self.n_requests,
            self.n_batches,
            self.mean_batch_fill,
            fmt_secs(self.wall_time.as_secs_f64()),
            self.throughput_rps(),
            fmt_secs(self.latency.percentile(50.0)),
            fmt_secs(self.latency.percentile(90.0)),
            fmt_secs(self.latency.percentile(99.0)),
            fmt_secs(self.latency.max()),
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn report_renders_and_computes_throughput() {
        let r = ServingReport {
            n_requests: 100,
            n_batches: 20,
            wall_time: Duration::from_secs(2),
            latency: Summary::from_samples(vec![0.01; 100]),
            mean_batch_fill: 5.0,
        };
        assert!((r.throughput_rps() - 50.0).abs() < 1e-9);
        let s = r.render();
        assert!(s.contains("requests=100"));
        assert!(s.contains("p99"));
    }
}
