//! Mechanical verification of the paper's optimality claims (Theorems 1–4).
//!
//! Used both in tests and as a debug assertion in the coordinator: every
//! stream assignment the engine uses is checked for maximum logical
//! concurrency before the AoT pre-run.

use crate::graph::{Dag, Reachability};

/// Maximum logical concurrency (paper §4.2): independent nodes must be on
/// different streams.
pub fn satisfies_max_logical_concurrency<N>(g: &Dag<N>, stream_of: &[usize]) -> bool {
    let reach = Reachability::compute(g);
    satisfies_max_logical_concurrency_with(&reach, stream_of)
}

/// Same, reusing a precomputed closure.
pub fn satisfies_max_logical_concurrency_with(
    reach: &Reachability,
    stream_of: &[usize],
) -> bool {
    let n = reach.n_nodes();
    for u in 0..n {
        for v in (u + 1)..n {
            if stream_of[u] == stream_of[v] && reach.independent(u, v) {
                return false;
            }
        }
    }
    true
}

/// Brute-force the minimum number of cross-stream MEG edges over *all*
/// assignments with maximum logical concurrency, by enumerating all maximal
/// matchings... infeasible in general, so instead we check Theorem 3's
/// formula directly against exhaustive search on tiny graphs: enumerate all
/// partitions of V into chains and count cross-chain MEG edges. Exponential;
/// only call with n ≤ 8.
pub fn brute_force_min_syncs<N>(g: &Dag<N>) -> usize {
    let n = g.n_nodes();
    assert!(n <= 8, "brute force is exponential");
    let reach = Reachability::compute(g);
    let meg = crate::graph::minimum_equivalent_graph(g);
    let meg_edges = meg.edges();

    // Enumerate set partitions via restricted growth strings.
    let mut best = usize::MAX;
    let mut rgs = vec![0usize; n];
    loop {
        // check: every block must be a chain (pairwise comparable)
        let valid = (0..n).all(|u| {
            ((u + 1)..n).all(|v| rgs[u] != rgs[v] || reach.comparable(u, v))
        });
        if valid {
            let syncs = meg_edges.iter().filter(|&&(u, v)| rgs[u] != rgs[v]).count();
            best = best.min(syncs);
        }
        // next restricted growth string
        let mut i = n;
        loop {
            if i == 1 {
                return best;
            }
            i -= 1;
            let max_prefix = rgs[..i].iter().copied().max().unwrap_or(0);
            if rgs[i] <= max_prefix {
                rgs[i] += 1;
                for r in rgs[i + 1..].iter_mut() {
                    *r = 0;
                }
                break;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::gen::random_dag;
    use crate::matching::MatchingAlgo;
    use crate::stream::assign::assign_streams;
    use crate::util::Pcg32;

    #[test]
    fn detects_violation() {
        // two independent nodes forced onto one stream
        let mut g: Dag<()> = Dag::new();
        g.add_node(());
        g.add_node(());
        assert!(!satisfies_max_logical_concurrency(&g, &[0, 0]));
        assert!(satisfies_max_logical_concurrency(&g, &[0, 1]));
    }

    #[test]
    fn chain_can_share_stream() {
        let mut g: Dag<()> = Dag::new();
        let a = g.add_node(());
        let b = g.add_node(());
        g.add_edge(a, b);
        assert!(satisfies_max_logical_concurrency(&g, &[0, 0]));
    }

    #[test]
    fn algorithm1_matches_brute_force_minimum() {
        // Theorem 4, checked exhaustively on small random DAGs: Algorithm 1's
        // sync count equals the true minimum over all max-concurrency
        // assignments.
        let mut rng = Pcg32::new(0xBEEF);
        for _ in 0..40 {
            let n = rng.gen_range_inclusive(2, 7);
            let g = random_dag(&mut rng, n, 0.35);
            let a = assign_streams(&g, MatchingAlgo::HopcroftKarp);
            assert!(satisfies_max_logical_concurrency(&g, &a.stream_of));
            let brute = brute_force_min_syncs(&g);
            assert_eq!(
                a.min_syncs(),
                brute,
                "Algorithm 1 gave {} syncs, brute force found {} (n={})",
                a.min_syncs(),
                brute,
                n
            );
        }
    }
}
