//! Stream assignment — the paper's §4.2.
//!
//! Pipeline: MEG (graph/meg) → bipartite graph → maximum matching
//! (matching/) → chain partition → stream assignment (`assign`), then the
//! synchronization plan (`sync`, exactly `|E'| − |M|` syncs by Theorem 3),
//! the launch-plan rewriter (`rewrite`, the paper's Graph Rewriter), the
//! max-logical-concurrency verifier (`verify`, Theorems 1–4 checked
//! mechanically), and the degree of logical concurrency (`width`, the
//! "Deg." column of Table 1).

pub mod assign;
pub mod rewrite;
pub mod sync;
pub mod verify;
pub mod width;

pub use assign::{assign_streams, StreamAssignment};
pub use rewrite::{rewrite, LaunchPlan, NodePlan};
pub use sync::{plan_syncs, SyncPlan};
pub use width::logical_concurrency_degree;
