//! Algorithm 1: stream assignment with maximum logical concurrency and the
//! minimum number of synchronizations.
//!
//! Steps (paper §4.2):
//!   1. MEG `G' = (V, E')` of the computation graph `G`.
//!   2. Bipartite graph `B = (V₁, V₂, E_B)` with `(xᵢ, yⱼ) ∈ E_B ⇔ (vᵢ, vⱼ) ∈ E'`.
//!   3. Maximum matching `M` of `B`.
//!   4. Union-find over matched pairs → partition of `V` into chains.
//!   5. One stream per chain.
//!
//! The partition produced in Step 4 is a minimum *path cover* of the MEG:
//! each set is a path (chain) in `G'`, so all nodes in a set are pairwise
//! comparable (max logical concurrency, Theorem 2), and the number of
//! cross-stream MEG edges is `|E'| − |M|`, the provable minimum (Theorem 3).

use crate::graph::{minimum_equivalent_graph_with, Dag, NodeId, Reachability};
use crate::matching::{maximum_matching, BipartiteGraph, MatchingAlgo};

/// Result of Algorithm 1.
#[derive(Debug, Clone)]
pub struct StreamAssignment {
    /// `stream_of[v]` = stream id of node `v`; ids are dense `0..n_streams`.
    pub stream_of: Vec<usize>,
    /// Number of distinct streams (`|V| − |M|`).
    pub n_streams: usize,
    /// The MEG the assignment was derived from (needed by the sync planner).
    pub meg: Dag<()>,
    /// Matching cardinality `|M|` (for the `|E'| − |M|` sync bound).
    pub matching_size: usize,
}

impl StreamAssignment {
    /// Nodes grouped by stream, each group in ascending node order.
    pub fn streams(&self) -> Vec<Vec<NodeId>> {
        let mut groups = vec![Vec::new(); self.n_streams];
        for (v, &s) in self.stream_of.iter().enumerate() {
            groups[s].push(v);
        }
        groups
    }

    /// The guaranteed-minimum number of synchronizations, `|E'| − |M|`.
    pub fn min_syncs(&self) -> usize {
        self.meg.n_edges() - self.matching_size
    }
}

/// Run Algorithm 1 on a computation graph.
pub fn assign_streams<N>(g: &Dag<N>, algo: MatchingAlgo) -> StreamAssignment {
    let reach = Reachability::compute(g);
    assign_streams_with(g, &reach, algo)
}

/// Run Algorithm 1 reusing a precomputed transitive closure.
pub fn assign_streams_with<N>(
    g: &Dag<N>,
    reach: &Reachability,
    algo: MatchingAlgo,
) -> StreamAssignment {
    let n = g.n_nodes();
    // Step 1: minimum equivalent graph.
    let meg = minimum_equivalent_graph_with(g, reach);
    // Step 2: bipartite graph from MEG edges.
    let b = BipartiteGraph::from_dag_edges(n, &meg.edges());
    // Step 3: maximum matching.
    let m = maximum_matching(&b, algo);
    // Step 4: union matched pairs (union-find).
    let mut uf = UnionFind::new(n);
    for (l, r) in m.edges() {
        uf.union(l, r);
    }
    // Step 5: dense stream ids per set.
    let mut stream_of = vec![usize::MAX; n];
    let mut next = 0usize;
    let mut root_to_stream = vec![usize::MAX; n];
    for v in 0..n {
        let root = uf.find(v);
        if root_to_stream[root] == usize::MAX {
            root_to_stream[root] = next;
            next += 1;
        }
        stream_of[v] = root_to_stream[root];
    }
    StreamAssignment { stream_of, n_streams: next, meg, matching_size: m.cardinality() }
}

/// Path-compressed, rank-unioned union-find.
struct UnionFind {
    parent: Vec<usize>,
    rank: Vec<u8>,
}

impl UnionFind {
    fn new(n: usize) -> Self {
        UnionFind { parent: (0..n).collect(), rank: vec![0; n] }
    }

    fn find(&mut self, mut x: usize) -> usize {
        while self.parent[x] != x {
            self.parent[x] = self.parent[self.parent[x]]; // halving
            x = self.parent[x];
        }
        x
    }

    fn union(&mut self, a: usize, b: usize) {
        let (ra, rb) = (self.find(a), self.find(b));
        if ra == rb {
            return;
        }
        match self.rank[ra].cmp(&self.rank[rb]) {
            std::cmp::Ordering::Less => self.parent[ra] = rb,
            std::cmp::Ordering::Greater => self.parent[rb] = ra,
            std::cmp::Ordering::Equal => {
                self.parent[rb] = ra;
                self.rank[ra] += 1;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::gen::{layered_dag, random_dag};
    use crate::stream::verify::satisfies_max_logical_concurrency;
    use crate::util::Pcg32;

    /// The paper's Figure 6 walk-through graph:
    /// v1→v2, v1→v3, v2→v4, v3→v4, v4→v5, v4→v6 (0-indexed here).
    fn figure6() -> Dag<()> {
        let mut g = Dag::new();
        for _ in 0..6 {
            g.add_node(());
        }
        g.add_edge(0, 1);
        g.add_edge(0, 2);
        g.add_edge(1, 3);
        g.add_edge(2, 3);
        g.add_edge(3, 4);
        g.add_edge(3, 5);
        g
    }

    #[test]
    fn figure6_walkthrough() {
        let g = figure6();
        let a = assign_streams(&g, MatchingAlgo::HopcroftKarp);
        // MEG == G (already minimal); |E'| = 6, max matching = 3
        assert_eq!(a.meg.n_edges(), 6);
        assert_eq!(a.matching_size, 3);
        // 6 nodes − 3 matched pairs = 3 streams, 3 syncs.
        assert_eq!(a.n_streams, 3);
        assert_eq!(a.min_syncs(), 3);
        // Independent pairs on distinct streams:
        assert_ne!(a.stream_of[1], a.stream_of[2]);
        assert_ne!(a.stream_of[4], a.stream_of[5]);
    }

    #[test]
    fn chain_uses_one_stream_no_syncs() {
        let mut g: Dag<()> = Dag::new();
        for _ in 0..8 {
            g.add_node(());
        }
        for i in 0..7 {
            g.add_edge(i, i + 1);
        }
        let a = assign_streams(&g, MatchingAlgo::HopcroftKarp);
        assert_eq!(a.n_streams, 1);
        assert_eq!(a.min_syncs(), 0);
    }

    #[test]
    fn fully_independent_nodes_all_distinct_streams() {
        let mut g: Dag<()> = Dag::new();
        for _ in 0..5 {
            g.add_node(());
        }
        let a = assign_streams(&g, MatchingAlgo::HopcroftKarp);
        assert_eq!(a.n_streams, 5);
        assert_eq!(a.min_syncs(), 0);
        let mut s = a.stream_of.clone();
        s.sort_unstable();
        s.dedup();
        assert_eq!(s.len(), 5);
    }

    #[test]
    fn streams_partition_into_chains() {
        // Every stream's node set must be totally ordered by reachability.
        let mut rng = Pcg32::new(77);
        for _ in 0..20 {
            let g = random_dag(&mut rng, 30, 0.12);
            let a = assign_streams(&g, MatchingAlgo::HopcroftKarp);
            let reach = crate::graph::Reachability::compute(&g);
            for group in a.streams() {
                for i in 0..group.len() {
                    for j in (i + 1)..group.len() {
                        assert!(
                            reach.comparable(group[i], group[j]),
                            "stream contains independent nodes {} {}",
                            group[i],
                            group[j]
                        );
                    }
                }
            }
        }
    }

    #[test]
    fn max_logical_concurrency_on_layered_graphs() {
        let mut rng = Pcg32::new(99);
        for _ in 0..15 {
            let g = layered_dag(&mut rng, 4, 5, 3);
            for algo in [MatchingAlgo::HopcroftKarp, MatchingAlgo::FordFulkerson] {
                let a = assign_streams(&g, algo);
                assert!(satisfies_max_logical_concurrency(&g, &a.stream_of));
            }
        }
    }

    #[test]
    fn both_algorithms_agree_on_stream_count() {
        let mut rng = Pcg32::new(1234);
        for _ in 0..20 {
            let g = random_dag(&mut rng, 25, 0.15);
            let hk = assign_streams(&g, MatchingAlgo::HopcroftKarp);
            let ff = assign_streams(&g, MatchingAlgo::FordFulkerson);
            assert_eq!(hk.n_streams, ff.n_streams);
            assert_eq!(hk.min_syncs(), ff.min_syncs());
        }
    }

    #[test]
    fn stream_count_is_nodes_minus_matching() {
        let g = figure6();
        let a = assign_streams(&g, MatchingAlgo::FordFulkerson);
        assert_eq!(a.n_streams, g.n_nodes() - a.matching_size);
    }
}
