//! Degree of logical concurrency (Table 1, "Deg." column).
//!
//! The paper reports each architecture's *maximum degree of logical
//! concurrency* — the largest set of pairwise-independent operators, i.e.
//! the maximum antichain of the operator DAG. By Dilworth's theorem this
//! equals the minimum number of chains covering V, which by the
//! Fulkerson reduction is `|V| − |M_closure|` where `M_closure` is a maximum
//! matching of the bipartite graph built from the *transitive closure*
//! (contrast Algorithm 1, which matches over the MEG to get a minimum
//! *path* cover — same machinery, different edge set).

use crate::graph::{Dag, Reachability};
use crate::matching::{maximum_matching, BipartiteGraph, MatchingAlgo};

/// Maximum-antichain size of the DAG.
pub fn logical_concurrency_degree<N>(g: &Dag<N>) -> usize {
    let reach = Reachability::compute(g);
    logical_concurrency_degree_with(g, &reach)
}

/// Same, reusing a precomputed closure.
pub fn logical_concurrency_degree_with<N>(g: &Dag<N>, reach: &Reachability) -> usize {
    let n = g.n_nodes();
    if n == 0 {
        return 0;
    }
    let mut b = BipartiteGraph::new(n, n);
    for u in 0..n {
        for v in 0..n {
            if reach.reaches(u, v) {
                b.add_edge(u, v);
            }
        }
    }
    let m = maximum_matching(&b, MatchingAlgo::HopcroftKarp);
    n - m.cardinality()
}

/// Brute-force maximum antichain for cross-checking (exponential; n ≤ 20).
pub fn brute_force_width<N>(g: &Dag<N>) -> usize {
    let n = g.n_nodes();
    assert!(n <= 20, "brute force width is exponential");
    let reach = Reachability::compute(g);
    let mut best = 0usize;
    for mask in 0u32..(1 << n) {
        let members: Vec<usize> = (0..n).filter(|&i| mask >> i & 1 == 1).collect();
        if members.len() <= best {
            continue;
        }
        let antichain = members
            .iter()
            .enumerate()
            .all(|(i, &u)| members[i + 1..].iter().all(|&v| reach.independent(u, v)));
        if antichain {
            best = members.len();
        }
    }
    best
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::gen::{layered_dag, random_dag};
    use crate::util::Pcg32;

    #[test]
    fn chain_has_width_one() {
        let mut g: Dag<()> = Dag::new();
        for _ in 0..5 {
            g.add_node(());
        }
        for i in 0..4 {
            g.add_edge(i, i + 1);
        }
        assert_eq!(logical_concurrency_degree(&g), 1);
    }

    #[test]
    fn independent_set_has_width_n() {
        let mut g: Dag<()> = Dag::new();
        for _ in 0..6 {
            g.add_node(());
        }
        assert_eq!(logical_concurrency_degree(&g), 6);
    }

    #[test]
    fn diamond_width_two() {
        let mut g: Dag<()> = Dag::new();
        for _ in 0..4 {
            g.add_node(());
        }
        g.add_edge(0, 1);
        g.add_edge(0, 2);
        g.add_edge(1, 3);
        g.add_edge(2, 3);
        assert_eq!(logical_concurrency_degree(&g), 2);
    }

    #[test]
    fn matches_brute_force_on_small_random_graphs() {
        let mut rng = Pcg32::new(0xACE);
        for _ in 0..30 {
            let n = rng.gen_range_inclusive(2, 12);
            let g = random_dag(&mut rng, n, 0.25);
            assert_eq!(logical_concurrency_degree(&g), brute_force_width(&g));
        }
    }

    #[test]
    fn width_at_least_max_branch_count_in_layered_graph() {
        let mut rng = Pcg32::new(0xBEE);
        let g = layered_dag(&mut rng, 1, 6, 1);
        // a single block with k branches has width ≥ k (branches are mutually
        // independent); the generator picked some k in 1..=6
        let w = logical_concurrency_degree(&g);
        assert!(w >= 1 && w <= g.n_nodes());
    }

    #[test]
    fn width_never_below_stream_chain_bound() {
        // width (min chain cover) ≤ Algorithm 1's stream count (min PATH
        // cover of the MEG): a path cover is a chain cover.
        use crate::matching::MatchingAlgo;
        use crate::stream::assign::assign_streams;
        let mut rng = Pcg32::new(0xF00);
        for _ in 0..20 {
            let g = random_dag(&mut rng, 18, 0.2);
            let a = assign_streams(&g, MatchingAlgo::HopcroftKarp);
            assert!(logical_concurrency_degree(&g) <= a.n_streams);
        }
    }
}
