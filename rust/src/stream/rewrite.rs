//! Graph Rewriter (paper §4, Figure 4): turn a computation graph plus a
//! stream assignment and sync plan into a **launch plan** — the per-node
//! stream id, the events to wait on before launch, and the events to record
//! after completion, in a deterministic submission order.
//!
//! The paper implements this by inserting custom sync nodes into the
//! TorchScript graph; here the rewrite is the explicit launch plan the AoT
//! scheduler pre-runs and the replay engine executes. The information
//! content is identical (task → stream, plus event record/wait routines).

use super::assign::StreamAssignment;
use super::sync::{plan_syncs, SyncPlan};
use crate::graph::{topo_order, Dag, NodeId};
use crate::matching::MatchingAlgo;

/// Per-node launch directives.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct NodePlan {
    pub node: NodeId,
    /// Stream the node's GPU tasks are issued on.
    pub stream: usize,
    /// Events that must be waited on (cudaStreamWaitEvent) before launch.
    pub wait_events: Vec<usize>,
    /// Events recorded on this node's stream right after its tasks.
    pub record_events: Vec<usize>,
}

/// The rewritten graph: submission order + per-node directives + totals.
#[derive(Debug, Clone)]
pub struct LaunchPlan {
    /// Node plans in submission order (a topological order of the graph).
    pub order: Vec<NodePlan>,
    pub n_streams: usize,
    pub n_events: usize,
    /// The assignment it was built from (kept for reporting/figures).
    pub stream_of: Vec<usize>,
}

impl LaunchPlan {
    /// Directive for a node id (linear scan; plans are built once).
    pub fn plan_for(&self, node: NodeId) -> Option<&NodePlan> {
        self.order.iter().find(|p| p.node == node)
    }

    /// Total number of cross-stream synchronizations.
    pub fn n_syncs(&self) -> usize {
        self.n_events
    }
}

/// Rewrite with multi-stream execution (the full Algorithm 1 pipeline).
pub fn rewrite<N>(g: &Dag<N>, algo: MatchingAlgo) -> LaunchPlan {
    let assignment = crate::stream::assign::assign_streams(g, algo);
    rewrite_with(g, &assignment)
}

/// Rewrite with a precomputed assignment.
pub fn rewrite_with<N>(g: &Dag<N>, assignment: &StreamAssignment) -> LaunchPlan {
    let syncs = plan_syncs(assignment);
    build_plan(g, &assignment.stream_of, assignment.n_streams, &syncs)
}

/// Rewrite forcing everything onto a single stream (the paper's
/// single-stream Nimble used as the Table 1 baseline). No syncs needed.
pub fn rewrite_single_stream<N>(g: &Dag<N>) -> LaunchPlan {
    let stream_of = vec![0usize; g.n_nodes()];
    build_plan(g, &stream_of, 1, &SyncPlan::default())
}

fn build_plan<N>(
    g: &Dag<N>,
    stream_of: &[usize],
    n_streams: usize,
    syncs: &SyncPlan,
) -> LaunchPlan {
    let order = topo_order(g).expect("rewrite requires a DAG");
    // Per-node event lists come from the plan's precomputed CSR index —
    // slice copies, not O(|Λ|) scans.
    let plans = order
        .iter()
        .map(|&v| NodePlan {
            node: v,
            stream: stream_of[v],
            wait_events: syncs.waits_before(v).to_vec(),
            record_events: syncs.records_after(v).to_vec(),
        })
        .collect();
    LaunchPlan {
        order: plans,
        n_streams,
        n_events: syncs.n_syncs(),
        stream_of: stream_of.to_vec(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::gen::layered_dag;
    use crate::stream::sync::plan_is_safe;
    use crate::util::Pcg32;

    fn diamond() -> Dag<()> {
        let mut g = Dag::new();
        for _ in 0..4 {
            g.add_node(());
        }
        g.add_edge(0, 1);
        g.add_edge(0, 2);
        g.add_edge(1, 3);
        g.add_edge(2, 3);
        g
    }

    #[test]
    fn diamond_plan_has_two_streams_two_syncs() {
        let g = diamond();
        let plan = rewrite(&g, MatchingAlgo::HopcroftKarp);
        assert_eq!(plan.n_streams, 2);
        assert_eq!(plan.n_events, 2);
        // every wait event is recorded by exactly one other node
        for p in &plan.order {
            for &e in &p.wait_events {
                let recorders: Vec<_> = plan
                    .order
                    .iter()
                    .filter(|q| q.record_events.contains(&e))
                    .collect();
                assert_eq!(recorders.len(), 1);
            }
        }
    }

    #[test]
    fn submission_order_is_topological() {
        let mut rng = Pcg32::new(5);
        let g = layered_dag(&mut rng, 3, 4, 2);
        let plan = rewrite(&g, MatchingAlgo::HopcroftKarp);
        let pos: std::collections::HashMap<_, _> =
            plan.order.iter().enumerate().map(|(i, p)| (p.node, i)).collect();
        for (u, v) in g.edges() {
            assert!(pos[&u] < pos[&v], "edge ({u},{v}) violates submission order");
        }
    }

    #[test]
    fn single_stream_plan_has_no_events() {
        let g = diamond();
        let plan = rewrite_single_stream(&g);
        assert_eq!(plan.n_streams, 1);
        assert_eq!(plan.n_events, 0);
        assert!(plan.order.iter().all(|p| p.stream == 0));
    }

    #[test]
    fn plan_events_form_safe_sync_plan() {
        let mut rng = Pcg32::new(17);
        for _ in 0..10 {
            let g = layered_dag(&mut rng, 4, 4, 2);
            let a = crate::stream::assign::assign_streams(&g, MatchingAlgo::HopcroftKarp);
            let syncs = plan_syncs(&a);
            let order: Vec<_> = rewrite_with(&g, &a).order.iter().map(|p| p.node).collect();
            assert!(plan_is_safe(&g, &a.stream_of, &order, &syncs));
        }
    }

    #[test]
    fn plan_for_lookup() {
        let g = diamond();
        let plan = rewrite(&g, MatchingAlgo::HopcroftKarp);
        assert_eq!(plan.plan_for(0).unwrap().node, 0);
        assert!(plan.plan_for(99).is_none());
    }
}
