//! Synchronization planning (Theorem 3): given a stream assignment derived
//! from the MEG, the safe plan with the minimum number of synchronizations
//! performs one event-sync on every MEG edge whose endpoints live on
//! different streams — `|E'| − |M|` edges in total.
//!
//! A synchronization on edge `(u, v)` means: record an event after task `u`
//! on stream `f(u)`, and make stream `f(v)` wait on that event before task
//! `v` (the paper's `cudaStreamWaitEvent` pattern).
//!
//! The plan carries a per-node event index (CSR over wait/record lists)
//! built once at construction, so the rewriter's per-node queries are
//! allocation-free slice lookups instead of O(|Λ|) scans.

use super::assign::StreamAssignment;
use crate::graph::{Dag, NodeId};

/// One cross-stream synchronization: record after `src`, wait before `dst`.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Sync {
    pub src: NodeId,
    pub dst: NodeId,
    /// Dense event id (one per sync).
    pub event: usize,
}

/// The synchronization plan Λ, with a per-node CSR index over the wait
/// (incoming) and record (outgoing) event lists. The sync list is
/// private so it cannot drift out of sync with the index — construct
/// through [`SyncPlan::new`], read through [`SyncPlan::syncs`].
#[derive(Debug, Clone, Default)]
pub struct SyncPlan {
    syncs: Vec<Sync>,
    wait_start: Vec<u32>,
    wait_evt: Vec<usize>,
    rec_start: Vec<u32>,
    rec_evt: Vec<usize>,
}

impl SyncPlan {
    /// Build a plan and its per-node event index. Events keep the order
    /// they appear in `syncs` within each node's list.
    pub fn new(syncs: Vec<Sync>, n_nodes: usize) -> SyncPlan {
        let mut wait_start = vec![0u32; n_nodes + 1];
        let mut rec_start = vec![0u32; n_nodes + 1];
        for s in &syncs {
            wait_start[s.dst + 1] += 1;
            rec_start[s.src + 1] += 1;
        }
        for v in 0..n_nodes {
            wait_start[v + 1] += wait_start[v];
            rec_start[v + 1] += rec_start[v];
        }
        let mut wait_evt = vec![0usize; syncs.len()];
        let mut rec_evt = vec![0usize; syncs.len()];
        let mut wait_cursor: Vec<u32> = wait_start[..n_nodes].to_vec();
        let mut rec_cursor: Vec<u32> = rec_start[..n_nodes].to_vec();
        for s in &syncs {
            wait_evt[wait_cursor[s.dst] as usize] = s.event;
            wait_cursor[s.dst] += 1;
            rec_evt[rec_cursor[s.src] as usize] = s.event;
            rec_cursor[s.src] += 1;
        }
        SyncPlan { syncs, wait_start, wait_evt, rec_start, rec_evt }
    }

    pub fn n_syncs(&self) -> usize {
        self.syncs.len()
    }

    /// The synchronizations, in construction order.
    pub fn syncs(&self) -> &[Sync] {
        &self.syncs
    }

    /// Events to wait on before launching `v` (indexed slice, no scan).
    pub fn waits_before(&self, v: NodeId) -> &[usize] {
        if v + 1 >= self.wait_start.len() {
            return &[];
        }
        &self.wait_evt[self.wait_start[v] as usize..self.wait_start[v + 1] as usize]
    }

    /// Events to record after `u` completes (indexed slice, no scan).
    pub fn records_after(&self, u: NodeId) -> &[usize] {
        if u + 1 >= self.rec_start.len() {
            return &[];
        }
        &self.rec_evt[self.rec_start[u] as usize..self.rec_start[u + 1] as usize]
    }
}

/// Build the minimum safe synchronization plan for an assignment.
pub fn plan_syncs(assignment: &StreamAssignment) -> SyncPlan {
    let mut syncs = Vec::new();
    for (u, v) in assignment.meg.edges() {
        if assignment.stream_of[u] != assignment.stream_of[v] {
            let event = syncs.len();
            syncs.push(Sync { src: u, dst: v, event });
        }
    }
    SyncPlan::new(syncs, assignment.stream_of.len())
}

/// Check the *operational* safety of a plan: build the "guarantee graph" H
/// whose edges are (a) consecutive same-stream tasks in submission order
/// (stream-FIFO ordering) and (b) the sync edges, and verify every original
/// dependency edge is realized by a path in H. This is strictly stronger
/// than the paper's Definition 2 and matches what the replay engine relies
/// on at run time.
pub fn plan_is_safe<N>(
    g: &Dag<N>,
    stream_of: &[usize],
    submission_order: &[NodeId],
    plan: &SyncPlan,
) -> bool {
    let n = g.n_nodes();
    let mut h: Dag<()> = Dag::new();
    for _ in 0..n {
        h.add_node(());
    }
    // (a) stream FIFO edges
    let n_streams = stream_of.iter().copied().max().map_or(0, |m| m + 1);
    let mut last_on_stream: Vec<Option<NodeId>> = vec![None; n_streams];
    for &v in submission_order {
        let s = stream_of[v];
        if let Some(prev) = last_on_stream[s] {
            h.add_edge(prev, v);
        }
        last_on_stream[s] = Some(v);
    }
    // (b) sync edges
    for s in &plan.syncs {
        if s.src != s.dst && !h.has_edge(s.src, s.dst) {
            h.add_edge(s.src, s.dst);
        }
    }
    if h.validate().is_err() {
        return false; // a cyclic guarantee graph would deadlock
    }
    let reach = crate::graph::Reachability::compute(&h);
    g.edges().iter().all(|&(u, v)| reach.reaches(u, v))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::gen::{layered_dag, random_dag};
    use crate::graph::topo_order;
    use crate::matching::MatchingAlgo;
    use crate::stream::assign::assign_streams;
    use crate::util::Pcg32;

    #[test]
    fn sync_count_matches_theorem3() {
        let mut rng = Pcg32::new(42);
        for _ in 0..25 {
            let g = random_dag(&mut rng, 30, 0.12);
            let a = assign_streams(&g, MatchingAlgo::HopcroftKarp);
            let plan = plan_syncs(&a);
            assert_eq!(plan.n_syncs(), a.min_syncs(), "|Λ| must equal |E'| − |M|");
        }
    }

    #[test]
    fn plan_is_safe_on_random_and_layered_graphs() {
        let mut rng = Pcg32::new(7);
        for i in 0..30 {
            let g = if i % 2 == 0 {
                random_dag(&mut rng, 25, 0.15)
            } else {
                layered_dag(&mut rng, 3, 4, 3)
            };
            let a = assign_streams(&g, MatchingAlgo::HopcroftKarp);
            let plan = plan_syncs(&a);
            let order = topo_order(&g).unwrap();
            assert!(plan_is_safe(&g, &a.stream_of, &order, &plan));
        }
    }

    #[test]
    fn dropping_a_sync_breaks_safety() {
        // Diamond: 0→1, 0→2, 1→3, 2→3. Streams will be chains, and the two
        // cross-stream MEG edges both carry syncs; removing one must be
        // detected as unsafe.
        let mut g: Dag<()> = Dag::new();
        for _ in 0..4 {
            g.add_node(());
        }
        g.add_edge(0, 1);
        g.add_edge(0, 2);
        g.add_edge(1, 3);
        g.add_edge(2, 3);
        let a = assign_streams(&g, MatchingAlgo::HopcroftKarp);
        let plan = plan_syncs(&a);
        assert_eq!(plan.n_syncs(), 2);
        let order = topo_order(&g).unwrap();
        assert!(plan_is_safe(&g, &a.stream_of, &order, &plan));
        for drop in 0..plan.n_syncs() {
            let reduced = SyncPlan::new(
                plan.syncs.iter().copied().filter(|s| s.event != drop).collect(),
                g.n_nodes(),
            );
            assert!(
                !plan_is_safe(&g, &a.stream_of, &order, &reduced),
                "plan stayed safe after dropping sync {drop}"
            );
        }
    }

    #[test]
    fn single_stream_needs_no_syncs() {
        let mut g: Dag<()> = Dag::new();
        for _ in 0..5 {
            g.add_node(());
        }
        for i in 0..4 {
            g.add_edge(i, i + 1);
        }
        let a = assign_streams(&g, MatchingAlgo::HopcroftKarp);
        let plan = plan_syncs(&a);
        assert_eq!(plan.n_syncs(), 0);
        let order = topo_order(&g).unwrap();
        assert!(plan_is_safe(&g, &a.stream_of, &order, &plan));
    }

    #[test]
    fn waits_and_records_lookup() {
        let plan = SyncPlan::new(
            vec![
                Sync { src: 0, dst: 3, event: 0 },
                Sync { src: 1, dst: 3, event: 1 },
                Sync { src: 0, dst: 2, event: 2 },
            ],
            4,
        );
        assert_eq!(plan.waits_before(3), &[0, 1][..]);
        assert_eq!(plan.records_after(0), &[0, 2][..]);
        assert!(plan.waits_before(0).is_empty());
        // out-of-range nodes (default plans) answer empty, never panic
        assert!(plan.waits_before(99).is_empty());
        assert!(SyncPlan::default().waits_before(0).is_empty());
        assert!(SyncPlan::default().records_after(5).is_empty());
    }

    #[test]
    fn index_matches_linear_scan_on_random_plans() {
        let mut rng = Pcg32::new(0x51DE);
        for _ in 0..20 {
            let g = random_dag(&mut rng, 35, 0.12);
            let a = assign_streams(&g, MatchingAlgo::HopcroftKarp);
            let plan = plan_syncs(&a);
            for v in 0..g.n_nodes() {
                let waits: Vec<usize> =
                    plan.syncs.iter().filter(|s| s.dst == v).map(|s| s.event).collect();
                let recs: Vec<usize> =
                    plan.syncs.iter().filter(|s| s.src == v).map(|s| s.event).collect();
                assert_eq!(plan.waits_before(v), waits.as_slice(), "waits of {v}");
                assert_eq!(plan.records_after(v), recs.as_slice(), "records of {v}");
            }
        }
    }
}
