//! The discrete-event simulation engine.
//!
//! Models exactly the pipeline of the paper's Figure 3: a *serial host
//! thread* walks the launch plan, paying the framework's per-op scheduling
//! overhead before each task submission; submitted tasks enter their
//! stream's FIFO; a task starts when (a) it has been submitted, (b) its
//! stream predecessor finished, (c) all awaited events have fired, and
//! (d) enough SMs are free. Completion records the task's events.
//!
//! The host-gating is what makes run-time scheduling slow even with many
//! streams (the Fig. 3 effect), and the SM pool is what caps multi-stream
//! gains for MAC-heavy networks (Table 1, NASNet-A large).

use super::cost::KernelCost;
use super::device::GpuSpec;
use super::framework::HostProfile;
use crate::graph::NodeId;
use crate::stream::LaunchPlan;

/// Per-task timing produced by the simulation.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct TaskSpan {
    pub node: NodeId,
    pub stream: usize,
    /// When the host finished submitting this task.
    pub submit_s: f64,
    /// When the GPU started executing it.
    pub start_s: f64,
    /// When it completed.
    pub end_s: f64,
}

impl TaskSpan {
    pub fn duration(&self) -> f64 {
        self.end_s - self.start_s
    }
}

/// Simulation inputs.
pub struct SimConfig<'a> {
    pub plan: &'a LaunchPlan,
    /// Kernel costs indexed by node id (virtual ops: zero).
    pub costs: &'a [KernelCost],
    pub host: HostProfile,
    pub device: GpuSpec,
}

/// Simulation outputs.
#[derive(Debug, Clone)]
pub struct SimResult {
    pub spans: Vec<TaskSpan>,
    /// End-to-end latency: everything submitted AND completed.
    pub total_s: f64,
    /// When the host finished its submission loop.
    pub host_s: f64,
    /// Union of busy intervals on the device (Fig. 2a numerator).
    pub gpu_active_s: f64,
}

impl SimResult {
    /// Ratio of GPU-active time to total running time (Fig. 2a).
    pub fn active_ratio(&self) -> f64 {
        if self.total_s == 0.0 {
            0.0
        } else {
            self.gpu_active_s / self.total_s
        }
    }
}

/// Run the simulation.
pub fn simulate(cfg: &SimConfig) -> SimResult {
    let plan = cfg.plan;
    let n_events = plan.n_events;
    let n_streams = plan.n_streams;

    // --- Phase 1: host submission loop (serial, Fig. 3's upper lane). ---
    // submit[i] = host clock when task i's submission completes.
    let mut submit = vec![0.0f64; plan.order.len()];
    let mut host_clock = 0.0f64;
    for (i, p) in plan.order.iter().enumerate() {
        let cost = &cfg.costs[p.node];
        let is_real = cost.duration_s > 0.0 || cost.sm_demand > 0;
        if is_real {
            // scheduling overhead + raw submission
            host_clock += cfg.host.per_task_s();
            // event record/wait submissions also occupy the host
            let sync_ops = p.wait_events.len() + p.record_events.len();
            host_clock += sync_ops as f64 * cfg.host.submit_s;
        }
        submit[i] = host_clock;
    }
    let host_s = host_clock;

    // --- Phase 2: device execution. ---
    // NOTE: `simulate_lanes` mirrors this device model over a merged
    // multi-lane task list; keep the two in lockstep when changing it.
    // Stream FIFOs hold indices into plan.order.
    let mut queues: Vec<std::collections::VecDeque<usize>> =
        vec![std::collections::VecDeque::new(); n_streams];
    for (i, p) in plan.order.iter().enumerate() {
        queues[p.stream].push_back(i);
    }
    let mut prev_end = vec![0.0f64; n_streams];
    let mut event_time: Vec<Option<f64>> = vec![None; n_events];
    let mut running: Vec<(f64, usize)> = Vec::new(); // (end, sm)
    let mut front_clock = 0.0f64; // device work-distributor serializer
    let mut spans: Vec<TaskSpan> = Vec::with_capacity(plan.order.len());
    let mut remaining: usize = queues.iter().map(|q| q.len()).sum();

    // Min-heap of stream heads keyed by (ready-time bits, stream) with lazy
    // revalidation — ready times are non-negative so the IEEE-754 bit
    // pattern orders correctly, and they only grow (submit is static,
    // prev_end and event times are monotone), so a popped entry is either
    // current or re-pushed with a later key. Heads blocked on an
    // unrecorded event park in `blocked_on` and re-enter when it fires.
    // This replaces an O(streams) scan per task (see EXPERIMENTS.md §Perf).
    use std::cmp::Reverse;
    use std::collections::BinaryHeap;
    let mut heap: BinaryHeap<Reverse<(u64, usize)>> = BinaryHeap::new();
    let mut blocked_on: Vec<Vec<usize>> = vec![Vec::new(); n_events];
    // Ready time of stream `s`'s head: Ok(t) or Err(event) if blocked.
    let ready_of = |s: usize,
                    queues: &[std::collections::VecDeque<usize>],
                    prev_end: &[f64],
                    event_time: &[Option<f64>],
                    submit: &[f64]|
     -> Option<std::result::Result<f64, usize>> {
        let &i = queues[s].front()?;
        let p = &plan.order[i];
        let mut ready = submit[i].max(prev_end[s]);
        for &e in &p.wait_events {
            match event_time[e] {
                Some(t) => ready = ready.max(t),
                None => return Some(Err(e)),
            }
        }
        Some(Ok(ready))
    };
    let enqueue_head = |s: usize,
                            heap: &mut BinaryHeap<Reverse<(u64, usize)>>,
                            blocked_on: &mut Vec<Vec<usize>>,
                            queues: &[std::collections::VecDeque<usize>],
                            prev_end: &[f64],
                            event_time: &[Option<f64>]| {
        match ready_of(s, queues, prev_end, event_time, &submit) {
            Some(Ok(t)) => heap.push(Reverse((t.to_bits(), s))),
            Some(Err(e)) => blocked_on[e].push(s),
            None => {}
        }
    };
    for s in 0..n_streams {
        enqueue_head(s, &mut heap, &mut blocked_on, &queues, &prev_end, &event_time);
    }

    while remaining > 0 {
        let Some(Reverse((bits, s))) = heap.pop() else {
            panic!("no eligible task: launch plan is unsafe or submission order non-topological");
        };
        // Lazy revalidation: the head may have advanced or its ready time
        // may have grown since the entry was pushed.
        let ready = match ready_of(s, &queues, &prev_end, &event_time, &submit) {
            Some(Ok(t)) => t,
            Some(Err(e)) => {
                blocked_on[e].push(s);
                continue;
            }
            None => continue, // stream drained by a fresher entry
        };
        if ready.to_bits() != bits {
            heap.push(Reverse((ready.to_bits(), s)));
            continue;
        }
        let i = queues[s].pop_front().unwrap();
        remaining -= 1;
        let p = &plan.order[i];
        let cost = &cfg.costs[p.node];

        // Find the earliest start ≥ ready with enough free SMs, after the
        // device front-end has dispatched every earlier kernel launch.
        // Demand is clamped to the device (kernel_cost already clamps;
        // hand-built costs in tests may not).
        let sm_demand = cost.sm_demand.min(cfg.device.sm_count);
        let mut start = ready;
        if sm_demand > 0 {
            start = start.max(front_clock);
            loop {
                let used: usize = running
                    .iter()
                    .filter(|&&(end, _)| end > start)
                    .map(|&(_, sm)| sm)
                    .sum();
                if cfg.device.sm_count.saturating_sub(used) >= sm_demand {
                    break;
                }
                // advance to the next completion after `start`
                let next = running
                    .iter()
                    .map(|&(end, _)| end)
                    .filter(|&end| end > start)
                    .fold(f64::INFINITY, f64::min);
                assert!(next.is_finite(), "SM demand can never be satisfied");
                start = next;
            }
        }
        let end = start + cost.duration_s;
        if sm_demand > 0 {
            front_clock = start + cfg.device.front_end_s;
            running.push((end, sm_demand));
            // Garbage-collect long-finished tasks to keep the scan short.
            if running.len() > 256 {
                running.retain(|&(e, _)| e > start);
            }
        }
        prev_end[s] = end;
        for &e in &p.record_events {
            event_time[e] = Some(end);
            // Wake heads parked on this event.
            for w in std::mem::take(&mut blocked_on[e]) {
                enqueue_head(w, &mut heap, &mut blocked_on, &queues, &prev_end, &event_time);
            }
        }
        spans.push(TaskSpan { node: p.node, stream: s, submit_s: submit[i], start_s: start, end_s: end });
        // This stream's next head becomes schedulable.
        enqueue_head(s, &mut heap, &mut blocked_on, &queues, &prev_end, &event_time);
    }

    let gpu_active_s = super::metrics::interval_union(
        spans.iter().filter(|sp| sp.duration() > 0.0).map(|sp| (sp.start_s, sp.end_s)),
    );
    let device_end = spans.iter().map(|sp| sp.end_s).fold(0.0, f64::max);
    SimResult { spans, total_s: device_end.max(host_s), host_s, gpu_active_s }
}

/// Replay a compiled [`ReplayTape`](crate::aot::tape::ReplayTape) on the
/// simulator. The tape round-trips to the launch plan it was compiled
/// from, so this predicts exactly what [`simulate`] predicts for that
/// plan — the DES cross-check for the real parallel executor: predicted
/// multi-stream speedups on one side, measured task interleavings
/// (`ReplayContext::completion_stamps`) on the other, over the *same*
/// artifact.
pub fn simulate_tape(
    tape: &crate::aot::tape::ReplayTape,
    costs: &[KernelCost],
    host: HostProfile,
    device: GpuSpec,
) -> SimResult {
    let plan = tape.to_launch_plan();
    simulate(&SimConfig { plan: &plan, costs, host, device })
}

/// Predicted peak concurrently-reserved bytes of a simulated replay: a
/// slot's reservation (`bytes[slot]`, normally the arena plan's
/// `rounded_sizes`) is live from its defining record until its last
/// reader finishes (forever, if nothing reads it). Spans are processed
/// in the simulator's execution order — a legal linearization of the
/// tape's happens-before order — with the same point-event discipline as
/// the executor's traced accounting (`ReplayContext::peak_live_bytes`):
/// mark the record's slot live, then retire exhausted argument slots.
/// On a single-stream tape both sides walk the identical order, so
/// prediction and measurement agree **exactly**; on multi-stream tapes
/// both are bounded by the arena plan's `arena_bytes` (the live set is
/// always pairwise-conflicting, and conflicting slots occupy disjoint
/// ranges).
pub fn peak_reserved_bytes(
    tape: &crate::aot::tape::ReplayTape,
    spans: &[TaskSpan],
    bytes: &[u64],
) -> u64 {
    use crate::aot::tape::TapeArg;
    let n_slots = tape.n_slots();
    assert_eq!(bytes.len(), n_slots, "one reservation size per slot");
    let mut op_of = vec![usize::MAX; n_slots];
    let mut readers = vec![0u32; n_slots];
    for (i, op) in tape.ops().iter().enumerate() {
        op_of[op.out_slot as usize] = i;
        for arg in tape.args(op) {
            if let TapeArg::Slot(s) = *arg {
                readers[s as usize] += 1;
            }
        }
    }
    let (mut live, mut peak) = (0u64, 0u64);
    for sp in spans {
        let i = op_of[sp.node];
        assert!(i != usize::MAX, "span for a slot the tape never writes");
        live += bytes[sp.node];
        peak = peak.max(live);
        for arg in tape.args(tape.op(i)) {
            if let TapeArg::Slot(s) = *arg {
                let s = s as usize;
                readers[s] -= 1;
                if readers[s] == 0 {
                    live -= bytes[s];
                }
            }
        }
    }
    peak
}

/// One serving lane's offered work in the multi-lane DES
/// ([`simulate_lanes`]): a compiled tape, its per-node kernel costs, and
/// the wall-clock when its batch was dispatched to the lane.
pub struct LaneLoad<'a> {
    pub tape: &'a crate::aot::tape::ReplayTape,
    pub costs: &'a [KernelCost],
    /// Dispatch time of this lane's batch (≥ 0; the simulation origin is
    /// the first possible dispatch).
    pub arrival_s: f64,
}

/// Multi-lane prediction: the overlapped makespan against the serialized
/// single-engine-thread baseline, plus a deterministic completion trace.
#[derive(Debug, Clone)]
pub struct MultiLaneResult {
    /// Independent single-lane results (each lane alone on the device,
    /// starting at t = 0) — the per-lane latency floor.
    pub per_lane: Vec<SimResult>,
    /// Absolute completion time of each lane in the overlapped schedule.
    pub lane_end_s: Vec<f64>,
    /// Overlapped makespan from t = 0.
    pub total_s: f64,
    /// Makespan when the same lanes run back-to-back on one engine
    /// thread (each starting no earlier than its arrival) — the PR-1
    /// serving baseline.
    pub serial_total_s: f64,
    /// `(lane, node)` pairs sorted by completion time (ties broken by
    /// lane then node) — the trace the determinism tests compare.
    pub completion_order: Vec<(usize, NodeId)>,
}

impl MultiLaneResult {
    /// Predicted throughput gain of overlapping the lanes.
    pub fn overlap_speedup(&self) -> f64 {
        if self.total_s == 0.0 {
            1.0
        } else {
            self.serial_total_s / self.total_s
        }
    }
}

/// Joint DES over several lanes: each lane has its **own host thread**
/// (per-lane submission clocks starting at its arrival — the lane
/// scheduler's defining property), while all lanes share one device
/// (SM pool + front-end serializer). Stream FIFOs and events never
/// cross lanes, exactly like the independent per-bucket replay contexts.
pub fn simulate_lanes(lanes: &[LaneLoad], host: HostProfile, device: GpuSpec) -> MultiLaneResult {
    assert!(!lanes.is_empty(), "need at least one lane");
    let plans: Vec<LaunchPlan> = lanes.iter().map(|l| l.tape.to_launch_plan()).collect();
    let per_lane: Vec<SimResult> = lanes
        .iter()
        .zip(&plans)
        .map(|(l, p)| {
            simulate(&SimConfig { plan: p, costs: l.costs, host, device: device.clone() })
        })
        .collect();

    // Serialized baseline: one engine thread replays the lanes in order.
    let mut serial_clock = 0.0f64;
    for (l, r) in lanes.iter().zip(&per_lane) {
        assert!(l.arrival_s >= 0.0, "arrivals must be non-negative");
        serial_clock = serial_clock.max(l.arrival_s) + r.total_s;
    }
    let serial_total_s = serial_clock;

    // --- Merge lanes into one device-level task list. ---
    struct MTask {
        lane: usize,
        node: NodeId,
        stream: usize,
        submit: f64,
        dur: f64,
        sm: usize,
        waits: Vec<usize>,
        records: Vec<usize>,
    }
    let n_lanes = lanes.len();
    let (mut n_streams, mut n_events) = (0usize, 0usize);
    let mut stream_off = vec![0usize; n_lanes];
    let mut event_off = vec![0usize; n_lanes];
    for (i, p) in plans.iter().enumerate() {
        stream_off[i] = n_streams;
        event_off[i] = n_events;
        n_streams += p.n_streams;
        n_events += p.n_events;
    }
    let mut tasks: Vec<MTask> = Vec::new();
    let mut host_end = vec![0.0f64; n_lanes];
    for (li, (lane, plan)) in lanes.iter().zip(&plans).enumerate() {
        // Per-lane host thread: submission starts at the lane's arrival.
        let mut host_clock = lane.arrival_s;
        for p in &plan.order {
            let cost = &lane.costs[p.node];
            let is_real = cost.duration_s > 0.0 || cost.sm_demand > 0;
            if is_real {
                host_clock += host.per_task_s();
                let sync_ops = p.wait_events.len() + p.record_events.len();
                host_clock += sync_ops as f64 * host.submit_s;
            }
            tasks.push(MTask {
                lane: li,
                node: p.node,
                stream: stream_off[li] + p.stream,
                submit: host_clock,
                dur: cost.duration_s,
                sm: cost.sm_demand.min(device.sm_count),
                waits: p.wait_events.iter().map(|&e| event_off[li] + e).collect(),
                records: p.record_events.iter().map(|&e| event_off[li] + e).collect(),
            });
        }
        host_end[li] = host_clock;
    }

    // --- Shared-device execution. ---
    // NOTE: this mirrors `simulate`'s phase-2 discipline (SM-pool
    // admission, front-end serializer, lazy-revalidated ready heap,
    // running-list pruning) over the merged task list. Any change to the
    // device model in `simulate` MUST be mirrored here — the
    // `single_lane_degenerates_to_the_plain_simulation` test pins the
    // single-lane case to within 1e-12, but multi-lane-only drift would
    // only show up as wrong BENCH_serving.json predictions.
    use std::cmp::Reverse;
    use std::collections::BinaryHeap;
    use std::collections::VecDeque;
    let mut queues: Vec<VecDeque<usize>> = vec![VecDeque::new(); n_streams];
    for (i, t) in tasks.iter().enumerate() {
        queues[t.stream].push_back(i);
    }
    let mut prev_end = vec![0.0f64; n_streams];
    let mut event_time: Vec<Option<f64>> = vec![None; n_events];
    let mut running: Vec<(f64, usize)> = Vec::new();
    let mut front_clock = 0.0f64;
    let mut remaining = tasks.len();
    let mut heap: BinaryHeap<Reverse<(u64, usize)>> = BinaryHeap::new();
    let mut blocked_on: Vec<Vec<usize>> = vec![Vec::new(); n_events];
    let ready_of = |s: usize,
                    queues: &[VecDeque<usize>],
                    prev_end: &[f64],
                    event_time: &[Option<f64>]|
     -> Option<std::result::Result<f64, usize>> {
        let &i = queues[s].front()?;
        let t = &tasks[i];
        let mut ready = t.submit.max(prev_end[s]);
        for &e in &t.waits {
            match event_time[e] {
                Some(at) => ready = ready.max(at),
                None => return Some(Err(e)),
            }
        }
        Some(Ok(ready))
    };
    let enqueue_head = |s: usize,
                        heap: &mut BinaryHeap<Reverse<(u64, usize)>>,
                        blocked_on: &mut Vec<Vec<usize>>,
                        queues: &[VecDeque<usize>],
                        prev_end: &[f64],
                        event_time: &[Option<f64>]| {
        match ready_of(s, queues, prev_end, event_time) {
            Some(Ok(t)) => heap.push(Reverse((t.to_bits(), s))),
            Some(Err(e)) => blocked_on[e].push(s),
            None => {}
        }
    };
    for s in 0..n_streams {
        enqueue_head(s, &mut heap, &mut blocked_on, &queues, &prev_end, &event_time);
    }
    let mut lane_end_s = host_end.clone();
    let mut done: Vec<(usize, NodeId, f64)> = Vec::with_capacity(tasks.len());
    while remaining > 0 {
        let Some(Reverse((bits, s))) = heap.pop() else {
            panic!("no eligible task: a lane's plan is unsafe or non-topological");
        };
        let ready = match ready_of(s, &queues, &prev_end, &event_time) {
            Some(Ok(t)) => t,
            Some(Err(e)) => {
                blocked_on[e].push(s);
                continue;
            }
            None => continue, // stream drained by a fresher entry
        };
        if ready.to_bits() != bits {
            heap.push(Reverse((ready.to_bits(), s)));
            continue;
        }
        let i = queues[s].pop_front().unwrap();
        remaining -= 1;
        let t = &tasks[i];
        let mut start = ready;
        if t.sm > 0 {
            start = start.max(front_clock);
            loop {
                let used: usize =
                    running.iter().filter(|&&(end, _)| end > start).map(|&(_, sm)| sm).sum();
                if device.sm_count.saturating_sub(used) >= t.sm {
                    break;
                }
                let next = running
                    .iter()
                    .map(|&(end, _)| end)
                    .filter(|&end| end > start)
                    .fold(f64::INFINITY, f64::min);
                assert!(next.is_finite(), "SM demand can never be satisfied");
                start = next;
            }
        }
        let end = start + t.dur;
        if t.sm > 0 {
            front_clock = start + device.front_end_s;
            running.push((end, t.sm));
            if running.len() > 256 {
                running.retain(|&(e, _)| e > start);
            }
        }
        prev_end[s] = end;
        for &e in &t.records {
            event_time[e] = Some(end);
            for w in std::mem::take(&mut blocked_on[e]) {
                enqueue_head(w, &mut heap, &mut blocked_on, &queues, &prev_end, &event_time);
            }
        }
        lane_end_s[t.lane] = lane_end_s[t.lane].max(end);
        done.push((t.lane, t.node, end));
        enqueue_head(s, &mut heap, &mut blocked_on, &queues, &prev_end, &event_time);
    }
    done.sort_by_key(|&(lane, node, end)| (end.to_bits(), lane, node));
    let total_s = lane_end_s.iter().fold(0.0f64, |a, &b| a.max(b));
    MultiLaneResult {
        per_lane,
        lane_end_s,
        total_s,
        serial_total_s,
        completion_order: done.into_iter().map(|(lane, node, _)| (lane, node)).collect(),
    }
}

/// One lane's queued batch traffic for the deadline-aware DES
/// ([`simulate_lanes_deadline`]): the lane's compiled tape and costs,
/// plus per-batch `(arrival_s, deadline_s)` pairs
/// (`f64::INFINITY` = no deadline).
pub struct LaneTraffic<'a> {
    pub tape: &'a crate::aot::tape::ReplayTape,
    pub costs: &'a [KernelCost],
    /// Batch arrivals, ascending: `(arrival_s, absolute deadline_s)`.
    pub batches: &'a [(f64, f64)],
}

/// Per-lane prediction of [`simulate_lanes_deadline`].
#[derive(Debug, Clone)]
pub struct DeadlineLaneStat {
    /// Per-batch service time of this lane's tape (single-lane DES
    /// latency, [`simulate_tape`]`.total_s`).
    pub service_s: f64,
    /// Batches that started before their deadline.
    pub completed: usize,
    /// Batches whose deadline passed while they queued (never served).
    pub shed: usize,
    /// When the lane's last served batch completes.
    pub lane_end_s: f64,
}

/// Output of [`simulate_lanes_deadline`].
#[derive(Debug, Clone)]
pub struct DeadlineShedResult {
    pub per_lane: Vec<DeadlineLaneStat>,
    /// Makespan across lanes (lanes independent).
    pub total_s: f64,
}

impl DeadlineShedResult {
    pub fn completed(&self) -> usize {
        self.per_lane.iter().map(|l| l.completed).sum()
    }

    pub fn shed(&self) -> usize {
        self.per_lane.iter().map(|l| l.shed).sum()
    }
}

/// Deadline-aware lane prediction: how many queued batches the lane
/// scheduler will shed under a given deadline budget.
///
/// Each lane is one FIFO server whose per-batch service time is its
/// tape's single-lane DES latency ([`simulate_tape`]`.total_s`) — the
/// same batch-granularity queue model (and uncontended-device
/// assumption) as [`simulate_scaling`]. The shed rule mirrors the live
/// dispatcher's pop-time check exactly: a batch whose execution would
/// start at or after its deadline (`start >= deadline_s`) is shed and
/// the server stays free; execution already started always completes.
/// With every deadline at `f64::INFINITY` nothing sheds and the lane
/// degenerates to plain FIFO pipelining.
pub fn simulate_lanes_deadline(
    lanes: &[LaneTraffic],
    host: HostProfile,
    device: GpuSpec,
) -> DeadlineShedResult {
    assert!(!lanes.is_empty(), "need at least one lane");
    let mut per_lane = Vec::with_capacity(lanes.len());
    for lane in lanes {
        let service_s = simulate_tape(lane.tape, lane.costs, host, device.clone()).total_s;
        let (mut free_at, mut lane_end_s) = (0.0f64, 0.0f64);
        let (mut completed, mut shed) = (0usize, 0usize);
        for &(arrival, deadline) in lane.batches {
            assert!(arrival >= 0.0, "arrivals must be non-negative");
            let start = free_at.max(arrival);
            if start >= deadline {
                shed += 1;
            } else {
                completed += 1;
                free_at = start + service_s;
                lane_end_s = free_at;
            }
        }
        per_lane.push(DeadlineLaneStat { service_s, completed, shed, lane_end_s });
    }
    let total_s = per_lane.iter().fold(0.0f64, |a, l| a.max(l.lane_end_s));
    DeadlineShedResult { per_lane, total_s }
}

/// One bucket's offered traffic for the scaling DES
/// ([`simulate_scaling`]): the bucket's compiled tape and costs, plus
/// the wall-clock dispatch times of its batches.
pub struct ScalingTrace<'a> {
    pub tape: &'a crate::aot::tape::ReplayTape,
    pub costs: &'a [KernelCost],
    /// Batch dispatch times for this bucket, ascending, ≥ 0.
    pub arrivals_s: &'a [f64],
}

/// The scaling policy the DES mirrors — the offline counterpart of the
/// lane scheduler's `ScaleOptions`.
#[derive(Debug, Clone)]
pub struct ScaleSimPolicy {
    /// Max lanes per bucket (1 = static).
    pub max_lanes_per_bucket: usize,
    /// Retire an elastic lane once idle this long.
    pub idle_retire_s: f64,
    /// Spawn another lane when a bucket has this many batches in flight
    /// and its least-loaded lane is busy.
    pub scale_up_backlog: usize,
}

/// Per-bucket prediction of [`simulate_scaling`].
#[derive(Debug, Clone)]
pub struct BucketScaling {
    /// Peak concurrently-live lanes in the elastic schedule.
    pub peak_lanes: usize,
    /// Lanes ever spawned (seed included).
    pub lanes_spawned: usize,
    /// Elastic lanes retired (every elastic lane eventually retires
    /// once idle, so this converges to `lanes_spawned - 1`).
    pub lanes_retired: usize,
    /// When the bucket's last batch completes, elastic lanes.
    pub elastic_end_s: f64,
    /// When it completes on the static single lane.
    pub static_end_s: f64,
    /// Integral of live lane count over time in the elastic schedule
    /// (lane-seconds) — each lane counts from spawn to retirement (or
    /// to its last completion, for the seed lane).
    pub elastic_lane_alive_s: f64,
}

/// Output of [`simulate_scaling`].
#[derive(Debug, Clone)]
pub struct ScalingResult {
    pub per_bucket: Vec<BucketScaling>,
    /// Elastic makespan across all buckets (buckets independent).
    pub elastic_total_s: f64,
    /// Static (one lane per bucket) makespan across all buckets.
    pub static_total_s: f64,
}

impl ScalingResult {
    /// Predicted makespan gain of elastic over static lanes.
    pub fn scaling_speedup(&self) -> f64 {
        if self.elastic_total_s == 0.0 {
            1.0
        } else {
            self.static_total_s / self.elastic_total_s
        }
    }

    pub fn lanes_spawned(&self) -> usize {
        self.per_bucket.iter().map(|b| b.lanes_spawned).sum()
    }

    pub fn lanes_retired(&self) -> usize {
        self.per_bucket.iter().map(|b| b.lanes_retired).sum()
    }

    /// Total elastic lane-seconds; compare against
    /// `n_buckets × max_lanes × static_total_s`, the cost of statically
    /// provisioning every bucket at the elastic peak.
    pub fn elastic_lane_alive_s(&self) -> f64 {
        self.per_bucket.iter().map(|b| b.elastic_lane_alive_s).sum()
    }
}

/// Offline prediction of the elastic lane scheduler: replays per-bucket
/// batch-arrival traces against the scaling policy and predicts lane
/// counts, spawn/retire decisions, and the elastic-vs-static makespan.
///
/// The model is a per-bucket multi-server queue at **batch**
/// granularity: each lane is a FIFO server whose per-batch service time
/// is the bucket tape's single-lane DES latency
/// ([`simulate_tape`]`.total_s`), arrivals route to the
/// earliest-available lane, a new lane spawns (up to the policy cap)
/// when every lane is busy and the bucket's in-flight count reaches
/// `scale_up_backlog`, and a lane retires after `idle_retire_s` of
/// idleness. Buckets are independent — the device is assumed
/// uncontended across lanes, the same approximation the per-round
/// overlap prediction in `bench_serving` makes (valid while per-lane SM
/// demand is low; [`simulate_lanes`] models the contended case for a
/// fixed lane set).
pub fn simulate_scaling(
    traces: &[ScalingTrace],
    host: HostProfile,
    device: GpuSpec,
    policy: &ScaleSimPolicy,
) -> ScalingResult {
    assert!(!traces.is_empty(), "need at least one bucket trace");
    assert!(policy.max_lanes_per_bucket >= 1, "need at least one lane per bucket");
    struct SimLane {
        /// Completion times of batches assigned and not yet known-done.
        pending_ends: std::collections::VecDeque<f64>,
        free_at: f64,
        spawned_at: f64,
        elastic: bool,
    }
    let mut per_bucket = Vec::with_capacity(traces.len());
    for trace in traces {
        let service_s =
            simulate_tape(trace.tape, trace.costs, host, device.clone()).total_s;

        // Static single-lane baseline.
        let mut static_end = 0.0f64;
        for &arr in trace.arrivals_s {
            assert!(arr >= 0.0, "arrivals must be non-negative");
            static_end = static_end.max(arr) + service_s;
        }

        // Elastic multi-server queue.
        let mut lanes = vec![SimLane {
            pending_ends: std::collections::VecDeque::new(),
            free_at: 0.0,
            spawned_at: 0.0,
            elastic: false,
        }];
        let (mut spawned, mut retired, mut peak) = (1usize, 0usize, 1usize);
        let mut alive_s = 0.0f64;
        for &arr in trace.arrivals_s {
            // Prune completed batches everywhere (the seed lane too —
            // its deque would otherwise grow with the whole trace and
            // turn the in-flight recount quadratic), then retire lanes
            // idle past the window, exactly like the dispatcher's
            // scaling pass observed at this arrival.
            for lane in &mut lanes {
                lane.pending_ends.retain(|&e| e > arr);
            }
            let mut i = 1;
            while i < lanes.len() {
                let lane = &lanes[i];
                if lane.elastic
                    && lane.pending_ends.is_empty()
                    && lane.free_at + policy.idle_retire_s <= arr
                {
                    let lane = lanes.remove(i);
                    retired += 1;
                    alive_s += (lane.free_at + policy.idle_retire_s) - lane.spawned_at;
                } else {
                    i += 1;
                }
            }
            // In-flight batches across the bucket (admission pressure).
            let in_flight: usize = lanes.iter().map(|l| l.pending_ends.len()).sum();
            // Earliest-available lane, ties to the seed end.
            let mut li = 0;
            for (i, l) in lanes.iter().enumerate() {
                if l.free_at < lanes[li].free_at {
                    li = i;
                }
            }
            if lanes[li].free_at > arr
                && in_flight >= policy.scale_up_backlog
                && lanes.len() < policy.max_lanes_per_bucket
            {
                lanes.push(SimLane {
                    pending_ends: std::collections::VecDeque::new(),
                    free_at: arr,
                    spawned_at: arr,
                    elastic: true,
                });
                spawned += 1;
                li = lanes.len() - 1;
            }
            peak = peak.max(lanes.len());
            let start = lanes[li].free_at.max(arr);
            let end = start + service_s;
            lanes[li].free_at = end;
            lanes[li].pending_ends.push_back(end);
        }
        // Wind down: every surviving elastic lane retires once idle.
        let elastic_end =
            lanes.iter().map(|l| l.free_at).fold(0.0f64, f64::max);
        for lane in &lanes {
            if lane.elastic {
                retired += 1;
                alive_s += (lane.free_at + policy.idle_retire_s) - lane.spawned_at;
            }
        }
        // The seed lane is alive for the whole bucket schedule.
        alive_s += elastic_end;
        per_bucket.push(BucketScaling {
            peak_lanes: peak,
            lanes_spawned: spawned,
            lanes_retired: retired,
            elastic_end_s: elastic_end,
            static_end_s: static_end,
            elastic_lane_alive_s: alive_s,
        });
    }
    let elastic_total_s =
        per_bucket.iter().map(|b| b.elastic_end_s).fold(0.0f64, f64::max);
    let static_total_s =
        per_bucket.iter().map(|b| b.static_end_s).fold(0.0f64, f64::max);
    ScalingResult { per_bucket, elastic_total_s, static_total_s }
}

/// One lane's chaos traffic for [`simulate_faults`]: deadline traffic
/// ([`LaneTraffic`]-shaped) plus the lane's seeded engine-fault
/// schedule and retry policy.
pub struct FaultTraffic<'a> {
    pub tape: &'a crate::aot::tape::ReplayTape,
    pub costs: &'a [KernelCost],
    /// Batch arrivals, ascending: `(arrival_s, absolute deadline_s)`
    /// (`f64::INFINITY` = no deadline).
    pub batches: &'a [(f64, f64)],
    /// The engine-level fault schedule this lane's `ChaosEngine` rolls
    /// — already derived for the lane's bucket
    /// (`FaultPlan::derive(bucket)`), exactly as the runtime builder
    /// derives it.
    pub plan: crate::fault::FaultPlan,
    /// Mirror of the live `RetryPolicy`: re-executions allowed per
    /// batch after its first attempt.
    pub max_retries: u32,
    /// Mirror of the live `RetryPolicy::backoff`, in seconds.
    pub backoff_s: f64,
}

/// Per-lane prediction of [`simulate_faults`].
#[derive(Debug, Clone)]
pub struct FaultLaneStat {
    /// Per-batch service time of this lane's tape (single-lane DES
    /// latency, [`simulate_tape`]`.total_s`).
    pub service_s: f64,
    /// Batches that eventually completed (possibly after retries).
    pub completed: usize,
    /// Batches that exhausted their retry budget (or could no longer
    /// retry within their deadline) and resolved as failed.
    pub failed: usize,
    /// Re-executions: every attempt after a batch's first.
    pub retried: usize,
    /// Batches shed before execution (deadline passed while queued).
    pub shed: usize,
    /// When the lane goes idle for good.
    pub lane_end_s: f64,
}

/// Output of [`simulate_faults`].
#[derive(Debug, Clone)]
pub struct FaultSimResult {
    pub per_lane: Vec<FaultLaneStat>,
    /// Makespan across lanes (lanes independent).
    pub total_s: f64,
}

impl FaultSimResult {
    pub fn completed(&self) -> usize {
        self.per_lane.iter().map(|l| l.completed).sum()
    }

    pub fn failed(&self) -> usize {
        self.per_lane.iter().map(|l| l.failed).sum()
    }

    pub fn retried(&self) -> usize {
        self.per_lane.iter().map(|l| l.retried).sum()
    }

    pub fn shed(&self) -> usize {
        self.per_lane.iter().map(|l| l.shed).sum()
    }
}

/// Chaos-aware lane prediction: how many batches the lane scheduler
/// completes, retries, fails, and sheds under a seeded
/// [`FaultPlan`](crate::fault::FaultPlan).
///
/// Extends [`simulate_lanes_deadline`]'s per-lane FIFO model with the
/// live chaos stack's engine-call semantics, mirrored bit-for-bit:
/// each lane's `ChaosEngine` rolls `plan.engine_fault(call)` on a
/// per-engine call counter that starts at 0 and advances once per
/// attempt, so the fault schedule here is *identical* to the one the
/// live engine sees as long as batches reach the engine in the same
/// order. A faulted attempt bails before the engine runs (costing only
/// the retry backoff); the lane retries until the attempt count
/// exceeds `max_retries` or the next attempt could not start before
/// the batch's deadline, then resolves the batch as failed. (The live
/// lane sheds still-unserved *rows* individually at that point; at
/// batch granularity the sim folds those into `failed`.) Replay-level
/// faults (worker death, poisoning join timeouts) are supervision
/// territory — lane replacement, re-admission — and are not modeled
/// here; drive them with zero replay probabilities when validating
/// against a measured run, as `bench_serving`'s chaos section does.
pub fn simulate_faults(
    lanes: &[FaultTraffic],
    host: HostProfile,
    device: GpuSpec,
) -> FaultSimResult {
    assert!(!lanes.is_empty(), "need at least one lane");
    let mut per_lane = Vec::with_capacity(lanes.len());
    for lane in lanes {
        let service_s = simulate_tape(lane.tape, lane.costs, host, device.clone()).total_s;
        let (mut free_at, mut lane_end_s) = (0.0f64, 0.0f64);
        let (mut completed, mut failed, mut retried, mut shed) =
            (0usize, 0usize, 0usize, 0usize);
        let mut call = 0u64; // the lane engine's ChaosEngine call counter
        for &(arrival, deadline) in lane.batches {
            assert!(arrival >= 0.0, "arrivals must be non-negative");
            let start = free_at.max(arrival);
            if start >= deadline {
                // Shed at pop time: no engine call, server stays free.
                shed += 1;
                continue;
            }
            let mut t = start;
            let mut attempts = 0u32;
            loop {
                let fault = lane.plan.engine_fault(call);
                call += 1;
                attempts += 1;
                if fault.is_none() {
                    t += service_s;
                    completed += 1;
                    break;
                }
                if attempts > lane.max_retries {
                    failed += 1;
                    break;
                }
                if t + lane.backoff_s >= deadline {
                    failed += 1;
                    break;
                }
                retried += 1;
                t += lane.backoff_s;
            }
            free_at = t;
            lane_end_s = lane_end_s.max(t);
        }
        per_lane.push(FaultLaneStat {
            service_s,
            completed,
            failed,
            retried,
            shed,
            lane_end_s,
        });
    }
    let total_s = per_lane.iter().fold(0.0f64, |a, l| a.max(l.lane_end_s));
    FaultSimResult { per_lane, total_s }
}

/// One bucket's offered traffic for the EDF-aware DES
/// ([`simulate_edf`]): the bucket's compiled tape and costs, plus
/// per-batch `(arrival_s, deadline_s)` pairs (`f64::INFINITY` = no
/// deadline).
pub struct EdfTraffic<'a> {
    pub tape: &'a crate::aot::tape::ReplayTape,
    pub costs: &'a [KernelCost],
    /// Batch arrivals, ascending: `(arrival_s, absolute deadline_s)`.
    pub batches: &'a [(f64, f64)],
}

/// The deadline discipline [`simulate_edf`] mirrors — the offline
/// counterpart of `RuntimeBuilder::{edf, slo}` plus the lane ceiling of
/// `ScaleOptions::max_lanes_per_bucket`.
#[derive(Debug, Clone)]
pub struct EdfSimPolicy {
    /// Mirror of `LaneConfig::edf`: earliest-deadline-first dispatch
    /// and admission-time shedding when true; strict FIFO with
    /// start-time shedding only (the [`simulate_lanes_deadline`]
    /// semantics) when false.
    pub edf: bool,
    /// Mirror of `RuntimeBuilder::slo`: target shed rate the controller
    /// holds by force-spawning lanes (`None` = controller off).
    pub slo: Option<f64>,
    /// Lane ceiling the controller may spawn up to (1 = static).
    pub max_lanes_per_bucket: usize,
}

/// Per-bucket prediction of [`simulate_edf`].
#[derive(Debug, Clone)]
pub struct EdfBucketStat {
    /// Per-batch service time of this bucket's tape (single-lane DES
    /// latency, [`simulate_tape`]`.total_s`).
    pub service_s: f64,
    /// Batches that started before their deadline.
    pub completed: usize,
    /// All deadline sheds: admission sheds plus batches whose deadline
    /// passed while they queued.
    pub shed: usize,
    /// Subset of [`shed`](Self::shed) resolved at admission by the
    /// queue-delay estimate (the live `admission_shed` counter).
    pub admission_shed: usize,
    /// Lanes ever live for this bucket (seed included; > 1 only when
    /// the SLO controller spawned).
    pub lanes_spawned: usize,
    /// When the bucket's last served batch completes.
    pub lane_end_s: f64,
}

/// Output of [`simulate_edf`].
#[derive(Debug, Clone)]
pub struct EdfSimResult {
    pub per_bucket: Vec<EdfBucketStat>,
    /// Makespan across buckets (buckets independent).
    pub total_s: f64,
}

impl EdfSimResult {
    pub fn completed(&self) -> usize {
        self.per_bucket.iter().map(|b| b.completed).sum()
    }

    pub fn shed(&self) -> usize {
        self.per_bucket.iter().map(|b| b.shed).sum()
    }

    pub fn admission_shed(&self) -> usize {
        self.per_bucket.iter().map(|b| b.admission_shed).sum()
    }

    pub fn lanes_spawned(&self) -> usize {
        self.per_bucket.iter().map(|b| b.lanes_spawned).sum()
    }

    /// Shed fraction of everything offered.
    pub fn shed_rate(&self) -> f64 {
        let total = self.completed() + self.shed();
        if total == 0 {
            0.0
        } else {
            self.shed() as f64 / total as f64
        }
    }
}

/// Deadline-first lane prediction: mirrors the live dispatcher's EDF
/// discipline — admission-time shedding from the per-bucket queue-delay
/// estimate, earliest-deadline-first dispatch (FIFO among equal or
/// absent deadlines), and the SLO controller's force-spawns — over
/// per-bucket batch traffic.
///
/// Each bucket is a multi-server queue at **batch** granularity whose
/// per-batch service time is the bucket tape's single-lane DES latency
/// ([`simulate_tape`]`.total_s`), the same model (and uncontended-device
/// assumption) as [`simulate_scaling`]. The live rules are mirrored
/// exactly, with their timing quantized to this model's events:
///
/// - **Admission estimate**: `est = ewma × (backlog / lanes + 1)` with
///   `backlog` = queued + executing batches, exactly the dispatcher's
///   `admission_estimate_s`. The bucket's service time is constant
///   here, so the live EWMA equals `service_s` from its first completed
///   batch onward and `0.0` (never sheds a live budget) before — the
///   sim warms the estimate at the instant the first batch completes,
///   where the live dispatcher warms at its next 5ms scaling pass.
/// - **Admission shed**: a deadline at or before its arrival sheds
///   deterministically; otherwise a batch sheds iff
///   `arrival + est >= deadline` (`edf` on only).
/// - **Dispatch**: a free lane takes the queued batch with the earliest
///   deadline, ties and deadline-less batches in arrival order (`edf`
///   off: strict arrival order). A batch whose start would reach its
///   deadline is shed and the lane stays free — equivalent to the live
///   dispatcher's expiry sweep, which resolves it at the moment it
///   comes due.
/// - **SLO controller**: evaluated at each admission (the live 5ms
///   control pass, quantized to arrivals): cumulative shed rate
///   (feedback) or the fraction of queued deadlines the estimate puts
///   at risk (feed-forward) above `slo` spawns a lane up to
///   `max_lanes_per_bucket`.
///
/// With `edf` off and `slo` unset this degenerates to
/// [`simulate_lanes_deadline`] bit-for-bit.
pub fn simulate_edf(
    buckets: &[EdfTraffic],
    host: HostProfile,
    device: GpuSpec,
    policy: &EdfSimPolicy,
) -> EdfSimResult {
    assert!(!buckets.is_empty(), "need at least one bucket trace");
    assert!(policy.max_lanes_per_bucket >= 1, "need at least one lane per bucket");
    let mut per_bucket = Vec::with_capacity(buckets.len());
    for trace in buckets {
        let service_s = simulate_tape(trace.tape, trace.costs, host, device.clone()).total_s;
        // Lane free times; index 0 is the seed lane.
        let mut lanes = vec![0.0f64];
        // Admitted, undispatched batches: (deadline, seq, arrival).
        let mut queue: Vec<(f64, usize, f64)> = Vec::new();
        let (mut completed, mut shed, mut admission_shed) = (0usize, 0usize, 0usize);
        let (mut spawned, mut lane_end_s) = (1usize, 0.0f64);
        // The estimate is 0 (cold, never sheds) until the first
        // completion lands, service_s afterwards (constant service makes
        // the live EWMA degenerate).
        let mut warm_at = f64::INFINITY;
        let est_at = |t: f64, warm_at: f64, queue: &[(f64, usize, f64)], lanes: &[f64]| {
            if t < warm_at {
                return 0.0;
            }
            let backlog = queue.len() + lanes.iter().filter(|&&f| f > t).count();
            service_s * (backlog as f64 / lanes.len() as f64 + 1.0)
        };
        // Dispatch every queued batch whose lane frees before `until`.
        let dispatch_until = |until: f64,
                              lanes: &mut Vec<f64>,
                              queue: &mut Vec<(f64, usize, f64)>,
                              completed: &mut usize,
                              shed: &mut usize,
                              warm_at: &mut f64,
                              lane_end_s: &mut f64| {
            loop {
                if queue.is_empty() {
                    break;
                }
                let li = (0..lanes.len()).min_by(|&a, &b| lanes[a].total_cmp(&lanes[b])).unwrap();
                if lanes[li] >= until {
                    break;
                }
                let qi = if policy.edf {
                    (0..queue.len())
                        .min_by(|&a, &b| {
                            (queue[a].0, queue[a].1).partial_cmp(&(queue[b].0, queue[b].1)).unwrap()
                        })
                        .unwrap()
                } else {
                    0 // arrival order: the queue is pushed in seq order
                };
                let (deadline, _seq, arrival) = queue.remove(qi);
                let start = lanes[li].max(arrival);
                if start >= deadline {
                    *shed += 1; // expired while queued; the lane stays free
                    continue;
                }
                let end = start + service_s;
                lanes[li] = end;
                *completed += 1;
                *warm_at = warm_at.min(end);
                *lane_end_s = lane_end_s.max(end);
            }
        };
        for (seq, &(arrival, deadline)) in trace.batches.iter().enumerate() {
            assert!(arrival >= 0.0, "arrivals must be non-negative");
            dispatch_until(
                arrival,
                &mut lanes,
                &mut queue,
                &mut completed,
                &mut shed,
                &mut warm_at,
                &mut lane_end_s,
            );
            let est = est_at(arrival, warm_at, &queue, &lanes);
            if policy.edf && (arrival >= deadline || arrival + est >= deadline) {
                shed += 1;
                admission_shed += 1;
            } else {
                queue.push((deadline, seq, arrival));
            }
            if let Some(target) = policy.slo {
                // The control pass, quantized to this arrival: feedback
                // is the cumulative shed rate, feed-forward the queued
                // deadlines the estimate already puts past due.
                let offered = completed + shed + queue.len();
                let feedback =
                    if offered == 0 { 0.0 } else { shed as f64 / offered as f64 };
                let est = est_at(arrival, warm_at, &queue, &lanes);
                let with_deadline =
                    queue.iter().filter(|&&(d, _, _)| d.is_finite()).count();
                let at_risk = queue
                    .iter()
                    .filter(|&&(d, _, _)| d.is_finite() && arrival + est >= d)
                    .count();
                let feedforward = if with_deadline == 0 {
                    0.0
                } else {
                    at_risk as f64 / with_deadline as f64
                };
                if (feedback > target || feedforward > target)
                    && lanes.len() < policy.max_lanes_per_bucket
                {
                    lanes.push(arrival);
                    spawned += 1;
                }
            }
        }
        dispatch_until(
            f64::INFINITY,
            &mut lanes,
            &mut queue,
            &mut completed,
            &mut shed,
            &mut warm_at,
            &mut lane_end_s,
        );
        per_bucket.push(EdfBucketStat {
            service_s,
            completed,
            shed,
            admission_shed,
            lanes_spawned: spawned,
            lane_end_s,
        });
    }
    let total_s = per_bucket.iter().fold(0.0f64, |a, b| a.max(b.lane_end_s));
    EdfSimResult { per_bucket, total_s }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::matching::MatchingAlgo;
    use crate::ops::GraphBuilder;
    use crate::sim::cost::kernel_cost;
    use crate::stream::rewrite::{rewrite, rewrite_single_stream};

    /// Two independent convs then a join — the paper's A/B/C example.
    /// Sized so each conv needs ~13 of 80 SMs: true concurrency is possible.
    fn branchy() -> crate::ops::OpGraph {
        let mut b = GraphBuilder::new();
        let x = b.input(&[1, 32, 28, 28]);
        let a = b.conv(x, 32, 3, 1);
        let c = b.conv(x, 32, 3, 1);
        let _ = b.add(a, c);
        b.finish()
    }

    fn costs(g: &crate::ops::OpGraph, dev: &GpuSpec) -> Vec<KernelCost> {
        (0..g.n_nodes()).map(|v| kernel_cost(g.node(v), dev)).collect()
    }

    #[test]
    fn tasks_respect_dependencies() {
        let g = branchy();
        let dev = GpuSpec::v100();
        let cs = costs(&g, &dev);
        let plan = rewrite(&g, MatchingAlgo::HopcroftKarp);
        let r = simulate(&SimConfig {
            plan: &plan,
            costs: &cs,
            host: HostProfile::pytorch(),
            device: dev,
        });
        let span = |n: usize| r.spans.iter().find(|s| s.node == n).unwrap();
        // add (node 3) starts after both convs end
        assert!(span(3).start_s >= span(1).end_s - 1e-12);
        assert!(span(3).start_s >= span(2).end_s - 1e-12);
    }

    #[test]
    fn multi_stream_overlaps_when_overhead_is_low() {
        let g = branchy();
        let dev = GpuSpec::v100();
        let cs = costs(&g, &dev);
        let plan = rewrite(&g, MatchingAlgo::HopcroftKarp);
        assert_eq!(plan.n_streams, 2);
        let r = simulate(&SimConfig {
            plan: &plan,
            costs: &cs,
            host: HostProfile::nimble(),
            device: dev,
        });
        let (a, b) = (
            r.spans.iter().find(|s| s.node == 1).unwrap(),
            r.spans.iter().find(|s| s.node == 2).unwrap(),
        );
        // the two convs overlap in time
        let overlap = a.end_s.min(b.end_s) - a.start_s.max(b.start_s);
        assert!(overlap > 0.0, "convs did not overlap: {a:?} {b:?}");
    }

    #[test]
    fn figure3_effect_high_overhead_serializes_streams() {
        // Same two-stream plan, but PyTorch-level scheduling overhead: the
        // second conv is submitted so late the first already finished.
        let mut b = GraphBuilder::new();
        let x = b.input(&[1, 16, 8, 8]); // tiny kernels (short durations)
        let a = b.conv(x, 16, 3, 1);
        let c = b.conv(x, 16, 3, 1);
        let _ = b.add(a, c);
        let g = b.finish();
        let dev = GpuSpec::v100();
        let cs = costs(&g, &dev);
        let plan = rewrite(&g, MatchingAlgo::HopcroftKarp);
        let r = simulate(&SimConfig {
            plan: &plan,
            costs: &cs,
            host: HostProfile::pytorch(),
            device: dev,
        });
        let (s1, s2) = (
            r.spans.iter().find(|s| s.node == 1).unwrap(),
            r.spans.iter().find(|s| s.node == 2).unwrap(),
        );
        let overlap = s1.end_s.min(s2.end_s) - s1.start_s.max(s2.start_s);
        assert!(overlap <= 0.0, "high overhead should kill overlap");
    }

    #[test]
    fn single_stream_never_overlaps() {
        let g = branchy();
        let dev = GpuSpec::v100();
        let cs = costs(&g, &dev);
        let plan = rewrite_single_stream(&g);
        let r = simulate(&SimConfig {
            plan: &plan,
            costs: &cs,
            host: HostProfile::nimble(),
            device: dev,
        });
        let mut spans: Vec<_> = r.spans.iter().filter(|s| s.duration() > 0.0).collect();
        spans.sort_by(|a, b| a.start_s.partial_cmp(&b.start_s).unwrap());
        for w in spans.windows(2) {
            assert!(w[1].start_s >= w[0].end_s - 1e-12);
        }
    }

    #[test]
    fn sm_capacity_limits_overlap() {
        // Two huge kernels on different streams: each demands all SMs, so
        // they must serialize even with zero host overhead.
        let mut b = GraphBuilder::new();
        let x = b.input(&[1, 256, 112, 112]);
        let a = b.conv(x, 256, 3, 1);
        let c = b.conv(x, 256, 3, 1);
        let _ = b.add(a, c);
        let g = b.finish();
        let dev = GpuSpec::v100();
        let cs = costs(&g, &dev);
        assert_eq!(cs[1].sm_demand, dev.sm_count);
        let plan = rewrite(&g, MatchingAlgo::HopcroftKarp);
        let r = simulate(&SimConfig {
            plan: &plan,
            costs: &cs,
            host: HostProfile::nimble(),
            device: dev,
        });
        let (s1, s2) = (
            r.spans.iter().find(|s| s.node == 1).unwrap(),
            r.spans.iter().find(|s| s.node == 2).unwrap(),
        );
        let overlap = s1.end_s.min(s2.end_s) - s1.start_s.max(s2.start_s);
        assert!(overlap <= 1e-12, "SM-saturating kernels must serialize");
    }

    #[test]
    fn lower_overhead_means_lower_latency() {
        let g = crate::models::build("mini_inception", 1);
        let dev = GpuSpec::v100();
        let cs = costs(&g, &dev);
        let plan = rewrite_single_stream(&g);
        let run = |host: HostProfile| {
            simulate(&SimConfig { plan: &plan, costs: &cs, host, device: dev.clone() }).total_s
        };
        let pt = run(HostProfile::pytorch());
        let nb = run(HostProfile::nimble());
        assert!(pt > 1.5 * nb, "pytorch {pt} vs nimble {nb}");
    }

    #[test]
    fn tape_simulation_matches_plan_simulation_exactly() {
        // The tape is a lossless re-encoding of the launch plan: the DES
        // must produce bit-identical spans through either route.
        for name in ["mini_inception", "inception_v3"] {
            let g = crate::models::build(name, 1);
            let dev = GpuSpec::v100();
            let cs = costs(&g, &dev);
            for plan in [rewrite(&g, MatchingAlgo::HopcroftKarp), rewrite_single_stream(&g)] {
                let tape = crate::aot::tape::ReplayTape::for_op_graph(&g, &plan, 64);
                let a = simulate(&SimConfig {
                    plan: &plan,
                    costs: &cs,
                    host: HostProfile::nimble(),
                    device: dev.clone(),
                });
                let b = simulate_tape(&tape, &cs, HostProfile::nimble(), dev.clone());
                assert_eq!(a.spans, b.spans, "{name}: spans diverged");
                assert_eq!(a.total_s.to_bits(), b.total_s.to_bits(), "{name}");
                assert_eq!(a.host_s.to_bits(), b.host_s.to_bits(), "{name}");
            }
        }
    }

    #[test]
    fn lanes_overlap_and_beat_the_serial_baseline() {
        // Two independent lanes of small kernels on a big device must
        // overlap almost perfectly: the joint makespan sits well under
        // the back-to-back baseline.
        let g = branchy();
        let dev = GpuSpec::v100();
        let cs = costs(&g, &dev);
        let plan = rewrite(&g, MatchingAlgo::HopcroftKarp);
        let tape = crate::aot::tape::ReplayTape::for_op_graph(&g, &plan, 64);
        let lanes = [
            LaneLoad { tape: &tape, costs: &cs, arrival_s: 0.0 },
            LaneLoad { tape: &tape, costs: &cs, arrival_s: 0.0 },
        ];
        let r = simulate_lanes(&lanes, HostProfile::nimble(), dev);
        assert_eq!(r.per_lane.len(), 2);
        assert!(r.total_s > 0.0);
        assert!(
            r.total_s < r.serial_total_s,
            "overlap {} vs serial {}",
            r.total_s,
            r.serial_total_s
        );
        assert!(r.overlap_speedup() > 1.2, "speedup {}", r.overlap_speedup());
        assert_eq!(r.completion_order.len(), 2 * plan.order.len());
    }

    #[test]
    fn single_lane_degenerates_to_the_plain_simulation() {
        let g = crate::models::build("mini_inception", 1);
        let dev = GpuSpec::v100();
        let cs = costs(&g, &dev);
        let plan = rewrite(&g, MatchingAlgo::HopcroftKarp);
        let tape = crate::aot::tape::ReplayTape::for_op_graph(&g, &plan, 64);
        let solo = simulate_tape(&tape, &cs, HostProfile::nimble(), dev.clone());
        let r = simulate_lanes(
            &[LaneLoad { tape: &tape, costs: &cs, arrival_s: 0.0 }],
            HostProfile::nimble(),
            dev,
        );
        assert!((r.total_s - solo.total_s).abs() < 1e-12, "{} vs {}", r.total_s, solo.total_s);
        assert!((r.serial_total_s - solo.total_s).abs() < 1e-12);
        assert!((r.overlap_speedup() - 1.0).abs() < 1e-9);
    }

    #[test]
    fn multi_lane_completion_trace_is_deterministic() {
        // Same lanes, same seed-free inputs: the completion-order trace
        // must be identical run to run (the determinism contract the
        // lane executor tests rely on).
        let g = branchy();
        let dev = GpuSpec::v100();
        let cs = costs(&g, &dev);
        let plan = rewrite(&g, MatchingAlgo::HopcroftKarp);
        let tape = crate::aot::tape::ReplayTape::for_op_graph(&g, &plan, 64);
        let mk = || {
            simulate_lanes(
                &[
                    LaneLoad { tape: &tape, costs: &cs, arrival_s: 0.0 },
                    LaneLoad { tape: &tape, costs: &cs, arrival_s: 1.0e-6 },
                    LaneLoad { tape: &tape, costs: &cs, arrival_s: 2.0e-6 },
                ],
                HostProfile::nimble(),
                dev.clone(),
            )
        };
        let (a, b) = (mk(), mk());
        assert_eq!(a.completion_order, b.completion_order);
        assert_eq!(a.total_s.to_bits(), b.total_s.to_bits());
        // A lane that arrives later can only finish later.
        assert!(a.lane_end_s[2] >= a.lane_end_s[0]);
    }

    #[test]
    fn des_peak_matches_the_serial_executors_measured_peak_exactly() {
        use crate::engine::executor::{ReplayContext, SyntheticKernel};
        let g = crate::models::build("mini_inception", 1);
        let dev = GpuSpec::v100();
        let cs = costs(&g, &dev);

        // Single stream: the simulator's execution order IS the merged
        // submission order the serial executor walks, so predicted and
        // measured peaks agree bit-for-bit.
        let tape =
            crate::aot::tape::ReplayTape::for_op_graph(&g, &rewrite_single_stream(&g), 64);
        let input = vec![0.5f32; tape.input_slots()[0].1];
        let mut ctx = ReplayContext::new(tape.clone(), SyntheticKernel);
        let sim = simulate_tape(&tape, &cs, HostProfile::nimble(), dev.clone());
        let predicted = peak_reserved_bytes(&tape, &sim.spans, &ctx.arena_plan().rounded_sizes);
        ctx.set_tracing(true);
        ctx.replay_serial(&[&input]).unwrap();
        assert_eq!(predicted, ctx.peak_live_bytes(), "single-stream peaks must match exactly");
        assert!(predicted > 0 && predicted <= ctx.reserved_bytes());

        // Multi stream: any legal schedule's live set is pairwise-
        // conflicting, so both peaks are bounded by the reservation.
        let tape = crate::aot::tape::ReplayTape::for_op_graph(
            &g,
            &rewrite(&g, MatchingAlgo::HopcroftKarp),
            64,
        );
        let input = vec![0.5f32; tape.input_slots()[0].1];
        let mut ctx = ReplayContext::new(tape.clone(), SyntheticKernel);
        let sim = simulate_tape(&tape, &cs, HostProfile::nimble(), dev.clone());
        let predicted = peak_reserved_bytes(&tape, &sim.spans, &ctx.arena_plan().rounded_sizes);
        assert!(predicted <= ctx.reserved_bytes(), "DES peak exceeds the reservation");
        ctx.set_tracing(true);
        ctx.replay_one(&input).unwrap();
        assert!(ctx.peak_live_bytes() <= ctx.reserved_bytes());
    }

    #[test]
    fn deadline_sim_with_infinite_budget_never_sheds() {
        let g = crate::models::build("mini_inception", 1);
        let dev = GpuSpec::v100();
        let cs = costs(&g, &dev);
        let plan = rewrite(&g, MatchingAlgo::HopcroftKarp);
        let tape = crate::aot::tape::ReplayTape::for_op_graph(&g, &plan, 64);
        let batches: Vec<(f64, f64)> = (0..6).map(|_| (0.0, f64::INFINITY)).collect();
        let r = simulate_lanes_deadline(
            &[LaneTraffic { tape: &tape, costs: &cs, batches: &batches }],
            HostProfile::nimble(),
            dev,
        );
        assert_eq!((r.completed(), r.shed()), (6, 0));
        let l = &r.per_lane[0];
        // Plain FIFO pipelining: makespan = n × service.
        assert!((l.lane_end_s - 6.0 * l.service_s).abs() < 1e-12);
    }

    #[test]
    fn deadline_sim_sheds_exactly_the_batches_past_their_budget() {
        let g = crate::models::build("mini_inception", 1);
        let dev = GpuSpec::v100();
        let cs = costs(&g, &dev);
        let plan = rewrite(&g, MatchingAlgo::HopcroftKarp);
        let tape = crate::aot::tape::ReplayTape::for_op_graph(&g, &plan, 64);
        let service = simulate_tape(&tape, &cs, HostProfile::nimble(), dev.clone()).total_s;
        // 8 batches arrive together with budget k×service: batch j
        // starts at j×service, so exactly min(8, k) are served.
        for k in [0usize, 1, 3, 8] {
            let batches: Vec<(f64, f64)> =
                (0..8).map(|_| (0.0, k as f64 * service)).collect();
            let r = simulate_lanes_deadline(
                &[LaneTraffic { tape: &tape, costs: &cs, batches: &batches }],
                HostProfile::nimble(),
                dev.clone(),
            );
            assert_eq!(r.completed(), k.min(8), "budget {k}x");
            assert_eq!(r.shed(), 8 - k.min(8), "budget {k}x");
            assert_eq!(r.completed() + r.shed(), 8, "accounting must close");
        }
        // A zero budget (deadline == arrival) sheds everything — the
        // live system's `deadline = now` behavior.
        let batches = [(0.0, 0.0), (1e-3, 1e-3)];
        let r = simulate_lanes_deadline(
            &[LaneTraffic { tape: &tape, costs: &cs, batches: &batches }],
            HostProfile::nimble(),
            dev,
        );
        assert_eq!((r.completed(), r.shed()), (0, 2));
        assert_eq!(r.per_lane[0].lane_end_s, 0.0, "a fully-shed lane never runs");
    }

    #[test]
    fn deadline_sim_is_deterministic_and_monotone_in_budget() {
        let g = branchy();
        let dev = GpuSpec::v100();
        let cs = costs(&g, &dev);
        let plan = rewrite(&g, MatchingAlgo::HopcroftKarp);
        let tape = crate::aot::tape::ReplayTape::for_op_graph(&g, &plan, 64);
        let service = simulate_tape(&tape, &cs, HostProfile::nimble(), dev.clone()).total_s;
        let mk = |budget_x: f64| {
            let batches: Vec<(f64, f64)> = (0..10)
                .map(|i| {
                    let arrival = i as f64 * 0.25 * service;
                    (arrival, arrival + budget_x * service)
                })
                .collect();
            simulate_lanes_deadline(
                &[LaneTraffic { tape: &tape, costs: &cs, batches: &batches }],
                HostProfile::nimble(),
                dev.clone(),
            )
        };
        let (a, b) = (mk(2.0), mk(2.0));
        assert_eq!(a.completed(), b.completed());
        assert_eq!(a.total_s.to_bits(), b.total_s.to_bits());
        // More budget can only shed fewer batches.
        let mut last = usize::MAX;
        for budget_x in [0.5, 1.5, 3.0, 8.0] {
            let shed = mk(budget_x).shed();
            assert!(shed <= last, "shed must be monotone non-increasing in budget");
            last = shed;
        }
    }

    #[test]
    fn fault_sim_with_a_noop_plan_matches_the_deadline_sim() {
        let g = crate::models::build("mini_inception", 1);
        let dev = GpuSpec::v100();
        let cs = costs(&g, &dev);
        let plan = rewrite(&g, MatchingAlgo::HopcroftKarp);
        let tape = crate::aot::tape::ReplayTape::for_op_graph(&g, &plan, 64);
        let service = simulate_tape(&tape, &cs, HostProfile::nimble(), dev.clone()).total_s;
        let batches: Vec<(f64, f64)> = (0..8)
            .map(|i| {
                let arrival = i as f64 * 0.5 * service;
                (arrival, arrival + 2.0 * service)
            })
            .collect();
        let base = simulate_lanes_deadline(
            &[LaneTraffic { tape: &tape, costs: &cs, batches: &batches }],
            HostProfile::nimble(),
            dev.clone(),
        );
        let chaos = simulate_faults(
            &[FaultTraffic {
                tape: &tape,
                costs: &cs,
                batches: &batches,
                plan: crate::fault::FaultPlan::seeded(9),
                max_retries: 3,
                backoff_s: 1e-4,
            }],
            HostProfile::nimble(),
            dev,
        );
        // FaultPlan::seeded has all-zero probabilities: no faults fire,
        // so the chaos sim degenerates to the deadline sim bit-for-bit.
        assert_eq!(chaos.completed(), base.completed());
        assert_eq!(chaos.shed(), base.shed());
        assert_eq!((chaos.failed(), chaos.retried()), (0, 0));
        assert_eq!(chaos.total_s.to_bits(), base.total_s.to_bits());
    }

    #[test]
    fn fault_sim_accounting_closes_and_is_deterministic() {
        let g = branchy();
        let dev = GpuSpec::v100();
        let cs = costs(&g, &dev);
        let plan = rewrite(&g, MatchingAlgo::HopcroftKarp);
        let tape = crate::aot::tape::ReplayTape::for_op_graph(&g, &plan, 64);
        let batches: Vec<(f64, f64)> = (0..24).map(|_| (0.0, f64::INFINITY)).collect();
        let mk = |seed: u64| {
            simulate_faults(
                &[FaultTraffic {
                    tape: &tape,
                    costs: &cs,
                    batches: &batches,
                    plan: crate::fault::FaultPlan {
                        engine_error: 0.5,
                        engine_panic: 0.1,
                        ..crate::fault::FaultPlan::seeded(seed)
                    },
                    max_retries: 2,
                    backoff_s: 5e-5,
                }],
                HostProfile::nimble(),
                dev.clone(),
            )
        };
        let mut any_retry = false;
        for seed in 0..8u64 {
            let (a, b) = (mk(seed), mk(seed));
            assert_eq!(
                a.completed() + a.failed() + a.shed(),
                24,
                "accounting must close (seed {seed})"
            );
            assert_eq!(a.shed(), 0, "infinite budgets never shed");
            assert_eq!(
                (a.completed(), a.failed(), a.retried()),
                (b.completed(), b.failed(), b.retried()),
                "seeded chaos must be deterministic (seed {seed})"
            );
            assert_eq!(a.total_s.to_bits(), b.total_s.to_bits());
            any_retry |= a.retried() > 0;
        }
        assert!(any_retry, "a 60% fault rate over 24 batches must retry somewhere");
    }

    #[test]
    fn fault_sim_certain_faults_exhaust_the_retry_budget() {
        let g = branchy();
        let dev = GpuSpec::v100();
        let cs = costs(&g, &dev);
        let plan = rewrite(&g, MatchingAlgo::HopcroftKarp);
        let tape = crate::aot::tape::ReplayTape::for_op_graph(&g, &plan, 64);
        let batches: Vec<(f64, f64)> = (0..5).map(|_| (0.0, f64::INFINITY)).collect();
        for max_retries in [0u32, 2] {
            let r = simulate_faults(
                &[FaultTraffic {
                    tape: &tape,
                    costs: &cs,
                    batches: &batches,
                    plan: crate::fault::FaultPlan {
                        engine_error: 1.0,
                        ..crate::fault::FaultPlan::seeded(1)
                    },
                    max_retries,
                    backoff_s: 1e-4,
                }],
                HostProfile::nimble(),
                dev.clone(),
            );
            assert_eq!(r.completed(), 0, "certain faults never complete");
            assert_eq!(r.failed(), 5);
            assert_eq!(r.retried(), 5 * max_retries as usize);
            // Faulted attempts bail before the engine runs: only the
            // backoffs advance the lane clock.
            let expected_end = 5.0 * max_retries as f64 * 1e-4;
            assert!((r.per_lane[0].lane_end_s - expected_end).abs() < 1e-12);
        }
    }

    #[test]
    fn fault_sim_stops_retrying_at_the_deadline() {
        let g = branchy();
        let dev = GpuSpec::v100();
        let cs = costs(&g, &dev);
        let plan = rewrite(&g, MatchingAlgo::HopcroftKarp);
        let tape = crate::aot::tape::ReplayTape::for_op_graph(&g, &plan, 64);
        // Certain faults and a deadline that admits exactly two
        // backoffs: the third retry would start past the deadline, so
        // the batch fails after two retries despite the roomy budget.
        let batches = [(0.0, 2.5e-4)];
        let r = simulate_faults(
            &[FaultTraffic {
                tape: &tape,
                costs: &cs,
                batches: &batches,
                plan: crate::fault::FaultPlan {
                    engine_error: 1.0,
                    ..crate::fault::FaultPlan::seeded(4)
                },
                max_retries: 10,
                backoff_s: 1e-4,
            }],
            HostProfile::nimble(),
            dev,
        );
        assert_eq!((r.completed(), r.failed(), r.retried(), r.shed()), (0, 1, 2, 0));
    }

    #[test]
    fn scaling_sim_with_one_lane_degenerates_to_the_static_schedule() {
        let g = crate::models::build("mini_inception", 1);
        let dev = GpuSpec::v100();
        let cs = costs(&g, &dev);
        let plan = rewrite(&g, MatchingAlgo::HopcroftKarp);
        let tape = crate::aot::tape::ReplayTape::for_op_graph(&g, &plan, 64);
        let arrivals = [0.0, 1e-6, 2e-6, 3e-6];
        let r = simulate_scaling(
            &[ScalingTrace { tape: &tape, costs: &cs, arrivals_s: &arrivals }],
            HostProfile::nimble(),
            dev,
            &ScaleSimPolicy { max_lanes_per_bucket: 1, idle_retire_s: 1e-3, scale_up_backlog: 1 },
        );
        assert_eq!(r.per_bucket.len(), 1);
        let b = &r.per_bucket[0];
        assert_eq!((b.peak_lanes, b.lanes_spawned, b.lanes_retired), (1, 1, 0));
        assert_eq!(
            b.elastic_end_s.to_bits(),
            b.static_end_s.to_bits(),
            "a capped-at-one policy IS the static schedule"
        );
        assert!((r.scaling_speedup() - 1.0).abs() < 1e-12);
    }

    #[test]
    fn scaling_sim_spawns_for_bursts_and_retires_after_them() {
        let g = crate::models::build("mini_inception", 1);
        let dev = GpuSpec::v100();
        let cs = costs(&g, &dev);
        let plan = rewrite(&g, MatchingAlgo::HopcroftKarp);
        let tape = crate::aot::tape::ReplayTape::for_op_graph(&g, &plan, 64);
        let service = simulate_tape(&tape, &cs, HostProfile::nimble(), dev.clone()).total_s;
        // A burst of 6 simultaneous batches, a long gap, then a small
        // second burst.
        let late = 100.0 * service;
        let arrivals = [0.0, 0.0, 0.0, 0.0, 0.0, 0.0, late, late];
        let policy =
            ScaleSimPolicy { max_lanes_per_bucket: 3, idle_retire_s: service, scale_up_backlog: 1 };
        let r = simulate_scaling(
            &[ScalingTrace { tape: &tape, costs: &cs, arrivals_s: &arrivals }],
            HostProfile::nimble(),
            dev,
            &policy,
        );
        let b = &r.per_bucket[0];
        assert_eq!(b.peak_lanes, 3, "the first burst must scale to the cap");
        assert_eq!(b.lanes_spawned, 4, "both bursts spawn (the gap retired the first extras)");
        assert_eq!(
            b.lanes_retired, 3,
            "the gap retires the first burst's lanes; wind-down retires the second's"
        );
        assert!(
            b.elastic_end_s < b.static_end_s,
            "elastic {} must beat static {}",
            b.elastic_end_s,
            b.static_end_s
        );
        assert!(r.scaling_speedup() > 1.0);
        // Elastic lane-seconds undercut provisioning every bucket at the
        // peak for the whole static makespan.
        assert!(r.elastic_lane_alive_s() < 3.0 * r.static_total_s);
    }

    #[test]
    fn scaling_sim_is_deterministic() {
        let g = branchy();
        let dev = GpuSpec::v100();
        let cs = costs(&g, &dev);
        let plan = rewrite(&g, MatchingAlgo::HopcroftKarp);
        let tape = crate::aot::tape::ReplayTape::for_op_graph(&g, &plan, 64);
        let arrivals_a = [0.0, 0.0, 1e-5];
        let arrivals_b = [5e-6, 6e-6];
        let mk = || {
            simulate_scaling(
                &[
                    ScalingTrace { tape: &tape, costs: &cs, arrivals_s: &arrivals_a },
                    ScalingTrace { tape: &tape, costs: &cs, arrivals_s: &arrivals_b },
                ],
                HostProfile::nimble(),
                dev.clone(),
                &ScaleSimPolicy {
                    max_lanes_per_bucket: 2,
                    idle_retire_s: 1e-4,
                    scale_up_backlog: 1,
                },
            )
        };
        let (a, b) = (mk(), mk());
        assert_eq!(a.elastic_total_s.to_bits(), b.elastic_total_s.to_bits());
        assert_eq!(a.static_total_s.to_bits(), b.static_total_s.to_bits());
        assert_eq!(a.lanes_spawned(), b.lanes_spawned());
        assert_eq!(a.lanes_retired(), b.lanes_retired());
    }

    #[test]
    fn edf_sim_with_edf_off_degenerates_to_the_deadline_sim() {
        let g = crate::models::build("mini_inception", 1);
        let dev = GpuSpec::v100();
        let cs = costs(&g, &dev);
        let plan = rewrite(&g, MatchingAlgo::HopcroftKarp);
        let tape = crate::aot::tape::ReplayTape::for_op_graph(&g, &plan, 64);
        let service = simulate_tape(&tape, &cs, HostProfile::nimble(), dev.clone()).total_s;
        let batches: Vec<(f64, f64)> = (0..10)
            .map(|i| {
                let arrival = i as f64 * 0.4 * service;
                (arrival, arrival + 1.7 * service)
            })
            .collect();
        let base = simulate_lanes_deadline(
            &[LaneTraffic { tape: &tape, costs: &cs, batches: &batches }],
            HostProfile::nimble(),
            dev.clone(),
        );
        let r = simulate_edf(
            &[EdfTraffic { tape: &tape, costs: &cs, batches: &batches }],
            HostProfile::nimble(),
            dev,
            &EdfSimPolicy { edf: false, slo: None, max_lanes_per_bucket: 1 },
        );
        assert_eq!(r.completed(), base.completed());
        assert_eq!(r.shed(), base.shed());
        assert_eq!(r.admission_shed(), 0, "FIFO mode has no admission estimate");
        assert_eq!(r.lanes_spawned(), 1);
        assert_eq!(
            r.total_s.to_bits(),
            base.total_s.to_bits(),
            "edf(false) must be the FIFO deadline sim bit-for-bit"
        );
    }

    #[test]
    fn edf_order_completes_tight_budgets_fifo_loses() {
        // A lax (deadline-less) batch arrives just before a tight one.
        // FIFO serves the lax batch first and the tight one misses;
        // EDF reorders and completes both. The estimate is cold (no
        // completion yet at admission), so admission shedding stays out
        // of the way in both modes.
        let g = crate::models::build("mini_inception", 1);
        let dev = GpuSpec::v100();
        let cs = costs(&g, &dev);
        let plan = rewrite(&g, MatchingAlgo::HopcroftKarp);
        let tape = crate::aot::tape::ReplayTape::for_op_graph(&g, &plan, 64);
        let service = simulate_tape(&tape, &cs, HostProfile::nimble(), dev.clone()).total_s;
        let batches = [(0.0, f64::INFINITY), (0.0, 0.9 * service)];
        let run = |edf: bool| {
            simulate_edf(
                &[EdfTraffic { tape: &tape, costs: &cs, batches: &batches }],
                HostProfile::nimble(),
                dev.clone(),
                &EdfSimPolicy { edf, slo: None, max_lanes_per_bucket: 1 },
            )
        };
        let fifo = run(false);
        let edf = run(true);
        assert_eq!((fifo.completed(), fifo.shed()), (1, 1), "FIFO sheds the tight batch");
        assert_eq!((edf.completed(), edf.shed()), (2, 0), "EDF completes both");
        // Deterministic: same inputs, same bits.
        assert_eq!(run(true).total_s.to_bits(), edf.total_s.to_bits());
    }

    #[test]
    fn edf_sim_sheds_doomed_budgets_at_admission_once_warm() {
        let g = crate::models::build("mini_inception", 1);
        let dev = GpuSpec::v100();
        let cs = costs(&g, &dev);
        let plan = rewrite(&g, MatchingAlgo::HopcroftKarp);
        let tape = crate::aot::tape::ReplayTape::for_op_graph(&g, &plan, 64);
        let service = simulate_tape(&tape, &cs, HostProfile::nimble(), dev.clone()).total_s;
        // Expired at the door: sheds at admission even on a cold server.
        let batches = [(0.5 * service, 0.5 * service)];
        let r = simulate_edf(
            &[EdfTraffic { tape: &tape, costs: &cs, batches: &batches }],
            HostProfile::nimble(),
            dev.clone(),
            &EdfSimPolicy { edf: true, slo: None, max_lanes_per_bucket: 1 },
        );
        assert_eq!((r.completed(), r.shed(), r.admission_shed()), (0, 1, 1));
        // Warm estimate: after the first batch completes, a budget under
        // one service time sheds at admission; an infinite budget never
        // does. Accounting closes either way.
        let batches =
            [(0.0, f64::INFINITY), (2.0 * service, 2.0 * service + 0.5 * service)];
        let r = simulate_edf(
            &[EdfTraffic { tape: &tape, costs: &cs, batches: &batches }],
            HostProfile::nimble(),
            dev.clone(),
            &EdfSimPolicy { edf: true, slo: None, max_lanes_per_bucket: 1 },
        );
        assert_eq!((r.completed(), r.shed(), r.admission_shed()), (1, 1, 1));
        assert_eq!(r.completed() + r.shed(), 2, "every batch lands in exactly one count");
    }

    #[test]
    fn edf_sim_slo_controller_spawns_lanes_and_saves_work() {
        let g = crate::models::build("mini_inception", 1);
        let dev = GpuSpec::v100();
        let cs = costs(&g, &dev);
        let plan = rewrite(&g, MatchingAlgo::HopcroftKarp);
        let tape = crate::aot::tape::ReplayTape::for_op_graph(&g, &plan, 64);
        let service = simulate_tape(&tape, &cs, HostProfile::nimble(), dev.clone()).total_s;
        // Warm-up, then a burst whose tail misses on one lane.
        let burst = 2.0 * service;
        let batches: Vec<(f64, f64)> = std::iter::once((0.0, f64::INFINITY))
            .chain((0..4).map(|_| (burst, burst + 2.2 * service)))
            .collect();
        let run = |slo: Option<f64>| {
            simulate_edf(
                &[EdfTraffic { tape: &tape, costs: &cs, batches: &batches }],
                HostProfile::nimble(),
                dev.clone(),
                &EdfSimPolicy { edf: true, slo, max_lanes_per_bucket: 3 },
            )
        };
        let off = run(None);
        let on = run(Some(0.05));
        assert_eq!(off.lanes_spawned(), 1, "no controller, no spawns");
        assert!(off.shed() > 0, "one lane must miss part of the burst");
        assert!(
            on.lanes_spawned() > 1,
            "breaching the target must force-spawn (spawned {})",
            on.lanes_spawned()
        );
        assert!(
            on.completed() > off.completed(),
            "extra lanes must convert sheds into completions ({} vs {})",
            on.completed(),
            off.completed()
        );
        assert_eq!(on.completed() + on.shed(), batches.len(), "accounting closes");
        assert!(on.shed_rate() < off.shed_rate());
    }

    #[test]
    fn active_ratio_between_zero_and_one() {
        let g = crate::models::build("mini_inception", 1);
        let dev = GpuSpec::v100();
        let cs = costs(&g, &dev);
        let plan = rewrite(&g, MatchingAlgo::HopcroftKarp);
        let r = simulate(&SimConfig {
            plan: &plan,
            costs: &cs,
            host: HostProfile::pytorch(),
            device: dev,
        });
        assert!(r.active_ratio() > 0.0 && r.active_ratio() <= 1.0);
    }
}
