//! VGPU: a discrete-event simulator of a multi-stream GPU plus the host
//! scheduling loop of a DL framework.
//!
//! This is the substrate substitution documented in DESIGN.md — the paper's
//! V100/CUDA testbed replaced by a device model + DES that reproduces the
//! *scheduling-level* quantities the paper measures: per-task host overhead
//! gating submission (Fig. 3), stream FIFO semantics, event-based
//! cross-stream synchronization, SM-capacity-bounded kernel overlap, GPU
//! active time (Fig. 2a), and critical-path time (Fig. 2c).

pub mod cluster;
pub mod cost;
pub mod des;
pub mod device;
pub mod framework;
pub mod metrics;
pub mod trace;

pub use cluster::{
    simulate_cluster, ClusterSimPolicy, ClusterSimResult, ClusterTraffic, ReplicaSimStat,
};
pub use cost::{kernel_cost, CostEntry, CostProfile, KernelCost};
pub use des::{
    peak_reserved_bytes, simulate, simulate_edf, simulate_faults, simulate_lanes,
    simulate_lanes_deadline, simulate_scaling, simulate_tape, BucketScaling, DeadlineLaneStat,
    DeadlineShedResult, EdfBucketStat, EdfSimPolicy, EdfSimResult, EdfTraffic, FaultLaneStat,
    FaultSimResult, FaultTraffic, LaneLoad, LaneTraffic, MultiLaneResult, ScaleSimPolicy,
    ScalingResult, ScalingTrace, SimConfig, SimResult, TaskSpan,
};
pub use device::GpuSpec;
pub use framework::HostProfile;
