//! Analytic kernel cost model (roofline + occupancy).
//!
//! For each operator the model predicts:
//! * **duration** — `max(flops / (peak·eff), bytes / bw) + kernel_fixed`,
//!   where the compute efficiency `eff` saturates with kernel size (small
//!   kernels cannot fill the machine — the reason Fig. 2's networks are
//!   launch-bound) and depends on op class (dense conv/matmul hit the MXU/
//!   TensorCore-class units; depthwise and elementwise ops are bandwidth-
//!   bound).
//! * **sm_demand** — SMs the kernel occupies, from output elements vs
//!   resident threads. Big kernels occupy the whole device, which is what
//!   limits multi-stream gains on NASNet-A (large) in Table 1.

use super::device::GpuSpec;
use crate::ops::{Op, OpGraph, OpKind};
use crate::util::json::{escape_json, parse_json, JsonValue};

/// Cost of one operator on a device.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct KernelCost {
    /// Kernel duration in seconds (device-side, including fixed overhead).
    pub duration_s: f64,
    /// SMs occupied while running.
    pub sm_demand: usize,
}

/// Peak-efficiency ceiling per op class.
fn eff_ceiling(kind: &OpKind) -> f64 {
    match kind {
        OpKind::Conv2d { groups, .. } if *groups > 1 => 0.08, // depthwise: BW-bound
        OpKind::Conv2d { .. } => 0.35,
        OpKind::Linear | OpKind::MatMul => 0.55,
        OpKind::Fused { parts } => {
            parts.iter().map(eff_ceiling).fold(0.05_f64, f64::max)
        }
        OpKind::Grad { of } => eff_ceiling(of) * 0.9, // bwd kernels slightly worse
        _ => 0.10, // elementwise / pool / norm: compute is never the limiting factor
    }
}

/// Efficiency saturation with size: eff = ceil · x/(x+K). K chosen so a
/// ~100 MFLOP kernel reaches ~80% of its ceiling (fits V100 microbenchmarks
/// of cuDNN conv efficiency vs problem size).
fn efficiency(kind: &OpKind, flops: u64) -> f64 {
    const K: f64 = 1.2e7;
    let x = flops as f64;
    eff_ceiling(kind) * (x / (x + K))
}

/// Compute the cost of an op on a device. Virtual ops cost nothing.
pub fn kernel_cost(op: &Op, dev: &GpuSpec) -> KernelCost {
    if op.kind.is_virtual() {
        return KernelCost { duration_s: 0.0, sm_demand: 0 };
    }
    let eff = efficiency(&op.kind, op.flops);
    let t_compute = if op.flops == 0 {
        0.0
    } else {
        op.flops as f64 / (dev.peak_tflops * 1e12 * eff)
    };
    let t_mem = op.bytes as f64 / (dev.mem_bw_gbps * 1e9);
    let duration_s = t_compute.max(t_mem) + dev.kernel_fixed_s;
    // Occupancy: one thread per output element, `threads_per_sm` resident.
    let threads = op.out_shape.numel().max(1);
    let sm_demand = threads.div_ceil(dev.threads_per_sm).clamp(1, dev.sm_count);
    KernelCost { duration_s, sm_demand }
}

/// Apply a per-class duration multiplier (TVM's tuned kernels, Nimble's
/// cuDNN-vs-native kernel selection). Only matmul-like kernels are tunable;
/// memory-bound ops are already at the bandwidth roofline.
pub fn scaled_cost(op: &Op, dev: &GpuSpec, matmul_scale: f64) -> KernelCost {
    let mut c = kernel_cost(op, dev);
    if op.kind.is_matmul_like() {
        // Scale only the roofline part, not the fixed kernel overhead.
        let var = (c.duration_s - dev.kernel_fixed_s).max(0.0);
        c.duration_s = var * matmul_scale + dev.kernel_fixed_s;
    }
    c
}

/// One measured per-op timing entry: aggregate statistics over every
/// recorded replay span that carried this op label.
#[derive(Debug, Clone, PartialEq)]
pub struct CostEntry {
    pub name: String,
    /// Spans aggregated into this entry.
    pub count: u64,
    pub mean_s: f64,
    pub p50_s: f64,
    pub p95_s: f64,
}

/// A calibration profile: measured per-op durations (from the
/// telemetry flight recorder, or any other source) that override the
/// analytic model where data exists. This is the measured input
/// ROADMAP item 4's contention-aware cost model consumes.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct CostProfile {
    pub entries: Vec<CostEntry>,
}

impl CostProfile {
    /// Measured mean duration for an op name, if this profile saw it.
    pub fn duration_for(&self, name: &str) -> Option<f64> {
        self.entries.iter().find(|e| e.name == name && e.count > 0).map(|e| e.mean_s)
    }

    /// Per-node costs for a graph: measured mean where the profile has
    /// the op's name, analytic [`kernel_cost`] otherwise. The analytic
    /// `sm_demand` is kept either way — the profile measures time, not
    /// occupancy. The result feeds `sim::simulate_tape` directly.
    pub fn costs_for_graph(&self, g: &OpGraph, dev: &GpuSpec) -> Vec<KernelCost> {
        (0..g.n_nodes())
            .map(|v| {
                let op = g.node(v);
                let mut c = kernel_cost(op, dev);
                if !op.kind.is_virtual() {
                    if let Some(measured) = self.duration_for(&op.name) {
                        c.duration_s = measured;
                    }
                }
                c
            })
            .collect()
    }

    /// Serialize as a versioned JSON document.
    pub fn to_json(&self) -> String {
        let mut out = String::from("{\n  \"version\": 1,\n  \"entries\": [\n");
        for (i, e) in self.entries.iter().enumerate() {
            if i > 0 {
                out.push_str(",\n");
            }
            out.push_str(&format!(
                "    {{\"name\": \"{}\", \"count\": {}, \"mean_s\": {:e}, \
                 \"p50_s\": {:e}, \"p95_s\": {:e}}}",
                escape_json(&e.name),
                e.count,
                e.mean_s,
                e.p50_s,
                e.p95_s,
            ));
        }
        out.push_str("\n  ]\n}\n");
        out
    }

    /// Parse a profile produced by [`CostProfile::to_json`].
    pub fn from_json(s: &str) -> Result<CostProfile, String> {
        let doc = parse_json(s).map_err(|e| format!("cost profile: {e}"))?;
        let entries = doc
            .get("entries")
            .and_then(JsonValue::as_array)
            .ok_or("cost profile: missing \"entries\" array")?;
        let mut out = Vec::with_capacity(entries.len());
        for (i, e) in entries.iter().enumerate() {
            let field = |k: &str| {
                e.get(k)
                    .and_then(JsonValue::as_f64)
                    .ok_or_else(|| format!("cost profile entry {i}: missing \"{k}\""))
            };
            out.push(CostEntry {
                name: e
                    .get("name")
                    .and_then(JsonValue::as_str)
                    .ok_or_else(|| format!("cost profile entry {i}: missing \"name\""))?
                    .to_string(),
                count: field("count")? as u64,
                mean_s: field("mean_s")?,
                p50_s: field("p50_s")?,
                p95_s: field("p95_s")?,
            });
        }
        Ok(CostProfile { entries: out })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ops::{GraphBuilder, Shape};

    fn conv_op(c_out: usize, hw: usize) -> Op {
        let mut b = GraphBuilder::new();
        let x = b.input(&[1, 64, hw, hw]);
        let c = b.conv(x, c_out, 3, 1);
        b.finish().node(c).clone()
    }

    #[test]
    fn bigger_kernels_run_longer() {
        let d = GpuSpec::v100();
        let small = kernel_cost(&conv_op(64, 7), &d);
        let big = kernel_cost(&conv_op(64, 56), &d);
        assert!(big.duration_s > small.duration_s * 5.0);
    }

    #[test]
    fn tiny_kernels_dominated_by_fixed_cost() {
        let d = GpuSpec::v100();
        let tiny = kernel_cost(&conv_op(8, 4), &d);
        assert!(tiny.duration_s < 4.0 * d.kernel_fixed_s, "t={}", tiny.duration_s);
    }

    #[test]
    fn big_kernel_fills_the_device() {
        let d = GpuSpec::v100();
        let big = kernel_cost(&conv_op(256, 56), &d);
        assert_eq!(big.sm_demand, d.sm_count);
        let small = kernel_cost(&conv_op(8, 4), &d);
        assert!(small.sm_demand < d.sm_count / 4);
    }

    #[test]
    fn virtual_ops_are_free() {
        let op = Op::virtual_op("x", OpKind::Input, Shape::new(&[1, 3, 224, 224]));
        let c = kernel_cost(&op, &GpuSpec::v100());
        assert_eq!(c.duration_s, 0.0);
        assert_eq!(c.sm_demand, 0);
    }

    #[test]
    fn efficiency_saturates() {
        let k = OpKind::Conv2d { kernel: (3, 3), stride: 1, groups: 1 };
        assert!(efficiency(&k, 1_000) < 0.01);
        let big = efficiency(&k, 10_000_000_000);
        assert!(big > 0.33 && big < 0.35);
    }

    #[test]
    fn tuned_kernels_scale_only_variable_part() {
        let d = GpuSpec::v100();
        let op = conv_op(256, 56);
        let base = kernel_cost(&op, &d);
        let tuned = scaled_cost(&op, &d, 0.5);
        assert!(tuned.duration_s < base.duration_s);
        assert!(tuned.duration_s > base.duration_s * 0.45);
        // memory-bound op unaffected
        let mut b = GraphBuilder::new();
        let x = b.input(&[1, 64, 56, 56]);
        let r = b.relu(x);
        let relu = b.finish().node(r).clone();
        assert_eq!(
            scaled_cost(&relu, &d, 0.5).duration_s,
            kernel_cost(&relu, &d).duration_s
        );
    }

    #[test]
    fn slower_device_slower_kernels() {
        let op = conv_op(128, 28);
        let v = kernel_cost(&op, &GpuSpec::v100());
        let xp = kernel_cost(&op, &GpuSpec::titan_xp());
        assert!(xp.duration_s > v.duration_s);
    }

    #[test]
    fn cost_profile_json_round_trips() {
        let profile = CostProfile {
            entries: vec![
                CostEntry {
                    name: "conv\"weird\\name".into(),
                    count: 12,
                    mean_s: 1.25e-6,
                    p50_s: 1.0e-6,
                    p95_s: 3.5e-6,
                },
                CostEntry { name: "relu_1".into(), count: 3, mean_s: 4e-7, p50_s: 4e-7, p95_s: 5e-7 },
            ],
        };
        let back = CostProfile::from_json(&profile.to_json()).expect("round trip");
        assert_eq!(back.entries.len(), 2);
        assert_eq!(back.entries[0].name, "conv\"weird\\name");
        assert_eq!(back.entries[0].count, 12);
        assert!((back.entries[0].mean_s - 1.25e-6).abs() < 1e-18);
        assert_eq!(back.duration_for("relu_1"), Some(4e-7));
        assert_eq!(back.duration_for("missing"), None);
    }

    #[test]
    fn measured_profile_overrides_analytic_durations_only() {
        let d = GpuSpec::v100();
        let mut b = GraphBuilder::new();
        let x = b.input(&[1, 64, 14, 14]);
        let c = b.conv(x, 64, 3, 1);
        let _ = b.relu(c);
        let g = b.finish();
        let conv_name = g.node(c).name.clone();
        let profile = CostProfile {
            entries: vec![CostEntry {
                name: conv_name.clone(),
                count: 5,
                mean_s: 42e-6,
                p50_s: 40e-6,
                p95_s: 50e-6,
            }],
        };
        let analytic: Vec<_> = (0..g.n_nodes()).map(|v| kernel_cost(g.node(v), &d)).collect();
        let calibrated = profile.costs_for_graph(&g, &d);
        assert_eq!(calibrated.len(), analytic.len());
        for v in 0..g.n_nodes() {
            // Occupancy always stays analytic.
            assert_eq!(calibrated[v].sm_demand, analytic[v].sm_demand);
            if g.node(v).name == conv_name {
                assert_eq!(calibrated[v].duration_s, 42e-6);
            } else {
                assert_eq!(calibrated[v].duration_s, analytic[v].duration_s);
            }
        }
    }
}
