//! Analytic kernel cost model (roofline + occupancy).
//!
//! For each operator the model predicts:
//! * **duration** — `max(flops / (peak·eff), bytes / bw) + kernel_fixed`,
//!   where the compute efficiency `eff` saturates with kernel size (small
//!   kernels cannot fill the machine — the reason Fig. 2's networks are
//!   launch-bound) and depends on op class (dense conv/matmul hit the MXU/
//!   TensorCore-class units; depthwise and elementwise ops are bandwidth-
//!   bound).
//! * **sm_demand** — SMs the kernel occupies, from output elements vs
//!   resident threads. Big kernels occupy the whole device, which is what
//!   limits multi-stream gains on NASNet-A (large) in Table 1.

use super::device::GpuSpec;
use crate::ops::{Op, OpKind};

/// Cost of one operator on a device.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct KernelCost {
    /// Kernel duration in seconds (device-side, including fixed overhead).
    pub duration_s: f64,
    /// SMs occupied while running.
    pub sm_demand: usize,
}

/// Peak-efficiency ceiling per op class.
fn eff_ceiling(kind: &OpKind) -> f64 {
    match kind {
        OpKind::Conv2d { groups, .. } if *groups > 1 => 0.08, // depthwise: BW-bound
        OpKind::Conv2d { .. } => 0.35,
        OpKind::Linear | OpKind::MatMul => 0.55,
        OpKind::Fused { parts } => {
            parts.iter().map(eff_ceiling).fold(0.05_f64, f64::max)
        }
        OpKind::Grad { of } => eff_ceiling(of) * 0.9, // bwd kernels slightly worse
        _ => 0.10, // elementwise / pool / norm: compute is never the limiting factor
    }
}

/// Efficiency saturation with size: eff = ceil · x/(x+K). K chosen so a
/// ~100 MFLOP kernel reaches ~80% of its ceiling (fits V100 microbenchmarks
/// of cuDNN conv efficiency vs problem size).
fn efficiency(kind: &OpKind, flops: u64) -> f64 {
    const K: f64 = 1.2e7;
    let x = flops as f64;
    eff_ceiling(kind) * (x / (x + K))
}

/// Compute the cost of an op on a device. Virtual ops cost nothing.
pub fn kernel_cost(op: &Op, dev: &GpuSpec) -> KernelCost {
    if op.kind.is_virtual() {
        return KernelCost { duration_s: 0.0, sm_demand: 0 };
    }
    let eff = efficiency(&op.kind, op.flops);
    let t_compute = if op.flops == 0 {
        0.0
    } else {
        op.flops as f64 / (dev.peak_tflops * 1e12 * eff)
    };
    let t_mem = op.bytes as f64 / (dev.mem_bw_gbps * 1e9);
    let duration_s = t_compute.max(t_mem) + dev.kernel_fixed_s;
    // Occupancy: one thread per output element, `threads_per_sm` resident.
    let threads = op.out_shape.numel().max(1);
    let sm_demand = threads.div_ceil(dev.threads_per_sm).clamp(1, dev.sm_count);
    KernelCost { duration_s, sm_demand }
}

/// Apply a per-class duration multiplier (TVM's tuned kernels, Nimble's
/// cuDNN-vs-native kernel selection). Only matmul-like kernels are tunable;
/// memory-bound ops are already at the bandwidth roofline.
pub fn scaled_cost(op: &Op, dev: &GpuSpec, matmul_scale: f64) -> KernelCost {
    let mut c = kernel_cost(op, dev);
    if op.kind.is_matmul_like() {
        // Scale only the roofline part, not the fixed kernel overhead.
        let var = (c.duration_s - dev.kernel_fixed_s).max(0.0);
        c.duration_s = var * matmul_scale + dev.kernel_fixed_s;
    }
    c
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ops::{GraphBuilder, Shape};

    fn conv_op(c_out: usize, hw: usize) -> Op {
        let mut b = GraphBuilder::new();
        let x = b.input(&[1, 64, hw, hw]);
        let c = b.conv(x, c_out, 3, 1);
        b.finish().node(c).clone()
    }

    #[test]
    fn bigger_kernels_run_longer() {
        let d = GpuSpec::v100();
        let small = kernel_cost(&conv_op(64, 7), &d);
        let big = kernel_cost(&conv_op(64, 56), &d);
        assert!(big.duration_s > small.duration_s * 5.0);
    }

    #[test]
    fn tiny_kernels_dominated_by_fixed_cost() {
        let d = GpuSpec::v100();
        let tiny = kernel_cost(&conv_op(8, 4), &d);
        assert!(tiny.duration_s < 4.0 * d.kernel_fixed_s, "t={}", tiny.duration_s);
    }

    #[test]
    fn big_kernel_fills_the_device() {
        let d = GpuSpec::v100();
        let big = kernel_cost(&conv_op(256, 56), &d);
        assert_eq!(big.sm_demand, d.sm_count);
        let small = kernel_cost(&conv_op(8, 4), &d);
        assert!(small.sm_demand < d.sm_count / 4);
    }

    #[test]
    fn virtual_ops_are_free() {
        let op = Op::virtual_op("x", OpKind::Input, Shape::new(&[1, 3, 224, 224]));
        let c = kernel_cost(&op, &GpuSpec::v100());
        assert_eq!(c.duration_s, 0.0);
        assert_eq!(c.sm_demand, 0);
    }

    #[test]
    fn efficiency_saturates() {
        let k = OpKind::Conv2d { kernel: (3, 3), stride: 1, groups: 1 };
        assert!(efficiency(&k, 1_000) < 0.01);
        let big = efficiency(&k, 10_000_000_000);
        assert!(big > 0.33 && big < 0.35);
    }

    #[test]
    fn tuned_kernels_scale_only_variable_part() {
        let d = GpuSpec::v100();
        let op = conv_op(256, 56);
        let base = kernel_cost(&op, &d);
        let tuned = scaled_cost(&op, &d, 0.5);
        assert!(tuned.duration_s < base.duration_s);
        assert!(tuned.duration_s > base.duration_s * 0.45);
        // memory-bound op unaffected
        let mut b = GraphBuilder::new();
        let x = b.input(&[1, 64, 56, 56]);
        let r = b.relu(x);
        let relu = b.finish().node(r).clone();
        assert_eq!(
            scaled_cost(&relu, &d, 0.5).duration_s,
            kernel_cost(&relu, &d).duration_s
        );
    }

    #[test]
    fn slower_device_slower_kernels() {
        let op = conv_op(128, 28);
        let v = kernel_cost(&op, &GpuSpec::v100());
        let xp = kernel_cost(&op, &GpuSpec::titan_xp());
        assert!(xp.duration_s > v.duration_s);
    }
}
