//! Host-side scheduler profiles — the "scheduling overhead" half of the
//! paper's motivation (§2/§3).
//!
//! Each profile models the per-operator host work a framework performs
//! before a GPU task is submitted: ready-queue/emitter bookkeeping (or the
//! Python interpreter), type/shape checks, kernel dispatch, memory
//! allocation from the caching pool, and argument marshalling. Values are
//! calibrated so the simulated Fig. 2a/2b ratios land where the paper
//! measured them on a 2.10 GHz Xeon host (see EXPERIMENTS.md §Calibration):
//! PyTorch ≈ 40 µs/op end-to-end matches the 2.37× ResNet-50 gap of
//! Fig. 2b and the ≤ 91% GPU-idle ratios of Fig. 2a.

/// Host scheduling profile: what happens on the CPU before each GPU task.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct HostProfile {
    pub name: &'static str,
    /// Per-operator scheduling overhead, seconds (shape check + dispatch +
    /// alloc + marshalling; for eager frameworks includes the interpreter).
    pub per_op_overhead_s: f64,
    /// Per-task raw submission cost, seconds (cudaLaunchKernel-equivalent).
    pub submit_s: f64,
}

impl HostProfile {
    /// PyTorch v1.4 eager: Python interpreter + C++ dispatcher + caching
    /// allocator.
    pub fn pytorch() -> Self {
        HostProfile { name: "PyTorch", per_op_overhead_s: 32.0e-6, submit_s: 2.0e-6 }
    }

    /// TorchScript: no Python on the path, but the full C++ runtime stack.
    pub fn torchscript() -> Self {
        HostProfile { name: "TorchScript", per_op_overhead_s: 24.0e-6, submit_s: 2.0e-6 }
    }

    /// Caffe2: graph runtime with operator emitter + workers.
    pub fn caffe2() -> Self {
        HostProfile { name: "Caffe2", per_op_overhead_s: 19.0e-6, submit_s: 2.0e-6 }
    }

    /// TensorFlow 1.x-style graph executor (Fig. 2a's second framework).
    pub fn tensorflow() -> Self {
        HostProfile { name: "TensorFlow", per_op_overhead_s: 20.0e-6, submit_s: 2.0e-6 }
    }

    /// TensorRT: a lean engine runtime, still one enqueue per layer.
    pub fn tensorrt() -> Self {
        HostProfile { name: "TensorRT", per_op_overhead_s: 2.5e-6, submit_s: 1.5e-6 }
    }

    /// TVM: compiled graph runtime, thin per-op dispatch.
    pub fn tvm() -> Self {
        HostProfile { name: "TVM", per_op_overhead_s: 2.5e-6, submit_s: 1.5e-6 }
    }

    /// Nimble: AoT-scheduled replay — no scheduling work at run time, only
    /// the raw (CUDA-Graph-style) task submission.
    pub fn nimble() -> Self {
        HostProfile { name: "Nimble", per_op_overhead_s: 0.0, submit_s: 0.4e-6 }
    }

    /// The paper's Fig. 2b "scheduling-minimized" hand-written C++ program:
    /// hardcoded shapes/addresses, direct kernel launches.
    pub fn sched_minimized() -> Self {
        HostProfile { name: "SchedMin", per_op_overhead_s: 0.0, submit_s: 2.0e-6 }
    }

    /// Total host time consumed per task before submission completes.
    pub fn per_task_s(&self) -> f64 {
        self.per_op_overhead_s + self.submit_s
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ordering_matches_reality() {
        // eager > graph runtimes > inference engines > nimble replay
        let p = HostProfile::pytorch().per_task_s();
        let ts = HostProfile::torchscript().per_task_s();
        let c2 = HostProfile::caffe2().per_task_s();
        let trt = HostProfile::tensorrt().per_task_s();
        let nb = HostProfile::nimble().per_task_s();
        assert!(p > ts && ts > c2 && c2 > trt && trt > nb);
    }

    #[test]
    fn nimble_is_submission_only() {
        let nb = HostProfile::nimble();
        assert_eq!(nb.per_op_overhead_s, 0.0);
        assert!(nb.submit_s < 1e-6);
    }

    #[test]
    fn sched_minimized_keeps_launch_cost() {
        let sm = HostProfile::sched_minimized();
        assert_eq!(sm.per_op_overhead_s, 0.0);
        assert!(sm.submit_s >= HostProfile::pytorch().submit_s * 0.9);
    }
}
