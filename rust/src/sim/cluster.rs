//! Cluster-scale DES: N device replicas — each its own SM pool and
//! clock — under ONE arrival process, fronted by a mirror of the
//! live replica router ([`crate::cluster::Cluster`]).
//!
//! Same measured-vs-predicted discipline as the lane, chaos, and EDF
//! sims: the router's decision procedure is reproduced *exactly* —
//! identical PCG32 draw protocol, identical pressure signal, identical
//! tie-breaks — so a seeded closed-loop cluster run and
//! [`simulate_cluster`] agree on completed / shed / per-replica
//! admitted counts bit-for-bit (`benches/bench_cluster.rs` asserts
//! this), and open-loop predictions are judged against measurement in
//! `BENCH_cluster.json`.
//!
//! ## The router mirror
//!
//! Per request, in arrival order (the live router serializes decisions
//! behind one mutex, so arrival order IS decision order):
//!
//! 1. **Door shed** — a deadline at or before the request's arrival is
//!    shed *before* routing and consumes **no** RNG draw.
//! 2. **Choice** — round-robin advances a counter over the routable
//!    replicas (again no RNG); power-of-two-choices over `n ≥ 2`
//!    replicas draws `a = rng.gen_range(n)`, then
//!    `b = rng.gen_range(n - 1); if b >= a { b += 1 }` (distinct
//!    second candidate), and keeps the lower **pressure score**
//!    `(est, in_flight, index)` compared lexicographically, where
//!    `est = ewma_queue_delay × in_flight`. One routable replica
//!    consumes no draws.
//!
//! Closed-loop traffic (each request waits for the previous outcome)
//! makes every pressure component identically zero, so decisions
//! reduce to the seeded draws + index tie-break — the property the
//! exact bench entry pins.

use super::cost::KernelCost;
use super::des::simulate_tape;
use super::device::GpuSpec;
use super::framework::HostProfile;
use crate::util::Pcg32;

/// The cluster's offered traffic: one model tape (every replica serves
/// the same spec) and per-request `(arrival_s, deadline_s)` pairs,
/// arrivals ascending; `f64::INFINITY` = no deadline, a deadline at or
/// before arrival = shed at the door.
pub struct ClusterTraffic<'a> {
    pub tape: &'a crate::aot::tape::ReplayTape,
    pub costs: &'a [KernelCost],
    /// Request arrivals, ascending: `(arrival_s, absolute deadline_s)`.
    pub requests: &'a [(f64, f64)],
}

/// The routing discipline [`simulate_cluster`] mirrors — the offline
/// counterpart of `ClusterBuilder::{replicas, route_p2c, route_round_robin}`.
#[derive(Debug, Clone)]
pub struct ClusterSimPolicy {
    /// Live device replicas (the sim has no mid-run drains).
    pub replicas: usize,
    /// Serving lanes per replica for the open-loop queue model
    /// (irrelevant under `closed_loop`).
    pub lanes_per_replica: usize,
    /// Power-of-two-choices when true, round-robin when false.
    pub p2c: bool,
    /// Router RNG seed — must equal the live cluster's
    /// `route_p2c(seed)` for exact-match runs.
    pub seed: u64,
    /// Closed-loop traffic: each request is submitted only after the
    /// previous one resolved, so per-replica pressure is identically
    /// zero at every decision and the run is exactly reproducible.
    /// Open-loop (false) models each replica as a `lanes_per_replica`-
    /// server queue under the arrival process.
    pub closed_loop: bool,
}

/// Per-replica prediction of [`simulate_cluster`].
#[derive(Debug, Clone)]
pub struct ReplicaSimStat {
    /// Requests the router sent to this replica.
    pub admitted: usize,
    /// Requests that started before their deadline.
    pub completed: usize,
    /// Requests shed after routing (expired while queued, or start
    /// would miss the deadline) — door sheds are counted cluster-wide
    /// in [`ClusterSimResult::router_shed`], not here.
    pub shed: usize,
    /// When this replica's last served request completes.
    pub end_s: f64,
}

/// Output of [`simulate_cluster`].
#[derive(Debug, Clone)]
pub struct ClusterSimResult {
    pub per_replica: Vec<ReplicaSimStat>,
    /// Requests shed at the router's door (deadline already expired at
    /// arrival), before any replica saw them.
    pub router_shed: usize,
    /// Makespan: closed-loop cumulative serve time, or the latest
    /// replica completion under open loop.
    pub total_s: f64,
}

impl ClusterSimResult {
    pub fn completed(&self) -> usize {
        self.per_replica.iter().map(|r| r.completed).sum()
    }

    /// All sheds: door sheds plus post-routing sheds on every replica
    /// — the counterpart of the live cluster's
    /// `router_shed + Σ deadline_shed`.
    pub fn shed(&self) -> usize {
        self.router_shed + self.per_replica.iter().map(|r| r.shed).sum::<usize>()
    }

    /// Per-replica admitted counts, replica order — the exact-match
    /// routing signature the bench pins against the live run.
    pub fn admitted_per_replica(&self) -> Vec<usize> {
        self.per_replica.iter().map(|r| r.admitted).collect()
    }

    /// Shed fraction of everything offered.
    pub fn shed_rate(&self) -> f64 {
        let total = self.completed() + self.shed();
        if total == 0 {
            0.0
        } else {
            self.shed() as f64 / total as f64
        }
    }
}

/// Draw the router's power-of-two candidate pair over `n ≥ 2`
/// routable replicas: two *distinct* indices, exactly two RNG draws.
/// `pub(crate)` so the live router uses this very function — the
/// mirror cannot drift.
pub(crate) fn p2c_draw(rng: &mut Pcg32, n: usize) -> (usize, usize) {
    debug_assert!(n >= 2);
    let a = rng.gen_range(n);
    let mut b = rng.gen_range(n - 1);
    if b >= a {
        b += 1;
    }
    (a, b)
}

/// Pressure comparison the router and this sim share: lexicographic
/// `(est, in_flight, index)` with `f64::total_cmp` on the estimate.
/// Returns the replica with the LOWER pressure.
pub(crate) fn lower_pressure(
    a: (f64, usize, usize),
    b: (f64, usize, usize),
) -> usize {
    match a.0.total_cmp(&b.0) {
        std::cmp::Ordering::Less => a.2,
        std::cmp::Ordering::Greater => b.2,
        std::cmp::Ordering::Equal => {
            if (a.1, a.2) <= (b.1, b.2) {
                a.2
            } else {
                b.2
            }
        }
    }
}

/// Cluster prediction: route the arrival stream through the mirrored
/// router (see the [module docs](self)) onto `replicas` independent
/// device models, each serving requests at the tape's single-lane DES
/// latency ([`simulate_tape`]`.total_s`) on `lanes_per_replica`
/// servers. Closed-loop runs are exact mirrors of a seeded live run;
/// open-loop runs predict throughput/shed under concurrency the same
/// way [`simulate_edf`](super::simulate_edf) does for one device.
pub fn simulate_cluster(
    traffic: &ClusterTraffic,
    host: HostProfile,
    device: GpuSpec,
    policy: &ClusterSimPolicy,
) -> ClusterSimResult {
    assert!(policy.replicas >= 1, "need at least one replica");
    assert!(policy.lanes_per_replica >= 1, "need at least one lane per replica");
    let n = policy.replicas;
    let service_s = simulate_tape(traffic.tape, traffic.costs, host, device).total_s;
    let mut rng = Pcg32::new(policy.seed);
    let mut rr = 0usize;
    let mut router_shed = 0usize;

    // Per-replica state. `lanes` holds server free-times (open loop);
    // `queue` the admitted, undispatched requests (deadline, arrival);
    // `warm_at` the EWMA warm instant (first completion, the same
    // quantization simulate_edf uses for constant service times).
    struct Rep {
        admitted: usize,
        completed: usize,
        shed: usize,
        end_s: f64,
        lanes: Vec<f64>,
        queue: Vec<(f64, f64)>,
        warm_at: f64,
    }
    let mut reps: Vec<Rep> = (0..n)
        .map(|_| Rep {
            admitted: 0,
            completed: 0,
            shed: 0,
            end_s: 0.0,
            lanes: vec![0.0; policy.lanes_per_replica],
            queue: Vec::new(),
            warm_at: f64::INFINITY,
        })
        .collect();

    // Dispatch a replica's queued requests (FIFO — one bucket) onto
    // lanes that free before `until`.
    let dispatch_until = |rep: &mut Rep, until: f64| {
        while !rep.queue.is_empty() {
            let li = (0..rep.lanes.len())
                .min_by(|&a, &b| rep.lanes[a].total_cmp(&rep.lanes[b]))
                .unwrap();
            if rep.lanes[li] >= until {
                break;
            }
            let (deadline, arrival) = rep.queue.remove(0);
            let start = rep.lanes[li].max(arrival);
            if start >= deadline {
                rep.shed += 1; // expired while queued; the lane stays free
                continue;
            }
            let end = start + service_s;
            rep.lanes[li] = end;
            rep.completed += 1;
            rep.warm_at = rep.warm_at.min(end);
            rep.end_s = rep.end_s.max(end);
        }
    };

    let mut clock = 0.0f64; // closed-loop serial clock
    for &(arrival, deadline) in traffic.requests {
        assert!(arrival >= 0.0, "arrivals must be non-negative");
        let now = if policy.closed_loop { clock.max(arrival) } else { arrival };
        // 1. Door shed: expired on arrival, no routing, no RNG draw.
        if deadline <= now {
            router_shed += 1;
            continue;
        }
        // Open loop: bring every replica's model up to `now` so the
        // pressure signal reflects work finished before this decision.
        if !policy.closed_loop {
            for rep in reps.iter_mut() {
                dispatch_until(rep, now);
            }
        }
        // 2. Choice.
        let pressure = |rep: &Rep, idx: usize| -> (f64, usize, usize) {
            if policy.closed_loop {
                // Each request waits for the previous outcome, so
                // nothing is ever in flight at a decision.
                return (0.0, 0, idx);
            }
            let in_flight =
                rep.queue.len() + rep.lanes.iter().filter(|&&f| f > now).count();
            let ewma = if now < rep.warm_at { 0.0 } else { service_s };
            (ewma * in_flight as f64, in_flight, idx)
        };
        let chosen = if !policy.p2c {
            let c = rr % n;
            rr += 1;
            c
        } else if n == 1 {
            0
        } else {
            let (a, b) = p2c_draw(&mut rng, n);
            lower_pressure(pressure(&reps[a], a), pressure(&reps[b], b))
        };
        // 3. Serve.
        let rep = &mut reps[chosen];
        rep.admitted += 1;
        if policy.closed_loop {
            // Sequential-blocking client: the request runs alone,
            // starting the moment it is admitted.
            let start = now;
            if start >= deadline {
                rep.shed += 1;
            } else {
                rep.completed += 1;
                clock = start + service_s;
                rep.end_s = clock;
                rep.warm_at = rep.warm_at.min(clock);
            }
        } else {
            rep.queue.push((deadline, now));
        }
    }
    // Open loop: flush everything still queued.
    if !policy.closed_loop {
        for rep in reps.iter_mut() {
            dispatch_until(rep, f64::INFINITY);
        }
    }
    let total_s = if policy.closed_loop {
        clock
    } else {
        reps.iter().map(|r| r.end_s).fold(0.0, f64::max)
    };
    ClusterSimResult {
        per_replica: reps
            .into_iter()
            .map(|r| ReplicaSimStat {
                admitted: r.admitted,
                completed: r.completed,
                shed: r.shed,
                end_s: r.end_s,
            })
            .collect(),
        router_shed,
        total_s,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::aot::tape::ReplayTape;
    use crate::matching::MatchingAlgo;
    use crate::sim::cost::kernel_cost;
    use crate::stream::rewrite::rewrite;

    fn tape_and_costs() -> (ReplayTape, Vec<KernelCost>) {
        let g = crate::models::build("mini_inception", 1);
        let dev = GpuSpec::v100();
        let costs: Vec<KernelCost> =
            (0..g.n_nodes()).map(|v| kernel_cost(g.node(v), &dev)).collect();
        let tape =
            ReplayTape::for_op_graph(&g, &rewrite(&g, MatchingAlgo::HopcroftKarp), 4096);
        (tape, costs)
    }

    #[test]
    fn closed_loop_round_robin_spreads_evenly_and_sheds_at_the_door() {
        let (tape, costs) = tape_and_costs();
        let requests: Vec<(f64, f64)> = (0..8)
            .map(|i| if i % 4 == 3 { (0.0, 0.0) } else { (0.0, f64::INFINITY) })
            .collect();
        let r = simulate_cluster(
            &ClusterTraffic { tape: &tape, costs: &costs, requests: &requests },
            HostProfile::nimble(),
            GpuSpec::v100(),
            &ClusterSimPolicy {
                replicas: 3,
                lanes_per_replica: 1,
                p2c: false,
                seed: 1,
                closed_loop: true,
            },
        );
        assert_eq!(r.router_shed, 2, "deadline <= arrival sheds before routing");
        assert_eq!(r.completed(), 6);
        assert_eq!(r.shed(), 2);
        // Round-robin over 6 routed requests and 3 replicas: 2 each.
        assert_eq!(r.admitted_per_replica(), vec![2, 2, 2]);
        assert!(r.total_s > 0.0);
    }

    #[test]
    fn closed_loop_p2c_is_deterministic_in_the_seed() {
        let (tape, costs) = tape_and_costs();
        let requests = vec![(0.0, f64::INFINITY); 32];
        let policy = |seed| ClusterSimPolicy {
            replicas: 4,
            lanes_per_replica: 1,
            p2c: true,
            seed,
            closed_loop: true,
        };
        let t = ClusterTraffic { tape: &tape, costs: &costs, requests: &requests };
        let a =
            simulate_cluster(&t, HostProfile::nimble(), GpuSpec::v100(), &policy(7));
        let b =
            simulate_cluster(&t, HostProfile::nimble(), GpuSpec::v100(), &policy(7));
        assert_eq!(a.admitted_per_replica(), b.admitted_per_replica());
        assert_eq!(a.completed(), 32);
        // Zero pressure everywhere: every choice is min(a, b) of the
        // two draws, which skews admissions toward LOW indices — the
        // tie-break signature the live router shares.
        let admitted = a.admitted_per_replica();
        assert!(
            admitted[0] >= admitted[3],
            "min-index tie-break must favor replica 0: {admitted:?}"
        );
        let c =
            simulate_cluster(&t, HostProfile::nimble(), GpuSpec::v100(), &policy(8));
        assert_eq!(c.completed(), 32, "different seed still completes everything");
    }

    #[test]
    fn open_loop_p2c_beats_a_queue_only_router_under_burst() {
        let (tape, costs) = tape_and_costs();
        // A burst far above one replica's service rate with tight
        // deadlines: spreading by pressure must shed no more than
        // blind round-robin (it sees queue depth, RR does not).
        let service = simulate_tape(
            &tape,
            &costs,
            HostProfile::nimble(),
            GpuSpec::v100(),
        )
        .total_s;
        let requests: Vec<(f64, f64)> = (0..64)
            .map(|i| {
                let arrival = i as f64 * service / 8.0;
                (arrival, arrival + 3.0 * service)
            })
            .collect();
        let t = ClusterTraffic { tape: &tape, costs: &costs, requests: &requests };
        let mk = |p2c| ClusterSimPolicy {
            replicas: 2,
            lanes_per_replica: 2,
            p2c,
            seed: 11,
            closed_loop: false,
        };
        let p2c = simulate_cluster(&t, HostProfile::nimble(), GpuSpec::v100(), &mk(true));
        let rr = simulate_cluster(&t, HostProfile::nimble(), GpuSpec::v100(), &mk(false));
        assert_eq!(p2c.completed() + p2c.shed(), 64);
        assert_eq!(rr.completed() + rr.shed(), 64);
        assert!(
            p2c.shed() <= rr.shed() + 4,
            "p2c shed {} must not collapse vs round-robin {}",
            p2c.shed(),
            rr.shed()
        );
        // More replicas serve strictly more of the same offered load.
        let wide = simulate_cluster(
            &t,
            HostProfile::nimble(),
            GpuSpec::v100(),
            &ClusterSimPolicy { replicas: 4, ..mk(true) },
        );
        assert!(wide.completed() >= p2c.completed());
    }
}
