//! Timeline metrics: interval union (GPU active time, Fig. 2a) and the
//! critical-path time of an operator graph (Fig. 2c).

use super::cost::KernelCost;
use crate::graph::Dag;

/// Total length of the union of (possibly overlapping) intervals.
pub fn interval_union(intervals: impl Iterator<Item = (f64, f64)>) -> f64 {
    let mut iv: Vec<(f64, f64)> = intervals.collect();
    iv.sort_by(|a, b| a.0.partial_cmp(&b.0).expect("NaN interval"));
    let mut total = 0.0;
    let mut cur: Option<(f64, f64)> = None;
    for (s, e) in iv {
        match cur {
            None => cur = Some((s, e)),
            Some((cs, ce)) => {
                if s <= ce {
                    cur = Some((cs, ce.max(e)));
                } else {
                    total += ce - cs;
                    cur = Some((s, e));
                }
            }
        }
    }
    if let Some((cs, ce)) = cur {
        total += ce - cs;
    }
    total
}

/// Critical-path time: the longest path through the DAG weighting each node
/// by its kernel duration ("sum of the GPU active times spent on the
/// operators in the longest path", paper §3).
pub fn critical_path_s<N>(g: &Dag<N>, costs: &[KernelCost]) -> f64 {
    let order = crate::graph::topo_order(g).expect("critical path requires a DAG");
    let mut finish = vec![0.0f64; g.n_nodes()];
    for &v in &order {
        let start = g
            .predecessors(v)
            .iter()
            .map(|&p| finish[p])
            .fold(0.0, f64::max);
        finish[v] = start + costs[v].duration_s;
    }
    finish.into_iter().fold(0.0, f64::max)
}

/// Sum of all kernel durations (serial lower bound; Fig. 2c denominator is
/// the GPU *active* time which equals this on a single stream).
pub fn total_kernel_s(costs: &[KernelCost]) -> f64 {
    costs.iter().map(|c| c.duration_s).sum()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::Dag;

    #[test]
    fn union_of_disjoint() {
        let u = interval_union(vec![(0.0, 1.0), (2.0, 3.0)].into_iter());
        assert!((u - 2.0).abs() < 1e-12);
    }

    #[test]
    fn union_of_overlapping() {
        let u = interval_union(vec![(0.0, 2.0), (1.0, 3.0), (2.5, 2.7)].into_iter());
        assert!((u - 3.0).abs() < 1e-12);
    }

    #[test]
    fn union_empty() {
        assert_eq!(interval_union(std::iter::empty()), 0.0);
    }

    #[test]
    fn union_nested() {
        let u = interval_union(vec![(0.0, 10.0), (2.0, 3.0)].into_iter());
        assert!((u - 10.0).abs() < 1e-12);
    }

    fn cost(d: f64) -> KernelCost {
        KernelCost { duration_s: d, sm_demand: 1 }
    }

    #[test]
    fn critical_path_of_diamond() {
        let mut g: Dag<()> = Dag::new();
        for _ in 0..4 {
            g.add_node(());
        }
        g.add_edge(0, 1);
        g.add_edge(0, 2);
        g.add_edge(1, 3);
        g.add_edge(2, 3);
        let costs = vec![cost(1.0), cost(5.0), cost(2.0), cost(1.0)];
        // longest path: 0 →1→ 3 = 1 + 5 + 1
        assert!((critical_path_s(&g, &costs) - 7.0).abs() < 1e-12);
    }

    #[test]
    fn critical_path_le_total() {
        let mut g: Dag<()> = Dag::new();
        for _ in 0..3 {
            g.add_node(());
        }
        g.add_edge(0, 1);
        let costs = vec![cost(1.0), cost(2.0), cost(4.0)];
        let cp = critical_path_s(&g, &costs);
        assert!((cp - 4.0).abs() < 1e-12, "independent node 2 is the longest chain");
        assert!(cp <= total_kernel_s(&costs));
    }
}
