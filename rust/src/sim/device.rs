//! GPU device models. Specs follow the public datasheets of the three GPUs
//! in the paper's evaluation (§5 and Appendix C), plus an idealized
//! infinitely-parallel device used for the Fig. 2c critical-path analysis.

/// A simulated GPU.
#[derive(Debug, Clone, PartialEq)]
pub struct GpuSpec {
    pub name: &'static str,
    /// Peak fp32 throughput in TFLOP/s.
    pub peak_tflops: f64,
    /// Memory bandwidth in GB/s.
    pub mem_bw_gbps: f64,
    /// Streaming multiprocessor count (bounds kernel overlap).
    pub sm_count: usize,
    /// Resident threads per SM (occupancy model).
    pub threads_per_sm: usize,
    /// Fixed device-side cost per kernel (scheduling on the GPU itself,
    /// not host overhead), seconds.
    pub kernel_fixed_s: f64,
    /// Serial per-kernel cost at the device's work distributor (the GPU
    /// front-end dispatches kernel launches one at a time, across ALL
    /// streams). This is what caps multi-stream speedups for launch-bound
    /// networks — the Table 1 ceiling.
    pub front_end_s: f64,
}

impl GpuSpec {
    /// NVIDIA V100 (the paper's §5 testbed).
    pub fn v100() -> Self {
        GpuSpec {
            name: "V100",
            peak_tflops: 15.7,
            mem_bw_gbps: 900.0,
            sm_count: 80,
            threads_per_sm: 2048,
            kernel_fixed_s: 1.2e-6,
            front_end_s: 1.5e-6,
        }
    }

    /// NVIDIA Titan RTX (Appendix C, Turing).
    pub fn titan_rtx() -> Self {
        GpuSpec {
            name: "TitanRTX",
            peak_tflops: 16.3,
            mem_bw_gbps: 672.0,
            sm_count: 72,
            threads_per_sm: 1024,
            kernel_fixed_s: 1.2e-6,
            front_end_s: 1.5e-6,
        }
    }

    /// NVIDIA Titan Xp (Appendix C, Pascal).
    pub fn titan_xp() -> Self {
        GpuSpec {
            name: "TitanXp",
            peak_tflops: 12.1,
            mem_bw_gbps: 548.0,
            sm_count: 60,
            threads_per_sm: 2048,
            kernel_fixed_s: 1.5e-6,
            front_end_s: 1.8e-6,
        }
    }

    /// Idealized device: unbounded parallelism, V100 per-kernel speed.
    /// Used for the Fig. 2c "sufficiently powerful GPU" thought experiment.
    pub fn infinite() -> Self {
        GpuSpec { name: "Infinite", sm_count: usize::MAX / 2, front_end_s: 0.0, ..Self::v100() }
    }

    /// All concrete devices.
    pub fn all() -> Vec<GpuSpec> {
        vec![Self::v100(), Self::titan_rtx(), Self::titan_xp()]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn specs_are_sane() {
        for d in GpuSpec::all() {
            assert!(d.peak_tflops > 1.0 && d.peak_tflops < 100.0);
            assert!(d.mem_bw_gbps > 100.0);
            assert!(d.sm_count >= 32);
            assert!(d.kernel_fixed_s > 0.0);
        }
    }

    #[test]
    fn v100_fastest_memory() {
        let v = GpuSpec::v100();
        assert!(v.mem_bw_gbps > GpuSpec::titan_rtx().mem_bw_gbps);
        assert!(v.mem_bw_gbps > GpuSpec::titan_xp().mem_bw_gbps);
    }

    #[test]
    fn infinite_device_has_huge_sm_pool() {
        assert!(GpuSpec::infinite().sm_count > 1_000_000);
    }
}
