//! Chrome-trace (`chrome://tracing` / Perfetto) export of a simulated
//! timeline: one row per stream, one slice per task — the visual
//! counterpart of the paper's Figure 3. Written by
//! `nimble sim <model> <system> --trace out.json`.
//!
//! The slice schema here is the overlay contract with the *measured*
//! exporter in [`crate::telemetry::chrome`]: identical keys, identical
//! units, so a live run and its DES prediction diff cleanly
//! (`telemetry::diff_traces`). Zero-duration (virtual) spans are
//! omitted from the slice list but declared in a `dropped_zero_duration_spans`
//! metadata record so the span accounting still closes.

use super::des::SimResult;
use crate::util::json::push_escaped;

/// Render the spans as a Chrome trace-event JSON array (µs timestamps).
pub fn to_chrome_trace(result: &SimResult, label: impl Fn(usize) -> String) -> String {
    let mut out = String::from("[\n");
    let mut first = true;
    let mut zero_duration = 0u64;
    for sp in &result.spans {
        if sp.duration() <= 0.0 {
            zero_duration += 1;
            continue;
        }
        if !first {
            out.push_str(",\n");
        }
        first = false;
        let mut name = String::new();
        push_escaped(&mut name, &label(sp.node));
        out.push_str(&format!(
            "  {{\"name\": \"{}\", \"ph\": \"X\", \"ts\": {:.3}, \"dur\": {:.3}, \
             \"pid\": 0, \"tid\": {}, \"args\": {{\"submit_us\": {:.3}}}}}",
            name,
            sp.start_s * 1e6,
            sp.duration() * 1e6,
            sp.stream,
            sp.submit_s * 1e6,
        ));
    }
    if zero_duration > 0 {
        if !first {
            out.push_str(",\n");
        }
        out.push_str(&format!(
            "  {{\"name\": \"dropped_zero_duration_spans\", \"ph\": \"M\", \"pid\": 0, \
             \"tid\": 0, \"args\": {{\"count\": {zero_duration}}}}}",
        ));
    }
    out.push_str("\n]\n");
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::baselines::{prepare, run_prepared, Baseline};
    use crate::models;
    use crate::sim::GpuSpec;
    use crate::util::json::parse_json;

    #[test]
    fn trace_is_valid_jsonish_and_covers_all_real_tasks() {
        let dev = GpuSpec::v100();
        let g = models::build("mini_inception", 1);
        let p = prepare(&g, Baseline::Nimble, &dev, true);
        let r = run_prepared(&p, &dev);
        let trace = to_chrome_trace(&r, |n| p.graph.node(n).name.clone());
        assert!(trace.starts_with("[\n"));
        assert!(trace.trim_end().ends_with(']'));
        let n_slices = trace.matches("\"ph\": \"X\"").count();
        let n_real = r.spans.iter().filter(|s| s.duration() > 0.0).count();
        assert_eq!(n_slices, n_real);
        // balanced braces per line, no raw double quotes from names
        for line in trace.lines().filter(|l| l.contains("\"ph\"")) {
            assert_eq!(line.matches('{').count(), line.matches('}').count());
        }
    }

    #[test]
    fn virtual_tasks_are_omitted() {
        let dev = GpuSpec::v100();
        let g = models::build("mini_inception", 1);
        let p = prepare(&g, Baseline::PyTorch, &dev, false);
        let r = run_prepared(&p, &dev);
        let trace = to_chrome_trace(&r, |n| p.graph.node(n).name.clone());
        assert!(!trace.contains("input_1"), "virtual input must not appear");
    }

    #[test]
    fn zero_duration_spans_are_counted_not_lost() {
        let dev = GpuSpec::v100();
        let g = models::build("mini_inception", 1);
        let p = prepare(&g, Baseline::PyTorch, &dev, false);
        let r = run_prepared(&p, &dev);
        let n_zero = r.spans.iter().filter(|s| s.duration() <= 0.0).count() as u64;
        assert!(n_zero > 0, "mini_inception must have virtual (zero-dur) spans");
        let trace = to_chrome_trace(&r, |n| p.graph.node(n).name.clone());
        let doc = parse_json(&trace).expect("trace must be valid JSON");
        let dropped = doc
            .as_array()
            .unwrap()
            .iter()
            .find(|rec| {
                rec.get("name").and_then(|n| n.as_str())
                    == Some("dropped_zero_duration_spans")
            })
            .expect("metadata record must declare the omissions");
        assert_eq!(
            dropped.get("args").and_then(|a| a.get("count")).and_then(|c| c.as_u64()),
            Some(n_zero)
        );
        // Slice count + declared omissions == total simulated spans.
        let n_slices = trace.matches("\"ph\": \"X\"").count() as u64;
        assert_eq!(n_slices + n_zero, r.spans.len() as u64);
    }

    #[test]
    fn hostile_labels_are_escaped_to_valid_json() {
        let dev = GpuSpec::v100();
        let g = models::build("mini_inception", 1);
        let p = prepare(&g, Baseline::Nimble, &dev, true);
        let r = run_prepared(&p, &dev);
        // Hostile names: quotes, backslashes, control characters — the
        // exact inputs the old `replace('"', '\'')` mangled or broke on.
        let trace = to_chrome_trace(&r, |n| format!("op\"{n}\\x\n\u{1}"));
        let doc = parse_json(&trace).expect("hostile labels must still be valid JSON");
        let arr = doc.as_array().unwrap();
        let with_name = arr
            .iter()
            .filter_map(|rec| rec.get("name").and_then(|n| n.as_str()))
            .filter(|n| n.starts_with("op\""))
            .count();
        assert_eq!(with_name, trace.matches("\"ph\": \"X\"").count());
        // Labels round-trip unmangled (quotes preserved, not rewritten
        // to apostrophes; backslash and control chars intact).
        assert!(arr.iter().any(|rec| {
            rec.get("name")
                .and_then(|n| n.as_str())
                .is_some_and(|n| n.starts_with("op\"") && n.ends_with("\\x\n\u{1}"))
        }));
    }
}
