//! Chrome-trace (`chrome://tracing` / Perfetto) export of a simulated
//! timeline: one row per stream, one slice per task — the visual
//! counterpart of the paper's Figure 3. Written by
//! `nimble sim <model> <system> --trace out.json`.

use super::des::SimResult;

/// Render the spans as a Chrome trace-event JSON array (µs timestamps).
pub fn to_chrome_trace(result: &SimResult, label: impl Fn(usize) -> String) -> String {
    let mut out = String::from("[\n");
    let mut first = true;
    for sp in &result.spans {
        if sp.duration() <= 0.0 {
            continue;
        }
        if !first {
            out.push_str(",\n");
        }
        first = false;
        out.push_str(&format!(
            "  {{\"name\": \"{}\", \"ph\": \"X\", \"ts\": {:.3}, \"dur\": {:.3}, \
             \"pid\": 0, \"tid\": {}, \"args\": {{\"submit_us\": {:.3}}}}}",
            label(sp.node).replace('"', "'"),
            sp.start_s * 1e6,
            sp.duration() * 1e6,
            sp.stream,
            sp.submit_s * 1e6,
        ));
    }
    out.push_str("\n]\n");
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::baselines::{prepare, run_prepared, Baseline};
    use crate::models;
    use crate::sim::GpuSpec;

    #[test]
    fn trace_is_valid_jsonish_and_covers_all_real_tasks() {
        let dev = GpuSpec::v100();
        let g = models::build("mini_inception", 1);
        let p = prepare(&g, Baseline::Nimble, &dev, true);
        let r = run_prepared(&p, &dev);
        let trace = to_chrome_trace(&r, |n| p.graph.node(n).name.clone());
        assert!(trace.starts_with("[\n"));
        assert!(trace.trim_end().ends_with(']'));
        let n_slices = trace.matches("\"ph\": \"X\"").count();
        let n_real = r.spans.iter().filter(|s| s.duration() > 0.0).count();
        assert_eq!(n_slices, n_real);
        // balanced braces per line, no raw double quotes from names
        for line in trace.lines().filter(|l| l.contains("\"ph\"")) {
            assert_eq!(line.matches('{').count(), line.matches('}').count());
        }
    }

    #[test]
    fn virtual_tasks_are_omitted() {
        let dev = GpuSpec::v100();
        let g = models::build("mini_inception", 1);
        let p = prepare(&g, Baseline::PyTorch, &dev, false);
        let r = run_prepared(&p, &dev);
        let trace = to_chrome_trace(&r, |n| p.graph.node(n).name.clone());
        assert!(!trace.contains("input_1"), "virtual input must not appear");
    }
}
