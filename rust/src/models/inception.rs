//! Inception-v3 (Szegedy et al. 2016), torchvision channel configuration,
//! 299×299 input — the paper's Table 1 entry with Deg. 6 and 5.7 GMACs.

use crate::graph::NodeId;
use crate::ops::{GraphBuilder, OpGraph};

/// Branch helper: conv + bn + relu (torchvision `BasicConv2d`).
fn basic(b: &mut GraphBuilder, x: NodeId, c: usize, k: usize, s: usize) -> NodeId {
    b.conv_bn_relu(x, c, k, s)
}

fn basic_valid(b: &mut GraphBuilder, x: NodeId, c: usize, k: usize, s: usize) -> NodeId {
    let v = b.conv_valid(x, c, k, s);
    let v = b.bn(v);
    b.relu(v)
}

fn basic_rect(b: &mut GraphBuilder, x: NodeId, c: usize, kh: usize, kw: usize) -> NodeId {
    let v = b.conv_rect(x, c, kh, kw);
    let v = b.bn(v);
    b.relu(v)
}

/// InceptionA: 4 parallel branches at 35×35.
fn inception_a(b: &mut GraphBuilder, x: NodeId, pool_c: usize) -> NodeId {
    let b1 = basic(b, x, 64, 1, 1);
    let b5 = basic(b, x, 48, 1, 1);
    let b5 = basic(b, b5, 64, 5, 1);
    let b3 = basic(b, x, 64, 1, 1);
    let b3 = basic(b, b3, 96, 3, 1);
    let b3 = basic(b, b3, 96, 3, 1);
    let p = b.avgpool(x, 3, 1);
    let p = basic(b, p, pool_c, 1, 1);
    b.concat(&[b1, b5, b3, p])
}

/// InceptionB: grid reduction 35 → 17.
fn inception_b(b: &mut GraphBuilder, x: NodeId) -> NodeId {
    let b3 = basic_valid(b, x, 384, 3, 2);
    let d = basic(b, x, 64, 1, 1);
    let d = basic(b, d, 96, 3, 1);
    let d = basic_valid(b, d, 96, 3, 2);
    let p = b.maxpool_valid(x, 3, 2);
    b.concat(&[b3, d, p])
}

/// InceptionC: 7×1/1×7 factorized branches at 17×17.
fn inception_c(b: &mut GraphBuilder, x: NodeId, c7: usize) -> NodeId {
    let b1 = basic(b, x, 192, 1, 1);
    let mut b7 = basic(b, x, c7, 1, 1);
    b7 = basic_rect(b, b7, c7, 1, 7);
    b7 = basic_rect(b, b7, 192, 7, 1);
    let mut d = basic(b, x, c7, 1, 1);
    d = basic_rect(b, d, c7, 7, 1);
    d = basic_rect(b, d, c7, 1, 7);
    d = basic_rect(b, d, c7, 7, 1);
    d = basic_rect(b, d, 192, 1, 7);
    let p = b.avgpool(x, 3, 1);
    let p = basic(b, p, 192, 1, 1);
    b.concat(&[b1, b7, d, p])
}

/// InceptionD: grid reduction 17 → 8.
fn inception_d(b: &mut GraphBuilder, x: NodeId) -> NodeId {
    let mut b3 = basic(b, x, 192, 1, 1);
    b3 = basic_valid(b, b3, 320, 3, 2);
    let mut b7 = basic(b, x, 192, 1, 1);
    b7 = basic_rect(b, b7, 192, 1, 7);
    b7 = basic_rect(b, b7, 192, 7, 1);
    b7 = basic_valid(b, b7, 192, 3, 2);
    let p = b.maxpool_valid(x, 3, 2);
    b.concat(&[b3, b7, p])
}

/// InceptionE: widest block (6 parallel conv chains) at 8×8 — the source of
/// Inception-v3's Deg. 6 in Table 1.
fn inception_e(b: &mut GraphBuilder, x: NodeId) -> NodeId {
    let b1 = basic(b, x, 320, 1, 1);
    let b3 = basic(b, x, 384, 1, 1);
    let b3a = basic_rect(b, b3, 384, 1, 3);
    let b3b = basic_rect(b, b3, 384, 3, 1);
    let b3 = b.concat(&[b3a, b3b]);
    let mut d = basic(b, x, 448, 1, 1);
    d = basic(b, d, 384, 3, 1);
    let da = basic_rect(b, d, 384, 1, 3);
    let db = basic_rect(b, d, 384, 3, 1);
    let d = b.concat(&[da, db]);
    let p = b.avgpool(x, 3, 1);
    let p = basic(b, p, 192, 1, 1);
    b.concat(&[b1, b3, d, p])
}

/// Full Inception-v3 inference graph.
pub fn inception_v3(batch: usize) -> OpGraph {
    let mut b = GraphBuilder::new();
    let input = b.input(&[batch, 3, 299, 299]);
    // Stem (valid convs, matching torchvision's 299→35 reduction).
    let mut x = basic_valid(&mut b, input, 32, 3, 2); // 149
    x = basic_valid(&mut b, x, 32, 3, 1); // 147
    x = basic(&mut b, x, 64, 3, 1); // 147 (same pad)
    x = b.maxpool_valid(x, 3, 2); // 73
    x = basic(&mut b, x, 80, 1, 1);
    x = basic_valid(&mut b, x, 192, 3, 1); // 71
    x = b.maxpool_valid(x, 3, 2); // 35
    // Inception stacks.
    x = inception_a(&mut b, x, 32);
    x = inception_a(&mut b, x, 64);
    x = inception_a(&mut b, x, 64);
    x = inception_b(&mut b, x); // 17
    x = inception_c(&mut b, x, 128);
    x = inception_c(&mut b, x, 160);
    x = inception_c(&mut b, x, 160);
    x = inception_c(&mut b, x, 192);
    x = inception_d(&mut b, x); // 8
    x = inception_e(&mut b, x);
    x = inception_e(&mut b, x);
    let g = b.gap(x);
    let _ = b.linear(g, 1000);
    b.finish()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ops::op::total_macs;
    use crate::stream::logical_concurrency_degree;

    #[test]
    fn macs_match_paper_table1() {
        // Paper Table 1: 5.7 GMACs.
        let g = inception_v3(1);
        let gmacs = total_macs(&g) as f64 / 1e9;
        assert!((4.8..6.8).contains(&gmacs), "inception_v3 gmacs={gmacs}");
    }

    #[test]
    fn logical_concurrency_degree_matches_paper() {
        // Paper Table 1: Deg. 6 (InceptionE's parallel conv chains).
        let g = inception_v3(1);
        let deg = logical_concurrency_degree(&g);
        assert!((5..=8).contains(&deg), "inception deg={deg}");
    }

    #[test]
    fn op_count_plausible() {
        // 94 convs ×3 (conv+bn+relu) + pools/concats ≈ 300–360 ops
        let g = inception_v3(1);
        assert!((250..420).contains(&g.n_nodes()), "n={}", g.n_nodes());
    }

    #[test]
    fn single_output() {
        let g = inception_v3(1);
        assert_eq!(g.sinks().len(), 1);
    }
}
