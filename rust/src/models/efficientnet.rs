//! EfficientNet-B0/B5 (Tan & Le 2019): MBConv blocks with squeeze-and-
//! excitation. SE branches give the mild inter-operator parallelism that
//! makes EfficientNets profit from Nimble's multi-stream execution, and the
//! many tiny kernels make them the most scheduling-bound nets in Fig. 2a.

use crate::graph::NodeId;
use crate::ops::{GraphBuilder, OpGraph, OpKind};

/// Width rounding (the reference implementation's `round_filters`).
fn round_filters(c: usize, width_mult: f64) -> usize {
    let divisor = 8.0;
    let c = c as f64 * width_mult;
    let mut new_c = ((c + divisor / 2.0) / divisor).floor() * divisor;
    if new_c < 0.9 * c {
        new_c += divisor;
    }
    new_c as usize
}

fn round_repeats(r: usize, depth_mult: f64) -> usize {
    (r as f64 * depth_mult).ceil() as usize
}

/// Squeeze-and-excitation: GAP → 1×1 reduce → swish → 1×1 expand → sigmoid
/// → channel-scale. The GAP...sigmoid chain runs concurrently with nothing
/// (it gates the main path), but *across blocks* it creates short
/// independent chains.
fn squeeze_excite(b: &mut GraphBuilder, x: NodeId, c: usize, se_c: usize) -> NodeId {
    let s = b.gap(x);
    let s = b.conv(s, se_c, 1, 1);
    let s = b.act(s, OpKind::Swish);
    let s = b.conv(s, c, 1, 1);
    let s = b.act(s, OpKind::Sigmoid);
    b.mul(x, s)
}

/// MBConv block.
#[allow(clippy::too_many_arguments)]
fn mbconv(
    b: &mut GraphBuilder,
    x: NodeId,
    in_c: usize,
    out_c: usize,
    k: usize,
    stride: usize,
    expand: usize,
) -> NodeId {
    let mut y = x;
    let mid_c = in_c * expand;
    if expand != 1 {
        y = b.conv(y, mid_c, 1, 1);
        y = b.bn(y);
        y = b.act(y, OpKind::Swish);
    }
    y = b.dwconv(y, k, stride);
    y = b.bn(y);
    y = b.act(y, OpKind::Swish);
    // SE with ratio 0.25 of the *input* channels.
    let se_c = (in_c / 4).max(1);
    y = squeeze_excite(b, y, mid_c, se_c);
    y = b.conv_bn(y, out_c, 1, 1);
    if stride == 1 && in_c == out_c {
        y = b.add(y, x);
    }
    y
}

/// Generic EfficientNet. `hw ≤ 64` only narrows the head to 10 classes
/// (CIFAR-10 training feeds 32×32 through the unmodified architecture).
pub fn efficientnet(batch: usize, hw: usize, width_mult: f64, depth_mult: f64) -> OpGraph {
    let cifar = hw <= 64;
    let mut b = GraphBuilder::new();
    let input = b.input(&[batch, 3, hw, hw]);
    let stem_c = round_filters(32, width_mult);
    let mut x = b.conv(input, stem_c, 3, 2);
    x = b.bn(x);
    x = b.act(x, OpKind::Swish);
    // (expand, channels, repeats, stride, kernel)
    let cfg: [(usize, usize, usize, usize, usize); 7] = [
        (1, 16, 1, 1, 3),
        (6, 24, 2, 2, 3),
        (6, 40, 2, 2, 5),
        (6, 80, 3, 2, 3),
        (6, 112, 3, 1, 5),
        (6, 192, 4, 2, 5),
        (6, 320, 1, 1, 3),
    ];
    let mut in_c = stem_c;
    for (t, c, n, s, k) in cfg {
        let c = round_filters(c, width_mult);
        let n = round_repeats(n, depth_mult);
        for i in 0..n {
            let stride = if i == 0 { s } else { 1 };
            x = mbconv(&mut b, x, in_c, c, k, stride, t);
            in_c = c;
        }
    }
    let head_c = round_filters(1280, width_mult);
    x = b.conv(x, head_c, 1, 1);
    x = b.bn(x);
    x = b.act(x, OpKind::Swish);
    let g = b.gap(x);
    let _ = b.linear(g, if cifar { 10 } else { 1000 });
    b.finish()
}

pub fn efficientnet_b0(batch: usize, hw: usize) -> OpGraph {
    efficientnet(batch, hw, 1.0, 1.0)
}

pub fn efficientnet_b5(batch: usize, hw: usize) -> OpGraph {
    efficientnet(batch, hw, 1.6, 2.2)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ops::op::total_macs;

    #[test]
    fn b0_macs_near_reference() {
        // reference: ~0.39 GMACs @224
        let g = efficientnet_b0(1, 224);
        let gmacs = total_macs(&g) as f64 / 1e9;
        assert!((0.3..0.6).contains(&gmacs), "b0 gmacs={gmacs}");
    }

    #[test]
    fn b5_much_heavier() {
        // reference: 9.9 GMACs @456 (the EfficientNet paper's "FLOPS"
        // column counts multiply-adds)
        let g = efficientnet_b5(1, 456);
        let gmacs = total_macs(&g) as f64 / 1e9;
        assert!((8.0..13.0).contains(&gmacs), "b5 gmacs={gmacs}");
    }

    #[test]
    fn round_filters_matches_reference_points() {
        assert_eq!(round_filters(32, 1.0), 32);
        assert_eq!(round_filters(32, 1.6), 48); // B5 stem
        assert_eq!(round_filters(1280, 1.6), 2048);
    }

    #[test]
    fn b5_deeper_than_b0() {
        let b0 = efficientnet_b0(1, 224);
        let b5 = efficientnet_b5(1, 456);
        assert!(b5.n_nodes() as f64 > 1.7 * b0.n_nodes() as f64);
    }

    #[test]
    fn se_gives_mild_concurrency() {
        let g = efficientnet_b0(1, 224);
        let deg = crate::stream::logical_concurrency_degree(&g);
        assert!((1..=4).contains(&deg), "b0 deg={deg}");
    }
}
