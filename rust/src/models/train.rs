//! Training-step graph construction (Figure 8 / Figure 10 workloads).
//!
//! Given an inference (forward) graph, produce the graph of one training
//! iteration: forward ops, a loss node, one backward op per forward op
//! (reverse-mode: grads flow along reversed edges and also consume the
//! forward activations), and an optimizer step per parameterized op.
//!
//! Cost model for backward ops follows the standard 2× rule: computing
//! ∂L/∂input and ∂L/∂weights each costs about one forward pass, so a
//! backward op carries 2× the forward MACs/FLOPs/bytes. This reproduces
//! the roughly 3× total cost and ~3× op count of a training step, which is
//! what the batch-size-dependent speedups in Fig. 8/10 hinge on.

use crate::graph::NodeId;
use crate::ops::{Op, OpGraph, OpKind, Shape};

/// Build the training-step graph from a forward graph.
pub fn training_graph(fwd: &OpGraph) -> OpGraph {
    let mut g = fwd.clone();
    let order = crate::graph::topo_order(fwd).expect("training requires a DAG");

    // Loss node after the forward sink(s).
    let sinks = fwd.sinks();
    let loss_shape = Shape::new(&[1]);
    let sink_numel: u64 = sinks.iter().map(|&s| fwd.node(s).out_shape.numel() as u64).sum();
    let loss = g.add_node(Op {
        name: "loss".into(),
        kind: OpKind::Softmax, // cross-entropy ≈ softmax + reduction
        out_shape: loss_shape,
        dtype: fwd.node(sinks[0]).dtype,
        macs: 0,
        flops: 6 * sink_numel,
        bytes: 8 * sink_numel,
        params: 0,
    });
    for &s in &sinks {
        g.add_edge(s, loss);
    }

    // Backward ops in reverse topological order.
    let mut grad_of: Vec<Option<NodeId>> = vec![None; fwd.n_nodes()];
    for &v in order.iter().rev() {
        let op = fwd.node(v);
        if matches!(op.kind, OpKind::Input) {
            continue; // no gradient w.r.t. the data input
        }
        let gnode = g.add_node(Op {
            name: format!("{}_bwd", op.name),
            kind: OpKind::Grad { of: Box::new(op.kind.clone()) },
            out_shape: op.out_shape.clone(),
            dtype: op.dtype,
            macs: 2 * op.macs,
            flops: 2 * op.flops.max(1),
            bytes: 2 * op.bytes,
            params: 0,
        });
        // Depends on: the forward op's own output (activations), and the
        // grads of all forward successors (or the loss for sinks).
        g.add_edge(v, gnode);
        let succs = fwd.successors(v);
        if succs.is_empty() {
            g.add_edge(loss, gnode);
        }
        for &w in succs {
            match grad_of[w] {
                Some(gw) => g.add_edge(gw, gnode),
                None => g.add_edge(loss, gnode), // successor had no grad (input-like)
            }
        }
        grad_of[v] = Some(gnode);
    }

    // Optimizer step (SGD w/ momentum: read grad+param+velocity, write 2).
    for &v in &order {
        let op = fwd.node(v);
        if op.params == 0 {
            continue;
        }
        let Some(gv) = grad_of[v] else { continue };
        let step = g.add_node(Op {
            name: format!("{}_sgd", op.name),
            kind: OpKind::OptimizerStep,
            out_shape: Shape::new(&[op.params as usize]),
            dtype: op.dtype,
            macs: 0,
            flops: 4 * op.params,
            bytes: 20 * op.params,
            params: 0,
        });
        g.add_edge(gv, step);
    }
    debug_assert!(g.validate().is_ok());
    g
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models;
    use crate::ops::op::total_macs;

    #[test]
    fn train_graph_is_valid_and_bigger() {
        let fwd = models::build("mini_inception", 8);
        let train = training_graph(&fwd);
        assert!(train.validate().is_ok());
        assert!(train.n_nodes() > 2 * fwd.n_nodes(), "train should ~3× ops");
    }

    #[test]
    fn train_macs_about_three_times_forward() {
        let fwd = models::build("resnet50_cifar", 32);
        let train = training_graph(&fwd);
        let ratio = total_macs(&train) as f64 / total_macs(&fwd) as f64;
        assert!((2.7..3.3).contains(&ratio), "ratio={ratio}");
    }

    #[test]
    fn every_forward_op_has_a_backward() {
        let fwd = models::build("mini_inception", 1);
        let train = training_graph(&fwd);
        let n_fwd_real =
            fwd.nodes().filter(|(_, o)| !matches!(o.kind, OpKind::Input)).count();
        let n_bwd = train
            .nodes()
            .filter(|(_, o)| matches!(o.kind, OpKind::Grad { .. }))
            .count();
        assert_eq!(n_fwd_real, n_bwd);
    }

    #[test]
    fn optimizer_steps_match_parameterized_ops() {
        let fwd = models::build("mini_inception", 1);
        let train = training_graph(&fwd);
        let n_param_ops = fwd.nodes().filter(|(_, o)| o.params > 0).count();
        let n_sgd = train
            .nodes()
            .filter(|(_, o)| matches!(o.kind, OpKind::OptimizerStep))
            .count();
        assert_eq!(n_param_ops, n_sgd);
    }

    #[test]
    fn backward_preserves_concurrency_structure() {
        // A branchy forward graph yields a branchy backward graph: the
        // training graph's width should be ≥ the forward width.
        let fwd = models::build("mini_inception", 1);
        let train = training_graph(&fwd);
        let wf = crate::stream::logical_concurrency_degree(&fwd);
        let wt = crate::stream::logical_concurrency_degree(&train);
        assert!(wt >= wf, "train width {wt} < fwd width {wf}");
    }
}
