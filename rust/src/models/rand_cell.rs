//! Seeded random serving graphs for the differential harness.
//!
//! [`random_cell`] builds an NN-shaped operator graph — a stem followed
//! by blocks of parallel shape-preserving branches joined by adds — with
//! exactly one input and one sink, the contract
//! [`TapeEngine::from_graph_fn`](crate::serving::TapeEngine::from_graph_fn)
//! needs. Every op keeps the `[batch, C, H, W]` shape of the stem, so
//! any branch pair can join with `add` regardless of how the generator
//! wandered, and the per-example input/output lengths are independent of
//! the batch size (the serving engine requires that across buckets).
//! Structure depends only on the PRNG draws, never on `batch`, so the
//! same seed yields the same topology at every bucket.

use crate::ops::{GraphBuilder, OpGraph, OpKind};
use crate::util::Pcg32;

/// Fixed per-example geometry: small enough that a padded batch-16
/// output stays under the substrate's task clamp, big enough that the
/// synthetic kernels do real work.
const CHANNELS: usize = 4;
const SIDE: usize = 6;

/// Per-example flattened input/output length of every [`random_cell`].
pub const RANDOM_CELL_EXAMPLE_LEN: usize = CHANNELS * SIDE * SIDE;

/// One random shape-preserving op on top of `from`.
fn random_unary(b: &mut GraphBuilder, rng: &mut Pcg32, from: usize) -> usize {
    match rng.gen_range(8) {
        0 => b.relu(from),
        1 => b.bn(from),
        2 => b.act(from, OpKind::Tanh),
        3 => b.act(from, OpKind::Sigmoid),
        4 => b.conv(from, CHANNELS, 3, 1),
        5 => b.conv(from, CHANNELS, 1, 1),
        6 => b.dwconv(from, 3, 1),
        _ => b.maxpool(from, 3, 1),
    }
}

/// Build a random cell with roughly `max_nodes` operator nodes
/// (8 ≤ recommended `max_nodes` ≤ 64) at batch size `batch`.
pub fn random_cell(rng: &mut Pcg32, max_nodes: usize, batch: usize) -> OpGraph {
    assert!(batch >= 1, "batch must be >= 1");
    let budget = max_nodes.max(4);
    let mut b = GraphBuilder::new();
    let input = b.input(&[batch, CHANNELS, SIDE, SIDE]);
    // Stem: one op so the input node has a single consumer block below.
    let mut prev = random_unary(&mut b, rng, input);
    while b.graph().n_nodes() < budget {
        let n_branches = rng.gen_range_inclusive(1, 3);
        let mut outs = Vec::with_capacity(n_branches);
        for _ in 0..n_branches {
            let len = rng.gen_range_inclusive(1, 3);
            let mut cur = prev;
            for _ in 0..len {
                cur = random_unary(&mut b, rng, cur);
            }
            outs.push(cur);
        }
        // Join the branches pairwise with adds (shape-preserving).
        let mut joined = outs[0];
        for &o in &outs[1..] {
            joined = b.add(joined, o);
        }
        prev = joined;
    }
    b.finish()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn random_cells_are_valid_single_input_single_sink() {
        let mut rng = Pcg32::new(0xA11CE);
        for _ in 0..20 {
            let n = 8 + rng.gen_range(57); // 8..=64
            let g = random_cell(&mut rng, n, 1);
            assert!(g.validate().is_ok());
            assert_eq!(g.sources().len(), 1, "exactly one input");
            assert_eq!(g.sinks().len(), 1, "exactly one output");
            assert!(g.n_nodes() >= 4);
        }
    }

    #[test]
    fn same_seed_same_topology_across_batches() {
        let a = random_cell(&mut Pcg32::new(99), 32, 1);
        let b = random_cell(&mut Pcg32::new(99), 32, 8);
        assert_eq!(a.n_nodes(), b.n_nodes());
        for v in 0..a.n_nodes() {
            assert_eq!(a.predecessors(v), b.predecessors(v), "node {v} wiring");
            // shapes differ only in the batch dim
            assert_eq!(
                a.node(v).out_shape.numel() * 8,
                b.node(v).out_shape.numel(),
                "node {v} shape scales with batch"
            );
        }
    }

    #[test]
    fn example_len_is_batch_independent() {
        for batch in [1usize, 2, 8, 16] {
            let g = random_cell(&mut Pcg32::new(7), 24, batch);
            let input = g.sources()[0];
            assert_eq!(g.node(input).out_shape.numel() / batch, RANDOM_CELL_EXAMPLE_LEN);
            let sink = g.sinks()[0];
            assert_eq!(g.node(sink).out_shape.numel() % batch, 0);
        }
    }
}
