//! The paper's §1 motivation examples beyond NAS cells: layers "that
//! consist of smaller operators arranged in parallel" — MixConv (Tan & Le
//! 2019b) and ResNeSt's Split-Attention block (Zhang et al. 2020). Both
//! create intra-layer operator parallelism that only a multi-stream
//! scheduler can exploit; they extend the Fig. 7 evaluation as the
//! "future-work" workloads the paper points at.

use crate::graph::NodeId;
use crate::ops::{GraphBuilder, OpGraph, OpKind};

/// MixConv: split channels into `kernels.len()` groups, run a depthwise
/// conv with a different kernel size on each group in parallel, concat.
fn mixconv(b: &mut GraphBuilder, x: NodeId, kernels: &[usize], stride: usize) -> NodeId {
    let c = b.out_shape(x).dim(1);
    let n_groups = kernels.len();
    let per = c / n_groups;
    let mut outs = Vec::with_capacity(n_groups);
    for (gi, &k) in kernels.iter().enumerate() {
        let slice_c = if gi + 1 == n_groups { c - per * (n_groups - 1) } else { per };
        let sl = b.slice_channels(x, slice_c);
        let d = b.dwconv(sl, k, stride);
        let d = b.bn(d);
        outs.push(d);
    }
    b.concat(&outs)
}

/// MixNet-style inverted residual with a MixConv middle.
fn mix_block(
    b: &mut GraphBuilder,
    x: NodeId,
    in_c: usize,
    out_c: usize,
    kernels: &[usize],
    stride: usize,
    expand: usize,
) -> NodeId {
    let mut y = x;
    if expand != 1 {
        y = b.conv(y, in_c * expand, 1, 1);
        y = b.bn(y);
        y = b.act(y, OpKind::Swish);
    }
    y = mixconv(b, y, kernels, stride);
    y = b.act(y, OpKind::Swish);
    y = b.conv_bn(y, out_c, 1, 1);
    if stride == 1 && in_c == out_c {
        y = b.add(y, x);
    }
    y
}

/// A MixNet-S-like network (224×224). Parallel depthwise groups per block.
pub fn mixnet_s(batch: usize) -> OpGraph {
    let mut b = GraphBuilder::new();
    let input = b.input(&[batch, 3, 224, 224]);
    let mut x = b.conv_bn_relu(input, 16, 3, 2);
    // (out_c, kernels, stride, expand) — mirrors MixNet-S's stage plan
    let cfg: &[(usize, &[usize], usize, usize)] = &[
        (16, &[3], 1, 1),
        (24, &[3], 2, 6),
        (24, &[3], 1, 3),
        (40, &[3, 5, 7], 2, 6),
        (40, &[3, 5], 1, 6),
        (80, &[3, 5, 7], 2, 6),
        (80, &[3, 5], 1, 6),
        (120, &[3, 5, 7], 1, 6),
        (120, &[3, 5, 7, 9], 1, 3),
        (200, &[3, 5, 7, 9, 11], 2, 6),
        (200, &[3, 5, 7, 9], 1, 6),
    ];
    let mut in_c = 16;
    for &(out_c, kernels, stride, expand) in cfg {
        x = mix_block(&mut b, x, in_c, out_c, kernels, stride, expand);
        in_c = out_c;
    }
    x = b.conv_bn_relu(x, 1536, 1, 1);
    let g = b.gap(x);
    let _ = b.linear(g, 1000);
    b.finish()
}

/// ResNeSt Split-Attention block: `radix` parallel conv branches whose
/// outputs are fused by a learned soft attention over the splits.
fn split_attention_block(
    b: &mut GraphBuilder,
    x: NodeId,
    mid_c: usize,
    out_c: usize,
    stride: usize,
    radix: usize,
    downsample: bool,
) -> NodeId {
    let reduced = b.conv_bn_relu(x, mid_c, 1, 1);
    // radix parallel 3×3 conv branches
    let splits: Vec<NodeId> =
        (0..radix).map(|_| b.conv_bn_relu(reduced, mid_c, 3, stride)).collect();
    // gap over the sum → dense → per-split softmax gates → weighted sum
    let mut sum = splits[0];
    for &s in &splits[1..] {
        sum = b.add(sum, s);
    }
    let gap = b.gap(sum);
    let attn = b.conv(gap, (mid_c / 4).max(8), 1, 1);
    let attn = b.relu(attn);
    let attn = b.conv(attn, mid_c * radix, 1, 1);
    let gates = b.softmax(attn);
    let mut fused: Option<NodeId> = None;
    for &s in &splits {
        let gated = b.mul(s, gates);
        fused = Some(match fused {
            None => gated,
            Some(f) => b.add(f, gated),
        });
    }
    let y = b.conv_bn(fused.unwrap(), out_c, 1, 1);
    let shortcut = if downsample { b.conv_bn(x, out_c, 1, stride) } else { x };
    let s = b.add(y, shortcut);
    b.relu(s)
}

/// A ResNeSt-50-like network (radix 2).
pub fn resnest50(batch: usize) -> OpGraph {
    let mut b = GraphBuilder::new();
    let input = b.input(&[batch, 3, 224, 224]);
    let s = b.conv_bn_relu(input, 64, 7, 2);
    let mut x = b.maxpool(s, 3, 2);
    let stages = [(64usize, 256usize, 3usize), (128, 512, 4), (256, 1024, 6), (512, 2048, 3)];
    for (stage, &(mid, out, blocks)) in stages.iter().enumerate() {
        for i in 0..blocks {
            let stride = if stage > 0 && i == 0 { 2 } else { 1 };
            x = split_attention_block(&mut b, x, mid, out, stride, 2, i == 0);
        }
    }
    let g = b.gap(x);
    let _ = b.linear(g, 1000);
    b.finish()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ops::op::total_macs;
    use crate::stream::logical_concurrency_degree;

    #[test]
    fn mixnet_builds_with_parallel_depthwise_groups() {
        let g = mixnet_s(1);
        assert!(g.validate().is_ok());
        let deg = logical_concurrency_degree(&g);
        assert!(deg >= 4, "mixconv groups should be parallel: deg={deg}");
    }

    #[test]
    fn mixnet_macs_small() {
        // MixNet-S reference: ~0.26 GMACs
        let gmacs = total_macs(&mixnet_s(1)) as f64 / 1e9;
        assert!((0.1..0.8).contains(&gmacs), "mixnet gmacs={gmacs}");
    }

    #[test]
    fn resnest_builds_with_radix_parallelism() {
        let g = resnest50(1);
        assert!(g.validate().is_ok());
        let deg = logical_concurrency_degree(&g);
        assert!(deg >= 2, "radix-2 branches independent: deg={deg}");
    }

    #[test]
    fn resnest_heavier_than_resnet50() {
        // ResNeSt-50: ~5.4 GMACs (vs ResNet-50's 4.1)
        let rs = total_macs(&resnest50(1)) as f64 / 1e9;
        let rn = total_macs(&crate::models::resnet::resnet50(1, 224)) as f64 / 1e9;
        assert!(rs > rn, "resnest {rs} should exceed resnet {rn}");
        assert!(rs < 10.0);
    }

    #[test]
    fn multi_stream_helps_both_extensions() {
        use crate::baselines::{simulate_inference, Baseline};
        use crate::sim::GpuSpec;
        let dev = GpuSpec::v100();
        for g in [mixnet_s(1), resnest50(1)] {
            let single = simulate_inference(&g, Baseline::NimbleSingleStream, &dev).total_s;
            let multi = simulate_inference(&g, Baseline::Nimble, &dev).total_s;
            assert!(multi <= single, "multi {multi} vs single {single}");
        }
    }
}
