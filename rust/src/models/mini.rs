//! MiniInception — the small branchy CNN whose per-operator XLA artifacts
//! drive the *real* execution path (runtime + AoT replay engine).
//!
//! The Rust graph here and the JAX model in `python/compile/model.py` are
//! the same architecture op-for-op; `runtime::manifest` maps each operator
//! node to its compiled HLO artifact by name. Keep the two in sync — the
//! integration test `integration_runtime.rs` cross-checks shapes.
//!
//! Architecture (CIFAR-scale, 3×32×32 inputs):
//!   stem:   conv3×3(16) + relu
//!   block1: [1×1(16) | 3×3(16) | 5×5(8) | maxpool3+1×1(8)] → concat (48)
//!   block2: [1×1(24) | 3×3(24) | 5×5(12) | maxpool3+1×1(12)] → concat (72)
//!   head:   GAP → linear(10)

use crate::graph::NodeId;
use crate::ops::{GraphBuilder, OpGraph};

/// Channel plan for one inception block.
#[derive(Debug, Clone, Copy)]
pub struct BlockPlan {
    pub b1x1: usize,
    pub b3x3: usize,
    pub b5x5: usize,
    pub bpool: usize,
}

pub const BLOCK1: BlockPlan = BlockPlan { b1x1: 16, b3x3: 16, b5x5: 8, bpool: 8 };
pub const BLOCK2: BlockPlan = BlockPlan { b1x1: 24, b3x3: 24, b5x5: 12, bpool: 12 };

fn block(b: &mut GraphBuilder, x: NodeId, plan: BlockPlan) -> NodeId {
    let c1 = b.conv(x, plan.b1x1, 1, 1);
    let r1 = b.relu(c1);
    let c3 = b.conv(x, plan.b3x3, 3, 1);
    let r3 = b.relu(c3);
    let c5 = b.conv(x, plan.b5x5, 5, 1);
    let r5 = b.relu(c5);
    let p = b.maxpool(x, 3, 1);
    let cp = b.conv(p, plan.bpool, 1, 1);
    let rp = b.relu(cp);
    b.concat(&[r1, r3, r5, rp])
}

/// Build the MiniInception operator graph.
pub fn mini_inception(batch: usize) -> OpGraph {
    let mut b = GraphBuilder::new();
    let input = b.input(&[batch, 3, 32, 32]);
    let stem = b.conv(input, 16, 3, 1);
    let stem = b.relu(stem);
    let b1 = block(&mut b, stem, BLOCK1);
    let b2 = block(&mut b, b1, BLOCK2);
    let g = b.gap(b2);
    let _ = b.linear(g, 10);
    b.finish()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::stream::logical_concurrency_degree;

    #[test]
    fn structure() {
        let g = mini_inception(8);
        assert!(g.validate().is_ok());
        // input + stem(2) + 2 blocks (9 each + concat counted) + gap + fc
        assert_eq!(g.n_nodes(), 25);
        assert_eq!(g.sinks().len(), 1);
    }

    #[test]
    fn four_way_parallel_blocks() {
        let g = mini_inception(1);
        let deg = logical_concurrency_degree(&g);
        assert_eq!(deg, 4, "each block has 4 independent branches");
    }

    #[test]
    fn output_is_ten_classes() {
        let g = mini_inception(8);
        let sink = g.sinks()[0];
        assert_eq!(g.node(sink).out_shape.0, vec![8, 10]);
    }

    #[test]
    fn concat_channels() {
        let g = mini_inception(1);
        // block1 concat = 48ch, block2 concat = 72ch
        let concats: Vec<_> = g
            .nodes()
            .filter(|(_, o)| matches!(o.kind, crate::ops::OpKind::Concat))
            .map(|(_, o)| o.out_shape.dim(1))
            .collect();
        assert_eq!(concats, vec![48, 72]);
    }
}
