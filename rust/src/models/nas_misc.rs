//! DARTS (Liu et al. 2019) and AmoebaNet-A (Real et al. 2019) ImageNet
//! models — the remaining NAS entries of Table 1 (Deg. 7 and 11, 0.5 GMACs
//! each).
//!
//! Both use the standard NAS search-space cell: 4 intermediate nodes, each
//! the sum of two operations over earlier states; cell output concatenates
//! the intermediate nodes. Genotypes follow the published architectures
//! (DARTS second-order genotype; AmoebaNet-A's pool-heavy normal cell).

use crate::graph::NodeId;
use crate::ops::{GraphBuilder, OpGraph};

/// One primitive of the NAS search space applied to state `x`.
#[derive(Debug, Clone, Copy)]
enum Prim {
    Sep3,
    Sep5,
    Dil3,
    Skip,
    Max3,
    Avg3,
}

fn apply(b: &mut GraphBuilder, p: Prim, x: NodeId, c: usize, stride: usize) -> NodeId {
    match p {
        Prim::Sep3 => b.sep_conv(x, c, 3, stride),
        Prim::Sep5 => b.sep_conv(x, c, 5, stride),
        // Dilated conv: model as relu → dw3×3(s) → pw → bn (half a sep conv;
        // same MAC count as sep3 at dilation 2's receptive field).
        Prim::Dil3 => {
            let y = b.relu(x);
            let y = b.dwconv(y, 3, stride);
            let y = b.conv(y, c, 1, 1);
            b.bn(y)
        }
        Prim::Skip => {
            if stride == 1 {
                b.identity(x)
            } else {
                // factorized reduce
                b.conv_bn(x, c, 1, stride)
            }
        }
        Prim::Max3 => b.maxpool(x, 3, stride),
        Prim::Avg3 => b.avgpool(x, 3, stride),
    }
}

/// A NAS cell: `genotype` lists, per intermediate node, two (primitive,
/// input-state-index) pairs; states 0/1 are the two cell inputs, 2+ are the
/// intermediate nodes in order. Returns concat of the 4 intermediates.
fn nas_cell(
    b: &mut GraphBuilder,
    h_prev: NodeId,
    h: NodeId,
    c: usize,
    reduction: bool,
    genotype: &[((Prim, usize), (Prim, usize))],
) -> NodeId {
    // fit inputs (factorized-reduce the skip input if its spatial dims are
    // larger — happens in the cell right after a reduction)
    let fit = |b: &mut GraphBuilder, x: NodeId, stride: usize| {
        let y = b.relu(x);
        let y = b.conv(y, c, 1, stride);
        b.bn(y)
    };
    let stride_p =
        b.out_shape(h_prev).dim(2).div_ceil(b.out_shape(h).dim(2)).max(1);
    let s0 = fit(b, h_prev, stride_p);
    let s1 = fit(b, h, 1);
    let mut states = vec![s0, s1];
    for &((p1, i1), (p2, i2)) in genotype {
        // In a reduction cell, ops reading the cell inputs use stride 2.
        let str1 = if reduction && i1 < 2 { 2 } else { 1 };
        let str2 = if reduction && i2 < 2 { 2 } else { 1 };
        let a = apply(b, p1, states[i1], c, str1);
        let bnode = apply(b, p2, states[i2], c, str2);
        states.push(b.add(a, bnode));
    }
    b.concat(&states[2..])
}

/// DARTS (second-order) genotype.
const DARTS_NORMAL: [((Prim, usize), (Prim, usize)); 4] = [
    ((Prim::Sep3, 0), (Prim::Sep3, 1)),
    ((Prim::Sep3, 0), (Prim::Sep3, 1)),
    ((Prim::Sep3, 1), (Prim::Skip, 0)),
    ((Prim::Skip, 0), (Prim::Dil3, 2)),
];
const DARTS_REDUCE: [((Prim, usize), (Prim, usize)); 4] = [
    ((Prim::Max3, 0), (Prim::Max3, 1)),
    ((Prim::Skip, 2), (Prim::Max3, 1)),
    ((Prim::Max3, 0), (Prim::Skip, 2)),
    ((Prim::Skip, 2), (Prim::Max3, 1)),
];

/// AmoebaNet-A-style genotype (pool/skip-heavy normal cell).
const AMOEBA_NORMAL: [((Prim, usize), (Prim, usize)); 4] = [
    ((Prim::Avg3, 0), (Prim::Max3, 1)),
    ((Prim::Sep3, 0), (Prim::Skip, 1)),
    ((Prim::Sep3, 1), (Prim::Sep5, 0)),
    ((Prim::Avg3, 1), (Prim::Sep3, 1)),
];
const AMOEBA_REDUCE: [((Prim, usize), (Prim, usize)); 4] = [
    ((Prim::Avg3, 0), (Prim::Sep3, 1)),
    ((Prim::Max3, 0), (Prim::Sep7ish, 1)),
    ((Prim::Avg3, 0), (Prim::Sep5, 1)),
    ((Prim::Skip, 2), (Prim::Max3, 0)),
];

// `Sep7ish` is not a real variant — alias to Sep5 at compile time.
#[allow(non_upper_case_globals)]
impl Prim {
    #[allow(non_upper_case_globals)]
    const Sep7ish: Prim = Prim::Sep5;
}

/// Shared ImageNet scaffold: 2-conv stem (4× downsample), 3 stacks of
/// cells with reductions between, GAP + classifier.
fn nas_imagenet(
    batch: usize,
    c0: usize,
    cells_per_stack: usize,
    normal: &[((Prim, usize), (Prim, usize))],
    reduce: &[((Prim, usize), (Prim, usize))],
) -> OpGraph {
    let mut b = GraphBuilder::new();
    let input = b.input(&[batch, 3, 224, 224]);
    // ImageNet stem: 8× downsample before the first cell (DARTS §"ImageNet"
    // setup) — cells run at 28×28 / 14×14 / 7×7.
    let s0a = b.conv_bn_relu(input, c0 / 2, 3, 2);
    let s0 = b.conv_bn(s0a, c0, 3, 2);
    let s1r = b.relu(s0);
    let s1 = b.conv_bn(s1r, c0, 3, 2);
    let (mut h_prev, mut h) = (s0, s1);
    let mut c = c0;
    for stack in 0..3 {
        if stack > 0 {
            c *= 2;
            let r = nas_cell(&mut b, h_prev, h, c, true, reduce);
            h_prev = h;
            h = r;
        }
        for _ in 0..cells_per_stack {
            let n = nas_cell(&mut b, h_prev, h, c, false, normal);
            h_prev = h;
            h = n;
        }
    }
    let x = b.relu(h);
    let g = b.gap(x);
    let _ = b.linear(g, 1000);
    b.finish()
}

/// DARTS ImageNet model. Paper Table 1: 0.5 GMACs, Deg. 7.
pub fn darts_imagenet(batch: usize) -> OpGraph {
    nas_imagenet(batch, 48, 4, &DARTS_NORMAL, &DARTS_REDUCE)
}

/// AmoebaNet-A ImageNet model. Paper Table 1: 0.5 GMACs, Deg. 11.
pub fn amoebanet_a(batch: usize) -> OpGraph {
    nas_imagenet(batch, 52, 4, &AMOEBA_NORMAL, &AMOEBA_REDUCE)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ops::op::total_macs;
    use crate::stream::logical_concurrency_degree;

    #[test]
    fn darts_macs_match_paper() {
        let g = darts_imagenet(1);
        let gmacs = total_macs(&g) as f64 / 1e9;
        assert!((0.3..0.9).contains(&gmacs), "darts gmacs={gmacs}");
    }

    #[test]
    fn amoebanet_macs_match_paper() {
        let g = amoebanet_a(1);
        let gmacs = total_macs(&g) as f64 / 1e9;
        assert!((0.3..0.9).contains(&gmacs), "amoeba gmacs={gmacs}");
    }

    #[test]
    fn concurrency_degrees_near_paper() {
        // Paper: DARTS 7, AmoebaNet 11. Cross-cell skip connections make the
        // measured width sensitive to exact genotype wiring; accept a band
        // around the paper's values.
        let d = logical_concurrency_degree(&darts_imagenet(1));
        let a = logical_concurrency_degree(&amoebanet_a(1));
        assert!((5..=12).contains(&d), "darts deg={d}");
        assert!((6..=14).contains(&a), "amoeba deg={a}");
    }

    #[test]
    fn both_are_valid_dags() {
        assert!(darts_imagenet(1).validate().is_ok());
        assert!(amoebanet_a(1).validate().is_ok());
    }
}
