//! NASNet-A mobile / large (Zoph et al. 2018) — the paper's headline
//! networks: multi-branch NAS cells built from separable convolutions and
//! pools, hundreds of tiny kernels, Deg. 12 (mobile) / 15 (large) in
//! Table 1, and the 22.34× Nimble-vs-PyTorch inference speedup in Fig. 7.
//!
//! Cell wiring follows the NASNet-A genotype (Zoph et al., Fig. 4): five
//! combine (Add) nodes per cell over {sep3×3, sep5×5, sep7×7, avg3×3,
//! max3×3, identity} applied to the two cell inputs, outputs concatenated.
//! Each separable conv is itself 8 operators (2 × relu/dw/pw/bn), which is
//! exactly why NAS networks are launch-overhead-bound on real frameworks.

use crate::graph::NodeId;
use crate::ops::{GraphBuilder, OpGraph};

/// Fit a cell input to `c` channels (relu → 1×1 conv → bn), with optional
/// spatial stride for skip inputs crossing a reduction boundary.
fn fit(b: &mut GraphBuilder, x: NodeId, c: usize, stride: usize) -> NodeId {
    let y = b.relu(x);
    let y = b.conv(y, c, 1, stride);
    b.bn(y)
}

/// NASNet-A normal cell. Returns the concat output (6·c channels).
/// When `h_prev` has larger spatial dims than `h` (the cell right after a
/// reduction), the skip input is factorized-reduced via a strided 1×1 fit.
fn normal_cell(b: &mut GraphBuilder, h_prev: NodeId, h: NodeId, c: usize) -> NodeId {
    // Input adaptation.
    let stride_p = b.out_shape(h_prev).dim(2).div_ceil(b.out_shape(h).dim(2));
    let p = fit(b, h_prev, c, stride_p.max(1));
    let x = fit(b, h, c, 1);
    // Five combines (genotype of NASNet-A normal cell).
    let s1 = b.sep_conv(x, c, 3, 1);
    let b1 = b.add(s1, x);
    let s2a = b.sep_conv(p, c, 3, 1);
    let s2b = b.sep_conv(x, c, 5, 1);
    let b2 = b.add(s2a, s2b);
    let a3 = b.avgpool(x, 3, 1);
    let b3 = b.add(a3, p);
    let a4a = b.avgpool(p, 3, 1);
    let a4b = b.avgpool(p, 3, 1);
    let b4 = b.add(a4a, a4b);
    let s5a = b.sep_conv(p, c, 5, 1);
    let s5b = b.sep_conv(p, c, 3, 1);
    let b5 = b.add(s5a, s5b);
    b.concat(&[x, b1, b2, b3, b4, b5])
}

/// NASNet-A reduction cell (stride-2). Returns the concat output (4·c).
fn reduction_cell(b: &mut GraphBuilder, h_prev: NodeId, h: NodeId, c: usize) -> NodeId {
    // The skip input must end up at the same spatial dims as `h` before the
    // cell's own stride-2 ops are applied.
    let stride_p = b.out_shape(h_prev).dim(2).div_ceil(b.out_shape(h).dim(2));
    let p = fit(b, h_prev, c, stride_p.max(1));
    let x = fit(b, h, c, 1);
    let s1a = b.sep_conv(x, c, 5, 2);
    let s1b = b.sep_conv(p, c, 7, 2);
    let b1 = b.add(s1a, s1b);
    let m2a = b.maxpool(x, 3, 2);
    let s2b = b.sep_conv(p, c, 7, 2);
    let b2 = b.add(m2a, s2b);
    let a3a = b.avgpool(x, 3, 2);
    let s3b = b.sep_conv(p, c, 5, 2);
    let b3 = b.add(a3a, s3b);
    let m4a = b.maxpool(x, 3, 2);
    let s4b = b.sep_conv(b1, c, 3, 1);
    let b4 = b.add(m4a, s4b);
    let a5a = b.avgpool(b1, 3, 1);
    let b5 = b.add(a5a, b2);
    b.concat(&[b3, b4, b5, b2])
}

/// Generic NASNet-A: `cells_per_stack` normal cells between reductions,
/// base filter count `c0`, ImageNet stem.
pub fn nasnet_a(batch: usize, hw: usize, cells_per_stack: usize, c0: usize) -> OpGraph {
    let mut b = GraphBuilder::new();
    let input = b.input(&[batch, 3, hw, hw]);
    // Stem: 3×3/s2 conv, then two reduction cells at c0/4 and c0/2
    // (mirrors the reference implementation's stem0/stem1).
    let stem = b.conv_bn(input, 32, 3, 2);
    let r0 = reduction_cell(&mut b, stem, stem, (c0 / 4).max(8));
    let r1 = reduction_cell(&mut b, stem, r0, (c0 / 2).max(8));
    let (mut h_prev, mut h) = (r0, r1);
    let mut c = c0;
    for stack in 0..3 {
        if stack > 0 {
            c *= 2;
            let r = reduction_cell(&mut b, h_prev, h, c);
            h_prev = h;
            h = r;
        }
        for _ in 0..cells_per_stack {
            let n = normal_cell(&mut b, h_prev, h, c);
            h_prev = h;
            h = n;
        }
    }
    let x = b.relu(h);
    let g = b.gap(x);
    let _ = b.linear(g, 1000);
    b.finish()
}

/// NASNet-A (mobile): 4 cells per stack, 44 base filters, 224×224.
/// Paper Table 1: 0.6 GMACs, Deg. 12.
pub fn nasnet_a_mobile(batch: usize) -> OpGraph {
    nasnet_a(batch, 224, 4, 44)
}

/// NASNet-A (large): 6 cells per stack, 168 base filters, 331×331.
/// Paper Table 1: 23.9 GMACs, Deg. 15.
pub fn nasnet_a_large(batch: usize) -> OpGraph {
    nasnet_a(batch, 331, 6, 168)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ops::op::total_macs;
    use crate::stream::logical_concurrency_degree;

    #[test]
    fn mobile_macs_match_paper() {
        // Paper Table 1: 0.6 GMACs
        let g = nasnet_a_mobile(1);
        let gmacs = total_macs(&g) as f64 / 1e9;
        assert!((0.35..0.95).contains(&gmacs), "nasnet mobile gmacs={gmacs}");
    }

    #[test]
    fn large_macs_match_paper() {
        // Paper Table 1: 23.9 GMACs
        let g = nasnet_a_large(1);
        let gmacs = total_macs(&g) as f64 / 1e9;
        assert!((16.0..32.0).contains(&gmacs), "nasnet large gmacs={gmacs}");
    }

    #[test]
    fn mobile_has_hundreds_of_ops() {
        // The reason for the 22× speedup: a sea of small kernels.
        let g = nasnet_a_mobile(1);
        assert!(g.n_nodes() > 500, "n={}", g.n_nodes());
    }

    #[test]
    fn high_logical_concurrency() {
        // Paper: Deg 12 (mobile), 15 (large). Ranges allow block-level
        // approximation differences.
        let m = logical_concurrency_degree(&nasnet_a_mobile(1));
        assert!((8..=16).contains(&m), "mobile deg={m}");
    }

    #[test]
    fn large_wider_than_mobile() {
        let m = logical_concurrency_degree(&nasnet_a_mobile(1));
        let l = logical_concurrency_degree(&nasnet_a_large(1));
        assert!(l >= m, "large deg {l} < mobile deg {m}");
    }
}
