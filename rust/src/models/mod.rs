//! Model zoo: operator-graph builders for every network in the paper's
//! evaluation (Figures 2/7/8/9/10, Table 1), plus the CIFAR training
//! variants and the MiniInception network whose per-operator XLA artifacts
//! drive the real execution path.
//!
//! Builders reconstruct each architecture at operator granularity (conv,
//! bn, activation, pool, add, concat as separate nodes — the granularity a
//! PyTorch-like eager runtime schedules at). MAC counts are validated
//! against the paper's Table 1 in `integration_models.rs`.

pub mod bert;
pub mod efficientnet;
pub mod inception;
pub mod mini;
pub mod mobilenet;
pub mod modern;
pub mod nas_misc;
pub mod nasnet;
pub mod rand_cell;
pub mod resnet;
pub mod train;

use crate::ops::OpGraph;

/// A named model the harness can build.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct ModelSpec {
    pub name: &'static str,
    /// Input resolution (images) or sequence length (BERT).
    pub resolution: usize,
    /// Paper-reported GMACs where available (Table 1), for validation.
    pub paper_gmacs: Option<f64>,
}

/// Every model in the zoo.
pub const MODELS: &[ModelSpec] = &[
    ModelSpec { name: "resnet50", resolution: 224, paper_gmacs: None },
    ModelSpec { name: "resnet101", resolution: 224, paper_gmacs: None },
    ModelSpec { name: "inception_v3", resolution: 299, paper_gmacs: Some(5.7) },
    ModelSpec { name: "mobilenet_v2", resolution: 224, paper_gmacs: None },
    ModelSpec { name: "efficientnet_b0", resolution: 224, paper_gmacs: None },
    ModelSpec { name: "efficientnet_b5", resolution: 456, paper_gmacs: None },
    ModelSpec { name: "nasnet_a_mobile", resolution: 224, paper_gmacs: Some(0.6) },
    ModelSpec { name: "nasnet_a_large", resolution: 331, paper_gmacs: Some(23.9) },
    ModelSpec { name: "darts", resolution: 224, paper_gmacs: Some(0.5) },
    ModelSpec { name: "amoebanet", resolution: 224, paper_gmacs: Some(0.5) },
    ModelSpec { name: "bert_base", resolution: 128, paper_gmacs: None },
    ModelSpec { name: "resnet50_cifar", resolution: 32, paper_gmacs: None },
    ModelSpec { name: "mobilenet_v2_cifar", resolution: 32, paper_gmacs: None },
    ModelSpec { name: "efficientnet_b0_cifar", resolution: 32, paper_gmacs: None },
    ModelSpec { name: "mini_inception", resolution: 32, paper_gmacs: None },
    // §1-motivation extensions (MixConv / Split-Attention parallel layers)
    ModelSpec { name: "mixnet_s", resolution: 224, paper_gmacs: None },
    ModelSpec { name: "resnest50", resolution: 224, paper_gmacs: None },
];

/// Build a model's inference graph by name.
pub fn build(name: &str, batch: usize) -> OpGraph {
    match name {
        "resnet50" => resnet::resnet50(batch, 224),
        "resnet101" => resnet::resnet101(batch, 224),
        "inception_v3" => inception::inception_v3(batch),
        "mobilenet_v2" => mobilenet::mobilenet_v2(batch, 224),
        "efficientnet_b0" => efficientnet::efficientnet_b0(batch, 224),
        "efficientnet_b5" => efficientnet::efficientnet_b5(batch, 456),
        "nasnet_a_mobile" => nasnet::nasnet_a_mobile(batch),
        "nasnet_a_large" => nasnet::nasnet_a_large(batch),
        "darts" => nas_misc::darts_imagenet(batch),
        "amoebanet" => nas_misc::amoebanet_a(batch),
        "bert_base" => bert::bert_base(batch, 128),
        "resnet50_cifar" => resnet::resnet50_cifar(batch),
        "mobilenet_v2_cifar" => mobilenet::mobilenet_v2(batch, 32),
        "efficientnet_b0_cifar" => efficientnet::efficientnet_b0(batch, 32),
        "mini_inception" => mini::mini_inception(batch),
        "mixnet_s" => modern::mixnet_s(batch),
        "resnest50" => modern::resnest50(batch),
        other => panic!("unknown model `{other}`; known: {:?}", names()),
    }
}

/// Build a model's *training-step* graph (forward + backward + optimizer).
pub fn build_train(name: &str, batch: usize) -> OpGraph {
    train::training_graph(&build(name, batch))
}

/// All model names.
pub fn names() -> Vec<&'static str> {
    MODELS.iter().map(|m| m.name).collect()
}

/// Spec lookup.
pub fn spec(name: &str) -> Option<&'static ModelSpec> {
    MODELS.iter().find(|m| m.name == name)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn every_model_builds_a_valid_dag() {
        for m in MODELS {
            let g = build(m.name, 1);
            assert!(g.validate().is_ok(), "{} invalid", m.name);
            assert!(g.n_nodes() > 10, "{} suspiciously small", m.name);
        }
    }

    #[test]
    #[should_panic(expected = "unknown model")]
    fn unknown_model_panics() {
        build("not_a_model", 1);
    }

    #[test]
    fn spec_lookup() {
        assert!(spec("inception_v3").is_some());
        assert!(spec("nope").is_none());
    }
}
