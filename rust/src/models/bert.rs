//! BERT-base (Devlin et al. 2019), sequence length 128 — the paper's
//! transformer training workload (Figure 8, marginal speedup case: large
//! matmuls hide scheduling overhead).

use crate::graph::NodeId;
use crate::ops::{GraphBuilder, OpGraph, OpKind};

/// One transformer encoder layer.
fn encoder_layer(
    b: &mut GraphBuilder,
    x: NodeId,
    batch: usize,
    seq: usize,
    hidden: usize,
    heads: usize,
    ffn: usize,
) -> NodeId {
    let head_dim = hidden / heads;
    // Q, K, V projections — three *independent* matmuls (the transformer's
    // inter-operator parallelism Nimble can put on different streams).
    let q = b.linear(x, hidden);
    let k = b.linear(x, hidden);
    let v = b.linear(x, hidden);
    // scores = Q·Kᵀ over heads: (B·h, S, d) × (B·h, d, S)
    let scores = b.matmul(q, k, &[batch * heads, seq, seq], (seq, seq, head_dim));
    let probs = b.softmax(scores);
    // context = probs·V, merged back to (B, S, H)
    let ctx = b.matmul(probs, v, &[batch * heads, seq, head_dim], (seq, head_dim, seq));
    let ctx = b.reshape(ctx, &[batch, seq, hidden]);
    let out = b.linear(ctx, hidden);
    let res1 = b.add(out, x);
    let ln1 = b.layernorm(res1);
    // FFN
    let f1 = b.linear(ln1, ffn);
    let g = b.act(f1, OpKind::GeLU);
    let f2 = b.linear(g, hidden);
    let res2 = b.add(f2, ln1);
    b.layernorm(res2)
}

/// BERT-base: 12 layers, hidden 768, 12 heads, FFN 3072.
pub fn bert_base(batch: usize, seq: usize) -> OpGraph {
    bert(batch, seq, 12, 768, 12, 3072)
}

pub fn bert(
    batch: usize,
    seq: usize,
    layers: usize,
    hidden: usize,
    heads: usize,
    ffn: usize,
) -> OpGraph {
    let mut b = GraphBuilder::new();
    let tokens = b.input(&[batch, seq]);
    let mut x = b.embedding(tokens, hidden, 30_522);
    x = b.layernorm(x);
    for _ in 0..layers {
        x = encoder_layer(&mut b, x, batch, seq, hidden, heads, ffn);
    }
    // pooler ([CLS] token) + classifier head
    let cls = b.reshape(x, &[batch * seq, hidden]);
    let pooled = b.linear(cls, hidden);
    let t = b.act(pooled, OpKind::Tanh);
    let _ = b.linear(t, 2);
    b.finish()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ops::op::total_macs;

    #[test]
    fn macs_near_reference() {
        // BERT-base fwd @seq128 batch1: ~11.2 GFLOPs ⇒ ~5.6 GMACs... but the
        // standard count (4 proj + 2 attn + 2 ffn matmuls) gives ~11 GMACs
        // per batch... verify against the analytic formula instead:
        let g = bert_base(1, 128);
        let analytic: u64 = {
            let (s, h, f, l, nh) = (128u64, 768u64, 3072u64, 12u64, 12u64);
            let proj = 4 * s * h * h;
            let attn = 2 * s * s * (h / nh) * nh;
            let ffn = 2 * s * h * f;
            l * (proj + attn + ffn)
        };
        let macs = total_macs(&g);
        let ratio = macs as f64 / analytic as f64;
        assert!((0.9..1.2).contains(&ratio), "macs={macs} analytic={analytic}");
    }

    #[test]
    fn qkv_projections_are_parallel() {
        let g = bert_base(1, 128);
        let deg = crate::stream::logical_concurrency_degree(&g);
        assert!((2..=4).contains(&deg), "bert deg={deg}");
    }

    #[test]
    fn batch_scales_macs() {
        let m1 = total_macs(&bert_base(1, 128));
        let m4 = total_macs(&bert_base(4, 128));
        assert!((3.6..4.4).contains(&(m4 as f64 / m1 as f64)));
    }

    #[test]
    fn layer_count_reflected_in_ops() {
        let g12 = bert_base(1, 128);
        let g2 = bert(1, 128, 2, 768, 12, 3072);
        assert!(g12.n_nodes() > 5 * g2.n_nodes() / 2);
    }
}
