//! ResNet-50/101 (He et al. 2016), the torchvision variants the paper uses,
//! plus the CIFAR-10 stem variant used in the Figure 8 training experiments.

use crate::graph::NodeId;
use crate::ops::{GraphBuilder, OpGraph};

/// Bottleneck block: 1×1 reduce → 3×3 → 1×1 expand (+ identity/downsample).
fn bottleneck(
    b: &mut GraphBuilder,
    x: NodeId,
    mid_c: usize,
    out_c: usize,
    stride: usize,
    downsample: bool,
) -> NodeId {
    let mut y = b.conv_bn_relu(x, mid_c, 1, 1);
    y = b.conv_bn_relu(y, mid_c, 3, stride);
    y = b.conv_bn(y, out_c, 1, 1);
    let shortcut = if downsample { b.conv_bn(x, out_c, 1, stride) } else { x };
    let s = b.add(y, shortcut);
    b.relu(s)
}

/// Generic ResNet-v1 with bottleneck blocks. The CIFAR-10 runs in the
/// paper's Figure 8 feed 32×32 inputs through the *unmodified* torchvision
/// architecture — only the classifier width changes — which is exactly why
/// they are so scheduling-bound (every kernel is tiny).
pub fn resnet(batch: usize, hw: usize, blocks: [usize; 4], classes: usize) -> OpGraph {
    let mut b = GraphBuilder::new();
    let input = b.input(&[batch, 3, hw, hw]);
    let mut x = {
        let s = b.conv_bn_relu(input, 64, 7, 2);
        b.maxpool(s, 3, 2)
    };
    let stage_channels = [(64, 256), (128, 512), (256, 1024), (512, 2048)];
    for (stage, (&n_blocks, &(mid_c, out_c))) in
        blocks.iter().zip(stage_channels.iter()).enumerate()
    {
        for i in 0..n_blocks {
            let stride = if stage > 0 && i == 0 { 2 } else { 1 };
            let downsample = i == 0; // channel change (and maybe stride)
            x = bottleneck(&mut b, x, mid_c, out_c, stride, downsample);
        }
    }
    let g = b.gap(x);
    let _ = b.linear(g, classes);
    b.finish()
}

pub fn resnet50(batch: usize, hw: usize) -> OpGraph {
    resnet(batch, hw, [3, 4, 6, 3], 1000)
}

pub fn resnet101(batch: usize, hw: usize) -> OpGraph {
    resnet(batch, hw, [3, 4, 23, 3], 1000)
}

pub fn resnet50_cifar(batch: usize) -> OpGraph {
    resnet(batch, 32, [3, 4, 6, 3], 10)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ops::op::total_macs;

    #[test]
    fn resnet50_macs_near_reference() {
        // torchvision resnet50 @224: ~4.1 GMACs
        let g = resnet50(1, 224);
        let gmacs = total_macs(&g) as f64 / 1e9;
        assert!((3.5..5.0).contains(&gmacs), "resnet50 gmacs={gmacs}");
    }

    #[test]
    fn resnet101_heavier_than_50() {
        let m50 = total_macs(&resnet50(1, 224));
        let m101 = total_macs(&resnet101(1, 224));
        assert!(m101 as f64 > 1.7 * m50 as f64, "101 should be ~1.9× of 50");
    }

    #[test]
    fn op_count_in_expected_range() {
        // 53 convs + bn/relu/add per block ≈ 170–230 operator nodes
        let g = resnet50(1, 224);
        assert!((150..280).contains(&g.n_nodes()), "n={}", g.n_nodes());
    }

    #[test]
    fn batch_scales_macs_linearly() {
        let m1 = total_macs(&resnet50(1, 224));
        let m8 = total_macs(&resnet50(8, 224));
        assert_eq!(m8, 8 * m1);
    }

    #[test]
    fn cifar_variant_is_light() {
        let g = resnet50_cifar(1);
        let gmacs = total_macs(&g) as f64 / 1e9;
        // 32×32 inputs with s1 stem: ~0.08–0.35 GMACs
        assert!(gmacs < 0.5, "cifar resnet50 gmacs={gmacs}");
    }

    #[test]
    fn mostly_sequential_topology() {
        // ResNet width is small (residual branches only): Deg ≤ 3
        let g = resnet50(1, 224);
        let deg = crate::stream::logical_concurrency_degree(&g);
        assert!((2..=3).contains(&deg), "resnet deg={deg}");
    }
}
