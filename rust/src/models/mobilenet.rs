//! MobileNetV2 (Sandler et al. 2018) — inverted residuals with linear
//! bottlenecks. Nearly chain-shaped: the network in Figure 7 where TVM's
//! tuned kernels beat everyone (kernel quality, not scheduling, dominates).

use crate::graph::NodeId;
use crate::ops::{GraphBuilder, OpGraph, OpKind};

/// Inverted residual block: expand 1×1 → depthwise 3×3 → project 1×1.
fn inverted_residual(
    b: &mut GraphBuilder,
    x: NodeId,
    in_c: usize,
    out_c: usize,
    stride: usize,
    expand: usize,
) -> NodeId {
    let mut y = x;
    if expand != 1 {
        y = b.conv(y, in_c * expand, 1, 1);
        y = b.bn(y);
        y = b.act(y, OpKind::ReLU6);
    }
    y = b.dwconv(y, 3, stride);
    y = b.bn(y);
    y = b.act(y, OpKind::ReLU6);
    y = b.conv_bn(y, out_c, 1, 1); // linear bottleneck: no activation
    if stride == 1 && in_c == out_c {
        y = b.add(y, x);
    }
    y
}

/// MobileNetV2 at width 1.0. `hw = 32` is the CIFAR-10 training workload of
/// Figure 8: the unmodified architecture on tiny inputs (only the head
/// narrows to 10 classes) — all kernels shrink, scheduling overhead
/// dominates.
pub fn mobilenet_v2(batch: usize, hw: usize) -> OpGraph {
    let cifar = hw <= 64;
    let mut b = GraphBuilder::new();
    let input = b.input(&[batch, 3, hw, hw]);
    let mut x = b.conv(input, 32, 3, 2);
    x = b.bn(x);
    x = b.act(x, OpKind::ReLU6);
    // (expand, out_c, repeats, first_stride)
    let cfg: [(usize, usize, usize, usize); 7] = [
        (1, 16, 1, 1),
        (6, 24, 2, 2),
        (6, 32, 3, 2),
        (6, 64, 4, 2),
        (6, 96, 3, 1),
        (6, 160, 3, 2),
        (6, 320, 1, 1),
    ];
    let mut in_c = 32;
    for (t, c, n, s) in cfg {
        for i in 0..n {
            let stride = if i == 0 { s } else { 1 };
            x = inverted_residual(&mut b, x, in_c, c, stride, t);
            in_c = c;
        }
    }
    x = b.conv(x, 1280, 1, 1);
    x = b.bn(x);
    x = b.act(x, OpKind::ReLU6);
    let g = b.gap(x);
    let _ = b.linear(g, if cifar { 10 } else { 1000 });
    b.finish()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ops::op::total_macs;

    #[test]
    fn imagenet_macs_near_reference() {
        // torchvision mobilenet_v2 @224: ~0.30 GMACs
        let g = mobilenet_v2(1, 224);
        let gmacs = total_macs(&g) as f64 / 1e9;
        assert!((0.25..0.45).contains(&gmacs), "mobilenet gmacs={gmacs}");
    }

    #[test]
    fn chain_like_topology() {
        let g = mobilenet_v2(1, 224);
        let deg = crate::stream::logical_concurrency_degree(&g);
        assert!(deg <= 2, "mobilenet deg={deg}");
    }

    #[test]
    fn cifar_variant_valid() {
        let g = mobilenet_v2(32, 32);
        assert!(g.validate().is_ok());
        // final FC outputs 10 classes
        let sink = g.sinks()[0];
        assert_eq!(g.node(sink).out_shape.dim(1), 10);
    }

    #[test]
    fn op_count_plausible() {
        // 52 convs ×3 + adds ≈ 150–180
        let g = mobilenet_v2(1, 224);
        assert!((120..220).contains(&g.n_nodes()), "n={}", g.n_nodes());
    }
}
