//! PJRT runtime: loads the AOT artifacts (`artifacts/*.hlo.txt` +
//! `manifest.tsv` + `weights/*.npy`) produced by `python/compile/aot.py`
//! and exposes compiled executables + pre-staged weight buffers to the
//! engine. Python never runs here — this is the request path.

#[cfg(feature = "xla")]
pub mod client;
pub mod manifest;
pub mod npy;
#[cfg(feature = "xla")]
pub mod registry;

#[cfg(feature = "xla")]
pub use client::RuntimeClient;
pub use manifest::{Manifest, NodeEntry};
#[cfg(feature = "xla")]
pub use registry::ArtifactRegistry;

use std::path::{Path, PathBuf};

/// Locate the artifacts directory: `$NIMBLE_ARTIFACTS` or `./artifacts`.
pub fn artifacts_dir() -> PathBuf {
    std::env::var_os("NIMBLE_ARTIFACTS")
        .map(PathBuf::from)
        .unwrap_or_else(|| PathBuf::from("artifacts"))
}

/// True when `make artifacts` has been run (tests skip gracefully if not).
pub fn artifacts_available() -> bool {
    artifacts_dir().join("manifest.tsv").exists()
}

/// Artifacts dir or a clear error telling the user what to run.
pub fn require_artifacts() -> anyhow::Result<PathBuf> {
    let dir = artifacts_dir();
    anyhow::ensure!(
        dir.join("manifest.tsv").exists(),
        "artifacts not found at {} — run `make artifacts` first \
         (or set NIMBLE_ARTIFACTS)",
        dir.display()
    );
    Ok(dir)
}

/// Join an artifact-relative path.
pub fn artifact_path(dir: &Path, rel: &str) -> PathBuf {
    dir.join(rel)
}
