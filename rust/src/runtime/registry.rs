//! Artifact registry: compiled executables + pre-staged weight buffers.
//!
//! Compilation is the expensive, input-independent half of "kernel
//! dispatch"; the registry performs it once at engine build (AoT), so both
//! the eager baseline and Nimble replay execute the exact same
//! executables — isolating *scheduling* as the only difference, like the
//! paper's Fig. 2b methodology.

use anyhow::{Context, Result};
use std::collections::HashMap;
use std::path::PathBuf;
use std::sync::Arc;

use super::client::RuntimeClient;
use super::manifest::Manifest;

pub struct ArtifactRegistry {
    pub client: Arc<RuntimeClient>,
    pub manifest: Manifest,
    pub dir: PathBuf,
    exes: HashMap<String, Arc<xla::PjRtLoadedExecutable>>,
    weights: HashMap<String, Arc<xla::PjRtBuffer>>,
}

impl ArtifactRegistry {
    /// Load manifest, compile every artifact, stage every weight.
    pub fn load(client: Arc<RuntimeClient>, dir: PathBuf) -> Result<Self> {
        let manifest = Manifest::load(&dir)?;
        let mut exes = HashMap::new();
        for (name, rel) in &manifest.artifacts {
            let exe = client
                .compile_artifact(&dir.join(rel))
                .with_context(|| format!("artifact {name}"))?;
            exes.insert(name.clone(), Arc::new(exe));
        }
        let mut weights = HashMap::new();
        for (name, (rel, dims)) in &manifest.weights {
            let (buf, got_dims) = client
                .buffer_from_npy(&dir.join(rel))
                .with_context(|| format!("weight {name}"))?;
            anyhow::ensure!(
                &got_dims == dims,
                "weight {name}: manifest says {dims:?}, file has {got_dims:?}"
            );
            weights.insert(name.clone(), Arc::new(buf));
        }
        Ok(ArtifactRegistry { client, manifest, dir, exes, weights })
    }

    pub fn executable(&self, name: &str) -> Result<Arc<xla::PjRtLoadedExecutable>> {
        self.exes
            .get(name)
            .cloned()
            .with_context(|| format!("unknown artifact {name}"))
    }

    pub fn weight(&self, name: &str) -> Result<Arc<xla::PjRtBuffer>> {
        self.weights
            .get(name)
            .cloned()
            .with_context(|| format!("unknown weight {name}"))
    }

    /// Borrowed weight buffer (hot-path variant: no Arc clone).
    pub fn weight_ref(&self, name: &str) -> Result<&xla::PjRtBuffer> {
        self.weights
            .get(name)
            .map(|a| a.as_ref())
            .with_context(|| format!("unknown weight {name}"))
    }

    pub fn n_executables(&self) -> usize {
        self.exes.len()
    }
}
