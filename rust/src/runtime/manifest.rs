//! `artifacts/manifest.tsv` parser — the contract between the Python
//! compile path (`python/compile/aot.py`, which documents the grammar) and
//! this runtime.

use anyhow::{bail, Context, Result};
use std::collections::HashMap;
use std::path::Path;

/// One operator node of the executable graph.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct NodeEntry {
    pub name: String,
    /// Artifact (executable) this node runs.
    pub artifact: String,
    /// Output dims.
    pub dims: Vec<usize>,
    /// Inputs in positional order.
    pub inputs: Vec<InputRef>,
}

/// A node input: another node's output or a weight tensor.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum InputRef {
    Node(String),
    Weight(String),
}

/// Training-step artifact description.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct TrainSpec {
    pub artifact: String,
    pub n_params: usize,
    pub batch: usize,
    pub in_dim: usize,
    pub n_classes: usize,
}

/// Parsed manifest.
#[derive(Debug, Clone, Default)]
pub struct Manifest {
    /// artifact name → relative path of the HLO text file.
    pub artifacts: HashMap<String, String>,
    /// weight name → (relative npy path, dims).
    pub weights: HashMap<String, (String, Vec<usize>)>,
    /// batch size → node graph in topological (file) order.
    pub graphs: HashMap<usize, Vec<NodeEntry>>,
    /// batch size → request input dims.
    pub inputs: HashMap<usize, Vec<usize>>,
    /// batch size → whole-model artifact (name, ordered weight args).
    pub models: HashMap<usize, (String, Vec<String>)>,
    pub train: Option<TrainSpec>,
}

impl Manifest {
    pub fn load(dir: &Path) -> Result<Manifest> {
        let path = dir.join("manifest.tsv");
        let text =
            std::fs::read_to_string(&path).with_context(|| format!("reading {path:?}"))?;
        Self::parse(&text)
    }

    pub fn parse(text: &str) -> Result<Manifest> {
        let mut m = Manifest::default();
        for (lineno, line) in text.lines().enumerate() {
            if line.is_empty() || line.starts_with('#') {
                continue;
            }
            let fields: Vec<&str> = line.split('\t').collect();
            let err = || format!("manifest line {}: {line:?}", lineno + 1);
            match fields[0] {
                "A" => {
                    if fields.len() != 3 {
                        bail!("{}: A needs 3 fields", err());
                    }
                    m.artifacts.insert(fields[1].to_string(), fields[2].to_string());
                }
                "W" => {
                    if fields.len() != 4 {
                        bail!("{}: W needs 4 fields", err());
                    }
                    let dims = parse_dims(fields[3]).with_context(err)?;
                    m.weights.insert(fields[1].to_string(), (fields[2].to_string(), dims));
                }
                "N" => {
                    if fields.len() != 6 {
                        bail!("{}: N needs 6 fields", err());
                    }
                    let batch: usize = fields[1].parse().with_context(err)?;
                    let inputs = fields[5]
                        .split(';')
                        .filter(|s| !s.is_empty())
                        .map(|item| match item.split_once(':') {
                            Some(("node", t)) => Ok(InputRef::Node(t.to_string())),
                            Some(("weight", t)) => Ok(InputRef::Weight(t.to_string())),
                            _ => bail!("bad input ref {item:?}"),
                        })
                        .collect::<Result<Vec<_>>>()
                        .with_context(err)?;
                    m.graphs.entry(batch).or_default().push(NodeEntry {
                        name: fields[2].to_string(),
                        artifact: fields[3].to_string(),
                        dims: parse_dims(fields[4]).with_context(err)?,
                        inputs,
                    });
                }
                "I" => {
                    if fields.len() != 3 {
                        bail!("{}: I needs 3 fields", err());
                    }
                    m.inputs
                        .insert(fields[1].parse().with_context(err)?, parse_dims(fields[2]).with_context(err)?);
                }
                "M" => {
                    if fields.len() != 4 {
                        bail!("{}: M needs 4 fields", err());
                    }
                    let weights: Vec<String> =
                        fields[3].split(',').filter(|s| !s.is_empty()).map(String::from).collect();
                    m.models.insert(
                        fields[1].parse().with_context(err)?,
                        (fields[2].to_string(), weights),
                    );
                }
                "T" => {
                    if fields.len() != 6 {
                        bail!("{}: T needs 6 fields", err());
                    }
                    m.train = Some(TrainSpec {
                        artifact: fields[1].to_string(),
                        n_params: fields[2].parse().with_context(err)?,
                        batch: fields[3].parse().with_context(err)?,
                        in_dim: fields[4].parse().with_context(err)?,
                        n_classes: fields[5].parse().with_context(err)?,
                    });
                }
                other => bail!("{}: unknown record kind {other:?}", err()),
            }
        }
        m.validate()?;
        Ok(m)
    }

    /// Cross-reference checks: every node's artifact/weights/deps exist and
    /// deps appear earlier (topological file order).
    fn validate(&self) -> Result<()> {
        for (batch, nodes) in &self.graphs {
            let mut seen = std::collections::HashSet::new();
            seen.insert("input".to_string());
            for n in nodes {
                if !self.artifacts.contains_key(&n.artifact) {
                    bail!("node {} (b{batch}): unknown artifact {}", n.name, n.artifact);
                }
                for i in &n.inputs {
                    match i {
                        InputRef::Node(t) => {
                            if !seen.contains(t) {
                                bail!("node {} (b{batch}): forward/unknown dep {t}", n.name);
                            }
                        }
                        InputRef::Weight(w) => {
                            if !self.weights.contains_key(w) {
                                bail!("node {} (b{batch}): unknown weight {w}", n.name);
                            }
                        }
                    }
                }
                seen.insert(n.name.clone());
            }
        }
        for (art, weights) in self.models.values() {
            if !self.artifacts.contains_key(art) {
                bail!("model artifact {art} not declared");
            }
            for w in weights {
                if !self.weights.contains_key(w) {
                    bail!("model artifact {art}: unknown weight {w}");
                }
            }
        }
        if let Some(t) = &self.train {
            if !self.artifacts.contains_key(&t.artifact) {
                bail!("train artifact {} not declared", t.artifact);
            }
        }
        Ok(())
    }

    /// Batch sizes with per-op graphs, ascending.
    pub fn batch_sizes(&self) -> Vec<usize> {
        let mut b: Vec<usize> = self.graphs.keys().copied().collect();
        b.sort_unstable();
        b
    }
}

fn parse_dims(s: &str) -> Result<Vec<usize>> {
    s.split(',')
        .filter(|p| !p.is_empty())
        .map(|p| p.trim().parse::<usize>().context("bad dim"))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = "\
A\tconv_sig\tops/conv.hlo.txt
A\trelu_sig\tops/relu.hlo.txt
A\tmodel_b1\tmodel_b1.hlo.txt
A\ttrain_step\ttrain_step.hlo.txt
W\tstem_w\tweights/stem_w.npy\t16,3,3,3
I\t1\t1,3,32,32
N\t1\tstem_conv\tconv_sig\t1,16,32,32\tnode:input;weight:stem_w
N\t1\tstem_relu\trelu_sig\t1,16,32,32\tnode:stem_conv
M\t1\tmodel_b1\tstem_w
T\ttrain_step\t6\t64\t3072\t10
";

    #[test]
    fn parses_sample() {
        let m = Manifest::parse(SAMPLE).unwrap();
        assert_eq!(m.artifacts.len(), 4);
        assert_eq!(m.weights["stem_w"].1, vec![16, 3, 3, 3]);
        let g = &m.graphs[&1];
        assert_eq!(g.len(), 2);
        assert_eq!(g[0].inputs.len(), 2);
        assert_eq!(g[1].inputs, vec![InputRef::Node("stem_conv".into())]);
        assert_eq!(m.models[&1].0, "model_b1");
        assert_eq!(m.models[&1].1, vec!["stem_w".to_string()]);
        assert_eq!(m.inputs[&1], vec![1, 3, 32, 32]);
        assert_eq!(m.train.as_ref().unwrap().n_params, 6);
        assert_eq!(m.batch_sizes(), vec![1]);
    }

    #[test]
    fn rejects_unknown_artifact() {
        let bad = "N\t1\tx\tnope\t1,2\tnode:input\n";
        assert!(Manifest::parse(bad).is_err());
    }

    #[test]
    fn rejects_forward_reference() {
        let bad = "\
A\ta\tf.hlo.txt
N\t1\tx\ta\t1,2\tnode:y
N\t1\ty\ta\t1,2\tnode:input
";
        assert!(Manifest::parse(bad).is_err());
    }

    #[test]
    fn rejects_unknown_weight() {
        let bad = "A\ta\tf.hlo.txt\nN\t1\tx\ta\t1,2\tweight:w\n";
        assert!(Manifest::parse(bad).is_err());
    }

    #[test]
    fn rejects_malformed_input_ref() {
        let bad = "A\ta\tf.hlo.txt\nN\t1\tx\ta\t1,2\tbogus\n";
        assert!(Manifest::parse(bad).is_err());
    }

    #[test]
    fn skips_comments_and_blanks() {
        let m = Manifest::parse("# comment\n\nA\ta\tf.hlo.txt\n").unwrap();
        assert_eq!(m.artifacts.len(), 1);
    }

    #[test]
    fn loads_real_manifest_if_built() {
        let dir = crate::runtime::artifacts_dir();
        if !dir.join("manifest.tsv").exists() {
            eprintln!("skipping: artifacts not built");
            return;
        }
        let m = Manifest::load(&dir).unwrap();
        assert!(m.artifacts.len() >= 30);
        assert_eq!(m.batch_sizes(), vec![1, 8]);
        assert!(m.train.is_some());
        // graph matches the rust-side MiniInception op count (sans input)
        let mini = crate::models::build("mini_inception", 8);
        assert_eq!(m.graphs[&8].len(), mini.n_nodes() - 1);
    }
}
