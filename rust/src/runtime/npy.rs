//! Minimal NumPy `.npy` (v1.x, little-endian f32, C-order) reader.
//!
//! The vendored `xla` crate ships an npy reader but it mis-parses the
//! quoted `descr` field of NumPy-written headers; weights are the one
//! binary interface between the Python compile path and this runtime, so
//! we parse them ourselves and keep the format under test.

use anyhow::{bail, Context, Result};
use std::io::Read;
use std::path::Path;

/// A parsed f32 array: shape + row-major data.
#[derive(Debug, Clone, PartialEq)]
pub struct NpyArray {
    pub dims: Vec<usize>,
    pub data: Vec<f32>,
}

impl NpyArray {
    pub fn numel(&self) -> usize {
        self.dims.iter().product()
    }
}

/// Read an `.npy` file containing a little-endian f32 C-order array.
pub fn read_npy_f32(path: &Path) -> Result<NpyArray> {
    let bytes = std::fs::read(path).with_context(|| format!("reading {}", path.display()))?;
    parse_npy_f32(&bytes).with_context(|| format!("parsing {}", path.display()))
}

/// Parse `.npy` bytes (exposed for tests).
pub fn parse_npy_f32(bytes: &[u8]) -> Result<NpyArray> {
    const MAGIC: &[u8] = b"\x93NUMPY";
    if bytes.len() < 10 || &bytes[..6] != MAGIC {
        bail!("not an npy file (bad magic)");
    }
    let major = bytes[6];
    let (header_len, header_start) = match major {
        1 => (u16::from_le_bytes([bytes[8], bytes[9]]) as usize, 10),
        2 | 3 => (
            u32::from_le_bytes([bytes[8], bytes[9], bytes[10], bytes[11]]) as usize,
            12,
        ),
        v => bail!("unsupported npy version {v}"),
    };
    let header_end = header_start + header_len;
    if bytes.len() < header_end {
        bail!("truncated npy header");
    }
    let header = std::str::from_utf8(&bytes[header_start..header_end])
        .context("npy header is not utf-8")?;

    // descr
    let descr = dict_value(header, "descr").context("no descr")?;
    let descr = descr.trim_matches(|c| c == '\'' || c == '"');
    if !matches!(descr.trim_start_matches(['<', '=', '|']), "f4") {
        bail!("unsupported dtype {descr:?} (only little-endian f32)");
    }
    // fortran_order
    let fortran = dict_value(header, "fortran_order").context("no fortran_order")?;
    if fortran.trim() != "False" {
        bail!("fortran order not supported");
    }
    // shape
    let shape = dict_value(header, "shape").context("no shape")?;
    let shape = shape.trim().trim_start_matches('(').trim_end_matches(')');
    let dims: Vec<usize> = shape
        .split(',')
        .map(str::trim)
        .filter(|s| !s.is_empty())
        .map(|s| s.parse::<usize>().context("bad dim"))
        .collect::<Result<_>>()?;

    let numel: usize = dims.iter().product();
    let payload = &bytes[header_end..];
    if payload.len() < numel * 4 {
        bail!("npy payload too short: {} < {}", payload.len(), numel * 4);
    }
    let mut data = Vec::with_capacity(numel);
    let mut rdr = payload;
    let mut buf = [0u8; 4];
    for _ in 0..numel {
        rdr.read_exact(&mut buf)?;
        data.push(f32::from_le_bytes(buf));
    }
    Ok(NpyArray { dims, data })
}

/// Extract a value from the header's python-dict literal: finds
/// `'key':` and returns the text up to the next top-level comma.
fn dict_value<'a>(header: &'a str, key: &str) -> Option<&'a str> {
    let needle = format!("'{key}':");
    let start = header.find(&needle)? + needle.len();
    let rest = &header[start..];
    let mut depth = 0usize;
    for (i, c) in rest.char_indices() {
        match c {
            '(' | '[' => depth += 1,
            ')' | ']' => depth = depth.saturating_sub(1),
            ',' | '}' if depth == 0 => return Some(rest[..i].trim()),
            _ => {}
        }
    }
    Some(rest.trim())
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Build a v1.0 npy file in memory the way numpy.save does.
    fn make_npy(dims: &[usize], data: &[f32]) -> Vec<u8> {
        let shape = match dims.len() {
            1 => format!("({},)", dims[0]),
            _ => format!(
                "({})",
                dims.iter().map(|d| d.to_string()).collect::<Vec<_>>().join(", ")
            ),
        };
        let mut header = format!(
            "{{'descr': '<f4', 'fortran_order': False, 'shape': {shape}, }}"
        );
        let pad = 64 - (10 + header.len() + 1) % 64;
        header.push_str(&" ".repeat(pad % 64));
        header.push('\n');
        let mut out = b"\x93NUMPY\x01\x00".to_vec();
        out.extend_from_slice(&(header.len() as u16).to_le_bytes());
        out.extend_from_slice(header.as_bytes());
        for v in data {
            out.extend_from_slice(&v.to_le_bytes());
        }
        out
    }

    #[test]
    fn round_trip() {
        let data = vec![1.5f32, -2.0, 0.0, 7.25, 3.0, -1.0];
        let bytes = make_npy(&[2, 3], &data);
        let arr = parse_npy_f32(&bytes).unwrap();
        assert_eq!(arr.dims, vec![2, 3]);
        assert_eq!(arr.data, data);
    }

    #[test]
    fn one_dim_trailing_comma() {
        let bytes = make_npy(&[4], &[0.0; 4]);
        let arr = parse_npy_f32(&bytes).unwrap();
        assert_eq!(arr.dims, vec![4]);
    }

    #[test]
    fn rejects_bad_magic() {
        assert!(parse_npy_f32(b"NOTNUMPYxxxxxxx").is_err());
    }

    #[test]
    fn rejects_f64() {
        let mut bytes = make_npy(&[1], &[0.0]);
        let s = String::from_utf8_lossy(&bytes.clone()).replace("<f4", "<f8");
        bytes = s.into_bytes();
        assert!(parse_npy_f32(&bytes).is_err());
    }

    #[test]
    fn rejects_truncated_payload() {
        let mut bytes = make_npy(&[8], &[0.0; 8]);
        bytes.truncate(bytes.len() - 4);
        assert!(parse_npy_f32(&bytes).is_err());
    }

    #[test]
    fn reads_real_numpy_output_if_artifacts_exist() {
        let dir = crate::runtime::artifacts_dir().join("weights/stem_w.npy");
        if !dir.exists() {
            eprintln!("skipping: artifacts not built");
            return;
        }
        let arr = read_npy_f32(&dir).unwrap();
        assert_eq!(arr.dims, vec![16, 3, 3, 3]);
        assert_eq!(arr.numel(), arr.data.len());
    }
}
