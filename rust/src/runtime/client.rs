//! PJRT client wrapper: compile HLO-text artifacts, stage host data to
//! device buffers. One client per process; executables/buffers keep a
//! handle to it.

use anyhow::{Context, Result};
use std::path::Path;
use std::sync::Arc;

use super::npy::read_npy_f32;

/// Wrapper around the PJRT CPU client.
pub struct RuntimeClient {
    client: xla::PjRtClient,
}

impl RuntimeClient {
    /// Create the CPU client (the testbed's "GPU").
    pub fn cpu() -> Result<Arc<Self>> {
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        Ok(Arc::new(RuntimeClient { client }))
    }

    pub fn platform_name(&self) -> String {
        self.client.platform_name()
    }

    /// Load an HLO **text** artifact and compile it (the AoT "kernel
    /// dispatch" — done exactly once per signature).
    pub fn compile_artifact(&self, path: &Path) -> Result<xla::PjRtLoadedExecutable> {
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().context("non-utf8 artifact path")?,
        )
        .with_context(|| format!("parsing HLO text {}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        self.client
            .compile(&comp)
            .with_context(|| format!("compiling {}", path.display()))
    }

    /// Stage an f32 host tensor to a device buffer.
    pub fn buffer_f32(&self, data: &[f32], dims: &[usize]) -> Result<xla::PjRtBuffer> {
        self.client
            .buffer_from_host_buffer(data, dims, None)
            .context("staging host buffer")
    }

    /// Load an `.npy` weight file straight to a device buffer.
    pub fn buffer_from_npy(&self, path: &Path) -> Result<(xla::PjRtBuffer, Vec<usize>)> {
        let arr = read_npy_f32(path)?;
        let buf = self.buffer_f32(&arr.data, &arr.dims)?;
        Ok((buf, arr.dims))
    }

    /// Copy a device buffer back to host f32 data.
    pub fn to_host_f32(&self, buf: &xla::PjRtBuffer) -> Result<Vec<f32>> {
        let lit = buf.to_literal_sync().context("device→host copy")?;
        lit.to_vec::<f32>().context("literal to vec")
    }
}
