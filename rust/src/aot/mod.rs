//! The AoT scheduler (paper §4.1): turn a manifest node graph into a
//! **task schedule** — the pre-resolved artifact the replay engine submits
//! from, with no run-time scheduling work.
//!
//! `memory` is the reserved-memory half (lifetime-interval arena planning,
//! the "pre-allocate the exact amount of GPU memory" step); `schedule` is
//! the execution-trace half (pre-run interception: resolved executables,
//! pre-bound argument sources, stream assignment, event plan).

pub mod memory;
pub mod schedule;

pub use memory::{plan_arena, ArenaPlan, Lifetime};
pub use schedule::{ArgSource, ReplayTask, TaskSchedule};
