//! The AoT scheduler (paper §4.1): turn a graph into a **task schedule**
//! and compile it down to the flat **replay tape** the executors submit
//! from, with no run-time scheduling work.
//!
//! * [`memory`] — the reserved-memory subsystem (the "pre-allocate the
//!   exact amount of GPU memory" step): serial and stream-aware
//!   (happens-before) lifetime analysis, conflict-driven arena layout,
//!   and the arena pool serving lanes draw their reservations from.
//! * [`tape`] — the fully-resolved replay artifact: per-stream tapes of
//!   integer-indexed task records shared by the parallel executor
//!   ([`crate::engine::executor`]) and the DES simulator
//!   ([`crate::sim::simulate_tape`]).
//! * [`schedule`] (feature `xla`) — the execution-trace half over real
//!   PJRT executables: pre-run interception, resolved executables,
//!   pre-bound argument sources, stream assignment, event plan.
//! * [`verify`] — static plan certification: an independent
//!   happens-before closure plus race, deadlock, aliasing, and
//!   well-formedness analysis over a compiled tape and its arena plan,
//!   run at build time so a mis-built schedule is a structured
//!   diagnostic instead of undefined behavior.

pub mod memory;
#[cfg(feature = "xla")]
pub mod schedule;
pub mod tape;
pub mod verify;

pub use memory::{
    happens_before_conflicts, plan_arena, plan_with_conflicts, ArenaPlan, ArenaPool, ConflictSet,
    Lifetime,
};
#[cfg(feature = "xla")]
pub use schedule::{ArgSource, PreparedReplay, ReplayTask, TaskSchedule};
pub use tape::{NodeMeta, ReplayTape, TapeArg, TapeOp, TapeRole};
pub use verify::{DiagKind, Diagnostic, VerifyMode, VerifyReport, Witness};
