//! Arena pooling: recycle the backing buffers of slot arenas across
//! replay-context builds.
//!
//! Every replay context reserves one contiguous `f32` arena. A serving
//! deployment builds many contexts — one per (lane, bucket) — and
//! rebuilds them whenever lanes restart or scale, so the arenas are the
//! dominant steady-state reservation. [`ArenaPool`] keeps retired
//! backing buffers in half-stepped size classes (1.0× and 1.5× per
//! power-of-two decade, "sized by bucket": one class per
//! bucket-footprint shape) and hands them back out on the next build,
//! so a lane restart — or an elastic scale-up — re-uses a previous
//! lane's reservation instead of growing the heap. Acquire/release
//! happen at context build/drop time — never on the replay hot path.

use std::collections::BTreeMap;
use std::sync::{Arc, Mutex};

/// Cheaply cloneable handle to a shared pool of arena backing buffers.
#[derive(Clone, Default)]
pub struct ArenaPool {
    inner: Arc<Mutex<PoolInner>>,
}

#[derive(Default)]
struct PoolInner {
    /// size class (elements) → retired buffers of that capacity.
    free: BTreeMap<usize, Vec<Vec<f32>>>,
    acquires: u64,
    hits: u64,
    /// Elements sitting in `free`.
    resident_elems: usize,
    /// Elements currently leased out.
    leased_elems: usize,
    /// Peak of `resident_elems + leased_elems`.
    high_water_elems: usize,
}

/// Pool counters (bytes assume `f32` elements).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ArenaPoolStats {
    /// Total `acquire` calls.
    pub acquires: u64,
    /// Acquires served from a retired buffer instead of a fresh one.
    pub hits: u64,
    /// Bytes held in the free lists right now.
    pub resident_bytes: u64,
    /// Bytes leased to live arenas right now.
    pub leased_bytes: u64,
    /// Peak bytes ever held by the pool (leased + resident).
    pub high_water_bytes: u64,
}

/// A leased (or owned) arena backing buffer. Pooled leases return their
/// buffer to the pool's size class on drop; owned leases just free it.
pub struct ArenaLease {
    pub(crate) buf: Vec<f32>,
    class_elems: usize,
    pool: Option<ArenaPool>,
}

impl ArenaLease {
    /// A pool-less backing buffer (freed on drop like any `Vec`).
    pub fn owned() -> ArenaLease {
        ArenaLease { buf: Vec::new(), class_elems: 0, pool: None }
    }

    pub fn is_pooled(&self) -> bool {
        self.pool.is_some()
    }

    /// Capacity class the lease came from (0 for owned leases).
    pub fn class_elems(&self) -> usize {
        self.class_elems
    }
}

impl Drop for ArenaLease {
    fn drop(&mut self) {
        if let Some(pool) = self.pool.take() {
            pool.give_back(std::mem::take(&mut self.buf), self.class_elems);
        }
    }
}

/// Round a request up to its size class.
///
/// Classes step at 1.0× and 1.5× per power-of-two decade (…, 4096,
/// 6144, 8192, 12288, 16384, …), floored at 1 KiB of elements so tiny
/// tapes share one class. Pure power-of-two classes waste up to 2×
/// resident bytes on odd footprints (the ROADMAP defragmentation item);
/// the half-class step caps rounding waste at ~33% while keeping the
/// class count logarithmic — two classes per decade — so recycling
/// still hits across rebuilds of the same bucket shapes.
fn class_of(elems: usize) -> usize {
    let n = elems.max(1024);
    let pow2 = n.next_power_of_two();
    if n == pow2 {
        return pow2;
    }
    // 1.5× the decade below `pow2`; element counts here are ≥ 1024, so
    // `pow2 / 4` is exact and the half class is 512-aligned like the
    // arena's allocation quanta.
    let half_class = pow2 / 2 + pow2 / 4;
    if n <= half_class {
        half_class
    } else {
        pow2
    }
}

impl ArenaPool {
    pub fn new() -> ArenaPool {
        ArenaPool::default()
    }

    /// Lease a buffer with capacity for at least `elems` f32s. The
    /// buffer's length and contents are unspecified — the slot arena
    /// resizes and re-seeds it at build. Returns to the pool on drop.
    pub fn acquire(&self, elems: usize) -> ArenaLease {
        let class = class_of(elems);
        let mut inner = self.inner.lock().unwrap();
        inner.acquires += 1;
        let buf = match inner.free.get_mut(&class).and_then(Vec::pop) {
            Some(buf) => {
                inner.hits += 1;
                inner.resident_elems -= class;
                buf
            }
            None => Vec::with_capacity(class),
        };
        inner.leased_elems += class;
        inner.high_water_elems =
            inner.high_water_elems.max(inner.leased_elems + inner.resident_elems);
        drop(inner);
        ArenaLease { buf, class_elems: class, pool: Some(self.clone()) }
    }

    fn give_back(&self, buf: Vec<f32>, class: usize) {
        let mut inner = self.inner.lock().unwrap();
        inner.leased_elems = inner.leased_elems.saturating_sub(class);
        inner.resident_elems += class;
        inner.free.entry(class).or_default().push(buf);
    }

    pub fn stats(&self) -> ArenaPoolStats {
        let inner = self.inner.lock().unwrap();
        ArenaPoolStats {
            acquires: inner.acquires,
            hits: inner.hits,
            resident_bytes: 4 * inner.resident_elems as u64,
            leased_bytes: 4 * inner.leased_elems as u64,
            high_water_bytes: 4 * inner.high_water_elems as u64,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn acquire_release_acquire_hits_the_class() {
        let pool = ArenaPool::new();
        let lease = pool.acquire(5000);
        assert!(lease.is_pooled());
        assert_eq!(lease.class_elems(), 6144, "5000 rounds to the 1.5×4096 half class");
        assert!(lease.buf.capacity() >= 6144);
        let stats = pool.stats();
        assert_eq!((stats.acquires, stats.hits), (1, 0));
        assert_eq!(stats.leased_bytes, 4 * 6144);
        drop(lease);
        let stats = pool.stats();
        assert_eq!(stats.leased_bytes, 0);
        assert_eq!(stats.resident_bytes, 4 * 6144);

        // same class → hit; the pool does not grow
        let lease2 = pool.acquire(6000);
        assert_eq!(lease2.class_elems(), 6144);
        let stats = pool.stats();
        assert_eq!((stats.acquires, stats.hits), (2, 1));
        assert_eq!(stats.high_water_bytes, 4 * 6144);
        drop(lease2);

        // different class → miss
        let lease3 = pool.acquire(100_000);
        assert_eq!(lease3.class_elems(), 131_072, "past 1.5×65536 rounds to the next pow2");
        let stats = pool.stats();
        assert_eq!((stats.acquires, stats.hits), (3, 1));
    }

    #[test]
    fn half_classes_step_at_one_and_one_point_five_per_decade() {
        assert_eq!(class_of(1), 1024, "floor class");
        assert_eq!(class_of(1024), 1024, "exact pow2 keeps its class");
        assert_eq!(class_of(1025), 1536);
        assert_eq!(class_of(1536), 1536, "exact half class keeps its class");
        assert_eq!(class_of(1537), 2048);
        assert_eq!(class_of(4096), 4096);
        assert_eq!(class_of(5000), 6144);
        assert_eq!(class_of(6144), 6144);
        assert_eq!(class_of(6145), 8192);
    }

    /// Regression (pow2-waste bugfix): an odd-sized footprint must pin
    /// pool resident bytes to its HALF class, not the next power of two
    /// — the pow2 rounding held up to 2× the bytes resident.
    #[test]
    fn odd_footprint_resident_bytes_are_pinned_to_the_half_class() {
        let pool = ArenaPool::new();
        drop(pool.acquire(5000));
        let stats = pool.stats();
        assert_eq!(stats.resident_bytes, 4 * 6144, "resident bytes pinned to the half class");
        assert!(
            stats.resident_bytes < 4 * 8192,
            "half class must beat the old pow2 class ({} !< {})",
            stats.resident_bytes,
            4 * 8192
        );
        // Same odd footprint re-acquired → recycled, and the counters
        // reflect the new class granularity.
        drop(pool.acquire(5000));
        let stats = pool.stats();
        assert_eq!((stats.acquires, stats.hits), (2, 1));
        assert_eq!(stats.high_water_bytes, 4 * 6144, "recycling kept the pool flat");
    }

    #[test]
    fn tiny_requests_share_the_floor_class() {
        let pool = ArenaPool::new();
        let a = pool.acquire(1);
        assert_eq!(a.class_elems(), 1024);
        drop(a);
        let b = pool.acquire(900);
        assert_eq!(b.class_elems(), 1024);
        assert_eq!(pool.stats().hits, 1);
    }

    #[test]
    fn owned_leases_do_not_touch_any_pool() {
        let lease = ArenaLease::owned();
        assert!(!lease.is_pooled());
        drop(lease); // must not panic
    }

    #[test]
    fn pool_is_shared_across_clones() {
        let pool = ArenaPool::new();
        let clone = pool.clone();
        drop(clone.acquire(2048));
        assert_eq!(pool.stats().acquires, 1);
        assert_eq!(pool.stats().resident_bytes, 4 * 2048);
    }
}
