//! Arena layout: pack tensors into one contiguous reservation such that
//! any two *conflicting* tensors (whose lifetimes can overlap in some
//! legal execution) never share bytes, while non-conflicting tensors
//! alias freely. Best-fit-decreasing over the conflict relation — the
//! standard static memory planner (cf. TFLite/TVM planners), generalized
//! from interval overlap to an arbitrary symmetric conflict set so the
//! stream-aware lifetime analysis ([`super::lifetime`]) can drive it.

use crate::engine::alloc::round_size;

/// Symmetric boolean relation over `n` tensors: `get(i, j)` is true iff
/// tensors `i` and `j` may be live at the same time and therefore must
/// occupy disjoint arena ranges. Stored as a dense row-major bitmap
/// (`n²` bits) — planning happens once at engine build, n = #slots.
#[derive(Debug, Clone)]
pub struct ConflictSet {
    n: usize,
    bits: Vec<u64>,
}

impl ConflictSet {
    pub fn new(n: usize) -> ConflictSet {
        ConflictSet { n, bits: vec![0u64; (n * n).div_ceil(64)] }
    }

    /// Mark `i` and `j` as conflicting (symmetric; `i == j` is ignored —
    /// a tensor never conflicts with itself).
    pub fn set(&mut self, i: usize, j: usize) {
        debug_assert!(i < self.n && j < self.n);
        if i == j {
            return;
        }
        for idx in [i * self.n + j, j * self.n + i] {
            self.bits[idx / 64] |= 1u64 << (idx % 64);
        }
    }

    pub fn get(&self, i: usize, j: usize) -> bool {
        debug_assert!(i < self.n && j < self.n);
        let idx = i * self.n + j;
        self.bits[idx / 64] >> (idx % 64) & 1 == 1
    }

    pub fn n(&self) -> usize {
        self.n
    }

    /// Number of conflicting unordered pairs.
    pub fn n_conflicts(&self) -> usize {
        self.bits.iter().map(|w| w.count_ones() as usize).sum::<usize>() / 2
    }
}

/// Planned arena: per-tensor byte offsets plus total footprint.
#[derive(Debug, Clone)]
pub struct ArenaPlan {
    /// Byte offset per tensor (same indexing as the input sizes).
    pub offsets: Vec<u64>,
    /// Allocator-rounded reservation per tensor (0 for zero-byte tensors).
    pub rounded_sizes: Vec<u64>,
    pub arena_bytes: u64,
}

impl ArenaPlan {
    /// Sum of all rounded tensor sizes — what per-tensor allocation would
    /// cost without lifetime reuse.
    pub fn unshared_bytes(&self) -> u64 {
        self.rounded_sizes.iter().sum()
    }

    /// The no-sharing layout: every tensor gets its own range (rounded
    /// sizes laid end to end). This is the per-slot-buffer baseline the
    /// differential harness replays against the packed plan.
    pub fn unshared(bytes: &[u64]) -> ArenaPlan {
        let rounded: Vec<u64> = bytes.iter().map(|&b| round_nonzero(b)).collect();
        let mut offsets = Vec::with_capacity(bytes.len());
        let mut cursor = 0u64;
        for &r in &rounded {
            offsets.push(cursor);
            cursor += r;
        }
        ArenaPlan { offsets, rounded_sizes: rounded, arena_bytes: cursor }
    }

    /// Byte ranges of `[0, arena_bytes)` covered by **no** tensor's data
    /// extent (`extents[i]` bytes from `offsets[i]` — the *written*
    /// sizes, not the rounded reservations). These ranges are never
    /// legally written, so the executor seeds them with canary words and
    /// verifies them after replays in debug builds.
    pub fn holes(&self, extents: &[u64]) -> Vec<(u64, u64)> {
        let mut covered: Vec<(u64, u64)> = self
            .offsets
            .iter()
            .zip(extents)
            .filter(|&(_, &e)| e > 0)
            .map(|(&o, &e)| (o, o + e))
            .collect();
        covered.sort_unstable();
        let mut holes = Vec::new();
        let mut cursor = 0u64;
        for (start, end) in covered {
            if start > cursor {
                holes.push((cursor, start));
            }
            cursor = cursor.max(end);
        }
        if cursor < self.arena_bytes {
            holes.push((cursor, self.arena_bytes));
        }
        holes
    }
}

/// Round like the caching allocator, except that zero-byte tensors
/// reserve nothing (never-written slots need no arena range).
fn round_nonzero(bytes: u64) -> u64 {
    if bytes == 0 {
        0
    } else {
        round_size(bytes)
    }
}

/// Plan an arena over an explicit conflict relation. Best-fit-decreasing:
/// tensors are placed largest-first; each placement scans **every** gap
/// between the already-placed conflicting ranges (sorted by offset) and
/// takes the *tightest* gap that fits — not the first one, which can
/// burn a loose gap a later tensor needed — falling back to the end of
/// the conflict span. `O(n²)` — engine-build time.
pub fn plan_with_conflicts(bytes: &[u64], conflicts: &ConflictSet) -> ArenaPlan {
    let n = bytes.len();
    assert_eq!(conflicts.n(), n, "conflict set arity != tensor count");
    let rounded: Vec<u64> = bytes.iter().map(|&b| round_nonzero(b)).collect();
    let mut order: Vec<usize> = (0..n).collect();
    order.sort_by_key(|&i| std::cmp::Reverse(rounded[i]));

    let mut offsets = vec![0u64; n];
    let mut placed: Vec<usize> = Vec::with_capacity(n);
    let mut arena = 0u64;
    let mut ranges: Vec<(u64, u64)> = Vec::with_capacity(n);
    for &i in &order {
        if rounded[i] == 0 {
            continue;
        }
        ranges.clear();
        ranges.extend(
            placed
                .iter()
                .filter(|&&j| conflicts.get(i, j))
                .map(|&j| (offsets[j], offsets[j] + rounded[j])),
        );
        ranges.sort_unstable();
        // Tightest-gap scan over every hole between conflicting ranges
        // (ties resolve to the lowest offset, scanned first).
        let mut best: Option<(u64, u64)> = None; // (gap length, gap offset)
        let mut cursor = 0u64;
        for &(start, end) in &ranges {
            if start > cursor {
                let gap = start - cursor;
                let tighter = match best {
                    None => true,
                    Some((g, _)) => gap < g,
                };
                if gap >= rounded[i] && tighter {
                    best = Some((gap, cursor));
                }
            }
            cursor = cursor.max(end);
        }
        offsets[i] = match best {
            Some((_, off)) => off,
            None => cursor,
        };
        arena = arena.max(offsets[i] + rounded[i]);
        placed.push(i);
    }
    ArenaPlan { offsets, rounded_sizes: rounded, arena_bytes: arena }
}

/// Verify the plan against a conflict relation: every tensor fits inside
/// the arena and no conflicting pair shares bytes (test helper and debug
/// assertion for the engine).
pub fn plan_respects_conflicts(conflicts: &ConflictSet, plan: &ArenaPlan) -> bool {
    let n = conflicts.n();
    if plan.offsets.len() != n || plan.rounded_sizes.len() != n {
        return false;
    }
    for i in 0..n {
        if plan.offsets[i] + plan.rounded_sizes[i] > plan.arena_bytes {
            return false;
        }
        for j in (i + 1)..n {
            if conflicts.get(i, j) && plan.rounded_sizes[i] > 0 && plan.rounded_sizes[j] > 0 {
                let (a0, a1) = (plan.offsets[i], plan.offsets[i] + plan.rounded_sizes[i]);
                let (b0, b1) = (plan.offsets[j], plan.offsets[j] + plan.rounded_sizes[j]);
                if a0 < b1 && b0 < a1 {
                    return false;
                }
            }
        }
    }
    true
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn conflict_set_is_symmetric_and_counts_pairs() {
        let mut c = ConflictSet::new(5);
        c.set(0, 3);
        c.set(4, 1);
        c.set(2, 2); // self: ignored
        assert!(c.get(0, 3) && c.get(3, 0));
        assert!(c.get(1, 4) && c.get(4, 1));
        assert!(!c.get(2, 2));
        assert!(!c.get(0, 1));
        assert_eq!(c.n_conflicts(), 2);
    }

    /// Satellite regression: a known layout where the old break-on-first-
    /// fitting-gap scan wastes space. Tensors a..e pack to
    /// `[a | b | c | d | e]`; X conflicts {a, c, e} only, so it sees a
    /// loose 1536-byte hole (b's span) and a tight 1024-byte hole (d's
    /// span). Best-fit puts X over d, leaving the loose hole for Y
    /// (conflicts everything but b) — total 6656 bytes. First-fit put X
    /// in the loose hole, whose 512-byte remainder could not take Y, and
    /// paid 7680.
    #[test]
    fn tightest_gap_wins_and_the_packed_footprint_is_pinned() {
        let bytes = [2048u64, 1536, 1024, 1024, 1024, 1024, 1024];
        let (a, b, c, d, e, x, y) = (0, 1, 2, 3, 4, 5, 6);
        let mut conflicts = ConflictSet::new(7);
        for t in [b, c, d, e] {
            conflicts.set(a, t); // a..e pack end to end
        }
        for (i, j) in [(b, c), (b, d), (b, e), (c, d), (c, e), (d, e)] {
            conflicts.set(i, j);
        }
        for t in [a, c, e] {
            conflicts.set(x, t);
        }
        for t in [a, c, d, e, x] {
            conflicts.set(y, t);
        }
        let plan = plan_with_conflicts(&bytes, &conflicts);
        assert!(plan_respects_conflicts(&conflicts, &plan));
        assert_eq!(plan.offsets[..5], [0, 2048, 3584, 4608, 5632], "a..e pack end to end");
        assert_eq!(plan.offsets[x], 4608, "X takes the tight hole (aliases d)");
        assert_eq!(plan.offsets[y], 2048, "Y takes the loose hole (aliases b)");
        assert_eq!(plan.arena_bytes, 6656, "packed footprint is pinned");
        assert!(plan.arena_bytes < plan.unshared_bytes());
    }

    #[test]
    fn non_conflicting_tensors_share_and_conflicting_do_not() {
        let bytes = [4096u64, 4096];
        let free = ConflictSet::new(2);
        let shared = plan_with_conflicts(&bytes, &free);
        assert_eq!(shared.offsets[0], shared.offsets[1]);
        assert_eq!(shared.arena_bytes, 4096);

        let mut c = ConflictSet::new(2);
        c.set(0, 1);
        let split = plan_with_conflicts(&bytes, &c);
        assert_ne!(split.offsets[0], split.offsets[1]);
        assert_eq!(split.arena_bytes, 8192);
        assert!(plan_respects_conflicts(&c, &split));
    }

    #[test]
    fn zero_byte_tensors_reserve_nothing() {
        let bytes = [0u64, 1024, 0];
        let mut c = ConflictSet::new(3);
        c.set(0, 1);
        c.set(1, 2);
        let plan = plan_with_conflicts(&bytes, &c);
        assert_eq!(plan.rounded_sizes, vec![0, 1024, 0]);
        assert_eq!(plan.arena_bytes, 1024);
        assert!(plan_respects_conflicts(&c, &plan));
    }

    #[test]
    fn unshared_layout_lays_ranges_end_to_end() {
        let plan = ArenaPlan::unshared(&[100, 600, 0, 1024]);
        assert_eq!(plan.rounded_sizes, vec![512, 1024, 0, 1024]);
        assert_eq!(plan.offsets, vec![0, 512, 1536, 1536]);
        assert_eq!(plan.arena_bytes, 2560);
        assert_eq!(plan.unshared_bytes(), 2560);
    }

    #[test]
    fn holes_cover_everything_outside_the_written_extents() {
        let plan = ArenaPlan {
            offsets: vec![0, 1024, 1024],
            rounded_sizes: vec![512, 512, 512],
            arena_bytes: 2048,
        };
        // written extents smaller than reservations; slot 2 aliases 1
        let holes = plan.holes(&[100, 40, 512]);
        assert_eq!(holes, vec![(100, 1024), (1536, 2048)]);
        // zero-extent tensors are skipped entirely
        let all = plan.holes(&[0, 0, 0]);
        assert_eq!(all, vec![(0, 2048)]);
    }
}
