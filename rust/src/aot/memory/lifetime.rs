//! Lifetime analysis for replay tapes.
//!
//! Two notions of "when may two slots share memory":
//!
//! * **Serial intervals** ([`serial_lifetimes`], [`Lifetime`]) — def step
//!   to last-use step in the merged submission order. Sound only for
//!   single-thread replay: under the parallel executor, two slots that
//!   are disjoint in submission order can still be live *concurrently*
//!   (their records run on different streams with no ordering between
//!   them), so an arena packed from serial intervals would race.
//! * **Happens-before conflicts** ([`happens_before_conflicts`]) — two
//!   slots may alias only if **every** execution the executor can
//!   legally produce keeps them temporally disjoint: all accesses of one
//!   (its defining record plus every reader) must happen strictly before
//!   the other's defining record in the tape's happens-before order —
//!   per-stream FIFO submission order joined with the record→wait event
//!   edges from the sync plan. This is the relation the shared-arena
//!   executor packs against; it is a superset of the serial conflicts
//!   (an execution's liveness can only grow when the order is relaxed),
//!   and any plan the layouter emits is bounded by the unshared
//!   footprint.
//!
//! Special cases: the **output** slot is read by the caller after the
//! replay, so nothing defined later may overwrite it (it can only be
//! placed *over* retired early slots, never under later ones); **input**
//! slots are written by the coordinator *before* the replay starts, so
//! no slot may retire early enough to sit below one — inputs only give
//! memory away, they never take it.

use super::layout::ConflictSet;
use crate::aot::tape::{ReplayTape, TapeArg, TapeRole};
use crate::graph::{Dag, Reachability};

/// A tensor's lifetime in submission steps, inclusive.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Lifetime {
    pub def_step: usize,
    pub last_use_step: usize,
    pub bytes: u64,
}

impl Lifetime {
    pub(crate) fn overlaps(&self, other: &Lifetime) -> bool {
        self.def_step <= other.last_use_step && other.def_step <= self.last_use_step
    }
}

/// Interval lifetimes of a tape's slots in **merged submission order**
/// (step i = the tape's i-th record). Input slots are defined at step 0
/// (the coordinator fills them before the replay starts); the output
/// slot's last use is `n_ops` (the caller reads it after the replay).
pub fn serial_lifetimes(tape: &ReplayTape) -> Vec<Lifetime> {
    let n_slots = tape.n_slots();
    let mut def = vec![0usize; n_slots];
    let mut last = vec![0usize; n_slots];
    let bytes = tape.slot_bytes();
    for (step, op) in tape.ops().iter().enumerate() {
        let slot = op.out_slot as usize;
        def[slot] = if op.role == TapeRole::Input { 0 } else { step };
        last[slot] = last[slot].max(step);
        for arg in tape.args(op) {
            if let TapeArg::Slot(s) = arg {
                last[*s as usize] = last[*s as usize].max(step);
            }
        }
    }
    last[tape.output_slot()] = tape.n_ops();
    (0..n_slots)
        .map(|s| Lifetime { def_step: def[s], last_use_step: last[s], bytes: bytes[s] })
        .collect()
}

/// The happens-before DAG over a tape's records: per-stream FIFO edges
/// plus one edge from each event's recorder to every record waiting on
/// it. Every execution the parallel executor can produce is a
/// linearization of this order.
pub fn happens_before_dag(tape: &ReplayTape) -> Dag<()> {
    let mut h: Dag<()> = Dag::new();
    for _ in 0..tape.n_ops() {
        h.add_node(());
    }
    for s in 0..tape.n_streams() {
        for w in tape.stream_ops(s).windows(2) {
            h.add_edge(w[0] as usize, w[1] as usize);
        }
    }
    let mut recorder = vec![usize::MAX; tape.n_events()];
    for (i, op) in tape.ops().iter().enumerate() {
        for &e in tape.records(op) {
            recorder[e as usize] = i;
        }
    }
    for (i, op) in tape.ops().iter().enumerate() {
        for &e in tape.waits(op) {
            let src = recorder[e as usize];
            if src != usize::MAX && src != i && !h.has_edge(src, i) {
                h.add_edge(src, i);
            }
        }
    }
    h
}

/// Stream-aware aliasing: the slot pairs that must NOT share arena bytes
/// because some legal parallel execution can have both live at once.
///
/// Slot `a` may retire below slot `b` iff every access of `a` (defining
/// record and all readers) strictly happens-before `b`'s defining
/// record; two slots conflict iff neither retires below the other. The
/// output slot never retires (caller reads it after the replay); nothing
/// retires below an input slot (its bytes are written before the replay
/// starts). Never-written slots occupy no memory and conflict with
/// nothing.
pub fn happens_before_conflicts(tape: &ReplayTape) -> ConflictSet {
    let n_slots = tape.n_slots();
    let reach = Reachability::compute(&happens_before_dag(tape));

    let mut def = vec![usize::MAX; n_slots];
    let mut is_input = vec![false; n_slots];
    let mut readers: Vec<Vec<usize>> = vec![Vec::new(); n_slots];
    for (i, op) in tape.ops().iter().enumerate() {
        def[op.out_slot as usize] = i;
        if op.role == TapeRole::Input {
            is_input[op.out_slot as usize] = true;
        }
        for arg in tape.args(op) {
            if let TapeArg::Slot(s) = arg {
                readers[*s as usize].push(i);
            }
        }
    }
    let output = tape.output_slot();

    // `a` fully retires (def + all reads strictly happen-before) under
    // `b`'s defining record. Reachability is strict, so a reader that IS
    // b's def (b consumes a) correctly fails the test and forces a
    // conflict — argument slots never alias their consumer's output.
    let retires_below = |a: usize, b: usize| -> bool {
        if a == output || is_input[b] {
            return false;
        }
        let (da, db) = (def[a], def[b]);
        if da == usize::MAX || db == usize::MAX {
            return true; // a never-written slot has no footprint
        }
        reach.reaches(da, db) && readers[a].iter().all(|&r| r != db && reach.reaches(r, db))
    };

    let mut conflicts = ConflictSet::new(n_slots);
    for i in 0..n_slots {
        for j in (i + 1)..n_slots {
            if !(retires_below(i, j) || retires_below(j, i)) {
                conflicts.set(i, j);
            }
        }
    }
    conflicts
}

/// Interval-overlap conflicts of serial lifetimes (the single-thread
/// analysis, for comparison and for the serial-only arena plan).
pub fn interval_conflicts(lifetimes: &[Lifetime]) -> ConflictSet {
    let n = lifetimes.len();
    let mut conflicts = ConflictSet::new(n);
    for i in 0..n {
        if lifetimes[i].bytes == 0 {
            continue;
        }
        for j in (i + 1)..n {
            if lifetimes[j].bytes != 0 && lifetimes[i].overlaps(&lifetimes[j]) {
                conflicts.set(i, j);
            }
        }
    }
    conflicts
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::aot::memory::{plan_respects_conflicts, plan_with_conflicts};
    use crate::matching::MatchingAlgo;
    use crate::models;
    use crate::stream::rewrite::{rewrite, rewrite_single_stream};

    fn tapes(name: &str) -> (ReplayTape, ReplayTape) {
        let g = models::build(name, 1);
        let multi = ReplayTape::for_op_graph(&g, &rewrite(&g, MatchingAlgo::HopcroftKarp), 256);
        let single = ReplayTape::for_op_graph(&g, &rewrite_single_stream(&g), 256);
        (multi, single)
    }

    #[test]
    fn serial_lifetimes_cover_every_access() {
        let (tape, _) = tapes("mini_inception");
        let lts = serial_lifetimes(&tape);
        for (step, op) in tape.ops().iter().enumerate() {
            let out = &lts[op.out_slot as usize];
            assert!(out.def_step <= step && step <= out.last_use_step);
            for arg in tape.args(op) {
                if let TapeArg::Slot(s) = arg {
                    let l = &lts[*s as usize];
                    assert!(l.def_step <= step && step <= l.last_use_step, "use outside lifetime");
                }
            }
        }
        assert_eq!(lts[tape.output_slot()].last_use_step, tape.n_ops());
    }

    #[test]
    fn hb_conflicts_contain_the_serial_conflicts_on_single_stream() {
        // On a single-stream tape the happens-before order IS the
        // submission order, so both analyses agree exactly (modulo the
        // pessimistic interval treatment of inputs, which serial
        // lifetimes also pin at step 0).
        let (_, single) = tapes("mini_inception");
        let hb = happens_before_conflicts(&single);
        let serial = interval_conflicts(&serial_lifetimes(&single));
        for i in 0..single.n_slots() {
            for j in 0..single.n_slots() {
                assert_eq!(
                    hb.get(i, j),
                    serial.get(i, j),
                    "single-stream hb vs serial disagree on ({i}, {j})"
                );
            }
        }
    }

    #[test]
    fn hb_conflicts_are_a_superset_of_serial_conflicts_on_multi_stream() {
        for name in ["mini_inception", "inception_v3"] {
            let (multi, _) = tapes(name);
            let hb = happens_before_conflicts(&multi);
            let serial = interval_conflicts(&serial_lifetimes(&multi));
            for i in 0..multi.n_slots() {
                for j in 0..multi.n_slots() {
                    if serial.get(i, j) {
                        assert!(hb.get(i, j), "{name}: serial conflict ({i}, {j}) missing in hb");
                    }
                }
            }
            assert!(hb.n_conflicts() >= serial.n_conflicts());
        }
    }

    #[test]
    fn args_always_conflict_with_their_consumers_output() {
        let (multi, _) = tapes("mini_inception");
        let hb = happens_before_conflicts(&multi);
        for op in multi.ops() {
            for arg in multi.args(op) {
                if let TapeArg::Slot(s) = arg {
                    assert!(
                        hb.get(*s as usize, op.out_slot as usize),
                        "arg slot {s} may alias consumer slot {}",
                        op.out_slot
                    );
                }
            }
        }
    }

    #[test]
    fn output_conflicts_with_everything_defined_after_it_can_be_read() {
        // Nothing may retire *on top of* the output: for every written
        // slot b ≠ output, the pair (output, b) conflicts unless b fully
        // retires below the output's def.
        let (multi, _) = tapes("mini_inception");
        let hb = happens_before_conflicts(&multi);
        let out = multi.output_slot();
        let last = multi.ops().last().unwrap();
        assert_eq!(last.out_slot as usize, out);
        // the output's own arguments certainly conflict with it
        for arg in multi.args(last) {
            if let TapeArg::Slot(s) = arg {
                assert!(hb.get(*s as usize, out));
            }
        }
    }

    #[test]
    fn hb_arena_shares_memory_and_both_plans_stay_valid() {
        for name in ["mini_inception", "inception_v3"] {
            let (multi, _) = tapes(name);
            let bytes = multi.slot_bytes();
            let hb = happens_before_conflicts(&multi);
            let serial = interval_conflicts(&serial_lifetimes(&multi));
            let hb_plan = plan_with_conflicts(&bytes, &hb);
            let serial_plan = plan_with_conflicts(&bytes, &serial);
            assert!(plan_respects_conflicts(&hb, &hb_plan), "{name}: hb plan invalid");
            assert!(plan_respects_conflicts(&serial, &serial_plan), "{name}: serial plan invalid");
            // The planner never exceeds the no-sharing footprint, and on
            // these branchy multi-stream models it genuinely shares.
            assert!(serial_plan.arena_bytes <= serial_plan.unshared_bytes());
            assert!(
                hb_plan.arena_bytes < hb_plan.unshared_bytes(),
                "{name}: hb arena {} not below unshared {}",
                hb_plan.arena_bytes,
                hb_plan.unshared_bytes()
            );
        }
    }
}
