//! Reserved-memory planning (paper §4.1): "since a static neural network
//! makes the same sequence of memory requests for different runs, we can
//! pre-allocate the exact amount of GPU memory required for its execution."
//!
//! The subsystem has three layers:
//!
//! * [`lifetime`] — when may two tensors share bytes. Serial interval
//!   lifetimes (submission order) for single-thread replay, and the
//!   **stream-aware** happens-before analysis for the parallel executor:
//!   two slots alias only if every legal execution keeps them temporally
//!   disjoint (per-stream FIFO order joined with the sync plan's
//!   record→wait edges).
//! * [`layout`] — pack tensors into one contiguous arena against a
//!   [`ConflictSet`], best-fit-decreasing with a tightest-gap scan;
//!   emits the [`ArenaPlan`] the executor's slot arena resolves views
//!   from.
//! * [`pool`] — recycle arena backing buffers across context builds
//!   ([`ArenaPool`]), so serving lanes re-draw their per-lane arenas
//!   from bucket-sized classes instead of growing the heap.
//!
//! The executor ([`crate::engine::executor`]) packs against the
//! happens-before conflicts, keeps its zero-allocation hot path (views
//! are resolved at build), and — in debug builds — seeds the plan's
//! holes with canary words to catch overlap corruption.

pub mod layout;
pub mod lifetime;
pub mod pool;

pub use layout::{plan_respects_conflicts, plan_with_conflicts, ArenaPlan, ConflictSet};
pub use lifetime::{
    happens_before_conflicts, happens_before_dag, interval_conflicts, serial_lifetimes, Lifetime,
};
pub use pool::{ArenaLease, ArenaPool, ArenaPoolStats};

/// Plan an arena from interval lifetimes (the serial-order analysis —
/// see [`lifetime`] for when this is sound). Kept as the compact API the
/// PJRT task schedule uses; conflict-set callers go through
/// [`plan_with_conflicts`].
pub fn plan_arena(lifetimes: &[Lifetime]) -> ArenaPlan {
    let bytes: Vec<u64> = lifetimes.iter().map(|l| l.bytes).collect();
    plan_with_conflicts(&bytes, &interval_conflicts(lifetimes))
}

/// Verify no two lifetime-overlapping tensors share bytes (test helper
/// and debug assertion for the engine).
pub fn plan_is_valid(lifetimes: &[Lifetime], plan: &ArenaPlan) -> bool {
    plan_respects_conflicts(&interval_conflicts(lifetimes), plan)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::{prop, Pcg32};

    fn lt(def: usize, last: usize, bytes: u64) -> Lifetime {
        Lifetime { def_step: def, last_use_step: last, bytes }
    }

    #[test]
    fn disjoint_lifetimes_share_memory() {
        let lts = [lt(0, 1, 4096), lt(2, 3, 4096)];
        let plan = plan_arena(&lts);
        assert!(plan_is_valid(&lts, &plan));
        assert_eq!(plan.offsets[0], plan.offsets[1], "disjoint tensors reuse");
        assert!(plan.arena_bytes < plan.unshared_bytes());
    }

    #[test]
    fn overlapping_lifetimes_do_not_share() {
        let lts = [lt(0, 5, 4096), lt(2, 3, 4096)];
        let plan = plan_arena(&lts);
        assert!(plan_is_valid(&lts, &plan));
        assert_ne!(plan.offsets[0], plan.offsets[1]);
        assert_eq!(plan.arena_bytes, plan.unshared_bytes());
    }

    #[test]
    fn chain_arena_is_two_tensors_wide() {
        // A chain a→b→c→d: at any step at most two tensors live.
        let lts = [lt(0, 1, 1000), lt(1, 2, 1000), lt(2, 3, 1000), lt(3, 4, 1000)];
        let plan = plan_arena(&lts);
        assert!(plan_is_valid(&lts, &plan));
        assert_eq!(plan.arena_bytes, 2 * 1024);
    }

    #[test]
    fn empty_plan() {
        let plan = plan_arena(&[]);
        assert_eq!(plan.arena_bytes, 0);
    }

    #[test]
    fn random_plans_are_valid_and_never_worse_than_unshared() {
        prop::check("arena planner validity", 80, |rng: &mut Pcg32| {
            let n = rng.gen_range_inclusive(1, 40);
            let lts: Vec<Lifetime> = (0..n)
                .map(|_| {
                    let def = rng.gen_range(60);
                    let len = rng.gen_range(20);
                    lt(def, def + len, (rng.gen_range(100_000) + 1) as u64)
                })
                .collect();
            let plan = plan_arena(&lts);
            prop::ensure(plan_is_valid(&lts, &plan), || format!("invalid plan for {lts:?}"))?;
            prop::ensure(plan.arena_bytes <= plan.unshared_bytes(), || {
                "arena larger than unshared".to_string()
            })
        });
    }
}
