//! Task-schedule construction — the pre-run interception of paper §4.1.
//!
//! Build steps (mirroring Fig. 5):
//!   1. Build the operator DAG from the manifest node graph.
//!   2. Graph rewriter: Algorithm 1 stream assignment + sync plan
//!      (`stream::rewrite`), verified for max logical concurrency.
//!   3. Resolve every node once: executable handle, argument sources
//!      (slot of a producer's output, or a pre-staged weight buffer),
//!      output slot — the work the eager scheduler redoes every run.
//!   4. Reserve memory: lifetime-interval arena plan over the slots.
//!   5. Pre-run: execute the schedule once with a dummy input, validating
//!      the trace end-to-end before it is ever used for a request.

use anyhow::{Context, Result};
use std::sync::Arc;

use crate::aot::memory::{plan_arena, plan_is_valid, ArenaPlan, Lifetime};
use crate::graph::Dag;
use crate::matching::MatchingAlgo;
use crate::runtime::manifest::{InputRef, NodeEntry};
use crate::runtime::ArtifactRegistry;
use crate::stream::rewrite::rewrite_with;
use crate::stream::{assign_streams, verify::satisfies_max_logical_concurrency};

/// Where a task argument comes from.
#[derive(Clone)]
pub enum ArgSource {
    /// Output slot of an earlier task (or the input slot).
    Slot(usize),
    /// Pre-staged weight buffer (reserved at AoT time).
    Weight(Arc<xla::PjRtBuffer>),
}

/// One pre-resolved GPU task.
pub struct ReplayTask {
    pub name: String,
    pub exe: Arc<xla::PjRtLoadedExecutable>,
    pub args: Vec<ArgSource>,
    pub out_slot: usize,
    /// Stream id from Algorithm 1 (submission bookkeeping; execution on the
    /// CPU PJRT device is serial — see DESIGN.md §Hardware-Adaptation).
    pub stream: usize,
    pub wait_events: Vec<usize>,
    pub record_events: Vec<usize>,
    pub out_dims: Vec<usize>,
}

/// The task schedule: everything needed to run the network with zero
/// run-time scheduling.
pub struct TaskSchedule {
    pub tasks: Vec<ReplayTask>,
    pub n_slots: usize,
    pub input_slot: usize,
    pub output_slot: usize,
    pub input_dims: Vec<usize>,
    pub output_dims: Vec<usize>,
    pub n_streams: usize,
    pub n_events: usize,
    /// Reserved-memory plan (reported, and validated in tests).
    pub arena: ArenaPlan,
    pub batch: usize,
}

impl TaskSchedule {
    /// Build (and pre-run) the schedule for one batch size.
    pub fn build(registry: &ArtifactRegistry, batch: usize) -> Result<TaskSchedule> {
        let nodes: &[NodeEntry] = registry
            .manifest
            .graphs
            .get(&batch)
            .with_context(|| format!("no node graph for batch {batch}"))?;

        // --- 1. operator DAG (node 0 = the input placeholder). ---
        let mut dag: Dag<usize> = Dag::new();
        let input_id = dag.add_node(usize::MAX);
        let mut id_of = std::collections::HashMap::new();
        id_of.insert("input".to_string(), input_id);
        for (i, n) in nodes.iter().enumerate() {
            let id = dag.add_node(i);
            for inp in &n.inputs {
                if let InputRef::Node(dep) = inp {
                    dag.add_edge(id_of[dep], id);
                }
            }
            id_of.insert(n.name.clone(), id);
        }

        // --- 2. Algorithm 1 + rewriter. ---
        let assignment = assign_streams(&dag, MatchingAlgo::HopcroftKarp);
        debug_assert!(satisfies_max_logical_concurrency(&dag, &assignment.stream_of));
        let plan = rewrite_with(&dag, &assignment);

        // --- 3. resolve tasks in submission order. ---
        // slot i = output of dag node i (slot of input_id = the request input).
        let n_slots = dag.n_nodes();
        let mut tasks = Vec::with_capacity(nodes.len());
        for p in &plan.order {
            if p.node == input_id {
                continue; // virtual
            }
            let n = &nodes[*dag.node(p.node)];
            let exe = registry.executable(&n.artifact)?;
            let args = n
                .inputs
                .iter()
                .map(|inp| match inp {
                    InputRef::Node(dep) => Ok(ArgSource::Slot(id_of[dep])),
                    InputRef::Weight(w) => Ok(ArgSource::Weight(registry.weight(w)?)),
                })
                .collect::<Result<Vec<_>>>()?;
            tasks.push(ReplayTask {
                name: n.name.clone(),
                exe,
                args,
                out_slot: id_of[&n.name],
                stream: p.stream,
                wait_events: p.wait_events.clone(),
                record_events: p.record_events.clone(),
                out_dims: n.dims.clone(),
            });
        }

        // --- 4. reserved-memory plan over slot lifetimes. ---
        let input_dims = registry
            .manifest
            .inputs
            .get(&batch)
            .cloned()
            .with_context(|| format!("no input dims for batch {batch}"))?;
        let mut def_step = vec![0usize; n_slots];
        let mut last_use = vec![0usize; n_slots];
        let mut bytes = vec![0u64; n_slots];
        bytes[input_slot_of(input_id)] = 4 * input_dims.iter().product::<usize>() as u64;
        for (step, t) in tasks.iter().enumerate() {
            def_step[t.out_slot] = step + 1;
            last_use[t.out_slot] = step + 1;
            bytes[t.out_slot] = 4 * t.out_dims.iter().product::<usize>() as u64;
            for a in &t.args {
                if let ArgSource::Slot(s) = a {
                    last_use[*s] = last_use[*s].max(step + 1);
                }
            }
        }
        let output_slot = tasks.last().context("empty schedule")?.out_slot;
        last_use[output_slot] = tasks.len() + 1; // output survives the run
        let lifetimes: Vec<Lifetime> = (0..n_slots)
            .map(|s| Lifetime { def_step: def_step[s], last_use_step: last_use[s], bytes: bytes[s] })
            .collect();
        // Serial-interval lifetimes are sound here: `replay` submits in
        // recorded order on one PJRT thread. The parallel tape executor
        // packs against the stream-aware happens-before plan instead
        // (`aot::memory::happens_before_conflicts`).
        let arena = plan_arena(&lifetimes);
        debug_assert!(plan_is_valid(&lifetimes, &arena), "arena plan violates slot lifetimes");

        let output_dims = tasks.last().unwrap().out_dims.clone();
        let schedule = TaskSchedule {
            tasks,
            n_slots,
            input_slot: input_id,
            output_slot,
            input_dims,
            output_dims,
            n_streams: plan.n_streams,
            n_events: plan.n_events,
            arena,
            batch,
        };

        // --- 5. pre-run with a dummy input (validates the whole trace). ---
        let dummy = vec![0.0f32; schedule.input_dims.iter().product()];
        let out = schedule
            .replay(registry, &dummy)
            .context("AoT pre-run failed — schedule is invalid")?;
        anyhow::ensure!(
            out.len() == schedule.output_dims.iter().product::<usize>(),
            "pre-run output size mismatch"
        );
        Ok(schedule)
    }

    /// Replay the schedule for one input — the paper's run-time path: no
    /// shape checks, no dispatch, no allocation decisions; just task
    /// submission in the recorded order.
    pub fn replay(&self, registry: &ArtifactRegistry, input: &[f32]) -> Result<Vec<f32>> {
        self.replay_with_stats(registry, input).map(|(out, _)| out)
    }

    /// Replay, additionally reporting the wall time spent on submission
    /// bookkeeping (everything except `execute_b`) — the AoT counterpart of
    /// [`crate::engine::eager::EagerStats::sched_s`].
    pub fn replay_with_stats(
        &self,
        registry: &ArtifactRegistry,
        input: &[f32],
    ) -> Result<(Vec<f32>, f64)> {
        let client = &registry.client;
        let mut sched_s = 0.0f64;
        let mut slots: Vec<Option<xla::PjRtBuffer>> = (0..self.n_slots).map(|_| None).collect();
        slots[self.input_slot] = Some(client.buffer_f32(input, &self.input_dims)?);
        for t in &self.tasks {
            let out_buf = {
                let t0 = std::time::Instant::now();
                // Gather pre-bound arguments (raw pointer copies, no lookups).
                let mut args: Vec<&xla::PjRtBuffer> = Vec::with_capacity(t.args.len());
                for a in &t.args {
                    match a {
                        ArgSource::Slot(s) => {
                            args.push(slots[*s].as_ref().expect("slot written before use"))
                        }
                        ArgSource::Weight(w) => args.push(w.as_ref()),
                    }
                }
                sched_s += t0.elapsed().as_secs_f64();
                let mut out = t.exe.execute_b(&args)?;
                out.remove(0).remove(0)
            };
            slots[t.out_slot] = Some(out_buf);
        }
        let out = slots[self.output_slot].take().expect("output slot filled");
        Ok((client.to_host_f32(&out)?, sched_s))
    }

    /// Count of GPU tasks.
    pub fn n_tasks(&self) -> usize {
        self.tasks.len()
    }

    /// Pre-resolve the schedule into a reusable [`PreparedReplay`]: flat
    /// integer argument references, a weight table, a persistent slot
    /// table and a reused argument scratch — the PJRT counterpart of the
    /// tape executor's `ReplayContext`. Build once per (model, batch);
    /// the per-request loop then performs no slot-table or argument-
    /// vector allocation.
    pub fn prepare_replay(&self) -> PreparedReplay {
        let mut args = Vec::new();
        let mut ranges = Vec::with_capacity(self.tasks.len());
        let mut weights: Vec<Arc<xla::PjRtBuffer>> = Vec::new();
        let mut max_args = 0usize;
        for t in &self.tasks {
            let lo = args.len() as u32;
            for a in &t.args {
                match a {
                    ArgSource::Slot(s) => args.push(PreparedArg::Slot(*s as u32)),
                    ArgSource::Weight(w) => {
                        let idx = weights.len() as u32;
                        weights.push(w.clone());
                        args.push(PreparedArg::Weight(idx));
                    }
                }
            }
            ranges.push((lo, args.len() as u32));
            max_args = max_args.max(t.args.len());
        }
        PreparedReplay {
            args,
            ranges,
            weights,
            slots: (0..self.n_slots).map(|_| None).collect(),
            scratch: Vec::with_capacity(max_args),
        }
    }

    /// Replay through a [`PreparedReplay`], reporting submission
    /// bookkeeping time like [`replay_with_stats`](Self::replay_with_stats)
    /// — but with the slot table and argument scratch reused across
    /// requests instead of reallocated per request.
    pub fn replay_prepared(
        &self,
        registry: &ArtifactRegistry,
        prep: &mut PreparedReplay,
        input: &[f32],
    ) -> Result<(Vec<f32>, f64)> {
        let client = &registry.client;
        let mut sched_s = 0.0f64;
        for s in prep.slots.iter_mut() {
            *s = None; // release the previous request's buffers
        }
        prep.slots[self.input_slot] = Some(client.buffer_f32(input, &self.input_dims)?);
        for (t, &(lo, hi)) in self.tasks.iter().zip(&prep.ranges) {
            let t0 = std::time::Instant::now();
            prep.scratch.clear();
            for a in &prep.args[lo as usize..hi as usize] {
                let ptr: *const xla::PjRtBuffer = match a {
                    PreparedArg::Slot(s) => {
                        prep.slots[*s as usize].as_ref().expect("slot written before use")
                    }
                    PreparedArg::Weight(w) => prep.weights[*w as usize].as_ref(),
                };
                prep.scratch.push(ptr);
            }
            // SAFETY: `*const PjRtBuffer` and `&PjRtBuffer` have identical
            // layout; every pointer targets a buffer owned by `prep` or
            // the registry that stays alive (and unmoved) until
            // `execute_b` returns.
            let args: &[&xla::PjRtBuffer] = unsafe {
                std::slice::from_raw_parts(prep.scratch.as_ptr().cast(), prep.scratch.len())
            };
            sched_s += t0.elapsed().as_secs_f64();
            let mut out = t.exe.execute_b(args)?;
            prep.slots[t.out_slot] = Some(out.remove(0).remove(0));
        }
        let out = prep.slots[self.output_slot].take().expect("output slot filled");
        Ok((client.to_host_f32(&out)?, sched_s))
    }
}

/// Pre-resolved argument reference (integer indices only).
enum PreparedArg {
    Slot(u32),
    Weight(u32),
}

/// Reusable replay state for one [`TaskSchedule`]: persistent slot table,
/// weight table, and argument scratch. Not `Send` (holds raw pointers);
/// it lives on the engine thread like the PJRT state itself.
pub struct PreparedReplay {
    args: Vec<PreparedArg>,
    ranges: Vec<(u32, u32)>,
    weights: Vec<Arc<xla::PjRtBuffer>>,
    slots: Vec<Option<xla::PjRtBuffer>>,
    scratch: Vec<*const xla::PjRtBuffer>,
}

fn input_slot_of(input_id: usize) -> usize {
    input_id
}
