//! Reserved-memory planning (paper §4.1): "since a static neural network
//! makes the same sequence of memory requests for different runs, we can
//! pre-allocate the exact amount of GPU memory required for its execution."
//!
//! Given each tensor's size and lifetime interval (definition step → last
//! use step in the submission order), compute a static arena layout:
//! offsets such that tensors with overlapping lifetimes never overlap in
//! memory. Greedy best-fit over sorted-by-size tensors — the standard
//! static memory planner (cf. TFLite/TVM planners).

/// A tensor's lifetime in submission steps, inclusive.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Lifetime {
    pub def_step: usize,
    pub last_use_step: usize,
    pub bytes: u64,
}

impl Lifetime {
    fn overlaps(&self, other: &Lifetime) -> bool {
        self.def_step <= other.last_use_step && other.def_step <= self.last_use_step
    }
}

/// Planned arena: per-tensor offsets plus total footprint.
#[derive(Debug, Clone)]
pub struct ArenaPlan {
    /// offset per tensor (same indexing as the input lifetimes).
    pub offsets: Vec<u64>,
    pub rounded_sizes: Vec<u64>,
    pub arena_bytes: u64,
}

impl ArenaPlan {
    /// Sum of all rounded tensor sizes — what per-tensor allocation would
    /// cost without lifetime reuse.
    pub fn unshared_bytes(&self) -> u64 {
        self.rounded_sizes.iter().sum()
    }
}

/// Plan the arena. `O(n² )` interval checks — engine-build time, n = #tensors.
pub fn plan_arena(lifetimes: &[Lifetime]) -> ArenaPlan {
    let n = lifetimes.len();
    let rounded: Vec<u64> =
        lifetimes.iter().map(|l| crate::engine::alloc::round_size(l.bytes)).collect();
    // Place big tensors first (best-fit-decreasing).
    let mut order: Vec<usize> = (0..n).collect();
    order.sort_by_key(|&i| std::cmp::Reverse(rounded[i]));

    let mut offsets = vec![0u64; n];
    let mut placed: Vec<usize> = Vec::with_capacity(n);
    let mut arena = 0u64;
    for &i in &order {
        // Candidate gaps: collect placed tensors with overlapping lifetimes,
        // sorted by offset; slide through gaps first-fit.
        let mut conflicts: Vec<(u64, u64)> = placed
            .iter()
            .filter(|&&j| lifetimes[i].overlaps(&lifetimes[j]))
            .map(|&j| (offsets[j], offsets[j] + rounded[j]))
            .collect();
        conflicts.sort_unstable();
        let mut cursor = 0u64;
        for (start, end) in conflicts {
            if cursor + rounded[i] <= start {
                break; // fits in the gap before `start`
            }
            cursor = cursor.max(end);
        }
        offsets[i] = cursor;
        arena = arena.max(cursor + rounded[i]);
        placed.push(i);
    }
    ArenaPlan { offsets, rounded_sizes: rounded, arena_bytes: arena }
}

/// Verify no two lifetime-overlapping tensors share bytes (test helper and
/// debug assertion for the engine).
pub fn plan_is_valid(lifetimes: &[Lifetime], plan: &ArenaPlan) -> bool {
    let n = lifetimes.len();
    for i in 0..n {
        if plan.offsets[i] + plan.rounded_sizes[i] > plan.arena_bytes {
            return false;
        }
        for j in (i + 1)..n {
            if lifetimes[i].overlaps(&lifetimes[j]) {
                let (a0, a1) = (plan.offsets[i], plan.offsets[i] + plan.rounded_sizes[i]);
                let (b0, b1) = (plan.offsets[j], plan.offsets[j] + plan.rounded_sizes[j]);
                if a0 < b1 && b0 < a1 {
                    return false;
                }
            }
        }
    }
    true
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::{prop, Pcg32};

    fn lt(def: usize, last: usize, bytes: u64) -> Lifetime {
        Lifetime { def_step: def, last_use_step: last, bytes }
    }

    #[test]
    fn disjoint_lifetimes_share_memory() {
        let lts = [lt(0, 1, 4096), lt(2, 3, 4096)];
        let plan = plan_arena(&lts);
        assert!(plan_is_valid(&lts, &plan));
        assert_eq!(plan.offsets[0], plan.offsets[1], "disjoint tensors reuse");
        assert!(plan.arena_bytes < plan.unshared_bytes());
    }

    #[test]
    fn overlapping_lifetimes_do_not_share() {
        let lts = [lt(0, 5, 4096), lt(2, 3, 4096)];
        let plan = plan_arena(&lts);
        assert!(plan_is_valid(&lts, &plan));
        assert_ne!(plan.offsets[0], plan.offsets[1]);
        assert_eq!(plan.arena_bytes, plan.unshared_bytes());
    }

    #[test]
    fn chain_arena_is_two_tensors_wide() {
        // A chain a→b→c→d: at any step at most two tensors live.
        let lts = [lt(0, 1, 1000), lt(1, 2, 1000), lt(2, 3, 1000), lt(3, 4, 1000)];
        let plan = plan_arena(&lts);
        assert!(plan_is_valid(&lts, &plan));
        assert_eq!(plan.arena_bytes, 2 * 1024);
    }

    #[test]
    fn empty_plan() {
        let plan = plan_arena(&[]);
        assert_eq!(plan.arena_bytes, 0);
    }

    #[test]
    fn random_plans_are_valid_and_never_worse_than_unshared() {
        prop::check("arena planner validity", 80, |rng: &mut Pcg32| {
            let n = rng.gen_range_inclusive(1, 40);
            let lts: Vec<Lifetime> = (0..n)
                .map(|_| {
                    let def = rng.gen_range(60);
                    let len = rng.gen_range(20);
                    lt(def, def + len, (rng.gen_range(100_000) + 1) as u64)
                })
                .collect();
            let plan = plan_arena(&lts);
            prop::ensure(plan_is_valid(&lts, &plan), || format!("invalid plan for {lts:?}"))?;
            prop::ensure(plan.arena_bytes <= plan.unshared_bytes(), || {
                "arena larger than unshared".to_string()
            })
        });
    }
}
