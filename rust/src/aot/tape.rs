//! Replay tapes: the flat, fully-resolved form of a task schedule.
//!
//! A [`ReplayTape`] compiles a [`LaunchPlan`](crate::stream::LaunchPlan)
//! into per-stream submission *tapes* — contiguous arrays of
//! [`TapeOp`] records whose argument sources, output slot, and
//! wait/record event ids are all plain integers. No strings, no hash
//! lookups, no per-task `Vec`s: every variable-length list (arguments,
//! wait events, record events) lives in one shared flat array and each
//! record carries `(start, end)` index ranges into it. This is the
//! artifact the parallel executor ([`crate::engine::executor`]) walks at
//! request time with zero heap allocation per task, and the same
//! artifact the DES simulator replays to predict multi-stream speedups
//! ([`crate::sim::simulate_tape`]).
//!
//! Invariant: tapes are compiled from launch plans produced by the graph
//! rewriter, whose sync plans are verified operationally safe
//! (`stream::sync::plan_is_safe`): every dependency edge is realized by
//! same-stream FIFO order or a record→wait event pair. The executor's
//! memory-safety argument rests on this (see the executor docs).

use crate::graph::{Dag, NodeId};
use crate::ops::{OpGraph, OpKind};
use crate::stream::rewrite::NodePlan;
use crate::stream::LaunchPlan;

/// What a tape record does at replay time.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum TapeRole {
    /// Slot is filled by the caller before the replay starts; the record
    /// only fires its `record_events` (so cross-stream consumers of the
    /// input observe it through the normal event mechanism).
    Input,
    /// A real task: resolve args, execute, write the output slot.
    Task,
}

/// One pre-resolved argument source.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum TapeArg {
    /// Output slot of an earlier record (or an input slot).
    Slot(u32),
    /// Index into the context's pre-staged weight table.
    Weight(u32),
}

/// One record of the tape. All list-valued fields are `(start, end)`
/// ranges into the tape's flat arrays.
#[derive(Debug, Clone, Copy)]
pub struct TapeOp {
    /// Graph node this record came from (cost-table / trace index).
    pub node: u32,
    /// Stream the record is submitted on.
    pub stream: u32,
    pub role: TapeRole,
    /// Slot receiving this record's output.
    pub out_slot: u32,
    /// Output element count (slot arena pre-sizing).
    pub out_len: u32,
    args: (u32, u32),
    waits: (u32, u32),
    records: (u32, u32),
}

/// Per-node metadata the tape compiler needs beyond the launch plan.
pub struct NodeMeta {
    pub role: TapeRole,
    pub out_len: usize,
    pub args: Vec<TapeArg>,
}

/// The compiled tape: one record per graph node in submission order,
/// plus per-stream index lists and the shared flat arrays.
#[derive(Debug, Clone)]
pub struct ReplayTape {
    /// All records in global submission order (a topological order).
    ops: Vec<TapeOp>,
    /// Per-stream submission order: indices into `ops`.
    stream_ops: Vec<Vec<u32>>,
    args: Vec<TapeArg>,
    waits: Vec<u32>,
    records: Vec<u32>,
    n_slots: usize,
    n_events: usize,
    /// `(slot, len)` of every [`TapeRole::Input`] record, in submission order.
    input_slots: Vec<(usize, usize)>,
    output_slot: usize,
    max_args: usize,
}

impl ReplayTape {
    /// Compile a launch plan into a tape. `output` names the node whose
    /// slot holds the replay result; `meta` supplies per-node argument
    /// sources, output length and role.
    pub fn compile(
        plan: &LaunchPlan,
        output: NodeId,
        mut meta: impl FnMut(NodeId) -> NodeMeta,
    ) -> ReplayTape {
        let n_slots = plan.stream_of.len();
        let mut ops = Vec::with_capacity(plan.order.len());
        let mut stream_ops: Vec<Vec<u32>> = vec![Vec::new(); plan.n_streams.max(1)];
        let mut args = Vec::new();
        let mut waits = Vec::new();
        let mut records = Vec::new();
        let mut input_slots = Vec::new();
        let mut max_args = 0usize;

        for p in &plan.order {
            let m = meta(p.node);
            let (a0, w0, r0) = (args.len() as u32, waits.len() as u32, records.len() as u32);
            args.extend_from_slice(&m.args);
            waits.extend(p.wait_events.iter().map(|&e| e as u32));
            records.extend(p.record_events.iter().map(|&e| e as u32));
            max_args = max_args.max(m.args.len());
            if m.role == TapeRole::Input {
                assert!(m.args.is_empty(), "input records take no arguments");
                input_slots.push((p.node, m.out_len));
            }
            let idx = ops.len() as u32;
            ops.push(TapeOp {
                node: p.node as u32,
                stream: p.stream as u32,
                role: m.role,
                out_slot: p.node as u32,
                out_len: m.out_len as u32,
                args: (a0, args.len() as u32),
                waits: (w0, waits.len() as u32),
                records: (r0, records.len() as u32),
            });
            stream_ops[p.stream].push(idx);
        }

        ReplayTape {
            ops,
            stream_ops,
            args,
            waits,
            records,
            n_slots,
            n_events: plan.n_events,
            input_slots,
            output_slot: output,
            max_args,
        }
    }

    /// Compile a tape for an operator graph: arguments are the graph
    /// predecessors, `Input`-kind nodes become caller-filled input slots,
    /// and the last node in submission order is the output. Intermediate
    /// slot lengths are clamped to `max_task_elems` (the synthetic
    /// substrate does not need full activations; input slots keep their
    /// true length so request marshalling stays exact).
    pub fn for_op_graph(g: &OpGraph, plan: &LaunchPlan, max_task_elems: usize) -> ReplayTape {
        let output = plan.order.last().expect("non-empty plan").node;
        Self::compile(plan, output, |v| {
            let op = g.node(v);
            let numel = op.out_shape.numel().max(1);
            if matches!(op.kind, OpKind::Input) {
                NodeMeta { role: TapeRole::Input, out_len: numel, args: Vec::new() }
            } else {
                NodeMeta {
                    role: TapeRole::Task,
                    out_len: numel.min(max_task_elems.max(1)),
                    args: g.predecessors(v).iter().map(|&p| TapeArg::Slot(p as u32)).collect(),
                }
            }
        })
    }

    /// Compile a tape for a payload-free DAG (property tests): every node
    /// is a task, arguments are the predecessors, and output lengths are
    /// small deterministic pseudo-sizes derived from the node id.
    pub fn for_dag(g: &Dag<()>, plan: &LaunchPlan) -> ReplayTape {
        let output = plan.order.last().expect("non-empty plan").node;
        Self::compile(plan, output, |v| NodeMeta {
            role: TapeRole::Task,
            out_len: 17 + 13 * (v % 29),
            args: g.predecessors(v).iter().map(|&p| TapeArg::Slot(p as u32)).collect(),
        })
    }

    /// Reconstruct the equivalent [`LaunchPlan`] (exact inverse of
    /// [`compile`](Self::compile) for the plan-level fields) — this is
    /// how the DES simulator replays the tape.
    pub fn to_launch_plan(&self) -> LaunchPlan {
        let mut stream_of = vec![0usize; self.n_slots];
        let order = self
            .ops
            .iter()
            .map(|op| {
                stream_of[op.node as usize] = op.stream as usize;
                NodePlan {
                    node: op.node as usize,
                    stream: op.stream as usize,
                    wait_events: self.waits(op).iter().map(|&e| e as usize).collect(),
                    record_events: self.records(op).iter().map(|&e| e as usize).collect(),
                }
            })
            .collect();
        LaunchPlan {
            order,
            n_streams: self.n_streams(),
            n_events: self.n_events,
            stream_of,
        }
    }

    pub fn n_ops(&self) -> usize {
        self.ops.len()
    }

    /// Count of real (non-input) tasks.
    pub fn n_tasks(&self) -> usize {
        self.ops.iter().filter(|op| op.role == TapeRole::Task).count()
    }

    pub fn n_streams(&self) -> usize {
        self.stream_ops.len()
    }

    pub fn n_slots(&self) -> usize {
        self.n_slots
    }

    pub fn n_events(&self) -> usize {
        self.n_events
    }

    /// Largest argument count of any record (scratch pre-sizing).
    pub fn max_args(&self) -> usize {
        self.max_args
    }

    pub fn op(&self, i: usize) -> &TapeOp {
        &self.ops[i]
    }

    /// All records in global submission order.
    pub fn ops(&self) -> &[TapeOp] {
        &self.ops
    }

    /// Submission order of one stream (indices into [`ops`](Self::ops)).
    pub fn stream_ops(&self, stream: usize) -> &[u32] {
        &self.stream_ops[stream]
    }

    pub fn args(&self, op: &TapeOp) -> &[TapeArg] {
        &self.args[op.args.0 as usize..op.args.1 as usize]
    }

    pub fn n_args(&self, op: &TapeOp) -> usize {
        (op.args.1 - op.args.0) as usize
    }

    pub fn waits(&self, op: &TapeOp) -> &[u32] {
        &self.waits[op.waits.0 as usize..op.waits.1 as usize]
    }

    pub fn records(&self, op: &TapeOp) -> &[u32] {
        &self.records[op.records.0 as usize..op.records.1 as usize]
    }

    pub fn input_slots(&self) -> &[(usize, usize)] {
        &self.input_slots
    }

    pub fn output_slot(&self) -> usize {
        self.output_slot
    }

    /// Element count each slot's arena buffer needs (0 for never-written
    /// slots — possible only for plans that skip nodes).
    pub fn slot_lens(&self) -> Vec<usize> {
        let mut lens = vec![0usize; self.n_slots];
        for op in &self.ops {
            lens[op.out_slot as usize] = op.out_len as usize;
        }
        lens
    }

    /// Byte size of each slot's tensor (`f32` elements), the input to the
    /// reserved-memory planner ([`crate::aot::memory`]).
    pub fn slot_bytes(&self) -> Vec<u64> {
        self.slot_lens().iter().map(|&l| 4 * l as u64).collect()
    }

    /// Check that every slot-argument dependency is realized by the
    /// tape's own happens-before structure (same-stream FIFO order plus
    /// record→wait event edges), and that no record waits on an event
    /// nothing records. The parallel executor's slot arena relies on
    /// exactly this for data-race freedom, so
    /// [`ReplayContext`](crate::engine::executor::ReplayContext)
    /// refuses tapes that fail it — a mis-built plan becomes a loud
    /// construction-time error instead of undefined behavior.
    ///
    /// Since the static plan verifier landed this is a thin shim over
    /// [`crate::aot::verify::verify`]; callers needing the *why* (which
    /// record, which slot, a witness interleaving) should call the
    /// verifier directly and read the report. The pre-verifier
    /// implementation is kept as
    /// [`dependencies_are_synchronized_legacy`](Self::dependencies_are_synchronized_legacy)
    /// and pinned equivalent over seeded legal and mutated tapes in
    /// `tests/prop_harness.rs`.
    pub fn dependencies_are_synchronized(&self) -> bool {
        crate::aot::verify::verify(self).is_clean()
    }

    /// The pre-verifier synchronization check (`plan_is_safe` over the
    /// reconstructed dependency graph). Retained as the independent
    /// oracle for the verifier's equivalence property and the mutation
    /// harness — not meant for new callers.
    #[doc(hidden)]
    pub fn dependencies_are_synchronized_legacy(&self) -> bool {
        use crate::stream::sync::{plan_is_safe, Sync, SyncPlan};
        // Dependency graph: producer slot → consuming record.
        let mut deps: Dag<()> = Dag::new();
        for _ in 0..self.n_slots {
            deps.add_node(());
        }
        for op in &self.ops {
            for arg in self.args(op) {
                if let TapeArg::Slot(s) = arg {
                    if *s as usize == op.node as usize {
                        return false; // self-dependency can never be satisfied
                    }
                    deps.add_edge(*s as usize, op.node as usize);
                }
            }
        }
        if deps.validate().is_err() {
            return false;
        }
        // Event edges: the unique recorder of each awaited event. A
        // multiply-recorded event is rejected outright — the runtime
        // event table releases waiters at the FIRST record, so ordering
        // against any later recorder would be illusory.
        let mut recorder = vec![usize::MAX; self.n_events];
        for op in &self.ops {
            for &e in self.records(op) {
                if recorder[e as usize] != usize::MAX {
                    return false;
                }
                recorder[e as usize] = op.node as usize;
            }
        }
        let mut syncs = Vec::new();
        for op in &self.ops {
            for &e in self.waits(op) {
                let src = recorder[e as usize];
                if src == usize::MAX {
                    return false; // waiting on an event nothing records
                }
                syncs.push(Sync { src, dst: op.node as usize, event: e as usize });
            }
        }
        let plan = SyncPlan::new(syncs, self.n_slots);
        let order: Vec<usize> = self.ops.iter().map(|op| op.node as usize).collect();
        let mut stream_of = vec![0usize; self.n_slots];
        for op in &self.ops {
            stream_of[op.node as usize] = op.stream as usize;
        }
        plan_is_safe(&deps, &stream_of, &order, &plan)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::matching::MatchingAlgo;
    use crate::models;
    use crate::stream::rewrite::{rewrite, rewrite_single_stream};

    #[test]
    fn tape_covers_every_node_once_per_stream() {
        let g = models::build("mini_inception", 1);
        let plan = rewrite(&g, MatchingAlgo::HopcroftKarp);
        let tape = ReplayTape::for_op_graph(&g, &plan, 4096);
        assert_eq!(tape.n_ops(), g.n_nodes());
        assert_eq!(tape.n_streams(), plan.n_streams);
        let per_stream: usize = (0..tape.n_streams()).map(|s| tape.stream_ops(s).len()).sum();
        assert_eq!(per_stream, tape.n_ops());
        // per-stream lists preserve global submission order
        for s in 0..tape.n_streams() {
            let idxs = tape.stream_ops(s);
            assert!(idxs.windows(2).all(|w| w[0] < w[1]), "stream {s} order");
        }
        assert_eq!(tape.input_slots().len(), 1);
        assert_eq!(tape.n_tasks(), tape.n_ops() - 1);
    }

    #[test]
    fn tape_round_trips_to_the_same_launch_plan() {
        let g = models::build("mini_inception", 1);
        for plan in [rewrite(&g, MatchingAlgo::HopcroftKarp), rewrite_single_stream(&g)] {
            let tape = ReplayTape::for_op_graph(&g, &plan, 4096);
            let back = tape.to_launch_plan();
            assert_eq!(back.n_streams, plan.n_streams);
            assert_eq!(back.n_events, plan.n_events);
            assert_eq!(back.stream_of, plan.stream_of);
            assert_eq!(back.order, plan.order);
        }
    }

    #[test]
    fn args_waits_records_ranges_resolve() {
        let g = models::build("mini_inception", 1);
        let plan = rewrite(&g, MatchingAlgo::HopcroftKarp);
        let tape = ReplayTape::for_op_graph(&g, &plan, 4096);
        let mut seen_events = vec![0usize; tape.n_events()];
        for i in 0..tape.n_ops() {
            let op = *tape.op(i);
            let preds = g.predecessors(op.node as usize);
            assert_eq!(tape.n_args(&op), if op.role == TapeRole::Task { preds.len() } else { 0 });
            for (a, &p) in tape.args(&op).iter().zip(preds) {
                assert_eq!(*a, TapeArg::Slot(p as u32));
            }
            for &e in tape.records(&op) {
                seen_events[e as usize] += 1;
            }
        }
        assert!(seen_events.iter().all(|&c| c == 1), "each event recorded exactly once");
    }

    #[test]
    fn safe_plans_pass_the_synchronization_check_and_broken_ones_fail() {
        let g = models::build("mini_inception", 1);
        let plan = rewrite(&g, MatchingAlgo::HopcroftKarp);
        let tape = ReplayTape::for_op_graph(&g, &plan, 64);
        assert!(tape.dependencies_are_synchronized());
        assert!(ReplayTape::for_op_graph(&g, &rewrite_single_stream(&g), 64)
            .dependencies_are_synchronized());

        // Strip every wait from the multi-stream plan: cross-stream
        // dependencies lose their happens-before edges.
        let mut broken = plan.clone();
        let mut any_cross_stream_waits = false;
        for p in &mut broken.order {
            any_cross_stream_waits |= !p.wait_events.is_empty();
            p.wait_events.clear();
        }
        assert!(any_cross_stream_waits, "test premise: plan has syncs");
        let tape = ReplayTape::for_op_graph(&g, &broken, 64);
        assert!(!tape.dependencies_are_synchronized());
    }

    #[test]
    fn input_slots_keep_true_length_tasks_are_clamped() {
        let g = models::build("mini_inception", 8);
        let plan = rewrite(&g, MatchingAlgo::HopcroftKarp);
        let tape = ReplayTape::for_op_graph(&g, &plan, 64);
        let (slot, len) = tape.input_slots()[0];
        let input_numel = g.node(slot).out_shape.numel();
        assert_eq!(len, input_numel);
        assert!(input_numel > 64, "test premise: input bigger than the clamp");
        for op in tape.ops() {
            if op.role == TapeRole::Task {
                assert!(op.out_len <= 64);
            }
        }
    }
}
