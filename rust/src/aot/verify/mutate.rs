//! Seeded plan mutator: the verifier's self-test adversary.
//!
//! Each mutation class injects one specific bug family into a *legal*
//! compiled plan — drop a sync edge, retarget a wait, swap two records
//! across streams, collapse two arena offsets — and the property
//! harness asserts the verifier flags every mutant with the expected
//! diagnostic kind and a concrete witness (zero false negatives), while
//! the unmutated plans verify clean (zero false positives).
//!
//! Tape-level mutations round-trip through
//! [`ReplayTape::to_launch_plan`] → edit → [`ReplayTape::compile`], so
//! mutants are real tapes, not synthetic fixtures. A dropped or moved
//! sync edge does not always break a plan (a transitive FIFO path can
//! still realize the dependency), so candidates are filtered through
//! the *legacy* operational-safety oracle
//! ([`ReplayTape::dependencies_are_synchronized_legacy`], which predates
//! and is independent of the verifier): [`mutate`] only returns mutants
//! that oracle certifies broken, making "the verifier must flag this"
//! sound by construction.

use crate::aot::memory::ArenaPlan;
use crate::aot::tape::{NodeMeta, ReplayTape, TapeArg, TapeRole};
use crate::stream::LaunchPlan;
use crate::util::Pcg32;

/// The mutation classes.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum MutationKind {
    /// Remove one wait from a record: the sync edge it realized is gone.
    DropSync,
    /// Point one wait at a different event: orders against the wrong
    /// recorder (and can even close a wait/record cycle).
    RetargetWait,
    /// Swap the stream assignment of two records on different streams:
    /// FIFO ordering both relied on silently changes.
    SwapStreams,
    /// Collapse a producer's arena offset onto its consumer's output
    /// slot: aliased bytes with overlapping lifetimes.
    ShrinkOffset,
}

pub const ALL_MUTATIONS: [MutationKind; 4] = [
    MutationKind::DropSync,
    MutationKind::RetargetWait,
    MutationKind::SwapStreams,
    MutationKind::ShrinkOffset,
];

impl MutationKind {
    pub fn name(self) -> &'static str {
        match self {
            MutationKind::DropSync => "drop-sync",
            MutationKind::RetargetWait => "retarget-wait",
            MutationKind::SwapStreams => "swap-streams",
            MutationKind::ShrinkOffset => "shrink-offset",
        }
    }
}

/// A certified-broken mutant: the tape/arena pair plus what was done.
pub struct Mutant {
    pub tape: ReplayTape,
    pub arena: ArenaPlan,
    pub kind: MutationKind,
    pub description: String,
}

/// Recompile a tape after plan surgery, reconstructing each node's
/// metadata (role, output length, argument sources) from the original.
fn recompile(tape: &ReplayTape, plan: &LaunchPlan) -> ReplayTape {
    let mut by_node = vec![u32::MAX; tape.n_slots()];
    for (i, op) in tape.ops().iter().enumerate() {
        by_node[op.node as usize] = i as u32;
    }
    ReplayTape::compile(plan, tape.output_slot(), |v| {
        let op = tape.op(by_node[v] as usize);
        NodeMeta { role: op.role, out_len: op.out_len as usize, args: tape.args(op).to_vec() }
    })
}

/// Apply one seeded mutation of the given class to a legal plan.
/// Returns `None` when no candidate of that class breaks the plan (for
/// example a single-stream tape has no sync edges to drop); the caller
/// moves on to the next seed. Any returned mutant is oracle-certified
/// broken, so a verifier that misses it has a real false negative.
pub fn mutate(
    tape: &ReplayTape,
    arena: &ArenaPlan,
    kind: MutationKind,
    rng: &mut Pcg32,
) -> Option<Mutant> {
    match kind {
        MutationKind::DropSync => drop_sync(tape, arena, rng),
        MutationKind::RetargetWait => retarget_wait(tape, arena, rng),
        MutationKind::SwapStreams => swap_streams(tape, arena, rng),
        MutationKind::ShrinkOffset => shrink_offset(tape, arena, rng),
    }
}

fn broken(tape: &ReplayTape) -> bool {
    !tape.dependencies_are_synchronized_legacy()
}

fn drop_sync(tape: &ReplayTape, arena: &ArenaPlan, rng: &mut Pcg32) -> Option<Mutant> {
    let plan = tape.to_launch_plan();
    let mut cands: Vec<(usize, usize)> = plan
        .order
        .iter()
        .enumerate()
        .flat_map(|(i, p)| (0..p.wait_events.len()).map(move |w| (i, w)))
        .collect();
    rng.shuffle(&mut cands);
    for (i, w) in cands {
        let mut m = plan.clone();
        let e = m.order[i].wait_events.remove(w);
        let t = recompile(tape, &m);
        if broken(&t) {
            return Some(Mutant {
                tape: t,
                arena: arena.clone(),
                kind: MutationKind::DropSync,
                description: format!("dropped wait on event {e} at record #{i}"),
            });
        }
    }
    None
}

fn retarget_wait(tape: &ReplayTape, arena: &ArenaPlan, rng: &mut Pcg32) -> Option<Mutant> {
    let plan = tape.to_launch_plan();
    let mut cands: Vec<(usize, usize)> = plan
        .order
        .iter()
        .enumerate()
        .flat_map(|(i, p)| (0..p.wait_events.len()).map(move |w| (i, w)))
        .collect();
    rng.shuffle(&mut cands);
    for (i, w) in cands {
        let old = plan.order[i].wait_events[w];
        let mut events: Vec<usize> = (0..plan.n_events).filter(|&e| e != old).collect();
        rng.shuffle(&mut events);
        for e in events {
            let mut m = plan.clone();
            m.order[i].wait_events[w] = e;
            let t = recompile(tape, &m);
            if broken(&t) {
                return Some(Mutant {
                    tape: t,
                    arena: arena.clone(),
                    kind: MutationKind::RetargetWait,
                    description: format!("retargeted record #{i}'s wait from event {old} to {e}"),
                });
            }
        }
    }
    None
}

fn swap_streams(tape: &ReplayTape, arena: &ArenaPlan, rng: &mut Pcg32) -> Option<Mutant> {
    let plan = tape.to_launch_plan();
    let mut cands: Vec<(usize, usize)> = Vec::new();
    for i in 0..plan.order.len() {
        for j in i + 1..plan.order.len() {
            if plan.order[i].stream != plan.order[j].stream {
                cands.push((i, j));
            }
        }
    }
    rng.shuffle(&mut cands);
    for (i, j) in cands {
        let mut m = plan.clone();
        let (si, sj) = (m.order[i].stream, m.order[j].stream);
        m.order[i].stream = sj;
        m.order[j].stream = si;
        m.stream_of[m.order[i].node] = sj;
        m.stream_of[m.order[j].node] = si;
        let t = recompile(tape, &m);
        if broken(&t) {
            return Some(Mutant {
                tape: t,
                arena: arena.clone(),
                kind: MutationKind::SwapStreams,
                description: format!(
                    "swapped records #{i} (stream {si}) and #{j} (stream {sj}) across streams"
                ),
            });
        }
    }
    None
}

/// Collapse a producer slot's offset onto its consumer's output slot.
/// This is illegal by construction: the consumer reads the producer
/// while (or after) writing the same bytes, so neither slot's lifetime
/// can fully precede the other's definition — no oracle filtering is
/// needed, and the tape itself stays legal (only the arena is mutated).
fn shrink_offset(tape: &ReplayTape, arena: &ArenaPlan, rng: &mut Pcg32) -> Option<Mutant> {
    let bytes = tape.slot_bytes();
    let mut cands: Vec<(usize, usize)> = Vec::new();
    for op in tape.ops() {
        if op.role != TapeRole::Task || op.out_len == 0 {
            continue;
        }
        for arg in tape.args(op) {
            if let TapeArg::Slot(s) = arg {
                let s = *s as usize;
                if s != op.out_slot as usize && bytes[s] > 0 {
                    cands.push((op.out_slot as usize, s));
                }
            }
        }
    }
    if cands.is_empty() {
        return None;
    }
    let (consumer, producer) = cands[rng.gen_range(cands.len())];
    let mut plan = arena.clone();
    let old = plan.offsets[consumer];
    plan.offsets[consumer] = plan.offsets[producer];
    // Keep every extent inside the reservation so the only diagnostic
    // left is the aliasing itself.
    let end = plan.offsets[consumer] + bytes[consumer];
    plan.arena_bytes = plan.arena_bytes.max(end);
    Some(Mutant {
        tape: tape.clone(),
        arena: plan,
        kind: MutationKind::ShrinkOffset,
        description: format!(
            "moved slot {consumer}'s offset {old} onto its producer slot {producer}'s offset"
        ),
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::matching::MatchingAlgo;
    use crate::models;
    use crate::stream::rewrite::rewrite;

    #[test]
    fn mutants_round_trip_as_real_tapes_and_are_oracle_broken() {
        let g = models::build("mini_inception", 1);
        let plan = rewrite(&g, MatchingAlgo::HopcroftKarp);
        let tape = ReplayTape::for_op_graph(&g, &plan, 4096);
        let arena = ArenaPlan::unshared(&tape.slot_bytes());
        let mut rng = Pcg32::new(7);
        let mut produced = 0;
        for kind in ALL_MUTATIONS {
            let Some(m) = mutate(&tape, &arena, kind, &mut rng) else {
                continue;
            };
            produced += 1;
            assert_eq!(m.tape.n_ops(), tape.n_ops(), "{}: same shape", kind.name());
            assert_eq!(m.tape.output_slot(), tape.output_slot());
            if kind == MutationKind::ShrinkOffset {
                assert!(m.tape.dependencies_are_synchronized_legacy());
                assert_ne!(m.arena.offsets, arena.offsets);
            } else {
                assert!(!m.tape.dependencies_are_synchronized_legacy(), "{}", m.description);
            }
        }
        assert!(produced >= 3, "multi-stream tape yields most mutation classes");
    }
}
